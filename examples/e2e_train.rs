//! END-TO-END CAPSTONE (EXPERIMENTS.md §E2E): train the multi-million-
//! parameter STLT LM for a few hundred steps on the synthetic corpus,
//! log the loss curve, then exercise the full serving path (streaming a
//! long document + greedy generation) with the trained weights — every
//! layer of the stack composing.
//!
//! Backend-agnostic since the native `train_step` landed: the default
//! build runs the whole pipeline in pure Rust (hand-derived backward +
//! AdamW + data-parallel accumulation in `stlt::train`);
//! `STLT_BACKEND=xla` (with `--features xla` + `make artifacts`) runs
//! the Pallas-kernel HLO through PJRT instead.
//!
//! Run: cargo run --release --example e2e_train
//! Scale: STLT_E2E_STEPS (default 300), STLT_E2E_DOC (default 8192).

use anyhow::Result;
use stlt::coordinator::{Server, ServerOpts, TrainOpts};
use stlt::data::corpus::Corpus;
use stlt::harness;
use stlt::metrics::perplexity;
use stlt::runtime::{default_artifacts_dir, BackendKind, Manifest, Runtime};

fn main() -> Result<()> {
    stlt::util::logging::init();
    let backend = BackendKind::parse(
        &std::env::var("STLT_BACKEND").unwrap_or_else(|_| "native".into()),
    )?;
    let manifest = Manifest::load(default_artifacts_dir())?;
    let artifact = "lm_stlt_e2e";
    let steps = harness::env_u64("STLT_E2E_STEPS", 300);
    let doc_len = harness::env_u64("STLT_E2E_DOC", 8192) as usize;
    let entry = manifest.get(&format!("{artifact}.train"))?;
    println!(
        "== e2e[{}]: {} params, d={}, {} layers, S={}, vocab={}, {} steps ==",
        backend.name(),
        entry.param_count,
        entry.config.d_model,
        entry.config.n_layers,
        entry.config.s_max,
        entry.config.vocab,
        steps
    );
    let ckpt = harness::results_dir().join("ckpt/e2e.ckpt");
    let rt = Runtime::new(backend)?;
    let t0 = std::time::Instant::now();
    let opts = TrainOpts {
        steps,
        log_every: 10,
        eval_every: 50,
        eval_batches: 2,
        seed: 0,
        checkpoint: Some(ckpt.to_string_lossy().into_owned()),
        resume: None,
        domain: 0,
    };
    let report = stlt::coordinator::train_lm(&rt, &manifest, artifact, &opts)?;
    println!("\n## loss curve (step, mean loss)");
    for (s, l) in &report.loss_curve {
        println!("  {s:5} {l:.4}");
    }
    println!("## eval curve (step, ppl)");
    for (s, p) in &report.eval_curve {
        println!("  {s:5} {p:.3}");
    }
    println!(
        "final ppl {:.3} | {:.0} tokens/s | wall {:.0}s",
        report.final_ppl,
        report.tokens_per_s,
        t0.elapsed().as_secs_f64()
    );

    // serving path with trained weights
    let state = stlt::coordinator::load_checkpoint(&ckpt)?;
    let server = Server::start(
        &manifest,
        artifact,
        state.flat,
        ServerOpts { backend, ..Default::default() },
    )?;
    let mut corpus = Corpus::new(
        harness::long_corpus_cfg(entry.config.vocab),
        31337,
    );
    let doc = corpus.take(doc_len);
    let t1 = std::time::Instant::now();
    let fr = server.feed(1, doc.clone(), true)?;
    let stream_s = t1.elapsed().as_secs_f64();
    println!(
        "streamed {} tokens in {:.1}s ({:.0} tok/s), streaming ppl {:.3}",
        doc.len(),
        stream_s,
        doc.len() as f64 / stream_s,
        perplexity(fr.nll_sum, fr.count)
    );
    let gen = server.generate(1, *doc.last().unwrap(), 48, None)?;
    println!("greedy continuation ({} tokens): {:?}", gen.tokens.len(), gen.tokens);
    println!("feed latency: {}", server.stats.feed_latency.lock().unwrap().summary());
    server.shutdown();
    println!("e2e OK");
    Ok(())
}
