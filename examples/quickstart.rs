//! Quickstart: the whole stack in one file.
//!
//!   1. train a tiny STLT LM for a few steps,
//!   2. evaluate held-out perplexity,
//!   3. stream a long document through the serving coordinator with the
//!      O(S d) carry,
//!   4. greedy-generate a continuation.
//!
//! Runs on the default pure-Rust backend with zero external deps — the
//! committed `artifacts/manifest.json` metadata is all it needs:
//!
//!   cargo run --release --example quickstart
//!
//! `STLT_BACKEND=xla` switches to the AOT/PJRT path (requires
//! `--features xla` and `make artifacts`); `STLT_STEPS=N` scales.

use anyhow::Result;
use stlt::coordinator::{Server, ServerOpts, TrainOpts};
use stlt::data::corpus::{Corpus, CorpusConfig};
use stlt::metrics::perplexity;
use stlt::runtime::{default_artifacts_dir, BackendKind, Manifest, Runtime};

fn main() -> Result<()> {
    stlt::util::logging::init();
    let backend = BackendKind::parse(
        &std::env::var("STLT_BACKEND").unwrap_or_else(|_| "native".into()),
    )?;
    let manifest = Manifest::load(default_artifacts_dir())?;
    let artifact = "lm_stlt_tiny";
    let steps = stlt::harness::env_u64("STLT_STEPS", 60);
    let ckpt = stlt::harness::results_dir().join("ckpt/quickstart.ckpt");

    // 1. train: native = hand-derived backward + pure-Rust AdamW
    //    (stlt::train); xla = the optimiser graph inside the AOT HLO
    let rt = Runtime::new(backend)?;
    println!("== training {artifact} for {steps} steps on the {} backend ==", backend.name());
    let opts = TrainOpts {
        steps,
        log_every: 20,
        eval_every: 0,
        checkpoint: Some(ckpt.to_string_lossy().into_owned()),
        ..Default::default()
    };
    let report = stlt::coordinator::train_lm(&rt, &manifest, artifact, &opts)?;
    println!("loss curve: {:?}", report.loss_curve);

    // 2. evaluate
    println!("held-out ppl after {steps} steps: {:.2}", report.final_ppl);

    // 3+4. serve: stream a 2k-token document, then generate
    let state = stlt::coordinator::load_checkpoint(&ckpt)?;
    let server = Server::start(
        &manifest,
        artifact,
        state.flat,
        ServerOpts { backend, ..Default::default() },
    )?;
    let vocab = manifest.get(&format!("{artifact}.eval"))?.config.vocab;
    let mut corpus = Corpus::new(CorpusConfig::default_for_vocab(vocab), 2024);
    let doc = corpus.take(2048);
    let t0 = std::time::Instant::now();
    let fr = server.feed(1, doc.clone(), true)?;
    println!(
        "== streamed {} tokens in {:.2}s, streaming ppl {:.2} ==",
        doc.len(),
        t0.elapsed().as_secs_f64(),
        perplexity(fr.nll_sum, fr.count)
    );
    let gen = server.generate(1, *doc.last().unwrap(), 32, None)?;
    println!("greedy continuation: {:?}", gen.tokens);
    println!(
        "server stats: feeds={} gens={} streamed={} tokens",
        server.stats.feeds.load(std::sync::atomic::Ordering::Relaxed),
        server.stats.gens.load(std::sync::atomic::Ordering::Relaxed),
        server.stats.tokens_streamed.load(std::sync::atomic::Ordering::Relaxed)
    );
    server.shutdown();
    println!("quickstart OK");
    Ok(())
}
