//! Table 4 reproduction: ablations on learnability (sigma/omega/T),
//! node count S, adaptive allocation, and mask regularisation — plus a
//! bonus linear-vs-quadratic mode row (DESIGN.md R2).
//!
//! Run: cargo run --release --example exp_ablation

use anyhow::Result;
use stlt::harness::{self, Table};
use stlt::runtime::{default_artifacts_dir, Manifest, Runtime};

const VARIANTS: &[(&str, &str)] = &[
    ("lm_stlt_adaptive_tiny", "Full (adaptive S_max=64, learn sigma/omega/T)"),
    ("lm_abl_fixed_all_tiny", "Fixed sigma,omega,T (hand-tuned)"),
    ("lm_abl_no_omega_tiny", "omega=0 (no oscillation)"),
    ("lm_abl_fixed_sigma_tiny", "Fixed sigma (log-spaced)"),
    ("lm_abl_fixed_t_tiny", "Fixed T"),
    ("lm_abl_s16_tiny", "Fixed S=16"),
    ("lm_stlt_fixed32_tiny", "Fixed S=32"),
    ("lm_abl_s64_tiny", "Fixed S=64"),
    ("lm_abl_noreg_tiny", "No mask regularisation"),
    ("lm_abl_quadratic_tiny", "Quadratic (figure-faithful) mode"),
];

fn main() -> Result<()> {
    stlt::util::logging::init();
    let manifest = Manifest::load(default_artifacts_dir())?;
    let rt = Runtime::cpu()?;
    let steps = harness::exp_steps(300);
    let mut table = Table::new(
        &format!("Table 4 analogue: STLT ablations ({steps} steps)"),
        &["ppl", "s_eff", "params"],
    );
    for &(v, label) in VARIANTS {
        let (state, _) = harness::train_or_load(&rt, &manifest, v, steps, 0)?;
        let (ppl, s_eff) = harness::short_ppl(&rt, &manifest, v, &state.flat, 8, 0.0, 0)?;
        let params = manifest.get(&format!("{v}.train"))?.param_count;
        let row = table.row(label);
        row.insert("ppl".into(), format!("{ppl:.2}"));
        row.insert("s_eff".into(), format!("{s_eff:.1}"));
        row.insert("params".into(), format!("{params}"));
        stlt::info!("exp_abl", "{label}: ppl {ppl:.2} s_eff {s_eff:.1}");
    }
    println!("{}", table.render());
    table.save_json("table4")?;
    println!("(paper shape: full model best; fixed-everything and omega=0 worst; S=16 under-provisioned)");
    Ok(())
}
