//! Table 2 reproduction: synthetic machine translation BLEU across
//! seq2seq architectures (hybrid bilateral-encoder / unilateral-decoder
//! STLT vs attention-family baselines). DESIGN.md §3 documents the
//! WMT'14 substitution.
//!
//! Run: cargo run --release --example exp_mt

use anyhow::Result;
use stlt::data::translate::{TranslateConfig, TranslateGen};
use stlt::harness::{self, Table};
use stlt::metrics::bleu4;
use stlt::runtime::{default_artifacts_dir, Manifest, Runtime, S2sDecode, S2sTrainStep, TrainState};
use stlt::tokenizer::{BOS, EOS};

const VARIANTS: &[&str] = &[
    "s2s_vanilla_tiny",
    "s2s_linformer_tiny",
    "s2s_performer_tiny",
    "s2s_ssm_tiny",
    "s2s_stlt_fixed32_tiny",
    "s2s_stlt_adaptive_tiny",
];

fn train_s2s(
    rt: &Runtime,
    manifest: &Manifest,
    base: &str,
    steps: u64,
) -> Result<TrainState> {
    let ckpt = harness::results_dir().join("ckpt").join(format!("{base}_s{steps}.ckpt"));
    if ckpt.exists() {
        return stlt::coordinator::load_checkpoint(&ckpt);
    }
    let ts = S2sTrainStep::new(rt, manifest, &format!("{base}.train"))?;
    let entry = manifest.get(&format!("{base}.train"))?;
    let mut state = TrainState::from_entry(entry)?;
    let mut gen = TranslateGen::new(
        TranslateConfig::tiny(entry.config.vocab, ts.n_src, ts.m_tgt_plus_1 - 1),
        42,
    );
    for step in 0..steps {
        let (src, tgt, _) = gen.batch(ts.batch);
        let (loss, ce) = ts.run(&mut state, &src, &tgt, step as i32)?;
        if (step + 1) % 25 == 0 {
            stlt::info!("exp_mt", "{base} step {}/{steps} loss {loss:.4} ce {ce:.4}", step + 1);
        }
    }
    stlt::coordinator::save_checkpoint(&ckpt, &state, base)?;
    Ok(state)
}

fn greedy_bleu(
    rt: &Runtime,
    manifest: &Manifest,
    base: &str,
    flat: &[f32],
    n_test: usize,
) -> Result<f64> {
    let dec = S2sDecode::new(rt, manifest, &format!("{base}.decode"))?;
    let entry = manifest.get(&format!("{base}.decode"))?;
    // held-out pairs: disjoint seed from training
    let mut gen = TranslateGen::new(
        TranslateConfig::tiny(entry.config.vocab, dec.n_src, dec.m_tgt - 1),
        4242,
    );
    let b = dec.batch;
    let mut pairs = Vec::new();
    let mut done = 0usize;
    while done < n_test {
        let (src, _tgt, gold_pairs) = gen.batch(b);
        // greedy decode the whole batch in lockstep
        let mut prefix = vec![0i32; b * dec.m_tgt];
        for r in 0..b {
            prefix[r * dec.m_tgt] = BOS;
        }
        let mut finished = vec![false; b];
        let mut hyps: Vec<Vec<i32>> = vec![Vec::new(); b];
        for pos in 1..dec.m_tgt {
            let logits = dec.run(flat, &src, &prefix, pos as i32)?;
            let vocab = logits.len() / b;
            for r in 0..b {
                if finished[r] {
                    continue;
                }
                let tok =
                    stlt::metrics::argmax(&logits[r * vocab..(r + 1) * vocab]) as i32;
                prefix[r * dec.m_tgt + pos] = tok;
                if tok == EOS {
                    finished[r] = true;
                } else {
                    hyps[r].push(tok);
                }
            }
            if finished.iter().all(|&f| f) {
                break;
            }
        }
        for r in 0..b {
            pairs.push((hyps[r].clone(), gold_pairs[r].gold.clone()));
            done += 1;
        }
    }
    Ok(bleu4(&pairs))
}

fn main() -> Result<()> {
    stlt::util::logging::init();
    let manifest = Manifest::load(default_artifacts_dir())?;
    let rt = Runtime::cpu()?;
    let steps = harness::exp_steps(300);
    let n_test = harness::env_u64("STLT_MT_TEST", 32) as usize;
    let mut table = Table::new(
        &format!("Table 2 analogue: synthetic MT BLEU ({steps} steps, {n_test} test pairs)"),
        &["params", "bleu"],
    );
    for &v in VARIANTS {
        let state = train_s2s(&rt, &manifest, v, steps)?;
        let bleu = greedy_bleu(&rt, &manifest, v, &state.flat, n_test)?;
        let params = manifest.get(&format!("{v}.train"))?.param_count;
        let row = table.row(v);
        row.insert("params".into(), format!("{params}"));
        row.insert("bleu".into(), format!("{bleu:.2}"));
        stlt::info!("exp_mt", "{v}: BLEU {bleu:.2}");
    }
    println!("{}", table.render());
    table.save_json("table2")?;
    println!("(paper shape: stlt ≳ linformer/performer, competitive with vanilla)");
    Ok(())
}
