//! §4.7 reproduction: robustness to input noise and OOD domain shift.
//!
//! Reuses the Table-1 checkpoints (run exp_lm first — or this example
//! trains them on demand). Noise is injected *inside the lowered HLO*
//! (eval_step's noise_std input scales Gaussian noise on the input
//! embeddings); OOD evaluation swaps the corpus domain, which changes
//! the Markov tables and motif content but not the vocabulary.
//!
//! Run: cargo run --release --example exp_robustness

use anyhow::Result;
use stlt::harness::{self, Table};
use stlt::runtime::{default_artifacts_dir, Manifest, Runtime};

const VARIANTS: &[&str] = &["lm_vanilla_tiny", "lm_ssm_tiny", "lm_stlt_adaptive_tiny"];

fn main() -> Result<()> {
    stlt::util::logging::init();
    let manifest = Manifest::load(default_artifacts_dir())?;
    let rt = Runtime::cpu()?;
    let steps = harness::exp_steps(150);
    let mut table = Table::new(
        &format!("§4.7 analogue: robustness ({steps}-step models)"),
        &["ppl_clean", "ppl_n05", "ppl_n10", "degr_n10_pct", "ppl_ood", "degr_ood_pct"],
    );
    for &v in VARIANTS {
        let (state, _) = harness::train_or_load(&rt, &manifest, v, steps, 0)?;
        let (clean, _) = harness::short_ppl(&rt, &manifest, v, &state.flat, 8, 0.0, 0)?;
        let (n05, _) = harness::short_ppl(&rt, &manifest, v, &state.flat, 8, 0.5, 0)?;
        let (n10, _) = harness::short_ppl(&rt, &manifest, v, &state.flat, 8, 1.0, 0)?;
        let (ood, _) = harness::short_ppl(&rt, &manifest, v, &state.flat, 8, 0.0, 1)?;
        let row = table.row(v);
        row.insert("ppl_clean".into(), format!("{clean:.2}"));
        row.insert("ppl_n05".into(), format!("{n05:.2}"));
        row.insert("ppl_n10".into(), format!("{n10:.2}"));
        row.insert("degr_n10_pct".into(), format!("{:.1}", 100.0 * (n10 / clean - 1.0)));
        row.insert("ppl_ood".into(), format!("{ood:.2}"));
        row.insert("degr_ood_pct".into(), format!("{:.1}", 100.0 * (ood / clean - 1.0)));
        stlt::info!("exp_rob", "{v}: clean {clean:.2} noise1.0 {n10:.2} ood {ood:.2}");
    }
    println!("{}", table.render());
    table.save_json("robustness")?;
    println!("(paper shape: STLT's noise degradation ~10-15% milder than vanilla; OOD comparable or milder)");
    Ok(())
}
