//! Table 3 reproduction: long-document needle QA (NarrativeQA analogue).
//!
//! Trains an STLT LM and a vanilla-attention LM on QA-formatted episodes
//! (fact ... question -> answer), then evaluates token F1 as the
//! fact-to-question distance grows from "fits in one context window" to
//! tens of thousands of tokens. The streaming STLT carries the fact in
//! its O(S d) Laplace state; the chunked baseline physically cannot see
//! beyond its window — the paper's Table 3 separation.
//!
//! Run: cargo run --release --example exp_qa

use anyhow::Result;
use stlt::coordinator::Server;
use stlt::data::longqa::{QaConfig, QaGen};
use stlt::harness::{self, Table};
use stlt::metrics::f1::corpus_f1;
use stlt::runtime::{default_artifacts_dir, Manifest, Runtime, TrainState, TrainStep};

fn train_qa_lm(
    rt: &Runtime,
    manifest: &Manifest,
    base: &str,
    steps: u64,
) -> Result<TrainState> {
    let ckpt = harness::results_dir().join("ckpt").join(format!("{base}_qa_s{steps}.ckpt"));
    if ckpt.exists() {
        return stlt::coordinator::load_checkpoint(&ckpt);
    }
    let ts = TrainStep::new(rt, manifest, &format!("{base}.train"))?;
    let entry = manifest.get(&format!("{base}.train"))?;
    let mut state = TrainState::from_entry(entry)?;
    for step in 0..steps {
        let tokens = harness::qa_training_batch(
            entry.config.vocab,
            ts.batch,
            ts.n_plus_1,
            7,
            step,
        );
        let m = ts.run(&mut state, &tokens, step as i32)?;
        if (step + 1) % 50 == 0 {
            stlt::info!("exp_qa", "{base} step {}/{steps} loss {:.4}", step + 1, m.loss);
        }
    }
    stlt::coordinator::save_checkpoint(&ckpt, &state, base)?;
    Ok(state)
}

fn main() -> Result<()> {
    stlt::util::logging::init();
    let manifest = Manifest::load(default_artifacts_dir())?;
    let rt = Runtime::cpu()?;
    let steps = harness::exp_steps(300);
    let n_eval = harness::env_u64("STLT_QA_EVAL", 8) as usize;
    let distances: Vec<usize> = std::env::var("STLT_QA_DISTS")
        .map(|v| v.split(',').filter_map(|x| x.parse().ok()).collect())
        .unwrap_or_else(|_| vec![64, 512, 4096, 16384]);
    let vocab = manifest.get("lm_stlt_adaptive_tiny.train")?.config.vocab;

    let stlt_state = train_qa_lm(&rt, &manifest, "lm_stlt_adaptive_tiny", steps)?;
    let van_state = train_qa_lm(&rt, &manifest, "lm_vanilla_tiny", steps)?;

    let server = Server::start(
        &manifest,
        "lm_stlt_adaptive_tiny",
        stlt_state.flat.clone(),
        Default::default(),
    )?;

    let cols: Vec<String> = distances.iter().map(|d| format!("dist_{d}")).collect();
    let col_refs: Vec<&str> = cols.iter().map(String::as_str).collect();
    let mut table = Table::new(
        &format!("Table 3 analogue: long-document QA token F1 ({steps} steps, {n_eval} samples/distance)"),
        &col_refs,
    );
    let mut stlt_cells = std::collections::BTreeMap::new();
    let mut van_cells = std::collections::BTreeMap::new();
    for &dist in &distances {
        let mut gen = QaGen::new(QaConfig::with_distance(vocab, dist), 9999 + dist as u64);
        let mut stream_pairs = Vec::new();
        let mut chunk_pairs = Vec::new();
        for i in 0..n_eval {
            let s = gen.sample();
            let pred = harness::stream_qa_answer(&server, (dist * 1000 + i) as u64, &s, s.answer.len())?;
            stream_pairs.push((pred, s.answer.clone()));
            let predc = harness::chunked_generate(
                &rt, &manifest, "lm_vanilla_tiny", &van_state.flat, &s.prompt, s.answer.len(),
            )?;
            chunk_pairs.push((predc, s.answer.clone()));
        }
        let f1_stream = corpus_f1(&stream_pairs);
        let f1_chunk = corpus_f1(&chunk_pairs);
        stlt_cells.insert(format!("dist_{dist}"), format!("{f1_stream:.1}"));
        van_cells.insert(format!("dist_{dist}"), format!("{f1_chunk:.1}"));
        stlt::info!("exp_qa", "dist {dist}: stream F1 {f1_stream:.1}, chunked F1 {f1_chunk:.1}");
    }
    *table.row("stlt (stream 16k+)") = stlt_cells;
    *table.row("vanilla (chunked 128)") = van_cells;
    println!("{}", table.render());
    table.save_json("table3")?;
    println!("(paper shape: streaming holds F1 as distance grows; chunked collapses beyond its window)");
    server.shutdown();
    Ok(())
}
