//! Table 1 reproduction: language-modeling perplexity, short context
//! (WikiText-103 analogue) and long-document streaming/chunked context
//! (Project Gutenberg analogue). See DESIGN.md §3 for the dataset
//! substitution and §5 for the experiment index.
//!
//! Run: cargo run --release --example exp_lm   (STLT_STEPS=NN to scale)

use anyhow::Result;
use stlt::harness::{self, Table};
use stlt::runtime::{default_artifacts_dir, Manifest, Runtime};

const VARIANTS: &[&str] = &[
    "lm_vanilla_tiny",
    "lm_linformer_tiny",
    "lm_fnet_tiny",
    "lm_ssm_tiny",
    "lm_stlt_fixed32_tiny",
    "lm_stlt_adaptive_tiny",
];

fn main() -> Result<()> {
    stlt::util::logging::init();
    let manifest = Manifest::load(default_artifacts_dir())?;
    let rt = Runtime::cpu()?;
    let steps = harness::exp_steps(300);
    let long_len = harness::env_u64("STLT_LONG_LEN", 4096) as usize;
    let mut table = Table::new(
        &format!("Table 1 analogue: LM perplexity ({steps} steps, synthetic corpus)"),
        &["params", "ppl_short", "ppl_long", "long_mode", "s_eff"],
    );

    for &v in VARIANTS {
        let t0 = std::time::Instant::now();
        let (state, report) = harness::train_or_load(&rt, &manifest, v, steps, 0)?;
        let (ppl_short, s_eff) = harness::short_ppl(&rt, &manifest, v, &state.flat, 8, 0.0, 0)?;
        let is_stlt = v.contains("stlt");
        let (ppl_long, mode) = if is_stlt {
            (harness::stream_ppl(&rt, &manifest, v, &state.flat, long_len, 77)?, "stream")
        } else {
            (harness::chunked_ppl(&rt, &manifest, v, &state.flat, long_len, 77)?, "chunked")
        };
        let params = manifest.get(&format!("{v}.train"))?.param_count;
        let row = table.row(v);
        row.insert("params".into(), format!("{params}"));
        row.insert("ppl_short".into(), format!("{ppl_short:.2}"));
        row.insert("ppl_long".into(), format!("{ppl_long:.2}"));
        row.insert("long_mode".into(), mode.into());
        row.insert("s_eff".into(), format!("{s_eff:.1}"));
        stlt::info!(
            "exp_lm",
            "{v}: short {ppl_short:.2} long {ppl_long:.2} ({:.0}s{})",
            t0.elapsed().as_secs_f64(),
            report.map(|r| format!(", {:.0} tok/s", r.tokens_per_s)).unwrap_or_default()
        );
    }
    println!("{}", table.render());
    table.save_json("table1")?;
    println!("(paper shape: STLT < Linformer/FNet on ppl, ≈ SSM; streaming wins on long docs)");
    Ok(())
}
