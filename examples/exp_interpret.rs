//! §4.5 reproduction: interpretability of learned parameters.
//!
//! Dumps per-layer learned sigma spectra (-> token-relevance half-lives
//! ln2/sigma), oscillation frequencies omega, window bandwidths T, and
//! (for adaptive models) the expected S_eff — the quantities the paper
//! reads tea leaves from. Requires a trained checkpoint (exp_lm
//! produces one; this example trains on demand otherwise).
//!
//! Run: cargo run --release --example exp_interpret

use anyhow::Result;
use stlt::harness;
use stlt::interpret;
use stlt::runtime::{default_artifacts_dir, Manifest, Runtime};
use stlt::util::json::Json;

fn main() -> Result<()> {
    stlt::util::logging::init();
    let manifest = Manifest::load(default_artifacts_dir())?;
    let rt = Runtime::cpu()?;
    let steps = harness::exp_steps(150);
    let v = "lm_stlt_adaptive_tiny";
    let (state, _) = harness::train_or_load(&rt, &manifest, v, steps, 0)?;
    let cfg = &manifest.get(&format!("{v}.train"))?.config;

    println!("{}", interpret::inspect_stlt_params(&state.flat, cfg));

    // init-vs-learned comparison: how far training moved the nodes
    let entry = manifest.get(&format!("{v}.train"))?;
    let init = stlt::runtime::exec::load_init_vec(
        entry.init_file.as_ref().expect("init vec"),
        entry.param_count,
    )?;
    let learned = interpret::extract_nodes(&state.flat, cfg);
    let initial = interpret::extract_nodes(&init, cfg);
    println!("## parameter drift (init -> learned)");
    let mut rows = Vec::new();
    for (l0, l1) in initial.iter().zip(&learned) {
        let dsig: f32 = l0
            .sigma
            .iter()
            .zip(&l1.sigma)
            .map(|(a, b)| (a - b).abs())
            .sum::<f32>()
            / l0.sigma.len() as f32;
        let dom: f32 = l0
            .omega
            .iter()
            .zip(&l1.omega)
            .map(|(a, b)| (a - b).abs())
            .sum::<f32>()
            / l0.omega.len() as f32;
        println!(
            "  layer {}: mean |d sigma| {:.4}  mean |d omega| {:.4}  T {:.2} -> {:.2}",
            l0.layer, dsig, dom, l0.t, l1.t
        );
        let mut m = std::collections::BTreeMap::new();
        m.insert("layer".to_string(), Json::Num(l0.layer as f64));
        m.insert(
            "sigma".to_string(),
            Json::Arr(l1.sigma.iter().map(|&x| Json::Num(x as f64)).collect()),
        );
        m.insert(
            "omega".to_string(),
            Json::Arr(l1.omega.iter().map(|&x| Json::Num(x as f64)).collect()),
        );
        m.insert("t".to_string(), Json::Num(l1.t as f64));
        m.insert(
            "half_lives".to_string(),
            Json::Arr(l1.half_lives.iter().map(|&x| Json::Num(x as f64)).collect()),
        );
        rows.push(Json::Obj(m));
    }
    let out = harness::results_dir().join("interpret.json");
    std::fs::write(&out, Json::Arr(rows).to_string())?;
    println!("wrote {}", out.display());
    Ok(())
}
