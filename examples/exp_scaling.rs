//! §4.6 reproduction: computational efficiency and scalability.
//!
//! Measures forward wall-clock vs sequence length for linear-mode STLT,
//! quadratic-mode STLT (figure-faithful) and vanilla attention, fits the
//! scaling exponent, and reports the streaming state footprint (O(S d),
//! constant in N) against an attention KV-cache model (O(N d)).
//!
//! Run: cargo run --release --example exp_scaling

use anyhow::Result;
use stlt::bench::{bench_for, fmt_time};
use stlt::harness::Table;
use stlt::runtime::{default_artifacts_dir, exec::init_vec_host, Forward, Manifest, Runtime, StreamStep};

fn fit_exponent(points: &[(usize, f64)]) -> f64 {
    // least-squares slope in log-log space
    let n = points.len() as f64;
    let (mut sx, mut sy, mut sxx, mut sxy) = (0.0, 0.0, 0.0, 0.0);
    for &(x, y) in points {
        let lx = (x as f64).ln();
        let ly = y.ln();
        sx += lx;
        sy += ly;
        sxx += lx * lx;
        sxy += lx * ly;
    }
    (n * sxy - sx * sy) / (n * sxx - sx * sx)
}

fn sweep(
    rt: &Runtime,
    manifest: &Manifest,
    prefix: &str,
    ns: &[usize],
) -> Result<Vec<(usize, f64)>> {
    let mut out = Vec::new();
    for &n in ns {
        let name = format!("{prefix}{n}.fwd");
        let fwd = Forward::new(rt, manifest, &name)?;
        let entry = manifest.get(&name)?;
        let flat = init_vec_host(entry.param_count, 1);
        let tokens: Vec<i32> = (0..n as i32).map(|i| 4 + (i % 200)).collect();
        let r = bench_for(&name, 1.0, || {
            let _ = fwd.run(&flat, &tokens).unwrap();
        });
        stlt::info!("exp_scaling", "{name}: p50 {}", fmt_time(r.p50_s));
        out.push((n, r.p50_s));
    }
    Ok(out)
}

fn main() -> Result<()> {
    stlt::util::logging::init();
    let manifest = Manifest::load(default_artifacts_dir())?;
    let rt = Runtime::cpu()?;

    let stlt_pts = sweep(&rt, &manifest, "scale_stlt_n", &[256, 512, 1024, 2048, 4096])?;
    let stltq_pts = sweep(&rt, &manifest, "scale_stltq_n", &[256, 512, 1024])?;
    let van_pts = sweep(&rt, &manifest, "scale_vanilla_n", &[256, 512, 1024, 2048])?;

    let mut table = Table::new(
        "§4.6 analogue: forward latency vs N (d=64, 2 layers, 1-core CPU PJRT)",
        &["n256", "n512", "n1024", "n2048", "n4096", "exponent"],
    );
    for (label, pts) in [
        ("stlt linear O(N S d)", &stlt_pts),
        ("stlt quadratic (fig.1)", &stltq_pts),
        ("vanilla attention O(N^2)", &van_pts),
    ] {
        let row = table.row(label);
        for (n, t) in pts {
            row.insert(format!("n{n}"), fmt_time(*t));
        }
        row.insert("exponent".into(), format!("{:.2}", fit_exponent(pts)));
    }
    println!("{}", table.render());
    table.save_json("fig_scaling")?;

    // memory: streaming state is constant in N; attention KV grows linearly
    let stream = StreamStep::new(&rt, &manifest, "lm_stlt_tiny.stream")?;
    let carry = stream.zero_carry();
    let entry = manifest.get("lm_stlt_tiny.stream")?;
    let d = entry.config.d_model;
    let layers = entry.config.n_layers;
    println!("\n## streaming state vs attention KV (per sequence)");
    println!("{:>10} {:>16} {:>16}", "N", "stlt carry", "attention KV");
    for n in [1024usize, 8192, 65536, 131072] {
        let kv = 2 * layers * n * d * 4; // K+V per layer, f32
        println!(
            "{:>10} {:>16} {:>16}",
            n,
            format!("{} KB", carry.state_bytes() / 1024),
            format!("{} KB", kv / 1024)
        );
    }
    println!("\n(paper shape: linear-mode exponent ~1, attention ~2; carry constant in N)");
    Ok(())
}
