"""Model trunk + baselines + seq2seq: shapes, causality, learnability."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import optim, seq2seq, train, trunk
from compile.config import ModelConfig

ARCHS = ["stlt", "vanilla", "linformer", "fnet", "ssm", "performer"]


def cfg(arch, **kw):
    base = dict(
        arch=arch, vocab=64, d_model=16, n_layers=2, n_ctx=32, s_max=8,
        batch=2, adaptive=(arch == "stlt" and kw.pop("adaptive", False)),
    )
    base.update(kw)
    return ModelConfig(**base)


def toks(c, seed=0, n=None):
    rng = np.random.default_rng(seed)
    n = n or c.n_ctx
    return jnp.asarray(rng.integers(4, c.vocab, (c.batch, n)), jnp.int32)


@pytest.mark.parametrize("arch", ARCHS)
def test_logits_shape(arch):
    c = cfg(arch)
    p = trunk.init(c)
    logits, reg, seff = trunk.apply(p, toks(c), c)
    assert logits.shape == (c.batch, c.n_ctx, c.vocab)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_causality_of_lm(arch):
    """Changing the last input token must not change earlier logits."""
    c = cfg(arch)
    p = trunk.init(c)
    t1 = toks(c, 1)
    logits1, _, _ = trunk.apply(p, t1, c)
    t2 = t1.at[:, -1].set((t1[:, -1] + 7) % (c.vocab - 4) + 4)
    logits2, _, _ = trunk.apply(p, t2, c)
    np.testing.assert_allclose(
        np.asarray(logits1[:, :-1]), np.asarray(logits2[:, :-1]), atol=2e-4,
        err_msg=f"{arch} leaks future information",
    )


@pytest.mark.parametrize("arch", ["stlt", "vanilla", "ssm"])
def test_loss_decreases_on_overfit(arch):
    """A few SGD steps on one repeated batch must reduce the loss."""
    c = cfg(arch, total_steps=50, warmup=1, lr=3e-3)
    tmpl = train.make_template(c)
    step_fn = jax.jit(train.make_train_step(c, tmpl))
    flat = optim.pack(tmpl)
    m = jnp.zeros_like(flat)
    v = jnp.zeros_like(flat)
    batch = toks(c, 3, n=c.n_ctx + 1)
    losses = []
    for i in range(12):
        flat, m, v, loss, ce, _ = step_fn(flat, m, v, jnp.int32(i), batch, jnp.int32(0))
        losses.append(float(ce))
    assert losses[-1] < losses[0] - 0.05, f"{arch}: {losses[0]:.3f} -> {losses[-1]:.3f}"


def test_eval_step_counts_tokens():
    c = cfg("stlt")
    tmpl = train.make_template(c)
    ev = jax.jit(train.make_eval_step(c, tmpl))
    flat = optim.pack(tmpl)
    nll, count, _ = ev(flat, toks(c, 0, c.n_ctx + 1), jnp.float32(0.0), jnp.int32(0))
    assert int(count) == c.batch * c.n_ctx
    assert float(nll) > 0


def test_eval_noise_degrades():
    c = cfg("stlt")
    tmpl = train.make_template(c)
    ev = jax.jit(train.make_eval_step(c, tmpl))
    flat = optim.pack(tmpl)
    t = toks(c, 0, c.n_ctx + 1)
    nll0, cnt, _ = ev(flat, t, jnp.float32(0.0), jnp.int32(0))
    nll5, _, _ = ev(flat, t, jnp.float32(5.0), jnp.int32(0))
    # with an untrained model the effect is small but noise must change nll
    assert float(nll0) != float(nll5)


def test_stream_trunk_matches_full_forward():
    """The streaming path (decode/serving) must equal the batch forward."""
    c = cfg("stlt")
    p = trunk.init(c)
    t = toks(c, 5)[0:1]
    logits_full, _, _ = trunk.apply(p, t, c, train=False)
    ls, us = train.carry_shapes(c)
    l_carry = jnp.zeros(ls)
    u_carry = jnp.zeros(us)
    outs = []
    chunk = 8
    for i in range(0, c.n_ctx, chunk):
        logits, l_carry, u_carry = train._stream_trunk(p, t[0, i : i + chunk], c, l_carry, u_carry)
        outs.append(logits)
    stream_logits = jnp.concatenate(outs)
    np.testing.assert_allclose(
        np.asarray(logits_full[0]), np.asarray(stream_logits), atol=5e-4, rtol=5e-4
    )


def test_decode_step_consistency():
    """decode_step == stream_step fed one token at a time."""
    c = cfg("stlt")
    tmpl = train.make_template(c)
    flat = optim.pack(tmpl)
    dec = jax.jit(train.make_decode_step(c, tmpl))
    ls, us = train.carry_shapes(c)
    l1, u1 = jnp.zeros(ls), jnp.zeros(us)
    seq = [5, 9, 11, 40]
    outs = []
    for t in seq:
        l1, u1, logits = dec(flat, l1, u1, jnp.asarray([t], jnp.int32))
        outs.append(logits)
    p = optim.unpack(flat, tmpl)
    full, _, _ = trunk.apply(p, jnp.asarray([seq], jnp.int32), c)
    np.testing.assert_allclose(np.asarray(outs[-1]), np.asarray(full[0, -1]), atol=5e-4, rtol=5e-4)


def test_stream_batch_active_gating():
    """Inactive rows keep carries; active rows advance."""
    c = cfg("stlt")
    tmpl = train.make_template(c)
    flat = optim.pack(tmpl)
    sb = jax.jit(train.make_stream_batch_step(c, tmpl))
    b, chunk = 2, 8
    ls, us = train.carry_shapes(c)
    l0 = jnp.ones((b, *ls)) * 0.1
    u0 = jnp.ones((b, *us)) * 0.2
    t = jnp.asarray(np.random.default_rng(0).integers(4, 64, (b, chunk)), jnp.int32)
    mask = jnp.ones((b, chunk))
    active = jnp.asarray([1.0, 0.0])
    l1, u1, nll, cnt = sb(flat, l0, u0, t, t, mask, active)
    assert not np.allclose(np.asarray(l1[0]), np.asarray(l0[0]))
    np.testing.assert_allclose(np.asarray(l1[1]), np.asarray(l0[1]))
    np.testing.assert_allclose(np.asarray(u1[1]), np.asarray(u0[1]))
    assert float(nll[1]) == 0.0 and float(cnt[1]) == 0.0
    assert float(cnt[0]) == chunk


# ---------------------------------------------------------------------------
# seq2seq
# ---------------------------------------------------------------------------

S2S_ARCHS = ["stlt", "vanilla", "performer"]


@pytest.mark.parametrize("arch", S2S_ARCHS)
def test_s2s_shapes(arch):
    c = cfg(arch)
    p = seq2seq.init(c)
    src = toks(c, 0, 16)
    tgt_in = toks(c, 1, 12)
    enc = seq2seq.encode(p, src, c)
    assert enc.shape == (c.batch, 16, c.d_model)
    logits, reg = seq2seq.decode(p, tgt_in, enc, c)
    assert logits.shape == (c.batch, 12, c.vocab)


def test_s2s_decoder_is_causal_in_target():
    c = cfg("stlt")
    p = seq2seq.init(c)
    src = toks(c, 0, 16)
    t1 = toks(c, 1, 12)
    enc = seq2seq.encode(p, src, c)
    l1, _ = seq2seq.decode(p, t1, enc, c)
    t2 = t1.at[:, -1].set(4)
    l2, _ = seq2seq.decode(p, t2, enc, c)
    np.testing.assert_allclose(np.asarray(l1[:, :-1]), np.asarray(l2[:, :-1]), atol=2e-4)


def test_s2s_decoder_attends_to_source():
    c = cfg("stlt")
    p = seq2seq.init(c)
    s1 = toks(c, 0, 16)
    t = toks(c, 1, 12)
    l1, _ = seq2seq.decode(p, t, seq2seq.encode(p, s1, c), c)
    s2 = s1.at[:, 0].set((s1[:, 0] + 3) % 60 + 4)
    l2, _ = seq2seq.decode(p, t, seq2seq.encode(p, s2, c), c)
    assert not np.allclose(np.asarray(l1), np.asarray(l2), atol=1e-5)


def test_s2s_loss_masks_padding():
    c = cfg("stlt")
    p = seq2seq.init(c)
    src = toks(c, 0, 16)
    tgt = jnp.concatenate(
        [toks(c, 1, 8), jnp.zeros((c.batch, 5), jnp.int32)], axis=1
    )  # pad tail
    loss, ce = seq2seq.s2s_loss(p, src, tgt, c)
    assert np.isfinite(float(loss))
    # all-pad targets -> ce must be 0 contribution (degenerate case)
    tgt_allpad = jnp.zeros((c.batch, 13), jnp.int32)
    _, ce0 = seq2seq.s2s_loss(p, src, tgt_allpad, c)
    assert float(ce0) == 0.0
