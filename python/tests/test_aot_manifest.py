"""aot.py manifest consistency: shapes recorded in the manifest match
what the entry functions actually produce, and init vectors match
param_count. Runs against a freshly-built single-group manifest in tmp
(does not require `make artifacts`)."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

HERE = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))  # python/


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    r = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--outdir", str(out), "--groups", "core"],
        cwd=HERE,
        capture_output=True,
        text=True,
        timeout=600,
    )
    assert r.returncode == 0, r.stderr[-2000:]
    return out


def manifest(built):
    with open(built / "manifest.json") as f:
        return json.load(f)


def test_manifest_entries_exist(built):
    m = manifest(built)
    assert m["version"] == 1
    names = set(m["entries"].keys())
    for suffix in ["train", "eval", "fwd", "stream", "decode", "stream_batch"]:
        assert f"lm_stlt_tiny.{suffix}" in names


def test_files_exist_and_parse_header(built):
    m = manifest(built)
    for name, e in m["entries"].items():
        path = built / e["file"]
        assert path.exists(), name
        head = path.read_text()[:200]
        assert "HloModule" in head, f"{name} does not look like HLO text"


def test_init_vector_length(built):
    m = manifest(built)
    e = m["entries"]["lm_stlt_tiny.train"]
    init = built / e["init"]
    data = np.fromfile(init, dtype=np.float32)
    assert data.size == e["param_count"]
    assert np.isfinite(data).all()
    # layer-norm gains exist: some exact 1.0 entries
    assert (data == 1.0).sum() > 0


def test_shapes_consistent_with_config(built):
    m = manifest(built)
    e = m["entries"]["lm_stlt_tiny.train"]
    cfg = e["config"]
    tok_spec = e["inputs"][4]
    assert tok_spec["shape"] == [cfg["batch"], cfg["n_ctx"] + 1]
    assert e["inputs"][0]["shape"] == [e["param_count"]]
    # outputs: flat', m', v', loss, ce, s_eff
    assert e["outputs"][0]["shape"] == [e["param_count"]]
    assert e["outputs"][3]["shape"] == []


def test_stream_carry_shapes(built):
    m = manifest(built)
    e = m["entries"]["lm_stlt_tiny.stream"]
    cfg = e["config"]
    l_shape = e["inputs"][1]["shape"]
    u_shape = e["inputs"][2]["shape"]
    assert l_shape == [cfg["n_layers"], cfg["s_max"], 2]
    assert u_shape == [cfg["n_layers"], cfg["s_max"], cfg["d_model"], 2]
    # stream_batch adds the serving batch dim
    sb = m["entries"]["lm_stlt_tiny.stream_batch"]
    assert sb["inputs"][1]["shape"] == [sb["batch_srv"]] + l_shape
