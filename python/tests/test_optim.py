"""Flat-vector packing and from-scratch AdamW."""

import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import optim


def tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "b": {"x": jnp.asarray(rng.normal(size=(3, 4)).astype(np.float32))},
        "a": [jnp.asarray(rng.normal(size=(2,)).astype(np.float32)),
              jnp.asarray(rng.normal(size=(5, 1)).astype(np.float32))],
        "scalar": jnp.asarray([1.5], np.float32),
    }


def test_pack_unpack_roundtrip():
    t = tree()
    flat = optim.pack(t)
    assert flat.shape == (12 + 2 + 5 + 1,)
    t2 = optim.unpack(flat, t)
    for a, b in zip(jax.tree_util.tree_leaves(t), jax.tree_util.tree_leaves(t2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_spec_deterministic_order():
    s1 = optim.spec(tree(1))
    s2 = optim.spec(tree(2))
    assert [p for p, _, _ in s1] == [p for p, _, _ in s2]
    # sorted by path
    paths = [p for p, _, _ in s1]
    assert paths == sorted(paths)


def test_n_params():
    assert optim.n_params(tree()) == 20


def test_adamw_matches_manual_reference():
    rng = np.random.default_rng(0)
    p = jnp.asarray(rng.normal(size=(16,)).astype(np.float32))
    g = jnp.asarray(rng.normal(size=(16,)).astype(np.float32))
    m = jnp.zeros(16)
    v = jnp.zeros(16)
    lr, b1, b2, eps, wd = 1e-2, 0.9, 0.98, 1e-8, 0.05
    p2, m2, v2 = optim.adamw_update(
        p, g, m, v, jnp.int32(1), lr=lr, beta1=b1, beta2=b2, eps=eps, weight_decay=wd
    )
    # manual numpy reference
    mm = (1 - b1) * np.asarray(g)
    vv = (1 - b2) * np.asarray(g) ** 2
    mhat = mm / (1 - b1)
    vhat = vv / (1 - b2)
    expect = np.asarray(p) - lr * (mhat / (np.sqrt(vhat) + eps) + wd * np.asarray(p))
    np.testing.assert_allclose(np.asarray(p2), expect, atol=1e-6)


def test_grad_clip_caps_norm():
    p = jnp.zeros(4)
    g = jnp.asarray([10.0, 0.0, 0.0, 0.0])
    m = jnp.zeros(4)
    v = jnp.zeros(4)
    p2, m2, _ = optim.adamw_update(
        p, g, m, v, jnp.int32(1), lr=1.0, beta1=0.0, beta2=0.0, grad_clip=1.0
    )
    # with clip, effective g has norm 1
    assert abs(float(m2[0]) - 1.0) < 1e-5


@settings(max_examples=20, deadline=None)
@given(step=st.integers(0, 10_000))
def test_lr_schedule_bounds(step):
    lr = float(optim.lr_schedule(jnp.int32(step), 3e-4, 100, 2000))
    assert 0.0 <= lr <= 3e-4 + 1e-9


def test_lr_schedule_shape():
    warm = float(optim.lr_schedule(jnp.int32(50), 3e-4, 100, 2000))
    peak = float(optim.lr_schedule(jnp.int32(100), 3e-4, 100, 2000))
    end = float(optim.lr_schedule(jnp.int32(2000), 3e-4, 100, 2000))
    assert warm < peak
    assert abs(peak - 3e-4) < 1e-8
    assert abs(end - 0.1 * 3e-4) < 1e-8


def test_adamw_descends_quadratic():
    """AdamW minimises a simple quadratic."""
    target = jnp.asarray(np.linspace(-1, 1, 8).astype(np.float32))
    p = jnp.zeros(8)
    m = jnp.zeros(8)
    v = jnp.zeros(8)
    for t in range(1, 200):
        g = 2 * (p - target)
        p, m, v = optim.adamw_update(p, g, m, v, jnp.int32(t), lr=3e-2, beta1=0.9, beta2=0.99)
    assert float(jnp.abs(p - target).max()) < 0.05
