"""STLT layer unit tests: gating, regularisation, ablation stop-grads,
linear/quadratic modes, streaming equivalence."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import stlt_layer, optim
from compile.config import ModelConfig


def cfg(**kw):
    base = dict(arch="stlt", vocab=64, d_model=16, n_layers=1, n_ctx=32, s_max=8)
    base.update(kw)
    return ModelConfig(**base)


def x_batch(c, b=2, n=24, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0, 1, (b, n, c.d_model)).astype(np.float32))


def test_output_shape_and_finite():
    c = cfg()
    p = stlt_layer.init(0, c)
    x = x_batch(c)
    z, reg, seff = stlt_layer.apply(p, x, c, causal=True)
    assert z.shape == x.shape
    assert np.isfinite(np.asarray(z)).all()
    assert float(seff) == c.s_max  # non-adaptive: all nodes active


def test_adaptive_gate_masks_nodes():
    c = cfg(adaptive=True)
    p = stlt_layer.init(0, c)
    x = x_batch(c)
    # force gate mostly off
    p["b_alpha"] = jnp.full((c.s_max,), -10.0)
    _, _, seff = stlt_layer.apply(p, x, c, causal=True, train=False)
    assert float(seff) < 0.5
    p["b_alpha"] = jnp.full((c.s_max,), 10.0)
    _, _, seff = stlt_layer.apply(p, x, c, causal=True, train=False)
    assert float(seff) > c.s_max - 0.5


def test_gate_zero_mask_silences_output():
    c = cfg(adaptive=True)
    p = stlt_layer.init(0, c)
    x = x_batch(c)
    p["b_alpha"] = jnp.full((c.s_max,), -30.0)
    p["w_alpha"] = jnp.zeros_like(p["w_alpha"])
    z, _, _ = stlt_layer.apply(p, x, c, causal=True, train=False)
    assert float(jnp.abs(z).max()) < 1e-5


def test_regulariser_terms():
    c = cfg(adaptive=True, lambda_omega=1.0, lambda_sigma=0.0, lambda_mask=0.0)
    p = stlt_layer.init(0, c)
    m = jnp.ones((2, c.s_max))
    r1 = stlt_layer.regulariser(p, m, c)
    assert abs(float(r1) - float(jnp.sum(jnp.abs(p["omega"])))) < 1e-4
    c2 = cfg(adaptive=True, lambda_omega=0.0, lambda_sigma=0.0, lambda_mask=2.0)
    r2 = stlt_layer.regulariser(p, m, c2)
    assert abs(float(r2) - 2.0 * c.s_max) < 1e-4


def test_ablation_stop_gradients():
    x = x_batch(cfg())
    for flag, leaf in [("learn_sigma", "sigma_raw"), ("learn_omega", "omega"), ("learn_t", "t_raw")]:
        c = cfg(**{flag: False})
        p = stlt_layer.init(3, c)

        def loss(p_):
            z, reg, _ = stlt_layer.apply(p_, x, c, causal=True)
            return jnp.sum(z * z) + reg

        g = jax.grad(loss)(p)
        assert float(jnp.abs(g[leaf]).max()) < 1e-8, f"{flag} leak via {leaf}"
        # other projections still receive gradient
        assert float(jnp.abs(g["w_f"]).max()) > 0


def test_omega_zero_ablation():
    c = cfg(omega_zero=True)
    p = stlt_layer.init(1, c)
    decay, theta, _, _ = stlt_layer.node_params(p, c)
    assert float(jnp.abs(theta).max()) == 0.0


def test_window_folds_into_decay():
    c = cfg()
    p = stlt_layer.init(0, c)
    _, _, sigma, t = stlt_layer.node_params(p, c)
    decay, _, _, _ = stlt_layer.node_params(p, c)
    expect = jnp.exp(-(sigma + 1.0 / t))
    assert np.allclose(np.asarray(decay), np.asarray(expect), atol=1e-6)


def test_causality_linear_mode():
    c = cfg()
    p = stlt_layer.init(0, c)
    x = x_batch(c, b=1, n=16)
    z1, _, _ = stlt_layer.apply(p, x, c, causal=True)
    x2 = x.at[0, -1].set(x[0, -1] + 5.0)
    z2, _, _ = stlt_layer.apply(p, x2, c, causal=True)
    # all positions except the last must be unchanged
    assert np.allclose(np.asarray(z1[0, :-1]), np.asarray(z2[0, :-1]), atol=1e-5)
    assert not np.allclose(np.asarray(z1[0, -1]), np.asarray(z2[0, -1]), atol=1e-3)


def test_bilateral_sees_future():
    c = cfg()
    p = stlt_layer.init(0, c)
    x = x_batch(c, b=1, n=16)
    z1, _, _ = stlt_layer.apply(p, x, c, causal=False)
    x2 = x.at[0, -1].set(x[0, -1] + 5.0)
    z2, _, _ = stlt_layer.apply(p, x2, c, causal=False)
    assert not np.allclose(np.asarray(z1[0, 0]), np.asarray(z2[0, 0]), atol=1e-5)


def test_quadratic_mode_runs_and_is_causal():
    c = cfg(mode="quadratic")
    p = stlt_layer.init(0, c)
    x = x_batch(c, b=1, n=16)
    z1, _, _ = stlt_layer.apply(p, x, c, causal=True)
    x2 = x.at[0, -1].set(x[0, -1] - 3.0)
    z2, _, _ = stlt_layer.apply(p, x2, c, causal=True)
    assert np.allclose(np.asarray(z1[0, :-1]), np.asarray(z2[0, :-1]), atol=1e-4)


def test_streaming_matches_monolithic_layer():
    c = cfg()
    p = stlt_layer.init(0, c)
    x = x_batch(c, b=1, n=32)[0]
    z_full, _, _ = stlt_layer.apply(p, x[None], c, causal=True)
    carry = stlt_layer.carry_init(c)
    outs = []
    for i in range(0, 32, 8):
        z, carry = stlt_layer.apply_stream(p, x[i : i + 8], c, carry)
        outs.append(z)
    z_stream = jnp.concatenate(outs)
    assert np.allclose(np.asarray(z_full[0]), np.asarray(z_stream), atol=2e-4)


def test_gumbel_gate_stochastic_in_train_only():
    c = cfg(adaptive=True)
    p = stlt_layer.init(0, c)
    x = x_batch(c)
    k1, k2 = jax.random.PRNGKey(1), jax.random.PRNGKey(2)
    m1, _ = stlt_layer.gate(p, x, c, k1, 1.0, train=True)
    m2, _ = stlt_layer.gate(p, x, c, k2, 1.0, train=True)
    assert not np.allclose(np.asarray(m1), np.asarray(m2))
    e1, _ = stlt_layer.gate(p, x, c, k1, 1.0, train=False)
    e2, _ = stlt_layer.gate(p, x, c, k2, 1.0, train=False)
    assert np.allclose(np.asarray(e1), np.asarray(e2))
