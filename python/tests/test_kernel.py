"""Kernel vs ref allclose — the CORE correctness signal.

Sweeps shapes/parameters with hypothesis; every Pallas kernel is checked
against the pure-jnp oracle in kernels/ref.py, and the differentiable
ops (custom VJP) are checked against jax.grad of the oracle.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ops, ref, stlt

SETTINGS = dict(max_examples=12, deadline=None)


def _mk(seed, n, s, d=None, sigma_lo=0.05, sigma_hi=2.0, omega_hi=2.0):
    rng = np.random.default_rng(seed)
    f = jnp.asarray(rng.normal(size=(n, s)).astype(np.float32))
    sigma = jnp.asarray(rng.uniform(sigma_lo, sigma_hi, s).astype(np.float32))
    omega = jnp.asarray(rng.uniform(0.0, omega_hi, s).astype(np.float32))
    decay, theta = ref.node_multiplier(sigma, omega)
    if d is None:
        return f, decay, theta
    v = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    return f, v, decay, theta


def _close(a, b, atol=2e-4, rtol=2e-4):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=atol, rtol=rtol)


# ---------------------------------------------------------------------------
# Scans
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    n=st.sampled_from([1, 3, 16, 64, 130]),
    s=st.sampled_from([1, 4, 16, 64]),
    seed=st.integers(0, 2**16),
)
def test_scan_uni_matches_ref(n, s, seed):
    f, decay, theta = _mk(seed, n, s)
    kr, ki = stlt.stlt_scan_uni(f, decay, theta)
    rr, ri = ref.stlt_scan_uni(f, decay, theta)
    _close(kr, rr)
    _close(ki, ri)


@settings(**SETTINGS)
@given(
    n=st.sampled_from([1, 2, 16, 64, 96]),
    s=st.sampled_from([1, 8, 32]),
    seed=st.integers(0, 2**16),
)
def test_scan_bi_matches_ref(n, s, seed):
    f, decay, theta = _mk(seed, n, s)
    kr, ki = stlt.stlt_scan_bi(f, decay, theta)
    rr, ri = ref.stlt_scan_bi(f, decay, theta)
    _close(kr, rr)
    _close(ki, ri)


def test_scan_pure_decay_is_ema():
    """omega = 0 reduces the scan to a plain exponential moving sum."""
    n, s = 32, 4
    f, decay, _ = _mk(7, n, s, omega_hi=0.0)
    theta = jnp.zeros((s,), jnp.float32)
    kr, ki = stlt.stlt_scan_uni(f, decay, theta)
    acc = np.zeros(s, np.float32)
    for i in range(n):
        acc = np.asarray(decay) * acc + np.asarray(f[i])
        np.testing.assert_allclose(np.asarray(kr[i]), acc, rtol=1e-5, atol=1e-5)
    assert float(jnp.abs(ki).max()) == 0.0


def test_scan_bi_is_fwd_plus_strict_bwd():
    n, s = 40, 8
    f, decay, theta = _mk(3, n, s)
    br, bi_ = stlt.stlt_scan_bi(f, decay, theta)
    fr, fi = stlt.stlt_scan_uni(f, decay, theta)
    # strictly-backward part via the reversal identity (DESIGN.md)
    rr, ri = stlt.stlt_scan_uni(f[::-1], decay, theta)
    _close(br, fr + rr[::-1] - f)
    _close(bi_, fi + ri[::-1])


def test_scan_translation_invariance():
    """Relative kernel (DESIGN.md R1): shifting the signal shifts L."""
    n, s, pad = 32, 4, 8
    f, decay, theta = _mk(11, n, s)
    l1, _ = stlt.stlt_scan_uni(f, decay, theta)
    fpad = jnp.concatenate([jnp.zeros((pad, s)), f], axis=0)
    l2, _ = stlt.stlt_scan_uni(fpad, decay, theta)
    # after the zero prefix the response is shifted but otherwise identical
    _close(l2[pad:], l1, atol=1e-3, rtol=1e-3)


# ---------------------------------------------------------------------------
# Quadratic relevance mode
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    n=st.sampled_from([16, 64, 128]),
    s=st.sampled_from([4, 16]),
    d=st.sampled_from([8, 32]),
    causal=st.booleans(),
    seed=st.integers(0, 2**16),
)
def test_relevance_qmode_matches_ref(n, s, d, causal, seed):
    f, v, decay, theta = _mk(seed, n, s, d)
    lr, li = ref.stlt_scan_uni(f, decay, theta)
    zk = stlt.relevance_qmode(lr, li, v, causal=causal, block_q=16, block_k=16)
    zr = ref.relevance_qmode(lr, li, v, causal=causal)
    _close(zk, zr, atol=5e-4, rtol=5e-4)


def test_relevance_rows_are_convex_combinations():
    """softmax rows sum to 1 => Z stays in the convex hull of V columns."""
    n, s, d = 32, 8, 4
    f, v, decay, theta = _mk(5, n, s, d)
    lr, li = ref.stlt_scan_uni(f, decay, theta)
    z = np.asarray(stlt.relevance_qmode(lr, li, v, causal=True, block_q=16, block_k=16))
    vmin, vmax = np.asarray(v).min(axis=0), np.asarray(v).max(axis=0)
    assert (z >= vmin - 1e-4).all() and (z <= vmax + 1e-4).all()


def test_relevance_causal_first_row_is_v0():
    n, s, d = 16, 4, 8
    f, v, decay, theta = _mk(9, n, s, d)
    lr, li = ref.stlt_scan_uni(f, decay, theta)
    z = stlt.relevance_qmode(lr, li, v, causal=True, block_q=16, block_k=16)
    _close(z[0], v[0])


# ---------------------------------------------------------------------------
# Linear mode + streaming
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    n=st.sampled_from([1, 8, 64, 100]),
    s=st.sampled_from([2, 16]),
    d=st.sampled_from([4, 32]),
    seed=st.integers(0, 2**16),
)
def test_linear_mode_matches_ref(n, s, d, seed):
    f, v, decay, theta = _mk(seed, n, s, d)
    zk = stlt.linear_mode_uni(f, v, decay, theta)
    zr = ref.linear_mode_uni(f, v, decay, theta)
    _close(zk, zr, atol=5e-4, rtol=5e-4)


@settings(**SETTINGS)
@given(
    chunks=st.sampled_from([[16, 16], [8, 24, 32], [1, 63], [32]]),
    s=st.sampled_from([4, 16]),
    d=st.sampled_from([8]),
    seed=st.integers(0, 2**16),
)
def test_streaming_equals_monolithic(chunks, s, d, seed):
    """The O(S d) carry makes chunked == whole-sequence processing."""
    n = sum(chunks)
    f, v, decay, theta = _mk(seed, n, s, d)
    z_mono = stlt.linear_mode_uni(f, v, decay, theta)
    carry = ref.stream_carry_init(s, d)
    outs, off = [], 0
    for c in chunks:
        z, carry = stlt.linear_mode_stream_chunk(
            f[off : off + c], v[off : off + c], decay, theta, carry
        )
        outs.append(z)
        off += c
    _close(jnp.concatenate(outs), z_mono, atol=5e-4, rtol=5e-4)


def test_stream_kernel_matches_ref_chunk():
    n, s, d = 48, 8, 16
    f, v, decay, theta = _mk(13, n, s, d)
    ck = ref.stream_carry_init(s, d)
    cr = ref.stream_carry_init(s, d)
    zk, ck = stlt.linear_mode_stream_chunk(f, v, decay, theta, ck)
    zr, cr = ref.linear_mode_stream_chunk(f, v, decay, theta, cr)
    _close(zk, zr, atol=5e-4, rtol=5e-4)
    _close(ck[0], cr[0], atol=5e-4, rtol=5e-4)
    _close(ck[1], cr[1], atol=5e-4, rtol=5e-4)


# ---------------------------------------------------------------------------
# Differentiable ops (custom VJP) vs jax.grad of the oracle
# ---------------------------------------------------------------------------


def _grad_pair(fn_ops, fn_ref, args, wrt):
    def wrap(fn):
        def loss(*a):
            out = fn(*a)
            out = out if isinstance(out, tuple) else (out,)
            return sum(jnp.sum(o * o) for o in out)

        return jax.grad(loss, argnums=wrt)(*args)

    return wrap(fn_ops), wrap(fn_ref)


@settings(**SETTINGS)
@given(
    n=st.sampled_from([4, 32, 65]),
    s=st.sampled_from([2, 8]),
    seed=st.integers(0, 2**16),
)
def test_scan_uni_vjp(n, s, seed):
    f, decay, theta = _mk(seed, n, s)
    go, gr = _grad_pair(ops.scan_uni_real, ref.stlt_scan_uni, (f, decay, theta), (0, 1, 2))
    for a, b in zip(go, gr):
        scale = float(jnp.abs(b).max()) + 1e-6
        _close(a / scale, b / scale, atol=1e-4, rtol=1e-4)


@settings(**SETTINGS)
@given(
    n=st.sampled_from([4, 32]),
    s=st.sampled_from([2, 8]),
    seed=st.integers(0, 2**16),
)
def test_scan_bi_vjp(n, s, seed):
    f, decay, theta = _mk(seed, n, s)
    go, gr = _grad_pair(ops.scan_bi_real, ref.stlt_scan_bi, (f, decay, theta), (0, 1, 2))
    for a, b in zip(go, gr):
        scale = float(jnp.abs(b).max()) + 1e-6
        _close(a / scale, b / scale, atol=1e-4, rtol=1e-4)


@settings(**SETTINGS)
@given(
    n=st.sampled_from([8, 33]),
    s=st.sampled_from([4]),
    d=st.sampled_from([8]),
    seed=st.integers(0, 2**16),
)
def test_linear_mode_vjp(n, s, d, seed):
    f, v, decay, theta = _mk(seed, n, s, d)
    go, gr = _grad_pair(
        lambda f_, dc, th: ops.linear_mode_uni(f_, v, dc, th),
        lambda f_, dc, th: ref.linear_mode_uni(f_, v, dc, th),
        (f, decay, theta),
        (0, 1, 2),
    )
    for a, b in zip(go, gr):
        scale = float(jnp.abs(b).max()) + 1e-6
        _close(a / scale, b / scale, atol=1e-4, rtol=1e-4)


def test_ops_linear_equals_fused_kernel():
    """Training-path composition == fused inference kernel."""
    n, s, d = 64, 16, 32
    f, v, decay, theta = _mk(17, n, s, d)
    _close(
        ops.linear_mode_uni(f, v, decay, theta),
        stlt.linear_mode_uni(f, v, decay, theta),
        atol=5e-4,
        rtol=5e-4,
    )


def test_batched_fold_matches_per_sequence():
    b, n, s = 3, 20, 8
    rng = np.random.default_rng(23)
    fb = jnp.asarray(rng.normal(size=(b, n, s)).astype(np.float32))
    _, decay, theta = _mk(23, n, s)
    lr, li = ops.scan_uni_batched(fb, decay, theta)
    for i in range(b):
        rr, ri = ref.stlt_scan_uni(fb[i], decay, theta)
        _close(lr[i], rr)
        _close(li[i], ri)


def test_vjp_gradcheck_finite_difference():
    """Central finite differences on a scalar loss through scan_uni."""
    n, s = 10, 3
    f, decay, theta = _mk(29, n, s)
    w = jnp.asarray(np.random.default_rng(1).normal(size=(n, s)).astype(np.float32))

    def loss(sig):
        dc = jnp.exp(-sig)
        lr, li = ops.scan_uni_real(f, dc, theta)
        return jnp.sum(w * lr) + jnp.sum(w * li)

    sig0 = -jnp.log(decay)
    g = jax.grad(loss)(sig0)
    eps = 1e-3
    for k in range(s):
        e = jnp.zeros((s,)).at[k].set(eps)
        fd = (loss(sig0 + e) - loss(sig0 - e)) / (2 * eps)
        assert abs(float(fd) - float(g[k])) < 5e-2 * max(1.0, abs(float(g[k])))


# ---------------------------------------------------------------------------
# Windowed-U discount (DESIGN.md R4 streaming stationarity)
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    n=st.sampled_from([8, 48]),
    s=st.sampled_from([4, 8]),
    d=st.sampled_from([8]),
    seed=st.integers(0, 2**16),
)
def test_gamma_consistency_kernel_ref_ops(n, s, d, seed):
    """Fused kernel == oracle == differentiable op under a U-discount."""
    f, v, decay, theta = _mk(seed, n, s, d)
    rng = np.random.default_rng(seed + 1)
    gamma = jnp.asarray(rng.uniform(0.8, 0.999, s).astype(np.float32))
    zk = stlt.linear_mode_uni(f, v, decay, theta, gamma)
    zr = ref.linear_mode_uni(f, v, decay, theta, gamma)
    zo = ops.linear_mode_uni(f, v, decay, theta, gamma)
    _close(zk, zr, atol=5e-4, rtol=5e-4)
    _close(zo, zr, atol=5e-4, rtol=5e-4)


def test_gamma_streaming_equals_monolithic():
    n, s, d = 64, 8, 8
    f, v, decay, theta = _mk(31, n, s, d)
    gamma = jnp.full((s,), 0.97, jnp.float32)
    z_mono = stlt.linear_mode_uni(f, v, decay, theta, gamma)
    carry = ref.stream_carry_init(s, d)
    outs = []
    for i in range(0, n, 16):
        z, carry = stlt.linear_mode_stream_chunk(
            f[i : i + 16], v[i : i + 16], decay, theta, carry, gamma
        )
        outs.append(z)
    _close(jnp.concatenate(outs), z_mono, atol=5e-4, rtol=5e-4)


def test_gamma_bounds_state():
    """With gamma < 1 the U carry converges instead of growing with N."""
    s, d = 4, 4
    rng = np.random.default_rng(2)
    decay = jnp.full((s,), 0.9, jnp.float32)
    theta = jnp.zeros((s,), jnp.float32)
    gamma = jnp.full((s,), 0.95, jnp.float32)
    carry = ref.stream_carry_init(s, d)
    prev = 0.0
    for i in range(8):
        f = jnp.asarray(rng.normal(size=(64, s)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(64, d)).astype(np.float32))
        _, carry = ref.linear_mode_stream_chunk(f, v, decay, theta, carry, gamma)
        mag = float(jnp.abs(carry[1]).max())
        prev = mag
    # bounded: well below the undiscounted ~N scale
    assert prev < 64.0, f"state grew unbounded: {prev}"
