"""§3.7 reproduction: empirical checks of the paper's error-analysis
claims at the granularity our discrete implementation supports.

1. Node-count convergence: the relevance matrix built from S nodes
   converges as S grows (the paper's E_quad = O(S^-p) story, measured as
   ||R_S - R_Smax|| decreasing monotonically-ish in S).
2. Window truncation: E_win <= C e^{-T sigma_min} — increasing window T
   moves the windowed transform toward the unwindowed one at an
   exponential-ish rate.
3. Perturbation -> loss: ||Delta R|| ~ 1e-2 changes downstream softmax
   cross-entropy by O(||Delta R||) (the paper's §3.7 'impact' claim).
"""

import numpy as np
import jax
import jax.numpy as jnp

from compile.kernels import ref


def _signal(n, s, seed=0):
    rng = np.random.default_rng(seed)
    f = jnp.asarray(rng.normal(size=(n, s)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(n, 8)).astype(np.float32))
    return f, v


def _relevance_with_s(x, s, seed=1):
    """Project a fixed signal onto s nodes (log-spaced sigma, linear omega)
    and build the relevance matrix."""
    n = x.shape[0]
    sigma = jnp.asarray(np.geomspace(0.02, 1.0, s).astype(np.float32))
    omega = jnp.asarray(np.linspace(0, 1.0, s).astype(np.float32))
    decay, theta = ref.node_multiplier(sigma, omega)
    f = jnp.tile(x[:, :1], (1, s))  # same scalar signal into every node
    l_re, l_im = ref.stlt_scan_uni(f, decay, theta)
    r = ref.relevance(l_re, l_im)
    # normalise scale so different S are comparable
    return r / jnp.float32(s)


def test_node_count_convergence():
    n = 48
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(n, 1)).astype(np.float32))
    r_ref = _relevance_with_s(x, 128)
    errs = []
    for s in [4, 8, 16, 32, 64]:
        r = _relevance_with_s(x, s)
        errs.append(float(jnp.linalg.norm(r - r_ref) / jnp.linalg.norm(r_ref)))
    # broadly decreasing: last must be much smaller than first
    assert errs[-1] < errs[0] * 0.5, f"errors {errs}"
    assert errs[-1] < 0.2


def test_window_truncation_decay():
    """Larger T (smaller 1/T added to sigma) approaches the unwindowed
    transform; error decreases monotonically in T."""
    n, s = 64, 8
    f, _ = _signal(n, s, 7)
    sigma = jnp.asarray(np.geomspace(0.05, 0.5, s).astype(np.float32))
    omega = jnp.zeros(s)
    d_inf, th = ref.node_multiplier(sigma, omega)
    l_inf, _ = ref.stlt_scan_uni(f, d_inf, th)
    errs = []
    for t in [4.0, 8.0, 16.0, 32.0, 64.0]:
        d_t, _ = ref.node_multiplier(sigma + 1.0 / t, omega)
        l_t, _ = ref.stlt_scan_uni(f, d_t, th)
        errs.append(float(jnp.abs(l_t - l_inf).max()))
    for a, b in zip(errs, errs[1:]):
        assert b <= a + 1e-6, f"not monotone: {errs}"
    assert errs[-1] < errs[0] * 0.2


def test_relevance_perturbation_bounds_loss_change():
    """|CE(R + dR) - CE(R)| = O(||dR||): the §3.7 downstream claim.
    At ||dR|| ~ 1e-2 the loss change should be <~ a few times 1e-2."""
    n, s, d = 32, 16, 8
    f, v = _signal(n, s, 11)
    sigma = jnp.asarray(np.geomspace(0.05, 1.0, s).astype(np.float32))
    decay, theta = ref.node_multiplier(sigma, jnp.zeros(s))
    l_re, l_im = ref.stlt_scan_uni(f, decay, theta)
    r = ref.relevance(l_re, l_im) / jnp.sqrt(jnp.float32(s))
    targets = jnp.asarray(np.random.default_rng(0).integers(0, d, n))

    def ce_from_r(r_):
        a = jax.nn.softmax(r_, axis=-1)
        z = a @ v  # [n, d] as logits over d "classes"
        logp = jax.nn.log_softmax(z, axis=-1)
        return -jnp.mean(jnp.take_along_axis(logp, targets[:, None], axis=1))

    base = float(ce_from_r(r))
    rng = np.random.default_rng(5)
    for scale in [1e-3, 1e-2]:
        dr = jnp.asarray(rng.normal(size=r.shape).astype(np.float32))
        dr = dr / jnp.linalg.norm(dr) * scale * jnp.linalg.norm(r)
        delta = abs(float(ce_from_r(r + dr)) - base)
        # loss change bounded by a modest constant times the rel. perturbation
        assert delta < 50 * scale, f"scale {scale}: delta {delta}"


def test_linear_vs_quadratic_mode_divergence_is_graceful():
    """The complexity-faithful linear mode is a different normalisation of
    the same relevance; outputs stay finite and correlated with the
    quadratic mode's (sanity for DESIGN.md R2)."""
    n, s, d = 32, 16, 8
    f, v = _signal(n, s, 13)
    sigma = jnp.asarray(np.geomspace(0.05, 1.0, s).astype(np.float32))
    decay, theta = ref.node_multiplier(sigma, jnp.zeros(s))
    zl = ref.linear_mode_uni(f, v, decay, theta)
    l_re, l_im = ref.stlt_scan_uni(f, decay, theta)
    zq = ref.relevance_qmode(l_re, l_im, v, causal=True)
    assert np.isfinite(np.asarray(zl)).all() and np.isfinite(np.asarray(zq)).all()
    # positively correlated on average (same relevance structure)
    zl_n = np.asarray(zl).ravel()
    zq_n = np.asarray(zq).ravel()
    corr = np.corrcoef(zl_n, zq_n)[0, 1]
    assert np.isfinite(corr)
