"""Model / training configuration shared by Layer-2 code and aot.py.

A plain dataclass (no serde deps); `to_dict` feeds the artifact manifest
that the Rust coordinator parses (rust/src/util/json.rs).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class ModelConfig:
    # architecture family: stlt | vanilla | linformer | fnet | ssm | performer
    arch: str = "stlt"
    vocab: int = 256
    d_model: int = 64
    n_layers: int = 2
    n_ctx: int = 128
    ffn_mult: int = 4
    # attention-family baselines
    n_heads: int = 4
    linformer_k: int = 32
    # --- STLT specifics ---
    s_max: int = 32  # number of Laplace nodes (S, or S_max when adaptive)
    mode: str = "linear"  # linear | quadratic (DESIGN.md R2)
    adaptive: bool = False  # Gumbel-sigmoid adaptive node allocation
    learn_sigma: bool = True
    learn_omega: bool = True
    learn_t: bool = True
    omega_zero: bool = False  # ablation: no oscillation
    sigma_min: float = 1e-3
    t_init: float = 32.0
    sigma_init_lo: float = 0.01
    sigma_init_hi: float = 2.0
    omega_init_hi: float = 0.785  # pi/4
    # regularisation (Eq. Reg)
    lambda_omega: float = 1e-4
    lambda_sigma: float = 1e-4
    lambda_mask: float = 1e-3
    # training
    batch: int = 8
    lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 2000
    weight_decay: float = 0.01
    beta1: float = 0.9
    beta2: float = 0.98
    grad_clip: float = 1.0
    gumbel_temp_hi: float = 1.0
    gumbel_temp_lo: float = 0.1
    temp_anneal_frac: float = 0.4
    seed: int = 0

    def to_dict(self):
        return dataclasses.asdict(self)


def preset(name: str, **over) -> ModelConfig:
    """Named size/arch presets used by aot.py and the experiment harnesses."""
    base = {
        "tiny": dict(vocab=256, d_model=64, n_layers=2, n_ctx=128, s_max=32, batch=8),
        "small": dict(vocab=512, d_model=128, n_layers=4, n_ctx=256, s_max=32, batch=4),
        "e2e": dict(
            vocab=4096, d_model=256, n_layers=4, n_ctx=256, s_max=32, batch=4,
            warmup=50, total_steps=400,
        ),
    }[name]
    base.update(over)
    return ModelConfig(**base)
