"""Jittable entry points lowered by aot.py and driven from Rust.

All cross-boundary state is flat f32 vectors / int32 token arrays so the
Rust side (rust/src/runtime/exec.rs) stays allocation-simple:

  train_step  (flat, m, v, step, tokens[B,N+1], seed)
                -> (flat', m', v', loss, ce, s_eff)
  eval_step   (flat, tokens[B,N+1], noise_std, seed) -> (nll_sum, count, s_eff)
  forward     (flat, tokens[B,N]) -> logits[B,N,V]
  stream_step (flat, l_carry, u_carry, x_carry?, tokens[C], targets[C], mask[C])
                -> (l', u', nll_sum, count)      [stlt linear causal only]
  decode_step (flat, l_carry, u_carry, token) -> (l', u', logits[V])
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import optim, stlt_layer, trunk
from .config import ModelConfig


def _temp_at(cfg: ModelConfig, step):
    """Gumbel temperature annealed hi->lo over the first anneal_frac of training."""
    frac = jnp.clip(
        step.astype(jnp.float32) / max(1.0, cfg.temp_anneal_frac * cfg.total_steps),
        0.0,
        1.0,
    )
    return cfg.gumbel_temp_hi + (cfg.gumbel_temp_lo - cfg.gumbel_temp_hi) * frac


def make_template(cfg: ModelConfig):
    return trunk.init(cfg)


def make_train_step(cfg: ModelConfig, template):
    def train_step(flat, m, v, step, tokens, seed):
        params = optim.unpack(flat, template)
        temp = _temp_at(cfg, step)
        key = jax.random.fold_in(jax.random.PRNGKey(0), seed)

        def loss_fn(p):
            return trunk.lm_loss(p, tokens, cfg, rng_key=key, temp=temp, train=True)

        (loss, (ce, seff)), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        g = optim.pack(grads)
        lr = optim.lr_schedule(step, cfg.lr, cfg.warmup, cfg.total_steps)
        flat2, m2, v2 = optim.adamw_update(
            flat, g, m, v, step + 1,
            lr=lr, beta1=cfg.beta1, beta2=cfg.beta2,
            weight_decay=cfg.weight_decay, grad_clip=cfg.grad_clip,
        )
        return flat2, m2, v2, loss, ce, seff

    return train_step


def make_eval_step(cfg: ModelConfig, template):
    def eval_step(flat, tokens, noise_std, seed):
        params = optim.unpack(flat, template)
        key = jax.random.fold_in(jax.random.PRNGKey(1), seed)
        inp, tgt = tokens[:, :-1], tokens[:, 1:]
        logits, _, seff = trunk.apply(
            params, inp, cfg, rng_key=key, temp=cfg.gumbel_temp_lo, train=False,
            noise_std=noise_std,
        )
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)
        return jnp.sum(nll), jnp.float32(tgt.size), seff

    return eval_step


def make_forward(cfg: ModelConfig, template):
    def forward(flat, tokens):
        params = optim.unpack(flat, template)
        logits, _, _ = trunk.apply(params, tokens, cfg, train=False)
        return (logits,)

    return forward


# ---------------------------------------------------------------------------
# Streaming (stlt, linear causal) — the O(S d) carry hot path
# ---------------------------------------------------------------------------


def carry_shapes(cfg: ModelConfig):
    ly, s, d = cfg.n_layers, cfg.s_max, cfg.d_model
    return (ly, s, 2), (ly, s, d, 2)


def _stream_trunk(params, tokens, cfg: ModelConfig, l_carry, u_carry):
    """tokens [C] -> (logits [C, V], l', u'). No posenc (recurrent position)."""
    d = cfg.d_model
    x = params["embed"][tokens] * jnp.sqrt(jnp.float32(d))
    nl, nu = [], []
    for li, lp in enumerate(params["layers"]):
        h = trunk._ln(x, lp["ln1_g"], lp["ln1_b"])
        z, (lc, uc) = stlt_layer.apply_stream(
            lp["mixer"], h, cfg, (l_carry[li], u_carry[li])
        )
        x = x + z
        x = x + trunk._ffn(lp, trunk._ln(x, lp["ln2_g"], lp["ln2_b"]))
        nl.append(lc)
        nu.append(uc)
    x = trunk._ln(x, params["lnf_g"], params["lnf_b"])
    logits = x @ params["embed"].T
    return logits, jnp.stack(nl), jnp.stack(nu)


def make_stream_step(cfg: ModelConfig, template):
    def stream_step(flat, l_carry, u_carry, tokens, targets, mask):
        """One chunk of streaming next-token evaluation.

        mask [C] in {0,1} marks positions that count toward the NLL
        (lets Rust feed ragged tails / skip question tokens in QA)."""
        params = optim.unpack(flat, template)
        logits, nl, nu = _stream_trunk(params, tokens, cfg, l_carry, u_carry)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, targets[:, None], axis=-1)[:, 0]
        return nl, nu, jnp.sum(nll * mask), jnp.sum(mask)

    return stream_step


def make_stream_batch_step(cfg: ModelConfig, template):
    def stream_batch_step(flat, l_carry, u_carry, tokens, targets, mask, active):
        """Batched streaming chunk for the serving coordinator.

        l_carry [B, L, S, 2], u_carry [B, L, S, d, 2], tokens/targets/mask
        [B, C], active [B] in {0,1}. Rows with active=0 keep their carry
        unchanged and contribute nothing — the dynamic batcher pads
        partially-filled batches with inactive rows without corrupting
        idle sessions' state."""
        params = optim.unpack(flat, template)

        def one(lc, uc, tok, tgt, msk):
            logits, nl, nu = _stream_trunk(params, tok, cfg, lc, uc)
            logp = jax.nn.log_softmax(logits, axis=-1)
            nll = -jnp.take_along_axis(logp, tgt[:, None], axis=-1)[:, 0]
            return nl, nu, jnp.sum(nll * msk), jnp.sum(msk)

        nl, nu, nll, cnt = jax.vmap(one)(l_carry, u_carry, tokens, targets, mask)
        a4 = active[:, None, None, None]
        a5 = active[:, None, None, None, None]
        nl = a4 * nl + (1.0 - a4) * l_carry
        nu = a5 * nu + (1.0 - a5) * u_carry
        return nl, nu, nll * active, cnt * active

    return stream_batch_step


def make_decode_step(cfg: ModelConfig, template):
    def decode_step(flat, l_carry, u_carry, token):
        """token [1] -> next-token logits [V] + advanced carries."""
        params = optim.unpack(flat, template)
        logits, nl, nu = _stream_trunk(params, token, cfg, l_carry, u_carry)
        return nl, nu, logits[-1]

    return decode_step
