"""From-scratch AdamW on a flat f32 parameter vector (no optax offline).

The Rust coordinator shuttles a single f32[P] vector (plus Adam moments)
across the PJRT boundary, so training state management on the Rust side
is trivial and allocation-free. `spec(params)` fixes a deterministic
(name-sorted) packing order; pack/unpack are exact inverses.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp


def _walk(tree, prefix=""):
    """Deterministic (path-sorted) leaf iteration over nested dict/list."""
    if isinstance(tree, dict):
        for k in sorted(tree.keys()):
            yield from _walk(tree[k], f"{prefix}/{k}")
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _walk(v, f"{prefix}/{i:03d}")
    else:
        yield prefix, tree


def spec(params):
    """[(path, shape, size)] in packing order."""
    out = []
    for path, leaf in _walk(params):
        out.append((path, tuple(leaf.shape), int(np.prod(leaf.shape) or 1)))
    return out


def pack(params):
    leaves = [jnp.reshape(leaf, (-1,)) for _, leaf in _walk(params)]
    return jnp.concatenate(leaves) if leaves else jnp.zeros((0,), jnp.float32)


def unpack(flat, params_template):
    """Rebuild the nested structure of `params_template` from flat f32[P]."""
    sp = spec(params_template)
    sizes = [s for _, _, s in sp]
    chunks = jnp.split(flat, np.cumsum(sizes)[:-1]) if len(sizes) > 1 else [flat]
    it = iter(zip(chunks, sp))

    def rebuild(tree):
        if isinstance(tree, dict):
            return {k: rebuild(tree[k]) for k in sorted(tree.keys())}
        if isinstance(tree, (list, tuple)):
            return [rebuild(v) for v in tree]
        chunk, (_, shape, _) = next(it)
        return jnp.reshape(chunk, shape)

    return rebuild(params_template)


def n_params(params):
    return sum(s for _, _, s in spec(params))


def lr_schedule(step, base_lr, warmup, total):
    """Linear warmup then cosine decay to 10% of base (all jnp, traced)."""
    step = step.astype(jnp.float32)
    warm = base_lr * step / jnp.maximum(1.0, float(warmup))
    prog = jnp.clip((step - warmup) / jnp.maximum(1.0, float(total - warmup)), 0.0, 1.0)
    cos = base_lr * (0.1 + 0.9 * 0.5 * (1.0 + jnp.cos(np.pi * prog)))
    return jnp.where(step < warmup, warm, cos)


def adamw_update(flat, g, m, v, step, *, lr, beta1, beta2, eps=1e-8, weight_decay=0.0,
                 grad_clip=0.0):
    """One AdamW step on flat vectors. step is the 1-based update index."""
    if grad_clip > 0:
        gn = jnp.sqrt(jnp.sum(g * g))
        g = g * jnp.minimum(1.0, grad_clip / jnp.maximum(gn, 1e-12))
    m = beta1 * m + (1 - beta1) * g
    v = beta2 * v + (1 - beta2) * g * g
    t = step.astype(jnp.float32)
    mhat = m / (1 - beta1**t)
    vhat = v / (1 - beta2**t)
    upd = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * flat
    return flat - lr * upd, m, v
