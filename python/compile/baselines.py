"""Baseline mixers the paper compares against (Tables 1–3).

Each mixer exposes `init(rng, cfg) -> params` and
`apply(params, x, cfg, causal, ...) -> (z, reg, s_eff)` with the same
signature shape as the STLT layer so the trunk is architecture-generic.

Causality adaptations (documented in DESIGN.md §3 substitutions):
  * vanilla    — standard multi-head softmax attention (exact).
  * linformer  — low-rank K/V projection. Linformer is not causal by
    construction; for LM rows we use the block-causal adaptation: full
    causal attention inside a block, previous blocks contribute through
    their k-dim projected summaries.
  * fnet       — "fixed spectral mixer, no decay": a frozen Laplace/
    Fourier bank (sigma tiny & fixed, omega on a fixed Fourier grid,
    nothing learnable) through the same linear machinery. This is the
    causal analogue of FNet's fixed FFT mixing and doubles as the
    fixed-everything ablation row.
  * ssm        — diagonal complex SSM (S4D-lite): per-channel learnable
    (sigma, omega) filter + channel mixing; the "Mamba-like" row
    (selectivity omitted; caveat recorded).
  * performer  — positive-feature (ReLU) linear attention with causal
    prefix sums.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from .config import ModelConfig
from .kernels import ops

_ZERO = lambda: jnp.zeros((), jnp.float32)


def _dense(k, i, o):
    return jnp.asarray(k.normal(0, 0.02, (i, o)).astype(np.float32))


# ---------------------------------------------------------------------------
# Vanilla multi-head attention
# ---------------------------------------------------------------------------


def vanilla_init(rng, cfg):
    k = np.random.default_rng(rng)
    d = cfg.d_model
    return {
        "w_q": _dense(k, d, d),
        "w_k": _dense(k, d, d),
        "w_v": _dense(k, d, d),
        "w_o": _dense(k, d, d),
    }


def _heads(x, h):
    b, n, d = x.shape
    return x.reshape(b, n, h, d // h).transpose(0, 2, 1, 3)  # [B,h,N,dh]


def vanilla_apply(p, x, cfg, causal, **_):
    b, n, d = x.shape
    h = cfg.n_heads
    q = _heads(x @ p["w_q"], h)
    k = _heads(x @ p["w_k"], h)
    v = _heads(x @ p["w_v"], h)
    a = jnp.einsum("bhnd,bhmd->bhnm", q, k) / jnp.sqrt(jnp.float32(d // h))
    if causal:
        mask = jnp.tril(jnp.ones((n, n), dtype=bool))
        a = jnp.where(mask[None, None], a, -jnp.inf)
    a = jax.nn.softmax(a, axis=-1)
    z = jnp.einsum("bhnm,bhmd->bhnd", a, v)
    z = z.transpose(0, 2, 1, 3).reshape(b, n, d)
    return z @ p["w_o"], _ZERO(), jnp.float32(cfg.n_heads)


# ---------------------------------------------------------------------------
# Linformer (block-causal adaptation for LM; exact low-rank for encoder use)
# ---------------------------------------------------------------------------


def linformer_init(rng, cfg):
    k = np.random.default_rng(rng)
    d = cfg.d_model
    p = vanilla_init(rng, cfg)
    p["e_proj"] = _dense(k, cfg.n_ctx, cfg.linformer_k)
    return p


def linformer_apply(p, x, cfg, causal, **_):
    b, n, d = x.shape
    h = cfg.n_heads
    q = _heads(x @ p["w_q"], h)
    k = _heads(x @ p["w_k"], h)
    v = _heads(x @ p["w_v"], h)
    e = p["e_proj"][:n, :]  # [N, kp]
    if not causal:
        kp = jnp.einsum("bhnd,nk->bhkd", k, e)
        vp = jnp.einsum("bhnd,nk->bhkd", v, e)
        a = jnp.einsum("bhnd,bhkd->bhnk", q, kp) / jnp.sqrt(jnp.float32(d // h))
        a = jax.nn.softmax(a, axis=-1)
        z = jnp.einsum("bhnk,bhkd->bhnd", a, vp)
    else:
        # block-causal: within-block exact causal attn; previous blocks via
        # projected summaries restricted to a lower-triangular block mask.
        blk = max(16, cfg.linformer_k)
        nb = (n + blk - 1) // blk
        pad = nb * blk - n
        if pad:
            q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
            k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        npad = nb * blk
        scale = 1.0 / jnp.sqrt(jnp.float32(d // h))
        # local causal
        a_loc = jnp.einsum("bhnd,bhmd->bhnm", q, k) * scale
        pos = jnp.arange(npad)
        same_blk = (pos[:, None] // blk) == (pos[None, :] // blk)
        causal_m = pos[None, :] <= pos[:, None]
        loc_mask = same_blk & causal_m
        # previous-block summaries: per-block means projected to kp dims
        kb = k.reshape(b, h, nb, blk, d // h)
        vb = v.reshape(b, h, nb, blk, d // h)
        ep = p["e_proj"][:blk, : cfg.linformer_k]  # [blk, kp]
        ks = jnp.einsum("bhgld,lk->bhgkd", kb, ep).reshape(b, h, -1, d // h)
        vs = jnp.einsum("bhgld,lk->bhgkd", vb, ep).reshape(b, h, -1, d // h)
        a_sum = jnp.einsum("bhnd,bhmd->bhnm", q, ks) * scale
        sum_blk = jnp.repeat(jnp.arange(nb), cfg.linformer_k)
        prev_mask = sum_blk[None, :] < (pos[:, None] // blk)
        logits = jnp.concatenate(
            [
                jnp.where(loc_mask[None, None], a_loc, -jnp.inf),
                jnp.where(prev_mask[None, None], a_sum, -jnp.inf),
            ],
            axis=-1,
        )
        a = jax.nn.softmax(logits, axis=-1)
        vall = jnp.concatenate([v, vs], axis=2)
        z = jnp.einsum("bhnm,bhmd->bhnd", a, vall)
        z = z[:, :, :n]
        q = q[:, :, :n]
    z = z.transpose(0, 2, 1, 3).reshape(b, n, d)
    return z @ p["w_o"], _ZERO(), jnp.float32(cfg.linformer_k)


# ---------------------------------------------------------------------------
# FNet-causal: frozen spectral bank through the STLT linear machinery
# ---------------------------------------------------------------------------


def fnet_init(rng, cfg):
    k = np.random.default_rng(rng)
    d, s = cfg.d_model, cfg.s_max
    return {
        "w_f": _dense(k, d, s),
        "w_v": _dense(k, d, d),
        "w_o": _dense(k, d, d),
    }


def _fnet_nodes(cfg):
    s = cfg.s_max
    sigma = np.full(s, 0.02, np.float32)  # tiny fixed decay for stability
    omega = (np.pi * np.arange(s) / max(s, 1)).astype(np.float32)  # Fourier grid
    decay = jnp.asarray(np.exp(-sigma))
    theta = jnp.asarray(omega)
    return decay, theta


def fnet_apply(p, x, cfg, causal, **_):
    decay, theta = _fnet_nodes(cfg)
    f = jnp.einsum("bnd,ds->bns", x, p["w_f"])
    v = jnp.einsum("bnd,de->bne", x, p["w_v"])
    if causal:
        z = ops.linear_mode_uni_batched(f, v, decay, theta) * jnp.float32(cfg.s_max)
    else:
        l_re, l_im = ops.scan_bi_batched(f, decay, theta)
        u_re = jnp.einsum("bns,bnd->bsd", l_re, v)
        u_im = jnp.einsum("bns,bnd->bsd", -l_im, v)
        z = jnp.einsum("bns,bsd->bnd", l_re, u_re) - jnp.einsum(
            "bns,bsd->bnd", l_im, u_im
        )
    z = z / jnp.float32(cfg.s_max)
    return jnp.einsum("bnd,de->bne", z, p["w_o"]), _ZERO(), jnp.float32(cfg.s_max)


# ---------------------------------------------------------------------------
# Diagonal complex SSM ("Mamba-like" row; selectivity omitted)
# ---------------------------------------------------------------------------


def ssm_init(rng, cfg):
    k = np.random.default_rng(rng)
    d = cfg.d_model
    sig = np.geomspace(0.01, 1.0, d).astype(np.float32)
    return {
        "sigma_raw": jnp.asarray(np.log(np.expm1(sig))),
        "omega": jnp.asarray(k.uniform(0, np.pi / 2, d).astype(np.float32)),
        "w_in": _dense(k, d, d),
        "w_o": _dense(k, d, d),
        "d_skip": jnp.ones((d,), jnp.float32),
    }


def ssm_apply(p, x, cfg, causal, **_):
    sigma = jnp.logaddexp(p["sigma_raw"], 0.0) + 1e-3
    decay = jnp.exp(-sigma)
    theta = p["omega"]
    u = jnp.einsum("bnd,de->bne", x, p["w_in"])
    if causal:
        h_re, _ = ops.scan_uni_batched(u, decay, theta)
    else:
        h_re, _ = ops.scan_bi_batched(u, decay, theta)
    y = h_re + u * p["d_skip"][None, None, :]
    return jnp.einsum("bnd,de->bne", y, p["w_o"]), _ZERO(), jnp.float32(cfg.d_model)


# ---------------------------------------------------------------------------
# Performer-style linear attention (positive ReLU features)
# ---------------------------------------------------------------------------


def performer_init(rng, cfg):
    return vanilla_init(rng, cfg)


def performer_apply(p, x, cfg, causal, **_):
    b, n, d = x.shape
    h = cfg.n_heads
    q = jax.nn.relu(_heads(x @ p["w_q"], h)) + 1e-6
    k = jax.nn.relu(_heads(x @ p["w_k"], h)) + 1e-6
    v = _heads(x @ p["w_v"], h)
    if causal:
        kv = jnp.cumsum(jnp.einsum("bhnd,bhne->bhnde", k, v), axis=2)
        ks = jnp.cumsum(k, axis=2)
        num = jnp.einsum("bhnd,bhnde->bhne", q, kv)
        den = jnp.einsum("bhnd,bhnd->bhn", q, ks)[..., None]
    else:
        kv = jnp.einsum("bhnd,bhne->bhde", k, v)
        ks = jnp.sum(k, axis=2)
        num = jnp.einsum("bhnd,bhde->bhne", q, kv)
        den = jnp.einsum("bhnd,bhd->bhn", q, ks)[..., None]
    z = num / jnp.maximum(den, 1e-6)
    z = z.transpose(0, 2, 1, 3).reshape(b, n, d)
    return z @ p["w_o"], _ZERO(), jnp.float32(cfg.n_heads)


MIXERS = {
    "vanilla": (vanilla_init, vanilla_apply),
    "linformer": (linformer_init, linformer_apply),
    "fnet": (fnet_init, fnet_apply),
    "ssm": (ssm_init, ssm_apply),
    "performer": (performer_init, performer_apply),
}
