"""Pure-jnp oracles for the STLT kernels.

Every Pallas kernel in `stlt.py` has a direct, O(N^2 S) (or otherwise
naive) counterpart here. These are the CORE correctness signal: pytest
asserts `allclose(kernel, ref)` over shape/dtype/parameter sweeps.

Conventions (shared with the kernels — see DESIGN.md R1..R4):
  * Complex numbers are carried as explicit (re, im) f32 planes.
  * The Laplace kernel is *relative*: e^{-s_k (n-m) Delta} decaying away
    from the query position n (both directions for the bilateral
    transform, past-only for the unilateral one). Current position is
    included with weight 1 (m == n term).
  * The streaming-compatible window is exponential, folded into the
    decay before these functions are called: sigma_eff = sigma + 1/T.
    Callers pass per-step complex multipliers (decay, theta):
        lam_k = decay_k * exp(-j * theta_k),  decay_k = e^{-sigma_eff_k * Delta}
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def node_multiplier(sigma: jnp.ndarray, omega: jnp.ndarray, delta: float = 1.0):
    """Per-step complex multiplier lam_k = e^{-(sigma_k + j omega_k) * delta}.

    Returns (decay, theta): decay = |lam| = e^{-sigma*delta}, theta = omega*delta.
    """
    decay = jnp.exp(-sigma * delta)
    theta = omega * delta
    return decay, theta


def _lam_powers(decay, theta, n_pows):
    """lam^p for p in [0, n_pows): (re, im) arrays of shape [n_pows, S]."""
    p = jnp.arange(n_pows)[:, None].astype(jnp.float32)
    mag = decay[None, :] ** p
    ang = -theta[None, :] * p  # e^{-j theta p}
    return mag * jnp.cos(ang), mag * jnp.sin(ang)


def stlt_scan_uni(f, decay, theta):
    """Unilateral (causal) STLT. f: [N, S] -> (L_re, L_im): [N, S].

    L_{n,k} = sum_{m<=n} f_{m,k} lam_k^{n-m}
    """
    n = f.shape[0]
    pow_re, pow_im = _lam_powers(decay, theta, n)  # [N, S]
    # W[n, m] weight = lam^{n-m} for m <= n else 0
    idx = jnp.arange(n)
    dist = idx[:, None] - idx[None, :]  # n - m
    mask = (dist >= 0).astype(jnp.float32)
    d = jnp.clip(dist, 0, n - 1)
    w_re = pow_re[d] * mask[..., None]  # [N, N, S]
    w_im = pow_im[d] * mask[..., None]
    l_re = jnp.einsum("nms,ms->ns", w_re, f)
    l_im = jnp.einsum("nms,ms->ns", w_im, f)
    return l_re, l_im


def stlt_scan_bi(f, decay, theta):
    """Bilateral STLT. L_{n,k} = sum_m f_{m,k} lam_k^{|n-m|}. f: [N, S]."""
    n = f.shape[0]
    pow_re, pow_im = _lam_powers(decay, theta, n)
    idx = jnp.arange(n)
    d = jnp.abs(idx[:, None] - idx[None, :])
    w_re = pow_re[d]  # [N, N, S]
    w_im = pow_im[d]
    l_re = jnp.einsum("nms,ms->ns", w_re, f)
    l_im = jnp.einsum("nms,ms->ns", w_im, f)
    return l_re, l_im


def relevance(l_re, l_im):
    """R_{n,m} = Re( sum_k L_{n,k} conj(L_{m,k}) ). -> [N, N]."""
    return l_re @ l_re.T + l_im @ l_im.T


def relevance_qmode(l_re, l_im, v, causal: bool = False):
    """Figure-1-faithful quadratic mode: Z = softmax(R / sqrt(S)) V.

    l_*: [N, S], v: [N, d] -> [N, d].
    """
    s = l_re.shape[1]
    r = relevance(l_re, l_im) / jnp.sqrt(jnp.float32(s))
    if causal:
        n = r.shape[0]
        mask = jnp.tril(jnp.ones((n, n), dtype=bool))
        r = jnp.where(mask, r, -jnp.inf)
    a = jnp.exp(r - jnp.max(r, axis=-1, keepdims=True))
    a = a / jnp.sum(a, axis=-1, keepdims=True)
    return a @ v


def linear_mode_uni(f, v, decay, theta, u_gamma=None):
    """Complexity-faithful causal linear mode (DESIGN.md R2 + R4).

    L from the causal scan; U is a *windowed* (discounted) accumulation
    U_k(n) = sum_{m<=n} u_gamma_k^{n-m} conj(L_{m,k}) v_m — the
    exponential window applied to the value side as well, which keeps
    the streaming state stationary (unbounded prefix sums drift out of
    the training distribution on 10k+ token streams).
    Z_n = Re( sum_k L_{n,k} U_k(n) ) / S.   f: [N,S], v: [N,d] -> [N,d].
    """
    s = f.shape[1]
    if u_gamma is None:
        u_gamma = jnp.ones((s,), jnp.float32)
    l_re, l_im = stlt_scan_uni(f, decay, theta)

    def step(c, x):
        ur, ui = c
        lr, li, vn = x
        ur = u_gamma[:, None] * ur + lr[:, None] * vn[None, :]
        ui = u_gamma[:, None] * ui - li[:, None] * vn[None, :]
        z = lr @ ur - li @ ui
        return (ur, ui), z

    d = v.shape[1]
    c0 = (jnp.zeros((s, d), jnp.float32), jnp.zeros((s, d), jnp.float32))
    _, z = jax.lax.scan(step, c0, (l_re, l_im, v))
    return z / jnp.float32(s)


def linear_mode_bi(f, v, decay, theta):
    """Bilateral linear mode: U uses the full-sequence sum (encoder)."""
    l_re, l_im = stlt_scan_bi(f, decay, theta)
    u_re = jnp.sum(l_re[:, :, None] * v[:, None, :], axis=0)  # [S, d]
    u_im = jnp.sum(-l_im[:, :, None] * v[:, None, :], axis=0)
    z = l_re @ u_re - l_im @ u_im
    return z / jnp.float32(f.shape[1])


def stream_carry_init(s: int, d: int):
    """Zero carry for streaming linear mode: (L_prev, U) re/im planes."""
    return (
        jnp.zeros((s, 2), jnp.float32),  # last L (re, im)
        jnp.zeros((s, d, 2), jnp.float32),  # U accumulator (re, im)
    )


def linear_mode_stream_chunk(f, v, decay, theta, carry, u_gamma=None):
    """Process one chunk with an O(S d) carry; equals linear_mode_uni on
    the concatenated stream. Returns (z, new_carry)."""
    if u_gamma is None:
        u_gamma = jnp.ones((f.shape[1],), jnp.float32)
    l_last, u = carry
    lam_re = decay * jnp.cos(theta)
    lam_im = -decay * jnp.sin(theta)

    def step(c, inp):
        (lr, li), (ur, ui) = c
        fn, vn = inp
        nlr = lam_re * lr - lam_im * li + fn
        nli = lam_re * li + lam_im * lr
        nur = u_gamma[:, None] * ur + nlr[:, None] * vn[None, :]
        nui = u_gamma[:, None] * ui - nli[:, None] * vn[None, :]
        z = nlr @ nur - nli @ nui
        return ((nlr, nli), (nur, nui)), z

    c0 = ((l_last[:, 0], l_last[:, 1]), (u[:, :, 0], u[:, :, 1]))
    (lc, uc), z = jax.lax.scan(step, c0, (f, v))
    new_carry = (
        jnp.stack([lc[0], lc[1]], axis=-1),
        jnp.stack([uc[0], uc[1]], axis=-1),
    )
    return z / jnp.float32(f.shape[1]), new_carry
