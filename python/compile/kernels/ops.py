"""Differentiable STLT ops: Pallas kernels + hand-derived custom VJPs.

`pallas_call` has no reverse-mode rule (even in interpret mode), so the
causal complex scan — the one true recurrence primitive — gets a
`jax.custom_vjp` whose backward pass is itself built from the same
Pallas scan kernel:

  forward   L_n = lam * L_{n-1} + f_n                       (1 scan)
  d f       df_m = sum_{n>=m} g_n conj(lam)^{n-m}
            = reversed conj-scan of the output cotangent g   (1 scan)
  d lam     M_n := dL_n/dlam satisfies M_n = lam M_{n-1} + L_{n-1}
            c_k = sum_n conj(g_n) M_n ;
            d decay = Re(c e^{-j theta}), d theta = Im(c lam) (1 scan)

Everything else in the layer (bilateral transform, linear mode,
quadratic relevance) is composed from this primitive plus plain jnp, so
the whole model is end-to-end differentiable while every recurrence
executes in the Pallas kernel.

Shapes: f_re/f_im [N, S]; decay/theta [S]. Columns are independent, so
Layer 2 batches by folding (B, N, S) -> (N, B*S) and tiling the node
parameters — no vmap needed on the training path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import stlt


def _scan(f_re, f_im, decay, theta):
    return stlt.stlt_scan_uni_c(f_re, f_im, decay, theta)


@jax.custom_vjp
def scan_uni(f_re, f_im, decay, theta):
    """Differentiable causal complex scan; returns (L_re, L_im)."""
    return _scan(f_re, f_im, decay, theta)


def _scan_uni_fwd(f_re, f_im, decay, theta):
    l_re, l_im = _scan(f_re, f_im, decay, theta)
    return (l_re, l_im), (l_re, l_im, decay, theta)


def _scan_uni_bwd(res, g):
    l_re, l_im, decay, theta = res
    g_re, g_im = g
    # --- df: reversed scan with conj(lam) = decay * e^{+j theta} ---
    h_re, h_im = _scan(g_re[::-1], g_im[::-1], decay, -theta)
    df_re = h_re[::-1]
    df_im = h_im[::-1]
    # --- dlam via M-scan: M_n = lam M_{n-1} + L_{n-1} (shifted L input) ---
    ls_re = jnp.concatenate([jnp.zeros_like(l_re[:1]), l_re[:-1]], axis=0)
    ls_im = jnp.concatenate([jnp.zeros_like(l_im[:1]), l_im[:-1]], axis=0)
    m_re, m_im = _scan(ls_re, ls_im, decay, theta)
    # c_k = sum_n conj(g_n) M_n
    c_re = jnp.sum(g_re * m_re + g_im * m_im, axis=0)
    c_im = jnp.sum(g_re * m_im - g_im * m_re, axis=0)
    # lam = decay e^{-j theta}: dlam/ddecay = e^{-j theta}; dlam/dtheta = -j lam
    ct, st = jnp.cos(theta), jnp.sin(theta)
    d_decay = c_re * ct + c_im * st  # Re(c * e^{-j theta})
    lam_re, lam_im = decay * ct, -decay * st
    d_theta = c_re * lam_im + c_im * lam_re  # Im(c * lam)
    return df_re, df_im, d_decay, d_theta


scan_uni.defvjp(_scan_uni_fwd, _scan_uni_bwd)


# ---------------------------------------------------------------------------
# Compositions (all differentiable)
# ---------------------------------------------------------------------------


def scan_uni_real(f, decay, theta):
    """Causal STLT of a real signal. f [N, S] -> (L_re, L_im)."""
    return scan_uni(f, jnp.zeros_like(f), decay, theta)


def scan_bi_real(f, decay, theta):
    """Bilateral STLT via two causal scans (DESIGN.md: Bwd = rev(scan(rev f)) - f)."""
    fwd_re, fwd_im = scan_uni_real(f, decay, theta)
    rev_re, rev_im = scan_uni_real(f[::-1], decay, theta)
    bwd_re = rev_re[::-1] - f
    bwd_im = rev_im[::-1]
    return fwd_re + bwd_re, fwd_im + bwd_im


def linear_mode_uni(f, v, decay, theta, u_gamma=None):
    """Causal linear mode (training path): Pallas scans + jnp U-scan.

    Numerically identical to kernels.stlt.linear_mode_uni (the fused
    inference kernel) — asserted in python/tests.
    """
    s = f.shape[1]
    if u_gamma is None:
        u_gamma = jnp.ones((s,), jnp.float32)
    l_re, l_im = scan_uni_real(f, decay, theta)

    def step(c, x):
        ur, ui = c
        lr, li, vn = x
        ur = u_gamma[:, None] * ur + lr[:, None] * vn[None, :]
        ui = u_gamma[:, None] * ui - li[:, None] * vn[None, :]
        z = lr @ ur - li @ ui
        return (ur, ui), z

    d = v.shape[1]
    c0 = (jnp.zeros((s, d), jnp.float32), jnp.zeros((s, d), jnp.float32))
    _, z = jax.lax.scan(step, c0, (l_re, l_im, v))
    return z / jnp.float32(s)


def linear_mode_bi(f, v, decay, theta):
    """Bilateral linear mode (encoder): U is the full-sequence sum."""
    l_re, l_im = scan_bi_real(f, decay, theta)
    u_re = jnp.einsum("ns,nd->sd", l_re, v)
    u_im = jnp.einsum("ns,nd->sd", -l_im, v)
    z = l_re @ u_re - l_im @ u_im
    return z / jnp.float32(f.shape[1])


def quadratic_mode(f, v, decay, theta, causal: bool):
    """Figure-1-faithful mode: Z = softmax(Re(L L^H)/sqrt(S)) V (training path)."""
    if causal:
        l_re, l_im = scan_uni_real(f, decay, theta)
    else:
        l_re, l_im = scan_bi_real(f, decay, theta)
    s = f.shape[1]
    r = (l_re @ l_re.T + l_im @ l_im.T) / jnp.sqrt(jnp.float32(s))
    if causal:
        n = r.shape[0]
        mask = jnp.tril(jnp.ones((n, n), dtype=bool))
        r = jnp.where(mask, r, -jnp.inf)
    a = jax.nn.softmax(r, axis=-1)
    return a @ v


# Batched helpers: fold batch into the node/column axis (no vmap on the
# training path; columns are independent in the scan).


def _fold(fb):  # [B, N, S] -> [N, B*S]
    b, n, s = fb.shape
    return jnp.transpose(fb, (1, 0, 2)).reshape(n, b * s)


def _unfold(l, b, s):  # [N, B*S] -> [B, N, S]
    n = l.shape[0]
    return jnp.transpose(l.reshape(n, b, s), (1, 0, 2))


def linear_mode_uni_batched(fb, vb, decay, theta, u_gamma=None):
    """Batched causal linear mode: Pallas L-scan + lax.scan U-accumulation.

    The U prefix-sum is a sequential scan with an O(B S d) carry instead
    of materialising the O(B N S d) cumsum — ~6x faster fwd+bwd on CPU
    (EXPERIMENTS.md §Perf L2-1). fb: [B,N,S], vb: [B,N,d] -> [B,N,d]."""
    b, n, s = fb.shape
    if u_gamma is None:
        u_gamma = jnp.ones((s,), jnp.float32)
    l_re, l_im = scan_uni_batched(fb, decay, theta)

    def step(c, x):
        ur, ui = c
        lr, li, vv = x
        g = u_gamma[None, :, None]
        ur = g * ur + jnp.einsum("bs,bd->bsd", lr, vv)
        ui = g * ui - jnp.einsum("bs,bd->bsd", li, vv)
        z = jnp.einsum("bs,bsd->bd", lr, ur) - jnp.einsum("bs,bsd->bd", li, ui)
        return (ur, ui), z

    d = vb.shape[-1]
    c0 = (jnp.zeros((b, s, d), jnp.float32), jnp.zeros((b, s, d), jnp.float32))
    _, z = jax.lax.scan(
        step,
        c0,
        (l_re.transpose(1, 0, 2), l_im.transpose(1, 0, 2), vb.transpose(1, 0, 2)),
    )
    return z.transpose(1, 0, 2) / jnp.float32(s)


def scan_uni_batched(fb, decay, theta):
    """fb: [B, N, S] -> (L_re, L_im) [B, N, S] via column folding."""
    b, n, s = fb.shape
    dec = jnp.tile(decay, b)
    th = jnp.tile(theta, b)
    l_re, l_im = scan_uni_real(_fold(fb), dec, th)
    return _unfold(l_re, b, s), _unfold(l_im, b, s)


def scan_bi_batched(fb, decay, theta):
    b, n, s = fb.shape
    dec = jnp.tile(decay, b)
    th = jnp.tile(theta, b)
    l_re, l_im = scan_bi_real(_fold(fb), dec, th)
    return _unfold(l_re, b, s), _unfold(l_im, b, s)
