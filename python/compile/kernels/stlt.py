"""Layer-1 Pallas kernels for the learnable two-sided STLT.

Four kernels implement the paper's compute hot-spots (DESIGN.md §4):

  * `stlt_scan_uni`  — unilateral (causal) Laplace scan, eq. (4) in
    relative form: one forward recurrence, O(N S) work, O(S) carry.
  * `stlt_scan_bi`   — bilateral scan, eq. (3): forward + backward
    recurrences summed ("two linear passes", §3.3).
  * `relevance_qmode`— Figure-1-faithful quadratic mode: tiled
    R = Re(L Lᴴ)/√S with an online-softmax accumulator (flash-style)
    and Z = softmax(R) V, never materialising the full N×N matrix in
    kernel memory (one 128-wide tile at a time).
  * `linear_mode_uni`— complexity-faithful causal mode: fused L-scan +
    conj(L)·v prefix accumulation, emitting Z_n directly with an
    O(S d) carry. This is the streaming hot path.

TPU mapping (DESIGN.md §Hardware-Adaptation): the scans keep the
O(S d) carry in VMEM scratch while BlockSpec streams x/V tiles
HBM→VMEM; the quadratic path is an MXU-friendly tiled matmul. Kernels
are lowered with `interpret=True` — the CPU PJRT plugin cannot execute
Mosaic custom-calls; real-TPU characteristics are estimated
analytically in DESIGN.md §7.

All kernels operate on a single sequence ([N, ...]); batching is done
with `jax.vmap` in Layer 2. Complex values are explicit (re, im) f32
planes, identical to `ref.py`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True  # CPU PJRT cannot run Mosaic custom-calls; see module doc.


def _lam(decay, theta):
    """Complex per-step multiplier lam = decay * e^{-j theta} as (re, im)."""
    return decay * jnp.cos(theta), -decay * jnp.sin(theta)


# ---------------------------------------------------------------------------
# Unilateral (causal) scan
# ---------------------------------------------------------------------------


def _scan_uni_c_kernel(fr_ref, fi_ref, lam_re_ref, lam_im_ref, o_re_ref, o_im_ref):
    n = fr_ref.shape[0]
    lam_re = lam_re_ref[...]
    lam_im = lam_im_ref[...]

    def body(i, carry):
        lr, li = carry
        fr = pl.load(fr_ref, (pl.dslice(i, 1), slice(None)))[0]
        fi = pl.load(fi_ref, (pl.dslice(i, 1), slice(None)))[0]
        nlr = lam_re * lr - lam_im * li + fr
        nli = lam_re * li + lam_im * lr + fi
        pl.store(o_re_ref, (pl.dslice(i, 1), slice(None)), nlr[None, :])
        pl.store(o_im_ref, (pl.dslice(i, 1), slice(None)), nli[None, :])
        return nlr, nli

    s = fr_ref.shape[1]
    zero = jnp.zeros((s,), jnp.float32)
    jax.lax.fori_loop(0, n, body, (zero, zero))


def stlt_scan_uni_c(f_re, f_im, decay, theta, block_s: int = 64):
    """Complex-input causal scan: L_n = lam * L_{n-1} + f_n over C^S.

    This is THE differentiable primitive (see ops.py custom_vjp): the
    forward STLT, its input-cotangent scan (conj(lam), reversed) and the
    node-parameter M-scan are all instances of this kernel.
    f_*: [N, S]; per-column independent, so batching = column concat.
    """
    n, s = f_re.shape
    bs = min(block_s, s)
    if s % bs != 0:
        bs = s
    out = pl.pallas_call(
        _scan_uni_c_kernel,
        grid=(s // bs,),
        in_specs=[
            pl.BlockSpec((n, bs), lambda j: (0, j)),
            pl.BlockSpec((n, bs), lambda j: (0, j)),
            pl.BlockSpec((bs,), lambda j: (j,)),
            pl.BlockSpec((bs,), lambda j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((n, bs), lambda j: (0, j)),
            pl.BlockSpec((n, bs), lambda j: (0, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, s), jnp.float32),
            jax.ShapeDtypeStruct((n, s), jnp.float32),
        ],
        interpret=INTERPRET,
    )(f_re, f_im, decay * jnp.cos(theta), -decay * jnp.sin(theta))
    return out[0], out[1]


def stlt_scan_uni(f, decay, theta, block_s: int = 64):
    """Causal STLT scan, real input. f: [N, S] -> (L_re, L_im)."""
    return stlt_scan_uni_c(f, jnp.zeros_like(f), decay, theta, block_s)


# ---------------------------------------------------------------------------
# Bilateral scan: forward pass (m <= n) + strictly-backward pass (m > n)
# ---------------------------------------------------------------------------


def _scan_bi_kernel(f_ref, lam_re_ref, lam_im_ref, o_re_ref, o_im_ref):
    n = f_ref.shape[0]
    lam_re = lam_re_ref[...]
    lam_im = lam_im_ref[...]
    s = f_ref.shape[1]
    zero = jnp.zeros((s,), jnp.float32)

    def fwd(i, carry):
        lr, li = carry
        fi = pl.load(f_ref, (pl.dslice(i, 1), slice(None)))[0]
        nlr = lam_re * lr - lam_im * li + fi
        nli = lam_re * li + lam_im * lr
        pl.store(o_re_ref, (pl.dslice(i, 1), slice(None)), nlr[None, :])
        pl.store(o_im_ref, (pl.dslice(i, 1), slice(None)), nli[None, :])
        return nlr, nli

    jax.lax.fori_loop(0, n, fwd, (zero, zero))

    # Backward: carry the strictly-future sum B_n = sum_{m>n} f_m lam^{m-n};
    # add to the already-stored forward value.
    def bwd(j, carry):
        i = n - 1 - j
        br, bi_ = carry
        fwd_r = pl.load(o_re_ref, (pl.dslice(i, 1), slice(None)))[0]
        fwd_i = pl.load(o_im_ref, (pl.dslice(i, 1), slice(None)))[0]
        pl.store(o_re_ref, (pl.dslice(i, 1), slice(None)), (fwd_r + br)[None, :])
        pl.store(o_im_ref, (pl.dslice(i, 1), slice(None)), (fwd_i + bi_)[None, :])
        fi = pl.load(f_ref, (pl.dslice(i, 1), slice(None)))[0]
        nbr = lam_re * (br + fi) - lam_im * (bi_)
        nbi = lam_re * (bi_) + lam_im * (br + fi)
        return nbr, nbi

    jax.lax.fori_loop(0, n, bwd, (zero, zero))


def stlt_scan_bi(f, decay, theta, block_s: int = 64):
    """Bilateral STLT scan ("two linear passes"). f: [N, S] -> (re, im)."""
    n, s = f.shape
    bs = min(block_s, s)
    assert s % bs == 0
    lam_re, lam_im = _lam(decay, theta)
    out = pl.pallas_call(
        _scan_bi_kernel,
        grid=(s // bs,),
        in_specs=[
            pl.BlockSpec((n, bs), lambda j: (0, j)),
            pl.BlockSpec((bs,), lambda j: (j,)),
            pl.BlockSpec((bs,), lambda j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((n, bs), lambda j: (0, j)),
            pl.BlockSpec((n, bs), lambda j: (0, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, s), jnp.float32),
            jax.ShapeDtypeStruct((n, s), jnp.float32),
        ],
        interpret=INTERPRET,
    )(f, lam_re, lam_im)
    return out[0], out[1]


# ---------------------------------------------------------------------------
# Quadratic relevance mode (Figure 1): Z = softmax(Re(L Lᴴ)/√S) V
# ---------------------------------------------------------------------------


def _relevance_kernel(l_re_q, l_im_q, l_re_k, l_im_k, v_ref, o_ref, *, block_k, causal, n_total):
    """One query tile; online softmax over key tiles (flash-style)."""
    qi = pl.program_id(0)
    bq = l_re_q.shape[0]
    d = v_ref.shape[1]
    s = l_re_q.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.float32(s))

    lrq = l_re_q[...]
    liq = l_im_q[...]

    num_k = n_total // block_k

    def body(kj, carry):
        m_prev, l_prev, acc = carry
        lrk = pl.load(l_re_k, (pl.dslice(kj * block_k, block_k), slice(None)))
        lik = pl.load(l_im_k, (pl.dslice(kj * block_k, block_k), slice(None)))
        vk = pl.load(v_ref, (pl.dslice(kj * block_k, block_k), slice(None)))
        # Re(L_q L_kᴴ) = re·reᵀ + im·imᵀ
        r = (jnp.dot(lrq, lrk.T) + jnp.dot(liq, lik.T)) * scale  # [bq, bk]
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, block_k), 0)
            kpos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32, (bq, block_k), 1)
            r = jnp.where(kpos <= qpos, r, -jnp.inf)
        m_new = jnp.maximum(m_prev, jnp.max(r, axis=1))
        # guard -inf rows (fully masked tiles)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(r - m_safe[:, None])
        p = jnp.where(jnp.isfinite(r), p, 0.0)
        corr = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
        l_new = l_prev * corr + jnp.sum(p, axis=1)
        acc = acc * corr[:, None] + jnp.dot(p, vk)
        return m_new, l_new, acc

    m0 = jnp.full((bq,), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc0 = jnp.zeros((bq, d), jnp.float32)
    if causal:
        # keys strictly beyond this query tile are fully masked; skip them.
        num_k_eff = (qi + 1) * bq // block_k
        num_k_eff = jnp.minimum(num_k_eff + (1 if (bq % block_k) else 0), num_k)
    else:
        num_k_eff = num_k
    m, l, acc = jax.lax.fori_loop(0, num_k_eff, body, (m0, l0, acc0))
    o_ref[...] = acc / jnp.maximum(l, 1e-30)[:, None]


def relevance_qmode(l_re, l_im, v, causal: bool = False, block_q: int = 128, block_k: int = 128):
    """Quadratic ("figure-faithful") mode. l_*: [N,S], v: [N,d] -> [N,d]."""
    n, s = l_re.shape
    d = v.shape[1]
    bq = min(block_q, n)
    bk = min(block_k, n)
    assert n % bq == 0 and n % bk == 0
    kern = functools.partial(_relevance_kernel, block_k=bk, causal=causal, n_total=n)
    return pl.pallas_call(
        kern,
        grid=(n // bq,),
        in_specs=[
            pl.BlockSpec((bq, s), lambda i: (i, 0)),
            pl.BlockSpec((bq, s), lambda i: (i, 0)),
            pl.BlockSpec((n, s), lambda i: (0, 0)),
            pl.BlockSpec((n, s), lambda i: (0, 0)),
            pl.BlockSpec((n, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bq, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        interpret=INTERPRET,
    )(l_re, l_im, l_re, l_im, v)


# ---------------------------------------------------------------------------
# Linear (complexity-faithful) causal mode: fused double scan
# ---------------------------------------------------------------------------


def _linear_uni_kernel(f_ref, v_ref, lam_re_ref, lam_im_ref, gam_ref, o_ref):
    n, s = f_ref.shape
    d = v_ref.shape[1]
    lam_re = lam_re_ref[...]
    lam_im = lam_im_ref[...]
    gam = gam_ref[...]
    inv_s = 1.0 / jnp.float32(s)

    def body(i, carry):
        lr, li, ur, ui = carry
        fi = pl.load(f_ref, (pl.dslice(i, 1), slice(None)))[0]
        vi = pl.load(v_ref, (pl.dslice(i, 1), slice(None)))[0]
        nlr = lam_re * lr - lam_im * li + fi
        nli = lam_re * li + lam_im * lr
        nur = gam[:, None] * ur + nlr[:, None] * vi[None, :]
        nui = gam[:, None] * ui - nli[:, None] * vi[None, :]
        z = (jnp.dot(nlr, nur) - jnp.dot(nli, nui)) * inv_s
        pl.store(o_ref, (pl.dslice(i, 1), slice(None)), z[None, :])
        return nlr, nli, nur, nui

    z_s = jnp.zeros((s,), jnp.float32)
    z_sd = jnp.zeros((s, d), jnp.float32)
    jax.lax.fori_loop(0, n, body, (z_s, z_s, z_sd, z_sd))


def linear_mode_uni(f, v, decay, theta, u_gamma=None):
    """Causal linear mode, O(N S d) time / O(S d) carry. -> Z [N, d]."""
    n, s = f.shape
    d = v.shape[1]
    if u_gamma is None:
        u_gamma = jnp.ones((s,), jnp.float32)
    lam_re, lam_im = _lam(decay, theta)
    return pl.pallas_call(
        _linear_uni_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((n, s), lambda i: (0, 0)),
            pl.BlockSpec((n, d), lambda i: (0, 0)),
            pl.BlockSpec((s,), lambda i: (0,)),
            pl.BlockSpec((s,), lambda i: (0,)),
            pl.BlockSpec((s,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((n, d), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        interpret=INTERPRET,
    )(f, v, lam_re, lam_im, u_gamma)


# ---------------------------------------------------------------------------
# Streaming chunk step (carry in / carry out) — used by stream_step artifacts
# ---------------------------------------------------------------------------


def _linear_stream_kernel(f_ref, v_ref, lam_re_ref, lam_im_ref, gam_ref, l0_ref, u0_ref,
                          o_ref, lc_ref, uc_ref):
    n, s = f_ref.shape
    d = v_ref.shape[1]
    lam_re = lam_re_ref[...]
    lam_im = lam_im_ref[...]
    gam = gam_ref[...]
    inv_s = 1.0 / jnp.float32(s)
    l0 = l0_ref[...]  # [S, 2]
    u0 = u0_ref[...]  # [S, d, 2]

    def body(i, carry):
        lr, li, ur, ui = carry
        fi = pl.load(f_ref, (pl.dslice(i, 1), slice(None)))[0]
        vi = pl.load(v_ref, (pl.dslice(i, 1), slice(None)))[0]
        nlr = lam_re * lr - lam_im * li + fi
        nli = lam_re * li + lam_im * lr
        nur = gam[:, None] * ur + nlr[:, None] * vi[None, :]
        nui = gam[:, None] * ui - nli[:, None] * vi[None, :]
        z = (jnp.dot(nlr, nur) - jnp.dot(nli, nui)) * inv_s
        pl.store(o_ref, (pl.dslice(i, 1), slice(None)), z[None, :])
        return nlr, nli, nur, nui

    lr, li, ur, ui = jax.lax.fori_loop(
        0, n, body, (l0[:, 0], l0[:, 1], u0[:, :, 0], u0[:, :, 1])
    )
    lc_ref[...] = jnp.stack([lr, li], axis=-1)
    uc_ref[...] = jnp.stack([ur, ui], axis=-1)


def linear_mode_stream_chunk(f, v, decay, theta, carry, u_gamma=None):
    """One streaming chunk; carry = (L_last [S,2], U [S,d,2]).

    Equals `linear_mode_uni` on the concatenated stream (tested in
    python/tests). Returns (z [N,d], new_carry)."""
    n, s = f.shape
    d = v.shape[1]
    if u_gamma is None:
        u_gamma = jnp.ones((s,), jnp.float32)
    l0, u0 = carry
    lam_re, lam_im = _lam(decay, theta)
    z, lc, uc = pl.pallas_call(
        _linear_stream_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((n, s), lambda i: (0, 0)),
            pl.BlockSpec((n, d), lambda i: (0, 0)),
            pl.BlockSpec((s,), lambda i: (0,)),
            pl.BlockSpec((s,), lambda i: (0,)),
            pl.BlockSpec((s,), lambda i: (0,)),
            pl.BlockSpec((s, 2), lambda i: (0, 0)),
            pl.BlockSpec((s, d, 2), lambda i: (0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((n, d), lambda i: (0, 0)),
            pl.BlockSpec((s, 2), lambda i: (0, 0)),
            pl.BlockSpec((s, d, 2), lambda i: (0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, d), jnp.float32),
            jax.ShapeDtypeStruct((s, 2), jnp.float32),
            jax.ShapeDtypeStruct((s, d, 2), jnp.float32),
        ],
        interpret=INTERPRET,
    )(f, v, lam_re, lam_im, u_gamma, l0, u0)
    return z, (lc, uc)
