"""Hybrid encoder–decoder STLT (§3.5) and baseline seq2seq models for WMT
(Table 2 reproduction).

Encoder layers use the *bilateral* transform (full context); decoder
layers use the *unilateral* transform (causal) plus a cross-STLT block:
the decoder's Laplace features L_dec interact with the encoder memory
through U_enc_k = sum_m conj(L_enc_{m,k}) v_m — an O(S d) summary, so
cross "attention" is O((N+M) S d) and the encoder memory handed to the
Rust decode loop is fixed-size.

Baselines (vanilla/linformer/performer/ssm/fnet) use their own self
mixers and standard multi-head cross attention (noted in DESIGN.md).

Source and target share one vocabulary (synthetic task).
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from . import baselines, optim, stlt_layer, trunk
from .config import ModelConfig


def _dense(k, i, o):
    return jnp.asarray(k.normal(0, 0.02, (i, o)).astype(np.float32))


def _cross_init(rng, cfg: ModelConfig):
    k = np.random.default_rng(rng)
    d = cfg.d_model
    if cfg.arch == "stlt":
        p = stlt_layer.init(rng, cfg)  # node bank + w_f reused for L_dec
        p["w_vx"] = _dense(k, d, d)  # encoder value proj
        return p
    return {
        "w_q": _dense(k, d, d),
        "w_k": _dense(k, d, d),
        "w_v": _dense(k, d, d),
        "w_o": _dense(k, d, d),
    }


def init(cfg: ModelConfig):
    k = np.random.default_rng(cfg.seed + 7)
    d = cfg.d_model
    mix_init, _ = trunk.mixer_fns(cfg)

    def block(li, with_cross):
        p = {
            "mixer": mix_init(cfg.seed * 1000 + li, cfg),
            "ln1_g": jnp.ones((d,)), "ln1_b": jnp.zeros((d,)),
            "ln2_g": jnp.ones((d,)), "ln2_b": jnp.zeros((d,)),
            "ffn_w1": _dense(k, d, d * cfg.ffn_mult),
            "ffn_b1": jnp.zeros((d * cfg.ffn_mult,)),
            "ffn_w2": _dense(k, d * cfg.ffn_mult, d),
            "ffn_b2": jnp.zeros((d,)),
        }
        if with_cross:
            p["cross"] = _cross_init(cfg.seed * 2000 + li, cfg)
            p["ln3_g"] = jnp.ones((d,))
            p["ln3_b"] = jnp.zeros((d,))
        return p

    return {
        "embed": _dense(k, cfg.vocab, d),
        "enc_layers": [block(i, False) for i in range(cfg.n_layers)],
        "dec_layers": [block(100 + i, True) for i in range(cfg.n_layers)],
        "enc_lnf_g": jnp.ones((d,)), "enc_lnf_b": jnp.zeros((d,)),
        "dec_lnf_g": jnp.ones((d,)), "dec_lnf_b": jnp.zeros((d,)),
    }


def _cross_apply(p, y, enc_h, cfg: ModelConfig):
    """y [B,M,d] decoder stream, enc_h [B,N,d] encoder output -> [B,M,d]."""
    if cfg.arch == "stlt":
        decay, theta, _, _ = stlt_layer.node_params(p, cfg)
        from .kernels import ops

        f_dec = jnp.einsum("bmd,ds->bms", y, p["w_f"])
        f_enc = jnp.einsum("bnd,ds->bns", enc_h, p["w_f"])
        v_enc = jnp.einsum("bnd,de->bne", enc_h, p["w_vx"])
        l_dec_re, l_dec_im = ops.scan_uni_batched(f_dec, decay, theta)
        l_enc_re, l_enc_im = ops.scan_bi_batched(f_enc, decay, theta)
        u_re = jnp.einsum("bns,bnd->bsd", l_enc_re, v_enc)
        u_im = jnp.einsum("bns,bnd->bsd", -l_enc_im, v_enc)
        z = jnp.einsum("bms,bsd->bmd", l_dec_re, u_re) - jnp.einsum(
            "bms,bsd->bmd", l_dec_im, u_im
        )
        z = z / jnp.float32(cfg.s_max)
        return jnp.einsum("bmd,de->bme", z, p["w_o"])
    # standard multi-head cross attention
    b, m, d = y.shape
    h = cfg.n_heads
    q = baselines._heads(y @ p["w_q"], h)
    kk = baselines._heads(enc_h @ p["w_k"], h)
    v = baselines._heads(enc_h @ p["w_v"], h)
    a = jnp.einsum("bhmd,bhnd->bhmn", q, kk) / jnp.sqrt(jnp.float32(d // h))
    a = jax.nn.softmax(a, axis=-1)
    z = jnp.einsum("bhmn,bhnd->bhmd", a, v)
    return z.transpose(0, 2, 1, 3).reshape(b, m, d) @ p["w_o"]


def encode(params, src, cfg: ModelConfig):
    """src [B, N] -> enc hidden [B, N, d] (bilateral / non-causal mixers)."""
    d = cfg.d_model
    x = params["embed"][src] * jnp.sqrt(jnp.float32(d))
    if trunk.uses_posenc(cfg):
        x = x + trunk._posenc(src.shape[1], d)[None]
    _, mix_apply = trunk.mixer_fns(cfg)
    key = jax.random.PRNGKey(3)
    for lp in params["enc_layers"]:
        key, sub = jax.random.split(key)
        z, _, _ = mix_apply(
            lp["mixer"], trunk._ln(x, lp["ln1_g"], lp["ln1_b"]), cfg,
            causal=False, rng_key=sub, temp=1.0, train=False,
        )
        x = x + z
        x = x + trunk._ffn(lp, trunk._ln(x, lp["ln2_g"], lp["ln2_b"]))
    return trunk._ln(x, params["enc_lnf_g"], params["enc_lnf_b"])


def decode(params, tgt_in, enc_h, cfg: ModelConfig, rng_key=None, temp=1.0, train=False):
    """tgt_in [B, M] -> logits [B, M, V]; causal self + cross each layer."""
    d = cfg.d_model
    y = params["embed"][tgt_in] * jnp.sqrt(jnp.float32(d))
    if trunk.uses_posenc(cfg):
        y = y + trunk._posenc(tgt_in.shape[1], d)[None]
    _, mix_apply = trunk.mixer_fns(cfg)
    if rng_key is None:
        rng_key = jax.random.PRNGKey(4)
    regs = []
    for lp in params["dec_layers"]:
        rng_key, sub = jax.random.split(rng_key)
        z, reg, _ = mix_apply(
            lp["mixer"], trunk._ln(y, lp["ln1_g"], lp["ln1_b"]), cfg,
            causal=True, rng_key=sub, temp=temp, train=train,
        )
        y = y + z
        y = y + _cross_apply(lp["cross"], trunk._ln(y, lp["ln3_g"], lp["ln3_b"]), enc_h, cfg)
        y = y + trunk._ffn(lp, trunk._ln(y, lp["ln2_g"], lp["ln2_b"]))
        regs.append(reg)
    y = trunk._ln(y, params["dec_lnf_g"], params["dec_lnf_b"])
    return y @ params["embed"].T, sum(regs)


def s2s_loss(params, src, tgt, cfg: ModelConfig, rng_key=None, temp=1.0, train=False,
             pad_id: int = 0):
    """tgt [B, M+1] teacher forcing; positions with target==pad are masked."""
    tgt_in, tgt_out = tgt[:, :-1], tgt[:, 1:]
    enc_h = encode(params, src, cfg)
    logits, reg = decode(params, tgt_in, enc_h, cfg, rng_key, temp, train)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, tgt_out[..., None], axis=-1)[..., 0]
    mask = (tgt_out != pad_id).astype(jnp.float32)
    ce = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return ce + reg, ce


def make_s2s_train_step(cfg: ModelConfig, template):
    def step_fn(flat, m, v, step, src, tgt, seed):
        params = optim.unpack(flat, template)
        key = jax.random.fold_in(jax.random.PRNGKey(5), seed)

        def loss_fn(p):
            return s2s_loss(p, src, tgt, cfg, rng_key=key, temp=1.0, train=True)

        (loss, ce), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        g = optim.pack(grads)
        lr = optim.lr_schedule(step, cfg.lr, cfg.warmup, cfg.total_steps)
        flat2, m2, v2 = optim.adamw_update(
            flat, g, m, v, step + 1, lr=lr, beta1=cfg.beta1, beta2=cfg.beta2,
            weight_decay=cfg.weight_decay, grad_clip=cfg.grad_clip,
        )
        return flat2, m2, v2, loss, ce

    return step_fn


def make_s2s_decode(cfg: ModelConfig, template, m_max: int):
    def decode_fn(flat, src, tgt_prefix, cur_len):
        """Greedy decode step: logits for position cur_len-1 of the prefix.

        src [B, N], tgt_prefix [B, m_max]; positions >= cur_len are junk
        (masked by causality). Returns logits [B, V]."""
        params = optim.unpack(flat, template)
        enc_h = encode(params, src, cfg)
        logits, _ = decode(params, tgt_prefix, enc_h, cfg)
        idx = jnp.clip(cur_len - 1, 0, m_max - 1)
        return (logits[:, idx, :],)

    return decode_fn
