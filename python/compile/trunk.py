"""Architecture-generic decoder-only LM trunk (pre-LN transformer shell).

Every architecture row in Tables 1/4 is this trunk with a different
mixer plugged in (STLT or a baseline from baselines.py):

    x = embed[tok] * sqrt(d) + posenc
    repeat L: x += mixer(LN(x)); x += FFN(LN(x))
    logits = LN(x) @ embed.T   (tied head)

Params are nested dicts with deterministic ordering (see optim.flatten).
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from . import baselines, stlt_layer
from .config import ModelConfig


def _posenc(n, d):
    pos = np.arange(n)[:, None]
    i = np.arange(d)[None, :]
    angle = pos / np.power(10000.0, (2 * (i // 2)) / d)
    pe = np.where(i % 2 == 0, np.sin(angle), np.cos(angle))
    return jnp.asarray(pe.astype(np.float32))


def uses_posenc(cfg: ModelConfig) -> bool:
    return cfg.arch in ("vanilla", "linformer", "performer")


def mixer_fns(cfg: ModelConfig):
    if cfg.arch == "stlt":
        return stlt_layer.init, stlt_layer.apply
    return baselines.MIXERS[cfg.arch]


def init(cfg: ModelConfig):
    k = np.random.default_rng(cfg.seed)
    d = cfg.d_model
    mix_init, _ = mixer_fns(cfg)
    layers = []
    for li in range(cfg.n_layers):
        layers.append(
            {
                "mixer": mix_init(cfg.seed * 1000 + li, cfg),
                "ln1_g": jnp.ones((d,), jnp.float32),
                "ln1_b": jnp.zeros((d,), jnp.float32),
                "ln2_g": jnp.ones((d,), jnp.float32),
                "ln2_b": jnp.zeros((d,), jnp.float32),
                "ffn_w1": jnp.asarray(k.normal(0, 0.02, (d, d * cfg.ffn_mult)).astype(np.float32)),
                "ffn_b1": jnp.zeros((d * cfg.ffn_mult,), jnp.float32),
                "ffn_w2": jnp.asarray(k.normal(0, 0.02, (d * cfg.ffn_mult, d)).astype(np.float32)),
                "ffn_b2": jnp.zeros((d,), jnp.float32),
            }
        )
    return {
        "embed": jnp.asarray(k.normal(0, 0.02, (cfg.vocab, d)).astype(np.float32)),
        "layers": layers,
        "lnf_g": jnp.ones((d,), jnp.float32),
        "lnf_b": jnp.zeros((d,), jnp.float32),
    }


def _ln(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _ffn(lp, x):
    h = jax.nn.gelu(x @ lp["ffn_w1"] + lp["ffn_b1"])
    return h @ lp["ffn_w2"] + lp["ffn_b2"]


def apply(params, tokens, cfg: ModelConfig, *, rng_key=None, temp=1.0, train=False,
          causal=True, noise_std=0.0):
    """tokens [B, N] int32 -> (logits [B, N, V], reg, s_eff_mean).

    noise_std > 0 adds Gaussian noise to the input embeddings (used by
    the §4.7 robustness experiment — noise is part of the lowered graph
    so Rust can sweep it as an input).
    """
    b, n = tokens.shape
    d = cfg.d_model
    x = params["embed"][tokens] * jnp.sqrt(jnp.float32(d))
    if uses_posenc(cfg):
        # recurrent mixers (stlt, ssm, fnet-causal) encode position via
        # their decay kernels; absolute PE would break streaming (>n_ctx).
        x = x + _posenc(n, d)[None]
    if rng_key is None:
        rng_key = jax.random.PRNGKey(0)
    noise_key, rng_key = jax.random.split(rng_key)
    x = x + noise_std * jax.random.normal(noise_key, x.shape, jnp.float32)
    _, mix_apply = mixer_fns(cfg)
    regs, seffs = [], []
    for li, lp in enumerate(params["layers"]):
        rng_key, sub = jax.random.split(rng_key)
        z, reg, seff = mix_apply(
            lp["mixer"], _ln(x, lp["ln1_g"], lp["ln1_b"]), cfg,
            causal=causal, rng_key=sub, temp=temp, train=train,
        )
        x = x + z
        x = x + _ffn(lp, _ln(x, lp["ln2_g"], lp["ln2_b"]))
        regs.append(reg)
        seffs.append(seff)
    x = _ln(x, params["lnf_g"], params["lnf_b"])
    logits = x @ params["embed"].T
    return logits, sum(regs), sum(seffs) / len(seffs)


def lm_loss(params, tokens, cfg: ModelConfig, *, rng_key=None, temp=1.0, train=False,
            noise_std=0.0):
    """tokens [B, N+1]: next-token CE averaged over B*N + Eq.Reg penalty.

    Returns (loss_total, (ce, s_eff))."""
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    logits, reg, seff = apply(
        params, inp, cfg, rng_key=rng_key, temp=temp, train=train, noise_std=noise_std
    )
    logp = jax.nn.log_softmax(logits, axis=-1)
    ce = -jnp.mean(jnp.take_along_axis(logp, tgt[..., None], axis=-1))
    return ce + reg, (ce, seff)
