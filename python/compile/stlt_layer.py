"""The learnable two-sided STLT mixer (the paper's core contribution).

Parameters per layer (all end-to-end learnable unless ablated):
  sigma_raw [S]  -> sigma = softplus(sigma_raw) + sigma_min   (decay)
  omega     [S]  -> oscillation frequency
  t_raw     []   -> T = softplus(t_raw) + 1                   (window)
  w_f [d, S]     -> per-node feature projection (DESIGN.md R3)
  w_v [d, d], w_o [d, d]
  adaptive only: w_alpha [d, S], b_alpha [S]  (importance scores)

The exponential window w(t; T) = e^{-|t|/T} folds into the decay:
sigma_eff = sigma + 1/T (DESIGN.md R4), keeping the recurrence exact
and T learnable.

Adaptive node allocation (§3.6): alpha = sigmoid(W_a pool(x) + b_a);
training uses the Gumbel-sigmoid relaxation at temperature `temp`,
inference uses the deterministic alpha (optionally hard-thresholded on
the Rust side). Masks scale the node features, so m̃_k ≈ 0 silences
node k exactly as in the paper.

Returns (z, reg, s_eff): the mixed output, the Eq. Reg penalty, and the
expected active node count.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .kernels import ops


def _softplus(x):
    return jnp.logaddexp(x, 0.0)


def _inv_softplus(y):
    # inverse of softplus for initialisation
    import numpy as np

    return float(np.log(np.expm1(y)))


def init(rng, cfg: ModelConfig):
    import numpy as np

    k = np.random.default_rng(rng)
    d, s = cfg.d_model, cfg.s_max
    # log-spaced sigma over [sigma_init_lo, sigma_init_hi] (§3.7)
    sig = np.geomspace(cfg.sigma_init_lo, cfg.sigma_init_hi, s).astype(np.float32)
    sigma_raw = np.log(np.expm1(np.maximum(sig, 1e-6))).astype(np.float32)
    omega = (
        np.zeros(s, np.float32)
        if cfg.omega_zero
        else k.uniform(0.0, cfg.omega_init_hi, s).astype(np.float32)
    )
    p = {
        "sigma_raw": jnp.asarray(sigma_raw),
        "omega": jnp.asarray(omega),
        "t_raw": jnp.asarray([_inv_softplus(cfg.t_init - 1.0)], np.float32),
        "w_f": jnp.asarray(k.normal(0, 0.02, (d, s)).astype(np.float32)),
        "w_v": jnp.asarray(k.normal(0, 0.02, (d, d)).astype(np.float32)),
        "w_o": jnp.asarray(k.normal(0, 0.02, (d, d)).astype(np.float32)),
    }
    if cfg.adaptive:
        p["w_alpha"] = jnp.asarray(k.normal(0, 0.02, (d, s)).astype(np.float32))
        p["b_alpha"] = jnp.asarray(np.full(s, 2.0, np.float32))  # start mostly-on
    return p


def node_params(p, cfg: ModelConfig):
    """(decay, theta, sigma, t) with ablation stop-gradients applied."""
    sigma = _softplus(p["sigma_raw"]) + cfg.sigma_min
    t = _softplus(p["t_raw"])[0] + 1.0
    omega = jnp.zeros_like(p["omega"]) if cfg.omega_zero else p["omega"]
    if not cfg.learn_sigma:
        sigma = jax.lax.stop_gradient(sigma)
    if not cfg.learn_omega:
        omega = jax.lax.stop_gradient(omega)
    if not cfg.learn_t:
        t = jax.lax.stop_gradient(t)
    sigma_eff = sigma + 1.0 / t
    decay = jnp.exp(-sigma_eff)  # Delta = 1
    theta = omega
    return decay, theta, sigma, t


def u_window(p, cfg: ModelConfig):
    """Windowed-U discount gamma (DESIGN.md R4): the learnable window
    also decays the value-side accumulation so the streaming state is
    stationary — gamma = e^{-1/(8 T)}, half-life ~5.5 T tokens. [S]."""
    t = _softplus(p["t_raw"])[0] + 1.0
    if not cfg.learn_t:
        t = jax.lax.stop_gradient(t)
    g = jnp.exp(-1.0 / (8.0 * t))
    return jnp.full((cfg.s_max,), 1.0, jnp.float32) * g


def gate(p, x, cfg: ModelConfig, rng_key, temp, train: bool):
    """Adaptive node mask m̃ [B, S] and importance alpha [B, S]."""
    b = x.shape[0]
    if not cfg.adaptive:
        ones = jnp.ones((b, cfg.s_max), jnp.float32)
        return ones, ones
    pooled = jnp.mean(x, axis=1)  # [B, d] mean-pool (§3.6)
    logits = pooled @ p["w_alpha"] + p["b_alpha"][None, :]
    alpha = jax.nn.sigmoid(logits)
    if train:
        u = jax.random.uniform(rng_key, logits.shape, minval=1e-6, maxval=1 - 1e-6)
        g = jnp.log(u) - jnp.log1p(-u)  # logistic noise == Gumbel diff
        m = jax.nn.sigmoid((logits + g) / temp)
    else:
        m = alpha
    return m, alpha


def regulariser(p, m, cfg: ModelConfig):
    """Eq. Reg: sparsity on active omega, smoothness on active sigma, mask sum.

    Honors the ablation stop-grads: a "fixed" parameter must receive no
    gradient through the penalty either."""
    sigma = _softplus(p["sigma_raw"]) + cfg.sigma_min
    omega = p["omega"]
    if not cfg.learn_sigma:
        sigma = jax.lax.stop_gradient(sigma)
    if not cfg.learn_omega:
        omega = jax.lax.stop_gradient(omega)
    m_mean = jnp.mean(m, axis=0)  # average gate over batch
    r_omega = cfg.lambda_omega * jnp.sum(jnp.abs(omega) * m_mean)
    dsig = (sigma[1:] - sigma[:-1]) ** 2
    r_sigma = cfg.lambda_sigma * jnp.sum(dsig * m_mean[1:] * m_mean[:-1])
    r_mask = cfg.lambda_mask * jnp.sum(m_mean)
    return r_omega + r_sigma + r_mask


def apply(p, x, cfg: ModelConfig, *, causal: bool, rng_key=None, temp=1.0, train=False):
    """x: [B, N, d] -> (z [B, N, d], reg scalar, s_eff scalar)."""
    b, n, d = x.shape
    decay, theta, _, _ = node_params(p, cfg)
    if rng_key is None:
        rng_key = jax.random.PRNGKey(0)
    m, _alpha = gate(p, x, cfg, rng_key, temp, train)  # [B, S]
    f = jnp.einsum("bnd,ds->bns", x, p["w_f"]) * m[:, None, :]
    v = jnp.einsum("bnd,de->bne", x, p["w_v"])

    if cfg.mode == "linear":
        if causal:
            # sequential-carry formulation (EXPERIMENTS.md §Perf L2-1)
            z = ops.linear_mode_uni_batched(f, v, decay, theta, u_window(p, cfg))
        else:
            l_re, l_im = ops.scan_bi_batched(f, decay, theta)
            u_re = jnp.einsum("bns,bnd->bsd", l_re, v)
            u_im = jnp.einsum("bns,bnd->bsd", -l_im, v)
            z = jnp.einsum("bns,bsd->bnd", l_re, u_re) - jnp.einsum(
                "bns,bsd->bnd", l_im, u_im
            )
            z = z / jnp.float32(cfg.s_max)
    elif cfg.mode == "quadratic":
        if causal:
            l_re, l_im = ops.scan_uni_batched(f, decay, theta)
        else:
            l_re, l_im = ops.scan_bi_batched(f, decay, theta)
        r = (
            jnp.einsum("bns,bms->bnm", l_re, l_re)
            + jnp.einsum("bns,bms->bnm", l_im, l_im)
        ) / jnp.sqrt(jnp.float32(cfg.s_max))
        if causal:
            mask = jnp.tril(jnp.ones((n, n), dtype=bool))
            r = jnp.where(mask[None], r, -jnp.inf)
        a = jax.nn.softmax(r, axis=-1)
        z = jnp.einsum("bnm,bmd->bnd", a, v)
    else:
        raise ValueError(f"unknown mode {cfg.mode}")

    z = jnp.einsum("bnd,de->bne", z, p["w_o"])
    reg = regulariser(p, m, cfg)
    s_eff = jnp.mean(jnp.sum(m, axis=1))
    return z, reg, s_eff


# ---------------------------------------------------------------------------
# Streaming/decode carries (linear causal mode only) for the Rust hot path
# ---------------------------------------------------------------------------


def carry_init(cfg: ModelConfig):
    """Zero carry for one layer: (L [S,2], U [S,d,2])."""
    s, d = cfg.s_max, cfg.d_model
    return jnp.zeros((s, 2), jnp.float32), jnp.zeros((s, d, 2), jnp.float32)


def apply_stream(p, x, cfg: ModelConfig, carry):
    """Single-sequence streaming chunk. x: [N, d]; linear causal mode.

    Adaptive gating in streaming uses the deterministic alpha of the
    *chunk* (documented deviation: pooling is per-chunk, not global).
    """
    decay, theta, _, _ = node_params(p, cfg)
    if cfg.adaptive:
        pooled = jnp.mean(x, axis=0)
        m = jax.nn.sigmoid(pooled @ p["w_alpha"] + p["b_alpha"])
    else:
        m = jnp.ones((cfg.s_max,), jnp.float32)
    f = (x @ p["w_f"]) * m[None, :]
    v = x @ p["w_v"]
    # the fused Pallas streaming kernel is the L1 hot path here
    from .kernels import stlt as stlt_kernels

    z, new_carry = stlt_kernels.linear_mode_stream_chunk(
        f, v, decay, theta, carry, u_window(p, cfg)
    )
    z = z @ p["w_o"]
    return z, new_carry
