"""AOT lowering driver: jax -> HLO *text* -> artifacts/ + manifest.json.

HLO text (not `.serialize()`) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Usage (from python/):
    python -m compile.aot --outdir ../artifacts --groups core,table1
    python -m compile.aot --outdir ../artifacts            # everything

The manifest records, per entry: the HLO file, input/output shapes &
dtypes, the ModelConfig, and the parameter count — everything the Rust
runtime (rust/src/runtime/artifact.rs) needs to drive execution without
ever importing Python.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import optim, seq2seq, train
from .config import ModelConfig, preset


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec_of(x):
    if isinstance(x, jax.ShapeDtypeStruct):
        return {"dtype": np.dtype(x.dtype).name, "shape": list(x.shape)}
    return {"dtype": np.dtype(x.dtype).name, "shape": list(np.shape(x))}


def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


class Builder:
    def __init__(self, outdir):
        self.outdir = outdir
        self.entries = {}
        os.makedirs(outdir, exist_ok=True)

    def lower(self, name, fn, args, cfg: ModelConfig | None, extra=None):
        t0 = time.time()
        lowered = jax.jit(fn).lower(*args)
        # jax prunes arguments the graph never uses (e.g. `seed` in
        # baselines without stochastic ops); record which inputs survive
        # so the Rust runtime can filter its argument list to match.
        n_in = len(jax.tree_util.tree_leaves(args))
        try:
            kept = sorted(lowered._lowering.compile_args["kept_var_idx"])
        except Exception:
            kept = list(range(n_in))
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.outdir, fname), "w") as f:
            f.write(text)
        out_avals = jax.eval_shape(fn, *args)
        if not isinstance(out_avals, (tuple, list)):
            out_avals = (out_avals,)
        flat_out, _ = jax.tree_util.tree_flatten(out_avals)
        entry = {
            "file": fname,
            "inputs": [_spec_of(a) for a in jax.tree_util.tree_leaves(args)],
            "outputs": [_spec_of(a) for a in flat_out],
            "kept_inputs": kept,
        }
        if cfg is not None:
            entry["config"] = cfg.to_dict()
        if extra:
            entry.update(extra)
        self.entries[name] = entry
        print(f"  lowered {name:42s} {len(text)/1e6:6.2f} MB  {time.time()-t0:5.1f}s",
              flush=True)

    def write_manifest(self):
        path = os.path.join(self.outdir, "manifest.json")
        with open(path, "w") as f:
            json.dump({"version": 1, "entries": self.entries}, f, indent=1, sort_keys=True)
        print(f"wrote {path} ({len(self.entries)} entries)")


# ---------------------------------------------------------------------------
# Model variants (Tables 1, 2, 4 + scaling + e2e)
# ---------------------------------------------------------------------------

LM_VARIANTS = {
    # Table 1 rows (tiny scale)
    "vanilla": dict(arch="vanilla"),
    "linformer": dict(arch="linformer"),
    "fnet": dict(arch="fnet"),
    "ssm": dict(arch="ssm"),
    "stlt_fixed32": dict(arch="stlt", s_max=32, adaptive=False),
    "stlt_adaptive": dict(arch="stlt", s_max=64, adaptive=True),
    # Table 4 ablations
    "abl_fixed_all": dict(arch="stlt", s_max=32, adaptive=False,
                          learn_sigma=False, learn_omega=False, learn_t=False),
    "abl_no_omega": dict(arch="stlt", s_max=32, adaptive=False, omega_zero=True),
    "abl_fixed_sigma": dict(arch="stlt", s_max=32, adaptive=False, learn_sigma=False),
    "abl_fixed_t": dict(arch="stlt", s_max=32, adaptive=False, learn_t=False),
    "abl_s16": dict(arch="stlt", s_max=16, adaptive=False),
    "abl_s64": dict(arch="stlt", s_max=64, adaptive=False),
    "abl_noreg": dict(arch="stlt", s_max=64, adaptive=True, lambda_mask=0.0),
    "abl_quadratic": dict(arch="stlt", s_max=32, adaptive=False, mode="quadratic"),
}

S2S_VARIANTS = {
    "vanilla": dict(arch="vanilla"),
    "linformer": dict(arch="linformer"),
    "performer": dict(arch="performer"),
    "ssm": dict(arch="ssm"),
    "stlt_fixed32": dict(arch="stlt", s_max=32, adaptive=False),
    "stlt_adaptive": dict(arch="stlt", s_max=64, adaptive=True),
}

TABLE1 = ["vanilla", "linformer", "fnet", "ssm", "stlt_fixed32", "stlt_adaptive"]
TABLE4 = ["abl_fixed_all", "abl_no_omega", "abl_fixed_sigma", "abl_fixed_t",
          "abl_s16", "abl_s64", "abl_noreg", "abl_quadratic"]


def lm_cfg(variant: str, size: str = "tiny", **over) -> ModelConfig:
    kw = dict(LM_VARIANTS[variant])
    kw.update(over)
    return preset(size, **kw)


def _dump_init(b: Builder, name: str, flat):
    """Raw little-endian f32 init vector (python-exact packing order)."""
    path = os.path.join(b.outdir, f"{name}.init.bin")
    np.asarray(flat, dtype=np.float32).tofile(path)
    return f"{name}.init.bin"


def build_lm(b: Builder, name: str, cfg: ModelConfig, with_stream: bool):
    tmpl = train.make_template(cfg)
    flat = optim.pack(tmpl)
    p = int(flat.size)
    init_file = _dump_init(b, name, flat)
    fp = _f32(p)
    toks = _i32(cfg.batch, cfg.n_ctx + 1)
    b.lower(
        f"{name}.train",
        train.make_train_step(cfg, tmpl),
        (fp, fp, fp, _i32(), toks, _i32()),
        cfg,
        extra={"kind": "train_step", "param_count": p, "init": init_file},
    )
    b.lower(
        f"{name}.eval",
        train.make_eval_step(cfg, tmpl),
        (fp, toks, _f32(), _i32()),
        cfg,
        extra={"kind": "eval_step", "param_count": p},
    )
    # single-sequence forward (chunked-baseline generation, QA Table 3)
    import dataclasses as _dc

    cfg1 = _dc.replace(cfg, batch=1)
    b.lower(
        f"{name}.fwd",
        train.make_forward(cfg1, tmpl),
        (fp, _i32(1, cfg.n_ctx)),
        cfg1,
        extra={"kind": "forward", "param_count": p},
    )
    if with_stream:
        (ls, us) = train.carry_shapes(cfg)
        c = 64
        b.lower(
            f"{name}.stream",
            train.make_stream_step(cfg, tmpl),
            (fp, _f32(*ls), _f32(*us), _i32(c), _i32(c), _f32(c)),
            cfg,
            extra={"kind": "stream_step", "param_count": p, "chunk": c},
        )
        b.lower(
            f"{name}.decode",
            train.make_decode_step(cfg, tmpl),
            (fp, _f32(*ls), _f32(*us), _i32(1)),
            cfg,
            extra={"kind": "decode_step", "param_count": p},
        )
        bsrv = 4
        b.lower(
            f"{name}.stream_batch",
            train.make_stream_batch_step(cfg, tmpl),
            (fp, _f32(bsrv, *ls), _f32(bsrv, *us), _i32(bsrv, c), _i32(bsrv, c),
             _f32(bsrv, c), _f32(bsrv)),
            cfg,
            extra={"kind": "stream_batch_step", "param_count": p, "chunk": c,
                   "batch_srv": bsrv},
        )


def build_s2s(b: Builder, name: str, cfg: ModelConfig, n_src: int, m_tgt: int):
    tmpl = seq2seq.init(cfg)
    flat = optim.pack(tmpl)
    p = int(flat.size)
    init_file = _dump_init(b, name, flat)
    fp = _f32(p)
    b.lower(
        f"{name}.train",
        seq2seq.make_s2s_train_step(cfg, tmpl),
        (fp, fp, fp, _i32(), _i32(cfg.batch, n_src), _i32(cfg.batch, m_tgt + 1), _i32()),
        cfg,
        extra={"kind": "s2s_train_step", "param_count": p, "n_src": n_src, "m_tgt": m_tgt, "init": init_file},
    )
    b.lower(
        f"{name}.decode",
        seq2seq.make_s2s_decode(cfg, tmpl, m_tgt),
        (fp, _i32(cfg.batch, n_src), _i32(cfg.batch, m_tgt), _i32()),
        cfg,
        extra={"kind": "s2s_decode", "param_count": p, "n_src": n_src, "m_tgt": m_tgt},
    )


def build_scaling(b: Builder):
    """Forward-pass artifacts for the §4.6 latency/memory sweep."""
    for n in [256, 512, 1024, 2048, 4096]:
        cfg = lm_cfg("stlt_fixed32", n_ctx=n, batch=1)
        tmpl = train.make_template(cfg)
        p = int(optim.pack(tmpl).size)
        b.lower(
            f"scale_stlt_n{n}.fwd",
            train.make_forward(cfg, tmpl),
            (_f32(p), _i32(1, n)),
            cfg,
            extra={"kind": "forward", "param_count": p},
        )
    for n in [256, 512, 1024, 2048]:
        cfg = lm_cfg("vanilla", n_ctx=n, batch=1)
        tmpl = train.make_template(cfg)
        p = int(optim.pack(tmpl).size)
        b.lower(
            f"scale_vanilla_n{n}.fwd",
            train.make_forward(cfg, tmpl),
            (_f32(p), _i32(1, n)),
            cfg,
            extra={"kind": "forward", "param_count": p},
        )
    # quadratic-mode STLT forward: shows the figure-faithful mode is O(N^2)
    for n in [256, 512, 1024]:
        cfg = lm_cfg("abl_quadratic", n_ctx=n, batch=1)
        tmpl = train.make_template(cfg)
        p = int(optim.pack(tmpl).size)
        b.lower(
            f"scale_stltq_n{n}.fwd",
            train.make_forward(cfg, tmpl),
            (_f32(p), _i32(1, n)),
            cfg,
            extra={"kind": "forward", "param_count": p},
        )


GROUPS = ["core", "table1", "table4", "table2", "scaling", "e2e"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--groups", default=",".join(GROUPS))
    args = ap.parse_args()
    groups = [g for g in args.groups.split(",") if g]
    b = Builder(args.outdir)

    t0 = time.time()
    if "core" in groups:
        print("== core ==", flush=True)
        build_lm(b, "lm_stlt_tiny", lm_cfg("stlt_fixed32"), with_stream=True)
    if "table1" in groups:
        print("== table1 ==", flush=True)
        for v in TABLE1:
            build_lm(b, f"lm_{v}_tiny", lm_cfg(v), with_stream=v.startswith("stlt"))
    if "table4" in groups:
        print("== table4 ==", flush=True)
        for v in TABLE4:
            build_lm(b, f"lm_{v}_tiny", lm_cfg(v), with_stream=False)
    if "table2" in groups:
        print("== table2 ==", flush=True)
        for v, kw in S2S_VARIANTS.items():
            cfg = preset("tiny", n_ctx=48, batch=8, **kw)
            build_s2s(b, f"s2s_{v}_tiny", cfg, n_src=48, m_tgt=48)
    if "scaling" in groups:
        print("== scaling ==", flush=True)
        build_scaling(b)
    if "e2e" in groups:
        print("== e2e ==", flush=True)
        build_lm(b, "lm_stlt_e2e", lm_cfg("stlt_adaptive", size="e2e", s_max=32),
                 with_stream=True)
    b.write_manifest()
    print(f"total {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
