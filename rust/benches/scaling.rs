//! §4.6 bench: forward latency vs sequence length for linear-mode STLT,
//! quadratic-mode STLT and vanilla attention (the figure-level claim:
//! linear scaling vs quadratic). A compact version of
//! examples/exp_scaling.rs suitable for `cargo bench`.

use stlt::bench::{bench_for, fmt_time};
use stlt::runtime::{default_artifacts_dir, exec::init_vec_host, Forward, Manifest, Runtime};

fn main() {
    println!("== scaling bench (requires `make artifacts`) ==");
    let manifest = Manifest::load(default_artifacts_dir()).expect("make artifacts");
    let rt = Runtime::cpu().unwrap();
    for (prefix, ns) in [
        ("scale_stlt_n", vec![256usize, 512, 1024, 2048]),
        ("scale_stltq_n", vec![256, 512, 1024]),
        ("scale_vanilla_n", vec![256, 512, 1024, 2048]),
    ] {
        let mut prev: Option<f64> = None;
        for n in ns {
            let name = format!("{prefix}{n}.fwd");
            let fwd = Forward::new(&rt, &manifest, &name).unwrap();
            let e = manifest.get(&name).unwrap();
            let flat = init_vec_host(e.param_count, 1);
            let tokens: Vec<i32> = (0..n as i32).map(|i| 4 + (i % 200)).collect();
            let r = bench_for(&name, 1.5, || {
                std::hint::black_box(fwd.run(&flat, &tokens).unwrap());
            });
            let ratio = prev.map(|p| r.p50_s / p).unwrap_or(0.0);
            println!(
                "{:24} p50 {:>10}   xN ratio {:.2}",
                name,
                fmt_time(r.p50_s),
                ratio
            );
            prev = Some(r.p50_s);
        }
        println!();
    }
    println!("(linear model: ratio ~2 per doubling; quadratic: ratio ~4)");
}
