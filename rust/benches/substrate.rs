//! Substrate micro-benchmarks (from-scratch harness, no criterion):
//! RNG, corpus generation, tokenizer, JSON, metrics. These set the
//! baseline showing the data path never bottlenecks the model path.

use stlt::bench::{bench, bench_for};
use stlt::data::corpus::{Corpus, CorpusConfig};
use stlt::metrics::bleu4;
use stlt::tokenizer::Bpe;
use stlt::util::json::Json;
use stlt::util::rng::Rng;

fn main() {
    println!("== substrate benches ==");
    let mut results = Vec::new();

    let mut rng = Rng::new(1);
    results.push(bench("rng/u64 x1000", 10, 200, || {
        let mut acc = 0u64;
        for _ in 0..1000 {
            acc ^= rng.next_u64();
        }
        std::hint::black_box(acc);
    }));

    let mut corpus = Corpus::new(CorpusConfig::default_for_vocab(256), 7);
    results.push(bench("corpus/take(1024)", 5, 100, || {
        std::hint::black_box(corpus.take(1024));
    }));

    let text = {
        let mut c = Corpus::new(CorpusConfig::default_for_vocab(256), 9);
        c.take(20_000).iter().map(|&t| (b'a' + (t % 26) as u8) as char).collect::<String>()
    };
    let bpe = Bpe::train(&text[..4000], 260 + 128);
    results.push(bench_for("bpe/encode 4k chars", 0.5, || {
        std::hint::black_box(bpe.encode(&text[..4000]));
    }));

    let manifest_text = std::fs::read_to_string("artifacts/manifest.json").ok();
    if let Some(mt) = manifest_text {
        results.push(bench("json/parse manifest", 3, 50, || {
            std::hint::black_box(Json::parse(&mt).unwrap());
        }));
    }

    let pairs: Vec<(Vec<i32>, Vec<i32>)> = (0..64)
        .map(|i| {
            let h: Vec<i32> = (0..32).map(|j| (i * 7 + j) % 100).collect();
            let r: Vec<i32> = (0..32).map(|j| (i * 7 + j + (j % 5)) % 100).collect();
            (h, r)
        })
        .collect();
    results.push(bench("bleu4/64 pairs x32 tokens", 3, 100, || {
        std::hint::black_box(bleu4(&pairs));
    }));

    for r in &results {
        println!("{}", r.row());
    }
}
