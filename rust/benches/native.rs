//! Native-backend engine bench: tokens/s of the pure-Rust STLT forward,
//! streaming and decode paths at the "tiny" scale (runs with default
//! features — no artifacts, no XLA).

use std::sync::Arc;

use stlt::bench::bench_for;
use stlt::runtime::artifact::ModelConfig;
use stlt::runtime::native_stlt::{host_init, StltModel};

fn main() {
    println!("== native engine bench (no artifacts needed) ==");
    let cfg = ModelConfig {
        arch: "stlt".into(),
        vocab: 256,
        d_model: 64,
        n_layers: 2,
        n_ctx: 128,
        s_max: 32,
        batch: 8,
        mode: "linear".into(),
        ..ModelConfig::default()
    };
    let model = StltModel::new(&cfg, Arc::new(host_init(&cfg, 1))).unwrap();
    let tokens: Vec<i32> = (0..128).map(|i| 4 + (i * 7) % 200).collect();

    let r = bench_for("native/forward 128 tok (d=64 S=32 L=2)", 3.0, || {
        std::hint::black_box(model.forward_logits(&tokens).unwrap());
    });
    println!("{}   ({:.0} tok/s)", r.row(), 128.0 / r.p50_s);

    let chunk: Vec<i32> = tokens[..64].to_vec();
    let (mut l, mut u) = model.zero_carry();
    let r = bench_for("native/stream chunk 64 tok", 3.0, || {
        std::hint::black_box(model.trunk_chunk(&mut l, &mut u, &chunk, 0.0, None).unwrap());
    });
    println!("{}   ({:.0} tok/s)", r.row(), 64.0 / r.p50_s);

    let (mut l, mut u) = model.zero_carry();
    let r = bench_for("native/decode 1 tok", 2.0, || {
        std::hint::black_box(model.trunk_chunk(&mut l, &mut u, &tokens[..1], 0.0, None).unwrap());
    });
    println!("{}   ({:.0} tok/s)", r.row(), 1.0 / r.p50_s);
}
