//! Native-backend engine bench: tokens/s of the pure-Rust STLT forward,
//! streaming, decode and train_step paths at the "tiny" scale (runs
//! with default features — no artifacts, no XLA), including the
//! segment-checkpointed train_step with its peak-tape-bytes accounting.
//!
//! STLT_BENCH_SMOKE=1 shortens every measurement window so CI can run
//! this as a visibility smoke (perf regressions in the backward pass
//! show up in the logged tokens/s) without burning minutes.
//!
//! Every row is also appended to a machine-readable `BENCH_native.json`
//! (override the path with STLT_BENCH_JSON) so the bench trajectory can
//! be tracked across commits instead of scraped from CI logs.

use std::fmt::Write as _;
use std::sync::Arc;

use stlt::bench::{bench_for, BenchResult};
use stlt::runtime::artifact::ModelConfig;
use stlt::runtime::native_stlt::{host_init, StltModel};
use stlt::train::{batch_loss_and_grad, native_train_step, tape_bytes};
use stlt::util::linalg;
use stlt::util::threadpool::{configured_threads, ThreadPool};

/// One machine-readable bench row: the timing summary plus whatever
/// derived metrics the human-readable line prints.
struct JsonRow {
    r: BenchResult,
    /// ("metric name", value) pairs: tokens_per_s, gflops, tape_bytes…
    extra: Vec<(&'static str, f64)>,
}

struct Rows(Vec<JsonRow>);

impl Rows {
    fn push(&mut self, r: BenchResult, extra: Vec<(&'static str, f64)>) {
        self.0.push(JsonRow { r, extra });
    }

    /// Hand-rolled JSON (util::json is a parser; no serde offline).
    fn to_json(&self) -> String {
        let mut s = String::from("{\n  \"bench\": \"native\",\n  \"results\": [\n");
        for (i, row) in self.0.iter().enumerate() {
            let _ = write!(
                s,
                "    {{\"name\": {:?}, \"iters\": {}, \"mean_s\": {:.9}, \
                 \"p50_s\": {:.9}, \"p95_s\": {:.9}, \"min_s\": {:.9}",
                row.r.name, row.r.iters, row.r.mean_s, row.r.p50_s, row.r.p95_s, row.r.min_s
            );
            for (k, v) in &row.extra {
                let _ = write!(s, ", {k:?}: {v:.3}");
            }
            s.push_str(if i + 1 < self.0.len() { "},\n" } else { "}\n" });
        }
        s.push_str("  ]\n}\n");
        s
    }
}

/// Blocked-kernel micro rows: GFLOP/s of the shared linalg kernels at
/// the tied-head shape (n × d × vocab, the single largest matmul) so
/// kernel regressions are visible independently of the full engine.
fn bench_kernels(secs: f64, rows: &mut Rows) {
    let (n, d, k) = (128usize, 64usize, 256usize);
    let mut rng = stlt::util::rng::Rng::new(7);
    let mut fill = |len: usize| -> Vec<f32> { (0..len).map(|_| rng.f32() - 0.5).collect() };
    let a = fill(n * d);
    let b = fill(d * k);
    let bt = linalg::transpose(&b, d, k);
    let gflop = 2.0 * (n * d * k) as f64 / 1e9;

    let mut out = vec![0.0f32; n * k];
    let r = bench_for("linalg/gemm_at 128x64x256 (packed)", secs.min(1.0), || {
        out.fill(0.0);
        linalg::gemm_at(&a, &bt, &mut out, n, d, k);
        std::hint::black_box(&out);
    });
    println!("{}   ({:.2} GFLOP/s)", r.row(), gflop / r.p50_s);
    rows.push(r.clone(), vec![("gflops", gflop / r.p50_s)]);

    let r = bench_for("linalg/gemm    128x64x256 (axpy)", secs.min(1.0), || {
        out.fill(0.0);
        linalg::gemm(&a, &b, &mut out, n, d, k);
        std::hint::black_box(&out);
    });
    println!("{}   ({:.2} GFLOP/s)", r.row(), gflop / r.p50_s);
    rows.push(r.clone(), vec![("gflops", gflop / r.p50_s)]);

    let mut dw = vec![0.0f32; d * k];
    let dy = fill(n * k);
    let r = bench_for("linalg/gemm_ta 128x64x256 (dW)", secs.min(1.0), || {
        dw.fill(0.0);
        linalg::gemm_ta(&a, &dy, &mut dw, n, d, k);
        std::hint::black_box(&dw);
    });
    println!("{}   ({:.2} GFLOP/s)", r.row(), gflop / r.p50_s);
    rows.push(r.clone(), vec![("gflops", gflop / r.p50_s)]);
}

fn main() {
    let smoke = std::env::var("STLT_BENCH_SMOKE")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false);
    let secs = if smoke { 0.3 } else { 3.0 };
    println!(
        "== native engine bench (no artifacts needed{}) ==",
        if smoke { ", smoke mode" } else { "" }
    );
    let mut rows = Rows(Vec::new());
    bench_kernels(secs, &mut rows);
    let cfg = ModelConfig {
        arch: "stlt".into(),
        vocab: 256,
        d_model: 64,
        n_layers: 2,
        n_ctx: 128,
        s_max: 32,
        batch: 8,
        mode: "linear".into(),
        ..ModelConfig::default()
    };
    let flat = host_init(&cfg, 1);
    let model = StltModel::new(&cfg, Arc::new(flat.clone())).unwrap();
    let tokens: Vec<i32> = (0..128).map(|i| 4 + (i * 7) % 200).collect();

    let r = bench_for("native/forward 128 tok (d=64 S=32 L=2)", secs, || {
        std::hint::black_box(model.forward_logits(&tokens).unwrap());
    });
    println!("{}   ({:.0} tok/s)", r.row(), 128.0 / r.p50_s);
    rows.push(r.clone(), vec![("tokens_per_s", 128.0 / r.p50_s)]);

    let chunk: Vec<i32> = tokens[..64].to_vec();
    let (mut l, mut u) = model.zero_carry();
    let r = bench_for("native/stream chunk 64 tok", secs, || {
        std::hint::black_box(model.trunk_chunk(&mut l, &mut u, &chunk, 0.0, None).unwrap());
    });
    println!("{}   ({:.0} tok/s)", r.row(), 64.0 / r.p50_s);
    rows.push(r.clone(), vec![("tokens_per_s", 64.0 / r.p50_s)]);

    let (mut l, mut u) = model.zero_carry();
    let r = bench_for("native/decode 1 tok", secs.min(2.0), || {
        std::hint::black_box(model.trunk_chunk(&mut l, &mut u, &tokens[..1], 0.0, None).unwrap());
    });
    println!("{}   ({:.0} tok/s)", r.row(), 1.0 / r.p50_s);
    rows.push(r.clone(), vec![("tokens_per_s", 1.0 / r.p50_s)]);

    // training: gradient accumulation alone, then the full optimiser
    // step — whole-sequence tape vs the segment-checkpointed tape
    let pool = ThreadPool::new(configured_threads());
    let (b, n1) = (cfg.batch, 33usize); // short rows keep the smoke cheap
    let n = n1 - 1;
    let mut rng = stlt::util::rng::Rng::new(5);
    let batch: Vec<i32> = (0..b * n1).map(|_| rng.below(cfg.vocab as u64) as i32).collect();
    let train_tokens = (b * n) as f64;

    let r = bench_for("native/grad batch 8x32 tok", secs, || {
        std::hint::black_box(batch_loss_and_grad(&model, &batch, b, n1, &pool).unwrap());
    });
    println!("{}   ({:.0} tok/s)", r.row(), train_tokens / r.p50_s);
    rows.push(r.clone(), vec![("tokens_per_s", train_tokens / r.p50_s)]);

    for (label, seg) in [("native/train_step 8x32 tok (full tape)", 0usize),
        ("native/train_step 8x32 tok (ckpt C=8)", 8)]
    {
        let mut c = cfg.clone();
        c.grad_ckpt_segment = seg;
        let tape = tape_bytes(&c, n) as f64;
        let m2 = StltModel::new(&c, Arc::new(flat.clone())).unwrap();
        let mut fl = flat.clone();
        let mut mm = vec![0.0f32; fl.len()];
        let mut vv = vec![0.0f32; fl.len()];
        let mut step = 0i32;
        let r = bench_for(label, secs, || {
            std::hint::black_box(
                native_train_step(&m2, &mut fl, &mut mm, &mut vv, step, &batch, b, n1, &pool)
                    .unwrap(),
            );
            step += 1;
        });
        println!(
            "{}   ({:.0} tok/s, tape {:.1} KiB/row)",
            r.row(),
            train_tokens / r.p50_s,
            tape / 1024.0
        );
        rows.push(
            r.clone(),
            vec![("tokens_per_s", train_tokens / r.p50_s), ("tape_bytes_per_row", tape)],
        );
    }

    let path = std::env::var("STLT_BENCH_JSON").unwrap_or_else(|_| "BENCH_native.json".into());
    match std::fs::write(&path, rows.to_json()) {
        Ok(()) => println!("wrote {path} ({} rows)", rows.0.len()),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
