//! Native-backend engine bench: tokens/s of the pure-Rust STLT forward,
//! streaming, decode and train_step paths at the "tiny" scale (runs
//! with default features — no artifacts, no XLA), including the
//! segment-checkpointed train_step with its peak-tape-bytes accounting,
//! plus the sharded-serving wire rows (router + N loopback workers:
//! decode scaling, ttft percentiles, live-migration latency).
//!
//! STLT_BENCH_SMOKE=1 shortens every measurement window so CI can run
//! this as a visibility smoke (perf regressions in the backward pass
//! show up in the logged tokens/s) without burning minutes.
//!
//! Every row is also appended to a machine-readable `BENCH_native.json`
//! (override the path with STLT_BENCH_JSON) so the bench trajectory can
//! be tracked across commits instead of scraped from CI logs.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use stlt::bench::{bench_for, BenchResult};
use stlt::coordinator::{GenOpts, Server, ServerOpts};
use stlt::runtime::artifact::{Entry, ModelConfig};
use stlt::runtime::native_stlt::{host_init, StltModel};
use stlt::runtime::Manifest;
use stlt::train::{batch_loss_and_grad, native_train_step, tape_bytes};
use stlt::util::linalg;
use stlt::util::threadpool::{configured_threads, ThreadPool};

/// One machine-readable bench row: the timing summary plus whatever
/// derived metrics the human-readable line prints.
struct JsonRow {
    r: BenchResult,
    /// ("metric name", value) pairs: tokens_per_s, gflops, tape_bytes…
    extra: Vec<(&'static str, f64)>,
}

struct Rows(Vec<JsonRow>);

impl Rows {
    fn push(&mut self, r: BenchResult, extra: Vec<(&'static str, f64)>) {
        self.0.push(JsonRow { r, extra });
    }

    /// Hand-rolled JSON (util::json is a parser; no serde offline).
    fn to_json(&self) -> String {
        let mut s = String::from("{\n  \"bench\": \"native\",\n  \"results\": [\n");
        for (i, row) in self.0.iter().enumerate() {
            let _ = write!(
                s,
                "    {{\"name\": {:?}, \"iters\": {}, \"mean_s\": {:.9}, \
                 \"p50_s\": {:.9}, \"p95_s\": {:.9}, \"min_s\": {:.9}",
                row.r.name, row.r.iters, row.r.mean_s, row.r.p50_s, row.r.p95_s, row.r.min_s
            );
            for (k, v) in &row.extra {
                let _ = write!(s, ", {k:?}: {v:.3}");
            }
            s.push_str(if i + 1 < self.0.len() { "},\n" } else { "}\n" });
        }
        s.push_str("  ]\n}\n");
        s
    }
}

/// Blocked-kernel micro rows: GFLOP/s of the shared linalg kernels at
/// the tied-head shape (n × d × vocab, the single largest matmul) so
/// kernel regressions are visible independently of the full engine.
fn bench_kernels(secs: f64, rows: &mut Rows) {
    let (n, d, k) = (128usize, 64usize, 256usize);
    let mut rng = stlt::util::rng::Rng::new(7);
    let mut fill = |len: usize| -> Vec<f32> { (0..len).map(|_| rng.f32() - 0.5).collect() };
    let a = fill(n * d);
    let b = fill(d * k);
    let bt = linalg::transpose(&b, d, k);
    let gflop = 2.0 * (n * d * k) as f64 / 1e9;

    let mut out = vec![0.0f32; n * k];
    let r = bench_for("linalg/gemm_at 128x64x256 (packed)", secs.min(1.0), || {
        out.fill(0.0);
        linalg::gemm_at(&a, &bt, &mut out, n, d, k);
        std::hint::black_box(&out);
    });
    println!("{}   ({:.2} GFLOP/s)", r.row(), gflop / r.p50_s);
    rows.push(r.clone(), vec![("gflops", gflop / r.p50_s)]);

    let r = bench_for("linalg/gemm    128x64x256 (axpy)", secs.min(1.0), || {
        out.fill(0.0);
        linalg::gemm(&a, &b, &mut out, n, d, k);
        std::hint::black_box(&out);
    });
    println!("{}   ({:.2} GFLOP/s)", r.row(), gflop / r.p50_s);
    rows.push(r.clone(), vec![("gflops", gflop / r.p50_s)]);

    let mut dw = vec![0.0f32; d * k];
    let dy = fill(n * k);
    let r = bench_for("linalg/gemm_ta 128x64x256 (dW)", secs.min(1.0), || {
        dw.fill(0.0);
        linalg::gemm_ta(&a, &dy, &mut dw, n, d, k);
        std::hint::black_box(&dw);
    });
    println!("{}   ({:.2} GFLOP/s)", r.row(), gflop / r.p50_s);
    rows.push(r.clone(), vec![("gflops", gflop / r.p50_s)]);
}

/// Summarise one-shot wall-clock samples into a BenchResult row
/// (stlt::bench::bench_for times a closure; the serving rows time
/// whole concurrent scenarios instead).
fn wall_row(name: &str, samples: &mut [f64]) -> BenchResult {
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len().max(1);
    BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        mean_s: samples.iter().sum::<f64>() / n as f64,
        p50_s: samples[samples.len() / 2],
        p95_s: samples[(samples.len() * 95 / 100).min(samples.len() - 1)],
        min_s: samples.first().copied().unwrap_or(0.0),
    }
}

/// Synthesize the serving manifest entries (the native backend reads
/// only metadata) for base "srv" at batch width `bsrv`, via the shared
/// per-kind builders so the schemas have one source of truth.
fn serving_manifest(cfg: &ModelConfig, p: usize, chunk: usize, bsrv: usize) -> Manifest {
    let mut entries = BTreeMap::new();
    for e in [
        Entry::synthetic_decode(cfg, p, "srv.decode"),
        Entry::synthetic_stream_batch(cfg, p, "srv.stream_batch", chunk, bsrv),
    ] {
        entries.insert(e.name.clone(), e);
    }
    Manifest { dir: PathBuf::from("."), entries }
}

/// Serving rows: batched continuous decode vs the old one-session-at-
/// a-time path (same B = 8 sessions, same prompts), plus first-token
/// latency under a mixed feed+generate load.
fn bench_serving(smoke: bool, cfg: &ModelConfig, flat: &[f32], rows: &mut Rows) {
    let bsrv = 8usize;
    let chunk = 64usize;
    let gen_len = if smoke { 16 } else { 64 };
    let prompt_len = chunk + 1;
    let m = serving_manifest(cfg, flat.len(), chunk, bsrv);
    let opts = || ServerOpts { max_sessions: 32, ..ServerOpts::default() };
    let vocab = cfg.vocab;
    let docv = |len: usize, seed: u64| -> Vec<i32> {
        let mut rng = stlt::util::rng::Rng::new(seed);
        (0..len).map(|_| rng.below(vocab as u64) as i32).collect()
    };

    // ---- sequential baseline: one session generates at a time -------
    let server = Server::start(&m, "srv", flat.to_vec(), opts()).unwrap();
    let mut seeds = Vec::new();
    for s in 0..bsrv as u64 {
        let prompt = docv(prompt_len, 100 + s);
        server.feed(1 + s, prompt.clone(), false).unwrap();
        seeds.push(*prompt.last().unwrap());
    }
    let t0 = Instant::now();
    for s in 0..bsrv {
        let g = server.generate(1 + s as u64, seeds[s], gen_len, None).unwrap();
        assert_eq!(g.tokens.len(), gen_len);
    }
    let seq_s = t0.elapsed().as_secs_f64();
    let seq_tps = (bsrv * gen_len) as f64 / seq_s;
    server.shutdown();

    let r = wall_row(
        &format!("serving/decode sequential B={bsrv}x{gen_len} tok"),
        &mut [seq_s],
    );
    println!("{}   ({seq_tps:.0} tok/s aggregate)", r.row());
    rows.push(r, vec![("tokens_per_s", seq_tps)]);

    // ---- batched continuous decode: the same sessions, concurrent ---
    let server = Arc::new(Server::start(&m, "srv", flat.to_vec(), opts()).unwrap());
    let mut seeds = Vec::new();
    for s in 0..bsrv as u64 {
        let prompt = docv(prompt_len, 100 + s);
        server.feed(1 + s, prompt.clone(), false).unwrap();
        seeds.push(*prompt.last().unwrap());
    }
    let t0 = Instant::now();
    let clients: Vec<_> = (0..bsrv)
        .map(|s| {
            let server = Arc::clone(&server);
            let seed_tok = seeds[s];
            std::thread::spawn(move || {
                let g = server.generate(1 + s as u64, seed_tok, gen_len, None).unwrap();
                assert_eq!(g.tokens.len(), gen_len);
            })
        })
        .collect();
    for c in clients {
        c.join().unwrap();
    }
    let bat_s = t0.elapsed().as_secs_f64();
    let bat_tps = (bsrv * gen_len) as f64 / bat_s;
    let speedup = bat_tps / seq_tps;

    let r = wall_row(
        &format!("serving/decode batched    B={bsrv}x{gen_len} tok"),
        &mut [bat_s],
    );
    println!("{}   ({bat_tps:.0} tok/s aggregate, {speedup:.2}x vs sequential)", r.row());
    rows.push(
        r,
        vec![("tokens_per_s", bat_tps), ("speedup_vs_sequential", speedup)],
    );

    // ---- first-token latency under mixed feed + generate load -------
    let stop = Arc::new(AtomicBool::new(false));
    let feeder = {
        let server = Arc::clone(&server);
        let stop = Arc::clone(&stop);
        let feed_len = 2 * chunk + 1;
        std::thread::spawn(move || {
            let mut i = 0u64;
            while !stop.load(Ordering::Relaxed) {
                let mut rng = stlt::util::rng::Rng::new(9000 + i);
                let d: Vec<i32> =
                    (0..feed_len).map(|_| rng.below(vocab as u64) as i32).collect();
                let _ = server.feed(500 + (i % 4), d, false);
                i += 1;
            }
        })
    };
    let rounds = if smoke { 2 } else { 5 };
    let mut ttfts = Vec::new();
    for _ in 0..rounds {
        let clients: Vec<_> = (0..bsrv)
            .map(|s| {
                let server = Arc::clone(&server);
                let seed_tok = seeds[s];
                std::thread::spawn(move || {
                    let t0 = Instant::now();
                    let mut stream = server
                        .start_generate(
                            1 + s as u64,
                            GenOpts {
                                seed_token: seed_tok,
                                max_tokens: gen_len,
                                ..GenOpts::default()
                            },
                        )
                        .unwrap();
                    stream.recv().unwrap().unwrap();
                    let ttft = t0.elapsed().as_secs_f64();
                    for t in stream.by_ref() {
                        t.unwrap();
                    }
                    ttft
                })
            })
            .collect();
        for c in clients {
            ttfts.push(c.join().unwrap());
        }
    }
    stop.store(true, Ordering::Relaxed);
    feeder.join().unwrap();
    let r = wall_row("serving/first-token latency (mixed load)", &mut ttfts);
    let p50 = r.p50_s;
    let p99 = ttfts[(ttfts.len() * 99 / 100).min(ttfts.len() - 1)];
    println!(
        "{}   (ttft p50 {:.2}ms, p99 {:.2}ms under feed load)",
        r.row(),
        p50 * 1e3,
        p99 * 1e3
    );
    // milliseconds: the JSON extras print at 3 decimals, which would
    // flatten sub-millisecond latencies recorded in seconds
    rows.push(r, vec![("ttft_p50_ms", p50 * 1e3), ("ttft_p99_ms", p99 * 1e3)]);
}

/// Observability overhead row: the batched continuous-decode workload
/// with the metrics registry enabled (the default) vs force-disabled.
/// Disabled cost is one relaxed atomic load per instrument; the budget
/// is <= 2% in steady state (CI asserts a looser 10% to stay unflaky
/// on shared runners).
fn bench_obs_overhead(smoke: bool, cfg: &ModelConfig, flat: &[f32], rows: &mut Rows) {
    let bsrv = 8usize;
    let chunk = 64usize;
    let gen_len = if smoke { 16 } else { 64 };
    let prompt_len = chunk + 1;
    let m = serving_manifest(cfg, flat.len(), chunk, bsrv);
    let opts = || ServerOpts { max_sessions: 32, ..ServerOpts::default() };
    let vocab = cfg.vocab;
    let docv = |len: usize, seed: u64| -> Vec<i32> {
        let mut rng = stlt::util::rng::Rng::new(seed);
        (0..len).map(|_| rng.below(vocab as u64) as i32).collect()
    };

    let run = || -> f64 {
        let server = Arc::new(Server::start(&m, "srv", flat.to_vec(), opts()).unwrap());
        let mut seeds = Vec::new();
        for s in 0..bsrv as u64 {
            let prompt = docv(prompt_len, 700 + s);
            server.feed(1 + s, prompt.clone(), false).unwrap();
            seeds.push(*prompt.last().unwrap());
        }
        let t0 = Instant::now();
        let clients: Vec<_> = (0..bsrv)
            .map(|s| {
                let server = Arc::clone(&server);
                let seed_tok = seeds[s];
                std::thread::spawn(move || {
                    let g = server.generate(1 + s as u64, seed_tok, gen_len, None).unwrap();
                    assert_eq!(g.tokens.len(), gen_len);
                })
            })
            .collect();
        for c in clients {
            c.join().unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        server.shutdown();
        wall
    };

    // warm once so thread-pool spin-up and panel packing are off the
    // clock, then measure enabled and disabled back to back
    let _ = run();
    let was_on = stlt::obs::metrics_on();
    stlt::obs::set_metrics(true);
    let on_s = run();
    stlt::obs::set_metrics(false);
    let off_s = run();
    stlt::obs::set_metrics(was_on);

    let on_tps = (bsrv * gen_len) as f64 / on_s;
    let off_tps = (bsrv * gen_len) as f64 / off_s;
    let overhead_pct = (on_s / off_s - 1.0) * 100.0;
    let r = wall_row(&format!("obs/overhead decode B={bsrv}x{gen_len} tok"), &mut [on_s]);
    println!(
        "{}   (metrics on {on_tps:.0} tok/s, off {off_tps:.0} tok/s, overhead {overhead_pct:+.2}%)",
        r.row()
    );
    rows.push(
        r,
        vec![
            ("tokens_per_s_metrics_on", on_tps),
            ("tokens_per_s_metrics_off", off_tps),
            ("overhead_pct", overhead_pct),
        ],
    );
}

/// Wire rows: the batched-decode workload again, but through the full
/// sharded topology — loopback TCP, session router, N worker servers —
/// plus live-migration latency. The delta between `serving/decode
/// batched` and `wire/decode W=1` is the protocol tax; scaling W shows
/// the sharding win (each worker runs its own decode waves).
fn bench_wire(smoke: bool, cfg: &ModelConfig, flat: &[f32], rows: &mut Rows) {
    use stlt::coordinator::Session;
    use stlt::net::{spawn_worker, Router, WireServer};

    let bsrv = 8usize;
    let chunk = 64usize;
    let gen_len = if smoke { 16 } else { 64 };
    let prompt_len = chunk + 1;
    let sessions = if smoke { 8usize } else { 16 };
    let m = serving_manifest(cfg, flat.len(), chunk, bsrv);
    let vocab = cfg.vocab;
    let docv = |len: usize, seed: u64| -> Vec<i32> {
        let mut rng = stlt::util::rng::Rng::new(seed);
        (0..len).map(|_| rng.below(vocab as u64) as i32).collect()
    };

    // kept alive to the end of the bench: router-client reader threads
    // hold the sockets, so topologies are not torn down mid-run
    let mut keep: Vec<(Arc<Server>, WireServer)> = Vec::new();

    for workers in [1usize, 2, 4] {
        let mut addrs = Vec::new();
        for _ in 0..workers {
            let s = Arc::new(
                Server::start(
                    &m,
                    "srv",
                    flat.to_vec(),
                    ServerOpts { max_sessions: 64, ..ServerOpts::default() },
                )
                .unwrap(),
            );
            let w = spawn_worker(Arc::clone(&s), "127.0.0.1:0").unwrap();
            addrs.push(w.addr().to_string());
            keep.push((s, w));
        }
        let router = Router::connect(&addrs).unwrap();

        // open + warm all sessions before the clock starts
        let mut sess = Vec::new();
        let mut seeds = Vec::new();
        for k in 0..sessions as u64 {
            let h = router.open_session().unwrap();
            let prompt = docv(prompt_len, 100 + k);
            h.feed(prompt.clone(), false).unwrap();
            seeds.push(*prompt.last().unwrap());
            sess.push(h);
        }

        let t0 = Instant::now();
        let clients: Vec<_> = sess
            .into_iter()
            .zip(seeds)
            .map(|(h, seed_tok)| {
                std::thread::spawn(move || {
                    let tg = Instant::now();
                    let mut stream = h
                        .generate(GenOpts {
                            seed_token: seed_tok,
                            max_tokens: gen_len,
                            ..GenOpts::default()
                        })
                        .unwrap();
                    stream.recv().unwrap().unwrap();
                    let ttft = tg.elapsed().as_secs_f64();
                    let mut n = 1usize;
                    for t in stream.by_ref() {
                        t.unwrap();
                        n += 1;
                    }
                    assert_eq!(n, gen_len);
                    (h, ttft)
                })
            })
            .collect();
        let mut ttfts = Vec::new();
        let mut handles = Vec::new();
        for c in clients {
            let (h, ttft) = c.join().unwrap();
            ttfts.push(ttft);
            handles.push(h);
        }
        let wall = t0.elapsed().as_secs_f64();
        let tps = (sessions * gen_len) as f64 / wall;
        // quantiles via the shared log-bucket histogram — the same
        // math `stlt stats` exposes live, so bench and serving
        // percentiles agree bit-for-bit by construction
        let mut th = stlt::metrics::Histogram::new();
        for &t in &ttfts {
            th.record(t);
        }
        let p50 = th.quantile(0.50);
        let p99 = th.quantile(0.99);
        let r =
            wall_row(&format!("wire/decode W={workers} {sessions}x{gen_len} tok"), &mut [wall]);
        println!(
            "{}   ({tps:.0} tok/s aggregate, ttft p50 {:.2}ms p99 {:.2}ms)",
            r.row(),
            p50 * 1e3,
            p99 * 1e3
        );
        rows.push(
            r,
            vec![
                ("tokens_per_s", tps),
                ("ttft_p50_ms", p50 * 1e3),
                ("ttft_p99_ms", p99 * 1e3),
                ("workers", workers as f64),
            ],
        );

        if workers == 2 {
            // live migration: ping-pong one warmed session between the
            // two workers (export → open same id → import → swap)
            let h = &handles[0];
            let id = h.session_id();
            let carry_kib = h.export_carry().unwrap().state_bytes() as f64 / 1024.0;
            let iters = if smoke { 8 } else { 32 };
            let mut samples = Vec::with_capacity(iters);
            for _ in 0..iters {
                let from = router.worker_of(id).unwrap();
                let tm = Instant::now();
                router.migrate(id, 1 - from).unwrap();
                samples.push(tm.elapsed().as_secs_f64());
            }
            let r = wall_row("wire/migrate session (2 workers)", &mut samples);
            let p50_ms = r.p50_s * 1e3;
            println!("{}   ({carry_kib:.1} KiB carry, p50 {p50_ms:.2}ms)", r.row());
            rows.push(r, vec![("carry_kib", carry_kib), ("migrate_p50_ms", p50_ms)]);
        }

        for mut h in handles {
            let _ = h.close();
        }
    }
}

/// Deep-lint row: wall time of the whole call-graph tier (parse,
/// graph build, reachability passes, lock-order analysis) over the
/// crate's own `src` tree — the price CI pays on every push, pinned so
/// an analyzer blow-up (e.g. a resolver gone quadratic) is visible as
/// a bench regression and not just a slower wall.
fn bench_lint_deep(smoke: bool, rows: &mut Rows) {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let (src, allow) = (root.join("src"), root.join("lint_deep.allow"));
    let iters = if smoke { 2usize } else { 5 };
    let mut samples = Vec::with_capacity(iters);
    let mut violations = 0usize;
    for _ in 0..iters {
        let t = Instant::now();
        let v = stlt::lint::run_deep(&src, &allow, None).expect("deep lint over crate src");
        samples.push(t.elapsed().as_secs_f64());
        violations = v.len();
    }
    let r = wall_row("lint/deep crate-src analyze", &mut samples);
    println!("{}   ({:.1} ms, {violations} violations)", r.row(), r.p50_s * 1e3);
    rows.push(
        r.clone(),
        vec![("deep_ms", r.p50_s * 1e3), ("violations", violations as f64)],
    );
}

fn main() {
    let smoke = std::env::var("STLT_BENCH_SMOKE")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false);
    let secs = if smoke { 0.3 } else { 3.0 };
    println!(
        "== native engine bench (no artifacts needed{}) ==",
        if smoke { ", smoke mode" } else { "" }
    );
    let mut rows = Rows(Vec::new());
    bench_kernels(secs, &mut rows);
    let cfg = ModelConfig {
        arch: "stlt".into(),
        vocab: 256,
        d_model: 64,
        n_layers: 2,
        n_ctx: 128,
        s_max: 32,
        batch: 8,
        mode: "linear".into(),
        ..ModelConfig::default()
    };
    let flat = host_init(&cfg, 1);
    let model = StltModel::new(&cfg, Arc::new(flat.clone())).unwrap();
    let tokens: Vec<i32> = (0..128).map(|i| 4 + (i * 7) % 200).collect();

    let r = bench_for("native/forward 128 tok (d=64 S=32 L=2)", secs, || {
        std::hint::black_box(model.forward_logits(&tokens).unwrap());
    });
    println!("{}   ({:.0} tok/s)", r.row(), 128.0 / r.p50_s);
    rows.push(r.clone(), vec![("tokens_per_s", 128.0 / r.p50_s)]);

    let chunk: Vec<i32> = tokens[..64].to_vec();
    let (mut l, mut u) = model.zero_carry();
    let r = bench_for("native/stream chunk 64 tok", secs, || {
        std::hint::black_box(model.trunk_chunk(&mut l, &mut u, &chunk, 0.0, None).unwrap());
    });
    println!("{}   ({:.0} tok/s)", r.row(), 64.0 / r.p50_s);
    rows.push(r.clone(), vec![("tokens_per_s", 64.0 / r.p50_s)]);

    let (mut l, mut u) = model.zero_carry();
    let r = bench_for("native/decode 1 tok", secs.min(2.0), || {
        std::hint::black_box(model.trunk_chunk(&mut l, &mut u, &tokens[..1], 0.0, None).unwrap());
    });
    println!("{}   ({:.0} tok/s)", r.row(), 1.0 / r.p50_s);
    rows.push(r.clone(), vec![("tokens_per_s", 1.0 / r.p50_s)]);

    // mixer seam: the linear-attention baseline through the same engine
    // and carry plumbing — the delta vs the rows above is the cost (or
    // win) of the φ-feature prefix sums over the Laplace recurrence
    let cfg_la = ModelConfig { mixer: "linear_attention".into(), ..cfg.clone() };
    let model_la = StltModel::new(&cfg_la, Arc::new(flat.clone())).unwrap();
    let r = bench_for("native/forward 128 tok (linear_attention)", secs, || {
        std::hint::black_box(model_la.forward_logits(&tokens).unwrap());
    });
    println!("{}   ({:.0} tok/s)", r.row(), 128.0 / r.p50_s);
    rows.push(r.clone(), vec![("tokens_per_s", 128.0 / r.p50_s)]);

    let (mut l, mut u) = model_la.zero_carry();
    let r = bench_for("native/decode 1 tok (linear_attention)", secs.min(2.0), || {
        std::hint::black_box(
            model_la.trunk_chunk(&mut l, &mut u, &tokens[..1], 0.0, None).unwrap(),
        );
    });
    println!("{}   ({:.0} tok/s)", r.row(), 1.0 / r.p50_s);
    rows.push(r.clone(), vec![("tokens_per_s", 1.0 / r.p50_s)]);

    // training: gradient accumulation alone, then the full optimiser
    // step — whole-sequence tape vs the segment-checkpointed tape
    let pool = ThreadPool::new(configured_threads());
    let (b, n1) = (cfg.batch, 33usize); // short rows keep the smoke cheap
    let n = n1 - 1;
    let mut rng = stlt::util::rng::Rng::new(5);
    let batch: Vec<i32> = (0..b * n1).map(|_| rng.below(cfg.vocab as u64) as i32).collect();
    let train_tokens = (b * n) as f64;

    let r = bench_for("native/grad batch 8x32 tok", secs, || {
        std::hint::black_box(batch_loss_and_grad(&model, &batch, b, n1, None, &pool).unwrap());
    });
    println!("{}   ({:.0} tok/s)", r.row(), train_tokens / r.p50_s);
    rows.push(r.clone(), vec![("tokens_per_s", train_tokens / r.p50_s)]);

    let r = bench_for("native/grad batch 8x32 tok (linear_attention)", secs, || {
        std::hint::black_box(batch_loss_and_grad(&model_la, &batch, b, n1, None, &pool).unwrap());
    });
    println!("{}   ({:.0} tok/s)", r.row(), train_tokens / r.p50_s);
    rows.push(r.clone(), vec![("tokens_per_s", train_tokens / r.p50_s)]);

    for (label, seg) in [("native/train_step 8x32 tok (full tape)", 0usize),
        ("native/train_step 8x32 tok (ckpt C=8)", 8)]
    {
        let mut c = cfg.clone();
        c.grad_ckpt_segment = seg;
        let tape = tape_bytes(&c, n) as f64;
        let m2 = StltModel::new(&c, Arc::new(flat.clone())).unwrap();
        let mut fl = flat.clone();
        let mut mm = vec![0.0f32; fl.len()];
        let mut vv = vec![0.0f32; fl.len()];
        let mut step = 0i32;
        let r = bench_for(label, secs, || {
            std::hint::black_box(
                native_train_step(&m2, &mut fl, &mut mm, &mut vv, step, &batch, b, n1, 0, &pool)
                    .unwrap(),
            );
            step += 1;
        });
        println!(
            "{}   ({:.0} tok/s, tape {:.1} KiB/row)",
            r.row(),
            train_tokens / r.p50_s,
            tape / 1024.0
        );
        rows.push(
            r.clone(),
            vec![("tokens_per_s", train_tokens / r.p50_s), ("tape_bytes_per_row", tape)],
        );
    }

    // serving: batched continuous decode vs sequential, ttft percentiles
    bench_serving(smoke, &cfg, &flat, &mut rows);

    // observability: metrics-enabled vs disabled on the same workload
    bench_obs_overhead(smoke, &cfg, &flat, &mut rows);

    // sharded serving: router + N wire workers over loopback TCP,
    // decode scaling and live-migration latency
    bench_wire(smoke, &cfg, &flat, &mut rows);

    // static analysis: the deep-lint tier's own wall time
    bench_lint_deep(smoke, &mut rows);

    let path = std::env::var("STLT_BENCH_JSON").unwrap_or_else(|_| "BENCH_native.json".into());
    match std::fs::write(&path, rows.to_json()) {
        Ok(()) => println!("wrote {path} ({} rows)", rows.0.len()),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
