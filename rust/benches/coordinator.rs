//! Coordinator benches: queue ops, dynamic batcher, state pool — the
//! pure-Rust control plane must be microseconds against the model's
//! milliseconds (paper §4.6: "overhead of adaptive node calculation
//! was minimal"; here: overhead of coordination is minimal).

use std::sync::Arc;
use std::time::Duration;

use stlt::bench::bench;
use stlt::coordinator::{BatchPolicy, Batcher, BoundedQueue, StatePool};
use stlt::runtime::StreamCarry;

fn carry() -> StreamCarry {
    StreamCarry {
        l: vec![0.0; 2 * 32 * 2],
        u: vec![0.0; 2 * 32 * 64 * 2],
        l_shape: vec![2, 32, 2],
        u_shape: vec![2, 32, 64, 2],
    }
}

fn main() {
    println!("== coordinator benches ==");
    let mut results = Vec::new();

    let q: BoundedQueue<u64> = BoundedQueue::new(1024);
    results.push(bench("queue/push+pop x1000", 5, 200, || {
        for i in 0..1000 {
            q.try_push(i).unwrap();
        }
        for _ in 0..1000 {
            q.pop();
        }
    }));

    results.push(bench("batcher/1000 items batches of 4", 3, 100, || {
        let q = Arc::new(BoundedQueue::new(2048));
        for i in 0..1000u64 {
            q.try_push(i).unwrap();
        }
        q.close();
        let b = Batcher::new(q, BatchPolicy { max_batch: 4, max_wait: Duration::from_micros(10) });
        let mut n = 0;
        while let Some(batch) = b.next_batch() {
            n += batch.len();
        }
        assert_eq!(n, 1000);
    }));

    results.push(bench("statepool/admit+checkout+checkin x100", 5, 200, || {
        let mut p = StatePool::new(64);
        for i in 0..100u64 {
            p.admit(i, carry());
            let c = p.checkout(i).unwrap();
            p.checkin(i, c, 64);
        }
    }));

    // carry copy cost: the per-step state movement of the serving path
    let c0 = carry();
    results.push(bench("carry/clone (2x32x64 f32)", 10, 1000, || {
        std::hint::black_box(c0.clone());
    }));

    for r in &results {
        println!("{}", r.row());
    }
}
