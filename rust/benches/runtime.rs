//! Runtime benches: PJRT execution latency per artifact kind and the
//! host<->literal conversion overhead on the hot path. EXPERIMENTS.md
//! §Perf tracks these before/after optimization.

use stlt::bench::{bench, bench_for};
use stlt::runtime::{
    default_artifacts_dir, exec::load_init_vec, EvalStep, Manifest, Runtime, StreamStep,
    TrainState, TrainStep,
};

fn main() {
    println!("== runtime benches (requires `make artifacts`) ==");
    let manifest = Manifest::load(default_artifacts_dir()).expect("make artifacts");
    let rt = Runtime::cpu().unwrap();
    let mut results = Vec::new();

    // host->device upload: 1M f32 through the backend buffer path
    let v = vec![1.0f32; 1_000_000];
    results.push(bench("upload/1M f32 host->device", 3, 30, || {
        let buf = rt.upload_f32(&v, &[1_000_000]).unwrap();
        std::hint::black_box(buf.len());
    }));

    let e = manifest.get("lm_stlt_tiny.train").unwrap();
    let flat = load_init_vec(e.init_file.as_ref().unwrap(), e.param_count).unwrap();

    let eval = EvalStep::new(&rt, &manifest, "lm_stlt_tiny.eval").unwrap();
    let mut gen = stlt::data::batch::LmBatcher::new(
        stlt::data::corpus::CorpusConfig::default_for_vocab(e.config.vocab),
        3,
        eval.batch,
        eval.n_plus_1,
    );
    let toks = gen.next_batch();
    results.push(bench_for("exec/eval_step tiny (8x128)", 3.0, || {
        std::hint::black_box(eval.run(&flat, &toks, 0.0, 0).unwrap());
    }));

    let ts = TrainStep::new(&rt, &manifest, "lm_stlt_tiny.train").unwrap();
    let mut state = TrainState::from_entry(e).unwrap();
    results.push(bench_for("exec/train_step tiny (8x128)", 5.0, || {
        std::hint::black_box(ts.run(&mut state, &toks, 1).unwrap());
    }));

    let stream = StreamStep::new(&rt, &manifest, "lm_stlt_tiny.stream").unwrap();
    let mut carry = stream.zero_carry();
    let ctoks = vec![5i32; stream.chunk];
    let mask = vec![1.0f32; stream.chunk];
    results.push(bench_for("exec/stream_step tiny (chunk 64)", 3.0, || {
        std::hint::black_box(stream.run(&flat, &mut carry, &ctoks, &ctoks, &mask).unwrap());
    }));

    for r in &results {
        println!("{}", r.row());
    }
    println!(
        "note: tokens/s -> eval {:.0}, train {:.0}, stream {:.0}",
        (eval.batch * (eval.n_plus_1 - 1)) as f64 / results[1].p50_s,
        (ts.batch * (ts.n_plus_1 - 1)) as f64 / results[2].p50_s,
        stream.chunk as f64 / results[3].p50_s,
    );
}
