//! Native training subsystem integration tests.
//!
//! Correctness of the hand-derived backward pass is pinned by central
//! finite differences against an *independent f64 oracle*: a from-
//! scratch double-precision transcription of the forward loss (module
//! [`oracle`]) that shares no code with `train/backward.rs`. The oracle
//! covers both mixers (Laplace recurrence and linear attention), the
//! causal adaptive gate, and — given the same `(temp, seed)` — replays
//! the tape's exact Gumbel-sigmoid logistic samples, so the relaxed
//! training loss is FD-pinned too. Arithmetic noise is f64-free, so the
//! comparison isolates the analytic f32 gradient's error; the 1e-3
//! acceptance tolerance sits ~100x above the observed f32 rounding
//! floor.
//!
//! Also here: the data-parallel bitwise-reduction guarantee, a native
//! `train_lm` smoke (NLL must decrease), bit-identical checkpoint
//! resume, and a drift check that the committed native manifest's
//! parameter counts match `interpret::trunk_layout`.
#![cfg(feature = "native")]
// index loops in the f64 oracle mirror the math on purpose
#![allow(clippy::needless_range_loop)]

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;

use stlt::coordinator::{load_checkpoint_meta, TrainOpts};
use stlt::interpret::{total_params, trunk_layout};
use stlt::runtime::artifact::{Entry, ModelConfig, TensorSpec};
use stlt::runtime::native_stlt::{host_init, StltModel};
use stlt::runtime::{Manifest, Runtime, TrainState, TrainStep};
use stlt::train::{batch_loss_and_grad, row_loss_and_grad, TrainNoise};
use stlt::util::rng::Rng;
use stlt::util::threadpool::ThreadPool;

/// Independent double-precision loss oracle (math transcribed from the
/// paper/python semantics, not from backward.rs).
mod oracle {
    use stlt::interpret::trunk_layout;
    use stlt::runtime::artifact::ModelConfig;
    use stlt::util::rng::Rng;

    fn softplus(x: f64) -> f64 {
        if x > 20.0 {
            x
        } else {
            (1.0 + x.exp()).ln()
        }
    }

    fn sigmoid(x: f64) -> f64 {
        1.0 / (1.0 + (-x).exp())
    }

    fn gelu(x: f64) -> f64 {
        const C: f64 = 0.797_884_6;
        0.5 * x * (1.0 + (C * (x + 0.044_715 * x * x * x)).tanh())
    }

    /// φ(x) = elu(x) + 1, the linear-attention feature map.
    fn phi(x: f64) -> f64 {
        if x > 0.0 {
            x + 1.0
        } else {
            x.exp()
        }
    }

    fn ln(x: &[f64], g: &[f64], b: &[f64], d: usize) -> Vec<f64> {
        let n = x.len() / d;
        let mut y = vec![0.0; n * d];
        for t in 0..n {
            let r = &x[t * d..(t + 1) * d];
            let mu = r.iter().sum::<f64>() / d as f64;
            let var = r.iter().map(|&v| (v - mu) * (v - mu)).sum::<f64>() / d as f64;
            let inv = 1.0 / (var + 1e-5).sqrt();
            for i in 0..d {
                y[t * d + i] = (r[i] - mu) * inv * g[i] + b[i];
            }
        }
        y
    }

    /// loss = ce_scale * Σ nll + reg_scale * reg for one token row.
    ///
    /// `noise = Some((temp, seed))` replays the tape's Gumbel-sigmoid
    /// relaxation: one `Rng` stream per row, S logistic samples per
    /// layer drawn in layer order, each rounded through f32 exactly as
    /// the tape holds them — so the oracle differentiates the *same*
    /// relaxed loss. `None` is the deterministic eval/FD gate.
    pub fn row_loss(
        cfg: &ModelConfig,
        flat: &[f32],
        tokens: &[i32],
        ce_scale: f64,
        reg_scale: f64,
        noise: Option<(f64, u64)>,
    ) -> f64 {
        let layout = trunk_layout(cfg);
        let off = |p: &str| layout.iter().find(|l| l.path == p).map(|l| l.offset);
        let take = |o: usize, n: usize| -> Vec<f64> {
            flat[o..o + n].iter().map(|&v| v as f64).collect()
        };
        let (d, s, vcb) = (cfg.d_model, cfg.s_max, cfg.vocab);
        let hd = d * cfg.ffn_mult.max(1);
        let n = tokens.len() - 1;
        let embed = take(off("/embed").unwrap(), vcb * d);
        let scale = (d as f64).sqrt();
        let mut x = vec![0.0; n * d];
        for t in 0..n {
            let tok = tokens[t] as usize;
            for i in 0..d {
                x[t * d + i] = embed[tok * d + i] * scale;
            }
        }
        let linattn = cfg.mixer == "linear_attention";
        let mut gum_rng = noise.map(|(_, seed)| Rng::new(seed));
        let mut reg_total = 0.0;
        for li in 0..cfg.n_layers {
            let p = format!("/layers/{li:03}");
            let o = |k: &str| off(&format!("{p}/{k}")).unwrap();
            let om = |k: &str| off(&format!("{p}/mixer/{k}"));
            let h1 = ln(&x, &take(o("ln1_g"), d), &take(o("ln1_b"), d), d);
            // gate: causal running-mean pooling over h1, per-token m;
            // Gumbel-relaxed when a noise stream is given
            let mut m = vec![1.0f64; n * s];
            if let (true, Some(wa), Some(ba)) = (cfg.adaptive, om("w_alpha"), om("b_alpha")) {
                let (temp, g) = match (noise, gum_rng.as_mut()) {
                    (Some((tmp, _)), Some(rng)) => {
                        let g: Vec<f64> = (0..s)
                            .map(|_| {
                                let u = rng.f64().clamp(1e-6, 1.0 - 1e-6);
                                ((u.ln() - (1.0 - u).ln()) as f32) as f64
                            })
                            .collect();
                        (tmp, g)
                    }
                    _ => (1.0, vec![0.0; s]),
                };
                let mut pool = vec![0.0; d];
                for t in 0..n {
                    for i in 0..d {
                        pool[i] += h1[t * d + i];
                    }
                    let inv = 1.0 / (t + 1) as f64;
                    for k in 0..s {
                        let mut logit = flat[ba + k] as f64;
                        for (i, pv) in pool.iter().enumerate() {
                            logit += pv * inv * flat[wa + i * s + k] as f64;
                        }
                        m[t * s + k] = sigmoid((logit + g[k]) / temp);
                    }
                }
            }
            let w_f = take(om("w_f").unwrap(), d * s);
            let w_v = take(om("w_v").unwrap(), d * d);
            let w_o = take(om("w_o").unwrap(), d * d);
            let t_val = softplus(flat[om("t_raw").unwrap()] as f64) + 1.0;
            let gamma = (-1.0 / (8.0 * t_val)).exp();
            let sigma: Vec<f64> = (0..s)
                .map(|k| softplus(flat[om("sigma_raw").unwrap() + k] as f64) + cfg.sigma_min as f64)
                .collect();
            let omega: Vec<f64> = (0..s).map(|k| flat[om("omega").unwrap() + k] as f64).collect();
            let theta: Vec<f64> = if cfg.omega_zero { vec![0.0; s] } else { omega.clone() };
            let mut z = vec![0.0; n * d];
            if linattn {
                // shared-QK linear attention: u = φ(f) ⊙ m, inclusive
                // prefix sums zv/S, readout z = (uᵀ S) / (uᵀ zv + ε)
                let mut zv = vec![0.0; s];
                let mut smat = vec![0.0; s * d];
                for t in 0..n {
                    let mut vv = vec![0.0; d];
                    for e in 0..d {
                        for i in 0..d {
                            vv[e] += h1[t * d + i] * w_v[i * d + e];
                        }
                    }
                    let mut u = vec![0.0; s];
                    for k in 0..s {
                        let mut f_tk = 0.0;
                        for i in 0..d {
                            f_tk += h1[t * d + i] * w_f[i * s + k];
                        }
                        u[k] = phi(f_tk) * m[t * s + k];
                        zv[k] += u[k];
                        for e in 0..d {
                            smat[k * d + e] += u[k] * vv[e];
                        }
                    }
                    let mut den = 1e-6;
                    for k in 0..s {
                        den += u[k] * zv[k];
                    }
                    for e in 0..d {
                        let mut num = 0.0;
                        for k in 0..s {
                            num += u[k] * smat[k * d + e];
                        }
                        z[t * d + e] = num / den;
                    }
                }
            } else {
                // Laplace-node recurrence
                let mut l = vec![0.0; s * 2];
                let mut u = vec![0.0; s * d * 2];
                for t in 0..n {
                    for k in 0..s {
                        let decay = (-(sigma[k] + 1.0 / t_val)).exp();
                        let (a, b) = (decay * theta[k].cos(), -decay * theta[k].sin());
                        let mut f_tk = 0.0;
                        for i in 0..d {
                            f_tk += h1[t * d + i] * w_f[i * s + k];
                        }
                        f_tk *= m[t * s + k];
                        let (lr, li2) = (l[k * 2], l[k * 2 + 1]);
                        let nlr = a * lr - b * li2 + f_tk;
                        let nli = a * li2 + b * lr;
                        l[k * 2] = nlr;
                        l[k * 2 + 1] = nli;
                        for e in 0..d {
                            let mut ve = 0.0;
                            for i in 0..d {
                                ve += h1[t * d + i] * w_v[i * d + e];
                            }
                            let ur = gamma * u[(k * d + e) * 2] + nlr * ve;
                            let ui = gamma * u[(k * d + e) * 2 + 1] - nli * ve;
                            u[(k * d + e) * 2] = ur;
                            u[(k * d + e) * 2 + 1] = ui;
                            z[t * d + e] += (nlr * ur - nli * ui) / s as f64;
                        }
                    }
                }
            }
            // x += z @ w_o ; FFN block
            let mut x_mid = x.clone();
            for t in 0..n {
                for e in 0..d {
                    let mut acc = 0.0;
                    for i in 0..d {
                        acc += z[t * d + i] * w_o[i * d + e];
                    }
                    x_mid[t * d + e] += acc;
                }
            }
            let h2 = ln(&x_mid, &take(o("ln2_g"), d), &take(o("ln2_b"), d), d);
            let w1 = take(o("ffn_w1"), d * hd);
            let b1 = take(o("ffn_b1"), hd);
            let w2 = take(o("ffn_w2"), hd * d);
            let b2 = take(o("ffn_b2"), d);
            let mut x_out = x_mid.clone();
            for t in 0..n {
                for e in 0..d {
                    x_out[t * d + e] += b2[e];
                }
                for j in 0..hd {
                    let mut hj = b1[j];
                    for i in 0..d {
                        hj += h2[t * d + i] * w1[i * hd + j];
                    }
                    let g = gelu(hj);
                    for e in 0..d {
                        x_out[t * d + e] += g * w2[j * d + e];
                    }
                }
            }
            x = x_out;
            // Eq. Reg on the token-mean gate mass m̄; the node-coupled
            // terms only exist for mixers that use the Laplace nodes
            let mut mbar = vec![0.0f64; s];
            for t in 0..n {
                for k in 0..s {
                    mbar[k] += m[t * s + k];
                }
            }
            for mb in mbar.iter_mut() {
                *mb /= n as f64;
            }
            for k in 0..s {
                if !linattn {
                    reg_total += cfg.lambda_omega as f64 * omega[k].abs() * mbar[k];
                }
                reg_total += cfg.lambda_mask as f64 * mbar[k];
            }
            if !linattn {
                for k in 1..s {
                    let ds = sigma[k] - sigma[k - 1];
                    reg_total += cfg.lambda_sigma as f64 * ds * ds * mbar[k] * mbar[k - 1];
                }
            }
        }
        let xf = ln(
            &x,
            &take(off("/lnf_g").unwrap(), d),
            &take(off("/lnf_b").unwrap(), d),
            d,
        );
        let mut nll_sum = 0.0;
        for t in 0..n {
            let mut logits = vec![0.0; vcb];
            for (v, le) in logits.iter_mut().enumerate() {
                let mut acc = 0.0;
                for i in 0..d {
                    acc += xf[t * d + i] * embed[v * d + i];
                }
                *le = acc;
            }
            let mx = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let denom: f64 = logits.iter().map(|&l| (l - mx).exp()).sum();
            nll_sum += denom.ln() - (logits[tokens[t + 1] as usize] - mx);
        }
        ce_scale * nll_sum + reg_scale * reg_total
    }
}

fn grad_cfg() -> ModelConfig {
    ModelConfig {
        arch: "stlt".into(),
        vocab: 17,
        d_model: 8,
        n_layers: 2,
        n_ctx: 16,
        s_max: 4,
        batch: 2,
        adaptive: true,
        mode: "linear".into(),
        ffn_mult: 2,
        t_init: 1.6,
        lambda_omega: 1e-3,
        lambda_sigma: 1e-3,
        lambda_mask: 1e-3,
        ..ModelConfig::default()
    }
}

/// host_init moved off the tiny-weight regime so every parameter group
/// carries a healthy gradient signal (validated: all group directional
/// derivatives >= 2e-4 at this perturbation).
fn perturbed_init(cfg: &ModelConfig, seed: u64) -> Vec<f32> {
    let mut flat = host_init(cfg, seed);
    let mut rng = Rng::new(seed ^ 0xBEEF);
    for x in flat.iter_mut() {
        *x += (rng.normal() * 0.25) as f32;
    }
    flat
}

fn fd_tokens(cfg: &ModelConfig, seed: u64, n: usize) -> Vec<i32> {
    let mut rng = Rng::new(seed);
    let mut toks: Vec<i32> = (0..n + 1).map(|_| rng.below(cfg.vocab as u64) as i32).collect();
    for t in (0..toks.len()).step_by(3) {
        toks[t] = 5; // periodic structure boosts the node-parameter grads
    }
    toks
}

/// Directional finite-difference check of one parameter group against
/// the f64 oracle: best error over eps in {1e-3, 1e-4}. `noise` must
/// match what the analytic gradient was computed with.
#[allow(clippy::too_many_arguments)]
fn group_fd_rel_err(
    cfg: &ModelConfig,
    flat: &[f32],
    grad: &[f32],
    tokens: &[i32],
    spans: &[(usize, usize)],
    dir_seed: u64,
    ce_scale: f64,
    reg_scale: f64,
    noise: Option<(f64, u64)>,
) -> (f64, f64) {
    let mut rng = Rng::new(dir_seed);
    let total: usize = spans.iter().map(|&(_, n)| n).sum();
    let norm = (total as f64).sqrt();
    let mut u = vec![0.0f64; flat.len()];
    for &(off, n) in spans {
        for x in u[off..off + n].iter_mut() {
            *x = if rng.below(2) == 0 { 1.0 } else { -1.0 } / norm;
        }
    }
    let analytic: f64 = u.iter().zip(grad).map(|(&ui, &g)| ui * g as f64).sum();
    let mut best = f64::INFINITY;
    for eps in [1e-3f64, 1e-4] {
        let shift = |sgn: f64| -> Vec<f32> {
            flat.iter()
                .zip(&u)
                .map(|(&f, &ui)| (f as f64 + sgn * eps * ui) as f32)
                .collect()
        };
        let lp = oracle::row_loss(cfg, &shift(1.0), tokens, ce_scale, reg_scale, noise);
        let lm = oracle::row_loss(cfg, &shift(-1.0), tokens, ce_scale, reg_scale, noise);
        let fd = (lp - lm) / (2.0 * eps);
        let err = (fd - analytic).abs() / fd.abs().max(analytic.abs()).max(1e-6);
        best = best.min(err);
    }
    (best, analytic)
}

/// Parameter groups (leaf-name -> [(offset, numel)]) of a config.
fn param_groups(cfg: &ModelConfig) -> BTreeMap<String, Vec<(usize, usize)>> {
    let mut groups: BTreeMap<String, Vec<(usize, usize)>> = BTreeMap::new();
    for leaf in trunk_layout(cfg) {
        let name = leaf.path.rsplit('/').next().unwrap().to_string();
        groups.entry(name).or_default().push((leaf.offset, leaf.numel()));
    }
    groups
}

#[test]
fn tape_forward_matches_engine_nll() {
    // ties the training-tape forward to the inference engine
    // (StltModel::eval_row -> trunk_chunk): a semantic edit to either
    // forward that is not mirrored in the other fails here, so training
    // can never silently optimise a different network than eval/serving
    // executes. Tolerance covers fp summation-order differences only.
    for (mixer, adaptive) in [("", false), ("", true), ("linear_attention", true)] {
        let mut cfg = grad_cfg();
        cfg.mixer = mixer.into();
        cfg.adaptive = adaptive;
        let flat = perturbed_init(&cfg, 17);
        let tokens = fd_tokens(&cfg, 23, 12);
        let model = StltModel::new(&cfg, Arc::new(flat)).unwrap();
        let out = row_loss_and_grad(&model, &tokens, 1.0, 0.0, None).unwrap();
        let (nll, cnt, _) = model.eval_row(&tokens, 0.0, 0).unwrap();
        assert_eq!(cnt, (tokens.len() - 1) as f64);
        assert!(
            (out.nll_sum - nll).abs() < 1e-4 * (1.0 + nll.abs()),
            "mixer={mixer:?} adaptive={adaptive}: tape nll {} vs engine {nll}",
            out.nll_sum
        );
    }
}

#[test]
fn fd_gradient_checks_every_param_group() {
    // the tentpole acceptance seam: rel-err <= 1e-3 for every parameter
    // group, including the Laplace-node sigma_raw / omega / t_raw and
    // the adaptive-gate w_alpha / b_alpha
    let cfg = grad_cfg();
    let flat = perturbed_init(&cfg, 11);
    let tokens = fd_tokens(&cfg, 42, 12);
    let n = tokens.len() - 1;
    let (ce_scale, reg_scale) = (1.0 / n as f64, 1.0);
    let model = StltModel::new(&cfg, Arc::new(flat.clone())).unwrap();
    let out = row_loss_and_grad(&model, &tokens, ce_scale as f32, reg_scale as f32, None).unwrap();

    // the f32 loss itself must agree with the f64 oracle
    let loss = ce_scale * out.nll_sum + reg_scale * out.reg as f64;
    let oracle_loss = oracle::row_loss(&cfg, &flat, &tokens, ce_scale, reg_scale, None);
    assert!(
        (loss - oracle_loss).abs() < 1e-4 * (1.0 + oracle_loss.abs()),
        "loss {loss} vs oracle {oracle_loss}"
    );

    for (dir_seed, (name, spans)) in param_groups(&cfg).iter().enumerate() {
        let (err, analytic) = group_fd_rel_err(
            &cfg, &flat, &out.grad, &tokens, spans, 1000 + dir_seed as u64, ce_scale, reg_scale,
            None,
        );
        assert!(
            err <= 1e-3,
            "group '{name}': FD rel err {err:.2e} (directional derivative {analytic:.3e})"
        );
    }
}

#[test]
fn fd_gradient_checks_non_adaptive_and_omega_zero() {
    for (seed, omega_zero) in [(3u64, false), (4, true)] {
        let mut cfg = grad_cfg();
        cfg.adaptive = false;
        cfg.omega_zero = omega_zero;
        let flat = perturbed_init(&cfg, seed);
        let tokens = fd_tokens(&cfg, seed * 7 + 1, 10);
        let n = tokens.len() - 1;
        let (ce_scale, reg_scale) = (1.0 / n as f64, 1.0);
        let model = StltModel::new(&cfg, Arc::new(flat.clone())).unwrap();
        let out =
            row_loss_and_grad(&model, &tokens, ce_scale as f32, reg_scale as f32, None).unwrap();
        for (i, (name, spans)) in param_groups(&cfg).iter().enumerate() {
            let (err, analytic) = group_fd_rel_err(
                &cfg, &flat, &out.grad, &tokens, spans, 2000 + i as u64, ce_scale, reg_scale, None,
            );
            assert!(
                err <= 1e-3,
                "omega_zero={omega_zero} group '{name}': rel err {err:.2e} (deriv {analytic:.3e})"
            );
        }
    }
}

#[test]
fn fd_gradient_checks_adaptive_gumbel_relaxation() {
    // the training-path gate: Gumbel-sigmoid relaxation at a fixed
    // (temp, seed). The oracle replays the identical logistic samples
    // from the same Rng stream, so FD pins the relaxed-gate gradients
    // (including the 1/temp chain factor) for every parameter group.
    let cfg = grad_cfg();
    let flat = perturbed_init(&cfg, 11);
    let tokens = fd_tokens(&cfg, 42, 12);
    let n = tokens.len() - 1;
    let (ce_scale, reg_scale) = (1.0 / n as f64, 1.0);
    let noise = TrainNoise { temp: 0.75, seed: 0x5EED };
    let onoise = Some((noise.temp as f64, noise.seed));
    let model = StltModel::new(&cfg, Arc::new(flat.clone())).unwrap();
    let out = row_loss_and_grad(&model, &tokens, ce_scale as f32, reg_scale as f32, Some(noise))
        .unwrap();

    let loss = ce_scale * out.nll_sum + reg_scale * out.reg as f64;
    let oracle_loss = oracle::row_loss(&cfg, &flat, &tokens, ce_scale, reg_scale, onoise);
    assert!(
        (loss - oracle_loss).abs() < 1e-4 * (1.0 + oracle_loss.abs()),
        "relaxed loss {loss} vs oracle {oracle_loss}: Gumbel streams must line up"
    );

    for (i, (name, spans)) in param_groups(&cfg).iter().enumerate() {
        let (err, analytic) = group_fd_rel_err(
            &cfg, &flat, &out.grad, &tokens, spans, 3000 + i as u64, ce_scale, reg_scale, onoise,
        );
        assert!(
            err <= 1e-3,
            "gumbel group '{name}': FD rel err {err:.2e} (directional derivative {analytic:.3e})"
        );
    }
}

#[test]
fn fd_gradient_checks_linear_attention_mixer() {
    // the pluggable-baseline seam: linear attention trains through the
    // same tape and trait. Every live parameter group FD-pins, the
    // adaptive gate stays trainable (it gates post-φ), and the unused
    // Laplace node parameters get *exactly* zero gradient —
    // `uses_node_params() == false` must skip both the mixer backward
    // and the node-coupled Eq. Reg terms, not merely shrink them.
    let mut cfg = grad_cfg();
    cfg.mixer = "linear_attention".into();
    let flat = perturbed_init(&cfg, 13);
    let tokens = fd_tokens(&cfg, 57, 12);
    let n = tokens.len() - 1;
    let (ce_scale, reg_scale) = (1.0 / n as f64, 1.0);
    let model = StltModel::new(&cfg, Arc::new(flat.clone())).unwrap();
    let out = row_loss_and_grad(&model, &tokens, ce_scale as f32, reg_scale as f32, None).unwrap();

    let loss = ce_scale * out.nll_sum + reg_scale * out.reg as f64;
    let oracle_loss = oracle::row_loss(&cfg, &flat, &tokens, ce_scale, reg_scale, None);
    assert!(
        (loss - oracle_loss).abs() < 1e-4 * (1.0 + oracle_loss.abs()),
        "linattn loss {loss} vs oracle {oracle_loss}"
    );

    let groups = param_groups(&cfg);
    for (i, (name, spans)) in groups.iter().enumerate() {
        let (err, analytic) = group_fd_rel_err(
            &cfg, &flat, &out.grad, &tokens, spans, 4000 + i as u64, ce_scale, reg_scale, None,
        );
        assert!(
            err <= 1e-3,
            "linattn group '{name}': FD rel err {err:.2e} (deriv {analytic:.3e})"
        );
    }
    for frozen in ["sigma_raw", "omega", "t_raw"] {
        for &(off, len) in &groups[frozen] {
            for i in off..off + len {
                assert_eq!(out.grad[i], 0.0, "linattn: node param grad[{i}] ({frozen}) not zero");
            }
        }
    }
    assert!(
        groups["w_alpha"].iter().any(|&(off, len)| out.grad[off..off + len]
            .iter()
            .any(|&g| g != 0.0)),
        "linattn: adaptive gate must stay trainable"
    );
}

#[test]
fn ablation_stop_grads_zero_the_right_groups() {
    // learn_sigma/learn_omega/learn_t = false must produce exactly-zero
    // gradients for their groups (python stop_gradient semantics: the
    // model AND the Eq. Reg penalty both stop)
    for fixed in ["sigma", "omega", "t"] {
        let mut cfg = grad_cfg();
        cfg.adaptive = false;
        match fixed {
            "sigma" => cfg.learn_sigma = false,
            "omega" => cfg.learn_omega = false,
            _ => cfg.learn_t = false,
        }
        let flat = perturbed_init(&cfg, 8);
        let tokens = fd_tokens(&cfg, 9, 8);
        let model = StltModel::new(&cfg, Arc::new(flat.clone())).unwrap();
        let out = row_loss_and_grad(&model, &tokens, 0.1, 1.0, None).unwrap();
        let groups = param_groups(&cfg);
        let frozen = match fixed {
            "sigma" => "sigma_raw",
            "omega" => "omega",
            _ => "t_raw",
        };
        for &(off, n) in &groups[frozen] {
            for i in off..off + n {
                assert_eq!(out.grad[i], 0.0, "{fixed}: grad[{i}] not stopped");
            }
        }
        // a non-frozen group must still have signal
        assert!(
            groups["embed"].iter().any(|&(off, n)| out.grad[off..off + n]
                .iter()
                .any(|&g| g != 0.0)),
            "{fixed}: embedding grads vanished"
        );
    }
}

#[test]
fn checkpointed_grads_bitwise_equal_across_segment_sizes() {
    // the tentpole keystone: the segment-checkpointed backward replays
    // each segment's carry history through the mixer's own token_step,
    // so the gradient must be BITWISE identical for every segment
    // length — 1, a mid C, C±1, N, and beyond-N — and for the
    // whole-sequence default (0). The sweep covers every mixer plus the
    // adaptive gate, with the Gumbel relaxation live where adaptive
    // (the sampled gate sits on the tape, so replay may not redraw it).
    for (mixer, adaptive) in [
        ("", false),
        ("", true),
        ("reference_n2", false),
        ("linear_attention", false),
        ("linear_attention", true),
    ] {
        let mut cfg = grad_cfg();
        cfg.mixer = mixer.into();
        cfg.adaptive = adaptive;
        let noise = adaptive.then(|| TrainNoise { temp: 0.8, seed: 0xC0FFEE });
        let flat = perturbed_init(&cfg, 31);
        let tokens = fd_tokens(&cfg, 37, 12); // n = 12
        let n = tokens.len() - 1;
        let run = |seg: usize| {
            let mut c = cfg.clone();
            c.grad_ckpt_segment = seg;
            let model = StltModel::new(&c, Arc::new(flat.clone())).unwrap();
            row_loss_and_grad(&model, &tokens, 0.125, 1.0, noise).unwrap()
        };
        let base = run(0);
        for seg in [1usize, 3, 4, 5, n - 1, n, n + 7] {
            let out = run(seg);
            assert_eq!(
                out.nll_sum.to_bits(),
                base.nll_sum.to_bits(),
                "mixer={mixer:?} adaptive={adaptive} seg={seg}: nll drifted"
            );
            assert_eq!(out.reg.to_bits(), base.reg.to_bits(), "seg={seg}: reg drifted");
            for (i, (a, b)) in out.grad.iter().zip(&base.grad).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "mixer={mixer:?} adaptive={adaptive} seg={seg}: grad[{i}] {a} != full-tape {b}"
                );
            }
        }
        // the segmented tape really shrinks with C
        assert!(
            run(3).tape_bytes < base.tape_bytes,
            "mixer={mixer:?} adaptive={adaptive}: C=3 tape must undercut the whole-sequence tape"
        );
    }
}

#[test]
fn long_context_train_step_fits_checkpointed_tape_budget() {
    // the acceptance seam: a 32k-token train_step runs on the native
    // backend inside a peak-tape budget the full-tape path provably
    // exceeds, with the accounting asserted against the real
    // allocations (RowOut::tape_bytes == train::tape_bytes).
    let n: usize = 32 * 1024;
    let mut cfg = ModelConfig {
        arch: "stlt".into(),
        vocab: 9,
        d_model: 8,
        n_layers: 1,
        n_ctx: n,
        s_max: 2,
        batch: 1,
        mode: "linear".into(),
        ffn_mult: 1,
        t_init: 4.0,
        ..ModelConfig::default()
    };
    let full_bytes = stlt::train::tape_bytes(&cfg, n);
    cfg.grad_ckpt_segment = 256;
    let ckpt_bytes = stlt::train::tape_bytes(&cfg, n);
    // the budget sits where the U tape alone (n*S*d*2 floats/layer)
    // would blow it but the checkpointed tape fits with headroom
    let u_tape = 4 * n * cfg.s_max * cfg.d_model * 2 * cfg.n_layers;
    let budget = full_bytes - u_tape / 2;
    assert!(full_bytes > budget, "full tape must provably exceed the budget");
    assert!(
        ckpt_bytes < budget,
        "checkpointed tape {ckpt_bytes} must fit the budget {budget} (full {full_bytes})"
    );
    // O(C) bound: the checkpointing overhead over the fixed projection
    // tape is exactly the (C+1)-slot replay buffer plus N/C snapshots —
    // for every C here that sum stays an order of magnitude under the
    // O(N) U tape it replaces, and the total stays inside the budget
    let fixed = {
        let mut cc = cfg.clone();
        cc.grad_ckpt_segment = 1;
        stlt::train::tape_bytes(&cc, n)
            - 4 * (1 + 1) * cfg.s_max * (2 + 2 * cfg.d_model)
            - 4 * cfg.n_layers * n * cfg.s_max * (2 + 2 * cfg.d_model)
    };
    for c in [64usize, 128, 256, 512] {
        let mut cc = cfg.clone();
        cc.grad_ckpt_segment = c;
        let b = stlt::train::tape_bytes(&cc, n);
        let extra = b - fixed;
        assert!(b < budget, "C={c}: tape {b} must fit the budget {budget}");
        assert!(
            extra * 10 < u_tape,
            "C={c}: checkpoint overhead {extra} not O(C)-small vs the U tape {u_tape}"
        );
    }

    let flat = host_init(&cfg, 5);
    let mut rng = Rng::new(13);
    let tokens: Vec<i32> = (0..n + 1).map(|_| rng.below(cfg.vocab as u64) as i32).collect();

    // accounting honesty: the real per-row allocation equals tape_bytes
    let model = StltModel::new(&cfg, Arc::new(flat.clone())).unwrap();
    let out = row_loss_and_grad(&model, &tokens, 1.0 / n as f32, 1.0, None).unwrap();
    assert_eq!(
        out.tape_bytes, ckpt_bytes,
        "tape accounting must match the real allocation"
    );
    assert!(out.tape_bytes < budget);
    assert!(out.nll_sum.is_finite());

    // and the full Backend-seam contract executes the same row: a
    // 32k-context native train_step (batch 1) completes with finite loss
    let manifest = long_manifest(&cfg);
    let rt = Runtime::native().unwrap();
    let step = TrainStep::new(&rt, &manifest, "long.train").unwrap();
    assert_eq!(step.n_plus_1, n + 1);
    let mut state = TrainState::init_for(step.entry(), 5).unwrap();
    let metrics = step.run(&mut state, &tokens, 0).unwrap();
    assert!(metrics.loss.is_finite(), "32k-token native train_step must survive");
    assert_eq!(state.step, 1);
}

/// Synthesize a `train_step`-only manifest for an arbitrary config.
fn long_manifest(cfg: &ModelConfig) -> Manifest {
    let p = total_params(&trunk_layout(cfg));
    let (b, n1) = (cfg.batch, cfg.n_ctx + 1);
    let e = Entry {
        name: "long.train".to_string(),
        file: PathBuf::from("native-synthetic"),
        kind: "train_step".to_string(),
        param_count: p,
        inputs: vec![f32s(&[p]), f32s(&[p]), f32s(&[p]), i32s(&[]), i32s(&[b, n1]), i32s(&[])],
        outputs: vec![f32s(&[p]), f32s(&[p]), f32s(&[p]), f32s(&[]), f32s(&[]), f32s(&[])],
        config: cfg.clone(),
        extra: BTreeMap::new(),
        init_file: None,
        kept_inputs: (0..6).collect(),
    };
    let mut entries = BTreeMap::new();
    entries.insert(e.name.clone(), e);
    Manifest { dir: PathBuf::from("."), entries }
}

#[test]
fn data_parallel_grads_bitwise_equal_across_pool_sizes() {
    let mut cfg = grad_cfg();
    cfg.adaptive = false;
    let flat = perturbed_init(&cfg, 21);
    let model = StltModel::new(&cfg, Arc::new(flat)).unwrap();
    let (b, n1) = (6usize, 13usize);
    let mut rng = Rng::new(77);
    let tokens: Vec<i32> = (0..b * n1).map(|_| rng.below(cfg.vocab as u64) as i32).collect();
    let pool1 = ThreadPool::new(1);
    let pool4 = ThreadPool::new(4);
    let (g1, m1) = batch_loss_and_grad(&model, &tokens, b, n1, None, &pool1).unwrap();
    let (g4, m4) = batch_loss_and_grad(&model, &tokens, b, n1, None, &pool4).unwrap();
    assert_eq!(g1, g4, "row-ordered reduction must be pool-size invariant");
    assert_eq!(m1.loss.to_bits(), m4.loss.to_bits());
    assert_eq!(m1.ce.to_bits(), m4.ce.to_bits());

    // Gumbel path: each row hashes its index into the step seed, so the
    // noise stream — and with it the reduced gradient — must also be
    // independent of which worker picks the row up
    let cfg_a = grad_cfg(); // adaptive
    let flat_a = perturbed_init(&cfg_a, 22);
    let model_a = StltModel::new(&cfg_a, Arc::new(flat_a)).unwrap();
    let noise = Some(TrainNoise { temp: 0.8, seed: 99 });
    let (ga1, ma1) = batch_loss_and_grad(&model_a, &tokens, b, n1, noise, &pool1).unwrap();
    let (ga4, ma4) = batch_loss_and_grad(&model_a, &tokens, b, n1, noise, &pool4).unwrap();
    assert_eq!(ga1, ga4, "gumbel reduction must be pool-size invariant");
    assert_eq!(ma1.loss.to_bits(), ma4.loss.to_bits());
    assert_eq!(ma1.s_eff.to_bits(), ma4.s_eff.to_bits());
}

// ---------------------------------------------------------------------------
// train_lm smoke + checkpoint resume on synthesized native manifest entries
// ---------------------------------------------------------------------------

fn smoke_cfg() -> ModelConfig {
    ModelConfig {
        arch: "stlt".into(),
        vocab: 32,
        d_model: 16,
        n_layers: 2,
        n_ctx: 24,
        s_max: 8,
        batch: 4,
        mode: "linear".into(),
        ffn_mult: 2,
        total_steps: 60,
        lr: 1e-2,
        warmup: 5,
        ..ModelConfig::default()
    }
}

fn f32s(shape: &[usize]) -> TensorSpec {
    TensorSpec { dtype: stlt::runtime::DType::F32, shape: shape.to_vec() }
}

fn i32s(shape: &[usize]) -> TensorSpec {
    TensorSpec { dtype: stlt::runtime::DType::I32, shape: shape.to_vec() }
}

fn smoke_manifest(cfg: &ModelConfig) -> Manifest {
    let p = total_params(&trunk_layout(cfg));
    let (b, n1) = (cfg.batch, cfg.n_ctx + 1);
    let mk = |name: &str, kind: &str, inputs: Vec<TensorSpec>, outputs: Vec<TensorSpec>| {
        let n_inputs = inputs.len();
        Entry {
            name: name.to_string(),
            file: PathBuf::from("native-synthetic"),
            kind: kind.to_string(),
            param_count: p,
            inputs,
            outputs,
            config: cfg.clone(),
            extra: BTreeMap::new(),
            init_file: None,
            kept_inputs: (0..n_inputs).collect(),
        }
    };
    let mut entries = BTreeMap::new();
    for e in [
        mk(
            "smoke.train",
            "train_step",
            vec![f32s(&[p]), f32s(&[p]), f32s(&[p]), i32s(&[]), i32s(&[b, n1]), i32s(&[])],
            vec![f32s(&[p]), f32s(&[p]), f32s(&[p]), f32s(&[]), f32s(&[]), f32s(&[])],
        ),
        mk(
            "smoke.eval",
            "eval_step",
            vec![f32s(&[p]), i32s(&[b, n1]), f32s(&[]), i32s(&[])],
            vec![f32s(&[]), f32s(&[]), f32s(&[])],
        ),
    ] {
        entries.insert(e.name.clone(), e);
    }
    Manifest { dir: PathBuf::from("."), entries }
}

#[test]
fn native_train_lm_smoke_nll_decreases() {
    let cfg = smoke_cfg();
    let manifest = smoke_manifest(&cfg);
    let rt = Runtime::native().unwrap();
    let opts = TrainOpts {
        steps: 60,
        log_every: 10,
        eval_every: 0,
        eval_batches: 2,
        seed: 1,
        checkpoint: None,
        resume: None,
        domain: 0,
        metrics_every: 0,
    };
    let report = stlt::coordinator::train_lm(&rt, &manifest, "smoke", &opts).unwrap();
    assert_eq!(report.steps_done, 60);
    let first = report.loss_curve.first().unwrap().1;
    let last = report.loss_curve.last().unwrap().1;
    assert!(
        last < first - 0.05,
        "train NLL must decrease: first window {first:.4}, last {last:.4}"
    );
    assert!(report.final_ppl.is_finite() && report.final_ppl > 1.0);
}

#[test]
fn checkpoint_roundtrip_resumes_bit_identically() {
    let cfg = smoke_cfg();
    let manifest = smoke_manifest(&cfg);
    let dir = std::env::temp_dir().join("stlt_native_train_test");
    let _ = std::fs::create_dir_all(&dir);
    let full = dir.join("full.ckpt");
    let half = dir.join("half.ckpt");
    let resumed = dir.join("resumed.ckpt");

    let run = |steps: u64, ckpt: &std::path::Path, resume: Option<&std::path::Path>| {
        let rt = Runtime::native().unwrap();
        let opts = TrainOpts {
            steps,
            log_every: 100,
            eval_every: 0,
            eval_batches: 1,
            seed: 3,
            checkpoint: Some(ckpt.to_string_lossy().into_owned()),
            resume: resume.map(|r| r.to_string_lossy().into_owned()),
            domain: 0,
            metrics_every: 0,
        };
        stlt::coordinator::train_lm(&rt, &manifest, "smoke", &opts).unwrap();
    };
    run(12, &full, None);
    run(6, &half, None);
    run(12, &resumed, Some(&half));

    let (a, meta_a) = load_checkpoint_meta(&full).unwrap();
    let (c, meta_c) = load_checkpoint_meta(&resumed).unwrap();
    let meta_a = meta_a.unwrap();
    assert_eq!(meta_a.artifact, "smoke");
    assert_eq!(meta_a.train_stream, Some((3, 0)));
    assert_eq!(meta_c.unwrap().artifact, "smoke");
    assert_eq!(a.step, 12);
    assert_eq!(c.step, 12);
    assert_eq!(a.flat, c.flat, "resumed params must be bit-identical");
    assert_eq!(a.m, c.m, "resumed first moment must be bit-identical");
    assert_eq!(a.v, c.v, "resumed second moment must be bit-identical");

    // resuming with a different seed would train on a different batch
    // stream — the recorded (seed, domain) must make that a hard error
    let rt = Runtime::native().unwrap();
    let opts = TrainOpts {
        steps: 12,
        eval_every: 0,
        seed: 99,
        resume: Some(half.to_string_lossy().into_owned()),
        ..Default::default()
    };
    let err = format!(
        "{:#}",
        stlt::coordinator::train_lm(&rt, &manifest, "smoke", &opts).unwrap_err()
    );
    assert!(err.contains("--seed 3"), "unhelpful resume-mismatch error: {err}");
}

#[test]
fn train_step_entry_runs_through_typed_runtime() {
    // the Backend-seam contract: TrainStep::run drives the native
    // train_step exactly like the XLA artifact
    let cfg = smoke_cfg();
    let manifest = smoke_manifest(&cfg);
    let rt = Runtime::native().unwrap();
    let step = TrainStep::new(&rt, &manifest, "smoke.train").unwrap();
    assert_eq!(step.batch, cfg.batch);
    assert_eq!(step.n_plus_1, cfg.n_ctx + 1);
    let mut state = TrainState::init_for(step.entry(), 0).unwrap();
    let before = state.flat.clone();
    let mut rng = Rng::new(5);
    let tokens: Vec<i32> = (0..step.batch * step.n_plus_1)
        .map(|_| rng.below(cfg.vocab as u64) as i32)
        .collect();
    let m0 = step.run(&mut state, &tokens, 0).unwrap();
    assert!(m0.loss.is_finite() && m0.ce.is_finite());
    assert!((m0.s_eff - cfg.s_max as f32).abs() < 1e-4, "non-adaptive s_eff == S");
    assert_eq!(state.step, 1);
    // step 0 is inside warmup with lr 0 -> params unchanged; moments move
    assert_eq!(state.flat, before, "warmup step 0 has lr=0");
    assert!(state.m.iter().any(|&x| x != 0.0));
    let m1 = step.run(&mut state, &tokens, 1).unwrap();
    assert!(m1.loss.is_finite());
    assert_ne!(state.flat, before, "params must move once lr > 0");
}

#[test]
fn committed_manifest_param_counts_match_layout() {
    // drift check for the checked-in native metadata manifest
    let dir = stlt::runtime::default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        return; // repo layout not available (e.g. packaged test run)
    }
    let manifest = Manifest::load(&dir).unwrap();
    let mut checked = 0;
    for entry in manifest.entries.values() {
        if entry.config.arch != "stlt" {
            continue;
        }
        let p = total_params(&trunk_layout(&entry.config));
        assert_eq!(
            p, entry.param_count,
            "{}: manifest param_count {} != layout {}",
            entry.name, entry.param_count, p
        );
        checked += 1;
    }
    assert!(checked >= 2, "expected stlt entries in the committed manifest");
}
