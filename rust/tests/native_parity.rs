//! Native-backend integration: the pure-Rust execution path against a
//! naive O(N^2) relevance-matrix reference, plus the full serving stack
//! (queue -> batcher -> model thread) running on `BackendKind::Native`
//! with zero external dependencies — no artifacts, no XLA, no Python.
//!
//! Entries are synthesized in-memory: the native backend only consumes
//! the manifest *metadata* (config + shapes), never the HLO text.
#![cfg(feature = "native")]

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;

use stlt::coordinator::{Server, ServerOpts};
use stlt::runtime::artifact::{Entry, ModelConfig, TensorSpec};
use stlt::runtime::native_stlt::{host_init, nll_of, StltModel};
use stlt::runtime::{BackendKind, DecodeStep, EvalStep, Manifest, Runtime, StreamStep};

const S: usize = 4;
const D: usize = 8;
const LAYERS: usize = 2;
const VOCAB: usize = 19;
const CHUNK: usize = 8;
const BSRV: usize = 2;

fn cfg() -> ModelConfig {
    ModelConfig {
        arch: "stlt".into(),
        vocab: VOCAB,
        d_model: D,
        n_layers: LAYERS,
        n_ctx: 32,
        s_max: S,
        batch: 2,
        mode: "linear".into(),
        ..ModelConfig::default()
    }
}

fn f32s(shape: &[usize]) -> TensorSpec {
    TensorSpec { dtype: stlt::runtime::DType::F32, shape: shape.to_vec() }
}

fn i32s(shape: &[usize]) -> TensorSpec {
    TensorSpec { dtype: stlt::runtime::DType::I32, shape: shape.to_vec() }
}

fn entry(
    name: &str,
    kind: &str,
    p: usize,
    inputs: Vec<TensorSpec>,
    outputs: Vec<TensorSpec>,
    extra: &[(&str, i64)],
) -> Entry {
    Entry::synthetic(name, kind, cfg(), p, inputs, outputs, extra)
}

/// Synthesize the manifest entries the runtime/server need for base
/// "nat" (the serving kinds come from the shared per-kind builders).
fn manifest_for(c: &ModelConfig, p: usize) -> Manifest {
    let mut entries = BTreeMap::new();
    for e in [
        Entry::synthetic(
            "nat.eval",
            "eval_step",
            c.clone(),
            p,
            vec![f32s(&[p]), i32s(&[2, 17]), f32s(&[]), i32s(&[])],
            vec![f32s(&[]), f32s(&[]), f32s(&[])],
            &[],
        ),
        Entry::synthetic_stream(c, p, "nat.stream", CHUNK),
        Entry::synthetic_decode(c, p, "nat.decode"),
        Entry::synthetic_stream_batch(c, p, "nat.stream_batch", CHUNK, BSRV),
    ] {
        entries.insert(e.name.clone(), e);
    }
    Manifest { dir: PathBuf::from("."), entries }
}

fn manifest(p: usize) -> Manifest {
    manifest_for(&cfg(), p)
}

fn doc(len: usize, seed: u64) -> Vec<i32> {
    let mut rng = stlt::util::rng::Rng::new(seed);
    (0..len).map(|_| rng.below(VOCAB as u64) as i32).collect()
}

fn reference_nll(flat: &[f32], tokens: &[i32]) -> f64 {
    // naive O(N^2 S d) relevance-matrix oracle, selected through the
    // same config key the CLI's --mixer flag sets
    let mut c = cfg();
    c.mixer = "reference_n2".into();
    let model = StltModel::new(&c, Arc::new(flat.to_vec())).unwrap();
    let n = tokens.len() - 1;
    let logits = model.forward_logits(&tokens[..n]).unwrap();
    (0..n)
        .map(|t| nll_of(&logits[t * VOCAB..(t + 1) * VOCAB], tokens[t + 1]).unwrap())
        .sum()
}

#[test]
fn stream_and_decode_match_n2_reference_nll() {
    // the satellite parity seam: NativeBackend stream + decode NLL vs
    // the O(N^2) reference on a tiny config. 16 tokens = 2 full chunks,
    // so no padding pollutes the carries.
    let c = cfg();
    let flat = host_init(&c, 42);
    let m = manifest(flat.len());
    let tokens = doc(17, 7); // 16 transitions
    let want = reference_nll(&flat, &tokens);

    let rt = Runtime::native().unwrap();

    // streaming path: two chunks of 8 through the stream_step entry
    let stream = StreamStep::new(&rt, &m, "nat.stream").unwrap();
    let mut carry = stream.zero_carry();
    let (mut nll_s, mut cnt_s) = (0.0f64, 0.0f64);
    for chunk in 0..2 {
        let off = chunk * CHUNK;
        let toks: Vec<i32> = tokens[off..off + CHUNK].to_vec();
        let tgts: Vec<i32> = tokens[off + 1..off + CHUNK + 1].to_vec();
        let mask = vec![1.0f32; CHUNK];
        let (n, ct) = stream.run(&flat, &mut carry, &toks, &tgts, &mask).unwrap();
        nll_s += n;
        cnt_s += ct;
    }
    assert_eq!(cnt_s, 16.0);
    assert!(
        (nll_s - want).abs() < 1e-3 * (1.0 + want.abs()),
        "stream nll {nll_s} vs reference {want}"
    );

    // decode path: token-by-token with the same carries
    let decode = DecodeStep::new(&rt, &m, "nat.decode").unwrap();
    let mut carry = decode.zero_carry();
    let mut nll_d = 0.0f64;
    for t in 0..16 {
        let logits = decode.run(&flat, &mut carry, tokens[t]).unwrap();
        nll_d += nll_of(&logits, tokens[t + 1]).unwrap();
    }
    assert!(
        (nll_d - want).abs() < 1e-3 * (1.0 + want.abs()),
        "decode nll {nll_d} vs reference {want}"
    );
}

#[test]
fn chunked_stream_matches_whole_sequence_nll_at_8k() {
    // long-context satellite seam: at N = 8192 the chunked-stream NLL
    // must match the whole-sequence NLL to f64 summation-order noise.
    // Per the PR-3 kernel guarantees the chunked logits are *bitwise*
    // the whole-sequence logits, so the only remaining difference is
    // the order the per-position f64 NLL terms are associated in — the
    // sum-order test pinning the f64 accumulation fix (an f32 running
    // sum re-associates with per-add error ~total·2⁻²⁴ and lands orders
    // of magnitude above the 1e-9 bar at this length).
    let c = cfg();
    let flat = host_init(&c, 23);
    let model = StltModel::new(&c, Arc::new(flat)).unwrap();
    let n = 8192usize;
    let tokens = doc(n + 1, 51);

    let (whole_nll, count, _) = model.eval_row(&tokens, 0.0, 0).unwrap();
    assert_eq!(count, n as f64);

    for chunk in [512usize, 1024] {
        let (mut l, mut u) = model.zero_carry();
        let mut nll = 0.0f64;
        for t0 in (0..n).step_by(chunk) {
            let t1 = (t0 + chunk).min(n);
            let (logits, _) = model.trunk_chunk(&mut l, &mut u, &tokens[t0..t1], 0.0, None).unwrap();
            for (j, t) in (t0..t1).enumerate() {
                nll += nll_of(&logits[j * VOCAB..(j + 1) * VOCAB], tokens[t + 1]).unwrap();
            }
        }
        let rel = (nll - whole_nll).abs() / whole_nll.abs().max(1.0);
        assert!(
            rel < 1e-9,
            "chunk={chunk}: stream nll {nll} vs whole {whole_nll} (rel {rel:.3e})"
        );
    }
}

#[test]
fn eval_step_runs_natively_and_is_near_uniform() {
    let c = cfg();
    let flat = host_init(&c, 3);
    let m = manifest(flat.len());
    let rt = Runtime::new(BackendKind::Native).unwrap();
    assert_eq!(rt.platform(), "native");
    let eval = EvalStep::new(&rt, &m, "nat.eval").unwrap();
    let toks = doc(eval.batch * eval.n_plus_1, 11);
    let (nll, count, _seff) = eval.run(&flat, &toks, 0.0, 0).unwrap();
    assert_eq!(count, (eval.batch * (eval.n_plus_1 - 1)) as f64);
    let ppl = stlt::metrics::perplexity(nll, count);
    let v = VOCAB as f64;
    assert!(ppl > 0.5 * v && ppl < 2.0 * v, "untrained ppl {ppl} vs vocab {v}");

    // hot path with a pre-uploaded native parameter buffer agrees
    let params = eval.upload(&flat).unwrap();
    let (nll_h, count_h, _) = eval.run_h(&params, &toks, 0.0, 0).unwrap();
    assert_eq!(nll, nll_h);
    assert_eq!(count, count_h);
}

#[test]
fn native_server_matches_direct_engine_end_to_end() {
    // full stack: queue -> batcher -> model thread -> stream_batch/decode
    // execs on the native backend, vs the engine called directly.
    let c = cfg();
    let flat = host_init(&c, 9);
    let m = manifest(flat.len());
    // 97 tokens: 96 transitions = 12 exact chunks of 8 (no padding), so
    // the batched server NLL must equal the single-pass engine NLL.
    let prompt = doc(97, 21);
    let model = StltModel::new(&c, Arc::new(flat.clone())).unwrap();
    let n = prompt.len() - 1;
    let logits = model.forward_logits(&prompt[..n]).unwrap();
    let want_nll: f64 = (0..n)
        .map(|t| nll_of(&logits[t * VOCAB..(t + 1) * VOCAB], prompt[t + 1]).unwrap())
        .sum();

    let server = Server::start(&m, "nat", flat.clone(), ServerOpts::default()).unwrap();
    let r = server.feed(1, prompt.clone(), true).unwrap();
    assert_eq!(r.count, n as f64, "server must count every transition");
    assert!(
        (r.nll_sum - want_nll).abs() < 1e-3 * (1.0 + want_nll.abs()),
        "server nll {} vs engine {want_nll}",
        r.nll_sum
    );

    // greedy generation through the server == greedy decode on the engine
    let gen_len = 12;
    let g = server.generate(1, prompt[n], gen_len, None).unwrap();
    assert_eq!(g.tokens.len(), gen_len);

    let (mut l, mut u) = model.zero_carry();
    model.trunk_chunk(&mut l, &mut u, &prompt[..n], 0.0, None).unwrap();
    let mut tok = prompt[n];
    let mut want_tokens = Vec::new();
    for _ in 0..gen_len {
        let (lg, _) = model.trunk_chunk(&mut l, &mut u, &[tok], 0.0, None).unwrap();
        tok = stlt::metrics::argmax(&lg[lg.len() - VOCAB..]) as i32;
        want_tokens.push(tok);
    }
    assert_eq!(g.tokens, want_tokens, "server generation must match the engine");

    // a second identical session reproduces exactly
    let r2 = server.feed(2, prompt.clone(), true).unwrap();
    assert_eq!(r2.nll_sum, r.nll_sum);
    let g2 = server.generate(2, prompt[n], gen_len, None).unwrap();
    assert_eq!(g2.tokens, g.tokens);
    server.shutdown();
}

#[test]
fn adaptive_and_linattn_serving_match_direct_engine() {
    // mixer-seam integration: the full server stack (chunked feed waves
    // + batched decode) over an adaptive-gate model and over the
    // linear-attention baseline reproduces the direct engine. Chunked
    // logits are bitwise the whole-sequence logits (pinned at the
    // engine level), so greedy generation must match token-for-token
    // and the NLL differs only by f64 summation association.
    for (mixer, adaptive) in
        [("recurrence", true), ("linear_attention", false), ("linear_attention", true)]
    {
        let mut c = cfg();
        c.adaptive = adaptive;
        c.mixer = mixer.into();
        let flat = host_init(&c, 13);
        let m = manifest_for(&c, flat.len());
        let prompt = doc(97, 33); // 96 transitions = 12 exact chunks
        let model = StltModel::new(&c, Arc::new(flat.clone())).unwrap();
        let n = prompt.len() - 1;
        let logits = model.forward_logits(&prompt[..n]).unwrap();
        let want_nll: f64 = (0..n)
            .map(|t| nll_of(&logits[t * VOCAB..(t + 1) * VOCAB], prompt[t + 1]).unwrap())
            .sum();

        let server = Server::start(&m, "nat", flat.clone(), ServerOpts::default()).unwrap();
        let r = server.feed(1, prompt.clone(), true).unwrap();
        assert_eq!(r.count, n as f64, "mixer={mixer} adaptive={adaptive}");
        let rel = (r.nll_sum - want_nll).abs() / want_nll.abs().max(1.0);
        assert!(
            rel < 1e-12,
            "mixer={mixer} adaptive={adaptive}: server nll {} vs engine {want_nll} (rel {rel:.3e})",
            r.nll_sum
        );

        let gen_len = 12;
        let g = server.generate(1, prompt[n], gen_len, None).unwrap();
        let (mut l, mut u) = model.zero_carry();
        model.trunk_chunk(&mut l, &mut u, &prompt[..n], 0.0, None).unwrap();
        let mut tok = prompt[n];
        let mut want_tokens = Vec::new();
        for _ in 0..gen_len {
            let (lg, _) = model.trunk_chunk(&mut l, &mut u, &[tok], 0.0, None).unwrap();
            tok = stlt::metrics::argmax(&lg[lg.len() - VOCAB..]) as i32;
            want_tokens.push(tok);
        }
        assert_eq!(
            g.tokens, want_tokens,
            "mixer={mixer} adaptive={adaptive}: server generation must match the engine"
        );
        server.shutdown();
    }
}

#[test]
fn unsupported_kinds_and_arches_fail_cleanly() {
    let c = cfg();
    let flat = host_init(&c, 1);
    let p = flat.len();
    let rt = Runtime::native().unwrap();
    // seq2seq training is xla-only
    let s2s = entry("nat.s2s", "s2s_train_step", p, vec![f32s(&[p])], vec![], &[]);
    let err = format!("{:#}", rt.run(&s2s, &[stlt::runtime::Tensor::f32(flat, &[p])]).unwrap_err());
    assert!(err.contains("native"), "unhelpful error: {err}");
    // baseline arches are xla-only
    let mut fwd = entry("van.fwd", "forward", 4, vec![f32s(&[4]), i32s(&[1, 4])], vec![], &[]);
    fwd.config.arch = "vanilla".into();
    let err = format!(
        "{:#}",
        rt.run(
            &fwd,
            &[
                stlt::runtime::Tensor::f32(vec![0.0; 4], &[4]),
                stlt::runtime::Tensor::i32(vec![0; 4], &[1, 4]),
            ],
        )
        .unwrap_err()
    );
    assert!(err.contains("stlt"), "unhelpful error: {err}");
}

#[cfg(not(feature = "xla"))]
#[test]
fn xla_backend_unavailable_without_feature() {
    let err = format!("{:#}", Runtime::new(BackendKind::Xla).unwrap_err());
    assert!(err.contains("xla"), "unhelpful error: {err}");
}
