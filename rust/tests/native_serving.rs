//! Continuous-batching serving integration (native backend, zero
//! external deps): concurrent session handles + token streams against
//! the sequential reference, cancellation mid-generate, LRU eviction
//! surfacing, first-token-before-completion, and the batched
//! `decode_batch` padding/masking bitwise-parity seam.

#![cfg(feature = "native")]

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use stlt::coordinator::{FinishReason, GenOpts, Sampling, Server, ServerOpts};
use stlt::runtime::artifact::{Entry, ModelConfig};
use stlt::runtime::native_stlt::host_init;
use stlt::runtime::{
    BackendKind, BatchedDecodeStep, DecodeStep, Manifest, Runtime, StreamStep,
};

const S: usize = 4;
const D: usize = 8;
const LAYERS: usize = 2;
const VOCAB: usize = 19;
const CHUNK: usize = 8;
const BSRV: usize = 4;

fn cfg() -> ModelConfig {
    ModelConfig {
        arch: "stlt".into(),
        vocab: VOCAB,
        d_model: D,
        n_layers: LAYERS,
        n_ctx: 32,
        s_max: S,
        batch: 2,
        mode: "linear".into(),
        ..ModelConfig::default()
    }
}

/// Synthesize the manifest entries the server needs for base "nat"
/// (shared per-kind builders keep the schemas in one place).
fn manifest(p: usize) -> Manifest {
    let c = cfg();
    let mut entries = BTreeMap::new();
    for e in [
        Entry::synthetic_stream(&c, p, "nat.stream", CHUNK),
        Entry::synthetic_decode(&c, p, "nat.decode"),
        Entry::synthetic_stream_batch(&c, p, "nat.stream_batch", CHUNK, BSRV),
    ] {
        entries.insert(e.name.clone(), e);
    }
    Manifest { dir: PathBuf::from("."), entries }
}

fn doc(len: usize, seed: u64) -> Vec<i32> {
    let mut rng = stlt::util::rng::Rng::new(seed);
    (0..len).map(|_| rng.below(VOCAB as u64) as i32).collect()
}

/// One client's conversation: feed, generate, feed more, generate —
/// returns everything observable so two runs can be compared bitwise.
/// Uses the session-id API with an explicit id (the sampling RNG is
/// seeded with `rng_seed ^ session`, so ids must match across the
/// sequential and concurrent runs for a bitwise comparison).
fn converse(server: &Server, seed: u64) -> (f64, f64, Vec<i32>, f64, Vec<i32>) {
    let session = 1000 + seed;
    let prompt = doc(41 + (seed % 3) as usize * 7, 100 + seed);
    let fr1 = server.feed(session, prompt.clone(), true).unwrap();
    let g1 = server
        .start_generate(
            session,
            GenOpts {
                seed_token: *prompt.last().unwrap(),
                max_tokens: 8,
                sampling: Sampling::Temperature(1.3),
                rng_seed: 7,
                ..Default::default()
            },
        )
        .unwrap()
        .wait()
        .unwrap();
    let more = doc(23, 500 + seed);
    let fr2 = server.feed(session, more.clone(), true).unwrap();
    let g2 = server
        .generate(session, *more.last().unwrap(), 6, None)
        .unwrap();
    assert_eq!(g1.tokens.len(), 8);
    assert_eq!(g1.reason, FinishReason::MaxTokens);
    assert!(!g1.fresh_carry, "fed session must resume its context");
    (fr1.nll_sum, fr1.count, g1.tokens, fr2.nll_sum, g2.tokens)
}

#[test]
fn concurrent_interleaved_serving_bitwise_matches_sequential() {
    // the tentpole e2e seam: N client threads with interleaved feeds +
    // generates through the continuous-batching scheduler produce
    // BITWISE the outputs of the same conversations run one at a time.
    let c = cfg();
    let flat = host_init(&c, 42);
    let m = manifest(flat.len());

    // sequential reference: one conversation at a time
    let server = Server::start(&m, "nat", flat.clone(), ServerOpts::default()).unwrap();
    let reference: Vec<_> = (0..6u64).map(|s| converse(&server, s)).collect();
    server.shutdown();

    // concurrent: 6 client threads (wave width BSRV=4, so rotation and
    // mid-flight admission are exercised)
    let server = Arc::new(Server::start(&m, "nat", flat, ServerOpts::default()).unwrap());
    let mut handles = Vec::new();
    for s in 0..6u64 {
        let server = Arc::clone(&server);
        handles.push(std::thread::spawn(move || (s, converse(&server, s))));
    }
    for h in handles {
        let (s, got) = h.join().unwrap();
        let want = &reference[s as usize];
        assert_eq!(got.0.to_bits(), want.0.to_bits(), "session {s} feed-1 nll");
        assert_eq!(got.1, want.1, "session {s} feed-1 count");
        assert_eq!(got.2, want.2, "session {s} generation 1");
        assert_eq!(got.3.to_bits(), want.3.to_bits(), "session {s} feed-2 nll");
        assert_eq!(got.4, want.4, "session {s} generation 2");
    }
    assert_eq!(server.stats.gens.get(), 12);
    assert_eq!(server.stats.feeds.get(), 12);
    // continuous batching actually batched: some wave held > 1 row
    let max_fill = server.stats.wave_max_fill.get() as usize;
    assert!(max_fill > 1, "no wave ever batched (max fill {max_fill})");
    assert!(server.stats.waves.get() > 0 && server.stats.wave_mean_fill() >= 1.0);
}

#[test]
fn cancellation_mid_generate() {
    let c = cfg();
    let flat = host_init(&c, 9);
    let m = manifest(flat.len());
    let server = Server::start(&m, "nat", flat, ServerOpts::default()).unwrap();
    let h = server.open_session();
    let prompt = doc(33, 3);
    h.feed(prompt.clone(), false).unwrap();
    let mut stream = h
        .generate(GenOpts {
            seed_token: *prompt.last().unwrap(),
            max_tokens: 1_000_000, // would run ~forever without cancel
            ..Default::default()
        })
        .unwrap();
    let mut got = Vec::new();
    for _ in 0..3 {
        got.push(stream.recv().unwrap().unwrap());
    }
    h.cancel().unwrap();
    // drain the remainder; the stream must terminate promptly
    for t in stream.by_ref() {
        got.push(t.unwrap());
    }
    assert_eq!(stream.finish_reason(), Some(FinishReason::Cancelled));
    assert!(
        got.len() < 1_000_000,
        "cancel must stop the generation (got {} tokens)",
        got.len()
    );
    assert!(server.stats.cancelled.get() >= 1);
    // the session survives cancellation: a follow-up generation works
    // and resumes the same carry state
    let g = h
        .generate_blocking(GenOpts {
            seed_token: *prompt.last().unwrap(),
            max_tokens: 4,
            ..Default::default()
        })
        .unwrap();
    assert_eq!(g.tokens.len(), 4);
    assert!(!g.fresh_carry, "cancelled session must keep its state");
    server.shutdown();
}

#[test]
fn dropping_the_stream_cancels_implicitly() {
    let c = cfg();
    let flat = host_init(&c, 11);
    let m = manifest(flat.len());
    let server = Server::start(&m, "nat", flat, ServerOpts::default()).unwrap();
    let h = server.open_session();
    h.feed(doc(20, 1), false).unwrap();
    let mut stream = h
        .generate(GenOpts { seed_token: 1, max_tokens: 1_000_000, ..Default::default() })
        .unwrap();
    let _ = stream.recv().unwrap().unwrap();
    drop(stream); // client walks away
    // the scheduler notices the dead channel at the next token send and
    // finishes the task (implicit cancel); poll until it has, since the
    // drop itself carries no message
    let t0 = Instant::now();
    while server.stats.cancelled.get() < 1 {
        assert!(
            t0.elapsed() < std::time::Duration::from_secs(10),
            "dropped stream never cancelled the generation"
        );
        std::thread::yield_now();
    }
    // a new generation on the session then works
    let g = h
        .generate_blocking(GenOpts { seed_token: 1, max_tokens: 3, ..Default::default() })
        .unwrap();
    assert_eq!(g.tokens.len(), 3);
    server.shutdown();
}

#[test]
fn eviction_is_surfaced_on_the_generate_path() {
    // the silent-eviction satellite seam: a client whose session was
    // LRU-evicted used to get logits from a zero carry with no signal.
    let c = cfg();
    let flat = host_init(&c, 5);
    let m = manifest(flat.len());
    let opts = ServerOpts { max_sessions: 2, ..ServerOpts::default() };
    let server = Server::start(&m, "nat", flat, opts).unwrap();
    server.feed(1, doc(30, 1), false).unwrap();
    server.feed(2, doc(30, 2), false).unwrap();
    let fr3 = server.feed(3, doc(30, 3), false).unwrap();
    assert_eq!(fr3.evicted, Some(1), "feed path reports the LRU victim");
    // session 1's state is gone; generating on it must say so
    let g = server.generate(1, 4, 5, None).unwrap();
    assert_eq!(g.tokens.len(), 5);
    assert!(g.fresh_carry, "evicted session restarted from a zero carry with no signal");
    assert_eq!(g.evicted, Some(2), "re-admission evicted the current LRU");
    // a resident session reports resumed context
    let g3 = server.generate(3, 4, 5, None).unwrap();
    assert!(!g3.fresh_carry);
    assert!(server.stats.evictions.get() >= 2);
    server.shutdown();
}

#[test]
fn eviction_under_concurrent_load_stays_correct() {
    let c = cfg();
    let flat = host_init(&c, 23);
    let m = manifest(flat.len());
    let opts = ServerOpts { max_sessions: 2, queue_cap: 64, ..ServerOpts::default() };
    let server = Arc::new(Server::start(&m, "nat", flat, opts).unwrap());
    let mut handles = Vec::new();
    for s in 0..6u64 {
        let server = Arc::clone(&server);
        handles.push(std::thread::spawn(move || {
            let d = doc(40, 70 + s);
            server.feed(100 + s, d.clone(), true).map(|r| (r, d.len()))
        }));
    }
    for h in handles {
        let (r, len) = h.join().unwrap().unwrap();
        assert_eq!(r.count, (len - 1) as f64, "every feed streams fully despite eviction");
    }
    assert!(
        server.stats.evictions.get() >= 4,
        "6 sessions through 2 slots must evict"
    );
}

#[test]
fn first_token_arrives_before_the_completion_finishes() {
    // acceptance seam: TokenStream must deliver token 1 while the rest
    // of the completion is still being decoded — not after the whole
    // generation like the old blocking GenResult.
    let c = cfg();
    let flat = host_init(&c, 31);
    let m = manifest(flat.len());
    let server = Server::start(&m, "nat", flat, ServerOpts::default()).unwrap();
    let h = server.open_session();
    let prompt = doc(25, 8);
    h.feed(prompt.clone(), false).unwrap();
    let t0 = Instant::now();
    let mut stream = h
        .generate(GenOpts {
            seed_token: *prompt.last().unwrap(),
            max_tokens: 64,
            ..Default::default()
        })
        .unwrap();
    let first = stream.recv().unwrap().unwrap();
    let t_first = t0.elapsed();
    assert!((0..VOCAB as i32).contains(&first));
    assert!(stream.finish_reason().is_none(), "stream still live after the first token");
    let rest: Vec<i32> = stream.by_ref().map(|t| t.unwrap()).collect();
    let t_done = t0.elapsed();
    assert_eq!(rest.len(), 63, "remaining tokens still stream after the first");
    assert_eq!(stream.finish_reason(), Some(FinishReason::MaxTokens));
    assert!(
        t_first < t_done,
        "first token ({t_first:?}) must land before completion ({t_done:?})"
    );
    let ttft_recorded = server.stats.ttft_latency.summary();
    assert!(!ttft_recorded.is_empty());
    server.shutdown();
}

#[test]
fn decode_batch_padding_masking_parity_is_bitwise() {
    // acceptance seam: the batched decode_batch path must produce
    // logits (and carries) BITWISE identical to single-session decode
    // for every row, under ragged padding.
    let c = cfg();
    let flat = host_init(&c, 77);
    let m = manifest(flat.len());
    let rt = Runtime::new(BackendKind::Native).unwrap();
    assert!(rt.supports_kind("decode_batch"));

    // distinct warmed carries via the single-session stream path
    let stream = StreamStep::new(&rt, &m, "nat.stream").unwrap();
    let decode = DecodeStep::new(&rt, &m, "nat.decode").unwrap();
    let batch = BatchedDecodeStep::from_decode(m.get("nat.decode").unwrap(), BSRV).unwrap();
    assert_eq!(batch.batch, BSRV);
    assert_eq!(batch.vocab, VOCAB);
    for rows in 1..=3usize {
        // ragged: `rows` real sessions, BSRV - rows padding rows
        let mut carries = Vec::new();
        for r in 0..rows {
            let mut carry = stream.zero_carry();
            let d = doc(CHUNK + 1, 40 + r as u64);
            let toks: Vec<i32> = d[..CHUNK].to_vec();
            let tgts: Vec<i32> = d[1..=CHUNK].to_vec();
            stream.run(&flat, &mut carry, &toks, &tgts, &[1.0; CHUNK]).unwrap();
            carries.push(carry);
        }
        let tokens: Vec<i32> = (0..rows as i32).map(|r| (r * 5 + 2) % VOCAB as i32).collect();
        // reference: each row through the single-session decode_step
        let mut ref_carries = carries.clone();
        let mut ref_logits = Vec::new();
        for (cr, &tok) in ref_carries.iter_mut().zip(&tokens) {
            ref_logits.push(decode.run(&flat, cr, tok).unwrap());
        }
        // batched, with padding rows
        let params = decode.upload(&flat).unwrap();
        let mut row_refs: Vec<&mut stlt::runtime::StreamCarry> = carries.iter_mut().collect();
        let logits = batch.run_h(&rt, &params, &mut row_refs, &tokens).unwrap();
        assert_eq!(logits.len(), rows);
        for r in 0..rows {
            assert_eq!(logits[r], ref_logits[r], "row {r}/{rows} logits diverge");
            assert_eq!(carries[r].l, ref_carries[r].l, "row {r}/{rows} L carry diverges");
            assert_eq!(carries[r].u, ref_carries[r].u, "row {r}/{rows} U carry diverges");
        }
    }
}

#[test]
fn export_import_resumes_bitwise_across_server_instances() {
    // the client-side resume seam: export a session's carry, bring it
    // to a *different* server instance (same weights), and continue —
    // NLL bits and sampled tokens match a session that never moved.
    let c = cfg();
    let flat = host_init(&c, 63);
    let m = manifest(flat.len());
    let id = 4242u64;
    let prompt = doc(37, 21);
    let more = doc(19, 22);
    let opts = GenOpts {
        seed_token: *more.last().unwrap(),
        max_tokens: 6,
        sampling: Sampling::Temperature(1.1),
        rng_seed: 3,
        ..Default::default()
    };

    // reference: one continuous session, one server
    let reference = Server::start(&m, "nat", flat.clone(), ServerOpts::default()).unwrap();
    let r1 = reference.feed(id, prompt.clone(), true).unwrap();
    let r2 = reference.feed(id, more.clone(), true).unwrap();
    let rg = reference.start_generate(id, opts.clone()).unwrap().wait().unwrap();
    reference.shutdown();

    // server A: first half of the conversation, then export
    let a = Server::start(&m, "nat", flat.clone(), ServerOpts::default()).unwrap();
    let a1 = a.feed(id, prompt.clone(), true).unwrap();
    assert_eq!(a1.nll_sum.to_bits(), r1.nll_sum.to_bits());
    let snap = a.export_carry(id).unwrap();
    assert!(snap.tokens_seen > 0, "snapshot must carry the token clock");
    assert!(snap.state_bytes() > 0);
    a.shutdown();

    // server B: import under the SAME id (the generation RNG is seeded
    // rng_seed ^ session, so the id is part of the session's identity),
    // then the second half
    let b = Server::start(&m, "nat", flat, ServerOpts::default()).unwrap();
    assert_eq!(b.import_carry(id, snap.clone()).unwrap(), None);
    let b2 = b.feed(id, more, true).unwrap();
    assert_eq!(b2.nll_sum.to_bits(), r2.nll_sum.to_bits(), "resumed feed diverged");
    assert_eq!(b2.count, r2.count);
    let bg = b.start_generate(id, opts).unwrap().wait().unwrap();
    assert!(!bg.fresh_carry, "imported session must resume, not restart");
    assert_eq!(bg.tokens, rg.tokens, "resumed generation diverged");

    // checkout safety: export refuses while a generation holds the carry
    let h = b.open_session();
    h.feed(doc(20, 23), false).unwrap();
    let mut stream = h
        .generate(GenOpts { seed_token: 1, max_tokens: 500_000, ..Default::default() })
        .unwrap();
    // first token ⇒ the carry is checked out, not merely queued
    stream.recv().unwrap().unwrap();
    let err = h.export_carry().unwrap_err();
    assert!(format!("{err:#}").contains("export"), "unhelpful error: {err:#}");
    h.cancel().unwrap();
    let r = stream.wait().unwrap();
    assert_eq!(r.reason, FinishReason::Cancelled);
    // and once the generation is gone, the handle-level seam round-trips
    let snap2 = h.export_carry().unwrap();
    assert_eq!(h.import_carry(snap2).unwrap(), None);
    b.shutdown();
}

#[test]
fn session_handle_lifecycle_and_conflicts() {
    let c = cfg();
    let flat = host_init(&c, 55);
    let m = manifest(flat.len());
    let server = Server::start(&m, "nat", flat, ServerOpts::default()).unwrap();
    let h1 = server.open_session();
    let h2 = server.open_session();
    assert_ne!(h1.id(), h2.id(), "handles get distinct sessions");
    assert!(h1.id() >= 1 << 32, "handle ids never collide with hand-picked ids");

    h1.feed(doc(20, 1), false).unwrap();
    // a second generation on the same session while one is in flight
    // is rejected through its own stream
    let s1 = h1
        .generate(GenOpts { seed_token: 1, max_tokens: 200_000, ..Default::default() })
        .unwrap();
    let err = h1
        .generate(GenOpts { seed_token: 1, max_tokens: 4, ..Default::default() })
        .unwrap()
        .wait()
        .unwrap_err();
    assert!(format!("{err:#}").contains("in flight"), "unhelpful error: {err:#}");
    // feeding mid-generation is rejected with a clear error
    let err = h1.feed(doc(10, 2), false).unwrap_err();
    assert!(format!("{err:#}").contains("in flight"), "unhelpful error: {err:#}");
    h1.cancel().unwrap();
    let r = s1.wait().unwrap();
    assert_eq!(r.reason, FinishReason::Cancelled);

    // an out-of-vocab seed token fails its own stream at intake — it
    // can never poison a shared decode wave
    let err = h2
        .generate(GenOpts { seed_token: -5, max_tokens: 4, ..Default::default() })
        .unwrap()
        .wait()
        .unwrap_err();
    assert!(format!("{err:#}").contains("vocab"), "unhelpful error: {err:#}");

    // stop token ends the stream with FinishReason::Stop
    let free = h2
        .generate_blocking(GenOpts { seed_token: 2, max_tokens: 16, ..Default::default() })
        .unwrap();
    let stop = free.tokens[0];
    let h3 = server.open_session();
    let stopped = h3
        .generate_blocking(GenOpts {
            seed_token: 2,
            max_tokens: 16,
            stop: Some(stop),
            ..Default::default()
        })
        .unwrap();
    assert_eq!(stopped.reason, FinishReason::Stop);
    assert_eq!(stopped.tokens, vec![stop]);

    // close releases state; dropping a handle releases too
    h3.close().unwrap();
    drop(h2);
    server.shutdown();
}
