//! Integration: the streaming server end-to-end — concurrent sessions,
//! batched execution correctness vs the single-session path, eviction,
//! and generation determinism. Pinned to the xla backend (requires
//! `make artifacts` + `--features xla`); the native-backend server
//! tests live in tests/native_parity.rs.
#![cfg(feature = "xla")]

use std::sync::Arc;

use stlt::coordinator::{BatchPolicy, Server, ServerOpts};
use stlt::data::corpus::{Corpus, CorpusConfig};
use stlt::runtime::{
    default_artifacts_dir, exec::load_init_vec, BackendKind, Manifest, Runtime, StreamStep,
};

fn xla_opts() -> ServerOpts {
    ServerOpts { backend: BackendKind::Xla, ..ServerOpts::default() }
}

fn manifest() -> Manifest {
    Manifest::load(default_artifacts_dir()).expect("run `make artifacts` first")
}

fn init_flat(m: &Manifest) -> Vec<f32> {
    let e = m.get("lm_stlt_tiny.train").unwrap();
    load_init_vec(e.init_file.as_ref().unwrap(), e.param_count).unwrap()
}

fn doc(vocab: usize, seed: u64, len: usize) -> Vec<i32> {
    Corpus::new(CorpusConfig::default_for_vocab(vocab), seed).take(len)
}

#[test]
fn concurrent_sessions_match_single_session_reference() {
    let m = manifest();
    let flat = init_flat(&m);
    let vocab = m.get("lm_stlt_tiny.eval").unwrap().config.vocab;

    // reference NLLs via the single-sequence stream artifact
    let rt = Runtime::cpu().unwrap();
    let stream = StreamStep::new(&rt, &m, "lm_stlt_tiny.stream").unwrap();
    let mut refs = Vec::new();
    for s in 0..3u64 {
        let d = doc(vocab, 100 + s, 300);
        let mut carry = stream.zero_carry();
        let c = stream.chunk;
        let (mut nll, mut cnt) = (0.0, 0.0);
        let mut off = 0;
        while off + 1 < d.len() {
            let take = c.min(d.len() - 1 - off);
            let mut toks = vec![0i32; c];
            let mut tgts = vec![0i32; c];
            let mut mask = vec![0f32; c];
            for j in 0..take {
                toks[j] = d[off + j];
                tgts[j] = d[off + j + 1];
                mask[j] = 1.0;
            }
            let (n, ct) = stream.run(&flat, &mut carry, &toks, &tgts, &mask).unwrap();
            nll += n;
            cnt += ct;
            off += take;
        }
        refs.push((nll, cnt));
    }

    // the same three documents through the batched server, concurrently
    let server = Arc::new(
        Server::start(&m, "lm_stlt_tiny", flat.clone(), xla_opts()).unwrap(),
    );
    let mut handles = Vec::new();
    for s in 0..3u64 {
        let server = Arc::clone(&server);
        let d = doc(vocab, 100 + s, 300);
        handles.push(std::thread::spawn(move || server.feed(s + 1, d, true).unwrap()));
    }
    let results: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    for (s, r) in results.iter().enumerate() {
        let (rn, rc) = refs[s];
        assert_eq!(r.count, rc, "session {s} token count");
        assert!(
            (r.nll_sum - rn).abs() < 0.25 + 1e-3 * rn.abs(),
            "session {s}: batched nll {} vs reference {}",
            r.nll_sum,
            rn
        );
    }
    // all feeds completed and every streamed token was accounted
    assert_eq!(server.stats.feeds.get(), 3);
    assert!(server.stats.tokens_streamed.get() >= 3 * 299);
}

#[test]
fn eviction_under_session_pressure() {
    let m = manifest();
    let flat = init_flat(&m);
    let vocab = m.get("lm_stlt_tiny.eval").unwrap().config.vocab;
    let opts = ServerOpts {
        queue_cap: 32,
        max_sessions: 2,
        policy: BatchPolicy::default(),
        backend: BackendKind::Xla,
    };
    let server = Server::start(&m, "lm_stlt_tiny", flat, opts).unwrap();
    for s in 0..5u64 {
        server.feed(s, doc(vocab, s, 150), false).unwrap();
    }
    assert!(
        server.stats.evictions.get() >= 3,
        "expected LRU evictions with max_sessions=2"
    );
    server.shutdown();
}

#[test]
fn generation_is_deterministic_and_session_scoped() {
    let m = manifest();
    let flat = init_flat(&m);
    let vocab = m.get("lm_stlt_tiny.eval").unwrap().config.vocab;
    let server = Server::start(&m, "lm_stlt_tiny", flat, xla_opts()).unwrap();
    let prompt = doc(vocab, 7, 100);
    let seed_tok = *prompt.last().unwrap();

    server.feed(1, prompt.clone(), false).unwrap();
    let g1 = server.generate(1, seed_tok, 16, None).unwrap();
    server.release(1).unwrap();

    server.feed(2, prompt.clone(), false).unwrap();
    let g2 = server.generate(2, seed_tok, 16, None).unwrap();
    server.release(2).unwrap();

    assert_eq!(g1.tokens, g2.tokens, "same prompt+params must generate identically");
    assert_eq!(g1.tokens.len(), 16);
    assert!(g1.tokens.iter().all(|&t| (0..vocab as i32).contains(&t)));

    // a session with a different prompt generates differently (untrained
    // models are near-uniform, so allow equality only if both short)
    server.feed(3, doc(vocab, 99, 100), false).unwrap();
    let g3 = server.generate(3, seed_tok, 16, None).unwrap();
    // not asserting inequality strictly (could coincide), but lengths hold
    assert_eq!(g3.tokens.len(), 16);
    server.shutdown();
}

#[test]
fn stop_token_halts_generation() {
    let m = manifest();
    let flat = init_flat(&m);
    let vocab = m.get("lm_stlt_tiny.eval").unwrap().config.vocab;
    let server = Server::start(&m, "lm_stlt_tiny", flat, xla_opts()).unwrap();
    server.feed(1, doc(vocab, 3, 80), false).unwrap();
    let free = server.generate(1, 5, 24, None).unwrap();
    server.release(1).unwrap();
    // pick the first emitted token as the stop token; a fresh identical
    // session must then stop at length 1
    let stop = free.tokens[0];
    server.feed(2, doc(vocab, 3, 80), false).unwrap();
    let stopped = server.generate(2, 5, 24, Some(stop)).unwrap();
    assert_eq!(stopped.tokens.len(), 1);
    assert_eq!(stopped.tokens[0], stop);
    server.shutdown();
}

#[test]
fn backpressure_sheds_load_not_correctness() {
    let m = manifest();
    let flat = init_flat(&m);
    let vocab = m.get("lm_stlt_tiny.eval").unwrap().config.vocab;
    let opts = ServerOpts {
        queue_cap: 2, // tiny queue to force backpressure
        max_sessions: 8,
        policy: BatchPolicy { max_batch: 4, max_wait: std::time::Duration::from_millis(1) },
        backend: BackendKind::Xla,
    };
    let server = Arc::new(Server::start(&m, "lm_stlt_tiny", flat, opts).unwrap());
    let mut handles = Vec::new();
    for s in 0..6u64 {
        let server = Arc::clone(&server);
        let d = doc(vocab, s, 120);
        handles.push(std::thread::spawn(move || server.feed(s, d, true)));
    }
    let mut ok = 0;
    for h in handles {
        if h.join().unwrap().is_ok() {
            ok += 1;
        }
    }
    // with a 30s push timeout everything should eventually get through
    assert_eq!(ok, 6, "all feeds should complete under backpressure");
}

#[test]
fn sampling_policies_through_server() {
    let m = manifest();
    let flat = init_flat(&m);
    let vocab = m.get("lm_stlt_tiny.eval").unwrap().config.vocab;
    let server = Server::start(&m, "lm_stlt_tiny", flat, xla_opts()).unwrap();
    let prompt = doc(vocab, 21, 80);
    let seed_tok = *prompt.last().unwrap();
    use stlt::coordinator::Sampling;
    // greedy twice: identical
    server.feed(1, prompt.clone(), false).unwrap();
    let a = server
        .generate_with(1, seed_tok, 12, None, Sampling::Greedy, 7)
        .unwrap();
    server.release(1).unwrap();
    server.feed(2, prompt.clone(), false).unwrap();
    let b = server
        .generate_with(2, seed_tok, 12, None, Sampling::Greedy, 8)
        .unwrap();
    server.release(2).unwrap();
    assert_eq!(a.tokens, b.tokens);
    // same temperature + same seed: reproducible; tokens stay in vocab
    server.feed(3, prompt.clone(), false).unwrap();
    let c = server
        .generate_with(3, seed_tok, 12, None, Sampling::Temperature(1.5), 7)
        .unwrap();
    server.release(3).unwrap();
    server.feed(3, prompt.clone(), false).unwrap();
    let d = server
        .generate_with(3, seed_tok, 12, None, Sampling::Temperature(1.5), 7)
        .unwrap();
    assert_eq!(c.tokens, d.tokens, "same (policy, seed, session) must reproduce");
    assert!(c.tokens.iter().all(|&t| (0..vocab as i32).contains(&t)));
    server.shutdown();
}
