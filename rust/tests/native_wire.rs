//! Sharded-serving integration (native backend, zero external deps):
//! the binary wire protocol, worker connection lifecycle, the session
//! router, and live carry migration.
//!
//! The load-bearing claims pinned here:
//!   * sessions driven over the wire are BITWISE the sessions driven
//!     in-process (f64 NLL bits, token streams) — the protocol adds
//!     transport, never arithmetic;
//!   * a client that vanishes mid-generate cancels its in-flight
//!     generation on the worker (no leaked pinned sessions);
//!   * a session migrated between two worker *processes* continues
//!     bitwise-identically to one that never moved;
//!   * killing a worker fails its sessions with clean errors while
//!     sessions on surviving workers proceed untouched.

#![cfg(feature = "native")]

use std::collections::BTreeMap;
use std::io::{BufRead, Write};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::Arc;
use std::time::{Duration, Instant};

use stlt::coordinator::{
    FinishReason, GenOpts, Sampling, Server, ServerOpts, Session,
};
use stlt::net::{
    read_frame, spawn_worker, write_frame, Client, Frame, Router, Stream, MAGIC,
    PROTOCOL_VERSION,
};
use stlt::runtime::artifact::{Entry, ModelConfig};
use stlt::runtime::native_stlt::host_init;
use stlt::runtime::{default_artifacts_dir, Manifest};

const S: usize = 4;
const D: usize = 8;
const LAYERS: usize = 2;
const VOCAB: usize = 19;
const CHUNK: usize = 8;
const BSRV: usize = 4;

fn cfg() -> ModelConfig {
    ModelConfig {
        arch: "stlt".into(),
        vocab: VOCAB,
        d_model: D,
        n_layers: LAYERS,
        n_ctx: 32,
        s_max: S,
        batch: 2,
        mode: "linear".into(),
        ..ModelConfig::default()
    }
}

fn manifest(p: usize) -> Manifest {
    let c = cfg();
    let mut entries = BTreeMap::new();
    for e in [
        Entry::synthetic_stream(&c, p, "nat.stream", CHUNK),
        Entry::synthetic_decode(&c, p, "nat.decode"),
        Entry::synthetic_stream_batch(&c, p, "nat.stream_batch", CHUNK, BSRV),
    ] {
        entries.insert(e.name.clone(), e);
    }
    Manifest { dir: PathBuf::from("."), entries }
}

fn doc(len: usize, seed: u64, vocab: usize) -> Vec<i32> {
    let mut rng = stlt::util::rng::Rng::new(seed);
    (0..len).map(|_| rng.below(vocab as u64) as i32).collect()
}

/// Everything observable from one scripted conversation, bit-exact
/// fields widened to bits so assertions compare raw representations.
#[derive(Debug, PartialEq)]
struct Transcript {
    nll1: u64,
    count1: f64,
    gen1: Vec<i32>,
    nll2: u64,
    gen2: Vec<i32>,
}

fn gen_opts(seed_token: i32, max_tokens: usize, temp: f32, rng_seed: u64) -> GenOpts {
    GenOpts {
        seed_token,
        max_tokens,
        sampling: Sampling::Temperature(temp),
        rng_seed,
        ..Default::default()
    }
}

/// The scripted conversation over any [`Session`] implementation.
/// Sampling is temperature-based so the `rng_seed ^ session` RNG seam
/// is exercised: matching transcripts prove the session *id* survived
/// the transport (and, in the migration tests, the move).
fn converse(sess: &dyn Session, k: u64, vocab: usize) -> Transcript {
    let prompt = doc(40 + (k % 5) as usize * 3, 1000 + k, vocab);
    let fr1 = sess.feed(prompt.clone(), true).unwrap();
    let g1 = sess
        .generate(gen_opts(*prompt.last().unwrap(), 7, 1.2, 11))
        .unwrap()
        .wait()
        .unwrap();
    assert_eq!(g1.reason, FinishReason::MaxTokens);
    let more = doc(21, 5000 + k, vocab);
    let fr2 = sess.feed(more.clone(), true).unwrap();
    let g2 = sess
        .generate(gen_opts(*more.last().unwrap(), 5, 0.9, 13))
        .unwrap()
        .wait()
        .unwrap();
    Transcript {
        nll1: fr1.nll_sum.to_bits(),
        count1: fr1.count,
        gen1: g1.tokens,
        nll2: fr2.nll_sum.to_bits(),
        gen2: g2.tokens,
    }
}

/// The same conversation through the session-id API (the reference
/// path: integration tests cannot mint explicit-id handles).
fn converse_by_id(server: &Server, session: u64, k: u64, vocab: usize) -> Transcript {
    let prompt = doc(40 + (k % 5) as usize * 3, 1000 + k, vocab);
    let fr1 = server.feed(session, prompt.clone(), true).unwrap();
    let g1 = server
        .start_generate(session, gen_opts(*prompt.last().unwrap(), 7, 1.2, 11))
        .unwrap()
        .wait()
        .unwrap();
    let more = doc(21, 5000 + k, vocab);
    let fr2 = server.feed(session, more.clone(), true).unwrap();
    let g2 = server
        .start_generate(session, gen_opts(*more.last().unwrap(), 5, 0.9, 13))
        .unwrap()
        .wait()
        .unwrap();
    Transcript {
        nll1: fr1.nll_sum.to_bits(),
        count1: fr1.count,
        gen1: g1.tokens,
        nll2: fr2.nll_sum.to_bits(),
        gen2: g2.tokens,
    }
}

#[test]
fn wire_sessions_bitwise_match_local() {
    let c = cfg();
    let flat = host_init(&c, 42);
    let m = manifest(flat.len());

    // reference: in-process server, session-id API, sequential
    let server = Server::start(&m, "nat", flat.clone(), ServerOpts::default()).unwrap();
    let reference: Vec<_> = (0..5u64).map(|k| converse_by_id(&server, 501 + k, k, VOCAB)).collect();
    server.shutdown();

    // wire: same conversations concurrently through one multiplexed
    // client connection to a loopback worker, same explicit ids
    let server = Arc::new(Server::start(&m, "nat", flat, ServerOpts::default()).unwrap());
    let wire = spawn_worker(Arc::clone(&server), "127.0.0.1:0").unwrap();
    let client = Client::connect(wire.addr()).unwrap();
    let mut threads = Vec::new();
    for k in 0..5u64 {
        let client = client.clone();
        threads.push(std::thread::spawn(move || {
            let mut sess = client.open(501 + k).unwrap();
            assert_eq!(sess.id(), 501 + k);
            let t = converse(&sess, k, VOCAB);
            sess.close().unwrap();
            (k, t)
        }));
    }
    for t in threads {
        let (k, got) = t.join().unwrap();
        assert_eq!(got, reference[k as usize], "wire session {k} diverged from local");
    }
    wire.shutdown();
}

#[test]
fn handshake_rejects_bad_version_and_magic() {
    let c = cfg();
    let flat = host_init(&c, 3);
    let m = manifest(flat.len());
    let server = Arc::new(Server::start(&m, "nat", flat, ServerOpts::default()).unwrap());
    let wire = spawn_worker(Arc::clone(&server), "127.0.0.1:0").unwrap();

    // wrong protocol version: explicit Error frame naming both versions
    let mut s = Stream::connect(wire.addr()).unwrap();
    write_frame(&mut s, &Frame::Hello { magic: MAGIC, version: PROTOCOL_VERSION + 1 }).unwrap();
    s.flush().unwrap();
    let mut r = std::io::BufReader::new(s.try_clone().unwrap());
    match read_frame(&mut r).unwrap() {
        Some(Frame::Error { req: 0, msg }) => {
            assert!(msg.contains("version"), "unhelpful version error: {msg}");
        }
        f => panic!("expected Error for version mismatch, got {f:?}"),
    }

    // wrong magic: a non-STLT peer gets told so
    let mut s = Stream::connect(wire.addr()).unwrap();
    write_frame(&mut s, &Frame::Hello { magic: 0xDEAD_BEEF, version: PROTOCOL_VERSION }).unwrap();
    s.flush().unwrap();
    let mut r = std::io::BufReader::new(s.try_clone().unwrap());
    match read_frame(&mut r).unwrap() {
        Some(Frame::Error { req: 0, msg }) => {
            assert!(msg.contains("magic"), "unhelpful magic error: {msg}");
        }
        f => panic!("expected Error for bad magic, got {f:?}"),
    }

    // and a well-formed handshake still succeeds afterwards
    let client = Client::connect(wire.addr()).unwrap();
    assert!(client.is_alive());
    wire.shutdown();
}

#[test]
fn abrupt_disconnect_cancels_inflight_generation() {
    let c = cfg();
    let flat = host_init(&c, 17);
    let m = manifest(flat.len());
    let server = Arc::new(Server::start(&m, "nat", flat, ServerOpts::default()).unwrap());
    let wire = spawn_worker(Arc::clone(&server), "127.0.0.1:0").unwrap();

    // hand-rolled frames on a raw socket: RemoteSession's Drop sends a
    // polite Close, and this test is about the *impolite* exit
    let mut s = Stream::connect(wire.addr()).unwrap();
    let mut r = std::io::BufReader::new(s.try_clone().unwrap());
    write_frame(&mut s, &Frame::Hello { magic: MAGIC, version: PROTOCOL_VERSION }).unwrap();
    s.flush().unwrap();
    assert!(matches!(read_frame(&mut r).unwrap(), Some(Frame::HelloAck { .. })));
    write_frame(&mut s, &Frame::Open { req: 1, session: 777 }).unwrap();
    s.flush().unwrap();
    assert!(matches!(
        read_frame(&mut r).unwrap(),
        Some(Frame::OpenOk { req: 1, session: 777 })
    ));
    let prompt = doc(30, 9, VOCAB);
    write_frame(
        &mut s,
        &Frame::Feed { req: 2, session: 777, count_loss: false, tokens: prompt.clone() },
    )
    .unwrap();
    s.flush().unwrap();
    assert!(matches!(read_frame(&mut r).unwrap(), Some(Frame::FeedOk { req: 2, .. })));
    write_frame(
        &mut s,
        &Frame::Generate {
            req: 3,
            session: 777,
            opts: GenOpts {
                seed_token: *prompt.last().unwrap(),
                max_tokens: 1_000_000, // would run ~forever without the cancel
                ..Default::default()
            },
        },
    )
    .unwrap();
    s.flush().unwrap();
    assert!(matches!(read_frame(&mut r).unwrap(), Some(Frame::Start { req: 3, .. })));
    for _ in 0..3 {
        assert!(matches!(read_frame(&mut r).unwrap(), Some(Frame::Token { req: 3, .. })));
    }

    // client walks away mid-stream, no Close, no Cancel
    drop(r);
    drop(s);

    // the worker's teardown releases the session; release cancels the
    // in-flight generation at the next wave boundary
    let t0 = Instant::now();
    while server.stats.cancelled.get() < 1 {
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "abrupt disconnect never cancelled the in-flight generation"
        );
        std::thread::yield_now();
    }

    // the worker keeps serving, and the session id is free again
    // (teardown released it from the connection registry)
    let client = Client::connect(wire.addr()).unwrap();
    let mut sess = client.open(777).unwrap();
    sess.feed(doc(10, 10, VOCAB), false).unwrap();
    sess.close().unwrap();
    wire.shutdown();
}

#[test]
fn migration_is_bitwise_under_concurrent_load() {
    let c = cfg();
    let flat = host_init(&c, 91);
    let m = manifest(flat.len());

    // reference: one in-process server, nothing ever moves
    let reference_server = Server::start(&m, "nat", flat.clone(), ServerOpts::default()).unwrap();

    // topology: two workers (identical weights), one router
    let w0 = Arc::new(Server::start(&m, "nat", flat.clone(), ServerOpts::default()).unwrap());
    let w1 = Arc::new(Server::start(&m, "nat", flat, ServerOpts::default()).unwrap());
    let wire0 = spawn_worker(Arc::clone(&w0), "127.0.0.1:0").unwrap();
    let wire1 = spawn_worker(Arc::clone(&w1), "127.0.0.1:0").unwrap();
    let router =
        Router::connect(&[wire0.addr().to_string(), wire1.addr().to_string()]).unwrap();
    assert_eq!(router.worker_count(), 2);

    let mut threads = Vec::new();
    for k in 0..6u64 {
        let router = router.clone();
        threads.push(std::thread::spawn(move || {
            let sess = router.open_session().unwrap();
            let id = sess.id();
            let vocab = VOCAB;
            let prompt = doc(40 + (k % 5) as usize * 3, 1000 + k, vocab);
            let fr1 = sess.feed(prompt.clone(), true).unwrap();
            let g1 = sess
                .generate(gen_opts(*prompt.last().unwrap(), 7, 1.2, 11))
                .unwrap()
                .wait()
                .unwrap();
            // live migration mid-conversation, concurrent with the
            // other sessions' feeds and generations
            let from = router.worker_of(id).unwrap();
            router.migrate(id, 1 - from).unwrap();
            assert_eq!(router.worker_of(id), Some(1 - from), "session {id} did not move");
            let more = doc(21, 5000 + k, vocab);
            let fr2 = sess.feed(more.clone(), true).unwrap();
            let g2 = sess
                .generate(gen_opts(*more.last().unwrap(), 5, 0.9, 13))
                .unwrap()
                .wait()
                .unwrap();
            let t = Transcript {
                nll1: fr1.nll_sum.to_bits(),
                count1: fr1.count,
                gen1: g1.tokens,
                nll2: fr2.nll_sum.to_bits(),
                gen2: g2.tokens,
            };
            (k, id, sess, t)
        }));
    }
    let mut sessions = Vec::new();
    for t in threads {
        let (k, id, sess, got) = t.join().unwrap();
        let want = converse_by_id(&reference_server, id, k, VOCAB);
        assert_eq!(got, want, "migrated session {id} diverged from the unmoved reference");
        sessions.push((k, id, sess));
    }

    // drain worker 0 entirely; continuations stay bitwise afterwards
    let (moved, failed) = router.drain(0);
    assert_eq!(failed, 0, "drain must move every session cleanly ({moved} moved)");
    assert!(router.sessions_on(0).is_empty(), "worker 0 still hosts sessions after drain");
    assert_eq!(router.sessions_on(1).len(), sessions.len(), "drain lost sessions");
    for (k, id, sess) in &sessions {
        let extra = doc(9, 9000 + k, VOCAB);
        let fr = sess.feed(extra.clone(), true).unwrap();
        let want = reference_server.feed(*id, extra, true).unwrap();
        assert_eq!(fr.nll_sum.to_bits(), want.nll_sum.to_bits(), "post-drain feed diverged");
    }

    // rebalance spreads them back within a delta of one
    router.rebalance_once();
    let (a, b) = (router.sessions_on(0).len(), router.sessions_on(1).len());
    assert!(a.abs_diff(b) <= 1, "rebalance left {a} vs {b}");

    for (_, _, mut sess) in sessions {
        sess.close().unwrap();
    }
    reference_server.shutdown();
    wire0.shutdown();
    wire1.shutdown();
}

#[test]
fn stats_frame_returns_parseable_snapshot() {
    let c = cfg();
    let flat = host_init(&c, 33);
    let m = manifest(flat.len());
    let server = Arc::new(Server::start(&m, "nat", flat, ServerOpts::default()).unwrap());
    let wire = spawn_worker(Arc::clone(&server), "127.0.0.1:0").unwrap();
    let client = Client::connect(wire.addr()).unwrap();

    // drive a little traffic so the counters have something to report
    let mut sess = client.open(901).unwrap();
    let prompt = doc(24, 77, VOCAB);
    sess.feed(prompt.clone(), true).unwrap();
    sess.generate(gen_opts(*prompt.last().unwrap(), 4, 1.0, 7)).unwrap().wait().unwrap();

    let text = client.stats().unwrap();
    let rows = stlt::obs::parse(&text).expect("stats payload must round-trip the parser");

    // The registry is process-global and other tests run concurrently,
    // so assert family presence and monotone positivity, never exact
    // counts owned by this test alone.
    let find = |kind: &str, name: &str| -> Option<f64> {
        rows.iter().find(|(k, n, _)| k == kind && n == name).map(|(_, _, v)| v[0])
    };
    assert!(find("counter", "wire/frames_tx").unwrap_or(0.0) > 0.0, "no frames counted");
    assert!(find("counter", "wire/frames_rx").unwrap_or(0.0) > 0.0);
    assert!(find("counter", "wire/bytes_tx").unwrap_or(0.0) > 0.0);
    // server/* rebinds to the most recently started Server (publish-rebind
    // scoping), and sibling tests start servers concurrently — so check the
    // family is exposed, not a value another instance may own right now.
    assert!(find("counter", "server/feeds").is_some(), "server/feeds family missing");
    assert!(find("counter", "server/gens").is_some(), "server/gens family missing");
    assert!(
        rows.iter().any(|(k, n, _)| k == "hist" && n == "server/ttft_seconds"),
        "ttft histogram family missing"
    );
    // per-node Laplace dynamics: sigma/omega/T/half-life published at load
    assert!(
        find("gauge", "node/l0/n0/half_life").is_some(),
        "node half-life gauges missing"
    );
    assert!(find("gauge", "node/l0/half_life_mean").unwrap_or(-1.0) > 0.0);

    sess.close().unwrap();
    wire.shutdown();
}

// ---------------------------------------------------------------------
// multi-process soak: real `stlt worker` processes over loopback TCP
// ---------------------------------------------------------------------

/// A spawned worker process, killed on drop (panic-safe).
struct WorkerProc {
    child: Child,
    addr: String,
}

impl WorkerProc {
    fn spawn(max_sessions: usize) -> WorkerProc {
        let mut child = Command::new(env!("CARGO_BIN_EXE_stlt"))
            .args([
                "worker",
                "--listen",
                "127.0.0.1:0",
                "--artifact",
                "lm_stlt_tiny",
                "--max-sessions",
                &max_sessions.to_string(),
            ])
            .stdout(Stdio::piped())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawn stlt worker");
        // the stdout line is the readiness signal (and carries the
        // resolved ephemeral port)
        let stdout = child.stdout.take().unwrap();
        let mut line = String::new();
        std::io::BufReader::new(stdout).read_line(&mut line).expect("worker stdout");
        let addr = line
            .trim()
            .strip_prefix("worker listening on ")
            .unwrap_or_else(|| panic!("unexpected worker banner: {line:?}"))
            .to_string();
        WorkerProc { child, addr }
    }
}

impl Drop for WorkerProc {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

#[test]
fn soak_two_worker_processes_interleaved_and_kill() {
    const SESSIONS: u64 = 96;
    let m = Manifest::load(default_artifacts_dir()).unwrap();
    let vocab = m.get("lm_stlt_tiny.stream_batch").unwrap().config.vocab;
    // the worker CLI loads exactly this when no --ckpt is given, so the
    // in-process reference holds bitwise the workers' weights
    let flat = stlt::runtime::exec::artifact_flat(&m, "lm_stlt_tiny").unwrap();

    let wp0 = WorkerProc::spawn(256);
    let wp1 = WorkerProc::spawn(256);
    let router = Router::connect(&[wp0.addr.clone(), wp1.addr.clone()]).unwrap();

    let reference = Arc::new(
        Server::start(
            &m,
            "lm_stlt_tiny",
            flat,
            ServerOpts { max_sessions: 256, queue_cap: 256, ..Default::default() },
        )
        .unwrap(),
    );

    // hundreds of concurrent wire sessions with interleaved
    // feed/generate/cancel/migrate; every non-cancelled transcript must
    // match the single-process reference bitwise
    let mut threads = Vec::new();
    for k in 0..SESSIONS {
        let router = router.clone();
        let reference = Arc::clone(&reference);
        threads.push(std::thread::spawn(move || {
            let sess = router.open_session().unwrap();
            let id = sess.id();
            if k % 10 == 7 {
                // cancellation traffic: long generation, cancel, drain;
                // excluded from the bitwise comparison
                let prompt = doc(24, 300 + k, vocab);
                sess.feed(prompt.clone(), false).unwrap();
                let mut stream = sess
                    .generate(GenOpts {
                        seed_token: *prompt.last().unwrap(),
                        max_tokens: 1_000_000,
                        ..Default::default()
                    })
                    .unwrap();
                for _ in 0..2 {
                    stream.recv().unwrap().unwrap();
                }
                sess.cancel().unwrap();
                let drained: Vec<i32> = stream.by_ref().map(|t| t.unwrap()).collect();
                assert!(drained.len() < 1_000_000);
                assert_eq!(stream.finish_reason(), Some(FinishReason::Cancelled));
                // the session survives its cancel
                let g = sess.generate_blocking(gen_opts(1, 3, 1.0, 5)).unwrap();
                assert_eq!(g.tokens.len(), 3);
                return (k, id, sess, None);
            }
            if k % 3 == 0 {
                // migration traffic, concurrent with everything else
                let got = {
                    let prompt = doc(40 + (k % 5) as usize * 3, 1000 + k, vocab);
                    let fr1 = sess.feed(prompt.clone(), true).unwrap();
                    let g1 = sess
                        .generate(gen_opts(*prompt.last().unwrap(), 7, 1.2, 11))
                        .unwrap()
                        .wait()
                        .unwrap();
                    let from = router.worker_of(id).unwrap();
                    router.migrate(id, 1 - from).unwrap();
                    let more = doc(21, 5000 + k, vocab);
                    let fr2 = sess.feed(more.clone(), true).unwrap();
                    let g2 = sess
                        .generate(gen_opts(*more.last().unwrap(), 5, 0.9, 13))
                        .unwrap()
                        .wait()
                        .unwrap();
                    Transcript {
                        nll1: fr1.nll_sum.to_bits(),
                        count1: fr1.count,
                        gen1: g1.tokens,
                        nll2: fr2.nll_sum.to_bits(),
                        gen2: g2.tokens,
                    }
                };
                return (k, id, sess, Some(got));
            }
            let got = converse(&sess, k, vocab);
            (k, id, sess, Some(got))
        }));
    }
    let mut live = Vec::new();
    for t in threads {
        let (k, id, sess, got) = t.join().unwrap();
        if let Some(got) = got {
            let want = converse_by_id(&reference, id, k, vocab);
            assert_eq!(got, want, "wire session {id} (k={k}) diverged from single-process");
        }
        live.push((id, sess));
    }
    assert_eq!(router.session_count(), live.len());

    // -- kill one worker ----------------------------------------------
    // sessions on the dead worker fail with clean errors (no hangs);
    // sessions on the survivor keep working; new sessions route around
    // the corpse
    let on0: Vec<u64> =
        live.iter().map(|(id, _)| *id).filter(|id| router.worker_of(*id) == Some(0)).collect();
    let on1: Vec<u64> =
        live.iter().map(|(id, _)| *id).filter(|id| router.worker_of(*id) == Some(1)).collect();
    assert!(!on0.is_empty() && !on1.is_empty(), "hash routing left a worker empty");
    drop(wp0); // SIGKILL

    let t0 = Instant::now();
    while router.worker_alive(0) {
        assert!(t0.elapsed() < Duration::from_secs(10), "router never noticed the dead worker");
        std::thread::sleep(Duration::from_millis(2));
    }
    let dead = live.iter().find(|(id, _)| on0.contains(id)).unwrap();
    let err = dead.1.feed(doc(5, 1, vocab), false).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("lost") || msg.contains("connect"), "unhelpful dead-worker error: {msg}");
    let survivor = live.iter().find(|(id, _)| on1.contains(id)).unwrap();
    survivor.1.feed(doc(5, 2, vocab), false).unwrap();
    let fresh = router.open_session().unwrap();
    assert_eq!(router.worker_of(fresh.id()), Some(1), "new sessions must avoid the dead worker");
    fresh.feed(doc(5, 3, vocab), false).unwrap();

    drop(live);
    drop(fresh);
    drop(wp1);
}
