//! Integration tests for `stlt lint --deep` (src/lint/deep.rs).
//!
//! The first test is the repo's own gate: the committed tree must pass
//! the deep passes with the committed `lint_deep.allow` ledger and
//! zero stale entries — the same invariant CI enforces, kept here so
//! `cargo test` catches a regression before the CI wall does.
//!
//! The rest exercise the lock-order pass end to end on synthetic
//! crates written to a temp dir: a deterministic cycle report + JSON
//! artifact for an injected ABBA pair, and the stale-ledger failure
//! mode.

use std::fs;
use std::path::{Path, PathBuf};

use stlt::lint::deep::{run_deep, RULE_STALE_DEEP};
use stlt::lint::locks::RULE_LOCK_CYCLE;

fn manifest_path(rel: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join(rel)
}

/// The committed repo passes its own deep lint: every finding is
/// ledgered in `lint_deep.allow` with a rationale, and every ledger
/// entry still suppresses something (no stale debt).
#[test]
fn committed_tree_is_clean_under_committed_ledger() {
    let violations = run_deep(
        &manifest_path("src"),
        &manifest_path("lint_deep.allow"),
        None,
    )
    .expect("deep lint ran");
    assert!(
        violations.is_empty(),
        "deep lint found {} violation(s) not covered by lint_deep.allow:\n{}",
        violations.len(),
        violations.iter().map(|v| format!("  {v}")).collect::<Vec<_>>().join("\n")
    );
}

/// Scratch crate layout for the synthetic tests. Unique per test so
/// parallel test threads never share a directory.
struct Fixture {
    root: PathBuf,
}

impl Fixture {
    fn new(tag: &str, files: &[(&str, &str)]) -> Fixture {
        let root = std::env::temp_dir()
            .join(format!("stlt_lint_deep_{}_{tag}", std::process::id()));
        let _ = fs::remove_dir_all(&root);
        for (rel, src) in files {
            let p = root.join("src").join(rel);
            fs::create_dir_all(p.parent().unwrap()).unwrap();
            fs::write(p, src).unwrap();
        }
        Fixture { root }
    }

    fn src(&self) -> PathBuf {
        self.root.join("src")
    }
}

impl Drop for Fixture {
    fn drop(&mut self) {
        let _ = fs::remove_dir_all(&self.root);
    }
}

/// Two functions taking the same pair of locks in opposite orders: the
/// classic ABBA deadlock. The pass must report exactly one
/// `lock-cycle` violation with the sorted `+`-joined qual, write the
/// lock-order JSON artifact, and do both bit-identically across runs.
#[test]
fn injected_abba_cycle_is_reported_and_json_is_deterministic() {
    let pair = "\
pub struct S;
impl S {
    pub fn ab(&self) {
        let a = self.alpha.lock();
        let b = self.beta.lock();
        drop(b);
        drop(a);
    }
    pub fn ba(&self) {
        let b = self.beta.lock();
        let a = self.alpha.lock();
        drop(a);
        drop(b);
    }
}
";
    let fx = Fixture::new("abba", &[("pair.rs", pair)]);
    let json_path = fx.root.join("lock_order.json");
    let allow = fx.root.join("lint_deep.allow"); // absent = empty ledger

    let violations = run_deep(&fx.src(), &allow, Some(&json_path)).expect("deep lint ran");
    let cycles: Vec<_> = violations.iter().filter(|v| v.rule == RULE_LOCK_CYCLE).collect();
    assert_eq!(cycles.len(), 1, "expected exactly one lock-cycle, got: {violations:?}");
    assert!(
        cycles[0].msg.contains("pair.alpha") && cycles[0].msg.contains("pair.beta"),
        "cycle names both locks: {}",
        cycles[0].msg
    );
    assert_eq!(
        violations.len(),
        1,
        "the fixture must trip only the lock pass: {violations:?}"
    );

    let first = fs::read_to_string(&json_path).unwrap();
    assert!(first.contains("\"locks\": [\"pair.alpha\", \"pair.beta\"]"), "{first}");
    assert!(
        first.contains("\"cycles\": [[\"pair.alpha\", \"pair.beta\"]]")
            || first.contains("\"cycles\": [[\"pair.beta\", \"pair.alpha\"]]"),
        "cycle missing from artifact: {first}"
    );
    assert!(first.contains("\"from\": \"pair.alpha\", \"to\": \"pair.beta\""), "{first}");
    assert!(first.contains("\"from\": \"pair.beta\", \"to\": \"pair.alpha\""), "{first}");

    // bitwise-deterministic artifact: a second run writes identical bytes
    let violations2 = run_deep(&fx.src(), &allow, Some(&json_path)).expect("deep lint ran");
    let second = fs::read_to_string(&json_path).unwrap();
    assert_eq!(first, second, "lock-order JSON must be deterministic");
    assert_eq!(
        violations.len(),
        violations2.len(),
        "violation set must be deterministic"
    );
}

/// A ledgered cycle is suppressed by its sorted `+`-joined qual — and
/// only with a rationale; the ledger line must then be counted as
/// used (no stale report).
#[test]
fn ledgered_cycle_is_suppressed_by_sorted_qual() {
    let pair = "\
pub fn ab(s: &S) {
    let a = s.alpha.lock();
    let b = s.beta.lock();
}
pub fn ba(s: &S) {
    let b = s.beta.lock();
    let a = s.alpha.lock();
}
";
    let fx = Fixture::new("ledgered", &[("pair.rs", pair)]);
    let allow = fx.root.join("lint_deep.allow");
    fs::write(
        &allow,
        "lock-cycle pair.alpha+pair.beta -- fixture: order is enforced at the call site\n",
    )
    .unwrap();
    let violations = run_deep(&fx.src(), &allow, None).expect("deep lint ran");
    assert!(violations.is_empty(), "{violations:?}");
}

/// A ledger entry that no longer matches any finding fails the lint,
/// pointing at the allow-file line — the mechanism that makes the
/// committed ledger shrink-only.
#[test]
fn stale_ledger_entry_is_a_violation() {
    let clean = "pub fn quiet() {}\n";
    let fx = Fixture::new("stale", &[("quiet.rs", clean)]);
    let allow = fx.root.join("lint_deep.allow");
    fs::write(&allow, "# ledger\nhot-alloc Ghost::vanished -- was real in PR 9\n").unwrap();
    let violations = run_deep(&fx.src(), &allow, None).expect("deep lint ran");
    assert_eq!(violations.len(), 1, "{violations:?}");
    assert_eq!(violations[0].rule, RULE_STALE_DEEP);
    assert_eq!(violations[0].line, 2, "points at the stale ledger line");
    assert!(violations[0].msg.contains("Ghost::vanished"), "{}", violations[0].msg);
}

/// A malformed ledger (entry without rationale) is a hard error, not a
/// silently-ignored line.
#[test]
fn ledger_without_rationale_is_rejected() {
    let fx = Fixture::new("badledger", &[("quiet.rs", "pub fn quiet() {}\n")]);
    let allow = fx.root.join("lint_deep.allow");
    fs::write(&allow, "hot-alloc Engine::step\n").unwrap();
    let err = run_deep(&fx.src(), &allow, None).expect_err("missing rationale must fail");
    assert!(err.contains("rule qual-suffix -- rationale"), "{err}");
}
