//! Property-based tests (util::prop, from-scratch proptest substitute)
//! on the coordinator's invariants: queue conservation, batcher
//! no-loss/no-dup, state-pool accounting, checkpoint roundtrips,
//! tokenizer roundtrips, metric bounds.

use std::collections::HashSet;
use std::sync::Arc;
use std::time::Duration;

use stlt::coordinator::{BatchPolicy, Batcher, BoundedQueue, StatePool};
use stlt::coordinator::{load_checkpoint, save_checkpoint};
use stlt::metrics::{bleu4, token_f1};
use stlt::prop_assert;
use stlt::runtime::{StreamCarry, TrainState};
use stlt::tokenizer::Bpe;
use stlt::util::prop::{check, Gen};

fn carry(g: &mut Gen) -> StreamCarry {
    let s = g.usize_in(1, 4);
    let d = g.usize_in(1, 6);
    StreamCarry {
        l: g.vec_f32(s * 2, -1.0, 1.0),
        u: g.vec_f32(s * d * 2, -1.0, 1.0),
        l_shape: vec![s, 2],
        u_shape: vec![s, d, 2],
    }
}

#[test]
fn prop_queue_conserves_items() {
    check("queue-conservation", 30, |g| {
        let cap = g.usize_in(1, 16);
        let n = g.usize_in(0, 64);
        let q = Arc::new(BoundedQueue::new(cap));
        let items: Vec<u64> = (0..n as u64).collect();
        let qp = Arc::clone(&q);
        let send = items.clone();
        let producer = std::thread::spawn(move || {
            for i in send {
                qp.push(i, Duration::from_secs(5)).unwrap();
            }
            qp.close();
        });
        let mut got = Vec::new();
        while let Some(x) = q.pop() {
            got.push(x);
        }
        producer.join().unwrap();
        prop_assert!(got == items, "items lost or reordered: {} vs {}", got.len(), n);
        Ok(())
    });
}

#[test]
fn prop_batcher_no_loss_no_dup() {
    check("batcher-no-loss", 25, |g| {
        let n = g.usize_in(1, 80);
        let max_batch = g.usize_in(1, 8);
        let q = Arc::new(BoundedQueue::new(128));
        for i in 0..n as u64 {
            q.try_push(i).unwrap();
        }
        q.close();
        let b = Batcher::new(
            Arc::clone(&q),
            BatchPolicy { max_batch, max_wait: Duration::from_millis(1) },
        );
        let mut seen = Vec::new();
        while let Some(batch) = b.next_batch() {
            prop_assert!(batch.len() <= max_batch, "batch exceeded max: {}", batch.len());
            seen.extend(batch);
        }
        let set: HashSet<_> = seen.iter().collect();
        prop_assert!(seen.len() == n, "lost items: {} of {}", seen.len(), n);
        prop_assert!(set.len() == n, "duplicated items");
        Ok(())
    });
}

#[test]
fn prop_state_pool_accounting() {
    check("state-pool", 40, |g| {
        let cap = g.usize_in(1, 6);
        let mut pool = StatePool::new(cap);
        let mut live: HashSet<u64> = HashSet::new();
        let mut checked_out: HashSet<u64> = HashSet::new();
        for _ in 0..60 {
            let id = g.i64_in(0, 9) as u64;
            match g.usize_in(0, 3) {
                0 => {
                    let admit = pool.admit(id, carry(g));
                    match admit {
                        stlt::coordinator::Admit::Evicted(v) => {
                            prop_assert!(live.remove(&v), "evicted unknown session {v}");
                            prop_assert!(!checked_out.contains(&v), "evicted pinned {v}");
                            live.insert(id);
                        }
                        stlt::coordinator::Admit::Ok => {
                            live.insert(id);
                        }
                        stlt::coordinator::Admit::Rejected => {
                            prop_assert!(
                                checked_out.len() >= cap,
                                "rejected but unpinned capacity remains"
                            );
                        }
                    }
                }
                1 => {
                    if live.contains(&id) && !checked_out.contains(&id) {
                        prop_assert!(pool.checkout(id).is_some(), "checkout of live {id} failed");
                        checked_out.insert(id);
                    } else if !live.contains(&id) {
                        prop_assert!(pool.checkout(id).is_none(), "checkout of dead {id} worked");
                    }
                }
                2 => {
                    if checked_out.remove(&id) {
                        pool.checkin(id, carry(g), 1);
                    }
                }
                _ => {
                    if g.bool() {
                        let was = pool.release(id);
                        prop_assert!(was == live.remove(&id), "release mismatch for {id}");
                        checked_out.remove(&id);
                    }
                }
            }
            prop_assert!(pool.len() == live.len(), "pool len {} != model {}", pool.len(), live.len());
            prop_assert!(pool.len() <= cap, "pool over capacity");
        }
        Ok(())
    });
}

#[test]
fn prop_checkpoint_roundtrip() {
    check("ckpt-roundtrip", 15, |g| {
        let n = g.usize_in(0, 2000);
        let st = TrainState {
            flat: g.vec_f32(n, -10.0, 10.0),
            m: g.vec_f32(n, -1.0, 1.0),
            v: g.vec_f32(n, 0.0, 1.0),
            step: g.i64_in(0, 1_000_000) as i32,
        };
        let path = std::env::temp_dir().join(format!("stlt_prop_ckpt_{:x}.bin", g.seed));
        save_checkpoint(&path, &st, "prop_artifact").map_err(|e| e.to_string())?;
        let ld = load_checkpoint(&path).map_err(|e| e.to_string())?;
        let _ = std::fs::remove_file(&path);
        prop_assert!(ld.step == st.step, "step");
        prop_assert!(ld.flat == st.flat && ld.m == st.m && ld.v == st.v, "vectors differ");
        Ok(())
    });
}

#[test]
fn prop_bpe_roundtrip_arbitrary_bytes() {
    check("bpe-roundtrip", 15, |g| {
        let len = g.usize_in(0, 400);
        let bytes: Vec<u8> = (0..len).map(|_| g.i64_in(32, 126) as u8).collect();
        let text = String::from_utf8(bytes).unwrap();
        let vocab = 260 + g.usize_in(0, 40);
        let bpe = Bpe::train(&text, vocab);
        prop_assert!(bpe.decode(&bpe.encode(&text)) == text, "roundtrip failed");
        Ok(())
    });
}

#[test]
fn prop_metric_bounds() {
    check("metric-bounds", 40, |g| {
        let hl = g.usize_in(0, 20);
        let rl = g.usize_in(0, 20);
        let h = g.vec_i32(hl, 0, 50);
        let r = g.vec_i32(rl, 0, 50);
        let f1 = token_f1(&h, &r);
        prop_assert!((0.0..=1.0).contains(&f1), "f1 out of range: {f1}");
        let b = bleu4(&[(h.clone(), r.clone())]);
        prop_assert!((0.0..=100.0 + 1e-9).contains(&b), "bleu out of range: {b}");
        // identity gives max
        if !h.is_empty() {
            prop_assert!((token_f1(&h, &h) - 1.0).abs() < 1e-12, "self f1 != 1");
        }
        Ok(())
    });
}

#[test]
fn prop_histogram_quantiles_monotone() {
    check("hist-monotone", 20, |g| {
        let mut hist = stlt::metrics::Histogram::new();
        for _ in 0..g.usize_in(1, 500) {
            hist.record(g.f64_in(1e-7, 50.0));
        }
        let qs: Vec<f64> = [0.1, 0.5, 0.9, 0.99].iter().map(|&q| hist.quantile(q)).collect();
        for w in qs.windows(2) {
            prop_assert!(w[1] >= w[0], "quantiles not monotone: {qs:?}");
        }
        Ok(())
    });
}
