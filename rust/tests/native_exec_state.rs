//! Error-path state preservation for the typed entry points in
//! `runtime/exec.rs` (satellite bugfix): `TrainStep::run`,
//! `StreamStep::run` and `DecodeStep::run` move their host state
//! (`TrainState.flat/m/v`, the stream carry) into the input tensors
//! before the fallible backend call. A backend error used to leave the
//! caller with silently zero-length vectors — a poisoned TrainState or
//! an unresumable stream. These tests drive real backend failures
//! (out-of-vocab tokens reaching the native engine) and pin that the
//! state survives bitwise and the step is retryable.
#![cfg(feature = "native")]

use std::collections::BTreeMap;
use std::path::PathBuf;

use stlt::runtime::artifact::{Entry, ModelConfig, TensorSpec};
use stlt::runtime::{DecodeStep, Manifest, Runtime, StreamStep, TrainState, TrainStep};
use stlt::util::rng::Rng;

const VOCAB: usize = 23;
const D: usize = 8;
const LAYERS: usize = 2;
const S: usize = 4;
const CHUNK: usize = 6;
const B: usize = 2;
const N1: usize = 9;

fn cfg() -> ModelConfig {
    ModelConfig {
        arch: "stlt".into(),
        vocab: VOCAB,
        d_model: D,
        n_layers: LAYERS,
        n_ctx: 16,
        s_max: S,
        batch: B,
        mode: "linear".into(),
        ffn_mult: 2,
        ..ModelConfig::default()
    }
}

fn f32s(shape: &[usize]) -> TensorSpec {
    TensorSpec { dtype: stlt::runtime::DType::F32, shape: shape.to_vec() }
}

fn i32s(shape: &[usize]) -> TensorSpec {
    TensorSpec { dtype: stlt::runtime::DType::I32, shape: shape.to_vec() }
}

fn entry(name: &str, kind: &str, p: usize, inputs: Vec<TensorSpec>, outputs: Vec<TensorSpec>) -> Entry {
    let n_inputs = inputs.len();
    Entry {
        name: name.to_string(),
        file: PathBuf::from("native-synthetic"),
        kind: kind.to_string(),
        param_count: p,
        inputs,
        outputs,
        config: cfg(),
        extra: BTreeMap::new(),
        init_file: None,
        kept_inputs: (0..n_inputs).collect(),
    }
}

fn manifest() -> Manifest {
    let p = stlt::interpret::total_params(&stlt::interpret::trunk_layout(&cfg()));
    let ls = [LAYERS, S, 2];
    let us = [LAYERS, S, D, 2];
    let mut entries = BTreeMap::new();
    for e in [
        entry(
            "st.train",
            "train_step",
            p,
            vec![f32s(&[p]), f32s(&[p]), f32s(&[p]), i32s(&[]), i32s(&[B, N1]), i32s(&[])],
            vec![f32s(&[p]), f32s(&[p]), f32s(&[p]), f32s(&[]), f32s(&[]), f32s(&[])],
        ),
        entry(
            "st.stream",
            "stream_step",
            p,
            vec![f32s(&[p]), f32s(&ls), f32s(&us), i32s(&[CHUNK]), i32s(&[CHUNK]), f32s(&[CHUNK])],
            vec![f32s(&ls), f32s(&us), f32s(&[]), f32s(&[])],
        ),
        entry(
            "st.decode",
            "decode_step",
            p,
            vec![f32s(&[p]), f32s(&ls), f32s(&us), i32s(&[1])],
            vec![f32s(&ls), f32s(&us), f32s(&[VOCAB])],
        ),
    ] {
        entries.insert(e.name.clone(), e);
    }
    Manifest { dir: PathBuf::from("."), entries }
}

fn tokens(n: usize, seed: u64) -> Vec<i32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.below(VOCAB as u64) as i32).collect()
}

#[test]
fn train_step_error_preserves_state_and_is_retryable() {
    let m = manifest();
    let rt = Runtime::native().unwrap();
    let step = TrainStep::new(&rt, &m, "st.train").unwrap();
    let mut state = TrainState::init_for(step.entry(), 3).unwrap();
    let good = tokens(B * N1, 5);

    // one good step so the moments are nonzero (a harder restore target)
    step.run(&mut state, &good, 0).unwrap();
    let (flat0, m0, v0, step0) =
        (state.flat.clone(), state.m.clone(), state.v.clone(), state.step);

    // a token past the vocab fails inside the native engine, after the
    // state vectors were moved into the input tensors
    let mut bad = good.clone();
    bad[N1 + 1] = VOCAB as i32 + 3;
    let err = format!("{:#}", step.run(&mut state, &bad, 1).unwrap_err());
    assert!(err.contains("vocab"), "unexpected error: {err}");

    assert_eq!(state.flat, flat0, "flat must survive a failed step bitwise");
    assert_eq!(state.m, m0, "first moment must survive a failed step");
    assert_eq!(state.v, v0, "second moment must survive a failed step");
    assert_eq!(state.step, step0, "step counter must not advance on error");

    // and the very same state must be usable for a retry
    let metrics = step.run(&mut state, &good, 1).unwrap();
    assert!(metrics.loss.is_finite());
    assert_eq!(state.step, step0 + 1);
}

#[test]
fn stream_step_error_preserves_carry_and_is_resumable() {
    let m = manifest();
    let rt = Runtime::native().unwrap();
    let stream = StreamStep::new(&rt, &m, "st.stream").unwrap();
    let flat = stlt::runtime::native_stlt::host_init(&cfg(), 11);
    let mut carry = stream.zero_carry();
    let toks = tokens(CHUNK, 1);
    let tgts = tokens(CHUNK, 2);
    let mask = vec![1.0f32; CHUNK];

    // advance one good chunk so the carry is nonzero
    stream.run(&flat, &mut carry, &toks, &tgts, &mask).unwrap();
    let (l0, u0) = (carry.l.clone(), carry.u.clone());
    assert!(l0.iter().any(|&x| x != 0.0), "carry should be advanced");

    let mut bad = toks.clone();
    bad[2] = VOCAB as i32 + 1;
    let err = format!("{:#}", stream.run(&flat, &mut carry, &bad, &tgts, &mask).unwrap_err());
    assert!(err.contains("vocab"), "unexpected error: {err}");
    assert_eq!(carry.l, l0, "L carry must survive a failed chunk bitwise");
    assert_eq!(carry.u, u0, "U carry must survive a failed chunk bitwise");

    // the stream must resume from exactly where it was
    let (nll, cnt) = stream.run(&flat, &mut carry, &toks, &tgts, &mask).unwrap();
    assert!(nll.is_finite() && cnt == CHUNK as f64);
}

#[test]
fn decode_step_error_preserves_carry() {
    let m = manifest();
    let rt = Runtime::native().unwrap();
    let decode = DecodeStep::new(&rt, &m, "st.decode").unwrap();
    let flat = stlt::runtime::native_stlt::host_init(&cfg(), 13);
    let mut carry = decode.zero_carry();
    decode.run(&flat, &mut carry, 4).unwrap();
    let (l0, u0) = (carry.l.clone(), carry.u.clone());

    let err = format!("{:#}", decode.run(&flat, &mut carry, VOCAB as i32).unwrap_err());
    assert!(err.contains("vocab"), "unexpected error: {err}");
    assert_eq!(carry.l, l0, "decode carry must survive a failed step");
    assert_eq!(carry.u, u0);

    let logits = decode.run(&flat, &mut carry, 5).unwrap();
    assert_eq!(logits.len(), VOCAB);
}
