//! Integration: manifest -> PJRT compile -> execute, and the Rust-side
//! parameter-layout mirror against python's packing.
//!
//! Requires `make artifacts` and a build with `--features xla`; the
//! native-backend equivalents live in tests/native_parity.rs. Heavy
//! sub-checks run sequentially inside one #[test] each (the PJRT
//! handles are !Send, and the box has 1 core).
#![cfg(feature = "xla")]

use stlt::interpret;
use stlt::runtime::{
    default_artifacts_dir, exec::load_init_vec, EvalStep, Forward, Manifest, Runtime,
    StreamStep, TrainState, TrainStep,
};

fn manifest() -> Manifest {
    Manifest::load(default_artifacts_dir()).expect("run `make artifacts` first")
}

#[test]
fn layout_mirror_matches_python_param_count() {
    let m = manifest();
    // Every lm_* train entry: rust-computed layout total == python param_count
    for e in m.by_kind("train_step") {
        if !e.name.starts_with("lm_") {
            continue;
        }
        let layout = interpret::trunk_layout(&e.config);
        let total = interpret::total_params(&layout);
        assert_eq!(
            total, e.param_count,
            "layout mismatch for {} (arch {})",
            e.name, e.config.arch
        );
    }
}

#[test]
fn init_vector_is_python_exact_for_stlt() {
    let m = manifest();
    let e = m.get("lm_stlt_tiny.train").unwrap();
    let init = load_init_vec(e.init_file.as_ref().unwrap(), e.param_count).unwrap();
    // LN gains are exactly 1.0 at the offsets the rust layout predicts
    let layout = interpret::trunk_layout(&e.config);
    let ln = layout.iter().find(|l| l.path == "/layers/000/ln1_g").unwrap();
    for i in 0..ln.numel() {
        assert_eq!(init[ln.offset + i], 1.0, "ln1_g[{i}] not 1.0 — packing drifted");
    }
    // sigma_raw is log-spaced increasing
    let sr = layout.iter().find(|l| l.path == "/layers/000/mixer/sigma_raw").unwrap();
    let sig: Vec<f32> = init[sr.offset..sr.offset + sr.numel()].to_vec();
    for w in sig.windows(2) {
        assert!(w[1] > w[0], "sigma_raw not increasing: {w:?}");
    }
}

#[test]
fn eval_untrained_model_is_near_uniform() {
    let m = manifest();
    let rt = Runtime::cpu().unwrap();
    let e = m.get("lm_stlt_tiny.eval").unwrap();
    let eval = EvalStep::new(&rt, &m, "lm_stlt_tiny.eval").unwrap();
    let init = load_init_vec(
        m.get("lm_stlt_tiny.train").unwrap().init_file.as_ref().unwrap(),
        e.param_count,
    )
    .unwrap();
    let mut gen = stlt::data::batch::LmBatcher::new(
        stlt::data::corpus::CorpusConfig::default_for_vocab(e.config.vocab),
        5,
        eval.batch,
        eval.n_plus_1,
    );
    let toks = gen.next_batch();
    let (nll, count, _seff) = eval.run(&init, &toks, 0.0, 0).unwrap();
    let ppl = stlt::metrics::perplexity(nll, count);
    let v = e.config.vocab as f64;
    assert!(
        ppl > v * 0.5 && ppl < v * 2.0,
        "untrained ppl {ppl} should be near vocab {v}"
    );
}

#[test]
fn forward_is_deterministic_and_shaped() {
    let m = manifest();
    let rt = Runtime::cpu().unwrap();
    let fwd = Forward::new(&rt, &m, "lm_stlt_tiny.fwd").unwrap();
    let e = m.get("lm_stlt_tiny.fwd").unwrap();
    let flat = stlt::runtime::exec::init_vec_host(e.param_count, 3);
    let tokens: Vec<i32> = (0..fwd.n as i32).map(|i| 4 + (i * 7) % 200).collect();
    let a = fwd.run(&flat, &tokens).unwrap();
    let b = fwd.run(&flat, &tokens).unwrap();
    assert_eq!(a.shape(), &[1, fwd.n, e.config.vocab]);
    assert_eq!(a.as_f32().unwrap(), b.as_f32().unwrap());
}

#[test]
fn stream_chunks_match_whole_document_nll() {
    // streaming invariance at the artifact level: two different chunkings
    // of the same document give the same total NLL
    let m = manifest();
    let rt = Runtime::cpu().unwrap();
    let stream = StreamStep::new(&rt, &m, "lm_stlt_tiny.stream").unwrap();
    let e = m.get("lm_stlt_tiny.stream").unwrap();
    let flat = load_init_vec(
        m.get("lm_stlt_tiny.train").unwrap().init_file.as_ref().unwrap(),
        e.param_count,
    )
    .unwrap();
    let mut corpus = stlt::data::corpus::Corpus::new(
        stlt::data::corpus::CorpusConfig::default_for_vocab(e.config.vocab),
        17,
    );
    let doc = corpus.take(257);
    let run = |piece_lens: &[usize]| -> (f64, f64) {
        let mut carry = stream.zero_carry();
        let c = stream.chunk;
        let (mut nll, mut cnt) = (0.0, 0.0);
        let mut off = 0usize;
        for &len in piece_lens {
            let take = len.min(doc.len() - 1 - off);
            let mut toks = vec![0i32; c];
            let mut tgts = vec![0i32; c];
            let mut mask = vec![0f32; c];
            for j in 0..take {
                toks[j] = doc[off + j];
                tgts[j] = doc[off + j + 1];
                mask[j] = 1.0;
            }
            let (n, ct) = stream.run(&flat, &mut carry, &toks, &tgts, &mask).unwrap();
            nll += n;
            cnt += ct;
            off += take;
        }
        (nll, cnt)
    };
    let (nll_a, cnt_a) = run(&[64, 64, 64, 64]);
    let (nll_b, cnt_b) = run(&[64, 32, 64, 64, 32]);
    assert_eq!(cnt_a, cnt_b);
    assert!(
        (nll_a - nll_b).abs() < 0.25 + 1e-3 * nll_a.abs(),
        "chunking changed NLL: {nll_a} vs {nll_b}"
    );
}

#[test]
fn train_step_descends_and_is_deterministic() {
    let m = manifest();
    let rt = Runtime::cpu().unwrap();
    let ts = TrainStep::new(&rt, &m, "lm_stlt_tiny.train").unwrap();
    let entry = ts.entry().clone();
    let mut gen = stlt::data::batch::LmBatcher::new(
        stlt::data::corpus::CorpusConfig::default_for_vocab(entry.config.vocab),
        9,
        ts.batch,
        ts.n_plus_1,
    );
    let batch = gen.next_batch();
    let run = || {
        let mut st = TrainState::from_entry(&entry).unwrap();
        let mut losses = Vec::new();
        for i in 0..6 {
            let m_ = ts.run(&mut st, &batch, 42 + i).unwrap();
            losses.push(m_.loss);
        }
        losses
    };
    let l1 = run();
    let l2 = run();
    assert_eq!(l1, l2, "train_step must be bit-deterministic");
    assert!(
        l1.last().unwrap() < l1.first().unwrap(),
        "overfit on one batch must reduce loss: {l1:?}"
    );
}

#[test]
fn missing_artifact_errors_cleanly() {
    let m = manifest();
    let rt = Runtime::cpu().unwrap();
    assert!(TrainStep::new(&rt, &m, "no_such_model.train").is_err());
    // wrong kind
    assert!(TrainStep::new(&rt, &m, "lm_stlt_tiny.eval").is_err());
}
