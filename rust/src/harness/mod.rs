//! Shared experiment-harness helpers used by examples/exp_*.rs: variant
//! training with checkpoint reuse, long-context evaluation (chunked vs
//! streaming), QA episodes, table rendering and results persistence.

use std::collections::BTreeMap;
use std::path::PathBuf;

use anyhow::{anyhow, Result};

use crate::config::Config;
use crate::coordinator::{self, TrainOpts};
use crate::data::batch::LmBatcher;
use crate::data::corpus::{Corpus, CorpusConfig};
use crate::data::longqa::QaSample;
use crate::metrics::perplexity;
use crate::runtime::{EvalStep, Manifest, Runtime, StreamStep, TrainState};
use crate::util::json::Json;

/// Where experiment outputs (checkpoints, json rows) live.
pub fn results_dir() -> PathBuf {
    let d = PathBuf::from("results");
    let _ = std::fs::create_dir_all(d.join("ckpt"));
    d
}

/// Experiment-scale knobs, overridable via env so the same binaries can
/// run smoke-scale in CI and full-scale for EXPERIMENTS.md.
pub fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Train a variant (or reuse its checkpoint if present) and return the
/// final params + training report.
pub fn train_or_load(
    rt: &Runtime,
    manifest: &Manifest,
    artifact_base: &str,
    steps: u64,
    seed: u64,
) -> Result<(TrainState, Option<coordinator::TrainReport>)> {
    // the seed is part of the cache key: a cached checkpoint from a
    // different seed must not silently masquerade as this run's result
    let ckpt = results_dir()
        .join("ckpt")
        .join(format!("{artifact_base}_s{steps}_seed{seed}.ckpt"));
    if ckpt.exists() {
        crate::info!("harness", "{artifact_base}: reusing {}", ckpt.display());
        let param_count = manifest.get(&format!("{artifact_base}.train"))?.param_count;
        let state = coordinator::load_checkpoint_for(&ckpt, artifact_base, param_count)?;
        return Ok((state, None));
    }
    let opts = TrainOpts {
        steps,
        log_every: (steps / 5).max(1),
        eval_every: 0,
        eval_batches: 4,
        seed,
        checkpoint: Some(ckpt.to_string_lossy().into_owned()),
        resume: None,
        domain: 0,
        metrics_every: 0,
    };
    let report = coordinator::train_lm(rt, manifest, artifact_base, &opts)?;
    Ok((coordinator::load_checkpoint(&ckpt)?, Some(report)))
}

/// Short-context held-out perplexity via the eval artifact.
pub fn short_ppl(
    rt: &Runtime,
    manifest: &Manifest,
    artifact_base: &str,
    flat: &[f32],
    batches: u64,
    noise: f32,
    domain: u64,
) -> Result<(f64, f32)> {
    let eval = EvalStep::new(rt, manifest, &format!("{artifact_base}.eval"))?;
    let entry = manifest.get(&format!("{artifact_base}.eval"))?;
    let mut cfg = CorpusConfig::default_for_vocab(entry.config.vocab);
    cfg.domain = domain;
    let mut data = LmBatcher::new(cfg, 0xE7A1, eval.batch, eval.n_plus_1);
    let params = eval.upload(flat)?; // §Perf L3-1
    let (mut nll, mut cnt, mut seff) = (0.0, 0.0, 0.0f32);
    for i in 0..batches {
        let toks = data.next_batch();
        let (n, c, s) = eval.run_h(&params, &toks, noise, i as i32)?;
        nll += n;
        cnt += c;
        seff = s;
    }
    Ok((perplexity(nll, cnt), seff))
}

/// Long-document corpus config: copy dependencies far beyond any single
/// training context (the Gutenberg-32k analogue).
pub fn long_corpus_cfg(vocab: usize) -> CorpusConfig {
    let mut c = CorpusConfig::default_for_vocab(vocab);
    c.copy_lag = (64, 1024);
    c.p_copy = 0.04;
    c
}

/// Streaming perplexity over one long document (stlt models): the carry
/// persists across chunks, so long-range copies remain visible.
pub fn stream_ppl(
    rt: &Runtime,
    manifest: &Manifest,
    artifact_base: &str,
    flat: &[f32],
    doc_len: usize,
    seed: u64,
) -> Result<f64> {
    let stream = StreamStep::new(rt, manifest, &format!("{artifact_base}.stream"))?;
    let entry = manifest.get(&format!("{artifact_base}.stream"))?;
    let mut corpus = Corpus::new(long_corpus_cfg(entry.config.vocab), seed);
    let doc = corpus.take(doc_len + 1);
    let params = stream.upload(flat)?; // §Perf L3-1
    let mut carry = stream.zero_carry();
    let c = stream.chunk;
    let (mut nll, mut cnt) = (0.0, 0.0);
    let mut off = 0usize;
    while off + 1 < doc.len() {
        let take = c.min(doc.len() - 1 - off);
        let mut toks = vec![0i32; c];
        let mut tgts = vec![0i32; c];
        let mut mask = vec![0f32; c];
        for j in 0..take {
            toks[j] = doc[off + j];
            tgts[j] = doc[off + j + 1];
            mask[j] = 1.0;
        }
        let (n, ct) = stream.run_h(&params, &mut carry, &toks, &tgts, &mask)?;
        nll += n;
        cnt += ct;
        off += take;
    }
    Ok(perplexity(nll, cnt))
}

/// Chunked perplexity over the same long document for context-reset
/// baselines: the model sees windows of its training context only.
pub fn chunked_ppl(
    rt: &Runtime,
    manifest: &Manifest,
    artifact_base: &str,
    flat: &[f32],
    doc_len: usize,
    seed: u64,
) -> Result<f64> {
    let eval = EvalStep::new(rt, manifest, &format!("{artifact_base}.eval"))?;
    let entry = manifest.get(&format!("{artifact_base}.eval"))?;
    let mut corpus = Corpus::new(long_corpus_cfg(entry.config.vocab), seed);
    let window = eval.batch * eval.n_plus_1;
    let (mut nll, mut cnt) = (0.0, 0.0);
    let mut consumed = 0usize;
    let mut i = 0;
    while consumed < doc_len {
        // each eval batch consumes batch*n_plus_1 fresh tokens; context
        // resets at every row boundary (the "chunked" penalty)
        let toks = corpus.take(window);
        let (n, c, _) = eval.run(flat, &toks, 0.0, i)?;
        nll += n;
        cnt += c;
        consumed += window;
        i += 1;
    }
    Ok(perplexity(nll, cnt))
}

/// QA training rows: episodes with short distances packed to n_plus_1.
pub fn qa_training_batch(
    vocab: usize,
    b: usize,
    n_plus_1: usize,
    seed: u64,
    step: u64,
) -> Vec<i32> {
    use crate::data::longqa::{QaConfig, QaGen};
    use crate::util::rng::Rng;
    let mut rng = Rng::new(seed ^ step.wrapping_mul(0x9E37));
    let mut out = Vec::with_capacity(b * n_plus_1);
    for bi in 0..b {
        let dist = 8 + (rng.below(64) as usize); // distances within context
        let mut cfg = QaConfig::with_distance(vocab, dist);
        cfg.doc_len = dist + 16;
        let mut gen = QaGen::new(cfg, seed ^ (step * 131 + bi as u64));
        let mut row = Vec::with_capacity(n_plus_1);
        while row.len() < n_plus_1 {
            let s = gen.sample();
            row.extend_from_slice(&s.prompt);
            row.extend_from_slice(&s.answer);
        }
        row.truncate(n_plus_1);
        out.extend_from_slice(&row);
    }
    out
}

/// Greedy-generate an answer from a chunked forward model: keep only the
/// last `n_ctx` tokens of the prompt, then extend token by token.
pub fn chunked_generate(
    rt: &Runtime,
    manifest: &Manifest,
    artifact_base: &str,
    flat: &[f32],
    prompt: &[i32],
    n_answer: usize,
) -> Result<Vec<i32>> {
    let fwd = crate::runtime::Forward::new(rt, manifest, &format!("{artifact_base}.fwd"))?;
    let entry = manifest.get(&format!("{artifact_base}.fwd"))?;
    let vocab = entry.config.vocab;
    let n = fwd.n;
    let mut window: Vec<i32> = prompt[prompt.len().saturating_sub(n)..].to_vec();
    let mut out = Vec::new();
    for _ in 0..n_answer {
        let pos = window.len().min(n) - 1;
        let mut padded = window.clone();
        padded.resize(n, 0);
        let logits = fwd.run(flat, &padded)?;
        let l = logits.as_f32()?;
        let row = &l[pos * vocab..(pos + 1) * vocab];
        let tok = crate::metrics::argmax(row) as i32;
        out.push(tok);
        window.push(tok);
        if window.len() > n {
            window.remove(0);
        }
    }
    Ok(out)
}

/// Evaluate one QA sample with the streaming server path.
pub fn stream_qa_answer(
    server: &coordinator::Server,
    session: u64,
    sample: &QaSample,
    n_answer: usize,
) -> Result<Vec<i32>> {
    let seed_token = *sample.prompt.last().unwrap();
    server.feed(session, sample.prompt.clone(), false)?;
    let g = server.generate(session, seed_token, n_answer, None)?;
    server.release(session)?;
    Ok(g.tokens)
}

// ---------------------------------------------------------------------------
// Result tables
// ---------------------------------------------------------------------------

/// Ordered result table: rows of (label, column -> value).
#[derive(Default)]
pub struct Table {
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<(String, BTreeMap<String, String>)>,
}

impl Table {
    pub fn new(title: &str, columns: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, label: &str) -> &mut BTreeMap<String, String> {
        self.rows.push((label.to_string(), BTreeMap::new()));
        &mut self.rows.last_mut().unwrap().1
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        let label_w = self
            .rows
            .iter()
            .map(|(l, _)| l.len())
            .chain([5])
            .max()
            .unwrap();
        for (_, cells) in &self.rows {
            for (i, c) in self.columns.iter().enumerate() {
                widths[i] = widths[i].max(cells.get(c).map(|v| v.len()).unwrap_or(1));
            }
        }
        let mut s = format!("## {}\n", self.title);
        s.push_str(&format!("{:label_w$}", "model"));
        for (i, c) in self.columns.iter().enumerate() {
            s.push_str(&format!("  {:>w$}", c, w = widths[i]));
        }
        s.push('\n');
        s.push_str(&"-".repeat(label_w + widths.iter().map(|w| w + 2).sum::<usize>()));
        s.push('\n');
        for (label, cells) in &self.rows {
            s.push_str(&format!("{label:label_w$}"));
            for (i, c) in self.columns.iter().enumerate() {
                let v = cells.get(c).map(String::as_str).unwrap_or("-");
                s.push_str(&format!("  {:>w$}", v, w = widths[i]));
            }
            s.push('\n');
        }
        s
    }

    /// Persist as JSON under results/.
    pub fn save_json(&self, name: &str) -> Result<()> {
        let mut rows = Vec::new();
        for (label, cells) in &self.rows {
            let mut m: std::collections::BTreeMap<String, Json> = Default::default();
            m.insert("model".into(), Json::Str(label.clone()));
            for (k, v) in cells {
                m.insert(k.clone(), Json::Str(v.clone()));
            }
            rows.push(Json::Obj(m));
        }
        let j = Json::Obj(
            [
                ("title".to_string(), Json::Str(self.title.clone())),
                ("rows".to_string(), Json::Arr(rows)),
            ]
            .into_iter()
            .collect(),
        );
        let path = results_dir().join(format!("{name}.json"));
        std::fs::write(&path, j.to_string()).map_err(|e| anyhow!("{}: {e}", path.display()))?;
        Ok(())
    }
}

/// Load experiment scale config (steps etc.) from configs/exp.toml if
/// present, else defaults; env STLT_STEPS wins.
pub fn exp_steps(default: u64) -> u64 {
    let from_cfg = Config::load("configs/exp.toml")
        .ok()
        .map(|c| c.i64_or("exp.steps", default as i64) as u64)
        .unwrap_or(default);
    env_u64("STLT_STEPS", from_cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_and_saves() {
        let mut t = Table::new("Demo", &["ppl", "s_eff"]);
        t.row("stlt").insert("ppl".into(), "23.8".into());
        let r = t.render();
        assert!(r.contains("Demo") && r.contains("stlt") && r.contains("23.8"));
        assert!(r.contains("model"));
    }

    #[test]
    fn qa_training_batch_shape() {
        let b = qa_training_batch(256, 3, 129, 1, 0);
        assert_eq!(b.len(), 3 * 129);
        assert!(b.iter().all(|&t| (0..256).contains(&t)));
    }

    #[test]
    fn env_u64_default() {
        assert_eq!(env_u64("STLT_NONEXISTENT_VAR_X", 7), 7);
    }
}
