//! Lock-light metrics registry: atomic counters, gauges, and
//! fixed-bucket latency histograms, published under stable names.
//!
//! Design:
//!
//! * **Hot path never allocates or locks.** A metric handle is an
//!   `Arc` around plain atomics; `inc`/`set`/`record` are one relaxed
//!   flag load plus one-to-two relaxed RMWs. With metrics disabled
//!   ([`set_metrics`]) the cost collapses to the single flag load.
//! * **Registration is the only synchronized step.** The global name
//!   table is a `Mutex<BTreeMap>` touched at metric creation /
//!   (re)binding and at render time only.
//! * **Per-instance scoping via rebinding.** Components that own their
//!   own metric set (one [`crate::coordinator::ServerStats`] per
//!   server) create free-standing handles and *publish* them under
//!   registry names; the latest publication wins. This keeps instance
//!   counters exact (tests assert on their own server) while `stlt
//!   stats` sees the live instance — one data structure, no parallel
//!   bookkeeping.
//! * **One quantile implementation.** [`Hist`] mirrors the
//!   [`crate::metrics::Histogram`] bucket geometry with atomic slots
//!   and snapshots back into it, so every p50/p95/p99 anyone prints —
//!   CLI summaries, `Stats` frames, bench rows — comes from
//!   `Histogram::quantile`.

use std::collections::BTreeMap;

use crate::metrics::stats::{Histogram, HIST_SLOTS};
use crate::util::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use crate::util::sync::{Arc, Mutex, OnceLock};

// ORDERING audit note (PR 9): every atomic in this module carries an
// independent monotonic count or last-write-wins value; none publishes
// other memory. Cross-thread *handle* visibility (publish/rebind, the
// case that would be load-bearing) is ordered by the `table()` Mutex,
// not by these atomics — so Relaxed is correct throughout, and each
// site below documents why.

static METRICS_ON: AtomicBool = AtomicBool::new(true);

/// Is metric collection enabled? One relaxed load — this is the entire
/// disabled-path cost of any instrumented call site.
#[inline]
pub fn metrics_on() -> bool {
    // ORDERING: Relaxed — independent on/off knob; a stale read only
    // drops or admits a few metric updates around the toggle.
    METRICS_ON.load(Ordering::Relaxed)
}

/// Globally enable/disable metric collection (default: enabled). While
/// disabled, counters/gauges/histograms silently drop updates; the
/// overhead bench row compares decode throughput across this switch.
pub fn set_metrics(on: bool) {
    // ORDERING: Relaxed — see metrics_on(); nothing is gated on this
    // flag beyond the update itself.
    METRICS_ON.store(on, Ordering::Relaxed);
}

/// Monotonic event counter.
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Counter {
        Counter(AtomicU64::new(0))
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        if metrics_on() {
            // ORDERING: Relaxed — a count with no associated payload;
            // atomicity (no lost increments) is all that is needed.
            self.0.fetch_add(n, Ordering::Relaxed);
        }
    }

    pub fn get(&self) -> u64 {
        // ORDERING: Relaxed — render-time snapshot; exactness at a
        // given instant is not part of the contract.
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins instantaneous value (f64 bits in an `AtomicU64`).
#[derive(Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn new() -> Gauge {
        Gauge(AtomicU64::new(0))
    }

    #[inline]
    pub fn set(&self, v: f64) {
        if metrics_on() {
            // ORDERING: Relaxed — last-write-wins value, publishes
            // nothing else.
            self.0.store(v.to_bits(), Ordering::Relaxed);
        }
    }

    /// Raise the gauge to `v` if larger (running maximum). Correct for
    /// non-negative values only: IEEE-754 bit patterns of non-negative
    /// floats order like unsigned integers, so `fetch_max` on the bits
    /// is `max` on the values.
    #[inline]
    pub fn set_max(&self, v: f64) {
        if metrics_on() {
            debug_assert!(v >= 0.0);
            // ORDERING: Relaxed — running max; the RMW is atomic and
            // no other memory rides on it.
            self.0.fetch_max(v.to_bits(), Ordering::Relaxed);
        }
    }

    pub fn get(&self) -> f64 {
        // ORDERING: Relaxed — render-time snapshot read.
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Atomic mirror of [`Histogram`]: identical bucket geometry, relaxed
/// per-slot counters so concurrent threads record without a lock.
/// Quantiles are never computed here — [`Hist::snapshot`] rebuilds a
/// `Histogram` and all math happens in the one shared implementation.
pub struct Hist {
    buckets: Box<[AtomicU64]>,
}

fn geometry() -> &'static Histogram {
    static GEOM: OnceLock<Histogram> = OnceLock::new();
    GEOM.get_or_init(Histogram::new)
}

impl Default for Hist {
    fn default() -> Self {
        Self::new()
    }
}

impl Hist {
    pub fn new() -> Hist {
        Hist { buckets: (0..HIST_SLOTS).map(|_| AtomicU64::new(0)).collect() }
    }

    #[inline]
    pub fn record(&self, seconds: f64) {
        if metrics_on() {
            let b = geometry().bucket_of(seconds);
            // ORDERING: Relaxed — independent per-slot count.
            self.buckets[b].fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn count(&self) -> u64 {
        // ORDERING: Relaxed — snapshot read; slots read at slightly
        // different instants is inherent to a lock-free histogram.
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    /// Materialize the current counts as a [`Histogram`] for quantile /
    /// summary queries.
    pub fn snapshot(&self) -> Histogram {
        // ORDERING: Relaxed — see count(): per-slot snapshot reads.
        Histogram::from_buckets(self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect())
    }

    /// `n=.. p50=..ms p95=..ms p99=..ms` via [`Histogram::summary`].
    pub fn summary(&self) -> String {
        self.snapshot().summary()
    }
}

/// One registered metric: the registry holds a strong handle so a
/// rendered snapshot never races an owner dropping its stats.
#[derive(Clone)]
pub enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Hist(Arc<Hist>),
}

fn table() -> &'static Mutex<BTreeMap<String, Metric>> {
    static TABLE: OnceLock<Mutex<BTreeMap<String, Metric>>> = OnceLock::new();
    TABLE.get_or_init(|| Mutex::new(BTreeMap::new()))
}

fn get_or_insert(name: &str, make: impl FnOnce() -> Metric) -> Metric {
    let mut t = table().lock().unwrap_or_else(|e| e.into_inner());
    t.entry(name.to_string()).or_insert_with(make).clone()
}

/// Get-or-create the process-wide counter `name`. If `name` is bound to
/// a different metric kind, a fresh unregistered counter is returned
/// (callers keep working; the registry keeps its original binding).
pub fn counter(name: &str) -> Arc<Counter> {
    match get_or_insert(name, || Metric::Counter(Arc::new(Counter::new()))) {
        Metric::Counter(c) => c,
        _ => Arc::new(Counter::new()),
    }
}

pub fn gauge(name: &str) -> Arc<Gauge> {
    match get_or_insert(name, || Metric::Gauge(Arc::new(Gauge::new()))) {
        Metric::Gauge(g) => g,
        _ => Arc::new(Gauge::new()),
    }
}

pub fn hist(name: &str) -> Arc<Hist> {
    match get_or_insert(name, || Metric::Hist(Arc::new(Hist::new()))) {
        Metric::Hist(h) => h,
        _ => Arc::new(Hist::new()),
    }
}

/// Bind an instance-owned metric under `name`, replacing any previous
/// binding (latest instance wins — see module docs on scoping).
pub fn publish(name: &str, metric: Metric) {
    let mut t = table().lock().unwrap_or_else(|e| e.into_inner());
    t.insert(name.to_string(), metric);
}

/// A consistent copy of the registry contents, name-sorted.
pub fn entries() -> Vec<(String, Metric)> {
    let t = table().lock().unwrap_or_else(|e| e.into_inner());
    t.iter().map(|(k, v)| (k.clone(), v.clone())).collect()
}

/// Statically-named counter for hot call sites: resolves its registry
/// handle once, costs one `OnceLock` load afterwards. `const`-
/// constructible so it can live in a `static`.
pub struct LazyCounter {
    name: &'static str,
    cell: OnceLock<Arc<Counter>>,
}

impl LazyCounter {
    pub const fn new(name: &'static str) -> LazyCounter {
        LazyCounter { name, cell: OnceLock::new() }
    }

    #[inline]
    fn get(&self) -> &Counter {
        self.cell.get_or_init(|| counter(self.name))
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        if metrics_on() {
            // ORDERING: Relaxed — same contract as Counter::add.
            self.get().0.fetch_add(n, Ordering::Relaxed);
        }
    }

    pub fn value(&self) -> u64 {
        self.get().get()
    }
}

/// Statically-named gauge (see [`LazyCounter`]).
pub struct LazyGauge {
    name: &'static str,
    cell: OnceLock<Arc<Gauge>>,
}

impl LazyGauge {
    pub const fn new(name: &'static str) -> LazyGauge {
        LazyGauge { name, cell: OnceLock::new() }
    }

    #[inline]
    fn get(&self) -> &Gauge {
        self.cell.get_or_init(|| gauge(self.name))
    }

    #[inline]
    pub fn set(&self, v: f64) {
        self.get().set(v);
    }

    pub fn value(&self) -> f64 {
        self.get().get()
    }
}

/// Statically-named histogram (see [`LazyCounter`]).
pub struct LazyHist {
    name: &'static str,
    cell: OnceLock<Arc<Hist>>,
}

impl LazyHist {
    pub const fn new(name: &'static str) -> LazyHist {
        LazyHist { name, cell: OnceLock::new() }
    }

    #[inline]
    fn get(&self) -> &Hist {
        self.cell.get_or_init(|| hist(self.name))
    }

    #[inline]
    pub fn record(&self, seconds: f64) {
        self.get().record(seconds);
    }

    pub fn snapshot(&self) -> Histogram {
        self.get().snapshot()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_basics() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        let g = Gauge::new();
        g.set(2.5);
        assert_eq!(g.get(), 2.5);
        g.set_max(1.0);
        assert_eq!(g.get(), 2.5);
        g.set_max(7.0);
        assert_eq!(g.get(), 7.0);
    }

    #[test]
    fn registry_interns_by_name() {
        let a = counter("test/registry_interns");
        let b = counter("test/registry_interns");
        a.inc();
        assert_eq!(b.get(), 1);
        // kind mismatch yields a detached (but functional) handle
        let g = gauge("test/registry_interns");
        g.set(3.0);
        assert_eq!(g.get(), 3.0);
        assert_eq!(b.get(), 1);
    }

    #[test]
    fn publish_rebinds_latest_instance() {
        let first = Arc::new(Counter::new());
        first.add(10);
        publish("test/rebind", Metric::Counter(Arc::clone(&first)));
        let second = Arc::new(Counter::new());
        second.add(2);
        publish("test/rebind", Metric::Counter(Arc::clone(&second)));
        let bound = counter("test/rebind");
        assert_eq!(bound.get(), 2, "latest publication wins");
        assert_eq!(first.get(), 10, "replaced instance keeps its counts");
    }

    /// Satellite: concurrent increments from the shared threadpool sum
    /// exactly — no lost updates, no double counting.
    #[test]
    fn concurrent_counter_sums_exactly() {
        let c = Arc::new(Counter::new());
        let pool = crate::util::threadpool::ThreadPool::new(4);
        let jobs = 64;
        let per_job = 1000u64;
        for _ in 0..jobs {
            let c = Arc::clone(&c);
            pool.execute(move || {
                for _ in 0..per_job {
                    c.inc();
                }
            });
        }
        pool.join();
        assert_eq!(c.get(), jobs as u64 * per_job);
    }

    /// Satellite: the atomic histogram's quantiles are bit-identical to
    /// the plain `metrics::Histogram` fed the same samples (single
    /// quantile implementation), and both agree with a sorted-vec
    /// oracle to within one log bucket.
    #[test]
    fn hist_matches_oracle_and_shared_impl() {
        let h = Hist::new();
        let mut plain = Histogram::new();
        let mut vals: Vec<f64> = Vec::new();
        // deterministic pseudo-random latencies, 10us..1s
        let mut x = 0x9e3779b97f4a7c15u64;
        for _ in 0..4096 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let v = 1e-5 + (x >> 11) as f64 / (1u64 << 53) as f64;
            h.record(v);
            plain.record(v);
            vals.push(v);
        }
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let snap = h.snapshot();
        assert_eq!(snap.count(), plain.count());
        // log-bucket geometry: ratio between adjacent bucket edges
        let ratio = (100.0f64 / 1e-6).powf(1.0 / 200.0);
        for q in [0.01, 0.5, 0.95, 0.99, 1.0] {
            let ours = snap.quantile(q);
            assert_eq!(ours.to_bits(), plain.quantile(q).to_bits(), "shared impl at q={q}");
            let target = ((q * vals.len() as f64).ceil() as usize).clamp(1, vals.len());
            let exact = vals[target - 1];
            assert!(
                ours <= exact && exact <= ours * ratio * 1.0001,
                "q={q}: bucket edge {ours} should bracket exact {exact}"
            );
        }
    }

    #[test]
    fn hist_oracle_edge_cases() {
        // empty: quantile is 0 by convention
        let h = Hist::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.snapshot().quantile(0.5), 0.0);
        // single sample: every quantile lands in its bucket
        let h = Hist::new();
        h.record(0.004);
        let snap = h.snapshot();
        for q in [0.0, 0.5, 1.0] {
            let v = snap.quantile(q);
            assert!(v <= 0.004 && 0.004 <= v * 1.1, "q={q} -> {v}");
        }
        // saturating buckets: everything beyond the range piles into the
        // overflow slot and quantiles clamp to the top edge
        let h = Hist::new();
        for _ in 0..10 {
            h.record(1e6);
        }
        let snap = h.snapshot();
        assert_eq!(snap.count(), 10);
        let top = snap.quantile(1.0);
        assert!(top >= 99.0, "overflow bucket reports the top edge, got {top}");
        // underflow side
        let h = Hist::new();
        h.record(0.0);
        assert!(h.snapshot().quantile(1.0) <= 1e-6);
    }
}
