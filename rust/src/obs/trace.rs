//! Span tracer: per-thread ring buffers of completed spans, drained on
//! demand into Chrome trace-event JSON (open `chrome://tracing` or
//! <https://ui.perfetto.dev> and load the file).
//!
//! Disabled (the default) a span costs one relaxed atomic load.
//! Enabled, [`span`] stamps the start against the shared
//! [`crate::util::logging::timebase`] and the returned guard records
//! one complete event (`ph: "X"`) into its thread's fixed-capacity
//! ring on drop — no allocation per span (names are `&'static str`),
//! no cross-thread contention (each ring has its own mutex, locked by
//! its owner thread and, briefly, by the drainer). When a ring wraps,
//! the oldest events are overwritten and counted in
//! `stlt.dropped_events` metadata so truncation is never silent.

use crate::util::logging::timebase;
use crate::util::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use crate::util::sync::{Arc, Mutex, OnceLock};

static TRACE_ON: AtomicBool = AtomicBool::new(false);

/// Is span tracing enabled? One relaxed load on the disabled path.
#[inline]
pub fn trace_on() -> bool {
    // ORDERING: Relaxed — on/off knob only; span data is published via
    // each ring's own Mutex, not via this flag. A stale read merely
    // starts/stops recording one span late.
    TRACE_ON.load(Ordering::Relaxed)
}

/// Globally enable/disable span collection (default: disabled; `stlt
/// serve --trace FILE` and the `STLT_TRACE` env switch it on).
pub fn set_tracing(on: bool) {
    // ORDERING: Relaxed — see trace_on(): the flag gates no other memory.
    TRACE_ON.store(on, Ordering::Relaxed);
}

/// Events kept per thread before the ring wraps (oldest dropped).
const RING_CAP: usize = 16 * 1024;

#[derive(Clone, Copy)]
struct Event {
    cat: &'static str,
    name: &'static str,
    ts_us: u64,
    dur_us: u64,
}

struct Ring {
    events: Vec<Event>,
    /// next write slot; wraps modulo RING_CAP once full
    head: usize,
    dropped: u64,
}

impl Ring {
    fn push(&mut self, ev: Event) {
        if self.events.len() < RING_CAP {
            self.events.push(ev);
        } else {
            self.events[self.head % RING_CAP] = ev;
            self.dropped += 1;
        }
        self.head = (self.head + 1) % RING_CAP;
    }
}

struct ThreadRing {
    tid: u64,
    ring: Mutex<Ring>,
}

fn rings() -> &'static Mutex<Vec<Arc<ThreadRing>>> {
    static RINGS: OnceLock<Mutex<Vec<Arc<ThreadRing>>>> = OnceLock::new();
    RINGS.get_or_init(|| Mutex::new(Vec::new()))
}

static NEXT_TID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    static LOCAL: Arc<ThreadRing> = {
        let tr = Arc::new(ThreadRing {
            // ORDERING: Relaxed — the counter only needs uniqueness;
            // the ring itself is published through rings()'s Mutex.
            tid: NEXT_TID.fetch_add(1, Ordering::Relaxed),
            ring: Mutex::new(Ring { events: Vec::new(), head: 0, dropped: 0 }),
        });
        rings().lock().unwrap_or_else(|e| e.into_inner()).push(Arc::clone(&tr));
        tr
    };
}

/// Open span: records itself into the owning thread's ring on drop.
pub struct SpanGuard {
    cat: &'static str,
    name: &'static str,
    t0_us: u64,
}

fn now_us() -> u64 {
    timebase().elapsed().as_micros() as u64
}

/// Start a span if tracing is enabled (`None` otherwise — the idiom is
/// `let _s = obs::span("scheduler", "decode_wave");`). `cat` groups
/// related spans into one Perfetto track-filterable category.
#[inline]
pub fn span(cat: &'static str, name: &'static str) -> Option<SpanGuard> {
    if !trace_on() {
        return None;
    }
    Some(SpanGuard { cat, name, t0_us: now_us() })
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let end = now_us();
        let ev = Event {
            cat: self.cat,
            name: self.name,
            ts_us: self.t0_us,
            dur_us: end.saturating_sub(self.t0_us),
        };
        LOCAL.with(|tr| {
            tr.ring.lock().unwrap_or_else(|e| e.into_inner()).push(ev);
        });
    }
}

fn escape_into(out: &mut String, s: &str) {
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
}

/// Drain every thread's ring into a Chrome trace-event JSON document
/// and clear the rings. Events come out in ring order per thread
/// (viewers sort by `ts` themselves).
pub fn drain_json() -> String {
    let mut out = String::from("{\"traceEvents\":[");
    let mut first = true;
    let mut dropped = 0u64;
    let rings: Vec<Arc<ThreadRing>> =
        rings().lock().unwrap_or_else(|e| e.into_inner()).clone();
    for tr in rings {
        let mut ring = tr.ring.lock().unwrap_or_else(|e| e.into_inner());
        dropped += ring.dropped;
        ring.dropped = 0;
        ring.head = 0;
        for ev in ring.events.drain(..) {
            if !first {
                out.push(',');
            }
            first = false;
            out.push_str("{\"name\":\"");
            escape_into(&mut out, ev.name);
            out.push_str("\",\"cat\":\"");
            escape_into(&mut out, ev.cat);
            out.push_str(&format!(
                "\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\"pid\":1,\"tid\":{}}}",
                ev.ts_us, ev.dur_us, tr.tid
            ));
        }
    }
    out.push_str(&format!(
        "],\"displayTimeUnit\":\"ms\",\"stlt\":{{\"dropped_events\":{dropped}}}}}"
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Both tests below flip the process-global TRACE_ON flag; cargo
    /// runs tests concurrently, so serialize them.
    static FLAG_LOCK: Mutex<()> = Mutex::new(());

    /// Golden test for the exporter: shape, required fields, escaping.
    #[test]
    fn trace_json_golden() {
        let _l = FLAG_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_tracing(true);
        {
            let _a = span("testcat", "golden_span");
            std::thread::sleep(std::time::Duration::from_millis(2));
            let _b = span("testcat", "inner\"quote");
        }
        set_tracing(false);
        let json = drain_json();
        assert!(json.starts_with("{\"traceEvents\":["), "{json}");
        assert!(json.ends_with('}'), "{json}");
        assert!(json.contains(
            "{\"name\":\"golden_span\",\"cat\":\"testcat\",\"ph\":\"X\",\"ts\":"
        ));
        assert!(json.contains("\"name\":\"inner\\\"quote\""), "escaped quote: {json}");
        assert!(json.contains("\"dropped_events\":"));
        // the outer span slept ~2ms; its dur must reflect that
        let dur = json
            .split("\"name\":\"golden_span\"")
            .nth(1)
            .and_then(|s| s.split("\"dur\":").nth(1))
            .and_then(|s| s.split(',').next())
            .and_then(|s| s.parse::<u64>().ok())
            .expect("golden_span has a dur field");
        assert!(dur >= 1_000, "2ms span recorded dur={dur}us");
        // drained rings are empty on the second pass
        let empty = drain_json();
        assert!(!empty.contains("golden_span"), "{empty}");
    }

    #[test]
    fn disabled_spans_record_nothing() {
        let _l = FLAG_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_tracing(false);
        assert!(span("x", "y").is_none());
    }
}
