//! Dependency-free observability: metrics registry + span tracer +
//! exposition plumbing.
//!
//! Three layers (ROADMAP: production serving needs a window into
//! itself; PAPER: per-node sigma/omega/T are the interpretability
//! story and deserve first-class telemetry):
//!
//! * [`registry`] — atomic counters / gauges / fixed-bucket latency
//!   histograms published under stable names. Enabled by default;
//!   disabling ([`set_metrics`]) reduces every instrumented site to a
//!   single relaxed atomic load (the `obs/decode` bench rows pin the
//!   enabled-vs-disabled decode cost).
//! * [`trace`] — spans around the load-bearing paths (scheduler waves,
//!   panel packing, scatter chunks, backward segment replay, wire
//!   encode/decode, carry migration), buffered in per-thread rings and
//!   exported as Chrome trace-event JSON for Perfetto. Off by default.
//! * [`expo`] — the `stlt`-text exposition format behind `stlt stats
//!   --connect`, the wire `Stats`/`StatsOk` frames, and the
//!   `--metrics-every` heartbeat lines.
//!
//! ## Metric name catalogue
//!
//! | family | metrics |
//! |---|---|
//! | `server/` | `feeds`, `gens`, `evictions`, `shed`, `cancelled`, `tokens_streamed`, `tokens_generated`, `waves`, `wave_rows`, `wave_max_fill`, `feed_seconds`, `gen_seconds`, `ttft_seconds` |
//! | `scheduler/` | `park_depth`, `parked_total` |
//! | `wire/` | `frames_tx`, `frames_rx`, `bytes_tx`, `bytes_rx` |
//! | `router/` | `migrations`, `migrate_seconds`, `sessions_open` |
//! | `panels/` | `bind_hits`, `bind_packs` |
//! | `train/` | `tape_bytes`, `segments_replayed` |
//! | `node/` | `l{L}/n{K}/{sigma,omega,t,half_life}`, `l{L}/half_life_mean` |
//! | `serve_cli/` | `ttft_seconds` (client-observed, `stlt serve`) |

pub mod expo;
pub mod registry;
pub mod trace;

pub use expo::{parse, render, summary_line, EXPO_VERSION};
pub use registry::{
    counter, gauge, hist, metrics_on, publish, set_metrics, Counter, Gauge, Hist, LazyCounter,
    LazyGauge, LazyHist, Metric,
};
pub use trace::{drain_json, set_tracing, span, trace_on, SpanGuard};

/// Apply the `STLT_METRICS` / `STLT_TRACE` env switches (`0`/`off` to
/// disable metrics; any non-empty value to enable tracing). Called from
/// `main`; library users flip the flags directly.
pub fn init_from_env() {
    if let Ok(v) = std::env::var("STLT_METRICS") {
        let off = matches!(v.as_str(), "0" | "off" | "false");
        set_metrics(!off);
    }
    if let Ok(v) = std::env::var("STLT_TRACE") {
        if !v.is_empty() && v != "0" {
            set_tracing(true);
        }
    }
}
