//! `stlt`-text exposition format: the line protocol `stlt stats`
//! prints and the wire `StatsOk` frame carries.
//!
//! ```text
//! # stlt-metrics v1
//! counter server/feeds 12
//! gauge scheduler/park_depth 0
//! hist server/ttft_seconds 12 0.000912 0.003113 0.004920
//! ```
//!
//! One metric per line: `KIND NAME VALUE...`, name-sorted. Counter
//! values are u64; gauge values f64 (Rust `Display`, round-trips
//! through `f64::from_str`); hist lines carry `count p50_s p95_s
//! p99_s` computed by the shared [`crate::metrics::Histogram`]
//! implementation. Lines starting with `#` are comments; the first
//! line names the format version ([`EXPO_VERSION`], also carried as a
//! field of the `StatsOk` frame so old clients can refuse new text).

use super::registry::{entries, Metric};

/// Version of the exposition text format (bump on breaking changes).
pub const EXPO_VERSION: u16 = 1;

/// Render the whole registry in exposition format.
pub fn render() -> String {
    let mut out = format!("# stlt-metrics v{EXPO_VERSION}\n");
    for (name, metric) in entries() {
        match metric {
            Metric::Counter(c) => {
                out.push_str(&format!("counter {name} {}\n", c.get()));
            }
            Metric::Gauge(g) => {
                out.push_str(&format!("gauge {name} {}\n", g.get()));
            }
            Metric::Hist(h) => {
                let s = h.snapshot();
                out.push_str(&format!(
                    "hist {name} {} {} {} {}\n",
                    s.count(),
                    s.quantile(0.5),
                    s.quantile(0.95),
                    s.quantile(0.99)
                ));
            }
        }
    }
    out
}

/// One-line digest for `--metrics-every` heartbeats: every counter and
/// gauge as `name=value`, every histogram as `name.p50_ms=..`, skipping
/// the (large) per-node `node/` family.
pub fn summary_line() -> String {
    let mut parts = Vec::new();
    for (name, metric) in entries() {
        if name.starts_with("node/") {
            continue;
        }
        match metric {
            Metric::Counter(c) => parts.push(format!("{name}={}", c.get())),
            Metric::Gauge(g) => parts.push(format!("{name}={:.3}", g.get())),
            Metric::Hist(h) => {
                let s = h.snapshot();
                parts.push(format!(
                    "{name}.n={} {name}.p50_ms={:.3}",
                    s.count(),
                    s.quantile(0.5) * 1e3
                ));
            }
        }
    }
    parts.join(" ")
}

/// Parse one exposition document into `(kind, name, values)` rows —
/// used by tests and by anything scraping `stlt stats` output.
pub fn parse(text: &str) -> Result<Vec<(String, String, Vec<f64>)>, String> {
    let mut rows = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut it = line.split_whitespace();
        let kind = it.next().ok_or_else(|| format!("empty row: {line:?}"))?;
        let name = it.next().ok_or_else(|| format!("row without name: {line:?}"))?;
        let vals: Result<Vec<f64>, _> = it.map(|v| v.parse::<f64>()).collect();
        let vals = vals.map_err(|e| format!("bad value in {line:?}: {e}"))?;
        let want = match kind {
            "counter" | "gauge" => 1,
            "hist" => 4,
            other => return Err(format!("unknown metric kind {other:?}")),
        };
        if vals.len() != want {
            return Err(format!("{kind} row wants {want} values, got {}: {line:?}", vals.len()));
        }
        rows.push((kind.to_string(), name.to_string(), vals));
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::super::registry;
    use super::*;

    #[test]
    fn render_parse_round_trip() {
        registry::counter("expo_test/ticks").add(7);
        registry::gauge("expo_test/depth").set(1.5);
        registry::hist("expo_test/lat").record(0.01);
        let text = render();
        assert!(text.starts_with("# stlt-metrics v1\n"), "{text}");
        let rows = parse(&text).expect("rendered text parses");
        let find = |k: &str, n: &str| {
            rows.iter().find(|(kind, name, _)| kind == k && name == n).cloned()
        };
        let (_, _, c) = find("counter", "expo_test/ticks").expect("counter row");
        assert!(c[0] >= 7.0);
        let (_, _, g) = find("gauge", "expo_test/depth").expect("gauge row");
        assert_eq!(g[0], 1.5);
        let (_, _, h) = find("hist", "expo_test/lat").expect("hist row");
        assert!(h[0] >= 1.0, "count recorded");
        assert!(h[1] > 0.0 && h[1] <= 0.01, "p50 is the bucket lower edge");
    }

    #[test]
    fn parse_rejects_malformed() {
        assert!(parse("counter only_name\n").is_err());
        assert!(parse("widget a/b 1\n").is_err());
        assert!(parse("gauge a/b not_a_number\n").is_err());
        assert!(parse("# comment\n\n").unwrap().is_empty());
    }

    #[test]
    fn summary_line_skips_node_family() {
        registry::gauge("node/l0/n0/half_life").set(9.0);
        registry::counter("expo_test/in_line").inc();
        let line = summary_line();
        assert!(!line.contains("node/"), "{line}");
        assert!(line.contains("expo_test/in_line="), "{line}");
    }
}
