//! Native backend: executes STLT manifest entries directly in Rust via
//! [`crate::runtime::native_stlt`] — no XLA, no PJRT, no Python.
//!
//! Supported entry kinds: `eval_step`, `forward`, `stream_step`,
//! `stream_batch_step`, `decode_step` (the inference/serving surface)
//! and `train_step` (the [`crate::train`] subsystem: hand-derived
//! backward pass + pure-Rust AdamW + data-parallel gradient
//! accumulation). Seq2seq kinds (`s2s_*`) remain xla-only.
//!
//! Batch rows are independent in every supported kind (training rows
//! couple only through the final gradient mean), so they fan out
//! across [`crate::util::threadpool::ThreadPool`].

use anyhow::{bail, Context, Result};

use crate::runtime::artifact::Entry;
use crate::runtime::backend::{Backend, DeviceBuffer, Executable};
use crate::runtime::native_stlt::{nll_of, StltModel, StltPlan};
use crate::runtime::tensor::Tensor;
use crate::util::sync::Arc;
use crate::util::threadpool::{self, parallel_map, ThreadPool};

/// Host-resident "device" buffer: the native device *is* the host.
pub struct NativeBuffer {
    data: Arc<Vec<f32>>,
}

impl NativeBuffer {
    pub fn data(&self) -> &Arc<Vec<f32>> {
        &self.data
    }
}

impl DeviceBuffer for NativeBuffer {
    fn len(&self) -> usize {
        self.data.len()
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

pub struct NativeBackend {
    /// The process-wide shared pool — per-backend pools would stack on
    /// top of the row-parallel kernel paths and oversubscribe the
    /// cores; nested fan-outs run inline (`threadpool::in_worker`).
    pool: &'static ThreadPool,
}

impl NativeBackend {
    pub fn new() -> NativeBackend {
        NativeBackend { pool: threadpool::global() }
    }
}

impl Default for NativeBackend {
    fn default() -> Self {
        NativeBackend::new()
    }
}

const SUPPORTED: &[&str] = &[
    "eval_step",
    "forward",
    "stream_step",
    "stream_batch_step",
    "decode_step",
    "decode_batch",
    "train_step",
];

impl Backend for NativeBackend {
    fn platform(&self) -> String {
        "native".to_string()
    }

    fn load(&self, entry: &Entry) -> Result<Arc<dyn Executable>> {
        if !SUPPORTED.contains(&entry.kind.as_str()) {
            bail!(
                "{}: kind '{}' is not supported by the native backend \
                 (supported: {SUPPORTED:?}; seq2seq requires --features xla)",
                entry.name,
                entry.kind
            );
        }
        // resolve the execution plan once here: dispatch only binds the
        // parameter vector, keeping the per-token decode path allocation-lean
        let plan = StltPlan::new(&entry.config)
            .with_context(|| format!("{}: unsupported by the native backend", entry.name))?;
        Ok(Arc::new(NativeExec { entry: entry.clone(), plan, pool: self.pool }))
    }

    fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<Box<dyn DeviceBuffer>> {
        let numel: usize = dims.iter().product::<usize>().max(1);
        if data.len() != numel {
            bail!("upload_f32: {} elements vs dims {:?}", data.len(), dims);
        }
        Ok(Box::new(NativeBuffer { data: Arc::new(data.to_vec()) }))
    }

    fn supports_kind(&self, kind: &str) -> bool {
        SUPPORTED.contains(&kind)
    }
}

pub struct NativeExec {
    entry: Entry,
    plan: StltPlan,
    pool: &'static ThreadPool,
}

impl Executable for NativeExec {
    fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        if inputs.is_empty() {
            bail!("{}: no inputs", self.entry.name);
        }
        let flat = Arc::new(inputs[0].as_f32()?.to_vec());
        self.dispatch(flat, &inputs[1..])
    }

    fn run_with_params(&self, params: &dyn DeviceBuffer, rest: &[Tensor]) -> Result<Vec<Tensor>> {
        let buf = params
            .as_any()
            .downcast_ref::<NativeBuffer>()
            .context("parameter buffer was not uploaded by the native backend")?;
        self.dispatch(Arc::clone(buf.data()), rest)
    }
}

impl NativeExec {
    /// `rest` holds the manifest inputs after the parameter vector.
    fn dispatch(&self, flat: Arc<Vec<f32>>, rest: &[Tensor]) -> Result<Vec<Tensor>> {
        let model = self.plan.bind(flat)
            .with_context(|| format!("binding params for {}", self.entry.name))?;
        match self.entry.kind.as_str() {
            "eval_step" => self.eval_step(model, rest),
            "forward" => self.forward(model, rest),
            "stream_step" => self.stream_step(model, rest),
            "stream_batch_step" => self.stream_batch_step(model, rest),
            "decode_step" => self.decode_step(model, rest),
            "decode_batch" => self.decode_batch(model, rest),
            "train_step" => self.train_step(model, rest),
            other => bail!("{}: unsupported kind '{other}'", self.entry.name),
        }
    }

    /// (m, v, step, tokens [B,N+1], seed) with device-resident flat ->
    /// (flat', m', v', loss, ce, s_eff) — the XLA `train_step` contract,
    /// implemented by [`crate::train`]. For adaptive configs the `seed`
    /// input drives the Gumbel-sigmoid gate relaxation (with the
    /// step-annealed temperature); otherwise the step is fully
    /// deterministic and the seed is inert. The backward tape is
    /// segment-checkpointed per `config.grad_ckpt_segment` (carried by
    /// the entry the plan was resolved from); gradients are bitwise
    /// identical for every segment length, so the knob never leaks into
    /// the contract outputs.
    fn train_step(&self, model: StltModel, rest: &[Tensor]) -> Result<Vec<Tensor>> {
        if rest.len() != 5 {
            bail!(
                "{}: train_step expects 5 inputs after the device-resident \
                 parameter vector — (m, v, step, tokens, seed); got {}",
                self.entry.name,
                rest.len()
            );
        }
        let mut flat = model.flat_params().to_vec();
        let mut m = rest[0].as_f32()?.to_vec();
        let mut v = rest[1].as_f32()?.to_vec();
        let step = rest[2].as_i32()?[0];
        let shape = rest[3].shape().to_vec();
        if shape.len() != 2 {
            bail!("{}: train_step tokens must be [B, N+1], got {shape:?}", self.entry.name);
        }
        let (b, n1) = (shape[0], shape[1]);
        let tokens = rest[3].as_i32()?;
        if m.len() != flat.len() || v.len() != flat.len() {
            bail!(
                "{}: moment vectors ({}, {}) do not match {} params",
                self.entry.name,
                m.len(),
                v.len(),
                flat.len()
            );
        }
        let seed = rest[4].as_i32()?[0] as u64;
        let metrics = crate::train::native_train_step(
            &model, &mut flat, &mut m, &mut v, step, tokens, b, n1, seed, &self.pool,
        )?;
        crate::debuglog!(
            "native",
            "{}: step {} peak backward tape {} bytes/row",
            self.entry.name,
            step,
            metrics.tape_bytes
        );
        let p = flat.len();
        Ok(vec![
            Tensor::f32(flat, &[p]),
            Tensor::f32(m, &[p]),
            Tensor::f32(v, &[p]),
            Tensor::scalar_f32(metrics.loss),
            Tensor::scalar_f32(metrics.ce),
            Tensor::scalar_f32(metrics.s_eff),
        ])
    }

    /// (tokens [B,N+1], noise_std, seed) -> (nll_sum, count, s_eff).
    fn eval_step(&self, model: StltModel, rest: &[Tensor]) -> Result<Vec<Tensor>> {
        let shape = rest[0].shape().to_vec();
        let (b, n1) = (shape[0], shape[1]);
        let tokens = Arc::new(rest[0].as_i32()?.to_vec());
        let noise_std = rest[1].as_f32()?[0];
        let seed = rest[2].as_i32()?[0];
        let rows = parallel_map(&self.pool, b, move |i| {
            let row = &tokens[i * n1..(i + 1) * n1];
            model.eval_row(row, noise_std, (seed as u64) ^ ((i as u64) << 32))
        });
        let (mut nll, mut cnt, mut seff) = (0.0f64, 0.0f64, 0.0f32);
        for r in rows {
            let (n, c, s) = r?;
            nll += n;
            cnt += c;
            seff += s;
        }
        Ok(vec![
            Tensor::scalar_f32(nll as f32),
            Tensor::scalar_f32(cnt as f32),
            Tensor::scalar_f32(seff / b.max(1) as f32),
        ])
    }

    /// (tokens [B,N]) -> logits [B,N,V].
    fn forward(&self, model: StltModel, rest: &[Tensor]) -> Result<Vec<Tensor>> {
        let shape = rest[0].shape().to_vec();
        let (b, n) = (shape[0], shape[1]);
        let v = model.cfg.vocab;
        let tokens = Arc::new(rest[0].as_i32()?.to_vec());
        let rows = parallel_map(&self.pool, b, move |i| {
            model.forward_logits(&tokens[i * n..(i + 1) * n])
        });
        let mut logits = Vec::with_capacity(b * n * v);
        for r in rows {
            logits.extend(r?);
        }
        Ok(vec![Tensor::f32(logits, &[b, n, v])])
    }

    /// (l, u, tokens[C], targets[C], mask[C]) -> (l', u', nll, count).
    fn stream_step(&self, model: StltModel, rest: &[Tensor]) -> Result<Vec<Tensor>> {
        let mut l = rest[0].as_f32()?.to_vec();
        let mut u = rest[1].as_f32()?.to_vec();
        let tokens = rest[2].as_i32()?;
        let targets = rest[3].as_i32()?;
        let mask = rest[4].as_f32()?;
        let (logits, _) = model.trunk_chunk(&mut l, &mut u, tokens, 0.0, None)?;
        let (nll, cnt) = masked_nll(&logits, model.cfg.vocab, targets, mask)?;
        Ok(vec![
            Tensor::f32(l, rest[0].shape()),
            Tensor::f32(u, rest[1].shape()),
            Tensor::scalar_f32(nll as f32),
            Tensor::scalar_f32(cnt as f32),
        ])
    }

    /// Batched serving chunk with inactive-row passthrough, matching
    /// `train.make_stream_batch_step`: rows with active=0 keep their
    /// carry and contribute nothing.
    fn stream_batch_step(&self, model: StltModel, rest: &[Tensor]) -> Result<Vec<Tensor>> {
        let l_all = Arc::new(rest[0].as_f32()?.to_vec());
        let u_all = Arc::new(rest[1].as_f32()?.to_vec());
        let tokens = Arc::new(rest[2].as_i32()?.to_vec());
        let targets = Arc::new(rest[3].as_i32()?.to_vec());
        let mask = Arc::new(rest[4].as_f32()?.to_vec());
        let active = rest[5].as_f32()?.to_vec();
        let b = rest[5].shape()[0];
        let c = rest[2].shape()[1];
        let l_stride = l_all.len() / b.max(1);
        let u_stride = u_all.len() / b.max(1);
        let vocab = model.cfg.vocab;
        let act = Arc::new(active);
        let act2 = Arc::clone(&act);
        let rows = parallel_map(&self.pool, b, move |i| {
            let mut l = l_all[i * l_stride..(i + 1) * l_stride].to_vec();
            let mut u = u_all[i * u_stride..(i + 1) * u_stride].to_vec();
            if act2[i] <= 0.5 {
                return Ok((l, u, 0.0f64, 0.0f64));
            }
            let toks = &tokens[i * c..(i + 1) * c];
            let tgts = &targets[i * c..(i + 1) * c];
            let msk = &mask[i * c..(i + 1) * c];
            let (logits, _) = model.trunk_chunk(&mut l, &mut u, toks, 0.0, None)?;
            let (nll, cnt) = masked_nll(&logits, vocab, tgts, msk)?;
            Ok::<_, anyhow::Error>((l, u, nll, cnt))
        });
        let mut l_out = Vec::with_capacity(b * l_stride);
        let mut u_out = Vec::with_capacity(b * u_stride);
        let mut nll_out = Vec::with_capacity(b);
        let mut cnt_out = Vec::with_capacity(b);
        for r in rows {
            let (l, u, nll, cnt) = r?;
            l_out.extend(l);
            u_out.extend(u);
            nll_out.push(nll as f32);
            cnt_out.push(cnt as f32);
        }
        Ok(vec![
            Tensor::f32(l_out, rest[0].shape()),
            Tensor::f32(u_out, rest[1].shape()),
            Tensor::f32(nll_out, &[b]),
            Tensor::f32(cnt_out, &[b]),
        ])
    }

    /// (l [B,…], u [B,…], tokens [B], active [B]) -> (l', u',
    /// logits [B, V]): the continuous-batching serving step
    /// ([`crate::runtime::artifact::Entry::to_decode_batch`]). The wave
    /// splits into one contiguous row chunk per worker, and each chunk
    /// runs the engine's batched single-token forward
    /// ([`StltModel::decode_step_batch`]) over its rows — thread
    /// parallelism across chunks, panel reuse across the rows inside
    /// one. Per-row results are bitwise independent of the chunking
    /// (rows never interact), so every row equals a single-session
    /// `decode_step` on the same carry. Rows with `active <= 0.5` keep
    /// their carry and return zero logits.
    fn decode_batch(&self, model: StltModel, rest: &[Tensor]) -> Result<Vec<Tensor>> {
        if rest.len() != 4 {
            bail!(
                "{}: decode_batch expects 4 inputs after the parameter vector \
                 — (l, u, tokens, active); got {}",
                self.entry.name,
                rest.len()
            );
        }
        let b = rest[2].shape().first().copied().unwrap_or(0);
        if b == 0 {
            bail!("{}: decode_batch with an empty batch", self.entry.name);
        }
        let l_all = Arc::new(rest[0].as_f32()?.to_vec());
        let u_all = Arc::new(rest[1].as_f32()?.to_vec());
        let tokens = Arc::new(rest[2].as_i32()?.to_vec());
        let active = Arc::new(rest[3].as_f32()?.to_vec());
        let l_stride = l_all.len() / b;
        let u_stride = u_all.len() / b;
        let vocab = model.cfg.vocab;
        let per = b.div_ceil(threadpool::configured_threads().min(b));
        let nch = b.div_ceil(per);
        let run_chunk = move |c: usize| {
            let (r0, r1) = (c * per, ((c + 1) * per).min(b));
            let mut l = l_all[r0 * l_stride..r1 * l_stride].to_vec();
            let mut u = u_all[r0 * u_stride..r1 * u_stride].to_vec();
            let logits = model.decode_step_batch(
                r1 - r0,
                &mut l,
                &mut u,
                &tokens[r0..r1],
                &active[r0..r1],
            )?;
            Ok::<_, anyhow::Error>((l, u, logits))
        };
        // idle-aware fallback (the serving-latency satellite): when
        // every shared worker is already busy (a training batch in the
        // same process), a one-token decode wave must not queue behind
        // them — run its chunks inline on the model thread instead
        let chunks: Vec<_> = if self.pool.saturated() {
            (0..nch).map(&run_chunk).collect()
        } else {
            parallel_map(&self.pool, nch, run_chunk)
        };
        let mut l_out = Vec::with_capacity(b * l_stride);
        let mut u_out = Vec::with_capacity(b * u_stride);
        let mut logits_out = Vec::with_capacity(b * vocab);
        for ch in chunks {
            let (l, u, lg) = ch?;
            l_out.extend(l);
            u_out.extend(u);
            logits_out.extend(lg);
        }
        Ok(vec![
            Tensor::f32(l_out, rest[0].shape()),
            Tensor::f32(u_out, rest[1].shape()),
            Tensor::f32(logits_out, &[b, vocab]),
        ])
    }

    /// (l, u, token[1]) -> (l', u', logits[V]).
    fn decode_step(&self, model: StltModel, rest: &[Tensor]) -> Result<Vec<Tensor>> {
        let mut l = rest[0].as_f32()?.to_vec();
        let mut u = rest[1].as_f32()?.to_vec();
        let token = rest[2].as_i32()?;
        let v = model.cfg.vocab;
        let (logits, _) = model.trunk_chunk(&mut l, &mut u, token, 0.0, None)?;
        let last = logits[logits.len() - v..].to_vec();
        Ok(vec![
            Tensor::f32(l, rest[0].shape()),
            Tensor::f32(u, rest[1].shape()),
            Tensor::f32(last, &[v]),
        ])
    }
}

fn masked_nll(logits: &[f32], vocab: usize, targets: &[i32], mask: &[f32]) -> Result<(f64, f64)> {
    let (mut nll, mut cnt) = (0.0f64, 0.0f64);
    for (t, (&tgt, &m)) in targets.iter().zip(mask).enumerate() {
        if m == 0.0 {
            continue;
        }
        nll += m as f64 * nll_of(&logits[t * vocab..(t + 1) * vocab], tgt)?;
        cnt += m as f64;
    }
    Ok((nll, cnt))
}
