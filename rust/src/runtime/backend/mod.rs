//! Backend abstraction: the structural seam every execution substrate
//! plugs into (native Rust, XLA/PJRT today; GPU PJRT, sharded or remote
//! executors tomorrow).
//!
//! Three object-safe traits cross the boundary:
//!
//!   * [`Backend`]    — load a manifest [`Entry`] into an executable and
//!                      upload long-lived device buffers.
//!   * [`Executable`] — run with host tensors, or with the first input
//!                      (the frozen parameter vector) device-resident.
//!   * [`DeviceBuffer`] — an opaque device-resident tensor; backends
//!                      downcast via `as_any` at execution time.
//!
//! Everything above this module ([`crate::runtime::Runtime`], the typed
//! entry points in `exec.rs`, the coordinator) is backend-agnostic: no
//! `xla::` type appears in any public API outside `backend/xla.rs`.

use anyhow::{bail, Result};

use crate::runtime::artifact::Entry;
use crate::runtime::tensor::Tensor;
use crate::util::sync::Arc;

#[cfg(feature = "native")]
pub mod native;
#[cfg(feature = "xla")]
pub mod xla;

#[cfg(feature = "native")]
pub use self::native::NativeBackend;
#[cfg(feature = "xla")]
pub use self::xla::XlaBackend;

/// Which execution substrate a [`crate::runtime::Runtime`] drives.
///
/// Both variants always exist so CLI parsing and configs stay uniform;
/// constructing an XLA runtime in a build without the `xla` feature
/// fails at [`crate::runtime::Runtime::new`] with a clear error.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-Rust STLT execution (`runtime/native_stlt.rs`): forward,
    /// streaming, decode and CE-eval with zero external dependencies.
    #[default]
    Native,
    /// AOT-lowered HLO artifacts executed through PJRT (`--features xla`).
    Xla,
}

impl BackendKind {
    pub fn parse(s: &str) -> Result<BackendKind> {
        match s {
            "native" => Ok(BackendKind::Native),
            "xla" => Ok(BackendKind::Xla),
            other => bail!("unknown backend '{other}' (expected native|xla)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            BackendKind::Native => "native",
            BackendKind::Xla => "xla",
        }
    }
}

/// Opaque device-resident buffer (the pre-uploaded parameter vector on
/// the hot path). Backends downcast through `as_any`.
pub trait DeviceBuffer {
    /// Number of elements in the buffer.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn as_any(&self) -> &dyn std::any::Any;
}

/// A loaded (compiled, for XLA) manifest entry ready to execute.
///
/// Inputs are validated against the manifest by the caller
/// ([`crate::runtime::Runtime`]) before either method is invoked.
pub trait Executable {
    /// Execute with host tensors; returns outputs in manifest order.
    fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>>;

    /// Execute with the first manifest input (the parameter vector)
    /// taken from a pre-uploaded buffer and the rest from host tensors.
    fn run_with_params(&self, params: &dyn DeviceBuffer, rest: &[Tensor]) -> Result<Vec<Tensor>>;
}

/// An execution substrate: turns manifest entries into executables.
pub trait Backend {
    /// Human-readable platform name (e.g. "native", "Host" for PJRT CPU).
    fn platform(&self) -> String;

    /// Load (and for XLA, compile) a manifest entry.
    fn load(&self, entry: &Entry) -> Result<Arc<dyn Executable>>;

    /// Upload a long-lived f32 tensor once; reused across executions.
    fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<Box<dyn DeviceBuffer>>;

    /// Whether this backend can execute entries of the given kind.
    /// Optional-capability probe for *derived* kinds (`decode_batch`,
    /// synthesized from a `decode_step` entry rather than read from the
    /// manifest): the XLA backend has no AOT program for a derived
    /// entry — its `load` would happily compile the underlying
    /// single-token HLO and then execute it with batched shapes — so
    /// callers must ask before loading and fall back (the server drops
    /// to per-row decode). Defaults to true: manifest-listed kinds
    /// already fail cleanly inside `load`.
    fn supports_kind(&self, kind: &str) -> bool {
        let _ = kind;
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_kind_parses() {
        assert_eq!(BackendKind::parse("native").unwrap(), BackendKind::Native);
        assert_eq!(BackendKind::parse("xla").unwrap(), BackendKind::Xla);
        assert!(BackendKind::parse("tpu").is_err());
        assert_eq!(BackendKind::default(), BackendKind::Native);
        assert_eq!(BackendKind::Native.name(), "native");
    }
}
