//! XLA/PJRT backend (`--features xla`): loads `artifacts/*.hlo.txt`
//! (AOT-lowered by python/compile/aot.py) and executes them on the XLA
//! CPU client. Python never runs on this path.
//!
//! This is the only module in the crate that touches `xla::` types; the
//! public API above it is backend-agnostic.
//!
//! Pattern follows /opt/xla-example/load_hlo: HLO *text* interchange,
//! `return_tuple=True` on the python side -> tuple literal unwrap here.

use anyhow::{bail, Context, Result};

use crate::runtime::artifact::Entry;
use crate::runtime::backend::{Backend, DeviceBuffer, Executable};
use crate::runtime::tensor::{DType, Tensor};
use crate::util::sync::Arc;

pub struct XlaBackend {
    client: xla::PjRtClient,
}

impl XlaBackend {
    pub fn cpu() -> Result<XlaBackend> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(XlaBackend { client })
    }
}

/// Device-resident PJRT buffer plus its element count (PJRT does not
/// expose one cheaply).
pub struct XlaBuffer {
    buf: xla::PjRtBuffer,
    len: usize,
}

impl XlaBuffer {
    pub fn buffer(&self) -> &xla::PjRtBuffer {
        &self.buf
    }
}

impl DeviceBuffer for XlaBuffer {
    fn len(&self) -> usize {
        self.len
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

impl Backend for XlaBackend {
    fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn load(&self, entry: &Entry) -> Result<Arc<dyn Executable>> {
        let path = entry
            .file
            .to_str()
            .context("artifact path not utf-8")?
            .to_string();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("XLA compile of {}", entry.name))?;
        Ok(Arc::new(XlaExec {
            exe,
            client: self.client.clone(),
            entry: entry.clone(),
        }))
    }

    fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<Box<dyn DeviceBuffer>> {
        let buf = self.client.buffer_from_host_buffer(data, dims, None)?;
        Ok(Box::new(XlaBuffer { buf, len: data.len() }))
    }

    fn supports_kind(&self, kind: &str) -> bool {
        // `decode_batch` entries are derived (Entry::to_decode_batch),
        // not AOT-lowered: the entry's `file` still points at the
        // single-token decode HLO, which would compile fine and then
        // execute with the wrong shapes. Refuse up front so the server
        // falls back to per-row decode on this backend.
        kind != "decode_batch"
    }
}

pub struct XlaExec {
    exe: xla::PjRtLoadedExecutable,
    client: xla::PjRtClient,
    entry: Entry,
}

/// Convert a host tensor to an xla Literal with the proper shape.
fn to_literal(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
    let lit = match t {
        Tensor::F32(d, _) => xla::Literal::vec1(d),
        Tensor::I32(d, _) => xla::Literal::vec1(d),
    };
    if dims.is_empty() {
        // scalar: reshape to rank-0
        Ok(lit.reshape(&[])?)
    } else {
        Ok(lit.reshape(&dims)?)
    }
}

/// Read back from a literal, trusting the manifest-declared shape.
fn from_literal(lit: &xla::Literal, dtype: DType, shape: &[usize]) -> Result<Tensor> {
    Ok(match dtype {
        DType::F32 => Tensor::F32(lit.to_vec::<f32>()?, shape.to_vec()),
        DType::I32 => Tensor::I32(lit.to_vec::<i32>()?, shape.to_vec()),
    })
}

impl XlaExec {
    fn untuple(&self, lit: xla::Literal) -> Result<Vec<Tensor>> {
        // python lowered with return_tuple=True -> tuple of outputs
        let parts = lit.to_tuple().context("untupling result")?;
        if parts.len() != self.entry.outputs.len() {
            bail!(
                "{}: got {} outputs, manifest says {}",
                self.entry.name,
                parts.len(),
                self.entry.outputs.len()
            );
        }
        parts
            .iter()
            .zip(&self.entry.outputs)
            .map(|(l, spec)| from_literal(l, spec.dtype, &spec.shape))
            .collect()
    }
}

impl Executable for XlaExec {
    fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        // drop arguments jax pruned from the lowered program (kept_inputs)
        let literals: Vec<xla::Literal> = self
            .entry
            .kept_inputs
            .iter()
            .map(|&i| to_literal(&inputs[i]))
            .collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?;
        let lit = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        self.untuple(lit)
    }

    fn run_with_params(&self, params: &dyn DeviceBuffer, rest: &[Tensor]) -> Result<Vec<Tensor>> {
        let params = params
            .as_any()
            .downcast_ref::<XlaBuffer>()
            .context("parameter buffer was not uploaded by the xla backend")?;
        if !self.entry.kept_inputs.contains(&0) {
            bail!(
                "{}: parameter vector was pruned from the program",
                self.entry.name
            );
        }
        let mut bufs: Vec<xla::PjRtBuffer> = Vec::with_capacity(rest.len());
        for (i, t) in rest.iter().enumerate() {
            if !self.entry.kept_inputs.contains(&(i + 1)) {
                continue; // jax pruned this argument
            }
            let b = match t {
                Tensor::F32(d, s) => self.client.buffer_from_host_buffer(d, s, None)?,
                Tensor::I32(d, s) => self.client.buffer_from_host_buffer(d, s, None)?,
            };
            bufs.push(b);
        }
        let mut args: Vec<&xla::PjRtBuffer> = vec![&params.buf];
        args.extend(bufs.iter());
        let result = self.exe.execute_b(&args)?;
        let lit = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        self.untuple(lit)
    }
}
