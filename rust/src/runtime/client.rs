//! PJRT execution: load HLO text -> compile -> run, with a per-process
//! executable cache (XLA compilation is seconds; every experiment reuses
//! compiled artifacts across steps).
//!
//! Pattern follows /opt/xla-example/load_hlo: HLO *text* interchange,
//! `return_tuple=True` on the python side -> tuple literal unwrap here.

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::runtime::artifact::Entry;
use crate::runtime::tensor::Tensor;

pub struct Runtime {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
    pub compile_seconds: Mutex<f64>,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, cache: Mutex::new(HashMap::new()), compile_seconds: Mutex::new(0.0) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch cached) executable for a manifest entry.
    pub fn load(&self, entry: &Entry) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.lock().unwrap().get(&entry.name) {
            return Ok(e.clone());
        }
        let t0 = Instant::now();
        let path = entry
            .file
            .to_str()
            .context("artifact path not utf-8")?
            .to_string();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("XLA compile of {}", entry.name))?;
        let exe = std::sync::Arc::new(exe);
        let dt = t0.elapsed().as_secs_f64();
        *self.compile_seconds.lock().unwrap() += dt;
        crate::info!("runtime", "compiled {} in {:.2}s", entry.name, dt);
        self.cache.lock().unwrap().insert(entry.name.clone(), exe.clone());
        Ok(exe)
    }

    /// Execute an entry with host tensors; returns output tensors in
    /// manifest order. Inputs are validated against the manifest first.
    pub fn run(&self, entry: &Entry, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        entry.check_inputs(inputs)?;
        let exe = self.load(entry)?;
        // drop arguments jax pruned from the lowered program (kept_inputs)
        let literals: Vec<xla::Literal> = entry
            .kept_inputs
            .iter()
            .map(|&i| inputs[i].to_literal())
            .collect::<Result<_>>()?;
        let result = exe.execute::<xla::Literal>(&literals)?;
        let lit = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        // python lowered with return_tuple=True -> tuple of outputs
        let parts = lit.to_tuple().context("untupling result")?;
        if parts.len() != entry.outputs.len() {
            anyhow::bail!(
                "{}: got {} outputs, manifest says {}",
                entry.name,
                parts.len(),
                entry.outputs.len()
            );
        }
        parts
            .iter()
            .zip(&entry.outputs)
            .map(|(l, spec)| Tensor::from_literal(l, spec.dtype, &spec.shape))
            .collect()
    }

    /// Upload a static tensor once; reuse across execute_b calls.
    /// (§Perf L3-1: skips the per-call host->literal->buffer copies of
    /// the parameter vector, which dominates input bytes on every path
    /// with frozen weights — eval/forward/stream/decode/serving.)
    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    /// Execute with the first input taken from a pre-uploaded buffer and
    /// the remaining inputs from host tensors. Shapes of `rest` are
    /// validated against entry.inputs[1..].
    pub fn run_with_param_buffer(
        &self,
        entry: &Entry,
        params: &xla::PjRtBuffer,
        rest: &[Tensor],
    ) -> Result<Vec<Tensor>> {
        if rest.len() + 1 != entry.inputs.len() {
            anyhow::bail!(
                "{}: expected {} inputs, got 1 buffer + {}",
                entry.name,
                entry.inputs.len(),
                rest.len()
            );
        }
        for (i, (t, spec)) in rest.iter().zip(&entry.inputs[1..]).enumerate() {
            if t.dtype() != spec.dtype || t.shape() != spec.shape.as_slice() {
                anyhow::bail!("{}: input {} mismatch vs manifest", entry.name, i + 1);
            }
        }
        let exe = self.load(entry)?;
        if !entry.kept_inputs.contains(&0) {
            anyhow::bail!("{}: parameter vector was pruned from the program", entry.name);
        }
        let mut bufs: Vec<xla::PjRtBuffer> = Vec::with_capacity(rest.len());
        for (i, t) in rest.iter().enumerate() {
            if !entry.kept_inputs.contains(&(i + 1)) {
                continue; // jax pruned this argument
            }
            let b = match t {
                Tensor::F32(d, s) => self.client.buffer_from_host_buffer(d, s, None)?,
                Tensor::I32(d, s) => self.client.buffer_from_host_buffer(d, s, None)?,
            };
            bufs.push(b);
        }
        let mut args: Vec<&xla::PjRtBuffer> = vec![params];
        args.extend(bufs.iter());
        let result = exe.execute_b(&args)?;
        let lit = result[0][0].to_literal_sync().context("fetching result literal")?;
        let parts = lit.to_tuple().context("untupling result")?;
        if parts.len() != entry.outputs.len() {
            anyhow::bail!("{}: output arity mismatch", entry.name);
        }
        parts
            .iter()
            .zip(&entry.outputs)
            .map(|(l, spec)| Tensor::from_literal(l, spec.dtype, &spec.shape))
            .collect()
    }

    /// Drop a cached executable (frees compiled program memory).
    pub fn evict(&self, name: &str) {
        self.cache.lock().unwrap().remove(name);
    }

    pub fn cached_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}
