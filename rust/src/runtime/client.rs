//! Backend-agnostic runtime: validates manifest inputs, dispatches to
//! the selected [`Backend`], and keeps a per-process executable cache
//! (XLA compilation is seconds; every experiment reuses loaded
//! artifacts across steps — native loads are cheap but cached too so
//! both backends share one code path).

use std::collections::HashMap;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::runtime::artifact::Entry;
use crate::runtime::backend::{Backend, BackendKind, DeviceBuffer, Executable};
use crate::runtime::tensor::Tensor;
use crate::util::sync::{Arc, Mutex};

pub struct Runtime {
    backend: Box<dyn Backend>,
    kind: BackendKind,
    cache: Mutex<HashMap<String, Arc<dyn Executable>>>,
    /// cumulative seconds spent loading/compiling executables
    pub compile_seconds: Mutex<f64>,
}

impl Runtime {
    /// Construct a runtime over the requested execution substrate.
    pub fn new(kind: BackendKind) -> Result<Runtime> {
        let backend: Box<dyn Backend> = match kind {
            #[cfg(feature = "native")]
            BackendKind::Native => {
                Box::new(crate::runtime::backend::native::NativeBackend::new())
            }
            #[cfg(not(feature = "native"))]
            BackendKind::Native => {
                bail!("this build has no native backend (rebuild with --features native)")
            }
            #[cfg(feature = "xla")]
            BackendKind::Xla => Box::new(crate::runtime::backend::xla::XlaBackend::cpu()?),
            #[cfg(not(feature = "xla"))]
            BackendKind::Xla => {
                bail!("this build has no XLA support (rebuild with --features xla)")
            }
        };
        Ok(Runtime {
            backend,
            kind,
            cache: Mutex::new(HashMap::new()),
            compile_seconds: Mutex::new(0.0),
        })
    }

    /// Pure-Rust native runtime (default feature `native`).
    #[cfg(feature = "native")]
    pub fn native() -> Result<Runtime> {
        Runtime::new(BackendKind::Native)
    }

    /// XLA CPU runtime (back-compat constructor for xla-gated tests,
    /// benches and the experiment harnesses).
    #[cfg(feature = "xla")]
    pub fn cpu() -> Result<Runtime> {
        Runtime::new(BackendKind::Xla)
    }

    pub fn backend_kind(&self) -> BackendKind {
        self.kind
    }

    pub fn platform(&self) -> String {
        self.backend.platform()
    }

    /// Whether the backend can execute entries of `kind`. Used for
    /// *derived* kinds (`decode_batch`) that never appear in the
    /// manifest: the server probes before loading and falls back to the
    /// per-row path on backends without a batched program.
    pub fn supports_kind(&self, kind: &str) -> bool {
        self.backend.supports_kind(kind)
    }

    /// Load (compile for XLA, resolve for native) a manifest entry,
    /// or fetch it from the per-process cache.
    pub fn load(&self, entry: &Entry) -> Result<Arc<dyn Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(&entry.name) {
            return Ok(e.clone());
        }
        let t0 = Instant::now();
        let exe = self.backend.load(entry)?;
        let dt = t0.elapsed().as_secs_f64();
        *self.compile_seconds.lock().unwrap() += dt;
        if dt > 0.05 {
            crate::info!("runtime", "loaded {} in {:.2}s", entry.name, dt);
        }
        self.cache.lock().unwrap().insert(entry.name.clone(), exe.clone());
        Ok(exe)
    }

    /// Execute an entry with host tensors; returns output tensors in
    /// manifest order. Inputs are validated against the manifest first.
    pub fn run(&self, entry: &Entry, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        entry.check_inputs(inputs)?;
        let exe = self.load(entry)?;
        exe.run(inputs)
    }

    /// Upload a static tensor once; reuse across run_with_param_buffer
    /// calls. (§Perf L3-1: skips the per-call host->device copies of the
    /// parameter vector, which dominates input bytes on every path with
    /// frozen weights — eval/forward/stream/decode/serving.)
    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<Box<dyn DeviceBuffer>> {
        self.backend.upload_f32(data, dims)
    }

    /// Execute with the first input taken from a pre-uploaded buffer and
    /// the remaining inputs from host tensors. Shapes of `rest` are
    /// validated against entry.inputs[1..].
    pub fn run_with_param_buffer(
        &self,
        entry: &Entry,
        params: &dyn DeviceBuffer,
        rest: &[Tensor],
    ) -> Result<Vec<Tensor>> {
        if rest.len() + 1 != entry.inputs.len() {
            bail!(
                "{}: expected {} inputs, got 1 buffer + {}",
                entry.name,
                entry.inputs.len(),
                rest.len()
            );
        }
        if !entry.inputs.is_empty() && params.len() != entry.inputs[0].numel() {
            bail!(
                "{}: param buffer has {} elements, manifest says {}",
                entry.name,
                params.len(),
                entry.inputs[0].numel()
            );
        }
        for (i, (t, spec)) in rest.iter().zip(&entry.inputs[1..]).enumerate() {
            if t.dtype() != spec.dtype || t.shape() != spec.shape.as_slice() {
                bail!("{}: input {} mismatch vs manifest", entry.name, i + 1);
            }
        }
        let exe = self.load(entry)?;
        exe.run_with_params(params, rest)
    }

    /// Drop a cached executable (frees compiled program memory).
    pub fn evict(&self, name: &str) {
        self.cache.lock().unwrap().remove(name);
    }

    pub fn cached_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}
