//! Pure-Rust STLT execution engine: the paper's central claim — token
//! mixing is an O(N·S·d) recursive convolution with an O(S·d) streaming
//! carry — means inference needs no XLA compiler at all. This module
//! executes the decoder-only STLT trunk (embedding, per-node recursive
//! Laplace convolution with learnable (sigma_s, omega_s, T), FFN,
//! LayerNorm, tied logits head) directly from the same flat parameter
//! vector and manifest `ModelConfig` the AOT artifacts consume.
//!
//! Semantics mirror `python/compile/{trunk,stlt_layer}.py` and the
//! kernel oracles in `python/compile/kernels/ref.py`:
//!
//!   sigma   = softplus(sigma_raw) + sigma_min
//!   T       = softplus(t_raw) + 1
//!   lam_k   = e^{-(sigma_k + 1/T)} * e^{-j omega_k}      (window folded)
//!   gamma   = e^{-1/(8 T)}                               (U discount)
//!   L_n     = lam * L_{n-1} + f_n                        (O(S) carry)
//!   U_n     = gamma * U_{n-1} + conj(L_n) (x) v_n        (O(S d) carry)
//!   z_n     = Re<L_n, U_n> / S
//!
//! Every projection around that recurrence — mixer w_f/w_v/w_o, the
//! FFN, and the n×vocab×d tied logits head — runs on the shared
//! blocked-GEMM kernels in [`crate::util::linalg`], against weight
//! panels pre-transposed once per bound parameter vector
//! ([`StltPlan::bind`] memoizes the packing by parameter-vector
//! identity, so the per-token decode serving path never re-packs).
//! The tied head and FFN additionally fan out over token rows via
//! [`crate::util::threadpool::scatter_rows`]. The training tape in
//! [`crate::train`] calls the same kernels on the same panels — and the
//! same [`lu_node_step`] recurrence kernel, so the (L, U) carry
//! snapshots its segment-checkpointed backward stores replay to
//! bitwise-identical values — so the forward and backward can never
//! drift numerically.
//!
//! Token mixing itself is pluggable: the trunk routes through the
//! [`crate::runtime::mixer::Mixer`] trait (selected by
//! `ModelConfig::mixer`), so the recursive Laplace convolution, the
//! naive O(N² S) relevance-matrix oracle (`reference_n2`, a supported
//! quadratic ablation mode), and the linear-attention baseline
//! (`linear_attention`) all share this trunk, the serving decode path,
//! and the training tape. FFT-based spectral relevance cross-checks
//! (via [`crate::util::fft`], the paper's SS3.4 claim) keep the
//! recurrence honest in tests.
//!
//! The adaptive node gate (SS3.6) is causal: gate logits at token t see
//! the running mean of the pre-mixer activations over tokens ≤ t, with
//! the (pool_sum, count) pooling state appended to each layer's l-carry
//! slot — so chunked streaming, batched decode, and whole-sequence
//! forwards are bitwise identical. (The python reference pools over the
//! whole row acausally; the causal running mean is the documented
//! deviation that makes adaptive models streamable at all.)

use anyhow::{anyhow, bail, Result};

use crate::interpret::{total_params, trunk_layout, Leaf};
use crate::runtime::artifact::ModelConfig;
use crate::runtime::mixer::{mixer_from_config, Mixer};
use crate::util::linalg;
use crate::util::rng::Rng;
use crate::util::sync::{Arc, Mutex, Weak};
use crate::util::threadpool::scatter_rows;

/// Row count below which the row-parallel head/FFN paths run inline —
/// the decode path (n = 1) and the server's small chunks never pay
/// thread-fanout overhead.
const MIN_PAR_ROWS: usize = 16;

static BIND_HITS: crate::obs::LazyCounter = crate::obs::LazyCounter::new("panels/bind_hits");
static BIND_PACKS: crate::obs::LazyCounter = crate::obs::LazyCounter::new("panels/bind_packs");

/// Publish per-node `sigma`/`omega`/`T`/half-life/`alpha` gauges under
/// `node/l{L}/n{K}/..` plus per-layer `half_life_mean` and
/// `active_nodes` — the paper's interpretability story (a node's memory
/// half-life is `ln2 / (sigma + 1/T)` tokens, and the adaptive gate's
/// resting activity `alpha = sigmoid(b_alpha)` says which nodes the
/// model still pays for) surfaced as live telemetry. `half_life_mean`
/// is alpha-weighted so nodes the gate has switched off stop dragging
/// the reported memory horizon; for non-adaptive configs alpha is 1.0
/// everywhere and the mean is the plain average. Called at server start
/// and every `--metrics-every` interval during training; a flat vector
/// that does not match the config is skipped silently
/// (foreign-backend layouts have nothing to report).
pub fn publish_node_gauges(cfg: &ModelConfig, flat: &[f32]) {
    if !crate::obs::metrics_on() {
        return;
    }
    let plan = match StltPlan::new(cfg) {
        Ok(p) => p,
        Err(_) => return,
    };
    if flat.len() != plan.total {
        return;
    }
    let ln2 = std::f64::consts::LN_2;
    for (l, lo) in plan.layers.iter().enumerate() {
        let t = softplus(flat[lo.t_raw]) + 1.0;
        let mut hl_wsum = 0.0f64;
        let mut a_sum = 0.0f64;
        let mut active = 0usize;
        for k in 0..cfg.s_max {
            let sigma = softplus(flat[lo.sigma_raw + k]) + cfg.sigma_min;
            let omega = if cfg.omega_zero { 0.0 } else { flat[lo.omega + k] };
            let half_life = ln2 / (sigma as f64 + 1.0 / t as f64);
            // resting gate activity: the causal pool starts at zero, so
            // sigmoid(b_alpha) is the gate a fresh stream opens with
            let alpha = match (cfg.adaptive, lo.b_alpha) {
                (true, Some(ba)) => sigmoid(flat[ba + k]) as f64,
                _ => 1.0,
            };
            if alpha > 0.5 {
                active += 1;
            }
            hl_wsum += alpha * half_life;
            a_sum += alpha;
            crate::obs::gauge(&format!("node/l{l}/n{k}/sigma")).set(sigma as f64);
            crate::obs::gauge(&format!("node/l{l}/n{k}/omega")).set(omega as f64);
            crate::obs::gauge(&format!("node/l{l}/n{k}/t")).set(t as f64);
            crate::obs::gauge(&format!("node/l{l}/n{k}/half_life")).set(half_life);
            crate::obs::gauge(&format!("node/l{l}/n{k}/alpha")).set(alpha);
        }
        crate::obs::gauge(&format!("node/l{l}/half_life_mean"))
            .set(if a_sum > 0.0 { hl_wsum / a_sum } else { 0.0 });
        crate::obs::gauge(&format!("node/l{l}/active_nodes")).set(active as f64);
    }
}

/// One node's Laplace-carry advance for a single timestep — THE
/// recurrence kernel, shared verbatim by the streaming engine (via
/// [`crate::runtime::mixer::Recurrence`]), the training-tape forward,
/// and the backward pass's segment-checkpoint replay.
/// One function on all three sides means a carry snapshot taken during
/// the tape forward replays to bitwise-identical (L, U) values during
/// the backward, and the tape can never drift from what the engine
/// serves.
///
///   L ← lam·L + f_tk          (lk = [re, im])
///   U ← gamma·U + conj(L)⊗v   (uk = [d][re, im])
///   z += Re(L·U)              (when zr is Some; caller divides by S)
///
/// `zr: None` is the backward's replay mode: it advances the identical
/// L/U state (z never feeds back into L or U) without paying the
/// discarded z flops. One body serves both so the two modes cannot
/// drift.
#[inline(always)]
pub(crate) fn lu_node_step(
    lam_re: f32,
    lam_im: f32,
    gamma: f32,
    f_tk: f32,
    lk: &mut [f32],
    uk: &mut [f32],
    vr: &[f32],
    mut zr: Option<&mut [f32]>,
) {
    let (lr, li) = (lk[0], lk[1]);
    let nlr = lam_re * lr - lam_im * li + f_tk;
    let nli = lam_re * li + lam_im * lr;
    lk[0] = nlr;
    lk[1] = nli;
    for (e, &ve) in vr.iter().enumerate() {
        let ur = gamma * uk[e * 2] + nlr * ve;
        let ui = gamma * uk[e * 2 + 1] - nli * ve;
        uk[e * 2] = ur;
        uk[e * 2 + 1] = ui;
        if let Some(z) = zr.as_deref_mut() {
            z[e] += nlr * ur - nli * ui;
        }
    }
}

pub(crate) fn softplus(x: f32) -> f32 {
    if x > 20.0 {
        x
    } else {
        (1.0 + x.exp()).ln()
    }
}

pub(crate) fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// Resolved offsets of one trunk layer inside the flat vector.
/// `pub(crate)` so the hand-derived backward pass in [`crate::train`]
/// can address the same parameter slices the forward reads.
#[derive(Clone, Debug)]
pub(crate) struct LayerOffsets {
    pub(crate) ln1_g: usize,
    pub(crate) ln1_b: usize,
    pub(crate) ln2_g: usize,
    pub(crate) ln2_b: usize,
    pub(crate) ffn_w1: usize,
    pub(crate) ffn_b1: usize,
    pub(crate) ffn_w2: usize,
    pub(crate) ffn_b2: usize,
    pub(crate) w_f: usize,
    pub(crate) w_v: usize,
    pub(crate) w_o: usize,
    pub(crate) sigma_raw: usize,
    pub(crate) omega: usize,
    pub(crate) t_raw: usize,
    /// adaptive node-allocation gate (SS3.6), if cfg.adaptive
    pub(crate) w_alpha: Option<usize>,
    pub(crate) b_alpha: Option<usize>,
}

/// Pre-transposed ("packed") weight panels of one layer: every matrix
/// the forward multiplies by, stored output-major so each output
/// element is one contiguous [`linalg::dot`] over the shared dimension
/// (the [`linalg::gemm_at`]/[`linalg::gemv`] layout).
pub(crate) struct LayerPanels {
    pub(crate) w_f_t: Vec<f32>,    // [S, d]
    pub(crate) w_v_t: Vec<f32>,    // [d, d]
    pub(crate) w_o_t: Vec<f32>,    // [d, d]
    pub(crate) ffn_w1_t: Vec<f32>, // [hd, d]
    pub(crate) ffn_w2_t: Vec<f32>, // [d, hd]
    pub(crate) w_alpha_t: Option<Vec<f32>>, // [S, d]
}

/// All layers' packed panels for one bound parameter vector. The tied
/// head needs no panel: the `[vocab, d]` embedding matrix is already
/// output-major for `logits = xf @ embedᵀ`.
pub(crate) struct Panels {
    pub(crate) layers: Vec<LayerPanels>,
}

fn pack_panels(cfg: &ModelConfig, layers: &[LayerOffsets], flat: &[f32]) -> Panels {
    let (s, d) = (cfg.s_max, cfg.d_model);
    let hd = d * cfg.ffn_mult.max(1);
    let layers = layers
        .iter()
        .map(|lo| LayerPanels {
            w_f_t: linalg::transpose(&flat[lo.w_f..lo.w_f + d * s], d, s),
            w_v_t: linalg::transpose(&flat[lo.w_v..lo.w_v + d * d], d, d),
            w_o_t: linalg::transpose(&flat[lo.w_o..lo.w_o + d * d], d, d),
            ffn_w1_t: linalg::transpose(&flat[lo.ffn_w1..lo.ffn_w1 + d * hd], d, hd),
            ffn_w2_t: linalg::transpose(&flat[lo.ffn_w2..lo.ffn_w2 + hd * d], hd, d),
            w_alpha_t: lo.w_alpha.map(|wa| linalg::transpose(&flat[wa..wa + d * s], d, s)),
        })
        .collect();
    Panels { layers }
}

/// Memoized packing: (identity of the last-bound parameter vector, its
/// panels). `Weak` so the cache never keeps a stale vector alive, and a
/// recycled allocation address can never alias a dead entry.
type PanelCache = Mutex<Option<(Weak<Vec<f32>>, Arc<Panels>)>>;

/// Per-layer node constants derived from the learnable parameters.
/// `pub` because the [`Mixer`] trait's methods take it (mixers that
/// ignore the Laplace nodes, like linear attention, just don't read it).
pub struct NodeParams {
    pub(crate) lam_re: Vec<f32>,
    pub(crate) lam_im: Vec<f32>,
    pub(crate) gamma: f32,
}

/// Resolved execution plan for one config: validated arch/mode plus
/// every parameter offset. Built once (per backend `load`), then bound
/// to concrete parameter vectors cheaply via [`StltPlan::bind`] — the
/// decode serving path binds once per call, so plan resolution (string
/// path lookups over the layout) must not sit on it, and the weight
/// panel packing is memoized by parameter-vector identity so repeat
/// binds of the same (Arc) vector are two Arc clones plus a pointer
/// compare.
#[derive(Clone)]
pub struct StltPlan {
    pub cfg: Arc<ModelConfig>,
    layers: Arc<Vec<LayerOffsets>>,
    embed: usize,
    lnf_g: usize,
    lnf_b: usize,
    total: usize,
    panel_cache: Arc<PanelCache>,
    mixer: Arc<dyn Mixer>,
}

/// The native STLT model: a plan bound to a flat packed parameter
/// vector (plus that vector's packed weight panels).
///
/// Cheap to clone (parameters and panels are behind `Arc`s),
/// `Send + Sync`, so batch rows parallelise across
/// [`crate::util::threadpool`].
#[derive(Clone)]
pub struct StltModel {
    /// shared with the plan — `model.cfg.field` reads through the Arc
    pub cfg: Arc<ModelConfig>,
    flat: Arc<Vec<f32>>,
    layers: Arc<Vec<LayerOffsets>>,
    panels: Arc<Panels>,
    embed: usize,
    lnf_g: usize,
    lnf_b: usize,
    mixer: Arc<dyn Mixer>,
}

fn find(layout: &[Leaf], path: &str) -> Result<usize> {
    layout
        .iter()
        .find(|l| l.path == path)
        .map(|l| l.offset)
        .ok_or_else(|| anyhow!("param layout missing '{path}'"))
}

impl StltPlan {
    /// Validate the config and resolve all parameter offsets.
    pub fn new(cfg: &ModelConfig) -> Result<StltPlan> {
        // register the panel-cache counter family up front: an idle
        // process (a worker that never took a wave) still exposes
        // zeroed `panels/` rows to a stats scrape
        crate::obs::counter("panels/bind_hits");
        crate::obs::counter("panels/bind_packs");
        if cfg.arch != "stlt" {
            bail!(
                "native backend executes arch 'stlt' only (got '{}'); \
                 use the xla backend for baseline architectures",
                cfg.arch
            );
        }
        if cfg.mode != "linear" {
            bail!(
                "native backend executes mode 'linear' only (got '{}')",
                cfg.mode
            );
        }
        if cfg.d_model == 0 || cfg.s_max == 0 || cfg.n_layers == 0 || cfg.vocab == 0 {
            bail!("degenerate ModelConfig: {cfg:?}");
        }
        let layout = trunk_layout(cfg);
        let total = total_params(&layout);
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for li in 0..cfg.n_layers {
            let p = format!("/layers/{li:03}");
            layers.push(LayerOffsets {
                ln1_g: find(&layout, &format!("{p}/ln1_g"))?,
                ln1_b: find(&layout, &format!("{p}/ln1_b"))?,
                ln2_g: find(&layout, &format!("{p}/ln2_g"))?,
                ln2_b: find(&layout, &format!("{p}/ln2_b"))?,
                ffn_w1: find(&layout, &format!("{p}/ffn_w1"))?,
                ffn_b1: find(&layout, &format!("{p}/ffn_b1"))?,
                ffn_w2: find(&layout, &format!("{p}/ffn_w2"))?,
                ffn_b2: find(&layout, &format!("{p}/ffn_b2"))?,
                w_f: find(&layout, &format!("{p}/mixer/w_f"))?,
                w_v: find(&layout, &format!("{p}/mixer/w_v"))?,
                w_o: find(&layout, &format!("{p}/mixer/w_o"))?,
                sigma_raw: find(&layout, &format!("{p}/mixer/sigma_raw"))?,
                omega: find(&layout, &format!("{p}/mixer/omega"))?,
                t_raw: find(&layout, &format!("{p}/mixer/t_raw"))?,
                w_alpha: find(&layout, &format!("{p}/mixer/w_alpha")).ok(),
                b_alpha: find(&layout, &format!("{p}/mixer/b_alpha")).ok(),
            });
        }
        Ok(StltPlan {
            mixer: mixer_from_config(cfg)?,
            cfg: Arc::new(cfg.clone()),
            embed: find(&layout, "/embed")?,
            lnf_g: find(&layout, "/lnf_g")?,
            lnf_b: find(&layout, "/lnf_b")?,
            total,
            layers: Arc::new(layers),
            panel_cache: Arc::new(Mutex::new(None)),
        })
    }

    /// Bind a parameter vector to the plan. The first bind of a given
    /// vector packs its pre-transposed weight panels (one pass over the
    /// weights); every repeat bind of the *same* `Arc` — the per-token
    /// decode serving path, which re-binds the uploaded parameter
    /// buffer on every step — hits the memo and costs a length check
    /// plus Arc clones.
    pub fn bind(&self, flat: Arc<Vec<f32>>) -> Result<StltModel> {
        if flat.len() != self.total {
            bail!(
                "flat param vector has {} elements, layout for '{}' needs {}",
                flat.len(),
                self.cfg.arch,
                self.total
            );
        }
        let panels = {
            let mut cache = self.panel_cache.lock().unwrap_or_else(|e| e.into_inner());
            let hit = cache.as_ref().and_then(|(prev, p)| {
                prev.upgrade()
                    .filter(|prev| Arc::ptr_eq(prev, &flat))
                    .map(|_| Arc::clone(p))
            });
            match hit {
                Some(p) => {
                    BIND_HITS.inc();
                    p
                }
                None => {
                    BIND_PACKS.inc();
                    let _span = crate::obs::span("panels", "pack");
                    let p = Arc::new(pack_panels(&self.cfg, &self.layers, &flat));
                    *cache = Some((Arc::downgrade(&flat), Arc::clone(&p)));
                    p
                }
            }
        };
        Ok(StltModel {
            cfg: Arc::clone(&self.cfg),
            flat,
            layers: Arc::clone(&self.layers),
            panels,
            embed: self.embed,
            lnf_g: self.lnf_g,
            lnf_b: self.lnf_b,
            mixer: Arc::clone(&self.mixer),
        })
    }
}

impl StltModel {
    /// Validate the config/param-vector pair and resolve all offsets.
    pub fn new(cfg: &ModelConfig, flat: Arc<Vec<f32>>) -> Result<StltModel> {
        StltPlan::new(cfg)?.bind(flat)
    }

    /// Zero streaming carry, `(l [n_layers * ll], u [n_layers * ul])`
    /// with per-layer slot lengths from [`ModelConfig::carry_lens`] —
    /// the mixer's own state plus, when adaptive, the causal gate's
    /// (pool_sum [d], count) appended to the l slot. For the default
    /// non-adaptive recurrence this is the historical
    /// (L [n_layers*S*2], U [n_layers*S*d*2]) layout, so v2 checkpoints
    /// and their exported carries stream unchanged.
    pub fn zero_carry(&self) -> (Vec<f32>, Vec<f32>) {
        let ly = self.cfg.n_layers;
        let (ll, ul) = self.cfg.carry_lens();
        (vec![0.0; ly * ll], vec![0.0; ly * ul])
    }

    /// The mixer this model routes token mixing through.
    pub fn mixer(&self) -> &dyn Mixer {
        &*self.mixer
    }

    /// Per-layer parameter offsets, in layer order ([`crate::train`]).
    pub(crate) fn layer_offsets(&self) -> &[LayerOffsets] {
        &self.layers
    }

    /// The packed weight panels of the bound vector ([`crate::train`]
    /// runs its tape forward on the same panels the engine uses).
    pub(crate) fn panels(&self) -> &Panels {
        &self.panels
    }

    /// The bound flat parameter vector ([`crate::train`]).
    pub(crate) fn flat_params(&self) -> &[f32] {
        &self.flat
    }

    /// (embed, lnf_g, lnf_b) offsets inside the flat vector.
    pub(crate) fn head_offsets(&self) -> (usize, usize, usize) {
        (self.embed, self.lnf_g, self.lnf_b)
    }

    pub(crate) fn node_params(&self, lo: &LayerOffsets) -> NodeParams {
        let s = self.cfg.s_max;
        let f = &self.flat[..];
        let t = softplus(f[lo.t_raw]) + 1.0;
        let gamma = (-1.0 / (8.0 * t)).exp();
        let mut lam_re = Vec::with_capacity(s);
        let mut lam_im = Vec::with_capacity(s);
        for k in 0..s {
            let sigma = softplus(f[lo.sigma_raw + k]) + self.cfg.sigma_min;
            let decay = (-(sigma + 1.0 / t)).exp();
            let theta = if self.cfg.omega_zero { 0.0 } else { f[lo.omega + k] };
            lam_re.push(decay * theta.cos());
            lam_im.push(-decay * theta.sin());
        }
        NodeParams { lam_re, lam_im, gamma }
    }

    /// Causal adaptive node gate (SS3.6, streaming form): one gate row
    /// [S] per token, where token t's logits see the running mean of
    /// the pre-mixer activations over tokens ≤ t. `gate_state` is the
    /// (pool_sum [d], count [1]) slice appended to the layer's l-carry
    /// slot, advanced in place — so any chunking of the token stream
    /// (including single-token decode) produces bitwise the same gates.
    /// Returns `None` when the config is not adaptive (callers share
    /// one all-ones row with stride 0).
    ///
    /// The training tape computes the same pool/logits arithmetic (plus
    /// Gumbel noise and a temperature) in `train/backward.rs`; the
    /// deterministic path here is what eval and serving use.
    pub(crate) fn causal_gate_rows(
        &self,
        lo: &LayerOffsets,
        lp: &LayerPanels,
        h: &[f32],
        n: usize,
        gate_state: &mut [f32],
    ) -> Option<Vec<f32>> {
        let (s, d) = (self.cfg.s_max, self.cfg.d_model);
        if !self.cfg.adaptive {
            return None;
        }
        let (ba, wat) = match (lo.b_alpha, &lp.w_alpha_t) {
            (Some(b), Some(w)) => (b, w),
            _ => return None,
        };
        debug_assert_eq!(gate_state.len(), d + 1);
        let f = &self.flat[..];
        let (pool, cnt) = gate_state.split_at_mut(d);
        let mut pooled = vec![0.0f32; d];
        let mut m = vec![0.0f32; n * s];
        for t in 0..n {
            for (p, &x) in pool.iter_mut().zip(&h[t * d..(t + 1) * d]) {
                *p += x;
            }
            cnt[0] += 1.0;
            let invc = 1.0 / cnt[0];
            for (o, &p) in pooled.iter_mut().zip(pool.iter()) {
                *o = p * invc;
            }
            for k in 0..s {
                m[t * s + k] =
                    sigmoid(f[ba + k] + linalg::dot(&pooled, &wat[k * d..(k + 1) * d]));
            }
        }
        Some(m)
    }

    /// One mixer chunk: h [n*d] (LayerNormed input) -> z [n*d],
    /// advancing the layer carry slot (mixer state + gate pooling
    /// state) in place. Returns (z, s_eff = mean-over-tokens gate mass,
    /// exactly S when not adaptive).
    fn mixer_chunk(
        &self,
        lo: &LayerOffsets,
        lp: &LayerPanels,
        h: &[f32],
        n: usize,
        l: &mut [f32],
        u: &mut [f32],
    ) -> (Vec<f32>, f32) {
        let (s, d) = (self.cfg.s_max, self.cfg.d_model);
        let np = self.node_params(lo);
        let (sl, _) = self.mixer.state_lens(&self.cfg);
        let (l_mix, gate_state) = l.split_at_mut(sl);
        let (m, m_stride) = match self.causal_gate_rows(lo, lp, h, n, gate_state) {
            Some(m) => (m, s),
            None => (vec![1.0f32; s], 0),
        };
        let s_eff: f32 = if m_stride == 0 {
            s as f32
        } else {
            m.iter().sum::<f32>() / n.max(1) as f32
        };

        // projections on the shared kernels: fraw [n*S] (pre-gate; the
        // mixer applies its own gating chain), v [n*d]
        let mut fraw = vec![0.0f32; n * s];
        linalg::gemm_at(h, &lp.w_f_t, &mut fraw, n, d, s);
        let mut v = vec![0.0f32; n * d];
        linalg::gemm_at(h, &lp.w_v_t, &mut v, n, d, d);

        let zmix = self.mixer.mix_chunk(&np, s, d, n, &fraw, &m, m_stride, &v, l_mix, u);

        // output projection z = zmix @ w_o
        let mut z = vec![0.0f32; n * d];
        linalg::gemm_at(&zmix, &lp.w_o_t, &mut z, n, d, d);
        (z, s_eff)
    }

    fn layer_norm(&self, x: &[f32], g_off: usize, b_off: usize, out: &mut [f32]) {
        let d = self.cfg.d_model;
        let f = &self.flat[..];
        for (row, orow) in x.chunks_exact(d).zip(out.chunks_exact_mut(d)) {
            let mu = row.iter().sum::<f32>() / d as f32;
            let var = row.iter().map(|&x| (x - mu) * (x - mu)).sum::<f32>() / d as f32;
            let inv = 1.0 / (var + 1e-5).sqrt();
            for (i, (&x, o)) in row.iter().zip(orow.iter_mut()).enumerate() {
                *o = (x - mu) * inv * f[g_off + i] + f[b_off + i];
            }
        }
    }

    /// FFN forward shared by the engine and the training tape (one
    /// implementation, one set of kernels — the backward can never
    /// differentiate a different network than the engine serves):
    /// `hgelu = gelu(h @ w1 + b1)`, `out = b2 + hgelu @ w2`, row-
    /// parallel via [`scatter_rows`]. Returns `(hpre, hgelu, out)`.
    ///
    /// With `want_pre` (the training tape) the pre-GELU activations and
    /// `hgelu` are materialised for the backward sweep; without it (the
    /// engine) both stay chunk-local inside one fused scatter — half
    /// the fan-outs, no O(n·hd) buffers — and `hpre`/`hgelu` come back
    /// empty. The fused and split epilogues are element-identical, so
    /// the two modes produce bitwise-equal `out`.
    pub(crate) fn ffn_parts(
        &self,
        lo: &LayerOffsets,
        lp: &LayerPanels,
        h: &[f32],
        n: usize,
        want_pre: bool,
    ) -> (Option<Vec<f32>>, Vec<f32>, Vec<f32>) {
        let d = self.cfg.d_model;
        let hd = d * self.cfg.ffn_mult.max(1);
        let f = &self.flat[..];
        let b1 = &f[lo.ffn_b1..lo.ffn_b1 + hd];
        let b2 = &f[lo.ffn_b2..lo.ffn_b2 + d];
        let mut out = vec![0.0f32; n * d];
        if !want_pre {
            scatter_rows(n, d, &mut out, MIN_PAR_ROWS, |t0, t1, chunk| {
                let rows = t1 - t0;
                let mut hid = vec![0.0f32; rows * hd];
                linalg::gemm_at(&h[t0 * d..t1 * d], &lp.ffn_w1_t, &mut hid, rows, d, hd);
                linalg::bias_gelu(&mut hid, b1);
                for row in chunk.chunks_exact_mut(d) {
                    row.copy_from_slice(b2);
                }
                linalg::gemm_at(&hid, &lp.ffn_w2_t, chunk, rows, hd, d);
            });
            return (None, Vec::new(), out);
        }
        let mut hid = vec![0.0f32; n * hd];
        scatter_rows(n, hd, &mut hid, MIN_PAR_ROWS, |t0, t1, chunk| {
            linalg::gemm_at(&h[t0 * d..t1 * d], &lp.ffn_w1_t, chunk, t1 - t0, d, hd);
            linalg::add_bias(chunk, b1);
        });
        let hpre = hid.clone();
        for v in hid.iter_mut() {
            *v = linalg::gelu(*v);
        }
        scatter_rows(n, d, &mut out, MIN_PAR_ROWS, |t0, t1, chunk| {
            for row in chunk.chunks_exact_mut(d) {
                row.copy_from_slice(b2);
            }
            linalg::gemm_at(&hid[t0 * hd..t1 * hd], &lp.ffn_w2_t, chunk, t1 - t0, hd, d);
        });
        (Some(hpre), hid, out)
    }

    /// Tied logits head `logits = xf @ embedᵀ` — the single largest
    /// matmul of the trunk (n × vocab × d) — row-parallel via
    /// [`scatter_rows`]. The `[vocab, d]` embedding matrix is already
    /// in the packed (output-major) layout, so no panel is needed.
    pub(crate) fn head_logits(&self, xf: &[f32], n: usize) -> Vec<f32> {
        let (d, vcb) = (self.cfg.d_model, self.cfg.vocab);
        let embed = &self.flat[self.embed..self.embed + vcb * d];
        let mut logits = vec![0.0f32; n * vcb];
        scatter_rows(n, vcb, &mut logits, MIN_PAR_ROWS, |t0, t1, out| {
            linalg::gemm_at(&xf[t0 * d..t1 * d], embed, out, t1 - t0, d, vcb);
        });
        logits
    }

    /// Run one chunk of tokens through the full trunk, advancing the
    /// stacked carry. Returns (logits [n*vocab], mean-over-layers s_eff).
    ///
    /// With a zero carry and the whole sequence as one chunk this is the
    /// `forward` / `eval` semantics; with persistent carries it is the
    /// `stream`/`decode` semantics (gate pooled per chunk, the documented
    /// streaming deviation of `stlt_layer.apply_stream`).
    pub fn trunk_chunk(
        &self,
        l_carry: &mut [f32],
        u_carry: &mut [f32],
        tokens: &[i32],
        noise_std: f32,
        noise_rng: Option<&mut Rng>,
    ) -> Result<(Vec<f32>, f32)> {
        let (s, d, vcb) = (self.cfg.s_max, self.cfg.d_model, self.cfg.vocab);
        let (ll, ul) = self.cfg.carry_lens();
        let n = tokens.len();
        let f = &self.flat[..];
        if l_carry.len() != self.cfg.n_layers * ll || u_carry.len() != self.cfg.n_layers * ul {
            bail!(
                "carry shape mismatch: l={} u={} for {} layers of mixer '{}' \
                 (want l={} u={} per layer, adaptive={})",
                l_carry.len(),
                u_carry.len(),
                self.cfg.n_layers,
                self.mixer.name(),
                ll,
                ul,
                self.cfg.adaptive
            );
        }
        if !self.mixer.streaming()
            && (l_carry.iter().any(|&x| x != 0.0) || u_carry.iter().any(|&x| x != 0.0))
        {
            bail!(
                "mixer '{}' recomputes every prefix sum from scratch \
                 and is only valid from a zero carry (full-sequence forward); \
                 streaming mid-sequence would silently produce wrong logits — \
                 use the Recurrence mixer for chunked/streamed execution",
                self.mixer.name()
            );
        }
        let scale = (d as f32).sqrt();
        let mut x = vec![0.0f32; n * d];
        for (t, &tok) in tokens.iter().enumerate() {
            let tok = tok as usize;
            if tok >= vcb {
                bail!("token {tok} out of vocab {vcb}");
            }
            let er = &f[self.embed + tok * d..self.embed + (tok + 1) * d];
            for (i, &e) in er.iter().enumerate() {
                x[t * d + i] = e * scale;
            }
        }
        if noise_std > 0.0 {
            if let Some(rng) = noise_rng {
                for xe in x.iter_mut() {
                    *xe += noise_std * rng.normal() as f32;
                }
            }
        }
        let mut h = vec![0.0f32; n * d];
        let mut s_eff_sum = 0.0f32;
        for (li, (lo, lp)) in self.layers.iter().zip(&self.panels.layers).enumerate() {
            self.layer_norm(&x, lo.ln1_g, lo.ln1_b, &mut h);
            let lsl = &mut l_carry[li * ll..(li + 1) * ll];
            let usl = &mut u_carry[li * ul..(li + 1) * ul];
            let (z, s_eff) = self.mixer_chunk(lo, lp, &h, n, lsl, usl);
            s_eff_sum += s_eff;
            for (xe, ze) in x.iter_mut().zip(&z) {
                *xe += ze;
            }
            self.layer_norm(&x, lo.ln2_g, lo.ln2_b, &mut h);
            let (_, _, f_out) = self.ffn_parts(lo, lp, &h, n, false);
            for (xe, fe) in x.iter_mut().zip(&f_out) {
                *xe += fe;
            }
        }
        let mut xf = vec![0.0f32; n * d];
        self.layer_norm(&x, self.lnf_g, self.lnf_b, &mut xf);
        let logits = self.head_logits(&xf, n);
        Ok((logits, s_eff_sum / self.cfg.n_layers as f32))
    }

    /// Batched single-token decode: advance `bsz` independent sessions
    /// by one token each, in one pass over the packed weight panels.
    /// This is the serving hot path behind the `decode_batch` artifact
    /// kind: session *rows* take the place of token rows in every GEMM
    /// (`h [bsz, d] @ panel`), so each weight panel is streamed once
    /// per wave instead of once per session, while the (L, U)
    /// recurrence advances each row's own carry slice exactly one step.
    ///
    /// Per-row outputs are bitwise identical to running
    /// [`StltModel::trunk_chunk`] on that row's carry with its single
    /// token: every `gemm_at` output element is `dot(a_row, bt_row)`
    /// independent of the row count (the linalg parity guarantee),
    /// LayerNorm and the recurrence are strictly per-row, and the
    /// adaptive gate pools over each row alone — exactly the n = 1
    /// pooling of a single-token chunk. Pinned by unit test and by the
    /// server's padding/masking parity test.
    ///
    /// `l_all` is `[bsz, n_layers*S*2]`, `u_all` `[bsz, n_layers*S*d*2]`
    /// (row-major). Rows with `active[r] <= 0.5` are padding: their
    /// carries are untouched and their logits row is zero. Returns
    /// logits `[bsz * vocab]`.
    pub fn decode_step_batch(
        &self,
        bsz: usize,
        l_all: &mut [f32],
        u_all: &mut [f32],
        tokens: &[i32],
        active: &[f32],
    ) -> Result<Vec<f32>> {
        if !self.mixer.streaming() {
            bail!(
                "decode_step_batch needs a streaming mixer, not '{}' (the quadratic \
                 oracle is valid from a zero carry on full sequences — see \
                 trunk_chunk; use the Recurrence mixer for decode)",
                self.mixer.name()
            );
        }
        let (s, d, vcb) = (self.cfg.s_max, self.cfg.d_model, self.cfg.vocab);
        let (ll, ul) = self.cfg.carry_lens();
        let (sl, _) = self.mixer.state_lens(&self.cfg);
        let (l_stride, u_stride) = (self.cfg.n_layers * ll, self.cfg.n_layers * ul);
        if l_all.len() != bsz * l_stride
            || u_all.len() != bsz * u_stride
            || tokens.len() != bsz
            || active.len() != bsz
        {
            bail!(
                "decode_step_batch shape mismatch: bsz={bsz} l={} u={} tokens={} active={}",
                l_all.len(),
                u_all.len(),
                tokens.len(),
                active.len()
            );
        }
        let f = &self.flat[..];
        let mut logits_out = vec![0.0f32; bsz * vcb];
        // compact the active rows so padding costs nothing and the GEMM
        // row dimension is dense; idx maps compact row -> original row
        let idx: Vec<usize> = (0..bsz).filter(|&r| active[r] > 0.5).collect();
        let na = idx.len();
        if na == 0 {
            return Ok(logits_out);
        }
        // validate every token before touching any carry, so a bad row
        // cannot leave sibling rows half-advanced
        for &r in &idx {
            let tok = tokens[r];
            if tok < 0 || tok as usize >= vcb {
                bail!("token {tok} out of vocab {vcb}");
            }
        }
        let scale = (d as f32).sqrt();
        let mut x = vec![0.0f32; na * d];
        for (c, &r) in idx.iter().enumerate() {
            let tok = tokens[r] as usize;
            let er = &f[self.embed + tok * d..self.embed + (tok + 1) * d];
            for (i, &e) in er.iter().enumerate() {
                x[c * d + i] = e * scale;
            }
        }
        let mut h = vec![0.0f32; na * d];
        let ones = vec![1.0f32; s];
        for (li, (lo, lp)) in self.layers.iter().zip(&self.panels.layers).enumerate() {
            self.layer_norm(&x, lo.ln1_g, lo.ln1_b, &mut h);
            // projections batched over session rows (pre-gate; the
            // mixer applies its own gating chain per row)
            let mut fraw = vec![0.0f32; na * s];
            linalg::gemm_at(&h, &lp.w_f_t, &mut fraw, na, d, s);
            let mut v = vec![0.0f32; na * d];
            linalg::gemm_at(&h, &lp.w_v_t, &mut v, na, d, d);
            // per-row one-step mixer advance on each row's own carry slice
            let np = self.node_params(lo);
            let mut zmix = vec![0.0f32; na * d];
            for (c, &r) in idx.iter().enumerate() {
                let l_off = r * l_stride + li * ll;
                let u_off = r * u_stride + li * ul;
                let lsl = &mut l_all[l_off..l_off + ll];
                let usl = &mut u_all[u_off..u_off + ul];
                let (l_mix, gate_state) = lsl.split_at_mut(sl);
                // a one-token chunk of this row's own stream: the causal
                // gate advances the row's pooling state exactly like
                // trunk_chunk would
                let m = self
                    .causal_gate_rows(lo, lp, &h[c * d..(c + 1) * d], 1, gate_state)
                    .unwrap_or_default();
                let m_row = if m.is_empty() { &ones[..] } else { &m[..] };
                self.mixer.token_step(
                    &np,
                    s,
                    d,
                    &fraw[c * s..(c + 1) * s],
                    m_row,
                    l_mix,
                    usl,
                    &v[c * d..(c + 1) * d],
                    Some(&mut zmix[c * d..(c + 1) * d]),
                );
            }
            let mut z = vec![0.0f32; na * d];
            linalg::gemm_at(&zmix, &lp.w_o_t, &mut z, na, d, d);
            for (xe, ze) in x.iter_mut().zip(&z) {
                *xe += ze;
            }
            self.layer_norm(&x, lo.ln2_g, lo.ln2_b, &mut h);
            let (_, _, f_out) = self.ffn_parts(lo, lp, &h, na, false);
            for (xe, fe) in x.iter_mut().zip(&f_out) {
                *xe += fe;
            }
        }
        let mut xf = vec![0.0f32; na * d];
        self.layer_norm(&x, self.lnf_g, self.lnf_b, &mut xf);
        let logits = self.head_logits(&xf, na);
        for (c, &r) in idx.iter().enumerate() {
            logits_out[r * vcb..(r + 1) * vcb].copy_from_slice(&logits[c * vcb..(c + 1) * vcb]);
        }
        Ok(logits_out)
    }

    /// Full-sequence forward from a zero carry: logits [n*vocab].
    pub fn forward_logits(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        let (mut l, mut u) = self.zero_carry();
        Ok(self.trunk_chunk(&mut l, &mut u, tokens, 0.0, None)?.0)
    }

    /// Next-token NLL of one row: tokens [n+1] -> (nll_sum, count, s_eff).
    ///
    /// `noise_std > 0` adds Gaussian embedding noise from the given seed
    /// (the SS4.7 robustness knob). The native noise stream is its own
    /// RNG — statistically, not bitwise, equivalent to the XLA backend's.
    pub fn eval_row(&self, tokens: &[i32], noise_std: f32, seed: u64) -> Result<(f64, f64, f32)> {
        if tokens.len() < 2 {
            bail!("eval row needs at least 2 tokens");
        }
        let n = tokens.len() - 1;
        let (mut l, mut u) = self.zero_carry();
        let mut rng = Rng::new(seed ^ 0x51A7_E2F0);
        let (logits, s_eff) =
            self.trunk_chunk(&mut l, &mut u, &tokens[..n], noise_std, Some(&mut rng))?;
        let mut nll = 0.0f64;
        for t in 0..n {
            nll += nll_of(&logits[t * self.cfg.vocab..(t + 1) * self.cfg.vocab], tokens[t + 1])?;
        }
        Ok((nll, n as f64, s_eff))
    }
}

/// -log softmax(logits)[target], accumulated in f64 like the XLA path's
/// f32 sum but stabler for long documents.
pub fn nll_of(logits: &[f32], target: i32) -> Result<f64> {
    let t = target as usize;
    if t >= logits.len() {
        bail!("target {t} out of vocab {}", logits.len());
    }
    let mx = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut denom = 0.0f64;
    for &x in logits {
        denom += ((x - mx) as f64).exp();
    }
    Ok(denom.ln() - (logits[t] - mx) as f64)
}

/// Host-side random init mirroring `python/compile/trunk.init` shapes
/// and magnitudes (LN gains 1, log-spaced sigma, T = t_init, mostly-on
/// adaptive gates). Not bitwise python-equal — used for native-only
/// smoke paths and tests when no `.init.bin` artifact exists.
pub fn host_init(cfg: &ModelConfig, seed: u64) -> Vec<f32> {
    let layout = trunk_layout(cfg);
    let total = total_params(&layout);
    let mut flat = vec![0.0f32; total];
    let mut rng = Rng::new(seed);
    let s = cfg.s_max;
    // softplus⁻¹(y) = ln(e^y − 1): the naive form overflows f32 to inf
    // for y ≳ 89 (e.g. a manifest with t_init ≳ 90), seeding non-finite
    // t_raw. Above the knee use the log1p-stable y + ln(1 − e⁻ʸ), which
    // round-trips exactly through the matching `softplus` branch.
    let inv_softplus = |y: f32| {
        if y > 20.0 {
            y + (-(-y).exp()).ln_1p()
        } else {
            y.exp_m1().max(1e-6).ln()
        }
    };
    for leaf in &layout {
        let out = &mut flat[leaf.offset..leaf.offset + leaf.numel()];
        let name = leaf.path.rsplit('/').next().unwrap_or("");
        match name {
            "ln1_g" | "ln2_g" | "lnf_g" => out.fill(1.0),
            "ln1_b" | "ln2_b" | "lnf_b" | "ffn_b1" | "ffn_b2" => out.fill(0.0),
            "sigma_raw" => {
                let (lo, hi) = (0.01f32, 2.0f32);
                for (k, o) in out.iter_mut().enumerate() {
                    let frac = if s > 1 { k as f32 / (s - 1) as f32 } else { 0.0 };
                    let sig = lo * (hi / lo).powf(frac);
                    *o = inv_softplus(sig);
                }
            }
            "omega" => {
                for o in out.iter_mut() {
                    *o = if cfg.omega_zero { 0.0 } else { rng.f32() * 0.785 };
                }
            }
            "t_raw" => out.fill(inv_softplus(cfg.t_init.max(1.5) - 1.0)),
            "b_alpha" => out.fill(2.0),
            _ => {
                for o in out.iter_mut() {
                    *o = (rng.normal() * 0.02) as f32;
                }
            }
        }
    }
    flat
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::fft::{relevance_direct, relevance_spectral};

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            arch: "stlt".into(),
            vocab: 17,
            d_model: 8,
            n_layers: 2,
            n_ctx: 16,
            s_max: 4,
            batch: 2,
            mode: "linear".into(),
            ..ModelConfig::default()
        }
    }

    fn model(cfg: &ModelConfig, seed: u64) -> StltModel {
        StltModel::new(cfg, Arc::new(host_init(cfg, seed))).unwrap()
    }

    #[test]
    fn rejects_wrong_arch_and_size() {
        let mut cfg = tiny_cfg();
        cfg.arch = "vanilla".into();
        assert!(StltModel::new(&cfg, Arc::new(vec![])).is_err());
        let cfg = tiny_cfg();
        assert!(StltModel::new(&cfg, Arc::new(vec![0.0; 3])).is_err());
    }

    #[test]
    fn recurrence_matches_n2_reference() {
        // the tentpole correctness seam: O(N S d) recurrence == O(N^2)
        // relevance-matrix oracle on full-sequence forwards, with both
        // mixers selected the supported way (cfg.mixer) over one
        // shared parameter vector — adaptive and not (the ablation
        // mode must see the same causal gates the recurrence does)
        for adaptive in [false, true] {
            for seed in [1u64, 9] {
                let mut cfg = tiny_cfg();
                cfg.adaptive = adaptive;
                let flat = Arc::new(host_init(&cfg, seed));
                let m = StltModel::new(&cfg, Arc::clone(&flat)).unwrap();
                let mut cfg2 = cfg.clone();
                cfg2.mixer = "reference_n2".into();
                let m2 = StltModel::new(&cfg2, flat).unwrap();
                let tokens: Vec<i32> =
                    (0..12).map(|i| (i * 5 + 3) % cfg.vocab as i32).collect();
                let fast = m.forward_logits(&tokens).unwrap();
                let slow = m2.forward_logits(&tokens).unwrap();
                for (a, b) in fast.iter().zip(&slow) {
                    assert!(
                        (a - b).abs() < 1e-3 * (1.0 + a.abs()),
                        "{a} vs {b} (adaptive={adaptive})"
                    );
                }
            }
        }
    }

    #[test]
    fn reference_n2_rejects_nonzero_carry() {
        // the oracle is documented zero-carry-only; streaming it
        // mid-sequence must be a hard error, not silently-wrong logits
        let mut cfg = tiny_cfg();
        cfg.mixer = "reference_n2".into();
        let m = model(&cfg, 1);
        let tokens: Vec<i32> = (0..6).map(|i| i % cfg.vocab as i32).collect();
        let (mut l, mut u) = m.zero_carry();
        m.trunk_chunk(&mut l, &mut u, &tokens, 0.0, None).unwrap();
        let err = m.trunk_chunk(&mut l, &mut u, &tokens, 0.0, None).unwrap_err();
        assert!(format!("{err:#}").contains("zero carry"), "unhelpful error: {err:#}");
    }

    #[test]
    fn unknown_mixer_is_rejected_at_plan_time() {
        let mut cfg = tiny_cfg();
        cfg.mixer = "softmax".into();
        let err = StltPlan::new(&cfg).unwrap_err();
        assert!(format!("{err:#}").contains("unknown mixer"), "unhelpful: {err:#}");
    }

    #[test]
    fn chunking_is_invariant() {
        let cfg = tiny_cfg();
        let m = model(&cfg, 3);
        let tokens: Vec<i32> = (0..15).map(|i| (i * 7 + 1) % cfg.vocab as i32).collect();
        let whole = m.forward_logits(&tokens).unwrap();
        let (mut l, mut u) = m.zero_carry();
        let mut pieces = Vec::new();
        for chunk in [5usize, 1, 6, 3] {
            let off = pieces.len() / cfg.vocab;
            let (lg, _) =
                m.trunk_chunk(&mut l, &mut u, &tokens[off..off + chunk], 0.0, None).unwrap();
            pieces.extend(lg);
        }
        assert_eq!(whole.len(), pieces.len());
        for (a, b) in whole.iter().zip(&pieces) {
            assert!((a - b).abs() < 1e-4 * (1.0 + a.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn panel_cache_hits_on_same_params_only() {
        // the bind-seam memo: same Arc -> same packed panels; different
        // params -> fresh panels (never stale weights)
        let cfg = tiny_cfg();
        let plan = StltPlan::new(&cfg).unwrap();
        let flat = Arc::new(host_init(&cfg, 1));
        let m1 = plan.bind(Arc::clone(&flat)).unwrap();
        let m2 = plan.bind(Arc::clone(&flat)).unwrap();
        assert!(Arc::ptr_eq(&m1.panels, &m2.panels), "repeat bind must hit the memo");
        let m3 = plan.bind(Arc::new(host_init(&cfg, 2))).unwrap();
        assert!(!Arc::ptr_eq(&m1.panels, &m3.panels), "new params must re-pack");
        // and the packed panels are really the transposed weights
        let lo = &m1.layers[0];
        let lp = &m1.panels.layers[0];
        let (d, s) = (cfg.d_model, cfg.s_max);
        for i in 0..d {
            for k in 0..s {
                assert_eq!(lp.w_f_t[k * d + i], m1.flat[lo.w_f + i * s + k]);
            }
        }
    }

    #[test]
    fn decode_step_batch_bitwise_matches_single_rows() {
        // the serving parity seam: each row of the batched single-token
        // forward must be BITWISE the single-session trunk_chunk on the
        // same carry, with inactive rows untouched — for every
        // streaming mixer, adaptive and not.
        for (mixer, adaptive) in [
            ("recurrence", false),
            ("recurrence", true),
            ("linear_attention", false),
            ("linear_attention", true),
        ] {
            let mut cfg = tiny_cfg();
            cfg.mixer = mixer.into();
            cfg.adaptive = adaptive;
            let m = model(&cfg, 17);
            let bsz = 5usize;
            let (l0, u0) = m.zero_carry();
            let (ls, us) = (l0.len(), u0.len());
            // give every row a distinct warmed-up carry
            let mut l_all = vec![0.0f32; bsz * ls];
            let mut u_all = vec![0.0f32; bsz * us];
            for r in 0..bsz {
                let (mut l, mut u) = m.zero_carry();
                let warm: Vec<i32> =
                    (0..3 + r).map(|i| ((i * 7 + r) % cfg.vocab) as i32).collect();
                m.trunk_chunk(&mut l, &mut u, &warm, 0.0, None).unwrap();
                l_all[r * ls..(r + 1) * ls].copy_from_slice(&l);
                u_all[r * us..(r + 1) * us].copy_from_slice(&u);
            }
            let tokens: Vec<i32> = (0..bsz).map(|r| ((r * 3 + 1) % cfg.vocab) as i32).collect();
            // row 2 inactive (ragged wave padding)
            let active: Vec<f32> = (0..bsz).map(|r| if r == 2 { 0.0 } else { 1.0 }).collect();
            let (l_ref_all, u_ref_all) = (l_all.clone(), u_all.clone());
            let logits =
                m.decode_step_batch(bsz, &mut l_all, &mut u_all, &tokens, &active).unwrap();
            for r in 0..bsz {
                let mut l = l_ref_all[r * ls..(r + 1) * ls].to_vec();
                let mut u = u_ref_all[r * us..(r + 1) * us].to_vec();
                if r == 2 {
                    assert_eq!(&l_all[r * ls..(r + 1) * ls], &l[..], "inactive carry touched");
                    assert_eq!(&u_all[r * us..(r + 1) * us], &u[..], "inactive carry touched");
                    assert!(
                        logits[r * cfg.vocab..(r + 1) * cfg.vocab].iter().all(|&x| x == 0.0),
                        "inactive logits must be zero"
                    );
                    continue;
                }
                let (want, _) =
                    m.trunk_chunk(&mut l, &mut u, &tokens[r..r + 1], 0.0, None).unwrap();
                assert_eq!(
                    &logits[r * cfg.vocab..(r + 1) * cfg.vocab],
                    &want[..],
                    "row {r} logits diverge (adaptive={adaptive})"
                );
                assert_eq!(&l_all[r * ls..(r + 1) * ls], &l[..], "row {r} L carry");
                assert_eq!(&u_all[r * us..(r + 1) * us], &u[..], "row {r} U carry");
            }
        }
    }

    #[test]
    fn decode_step_batch_rejects_bad_tokens_without_mutation() {
        let cfg = tiny_cfg();
        let m = model(&cfg, 8);
        let (l0, u0) = m.zero_carry();
        let (ls, us) = (l0.len(), u0.len());
        let mut l_all = vec![0.5f32; 2 * ls];
        let mut u_all = vec![0.25f32; 2 * us];
        let (l_ref, u_ref) = (l_all.clone(), u_all.clone());
        let err = m
            .decode_step_batch(2, &mut l_all, &mut u_all, &[1, cfg.vocab as i32], &[1.0, 1.0])
            .unwrap_err();
        assert!(format!("{err:#}").contains("vocab"), "unhelpful: {err:#}");
        assert_eq!(l_all, l_ref, "no carry may advance on a rejected wave");
        assert_eq!(u_all, u_ref);
        // the reference_n2 oracle is zero-carry/full-sequence only; the
        // batched decode path must refuse it like trunk_chunk does
        let mut cfg2 = cfg.clone();
        cfg2.mixer = "reference_n2".into();
        let m2 = model(&cfg2, 8);
        let err =
            m2.decode_step_batch(2, &mut l_all, &mut u_all, &[1, 2], &[1.0, 1.0]).unwrap_err();
        assert!(format!("{err:#}").contains("Recurrence"), "unhelpful: {err:#}");
    }

    #[test]
    fn adaptive_gate_thins_nodes() {
        let mut cfg = tiny_cfg();
        cfg.adaptive = true;
        let m = model(&cfg, 5);
        let tokens: Vec<i32> = (0..10).map(|i| i % cfg.vocab as i32).collect();
        let (mut l, mut u) = m.zero_carry();
        let (_, s_eff) = m.trunk_chunk(&mut l, &mut u, &tokens, 0.0, None).unwrap();
        assert!(s_eff > 0.0 && s_eff < cfg.s_max as f32, "s_eff {s_eff}");
    }

    #[test]
    fn adaptive_and_linattn_chunking_is_bitwise_invariant() {
        // the causal gate carries its pooling state in the l-slot, so
        // chunked streaming must be BITWISE the whole-sequence forward
        // (not merely close, as the float-reassociation tolerance of
        // `chunking_is_invariant` allows) — the satellite guarantee the
        // serving path depends on, for every streaming mixer
        for (mixer, adaptive) in [
            ("recurrence", true),
            ("linear_attention", false),
            ("linear_attention", true),
        ] {
            let mut cfg = tiny_cfg();
            cfg.mixer = mixer.into();
            cfg.adaptive = adaptive;
            let m = model(&cfg, 23);
            let tokens: Vec<i32> = (0..15).map(|i| (i * 7 + 1) % cfg.vocab as i32).collect();
            let whole = m.forward_logits(&tokens).unwrap();
            let (mut l, mut u) = m.zero_carry();
            let mut pieces = Vec::new();
            for chunk in [5usize, 1, 6, 3] {
                let off = pieces.len() / cfg.vocab;
                let (lg, _) = m
                    .trunk_chunk(&mut l, &mut u, &tokens[off..off + chunk], 0.0, None)
                    .unwrap();
                pieces.extend(lg);
            }
            assert_eq!(whole, pieces, "mixer={mixer} adaptive={adaptive}");
        }
    }

    #[test]
    fn eval_row_near_uniform_for_random_params() {
        let cfg = tiny_cfg();
        let m = model(&cfg, 11);
        let tokens: Vec<i32> = (0..13).map(|i| (3 * i) % cfg.vocab as i32).collect();
        let (nll, cnt, _) = m.eval_row(&tokens, 0.0, 0).unwrap();
        let ppl = (nll / cnt).exp();
        let v = cfg.vocab as f64;
        assert!(ppl > 0.5 * v && ppl < 2.0 * v, "ppl {ppl} vs vocab {v}");
    }

    #[test]
    fn noise_changes_nll_deterministically() {
        let cfg = tiny_cfg();
        let m = model(&cfg, 2);
        let tokens: Vec<i32> = (0..9).map(|i| i % cfg.vocab as i32).collect();
        let (a, _, _) = m.eval_row(&tokens, 0.5, 7).unwrap();
        let (b, _, _) = m.eval_row(&tokens, 0.5, 7).unwrap();
        let (c, _, _) = m.eval_row(&tokens, 0.0, 7).unwrap();
        assert_eq!(a, b, "same seed must reproduce");
        assert!((a - c).abs() > 1e-9, "noise should perturb the NLL");
    }

    #[test]
    fn host_init_stable_for_large_t_init() {
        // the naive softplus-inverse overflowed f32 here, seeding
        // t_raw = inf and a non-finite forward
        let mut cfg = tiny_cfg();
        cfg.t_init = 5000.0;
        let flat = host_init(&cfg, 1);
        assert!(flat.iter().all(|x| x.is_finite()), "init must be finite");
        let m = StltModel::new(&cfg, Arc::new(flat)).unwrap();
        let np = m.node_params(&m.layers[0]);
        // T must round-trip: gamma = e^{-1/(8 T)} with T = t_init
        let want = (-1.0f32 / (8.0 * cfg.t_init)).exp();
        assert!(
            (np.gamma - want).abs() < 1e-5 && np.gamma < 1.0,
            "gamma {} vs {want}",
            np.gamma
        );
        let tokens: Vec<i32> = (0..8).map(|i| i % cfg.vocab as i32).collect();
        let logits = m.forward_logits(&tokens).unwrap();
        assert!(logits.iter().all(|x| x.is_finite()), "forward must stay finite");
    }

    #[test]
    fn relevance_of_laplace_rows_matches_spectral_form() {
        // SS3.4 cross-check, reusing util::fft: the relevance between two
        // transform rows computed directly equals the Parseval/spectral
        // form on the native backend's own L values.
        let cfg = tiny_cfg();
        let m = model(&cfg, 13);
        let lo = &m.layers[0];
        let np = m.node_params(lo);
        let s = cfg.s_max;
        let mut rng = Rng::new(4);
        let n = 6usize;
        let f: Vec<f32> = (0..n * s).map(|_| rng.f32() - 0.5).collect();
        // build L rows via the recurrence
        let mut l_rows_re = vec![0.0f32; n * s];
        let mut l_rows_im = vec![0.0f32; n * s];
        let (mut lr, mut li) = (vec![0.0f32; s], vec![0.0f32; s]);
        for t in 0..n {
            for k in 0..s {
                let (a, b) = (lr[k], li[k]);
                lr[k] = np.lam_re[k] * a - np.lam_im[k] * b + f[t * s + k];
                li[k] = np.lam_re[k] * b + np.lam_im[k] * a;
            }
            l_rows_re[t * s..(t + 1) * s].copy_from_slice(&lr);
            l_rows_im[t * s..(t + 1) * s].copy_from_slice(&li);
        }
        for a in 0..n {
            for b in 0..n {
                let (ar, ai) = (&l_rows_re[a * s..(a + 1) * s], &l_rows_im[a * s..(a + 1) * s]);
                let (br, bi) = (&l_rows_re[b * s..(b + 1) * s], &l_rows_im[b * s..(b + 1) * s]);
                let direct = relevance_direct(ar, ai, br, bi);
                let spectral = relevance_spectral(ar, ai, br, bi);
                assert!(
                    (direct - spectral).abs() < 1e-3 * (1.0 + direct.abs()),
                    "R[{a},{b}]: {direct} vs {spectral}"
                );
            }
        }
    }
}
