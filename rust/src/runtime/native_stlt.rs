//! Pure-Rust STLT execution engine: the paper's central claim — token
//! mixing is an O(N·S·d) recursive convolution with an O(S·d) streaming
//! carry — means inference needs no XLA compiler at all. This module
//! executes the decoder-only STLT trunk (embedding, per-node recursive
//! Laplace convolution with learnable (sigma_s, omega_s, T), FFN,
//! LayerNorm, tied logits head) directly from the same flat parameter
//! vector and manifest `ModelConfig` the AOT artifacts consume.
//!
//! Semantics mirror `python/compile/{trunk,stlt_layer}.py` and the
//! kernel oracles in `python/compile/kernels/ref.py`:
//!
//!   sigma   = softplus(sigma_raw) + sigma_min
//!   T       = softplus(t_raw) + 1
//!   lam_k   = e^{-(sigma_k + 1/T)} * e^{-j omega_k}      (window folded)
//!   gamma   = e^{-1/(8 T)}                               (U discount)
//!   L_n     = lam * L_{n-1} + f_n                        (O(S) carry)
//!   U_n     = gamma * U_{n-1} + conj(L_n) (x) v_n        (O(S d) carry)
//!   z_n     = Re<L_n, U_n> / S
//!
//! Every projection around that recurrence — mixer w_f/w_v/w_o, the
//! FFN, and the n×vocab×d tied logits head — runs on the shared
//! blocked-GEMM kernels in [`crate::util::linalg`], against weight
//! panels pre-transposed once per bound parameter vector
//! ([`StltPlan::bind`] memoizes the packing by parameter-vector
//! identity, so the per-token decode serving path never re-packs).
//! The tied head and FFN additionally fan out over token rows via
//! [`crate::util::threadpool::scatter_rows`]. The training tape in
//! [`crate::train`] calls the same kernels on the same panels — and the
//! same [`lu_node_step`] recurrence kernel, so the (L, U) carry
//! snapshots its segment-checkpointed backward stores replay to
//! bitwise-identical values — so the forward and backward can never
//! drift numerically.
//!
//! A naive O(N^2 S) relevance-matrix oracle ([`MixerImpl::ReferenceN2`])
//! and FFT-based spectral relevance cross-checks (via [`crate::util::fft`],
//! the paper's SS3.4 claim) keep the recurrence honest in tests.

use std::sync::{Arc, Mutex, Weak};

use anyhow::{anyhow, bail, Result};

use crate::interpret::{total_params, trunk_layout, Leaf};
use crate::runtime::artifact::ModelConfig;
use crate::util::linalg;
use crate::util::rng::Rng;
use crate::util::threadpool::scatter_rows;

/// Row count below which the row-parallel head/FFN paths run inline —
/// the decode path (n = 1) and the server's small chunks never pay
/// thread-fanout overhead.
const MIN_PAR_ROWS: usize = 16;

static BIND_HITS: crate::obs::LazyCounter = crate::obs::LazyCounter::new("panels/bind_hits");
static BIND_PACKS: crate::obs::LazyCounter = crate::obs::LazyCounter::new("panels/bind_packs");

/// Publish per-node `sigma`/`omega`/`T`/half-life gauges under
/// `node/l{L}/n{K}/..` plus a per-layer `half_life_mean` — the paper's
/// interpretability story (a node's memory half-life is
/// `ln2 / (sigma + 1/T)` tokens) surfaced as live telemetry. Called at
/// server start and every `--metrics-every` interval during training;
/// a flat vector that does not match the config is skipped silently
/// (foreign-backend layouts have nothing to report).
pub fn publish_node_gauges(cfg: &ModelConfig, flat: &[f32]) {
    if !crate::obs::metrics_on() {
        return;
    }
    let plan = match StltPlan::new(cfg) {
        Ok(p) => p,
        Err(_) => return,
    };
    if flat.len() != plan.total {
        return;
    }
    let ln2 = std::f64::consts::LN_2;
    for (l, lo) in plan.layers.iter().enumerate() {
        let t = softplus(flat[lo.t_raw]) + 1.0;
        let mut hl_sum = 0.0f64;
        for k in 0..cfg.s_max {
            let sigma = softplus(flat[lo.sigma_raw + k]) + cfg.sigma_min;
            let omega = if cfg.omega_zero { 0.0 } else { flat[lo.omega + k] };
            let half_life = ln2 / (sigma as f64 + 1.0 / t as f64);
            hl_sum += half_life;
            crate::obs::gauge(&format!("node/l{l}/n{k}/sigma")).set(sigma as f64);
            crate::obs::gauge(&format!("node/l{l}/n{k}/omega")).set(omega as f64);
            crate::obs::gauge(&format!("node/l{l}/n{k}/t")).set(t as f64);
            crate::obs::gauge(&format!("node/l{l}/n{k}/half_life")).set(half_life);
        }
        crate::obs::gauge(&format!("node/l{l}/half_life_mean"))
            .set(hl_sum / cfg.s_max.max(1) as f64);
    }
}

/// One node's Laplace-carry advance for a single timestep — THE
/// recurrence kernel, shared verbatim by the streaming engine
/// ([`StltModel::mix_recurrence`]), the training-tape forward, and the
/// backward pass's segment-checkpoint replay (`train/backward.rs`).
/// One function on all three sides means a carry snapshot taken during
/// the tape forward replays to bitwise-identical (L, U) values during
/// the backward, and the tape can never drift from what the engine
/// serves.
///
///   L ← lam·L + f_tk          (lk = [re, im])
///   U ← gamma·U + conj(L)⊗v   (uk = [d][re, im])
///   z += Re(L·U)              (when zr is Some; caller divides by S)
///
/// `zr: None` is the backward's replay mode: it advances the identical
/// L/U state (z never feeds back into L or U) without paying the
/// discarded z flops. One body serves both so the two modes cannot
/// drift.
#[inline(always)]
pub(crate) fn lu_node_step(
    lam_re: f32,
    lam_im: f32,
    gamma: f32,
    f_tk: f32,
    lk: &mut [f32],
    uk: &mut [f32],
    vr: &[f32],
    mut zr: Option<&mut [f32]>,
) {
    let (lr, li) = (lk[0], lk[1]);
    let nlr = lam_re * lr - lam_im * li + f_tk;
    let nli = lam_re * li + lam_im * lr;
    lk[0] = nlr;
    lk[1] = nli;
    for (e, &ve) in vr.iter().enumerate() {
        let ur = gamma * uk[e * 2] + nlr * ve;
        let ui = gamma * uk[e * 2 + 1] - nli * ve;
        uk[e * 2] = ur;
        uk[e * 2 + 1] = ui;
        if let Some(z) = zr.as_deref_mut() {
            z[e] += nlr * ur - nli * ui;
        }
    }
}

pub(crate) fn softplus(x: f32) -> f32 {
    if x > 20.0 {
        x
    } else {
        (1.0 + x.exp()).ln()
    }
}

pub(crate) fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// Which mixer implementation [`StltModel::forward_logits`] uses.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum MixerImpl {
    /// The O(N·S·d) recursive convolution (production path).
    #[default]
    Recurrence,
    /// Naive O(N^2·S·d) relevance-style oracle recomputing every
    /// discounted prefix sum from scratch — test-only cross-check;
    /// only valid from a zero carry (full-sequence forward), enforced
    /// by [`StltModel::trunk_chunk`].
    ReferenceN2,
}

/// Resolved offsets of one trunk layer inside the flat vector.
/// `pub(crate)` so the hand-derived backward pass in [`crate::train`]
/// can address the same parameter slices the forward reads.
#[derive(Clone, Debug)]
pub(crate) struct LayerOffsets {
    pub(crate) ln1_g: usize,
    pub(crate) ln1_b: usize,
    pub(crate) ln2_g: usize,
    pub(crate) ln2_b: usize,
    pub(crate) ffn_w1: usize,
    pub(crate) ffn_b1: usize,
    pub(crate) ffn_w2: usize,
    pub(crate) ffn_b2: usize,
    pub(crate) w_f: usize,
    pub(crate) w_v: usize,
    pub(crate) w_o: usize,
    pub(crate) sigma_raw: usize,
    pub(crate) omega: usize,
    pub(crate) t_raw: usize,
    /// adaptive node-allocation gate (SS3.6), if cfg.adaptive
    pub(crate) w_alpha: Option<usize>,
    pub(crate) b_alpha: Option<usize>,
}

/// Pre-transposed ("packed") weight panels of one layer: every matrix
/// the forward multiplies by, stored output-major so each output
/// element is one contiguous [`linalg::dot`] over the shared dimension
/// (the [`linalg::gemm_at`]/[`linalg::gemv`] layout).
pub(crate) struct LayerPanels {
    pub(crate) w_f_t: Vec<f32>,    // [S, d]
    pub(crate) w_v_t: Vec<f32>,    // [d, d]
    pub(crate) w_o_t: Vec<f32>,    // [d, d]
    pub(crate) ffn_w1_t: Vec<f32>, // [hd, d]
    pub(crate) ffn_w2_t: Vec<f32>, // [d, hd]
    pub(crate) w_alpha_t: Option<Vec<f32>>, // [S, d]
}

/// All layers' packed panels for one bound parameter vector. The tied
/// head needs no panel: the `[vocab, d]` embedding matrix is already
/// output-major for `logits = xf @ embedᵀ`.
pub(crate) struct Panels {
    pub(crate) layers: Vec<LayerPanels>,
}

fn pack_panels(cfg: &ModelConfig, layers: &[LayerOffsets], flat: &[f32]) -> Panels {
    let (s, d) = (cfg.s_max, cfg.d_model);
    let hd = d * cfg.ffn_mult.max(1);
    let layers = layers
        .iter()
        .map(|lo| LayerPanels {
            w_f_t: linalg::transpose(&flat[lo.w_f..lo.w_f + d * s], d, s),
            w_v_t: linalg::transpose(&flat[lo.w_v..lo.w_v + d * d], d, d),
            w_o_t: linalg::transpose(&flat[lo.w_o..lo.w_o + d * d], d, d),
            ffn_w1_t: linalg::transpose(&flat[lo.ffn_w1..lo.ffn_w1 + d * hd], d, hd),
            ffn_w2_t: linalg::transpose(&flat[lo.ffn_w2..lo.ffn_w2 + hd * d], hd, d),
            w_alpha_t: lo.w_alpha.map(|wa| linalg::transpose(&flat[wa..wa + d * s], d, s)),
        })
        .collect();
    Panels { layers }
}

/// Memoized packing: (identity of the last-bound parameter vector, its
/// panels). `Weak` so the cache never keeps a stale vector alive, and a
/// recycled allocation address can never alias a dead entry.
type PanelCache = Mutex<Option<(Weak<Vec<f32>>, Arc<Panels>)>>;

/// Per-layer node constants derived from the learnable parameters.
pub(crate) struct NodeParams {
    pub(crate) lam_re: Vec<f32>,
    pub(crate) lam_im: Vec<f32>,
    pub(crate) gamma: f32,
}

/// Resolved execution plan for one config: validated arch/mode plus
/// every parameter offset. Built once (per backend `load`), then bound
/// to concrete parameter vectors cheaply via [`StltPlan::bind`] — the
/// decode serving path binds once per call, so plan resolution (string
/// path lookups over the layout) must not sit on it, and the weight
/// panel packing is memoized by parameter-vector identity so repeat
/// binds of the same (Arc) vector are two Arc clones plus a pointer
/// compare.
#[derive(Clone)]
pub struct StltPlan {
    pub cfg: Arc<ModelConfig>,
    layers: Arc<Vec<LayerOffsets>>,
    embed: usize,
    lnf_g: usize,
    lnf_b: usize,
    total: usize,
    panel_cache: Arc<PanelCache>,
}

/// The native STLT model: a plan bound to a flat packed parameter
/// vector (plus that vector's packed weight panels).
///
/// Cheap to clone (parameters and panels are behind `Arc`s),
/// `Send + Sync`, so batch rows parallelise across
/// [`crate::util::threadpool`].
#[derive(Clone)]
pub struct StltModel {
    /// shared with the plan — `model.cfg.field` reads through the Arc
    pub cfg: Arc<ModelConfig>,
    flat: Arc<Vec<f32>>,
    layers: Arc<Vec<LayerOffsets>>,
    panels: Arc<Panels>,
    embed: usize,
    lnf_g: usize,
    lnf_b: usize,
    pub mixer: MixerImpl,
}

fn find(layout: &[Leaf], path: &str) -> Result<usize> {
    layout
        .iter()
        .find(|l| l.path == path)
        .map(|l| l.offset)
        .ok_or_else(|| anyhow!("param layout missing '{path}'"))
}

impl StltPlan {
    /// Validate the config and resolve all parameter offsets.
    pub fn new(cfg: &ModelConfig) -> Result<StltPlan> {
        // register the panel-cache counter family up front: an idle
        // process (a worker that never took a wave) still exposes
        // zeroed `panels/` rows to a stats scrape
        crate::obs::counter("panels/bind_hits");
        crate::obs::counter("panels/bind_packs");
        if cfg.arch != "stlt" {
            bail!(
                "native backend executes arch 'stlt' only (got '{}'); \
                 use the xla backend for baseline architectures",
                cfg.arch
            );
        }
        if cfg.mode != "linear" {
            bail!(
                "native backend executes mode 'linear' only (got '{}')",
                cfg.mode
            );
        }
        if cfg.d_model == 0 || cfg.s_max == 0 || cfg.n_layers == 0 || cfg.vocab == 0 {
            bail!("degenerate ModelConfig: {cfg:?}");
        }
        let layout = trunk_layout(cfg);
        let total = total_params(&layout);
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for li in 0..cfg.n_layers {
            let p = format!("/layers/{li:03}");
            layers.push(LayerOffsets {
                ln1_g: find(&layout, &format!("{p}/ln1_g"))?,
                ln1_b: find(&layout, &format!("{p}/ln1_b"))?,
                ln2_g: find(&layout, &format!("{p}/ln2_g"))?,
                ln2_b: find(&layout, &format!("{p}/ln2_b"))?,
                ffn_w1: find(&layout, &format!("{p}/ffn_w1"))?,
                ffn_b1: find(&layout, &format!("{p}/ffn_b1"))?,
                ffn_w2: find(&layout, &format!("{p}/ffn_w2"))?,
                ffn_b2: find(&layout, &format!("{p}/ffn_b2"))?,
                w_f: find(&layout, &format!("{p}/mixer/w_f"))?,
                w_v: find(&layout, &format!("{p}/mixer/w_v"))?,
                w_o: find(&layout, &format!("{p}/mixer/w_o"))?,
                sigma_raw: find(&layout, &format!("{p}/mixer/sigma_raw"))?,
                omega: find(&layout, &format!("{p}/mixer/omega"))?,
                t_raw: find(&layout, &format!("{p}/mixer/t_raw"))?,
                w_alpha: find(&layout, &format!("{p}/mixer/w_alpha")).ok(),
                b_alpha: find(&layout, &format!("{p}/mixer/b_alpha")).ok(),
            });
        }
        Ok(StltPlan {
            cfg: Arc::new(cfg.clone()),
            embed: find(&layout, "/embed")?,
            lnf_g: find(&layout, "/lnf_g")?,
            lnf_b: find(&layout, "/lnf_b")?,
            total,
            layers: Arc::new(layers),
            panel_cache: Arc::new(Mutex::new(None)),
        })
    }

    /// Bind a parameter vector to the plan. The first bind of a given
    /// vector packs its pre-transposed weight panels (one pass over the
    /// weights); every repeat bind of the *same* `Arc` — the per-token
    /// decode serving path, which re-binds the uploaded parameter
    /// buffer on every step — hits the memo and costs a length check
    /// plus Arc clones.
    pub fn bind(&self, flat: Arc<Vec<f32>>) -> Result<StltModel> {
        if flat.len() != self.total {
            bail!(
                "flat param vector has {} elements, layout for '{}' needs {}",
                flat.len(),
                self.cfg.arch,
                self.total
            );
        }
        let panels = {
            let mut cache = self.panel_cache.lock().unwrap_or_else(|e| e.into_inner());
            let hit = cache.as_ref().and_then(|(prev, p)| {
                prev.upgrade()
                    .filter(|prev| Arc::ptr_eq(prev, &flat))
                    .map(|_| Arc::clone(p))
            });
            match hit {
                Some(p) => {
                    BIND_HITS.inc();
                    p
                }
                None => {
                    BIND_PACKS.inc();
                    let _span = crate::obs::span("panels", "pack");
                    let p = Arc::new(pack_panels(&self.cfg, &self.layers, &flat));
                    *cache = Some((Arc::downgrade(&flat), Arc::clone(&p)));
                    p
                }
            }
        };
        Ok(StltModel {
            cfg: Arc::clone(&self.cfg),
            flat,
            layers: Arc::clone(&self.layers),
            panels,
            embed: self.embed,
            lnf_g: self.lnf_g,
            lnf_b: self.lnf_b,
            mixer: MixerImpl::Recurrence,
        })
    }
}

impl StltModel {
    /// Validate the config/param-vector pair and resolve all offsets.
    pub fn new(cfg: &ModelConfig, flat: Arc<Vec<f32>>) -> Result<StltModel> {
        StltPlan::new(cfg)?.bind(flat)
    }

    /// Zero streaming carry: (L [n_layers*S*2], U [n_layers*S*d*2]).
    pub fn zero_carry(&self) -> (Vec<f32>, Vec<f32>) {
        let (ly, s, d) = (self.cfg.n_layers, self.cfg.s_max, self.cfg.d_model);
        (vec![0.0; ly * s * 2], vec![0.0; ly * s * d * 2])
    }

    /// Per-layer parameter offsets, in layer order ([`crate::train`]).
    pub(crate) fn layer_offsets(&self) -> &[LayerOffsets] {
        &self.layers
    }

    /// The packed weight panels of the bound vector ([`crate::train`]
    /// runs its tape forward on the same panels the engine uses).
    pub(crate) fn panels(&self) -> &Panels {
        &self.panels
    }

    /// The bound flat parameter vector ([`crate::train`]).
    pub(crate) fn flat_params(&self) -> &[f32] {
        &self.flat
    }

    /// (embed, lnf_g, lnf_b) offsets inside the flat vector.
    pub(crate) fn head_offsets(&self) -> (usize, usize, usize) {
        (self.embed, self.lnf_g, self.lnf_b)
    }

    pub(crate) fn node_params(&self, lo: &LayerOffsets) -> NodeParams {
        let s = self.cfg.s_max;
        let f = &self.flat[..];
        let t = softplus(f[lo.t_raw]) + 1.0;
        let gamma = (-1.0 / (8.0 * t)).exp();
        let mut lam_re = Vec::with_capacity(s);
        let mut lam_im = Vec::with_capacity(s);
        for k in 0..s {
            let sigma = softplus(f[lo.sigma_raw + k]) + self.cfg.sigma_min;
            let decay = (-(sigma + 1.0 / t)).exp();
            let theta = if self.cfg.omega_zero { 0.0 } else { f[lo.omega + k] };
            lam_re.push(decay * theta.cos());
            lam_im.push(-decay * theta.sin());
        }
        NodeParams { lam_re, lam_im, gamma }
    }

    /// Adaptive node gate m [S] plus the mean-pooled pre-mixer
    /// activations it was computed from (deterministic inference alpha,
    /// SS3.6) — shared by the engine and the training tape so the gate
    /// logits are computed by the same kernel on both sides. All-ones
    /// (and an empty pooled vector) when not adaptive.
    pub(crate) fn gate_full(
        &self,
        lo: &LayerOffsets,
        lp: &LayerPanels,
        h: &[f32],
        n: usize,
    ) -> (Vec<f32>, Vec<f32>) {
        let (s, d) = (self.cfg.s_max, self.cfg.d_model);
        if !self.cfg.adaptive {
            return (vec![1.0; s], Vec::new());
        }
        let (ba, wat) = match (lo.b_alpha, &lp.w_alpha_t) {
            (Some(b), Some(w)) => (b, w),
            _ => return (vec![1.0; s], Vec::new()),
        };
        let f = &self.flat[..];
        let mut pooled = vec![0.0f32; d];
        for row in h.chunks_exact(d) {
            for (p, &x) in pooled.iter_mut().zip(row) {
                *p += x;
            }
        }
        let inv_n = 1.0 / n as f32;
        for p in pooled.iter_mut() {
            *p *= inv_n;
        }
        let m = (0..s)
            .map(|k| sigmoid(f[ba + k] + linalg::dot(&pooled, &wat[k * d..(k + 1) * d])))
            .collect();
        (m, pooled)
    }

    /// One mixer chunk: h [n*d] (LayerNormed input) -> z [n*d], advancing
    /// the layer carry (l [S*2], u [S*d*2]) in place. Returns (z, s_eff).
    fn mixer_chunk(
        &self,
        lo: &LayerOffsets,
        lp: &LayerPanels,
        h: &[f32],
        n: usize,
        l: &mut [f32],
        u: &mut [f32],
    ) -> (Vec<f32>, f32) {
        let (s, d) = (self.cfg.s_max, self.cfg.d_model);
        let np = self.node_params(lo);
        let (m, _pooled) = self.gate_full(lo, lp, h, n);
        let s_eff: f32 = m.iter().sum();

        // projections on the shared kernels: fproj [n*S] (gated), v [n*d]
        let mut fproj = vec![0.0f32; n * s];
        linalg::gemm_at(h, &lp.w_f_t, &mut fproj, n, d, s);
        for row in fproj.chunks_exact_mut(s) {
            for (fk, &mk) in row.iter_mut().zip(&m) {
                *fk *= mk;
            }
        }
        let mut v = vec![0.0f32; n * d];
        linalg::gemm_at(h, &lp.w_v_t, &mut v, n, d, d);

        let zmix = match self.mixer {
            MixerImpl::Recurrence => self.mix_recurrence(&np, &fproj, &v, n, l, u),
            MixerImpl::ReferenceN2 => self.mix_reference_n2(&np, &fproj, &v, n, l, u),
        };

        // output projection z = zmix @ w_o
        let mut z = vec![0.0f32; n * d];
        linalg::gemm_at(&zmix, &lp.w_o_t, &mut z, n, d, d);
        (z, s_eff)
    }

    /// The production O(n·S·d) path: sequential L/U recurrences.
    fn mix_recurrence(
        &self,
        np: &NodeParams,
        fproj: &[f32],
        v: &[f32],
        n: usize,
        l: &mut [f32],
        u: &mut [f32],
    ) -> Vec<f32> {
        let (s, d) = (self.cfg.s_max, self.cfg.d_model);
        let inv_s = 1.0 / s as f32;
        let mut z = vec![0.0f32; n * d];
        for t in 0..n {
            let fr = &fproj[t * s..(t + 1) * s];
            let vr = &v[t * d..(t + 1) * d];
            let zr = &mut z[t * d..(t + 1) * d];
            for k in 0..s {
                lu_node_step(
                    np.lam_re[k],
                    np.lam_im[k],
                    np.gamma,
                    fr[k],
                    &mut l[k * 2..(k + 1) * 2],
                    &mut u[k * d * 2..(k + 1) * d * 2],
                    vr,
                    Some(&mut zr[..]),
                );
            }
            for ze in zr.iter_mut() {
                *ze *= inv_s;
            }
        }
        z
    }

    /// Naive O(n^2·S·d) oracle: materialises L via explicit lam powers
    /// (the relevance-matrix view) and recomputes every discounted U
    /// prefix sum. Only valid from a zero carry (enforced by
    /// [`StltModel::trunk_chunk`]); still advances the carry to the
    /// post-chunk state so callers can cross-check both.
    fn mix_reference_n2(
        &self,
        np: &NodeParams,
        fproj: &[f32],
        v: &[f32],
        n: usize,
        l: &mut [f32],
        u: &mut [f32],
    ) -> Vec<f32> {
        let (s, d) = (self.cfg.s_max, self.cfg.d_model);
        let inv_s = 1.0 / s as f32;
        // lam^p for p in [0, n): [n][s]
        let mut pow_re = vec![0.0f32; n.max(1) * s];
        let mut pow_im = vec![0.0f32; n.max(1) * s];
        for k in 0..s {
            pow_re[k] = 1.0;
            pow_im[k] = 0.0;
        }
        for p in 1..n {
            for k in 0..s {
                let (ar, ai) = (pow_re[(p - 1) * s + k], pow_im[(p - 1) * s + k]);
                pow_re[p * s + k] = ar * np.lam_re[k] - ai * np.lam_im[k];
                pow_im[p * s + k] = ar * np.lam_im[k] + ai * np.lam_re[k];
            }
        }
        // L[t,k] = sum_{m<=t} f[m,k] lam^{t-m}
        let mut l_re = vec![0.0f32; n * s];
        let mut l_im = vec![0.0f32; n * s];
        for t in 0..n {
            for mm in 0..=t {
                let p = t - mm;
                for k in 0..s {
                    let f = fproj[mm * s + k];
                    l_re[t * s + k] += f * pow_re[p * s + k];
                    l_im[t * s + k] += f * pow_im[p * s + k];
                }
            }
        }
        // z_t = Re<L_t, U_t>/S with U_t = sum_{m<=t} gamma^{t-m} conj(L_m) (x) v_m
        let mut z = vec![0.0f32; n * d];
        for t in 0..n {
            for k in 0..s {
                let (ltr, lti) = (l_re[t * s + k], l_im[t * s + k]);
                let mut g = 1.0f32;
                for mm in (0..=t).rev() {
                    let (lmr, lmi) = (l_re[mm * s + k], l_im[mm * s + k]);
                    for e in 0..d {
                        let ve = v[mm * d + e];
                        // ur += g*lmr*ve ; ui += -g*lmi*ve ; z += ltr*ur - lti*ui
                        z[t * d + e] += (ltr * lmr + lti * lmi) * g * ve;
                    }
                    g *= np.gamma;
                }
            }
            for e in 0..d {
                z[t * d + e] *= inv_s;
            }
        }
        // advance the carry to the end-of-chunk state for parity checks
        if n > 0 {
            for k in 0..s {
                l[k * 2] = l_re[(n - 1) * s + k];
                l[k * 2 + 1] = l_im[(n - 1) * s + k];
                let ub = &mut u[k * d * 2..(k + 1) * d * 2];
                for e in 0..d {
                    let (mut ur, mut ui) = (0.0f32, 0.0f32);
                    let mut g = 1.0f32;
                    for mm in (0..n).rev() {
                        ur += g * l_re[mm * s + k] * v[mm * d + e];
                        ui -= g * l_im[mm * s + k] * v[mm * d + e];
                        g *= np.gamma;
                    }
                    ub[e * 2] = ur;
                    ub[e * 2 + 1] = ui;
                }
            }
        }
        z
    }

    fn layer_norm(&self, x: &[f32], g_off: usize, b_off: usize, out: &mut [f32]) {
        let d = self.cfg.d_model;
        let f = &self.flat[..];
        for (row, orow) in x.chunks_exact(d).zip(out.chunks_exact_mut(d)) {
            let mu = row.iter().sum::<f32>() / d as f32;
            let var = row.iter().map(|&x| (x - mu) * (x - mu)).sum::<f32>() / d as f32;
            let inv = 1.0 / (var + 1e-5).sqrt();
            for (i, (&x, o)) in row.iter().zip(orow.iter_mut()).enumerate() {
                *o = (x - mu) * inv * f[g_off + i] + f[b_off + i];
            }
        }
    }

    /// FFN forward shared by the engine and the training tape (one
    /// implementation, one set of kernels — the backward can never
    /// differentiate a different network than the engine serves):
    /// `hgelu = gelu(h @ w1 + b1)`, `out = b2 + hgelu @ w2`, row-
    /// parallel via [`scatter_rows`]. Returns `(hpre, hgelu, out)`.
    ///
    /// With `want_pre` (the training tape) the pre-GELU activations and
    /// `hgelu` are materialised for the backward sweep; without it (the
    /// engine) both stay chunk-local inside one fused scatter — half
    /// the fan-outs, no O(n·hd) buffers — and `hpre`/`hgelu` come back
    /// empty. The fused and split epilogues are element-identical, so
    /// the two modes produce bitwise-equal `out`.
    pub(crate) fn ffn_parts(
        &self,
        lo: &LayerOffsets,
        lp: &LayerPanels,
        h: &[f32],
        n: usize,
        want_pre: bool,
    ) -> (Option<Vec<f32>>, Vec<f32>, Vec<f32>) {
        let d = self.cfg.d_model;
        let hd = d * self.cfg.ffn_mult.max(1);
        let f = &self.flat[..];
        let b1 = &f[lo.ffn_b1..lo.ffn_b1 + hd];
        let b2 = &f[lo.ffn_b2..lo.ffn_b2 + d];
        let mut out = vec![0.0f32; n * d];
        if !want_pre {
            scatter_rows(n, d, &mut out, MIN_PAR_ROWS, |t0, t1, chunk| {
                let rows = t1 - t0;
                let mut hid = vec![0.0f32; rows * hd];
                linalg::gemm_at(&h[t0 * d..t1 * d], &lp.ffn_w1_t, &mut hid, rows, d, hd);
                linalg::bias_gelu(&mut hid, b1);
                for row in chunk.chunks_exact_mut(d) {
                    row.copy_from_slice(b2);
                }
                linalg::gemm_at(&hid, &lp.ffn_w2_t, chunk, rows, hd, d);
            });
            return (None, Vec::new(), out);
        }
        let mut hid = vec![0.0f32; n * hd];
        scatter_rows(n, hd, &mut hid, MIN_PAR_ROWS, |t0, t1, chunk| {
            linalg::gemm_at(&h[t0 * d..t1 * d], &lp.ffn_w1_t, chunk, t1 - t0, d, hd);
            linalg::add_bias(chunk, b1);
        });
        let hpre = hid.clone();
        for v in hid.iter_mut() {
            *v = linalg::gelu(*v);
        }
        scatter_rows(n, d, &mut out, MIN_PAR_ROWS, |t0, t1, chunk| {
            for row in chunk.chunks_exact_mut(d) {
                row.copy_from_slice(b2);
            }
            linalg::gemm_at(&hid[t0 * hd..t1 * hd], &lp.ffn_w2_t, chunk, t1 - t0, hd, d);
        });
        (Some(hpre), hid, out)
    }

    /// Tied logits head `logits = xf @ embedᵀ` — the single largest
    /// matmul of the trunk (n × vocab × d) — row-parallel via
    /// [`scatter_rows`]. The `[vocab, d]` embedding matrix is already
    /// in the packed (output-major) layout, so no panel is needed.
    pub(crate) fn head_logits(&self, xf: &[f32], n: usize) -> Vec<f32> {
        let (d, vcb) = (self.cfg.d_model, self.cfg.vocab);
        let embed = &self.flat[self.embed..self.embed + vcb * d];
        let mut logits = vec![0.0f32; n * vcb];
        scatter_rows(n, vcb, &mut logits, MIN_PAR_ROWS, |t0, t1, out| {
            linalg::gemm_at(&xf[t0 * d..t1 * d], embed, out, t1 - t0, d, vcb);
        });
        logits
    }

    /// Run one chunk of tokens through the full trunk, advancing the
    /// stacked carry. Returns (logits [n*vocab], mean-over-layers s_eff).
    ///
    /// With a zero carry and the whole sequence as one chunk this is the
    /// `forward` / `eval` semantics; with persistent carries it is the
    /// `stream`/`decode` semantics (gate pooled per chunk, the documented
    /// streaming deviation of `stlt_layer.apply_stream`).
    pub fn trunk_chunk(
        &self,
        l_carry: &mut [f32],
        u_carry: &mut [f32],
        tokens: &[i32],
        noise_std: f32,
        noise_rng: Option<&mut Rng>,
    ) -> Result<(Vec<f32>, f32)> {
        let (s, d, vcb) = (self.cfg.s_max, self.cfg.d_model, self.cfg.vocab);
        let n = tokens.len();
        let f = &self.flat[..];
        if l_carry.len() != self.cfg.n_layers * s * 2
            || u_carry.len() != self.cfg.n_layers * s * d * 2
        {
            bail!(
                "carry shape mismatch: l={} u={} for {} layers S={} d={}",
                l_carry.len(),
                u_carry.len(),
                self.cfg.n_layers,
                s,
                d
            );
        }
        if self.mixer == MixerImpl::ReferenceN2
            && (l_carry.iter().any(|&x| x != 0.0) || u_carry.iter().any(|&x| x != 0.0))
        {
            bail!(
                "MixerImpl::ReferenceN2 recomputes every prefix sum from scratch \
                 and is only valid from a zero carry (full-sequence forward); \
                 streaming mid-sequence would silently produce wrong logits — \
                 use MixerImpl::Recurrence for chunked/streamed execution"
            );
        }
        let scale = (d as f32).sqrt();
        let mut x = vec![0.0f32; n * d];
        for (t, &tok) in tokens.iter().enumerate() {
            let tok = tok as usize;
            if tok >= vcb {
                bail!("token {tok} out of vocab {vcb}");
            }
            let er = &f[self.embed + tok * d..self.embed + (tok + 1) * d];
            for (i, &e) in er.iter().enumerate() {
                x[t * d + i] = e * scale;
            }
        }
        if noise_std > 0.0 {
            if let Some(rng) = noise_rng {
                for xe in x.iter_mut() {
                    *xe += noise_std * rng.normal() as f32;
                }
            }
        }
        let mut h = vec![0.0f32; n * d];
        let mut s_eff_sum = 0.0f32;
        for (li, (lo, lp)) in self.layers.iter().zip(&self.panels.layers).enumerate() {
            self.layer_norm(&x, lo.ln1_g, lo.ln1_b, &mut h);
            let lsl = &mut l_carry[li * s * 2..(li + 1) * s * 2];
            let usl = &mut u_carry[li * s * d * 2..(li + 1) * s * d * 2];
            let (z, s_eff) = self.mixer_chunk(lo, lp, &h, n, lsl, usl);
            s_eff_sum += s_eff;
            for (xe, ze) in x.iter_mut().zip(&z) {
                *xe += ze;
            }
            self.layer_norm(&x, lo.ln2_g, lo.ln2_b, &mut h);
            let (_, _, f_out) = self.ffn_parts(lo, lp, &h, n, false);
            for (xe, fe) in x.iter_mut().zip(&f_out) {
                *xe += fe;
            }
        }
        let mut xf = vec![0.0f32; n * d];
        self.layer_norm(&x, self.lnf_g, self.lnf_b, &mut xf);
        let logits = self.head_logits(&xf, n);
        Ok((logits, s_eff_sum / self.cfg.n_layers as f32))
    }

    /// Batched single-token decode: advance `bsz` independent sessions
    /// by one token each, in one pass over the packed weight panels.
    /// This is the serving hot path behind the `decode_batch` artifact
    /// kind: session *rows* take the place of token rows in every GEMM
    /// (`h [bsz, d] @ panel`), so each weight panel is streamed once
    /// per wave instead of once per session, while the (L, U)
    /// recurrence advances each row's own carry slice exactly one step.
    ///
    /// Per-row outputs are bitwise identical to running
    /// [`StltModel::trunk_chunk`] on that row's carry with its single
    /// token: every `gemm_at` output element is `dot(a_row, bt_row)`
    /// independent of the row count (the linalg parity guarantee),
    /// LayerNorm and the recurrence are strictly per-row, and the
    /// adaptive gate pools over each row alone — exactly the n = 1
    /// pooling of a single-token chunk. Pinned by unit test and by the
    /// server's padding/masking parity test.
    ///
    /// `l_all` is `[bsz, n_layers*S*2]`, `u_all` `[bsz, n_layers*S*d*2]`
    /// (row-major). Rows with `active[r] <= 0.5` are padding: their
    /// carries are untouched and their logits row is zero. Returns
    /// logits `[bsz * vocab]`.
    pub fn decode_step_batch(
        &self,
        bsz: usize,
        l_all: &mut [f32],
        u_all: &mut [f32],
        tokens: &[i32],
        active: &[f32],
    ) -> Result<Vec<f32>> {
        if self.mixer != MixerImpl::Recurrence {
            bail!(
                "decode_step_batch runs MixerImpl::Recurrence only (the ReferenceN2 \
                 oracle is valid from a zero carry on full sequences — see trunk_chunk)"
            );
        }
        let (s, d, vcb) = (self.cfg.s_max, self.cfg.d_model, self.cfg.vocab);
        let (l_stride, u_stride) = (self.cfg.n_layers * s * 2, self.cfg.n_layers * s * d * 2);
        if l_all.len() != bsz * l_stride
            || u_all.len() != bsz * u_stride
            || tokens.len() != bsz
            || active.len() != bsz
        {
            bail!(
                "decode_step_batch shape mismatch: bsz={bsz} l={} u={} tokens={} active={}",
                l_all.len(),
                u_all.len(),
                tokens.len(),
                active.len()
            );
        }
        let f = &self.flat[..];
        let mut logits_out = vec![0.0f32; bsz * vcb];
        // compact the active rows so padding costs nothing and the GEMM
        // row dimension is dense; idx maps compact row -> original row
        let idx: Vec<usize> = (0..bsz).filter(|&r| active[r] > 0.5).collect();
        let na = idx.len();
        if na == 0 {
            return Ok(logits_out);
        }
        // validate every token before touching any carry, so a bad row
        // cannot leave sibling rows half-advanced
        for &r in &idx {
            let tok = tokens[r];
            if tok < 0 || tok as usize >= vcb {
                bail!("token {tok} out of vocab {vcb}");
            }
        }
        let scale = (d as f32).sqrt();
        let mut x = vec![0.0f32; na * d];
        for (c, &r) in idx.iter().enumerate() {
            let tok = tokens[r] as usize;
            let er = &f[self.embed + tok * d..self.embed + (tok + 1) * d];
            for (i, &e) in er.iter().enumerate() {
                x[c * d + i] = e * scale;
            }
        }
        let mut h = vec![0.0f32; na * d];
        let inv_s = 1.0 / s as f32;
        for (li, (lo, lp)) in self.layers.iter().zip(&self.panels.layers).enumerate() {
            self.layer_norm(&x, lo.ln1_g, lo.ln1_b, &mut h);
            // projections batched over session rows
            let mut fproj = vec![0.0f32; na * s];
            linalg::gemm_at(&h, &lp.w_f_t, &mut fproj, na, d, s);
            if self.cfg.adaptive {
                // per-row gate: a single-token chunk pools over just its
                // own (one-row) h, so the pooled vector IS the h row
                for (c, frow) in fproj.chunks_exact_mut(s).enumerate() {
                    let (m, _) = self.gate_full(lo, lp, &h[c * d..(c + 1) * d], 1);
                    for (fk, &mk) in frow.iter_mut().zip(&m) {
                        *fk *= mk;
                    }
                }
            }
            let mut v = vec![0.0f32; na * d];
            linalg::gemm_at(&h, &lp.w_v_t, &mut v, na, d, d);
            // per-row one-step recurrence on each row's own carry slice
            let np = self.node_params(lo);
            let mut zmix = vec![0.0f32; na * d];
            for (c, &r) in idx.iter().enumerate() {
                let l_off = r * l_stride + li * s * 2;
                let u_off = r * u_stride + li * s * d * 2;
                let lsl = &mut l_all[l_off..l_off + s * 2];
                let usl = &mut u_all[u_off..u_off + s * d * 2];
                let fr = &fproj[c * s..(c + 1) * s];
                let vr = &v[c * d..(c + 1) * d];
                let zr = &mut zmix[c * d..(c + 1) * d];
                for k in 0..s {
                    lu_node_step(
                        np.lam_re[k],
                        np.lam_im[k],
                        np.gamma,
                        fr[k],
                        &mut lsl[k * 2..(k + 1) * 2],
                        &mut usl[k * d * 2..(k + 1) * d * 2],
                        vr,
                        Some(&mut zr[..]),
                    );
                }
                for ze in zr.iter_mut() {
                    *ze *= inv_s;
                }
            }
            let mut z = vec![0.0f32; na * d];
            linalg::gemm_at(&zmix, &lp.w_o_t, &mut z, na, d, d);
            for (xe, ze) in x.iter_mut().zip(&z) {
                *xe += ze;
            }
            self.layer_norm(&x, lo.ln2_g, lo.ln2_b, &mut h);
            let (_, _, f_out) = self.ffn_parts(lo, lp, &h, na, false);
            for (xe, fe) in x.iter_mut().zip(&f_out) {
                *xe += fe;
            }
        }
        let mut xf = vec![0.0f32; na * d];
        self.layer_norm(&x, self.lnf_g, self.lnf_b, &mut xf);
        let logits = self.head_logits(&xf, na);
        for (c, &r) in idx.iter().enumerate() {
            logits_out[r * vcb..(r + 1) * vcb].copy_from_slice(&logits[c * vcb..(c + 1) * vcb]);
        }
        Ok(logits_out)
    }

    /// Full-sequence forward from a zero carry: logits [n*vocab].
    pub fn forward_logits(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        let (mut l, mut u) = self.zero_carry();
        Ok(self.trunk_chunk(&mut l, &mut u, tokens, 0.0, None)?.0)
    }

    /// Next-token NLL of one row: tokens [n+1] -> (nll_sum, count, s_eff).
    ///
    /// `noise_std > 0` adds Gaussian embedding noise from the given seed
    /// (the SS4.7 robustness knob). The native noise stream is its own
    /// RNG — statistically, not bitwise, equivalent to the XLA backend's.
    pub fn eval_row(&self, tokens: &[i32], noise_std: f32, seed: u64) -> Result<(f64, f64, f32)> {
        if tokens.len() < 2 {
            bail!("eval row needs at least 2 tokens");
        }
        let n = tokens.len() - 1;
        let (mut l, mut u) = self.zero_carry();
        let mut rng = Rng::new(seed ^ 0x51A7_E2F0);
        let (logits, s_eff) =
            self.trunk_chunk(&mut l, &mut u, &tokens[..n], noise_std, Some(&mut rng))?;
        let mut nll = 0.0f64;
        for t in 0..n {
            nll += nll_of(&logits[t * self.cfg.vocab..(t + 1) * self.cfg.vocab], tokens[t + 1])?;
        }
        Ok((nll, n as f64, s_eff))
    }
}

/// -log softmax(logits)[target], accumulated in f64 like the XLA path's
/// f32 sum but stabler for long documents.
pub fn nll_of(logits: &[f32], target: i32) -> Result<f64> {
    let t = target as usize;
    if t >= logits.len() {
        bail!("target {t} out of vocab {}", logits.len());
    }
    let mx = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut denom = 0.0f64;
    for &x in logits {
        denom += ((x - mx) as f64).exp();
    }
    Ok(denom.ln() - (logits[t] - mx) as f64)
}

/// Host-side random init mirroring `python/compile/trunk.init` shapes
/// and magnitudes (LN gains 1, log-spaced sigma, T = t_init, mostly-on
/// adaptive gates). Not bitwise python-equal — used for native-only
/// smoke paths and tests when no `.init.bin` artifact exists.
pub fn host_init(cfg: &ModelConfig, seed: u64) -> Vec<f32> {
    let layout = trunk_layout(cfg);
    let total = total_params(&layout);
    let mut flat = vec![0.0f32; total];
    let mut rng = Rng::new(seed);
    let s = cfg.s_max;
    // softplus⁻¹(y) = ln(e^y − 1): the naive form overflows f32 to inf
    // for y ≳ 89 (e.g. a manifest with t_init ≳ 90), seeding non-finite
    // t_raw. Above the knee use the log1p-stable y + ln(1 − e⁻ʸ), which
    // round-trips exactly through the matching `softplus` branch.
    let inv_softplus = |y: f32| {
        if y > 20.0 {
            y + (-(-y).exp()).ln_1p()
        } else {
            y.exp_m1().max(1e-6).ln()
        }
    };
    for leaf in &layout {
        let out = &mut flat[leaf.offset..leaf.offset + leaf.numel()];
        let name = leaf.path.rsplit('/').next().unwrap_or("");
        match name {
            "ln1_g" | "ln2_g" | "lnf_g" => out.fill(1.0),
            "ln1_b" | "ln2_b" | "lnf_b" | "ffn_b1" | "ffn_b2" => out.fill(0.0),
            "sigma_raw" => {
                let (lo, hi) = (0.01f32, 2.0f32);
                for (k, o) in out.iter_mut().enumerate() {
                    let frac = if s > 1 { k as f32 / (s - 1) as f32 } else { 0.0 };
                    let sig = lo * (hi / lo).powf(frac);
                    *o = inv_softplus(sig);
                }
            }
            "omega" => {
                for o in out.iter_mut() {
                    *o = if cfg.omega_zero { 0.0 } else { rng.f32() * 0.785 };
                }
            }
            "t_raw" => out.fill(inv_softplus(cfg.t_init.max(1.5) - 1.0)),
            "b_alpha" => out.fill(2.0),
            _ => {
                for o in out.iter_mut() {
                    *o = (rng.normal() * 0.02) as f32;
                }
            }
        }
    }
    flat
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::fft::{relevance_direct, relevance_spectral};

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            arch: "stlt".into(),
            vocab: 17,
            d_model: 8,
            n_layers: 2,
            n_ctx: 16,
            s_max: 4,
            batch: 2,
            mode: "linear".into(),
            ..ModelConfig::default()
        }
    }

    fn model(cfg: &ModelConfig, seed: u64) -> StltModel {
        StltModel::new(cfg, Arc::new(host_init(cfg, seed))).unwrap()
    }

    #[test]
    fn rejects_wrong_arch_and_size() {
        let mut cfg = tiny_cfg();
        cfg.arch = "vanilla".into();
        assert!(StltModel::new(&cfg, Arc::new(vec![])).is_err());
        let cfg = tiny_cfg();
        assert!(StltModel::new(&cfg, Arc::new(vec![0.0; 3])).is_err());
    }

    #[test]
    fn recurrence_matches_n2_reference() {
        // the tentpole correctness seam: O(N S d) recurrence == O(N^2)
        // relevance-matrix oracle on full-sequence forwards
        for seed in [1u64, 9] {
            let cfg = tiny_cfg();
            let mut m = model(&cfg, seed);
            let tokens: Vec<i32> = (0..12).map(|i| (i * 5 + 3) % cfg.vocab as i32).collect();
            let fast = m.forward_logits(&tokens).unwrap();
            m.mixer = MixerImpl::ReferenceN2;
            let slow = m.forward_logits(&tokens).unwrap();
            for (a, b) in fast.iter().zip(&slow) {
                assert!((a - b).abs() < 1e-3 * (1.0 + a.abs()), "{a} vs {b}");
            }
        }
    }

    #[test]
    fn reference_n2_rejects_nonzero_carry() {
        // the oracle is documented zero-carry-only; streaming it
        // mid-sequence must be a hard error, not silently-wrong logits
        let cfg = tiny_cfg();
        let mut m = model(&cfg, 1);
        m.mixer = MixerImpl::ReferenceN2;
        let tokens: Vec<i32> = (0..6).map(|i| i % cfg.vocab as i32).collect();
        let (mut l, mut u) = m.zero_carry();
        m.trunk_chunk(&mut l, &mut u, &tokens, 0.0, None).unwrap();
        let err = m.trunk_chunk(&mut l, &mut u, &tokens, 0.0, None).unwrap_err();
        assert!(format!("{err:#}").contains("zero carry"), "unhelpful error: {err:#}");
    }

    #[test]
    fn chunking_is_invariant() {
        let cfg = tiny_cfg();
        let m = model(&cfg, 3);
        let tokens: Vec<i32> = (0..15).map(|i| (i * 7 + 1) % cfg.vocab as i32).collect();
        let whole = m.forward_logits(&tokens).unwrap();
        let (mut l, mut u) = m.zero_carry();
        let mut pieces = Vec::new();
        for chunk in [5usize, 1, 6, 3] {
            let off = pieces.len() / cfg.vocab;
            let (lg, _) =
                m.trunk_chunk(&mut l, &mut u, &tokens[off..off + chunk], 0.0, None).unwrap();
            pieces.extend(lg);
        }
        assert_eq!(whole.len(), pieces.len());
        for (a, b) in whole.iter().zip(&pieces) {
            assert!((a - b).abs() < 1e-4 * (1.0 + a.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn panel_cache_hits_on_same_params_only() {
        // the bind-seam memo: same Arc -> same packed panels; different
        // params -> fresh panels (never stale weights)
        let cfg = tiny_cfg();
        let plan = StltPlan::new(&cfg).unwrap();
        let flat = Arc::new(host_init(&cfg, 1));
        let m1 = plan.bind(Arc::clone(&flat)).unwrap();
        let m2 = plan.bind(Arc::clone(&flat)).unwrap();
        assert!(Arc::ptr_eq(&m1.panels, &m2.panels), "repeat bind must hit the memo");
        let m3 = plan.bind(Arc::new(host_init(&cfg, 2))).unwrap();
        assert!(!Arc::ptr_eq(&m1.panels, &m3.panels), "new params must re-pack");
        // and the packed panels are really the transposed weights
        let lo = &m1.layers[0];
        let lp = &m1.panels.layers[0];
        let (d, s) = (cfg.d_model, cfg.s_max);
        for i in 0..d {
            for k in 0..s {
                assert_eq!(lp.w_f_t[k * d + i], m1.flat[lo.w_f + i * s + k]);
            }
        }
    }

    #[test]
    fn decode_step_batch_bitwise_matches_single_rows() {
        // the serving parity seam: each row of the batched single-token
        // forward must be BITWISE the single-session trunk_chunk on the
        // same carry, with inactive rows untouched — adaptive and not.
        for adaptive in [false, true] {
            let mut cfg = tiny_cfg();
            cfg.adaptive = adaptive;
            let m = model(&cfg, 17);
            let bsz = 5usize;
            let (l0, u0) = m.zero_carry();
            let (ls, us) = (l0.len(), u0.len());
            // give every row a distinct warmed-up carry
            let mut l_all = vec![0.0f32; bsz * ls];
            let mut u_all = vec![0.0f32; bsz * us];
            for r in 0..bsz {
                let (mut l, mut u) = m.zero_carry();
                let warm: Vec<i32> =
                    (0..3 + r).map(|i| ((i * 7 + r) % cfg.vocab) as i32).collect();
                m.trunk_chunk(&mut l, &mut u, &warm, 0.0, None).unwrap();
                l_all[r * ls..(r + 1) * ls].copy_from_slice(&l);
                u_all[r * us..(r + 1) * us].copy_from_slice(&u);
            }
            let tokens: Vec<i32> = (0..bsz).map(|r| ((r * 3 + 1) % cfg.vocab) as i32).collect();
            // row 2 inactive (ragged wave padding)
            let active: Vec<f32> = (0..bsz).map(|r| if r == 2 { 0.0 } else { 1.0 }).collect();
            let (l_ref_all, u_ref_all) = (l_all.clone(), u_all.clone());
            let logits =
                m.decode_step_batch(bsz, &mut l_all, &mut u_all, &tokens, &active).unwrap();
            for r in 0..bsz {
                let mut l = l_ref_all[r * ls..(r + 1) * ls].to_vec();
                let mut u = u_ref_all[r * us..(r + 1) * us].to_vec();
                if r == 2 {
                    assert_eq!(&l_all[r * ls..(r + 1) * ls], &l[..], "inactive carry touched");
                    assert_eq!(&u_all[r * us..(r + 1) * us], &u[..], "inactive carry touched");
                    assert!(
                        logits[r * cfg.vocab..(r + 1) * cfg.vocab].iter().all(|&x| x == 0.0),
                        "inactive logits must be zero"
                    );
                    continue;
                }
                let (want, _) =
                    m.trunk_chunk(&mut l, &mut u, &tokens[r..r + 1], 0.0, None).unwrap();
                assert_eq!(
                    &logits[r * cfg.vocab..(r + 1) * cfg.vocab],
                    &want[..],
                    "row {r} logits diverge (adaptive={adaptive})"
                );
                assert_eq!(&l_all[r * ls..(r + 1) * ls], &l[..], "row {r} L carry");
                assert_eq!(&u_all[r * us..(r + 1) * us], &u[..], "row {r} U carry");
            }
        }
    }

    #[test]
    fn decode_step_batch_rejects_bad_tokens_without_mutation() {
        let cfg = tiny_cfg();
        let m = model(&cfg, 8);
        let (l0, u0) = m.zero_carry();
        let (ls, us) = (l0.len(), u0.len());
        let mut l_all = vec![0.5f32; 2 * ls];
        let mut u_all = vec![0.25f32; 2 * us];
        let (l_ref, u_ref) = (l_all.clone(), u_all.clone());
        let err = m
            .decode_step_batch(2, &mut l_all, &mut u_all, &[1, cfg.vocab as i32], &[1.0, 1.0])
            .unwrap_err();
        assert!(format!("{err:#}").contains("vocab"), "unhelpful: {err:#}");
        assert_eq!(l_all, l_ref, "no carry may advance on a rejected wave");
        assert_eq!(u_all, u_ref);
        // the ReferenceN2 oracle is zero-carry/full-sequence only; the
        // batched decode path must refuse it like trunk_chunk does
        let mut m2 = model(&cfg, 8);
        m2.mixer = MixerImpl::ReferenceN2;
        let err =
            m2.decode_step_batch(2, &mut l_all, &mut u_all, &[1, 2], &[1.0, 1.0]).unwrap_err();
        assert!(format!("{err:#}").contains("Recurrence"), "unhelpful: {err:#}");
    }

    #[test]
    fn adaptive_gate_thins_nodes() {
        let mut cfg = tiny_cfg();
        cfg.adaptive = true;
        let m = model(&cfg, 5);
        let tokens: Vec<i32> = (0..10).map(|i| i % cfg.vocab as i32).collect();
        let (mut l, mut u) = m.zero_carry();
        let (_, s_eff) = m.trunk_chunk(&mut l, &mut u, &tokens, 0.0, None).unwrap();
        assert!(s_eff > 0.0 && s_eff < cfg.s_max as f32, "s_eff {s_eff}");
    }

    #[test]
    fn eval_row_near_uniform_for_random_params() {
        let cfg = tiny_cfg();
        let m = model(&cfg, 11);
        let tokens: Vec<i32> = (0..13).map(|i| (3 * i) % cfg.vocab as i32).collect();
        let (nll, cnt, _) = m.eval_row(&tokens, 0.0, 0).unwrap();
        let ppl = (nll / cnt).exp();
        let v = cfg.vocab as f64;
        assert!(ppl > 0.5 * v && ppl < 2.0 * v, "ppl {ppl} vs vocab {v}");
    }

    #[test]
    fn noise_changes_nll_deterministically() {
        let cfg = tiny_cfg();
        let m = model(&cfg, 2);
        let tokens: Vec<i32> = (0..9).map(|i| i % cfg.vocab as i32).collect();
        let (a, _, _) = m.eval_row(&tokens, 0.5, 7).unwrap();
        let (b, _, _) = m.eval_row(&tokens, 0.5, 7).unwrap();
        let (c, _, _) = m.eval_row(&tokens, 0.0, 7).unwrap();
        assert_eq!(a, b, "same seed must reproduce");
        assert!((a - c).abs() > 1e-9, "noise should perturb the NLL");
    }

    #[test]
    fn host_init_stable_for_large_t_init() {
        // the naive softplus-inverse overflowed f32 here, seeding
        // t_raw = inf and a non-finite forward
        let mut cfg = tiny_cfg();
        cfg.t_init = 5000.0;
        let flat = host_init(&cfg, 1);
        assert!(flat.iter().all(|x| x.is_finite()), "init must be finite");
        let m = StltModel::new(&cfg, Arc::new(flat)).unwrap();
        let np = m.node_params(&m.layers[0]);
        // T must round-trip: gamma = e^{-1/(8 T)} with T = t_init
        let want = (-1.0f32 / (8.0 * cfg.t_init)).exp();
        assert!(
            (np.gamma - want).abs() < 1e-5 && np.gamma < 1.0,
            "gamma {} vs {want}",
            np.gamma
        );
        let tokens: Vec<i32> = (0..8).map(|i| i % cfg.vocab as i32).collect();
        let logits = m.forward_logits(&tokens).unwrap();
        assert!(logits.iter().all(|x| x.is_finite()), "forward must stay finite");
    }

    #[test]
    fn relevance_of_laplace_rows_matches_spectral_form() {
        // SS3.4 cross-check, reusing util::fft: the relevance between two
        // transform rows computed directly equals the Parseval/spectral
        // form on the native backend's own L values.
        let cfg = tiny_cfg();
        let m = model(&cfg, 13);
        let lo = &m.layers[0];
        let np = m.node_params(lo);
        let s = cfg.s_max;
        let mut rng = Rng::new(4);
        let n = 6usize;
        let f: Vec<f32> = (0..n * s).map(|_| rng.f32() - 0.5).collect();
        // build L rows via the recurrence
        let mut l_rows_re = vec![0.0f32; n * s];
        let mut l_rows_im = vec![0.0f32; n * s];
        let (mut lr, mut li) = (vec![0.0f32; s], vec![0.0f32; s]);
        for t in 0..n {
            for k in 0..s {
                let (a, b) = (lr[k], li[k]);
                lr[k] = np.lam_re[k] * a - np.lam_im[k] * b + f[t * s + k];
                li[k] = np.lam_re[k] * b + np.lam_im[k] * a;
            }
            l_rows_re[t * s..(t + 1) * s].copy_from_slice(&lr);
            l_rows_im[t * s..(t + 1) * s].copy_from_slice(&li);
        }
        for a in 0..n {
            for b in 0..n {
                let (ar, ai) = (&l_rows_re[a * s..(a + 1) * s], &l_rows_im[a * s..(a + 1) * s]);
                let (br, bi) = (&l_rows_re[b * s..(b + 1) * s], &l_rows_im[b * s..(b + 1) * s]);
                let direct = relevance_direct(ar, ai, br, bi);
                let spectral = relevance_spectral(ar, ai, br, bi);
                assert!(
                    (direct - spectral).abs() < 1e-3 * (1.0 + direct.abs()),
                    "R[{a},{b}]: {direct} vs {spectral}"
                );
            }
        }
    }
}
