//! Host tensors — the backend-agnostic data interchange type.
//!
//! Only the dtypes crossing the execution boundary exist: f32 and i32.
//! Shapes are validated against the manifest before every execution so
//! a mismatched artifact fails loudly at the boundary, not inside the
//! backend. Conversion to/from `xla::Literal` lives in
//! `runtime/backend/xla.rs` (the only module that may touch `xla::`).

use anyhow::{bail, Result};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn from_name(s: &str) -> Result<DType> {
        match s {
            "float32" => Ok(DType::F32),
            "int32" => Ok(DType::I32),
            other => bail!("unsupported dtype '{other}'"),
        }
    }
}

/// Host tensor: flat storage + shape. Row-major.
#[derive(Clone, Debug)]
pub enum Tensor {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
}

impl Tensor {
    pub fn f32(data: Vec<f32>, shape: &[usize]) -> Tensor {
        // PANIC-OK: constructor contract — a data/shape mismatch is a
        // caller bug caught at the construction site, not downstream
        assert_eq!(data.len(), shape.iter().product::<usize>().max(1));
        Tensor::F32(data, shape.to_vec())
    }

    pub fn i32(data: Vec<i32>, shape: &[usize]) -> Tensor {
        // PANIC-OK: constructor contract — a data/shape mismatch is a
        // caller bug caught at the construction site, not downstream
        assert_eq!(data.len(), shape.iter().product::<usize>().max(1));
        Tensor::I32(data, shape.to_vec())
    }

    pub fn scalar_f32(x: f32) -> Tensor {
        Tensor::F32(vec![x], vec![])
    }

    pub fn scalar_i32(x: i32) -> Tensor {
        Tensor::I32(vec![x], vec![])
    }

    pub fn zeros_f32(shape: &[usize]) -> Tensor {
        Tensor::F32(vec![0.0; shape.iter().product::<usize>().max(1)], shape.to_vec())
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32(_, s) | Tensor::I32(_, s) => s,
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            Tensor::F32(..) => DType::F32,
            Tensor::I32(..) => DType::I32,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Tensor::F32(d, _) => d.len(),
            Tensor::I32(d, _) => d.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32(d, _) => Ok(d),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32(d, _) => Ok(d),
            _ => bail!("tensor is not i32"),
        }
    }

    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            Tensor::F32(d, _) => Ok(d),
            _ => bail!("tensor is not f32"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_accounting() {
        let t = Tensor::f32(vec![0.0; 12], &[3, 4]);
        assert_eq!(t.shape(), &[3, 4]);
        assert_eq!(t.len(), 12);
        assert_eq!(t.dtype(), DType::F32);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::f32(vec![0.0; 5], &[3, 4]);
    }

    #[test]
    fn dtype_parse() {
        assert_eq!(DType::from_name("float32").unwrap(), DType::F32);
        assert_eq!(DType::from_name("int32").unwrap(), DType::I32);
        assert!(DType::from_name("bfloat16").is_err());
    }

    #[test]
    fn scalars() {
        assert_eq!(Tensor::scalar_i32(7).shape(), &[] as &[usize]);
        assert_eq!(Tensor::scalar_f32(1.5).len(), 1);
    }
}
