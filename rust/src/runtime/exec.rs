//! Typed entry points over raw artifact execution: one struct per
//! artifact kind, owning its host-side state and hiding tensor plumbing
//! from the coordinator.

use anyhow::{bail, Result};

use crate::runtime::artifact::{Entry, Manifest};
use crate::runtime::backend::DeviceBuffer;
use crate::runtime::client::Runtime;
use crate::runtime::tensor::Tensor;
use crate::util::rng::Rng;

/// Pre-uploaded parameter vector (§Perf L3-1): frozen weights are copied
/// host->device once and reused across every execution, instead of
/// per-call Vec clone + upload copies. Backend-opaque: the buffer was
/// produced by whichever backend the [`Runtime`] drives.
pub struct ParamBuf {
    buf: Box<dyn DeviceBuffer>,
    pub param_count: usize,
}

impl ParamBuf {
    pub fn buffer(&self) -> &dyn DeviceBuffer {
        self.buf.as_ref()
    }
}

pub fn upload_params(rt: &Runtime, entry: &Entry, flat: &[f32]) -> Result<ParamBuf> {
    if flat.len() != entry.param_count {
        bail!("{}: {} params != manifest {}", entry.name, flat.len(), entry.param_count);
    }
    Ok(ParamBuf { buf: rt.upload_f32(flat, &[flat.len()])?, param_count: flat.len() })
}

/// Training state for one model: flat params + Adam moments.
pub struct TrainState {
    pub flat: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub step: i32,
}

impl TrainState {
    pub fn zeros(param_count: usize) -> TrainState {
        TrainState {
            flat: vec![0.0; param_count],
            m: vec![0.0; param_count],
            v: vec![0.0; param_count],
            step: 0,
        }
    }

    /// Proper initialisation: aot.py dumps the python-exact packed init
    /// vector (`<name>.init.bin`, raw LE f32) next to the HLO; this loads
    /// it so LN gains start at 1, sigma_raw log-spaced, etc.
    pub fn from_entry(entry: &Entry) -> Result<TrainState> {
        let init = entry
            .init_file
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("{}: no init vector in manifest", entry.name))?;
        let flat = load_init_vec(init, entry.param_count)?;
        Ok(TrainState {
            m: vec![0.0; flat.len()],
            v: vec![0.0; flat.len()],
            flat,
            step: 0,
        })
    }

    /// [`TrainState::from_entry`], falling back to the native host init
    /// (same shapes/magnitudes as `trunk.init`, not bitwise python-equal)
    /// for manifests that ship no `.init.bin` — the normal case for the
    /// native backend, whose artifacts are pure metadata.
    pub fn init_for(entry: &Entry, seed: u64) -> Result<TrainState> {
        if entry.init_file.is_some() {
            return TrainState::from_entry(entry);
        }
        #[cfg(feature = "native")]
        if entry.config.arch == "stlt" {
            let flat = crate::runtime::native_stlt::host_init(&entry.config, seed);
            if flat.len() != entry.param_count {
                anyhow::bail!(
                    "{}: host init produced {} params, manifest says {} \
                     (config/manifest mismatch)",
                    entry.name,
                    flat.len(),
                    entry.param_count
                );
            }
            return Ok(TrainState {
                m: vec![0.0; flat.len()],
                v: vec![0.0; flat.len()],
                flat,
                step: 0,
            });
        }
        let _ = seed;
        anyhow::bail!(
            "{}: no init vector in manifest (run `make artifacts`, or use an \
             stlt-arch entry on the native backend)",
            entry.name
        )
    }
}

/// `train_step` artifact: (flat, m, v, step, tokens, seed) ->
/// (flat', m', v', loss, ce, s_eff).
pub struct TrainStep<'a> {
    rt: &'a Runtime,
    entry: &'a Entry,
    pub batch: usize,
    pub n_plus_1: usize,
}

#[derive(Clone, Copy, Debug)]
pub struct StepMetrics {
    pub loss: f32,
    pub ce: f32,
    pub s_eff: f32,
}

impl<'a> TrainStep<'a> {
    pub fn new(rt: &'a Runtime, manifest: &'a Manifest, name: &str) -> Result<TrainStep<'a>> {
        let entry = manifest.get(name)?;
        if entry.kind != "train_step" {
            bail!("{name} is kind '{}', expected train_step", entry.kind);
        }
        let tok = &entry.inputs[4].shape;
        Ok(TrainStep { rt, entry, batch: tok[0], n_plus_1: tok[1] })
    }

    pub fn param_count(&self) -> usize {
        self.entry.param_count
    }

    pub fn entry(&self) -> &Entry {
        self.entry
    }

    /// Advance `state` by one step on `tokens` (flat [batch * n_plus_1]).
    ///
    /// The state vectors are *moved* (not copied) into the input
    /// tensors; on any failure — backend error or output mismatch —
    /// they are moved back and `state` is assigned only from a fully
    /// parsed output set, so a failed step leaves `state` exactly as
    /// it was and is retryable (previously an error left a silently
    /// zero-length TrainState).
    pub fn run(&self, state: &mut TrainState, tokens: &[i32], seed: i32) -> Result<StepMetrics> {
        let p = self.entry.param_count;
        let inputs = vec![
            Tensor::f32(std::mem::take(&mut state.flat), &[p]),
            Tensor::f32(std::mem::take(&mut state.m), &[p]),
            Tensor::f32(std::mem::take(&mut state.v), &[p]),
            Tensor::scalar_i32(state.step),
            Tensor::i32(tokens.to_vec(), &[self.batch, self.n_plus_1]),
            Tensor::scalar_i32(seed),
        ];
        match parse_train_out(self.rt.run(self.entry, &inputs)) {
            Ok((flat, m, v, metrics)) => {
                state.flat = flat;
                state.m = m;
                state.v = v;
                state.step += 1;
                Ok(metrics)
            }
            Err(e) => {
                restore_train_state(state, inputs);
                Err(e)
            }
        }
    }
}

/// Pop the next (rightmost) output tensor, turning a short output set
/// into an error instead of a panic — an arity mismatch must take the
/// same state-restore path as a dtype/shape mismatch, or the
/// "retryable failed step" guarantee dies in an unwind.
fn pop_out(out: &mut Vec<Tensor>, what: &str) -> Result<Tensor> {
    out.pop().ok_or_else(|| anyhow::anyhow!("backend returned too few outputs: missing {what}"))
}

/// [`pop_out`] for scalar outputs: an empty tensor errors (through the
/// same restore path) instead of panicking on `[0]`.
fn pop_scalar(out: &mut Vec<Tensor>, what: &str) -> Result<f32> {
    let t = pop_out(out, what)?;
    let v = t.as_f32()?;
    v.first()
        .copied()
        .ok_or_else(|| anyhow::anyhow!("backend returned an empty scalar for {what}"))
}

/// Parse `(flat', m', v', metrics)` from a train_step result —
/// outputs are flat', m', v', loss, ce, s_eff — without touching the
/// caller's TrainState, so a partial/mismatched output set cannot
/// corrupt it.
fn parse_train_out(
    run: Result<Vec<Tensor>>,
) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, StepMetrics)> {
    let mut out = run?;
    let s_eff = pop_scalar(&mut out, "s_eff")?;
    let ce = pop_scalar(&mut out, "ce")?;
    let loss = pop_scalar(&mut out, "loss")?;
    let v = pop_out(&mut out, "v")?.into_f32()?;
    let m = pop_out(&mut out, "m")?.into_f32()?;
    let flat = pop_out(&mut out, "flat")?.into_f32()?;
    Ok((flat, m, v, StepMetrics { loss, ce, s_eff }))
}

/// [`parse_train_out`] for the s2s contract — outputs are flat', m',
/// v', loss, ce.
fn parse_s2s_out(run: Result<Vec<Tensor>>) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, f32, f32)> {
    let mut out = run?;
    let ce = pop_scalar(&mut out, "ce")?;
    let loss = pop_scalar(&mut out, "loss")?;
    let v = pop_out(&mut out, "v")?.into_f32()?;
    let m = pop_out(&mut out, "m")?.into_f32()?;
    let flat = pop_out(&mut out, "flat")?.into_f32()?;
    Ok((flat, m, v, loss, ce))
}

/// Move taken (flat, m, v) training-state vectors back out of the input
/// tensors after a failed execution or output parse.
fn restore_train_state(state: &mut TrainState, inputs: Vec<Tensor>) {
    let mut it = inputs.into_iter();
    state.flat = it.next().unwrap().into_f32().expect("restore flat");
    state.m = it.next().unwrap().into_f32().expect("restore m");
    state.v = it.next().unwrap().into_f32().expect("restore v");
}

/// `eval_step` artifact: (flat, tokens, noise_std, seed) -> (nll, count, s_eff).
pub struct EvalStep<'a> {
    rt: &'a Runtime,
    entry: &'a Entry,
    pub batch: usize,
    pub n_plus_1: usize,
}

impl<'a> EvalStep<'a> {
    pub fn new(rt: &'a Runtime, manifest: &'a Manifest, name: &str) -> Result<EvalStep<'a>> {
        let entry = manifest.get(name)?;
        if entry.kind != "eval_step" {
            bail!("{name} is kind '{}', expected eval_step", entry.kind);
        }
        let tok = &entry.inputs[1].shape;
        Ok(EvalStep { rt, entry, batch: tok[0], n_plus_1: tok[1] })
    }

    pub fn run(
        &self,
        flat: &[f32],
        tokens: &[i32],
        noise_std: f32,
        seed: i32,
    ) -> Result<(f64, f64, f32)> {
        let p = self.entry.param_count;
        let out = self.rt.run(
            self.entry,
            &[
                Tensor::f32(flat.to_vec(), &[p]),
                Tensor::i32(tokens.to_vec(), &[self.batch, self.n_plus_1]),
                Tensor::scalar_f32(noise_std),
                Tensor::scalar_i32(seed),
            ],
        )?;
        Ok((out[0].as_f32()?[0] as f64, out[1].as_f32()?[0] as f64, out[2].as_f32()?[0]))
    }

    pub fn upload(&self, flat: &[f32]) -> Result<ParamBuf> {
        upload_params(self.rt, self.entry, flat)
    }

    /// Hot-path variant with a pre-uploaded parameter buffer.
    pub fn run_h(
        &self,
        params: &ParamBuf,
        tokens: &[i32],
        noise_std: f32,
        seed: i32,
    ) -> Result<(f64, f64, f32)> {
        let out = self.rt.run_with_param_buffer(
            self.entry,
            params.buffer(),
            &[
                Tensor::i32(tokens.to_vec(), &[self.batch, self.n_plus_1]),
                Tensor::scalar_f32(noise_std),
                Tensor::scalar_i32(seed),
            ],
        )?;
        Ok((out[0].as_f32()?[0] as f64, out[1].as_f32()?[0] as f64, out[2].as_f32()?[0]))
    }
}

/// `forward` artifact: (flat, tokens [1, N]) -> logits [1, N, V].
pub struct Forward<'a> {
    rt: &'a Runtime,
    entry: &'a Entry,
    pub n: usize,
}

impl<'a> Forward<'a> {
    pub fn new(rt: &'a Runtime, manifest: &'a Manifest, name: &str) -> Result<Forward<'a>> {
        let entry = manifest.get(name)?;
        if entry.kind != "forward" {
            bail!("{name} is kind '{}', expected forward", entry.kind);
        }
        let n = entry.inputs[1].shape[1];
        Ok(Forward { rt, entry, n })
    }

    pub fn run(&self, flat: &[f32], tokens: &[i32]) -> Result<Tensor> {
        let p = self.entry.param_count;
        let mut out = self.rt.run(
            self.entry,
            &[Tensor::f32(flat.to_vec(), &[p]), Tensor::i32(tokens.to_vec(), &[1, self.n])],
        )?;
        Ok(out.remove(0))
    }
}

/// STLT streaming carry: per-layer Laplace state (L, U), the O(S d)
/// "KV-cache analog" that makes 100k+ contexts feasible.
#[derive(Clone, Debug)]
pub struct StreamCarry {
    pub l: Vec<f32>,
    pub u: Vec<f32>,
    pub l_shape: Vec<usize>,
    pub u_shape: Vec<usize>,
}

impl StreamCarry {
    pub fn zeros(entry: &Entry) -> StreamCarry {
        let l_shape = entry.inputs[1].shape.clone();
        let u_shape = entry.inputs[2].shape.clone();
        StreamCarry {
            l: vec![0.0; l_shape.iter().product()],
            u: vec![0.0; u_shape.iter().product()],
            l_shape,
            u_shape,
        }
    }

    pub fn state_bytes(&self) -> usize {
        (self.l.len() + self.u.len()) * 4
    }
}

/// `stream_step` artifact:
/// (flat, l, u, tokens[C], targets[C], mask[C]) -> (l', u', nll, count).
pub struct StreamStep<'a> {
    rt: &'a Runtime,
    entry: &'a Entry,
    pub chunk: usize,
}

impl<'a> StreamStep<'a> {
    pub fn new(rt: &'a Runtime, manifest: &'a Manifest, name: &str) -> Result<StreamStep<'a>> {
        let entry = manifest.get(name)?;
        if entry.kind != "stream_step" {
            bail!("{name} is kind '{}', expected stream_step", entry.kind);
        }
        let chunk = entry.inputs[3].shape[0];
        Ok(StreamStep { rt, entry, chunk })
    }

    pub fn zero_carry(&self) -> StreamCarry {
        StreamCarry::zeros(self.entry)
    }

    /// Process one chunk; returns (nll_sum, count) for masked positions.
    ///
    /// The carry is moved into the inputs and moved back on any
    /// failure (backend error or output mismatch), so a failed chunk
    /// leaves the stream resumable instead of silently zero-length.
    pub fn run(
        &self,
        flat: &[f32],
        carry: &mut StreamCarry,
        tokens: &[i32],
        targets: &[i32],
        mask: &[f32],
    ) -> Result<(f64, f64)> {
        let p = self.entry.param_count;
        let inputs = vec![
            Tensor::f32(flat.to_vec(), &[p]),
            Tensor::f32(std::mem::take(&mut carry.l), &carry.l_shape.clone()),
            Tensor::f32(std::mem::take(&mut carry.u), &carry.u_shape.clone()),
            Tensor::i32(tokens.to_vec(), &[self.chunk]),
            Tensor::i32(targets.to_vec(), &[self.chunk]),
            Tensor::f32(mask.to_vec(), &[self.chunk]),
        ];
        match parse_stream_out(self.rt.run(self.entry, &inputs)) {
            Ok((l, u, nll, count)) => {
                carry.l = l;
                carry.u = u;
                Ok((nll, count))
            }
            Err(e) => {
                restore_carry(carry, inputs, 1);
                Err(e)
            }
        }
    }

    pub fn upload(&self, flat: &[f32]) -> Result<ParamBuf> {
        upload_params(self.rt, self.entry, flat)
    }

    /// Hot-path variant with a pre-uploaded parameter buffer.
    pub fn run_h(
        &self,
        params: &ParamBuf,
        carry: &mut StreamCarry,
        tokens: &[i32],
        targets: &[i32],
        mask: &[f32],
    ) -> Result<(f64, f64)> {
        let _span = crate::obs::span("exec", "stream_chunk");
        let inputs = vec![
            Tensor::f32(std::mem::take(&mut carry.l), &carry.l_shape.clone()),
            Tensor::f32(std::mem::take(&mut carry.u), &carry.u_shape.clone()),
            Tensor::i32(tokens.to_vec(), &[self.chunk]),
            Tensor::i32(targets.to_vec(), &[self.chunk]),
            Tensor::f32(mask.to_vec(), &[self.chunk]),
        ];
        let run = self.rt.run_with_param_buffer(self.entry, params.buffer(), &inputs);
        match parse_stream_out(run) {
            Ok((l, u, nll, count)) => {
                carry.l = l;
                carry.u = u;
                Ok((nll, count))
            }
            Err(e) => {
                restore_carry(carry, inputs, 0);
                Err(e)
            }
        }
    }
}

/// Parse `(l', u', nll, count)` from a stream_step result without
/// touching the caller's carry, so a partial/mismatched output set
/// cannot corrupt it.
fn parse_stream_out(run: Result<Vec<Tensor>>) -> Result<(Vec<f32>, Vec<f32>, f64, f64)> {
    let mut out = run?;
    let count = pop_scalar(&mut out, "count")? as f64;
    let nll = pop_scalar(&mut out, "nll")? as f64;
    let u = pop_out(&mut out, "u")?.into_f32()?;
    let l = pop_out(&mut out, "l")?.into_f32()?;
    Ok((l, u, nll, count))
}

/// Parse `(l', u', logits)` from a decode_step result without touching
/// the caller's carry.
fn parse_decode_out(run: Result<Vec<Tensor>>) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
    let mut out = run?;
    let logits = pop_out(&mut out, "logits")?.into_f32()?;
    let u = pop_out(&mut out, "u")?.into_f32()?;
    let l = pop_out(&mut out, "l")?.into_f32()?;
    Ok((l, u, logits))
}

/// Move a taken (l, u) carry back out of the input tensors after a
/// failed execution or output parse; `skip` is the number of inputs
/// before the carry pair (the flat parameter vector, when it is passed
/// by value).
fn restore_carry(carry: &mut StreamCarry, inputs: Vec<Tensor>, skip: usize) {
    let mut it = inputs.into_iter().skip(skip);
    carry.l = it.next().unwrap().into_f32().expect("restore carry l");
    carry.u = it.next().unwrap().into_f32().expect("restore carry u");
}

/// `decode_step` artifact: (flat, l, u, token[1]) -> (l', u', logits[V]).
pub struct DecodeStep<'a> {
    rt: &'a Runtime,
    entry: &'a Entry,
    pub vocab: usize,
}

impl<'a> DecodeStep<'a> {
    pub fn new(rt: &'a Runtime, manifest: &'a Manifest, name: &str) -> Result<DecodeStep<'a>> {
        DecodeStep::from_entry(rt, manifest.get(name)?)
    }

    /// Construct directly over an entry the caller already owns (the
    /// serving model thread holds its decode entry outside a manifest).
    pub fn from_entry(rt: &'a Runtime, entry: &'a Entry) -> Result<DecodeStep<'a>> {
        if entry.kind != "decode_step" {
            bail!("{} is kind '{}', expected decode_step", entry.name, entry.kind);
        }
        let vocab = entry.outputs[2].shape[0];
        Ok(DecodeStep { rt, entry, vocab })
    }

    pub fn zero_carry(&self) -> StreamCarry {
        StreamCarry::zeros(self.entry)
    }

    pub fn run(&self, flat: &[f32], carry: &mut StreamCarry, token: i32) -> Result<Vec<f32>> {
        let p = self.entry.param_count;
        let inputs = vec![
            Tensor::f32(flat.to_vec(), &[p]),
            Tensor::f32(std::mem::take(&mut carry.l), &carry.l_shape.clone()),
            Tensor::f32(std::mem::take(&mut carry.u), &carry.u_shape.clone()),
            Tensor::i32(vec![token], &[1]),
        ];
        match parse_decode_out(self.rt.run(self.entry, &inputs)) {
            Ok((l, u, logits)) => {
                carry.l = l;
                carry.u = u;
                Ok(logits)
            }
            Err(e) => {
                restore_carry(carry, inputs, 1);
                Err(e)
            }
        }
    }

    pub fn upload(&self, flat: &[f32]) -> Result<ParamBuf> {
        upload_params(self.rt, self.entry, flat)
    }

    /// Hot-path variant with a pre-uploaded parameter buffer.
    pub fn run_h(&self, params: &ParamBuf, carry: &mut StreamCarry, token: i32) -> Result<Vec<f32>> {
        let _span = crate::obs::span("exec", "decode");
        let inputs = vec![
            Tensor::f32(std::mem::take(&mut carry.l), &carry.l_shape.clone()),
            Tensor::f32(std::mem::take(&mut carry.u), &carry.u_shape.clone()),
            Tensor::i32(vec![token], &[1]),
        ];
        let run = self.rt.run_with_param_buffer(self.entry, params.buffer(), &inputs);
        match parse_decode_out(run) {
            Ok((l, u, logits)) => {
                carry.l = l;
                carry.u = u;
                Ok(logits)
            }
            Err(e) => {
                restore_carry(carry, inputs, 0);
                Err(e)
            }
        }
    }
}

/// `decode_batch` artifact: the continuous-batching serving step.
/// (flat, l [b,…], u [b,…], tokens [b], active [b]) ->
/// (l', u', logits [b, V]).
///
/// Unlike the other typed entry points this owns its [`Entry`]: the
/// entry is *derived* from a `decode_step` entry
/// ([`Entry::to_decode_batch`]) rather than read from the manifest, so
/// there is no manifest-owned entry to borrow, and the server (which
/// owns its `Runtime` inside the model thread) passes the runtime per
/// call instead of holding a self-referential borrow.
pub struct BatchedDecodeStep {
    entry: Entry,
    pub batch: usize,
    pub vocab: usize,
}

impl BatchedDecodeStep {
    /// Derive from a `decode_step` entry with serving batch width `b`.
    pub fn from_decode(decode_entry: &Entry, b: usize) -> Result<BatchedDecodeStep> {
        let entry = decode_entry.to_decode_batch(b)?;
        let vocab = entry.outputs[2].shape[1];
        Ok(BatchedDecodeStep { entry, batch: b, vocab })
    }

    pub fn entry(&self) -> &Entry {
        &self.entry
    }

    fn l_stride(&self) -> usize {
        self.entry.inputs[1].shape[1..].iter().product()
    }

    fn u_stride(&self) -> usize {
        self.entry.inputs[2].shape[1..].iter().product()
    }

    /// Advance up to `batch` sessions one token each. `rows[i]` is that
    /// session's carry (updated in place on success) and `tokens[i]`
    /// its input token; missing rows up to `batch` are padded with
    /// inactive zero-carry rows, which the kind guarantees contribute
    /// nothing and cost (near) nothing. Returns one logits vector [V]
    /// per provided row, bitwise identical to a single-session
    /// `decode_step` on the same carry (the padding/masking parity
    /// seam, pinned in tests/native_serving.rs).
    ///
    /// The carries are gathered by copy, so on any failure — backend
    /// error or output mismatch — every session carry is left exactly
    /// as it was (same retryability contract as [`DecodeStep::run`]).
    pub fn run_h(
        &self,
        rt: &Runtime,
        params: &ParamBuf,
        rows: &mut [&mut StreamCarry],
        tokens: &[i32],
    ) -> Result<Vec<Vec<f32>>> {
        let _span = crate::obs::span("exec", "decode_batch");
        let n = rows.len();
        if n == 0 || n > self.batch {
            bail!("decode_batch wave of {n} rows (batch width {})", self.batch);
        }
        if tokens.len() != n {
            bail!("decode_batch: {n} rows but {} tokens", tokens.len());
        }
        let (ls, us) = (self.l_stride(), self.u_stride());
        let b = self.batch;
        let mut l_all = vec![0.0f32; b * ls];
        let mut u_all = vec![0.0f32; b * us];
        let mut toks = vec![0i32; b];
        let mut active = vec![0.0f32; b];
        for (i, cr) in rows.iter().enumerate() {
            if cr.l.len() != ls || cr.u.len() != us {
                bail!(
                    "decode_batch row {i}: carry ({}, {}) != entry strides ({ls}, {us})",
                    cr.l.len(),
                    cr.u.len()
                );
            }
            l_all[i * ls..(i + 1) * ls].copy_from_slice(&cr.l);
            u_all[i * us..(i + 1) * us].copy_from_slice(&cr.u);
            toks[i] = tokens[i];
            active[i] = 1.0;
        }
        let e = &self.entry;
        let mut out = rt.run_with_param_buffer(
            e,
            params.buffer(),
            &[
                Tensor::f32(l_all, &e.inputs[1].shape.clone()),
                Tensor::f32(u_all, &e.inputs[2].shape.clone()),
                Tensor::i32(toks, &[b]),
                Tensor::f32(active, &[b]),
            ],
        )?;
        let logits_all = pop_out(&mut out, "logits")?.into_f32()?;
        let u_new = pop_out(&mut out, "u")?.into_f32()?;
        let l_new = pop_out(&mut out, "l")?.into_f32()?;
        if logits_all.len() != b * self.vocab || u_new.len() != b * us || l_new.len() != b * ls {
            bail!(
                "decode_batch: output sizes (l {}, u {}, logits {}) do not match \
                 the entry (b={b}, strides {ls}/{us}, vocab {})",
                l_new.len(),
                u_new.len(),
                logits_all.len(),
                self.vocab
            );
        }
        for (i, cr) in rows.iter_mut().enumerate() {
            cr.l.clear();
            cr.l.extend_from_slice(&l_new[i * ls..(i + 1) * ls]);
            cr.u.clear();
            cr.u.extend_from_slice(&u_new[i * us..(i + 1) * us]);
        }
        Ok((0..n)
            .map(|i| logits_all[i * self.vocab..(i + 1) * self.vocab].to_vec())
            .collect())
    }
}

/// `s2s_train_step` artifact.
pub struct S2sTrainStep<'a> {
    rt: &'a Runtime,
    entry: &'a Entry,
    pub batch: usize,
    pub n_src: usize,
    pub m_tgt_plus_1: usize,
}

impl<'a> S2sTrainStep<'a> {
    pub fn new(rt: &'a Runtime, manifest: &'a Manifest, name: &str) -> Result<S2sTrainStep<'a>> {
        let entry = manifest.get(name)?;
        if entry.kind != "s2s_train_step" {
            bail!("{name} is kind '{}', expected s2s_train_step", entry.kind);
        }
        let src = &entry.inputs[4].shape;
        let tgt = &entry.inputs[5].shape;
        Ok(S2sTrainStep { rt, entry, batch: src[0], n_src: src[1], m_tgt_plus_1: tgt[1] })
    }

    pub fn param_count(&self) -> usize {
        self.entry.param_count
    }

    /// Like [`TrainStep::run`], the moved state vectors are restored on
    /// any failure (backend error or output mismatch) so a failed step
    /// is retryable.
    pub fn run(
        &self,
        state: &mut TrainState,
        src: &[i32],
        tgt: &[i32],
        seed: i32,
    ) -> Result<(f32, f32)> {
        let p = self.entry.param_count;
        let inputs = vec![
            Tensor::f32(std::mem::take(&mut state.flat), &[p]),
            Tensor::f32(std::mem::take(&mut state.m), &[p]),
            Tensor::f32(std::mem::take(&mut state.v), &[p]),
            Tensor::scalar_i32(state.step),
            Tensor::i32(src.to_vec(), &[self.batch, self.n_src]),
            Tensor::i32(tgt.to_vec(), &[self.batch, self.m_tgt_plus_1]),
            Tensor::scalar_i32(seed),
        ];
        match parse_s2s_out(self.rt.run(self.entry, &inputs)) {
            Ok((flat, m, v, loss, ce)) => {
                state.flat = flat;
                state.m = m;
                state.v = v;
                state.step += 1;
                Ok((loss, ce))
            }
            Err(e) => {
                restore_train_state(state, inputs);
                Err(e)
            }
        }
    }
}

/// `s2s_decode` artifact: (flat, src, tgt_prefix, cur_len) -> logits [B, V].
pub struct S2sDecode<'a> {
    rt: &'a Runtime,
    entry: &'a Entry,
    pub batch: usize,
    pub n_src: usize,
    pub m_tgt: usize,
}

impl<'a> S2sDecode<'a> {
    pub fn new(rt: &'a Runtime, manifest: &'a Manifest, name: &str) -> Result<S2sDecode<'a>> {
        let entry = manifest.get(name)?;
        if entry.kind != "s2s_decode" {
            bail!("{name} is kind '{}', expected s2s_decode", entry.kind);
        }
        let src = &entry.inputs[1].shape;
        let tgt = &entry.inputs[2].shape;
        Ok(S2sDecode { rt, entry, batch: src[0], n_src: src[1], m_tgt: tgt[1] })
    }

    pub fn run(
        &self,
        flat: &[f32],
        src: &[i32],
        tgt_prefix: &[i32],
        cur_len: i32,
    ) -> Result<Vec<f32>> {
        let p = self.entry.param_count;
        let mut out = self.rt.run(
            self.entry,
            &[
                Tensor::f32(flat.to_vec(), &[p]),
                Tensor::i32(src.to_vec(), &[self.batch, self.n_src]),
                Tensor::i32(tgt_prefix.to_vec(), &[self.batch, self.m_tgt]),
                Tensor::scalar_i32(cur_len),
            ],
        )?;
        out.pop().unwrap().into_f32()
    }
}

/// Fallback host init (N(0, 0.02)) for latency-only artifacts (scaling
/// sweeps) that have no python init vector; never used for training.
pub fn init_vec_host(param_count: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..param_count).map(|_| (rng.normal() * 0.02) as f32).collect()
}

/// Untrained parameter vector for `{artifact}.*`: the python-exact
/// `.init.bin` when the manifest ships one, else the deterministic
/// host init (seed 0). Every process that loads the same manifest
/// this way holds bitwise-identical weights — the invariant the
/// sharded-serving tests and benches lean on when comparing token
/// streams across workers.
pub fn artifact_flat(manifest: &Manifest, artifact: &str) -> Result<Vec<f32>> {
    let prefix = format!("{artifact}.");
    if let Some(entry) = manifest
        .entries
        .values()
        .find(|e| e.name.starts_with(&prefix) && e.init_file.is_some())
    {
        return load_init_vec(entry.init_file.as_ref().unwrap(), entry.param_count);
    }
    let entry = manifest
        .entries
        .values()
        .find(|e| e.name.starts_with(&prefix))
        .ok_or_else(|| anyhow::anyhow!("no '{artifact}.*' entries in manifest"))?;
    Ok(TrainState::init_for(entry, 0)?.flat)
}

/// Load an init vector dumped by aot.py (f32 little-endian raw file).
pub fn load_init_vec(path: &std::path::Path, expected: usize) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path)?;
    if bytes.len() != expected * 4 {
        bail!("init vec {}: {} bytes != {} params * 4", path.display(), bytes.len(), expected);
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}
