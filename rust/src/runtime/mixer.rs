//! The mixer seam: token mixing as a first-class, pluggable contract.
//!
//! A [`Mixer`] owns everything that differs between token-mixing
//! families sharing the STLT trunk (embedding → LN → mix → FFN → head):
//! the per-layer streaming-carry layout, the single-token state advance
//! ([`Mixer::token_step`], the serving decode hot path), the chunked
//! forward ([`Mixer::mix_chunk`]), and the segment-checkpointed
//! reverse-mode adjoints ([`Mixer::backward_chunk`]) the native trainer
//! replays through. Engine ([`crate::runtime::native_stlt`]), backward
//! ([`crate::train::backward`]), batched serving decode, and carry
//! export/import/migration all route through this trait — none of them
//! hard-code STLT carry shapes.
//!
//! Three implementations ship:
//!
//! * [`Recurrence`] — the paper's O(N·S·d) recursive Laplace
//!   convolution (production path), carry = (L [S,2], U [S,d,2]).
//! * [`ReferenceN2`] — the naive O(N²·S·d) relevance-matrix oracle,
//!   promoted from test-only to a supported quadratic ablation mode
//!   (`mixer = "reference_n2"`). Identical model to [`Recurrence`]
//!   (same parameters, same math, different evaluation order), but
//!   only valid from a zero carry — [`Mixer::streaming`] is false and
//!   the engine refuses to stream it mid-sequence. Training uses the
//!   recurrence tape (same function, O(N) memory).
//! * [`LinearAttention`] — shared-QK linear attention per
//!   "Transformers are RNNs" (Katharopoulos et al.): features
//!   u = φ(f)·m with φ(x) = elu(x)+1, carry = (zv [S], S_mat [S,d]),
//!   z_t = (u_tᵀ S_t) / (u_tᵀ zv_t + ε). The Laplace node parameters
//!   (σ, ω, T) do not feed it ([`Mixer::uses_node_params`] is false):
//!   they stay in the parameter layout for checkpoint compatibility
//!   but receive exactly-zero gradients.
//!
//! The adaptive node gate multiplies the per-node features in every
//! mixer; the per-token gate rows are computed by the trunk (they need
//! the gate parameters and the causal pooling state the trunk carries)
//! and passed in as a strided tape: row t is
//! `m[t*m_stride .. t*m_stride + S]`, with `m_stride = 0` sharing one
//! all-ones row across tokens for non-adaptive configs.
//!
//! Carry-slot sizing is mirrored (and pinned by a test here) by the
//! feature-independent [`ModelConfig::state_lens`] /
//! [`ModelConfig::carry_lens`], which the manifest entry builders use.

use anyhow::{bail, Result};

use crate::runtime::artifact::ModelConfig;
use crate::runtime::native_stlt::{lu_node_step, NodeParams};
use crate::util::sync::Arc;

static SEGMENTS_REPLAYED: crate::obs::LazyCounter =
    crate::obs::LazyCounter::new("train/segments_replayed");

/// Denominator guard of the linear-attention readout (Katharopoulos
/// et al. use the same form: numerator / (uᵀ zv + ε)).
const LINATTN_EPS: f32 = 1e-6;

/// The token-mixing contract (see module docs).
pub trait Mixer: Send + Sync {
    /// Config-string name (`ModelConfig::mixer`).
    fn name(&self) -> &'static str;

    /// Per-layer (l-slot, u-slot) lengths of the mixer *state* alone.
    /// The engine appends the adaptive gate's causal pooling state
    /// (d+1 floats) to the l slot; [`ModelConfig::carry_lens`] folds
    /// both and must agree with this (pinned by a test below).
    fn state_lens(&self, cfg: &ModelConfig) -> (usize, usize);

    /// Whether the mixer can resume from a carried mid-sequence state.
    /// `false` (the O(N²) oracle) restricts it to whole-sequence
    /// forwards from a zero carry; the engine enforces this.
    fn streaming(&self) -> bool {
        true
    }

    /// Whether the Laplace node parameters (sigma_raw, omega, t_raw)
    /// feed this mixer. `false` gates the node-parameter gradient
    /// conversion and the omega/sigma Eq. Reg terms off in the
    /// backward, so those parameter groups get exactly-zero gradients.
    fn uses_node_params(&self) -> bool {
        true
    }

    /// Advance the layer state by one token. `fraw_row` [S] is the
    /// pre-gate feature projection, `m_row` [S] the node gate,
    /// `v_row` [d] the value projection; `l`/`u` are the
    /// [`Mixer::state_lens`]-sized state slices. When `z_row` is
    /// `Some` the mixed output row [d] is accumulated into it (caller
    /// provides it zeroed); `None` is the backward's replay mode —
    /// advance the state only, skipping the discarded output flops.
    #[allow(clippy::too_many_arguments)]
    fn token_step(
        &self,
        np: &NodeParams,
        s: usize,
        d: usize,
        fraw_row: &[f32],
        m_row: &[f32],
        l: &mut [f32],
        u: &mut [f32],
        v_row: &[f32],
        z_row: Option<&mut [f32]>,
    );

    /// One chunk of `n` tokens → zmix [n*d], advancing (l, u) in
    /// place. Default: the streaming token loop (exactly what the
    /// engine, tape forward and decode replay, so chunked and
    /// whole-sequence execution are bitwise identical).
    #[allow(clippy::too_many_arguments)]
    fn mix_chunk(
        &self,
        np: &NodeParams,
        s: usize,
        d: usize,
        n: usize,
        fraw: &[f32],
        m: &[f32],
        m_stride: usize,
        v: &[f32],
        l: &mut [f32],
        u: &mut [f32],
    ) -> Vec<f32> {
        let mut z = vec![0.0f32; n * d];
        for t in 0..n {
            self.token_step(
                np,
                s,
                d,
                &fraw[t * s..(t + 1) * s],
                &m[t * m_stride..t * m_stride + s],
                l,
                u,
                &v[t * d..(t + 1) * d],
                Some(&mut z[t * d..(t + 1) * d]),
            );
        }
        z
    }

    /// Reverse-mode adjoints of a whole-row [`Mixer::mix_chunk`] from a
    /// zero entry carry, segment-checkpointed: `l_snap`/`u_snap` hold
    /// the state entering each `ckpt`-token segment (recorded by the
    /// tape forward), `l_seg`/`u_seg` are caller-provided replay
    /// buffers of (ckpt+1) state slots, and each segment's state
    /// history is replayed on the fly through [`Mixer::token_step`] —
    /// bitwise what a full tape would have stored, so gradients are
    /// bitwise independent of the segment length.
    ///
    /// Inputs mirror the tape: `fraw` [n,S], the strided gate tape
    /// `m`, `v` [n,d], the recorded outputs `zmix` [n,d] and their
    /// adjoint `dz` [n,d]. Outputs: `dfraw`/`dm` [n,S] (per-token —
    /// the gate chain rule differs per mixer, so the fraw/gate split
    /// happens in here), `dv` [n,d], and the node-constant adjoints
    /// `da`/`db` [S] (∂/∂lam_re, ∂/∂lam_im) with ∂/∂gamma returned —
    /// all-zero for mixers with `uses_node_params() == false`.
    #[allow(clippy::too_many_arguments)]
    fn backward_chunk(
        &self,
        np: &NodeParams,
        s: usize,
        d: usize,
        n: usize,
        ckpt: usize,
        fraw: &[f32],
        m: &[f32],
        m_stride: usize,
        v: &[f32],
        zmix: &[f32],
        dz: &[f32],
        l_snap: &[f32],
        u_snap: &[f32],
        l_seg: &mut [f32],
        u_seg: &mut [f32],
        dfraw: &mut [f32],
        dm: &mut [f32],
        dv: &mut [f32],
        da: &mut [f32],
        db: &mut [f32],
    ) -> f64;
}

/// Resolve a [`Mixer`] from `cfg.mixer` ("" defaults to the
/// recurrence). The same names are what `parse_config` validates and
/// the `--mixer` CLI override accepts.
pub fn mixer_from_config(cfg: &ModelConfig) -> Result<Arc<dyn Mixer>> {
    match cfg.mixer.as_str() {
        "" | "recurrence" => Ok(Arc::new(Recurrence)),
        "reference_n2" => Ok(Arc::new(ReferenceN2)),
        "linear_attention" => Ok(Arc::new(LinearAttention)),
        other => bail!(
            "unknown mixer '{other}' (expected recurrence | reference_n2 | linear_attention)"
        ),
    }
}

/// The O(N·S·d) recursive Laplace convolution (production path):
///   L_t = lam·L_{t-1} + f_t,  U_t = gamma·U_{t-1} + conj(L_t)⊗v_t,
///   z_t = Re⟨L_t, U_t⟩ / S,   with f_t = fraw_t ⊙ m_t.
pub struct Recurrence;

impl Mixer for Recurrence {
    fn name(&self) -> &'static str {
        "recurrence"
    }

    fn state_lens(&self, cfg: &ModelConfig) -> (usize, usize) {
        (cfg.s_max * 2, cfg.s_max * cfg.d_model * 2)
    }

    fn token_step(
        &self,
        np: &NodeParams,
        s: usize,
        d: usize,
        fraw_row: &[f32],
        m_row: &[f32],
        l: &mut [f32],
        u: &mut [f32],
        v_row: &[f32],
        mut z_row: Option<&mut [f32]>,
    ) {
        let inv_s = 1.0 / s as f32;
        for k in 0..s {
            lu_node_step(
                np.lam_re[k],
                np.lam_im[k],
                np.gamma,
                fraw_row[k] * m_row[k],
                &mut l[k * 2..(k + 1) * 2],
                &mut u[k * d * 2..(k + 1) * d * 2],
                v_row,
                z_row.as_deref_mut(),
            );
        }
        if let Some(zr) = z_row {
            for ze in zr.iter_mut() {
                *ze *= inv_s;
            }
        }
    }

    fn backward_chunk(
        &self,
        np: &NodeParams,
        s: usize,
        d: usize,
        n: usize,
        ckpt: usize,
        fraw: &[f32],
        m: &[f32],
        m_stride: usize,
        v: &[f32],
        _zmix: &[f32],
        dz: &[f32],
        l_snap: &[f32],
        u_snap: &[f32],
        l_seg: &mut [f32],
        u_seg: &mut [f32],
        dfraw: &mut [f32],
        dm: &mut [f32],
        dv: &mut [f32],
        da: &mut [f32],
        db: &mut [f32],
    ) -> f64 {
        // Running the adjoints GL_t = ∂loss/∂L_t, GU_t = ∂loss/∂U_t
        // backwards in t gives an exact O(N·S·d) gradient — the same
        // linear-attention trick the forward exploits, transposed in
        // time. Segments replay in reverse order; the GL/GU carries
        // thread across segment boundaries exactly like the forward
        // carries did, just reversed.
        let inv_s = 1.0 / s as f32;
        let mut gl = vec![0.0f32; s * 2];
        let mut gu = vec![0.0f32; s * d * 2];
        let mut dgamma = 0.0f64;
        let mut dfp = vec![0.0f32; n * s]; // adjoint of the gated f
        let nseg = n.div_ceil(ckpt);
        for seg in (0..nseg).rev() {
            let _span = crate::obs::span("train", "segment_replay");
            SEGMENTS_REPLAYED.inc();
            let t0 = seg * ckpt;
            let len = ckpt.min(n - t0);
            l_seg[..s * 2].copy_from_slice(&l_snap[seg * s * 2..(seg + 1) * s * 2]);
            u_seg[..s * d * 2]
                .copy_from_slice(&u_snap[seg * s * d * 2..(seg + 1) * s * d * 2]);
            for j in 0..len {
                let t = t0 + j;
                let (ldone, lrest) = l_seg.split_at_mut((j + 1) * s * 2);
                let lcur = &mut lrest[..s * 2];
                lcur.copy_from_slice(&ldone[j * s * 2..]);
                let (udone, urest) = u_seg.split_at_mut((j + 1) * s * d * 2);
                let ucur = &mut urest[..s * d * 2];
                ucur.copy_from_slice(&udone[j * s * d * 2..]);
                // replay advances L/U only; z is never re-needed
                self.token_step(
                    np,
                    s,
                    d,
                    &fraw[t * s..(t + 1) * s],
                    &m[t * m_stride..t * m_stride + s],
                    lcur,
                    ucur,
                    &v[t * d..(t + 1) * d],
                    None,
                );
            }
            for j in (0..len).rev() {
                let t = t0 + j;
                let lrow = &l_seg[(j + 1) * s * 2..(j + 2) * s * 2];
                let urow = &u_seg[(j + 1) * s * d * 2..(j + 2) * s * d * 2];
                // slot j: the state before t — for the global t = 0 this
                // is the zero carry, so its adjoint terms add exact zeros
                let lprev = &l_seg[j * s * 2..(j + 1) * s * 2];
                let uprev = &u_seg[j * s * d * 2..(j + 1) * s * d * 2];
                let vr = &v[t * d..(t + 1) * d];
                let dvr = &mut dv[t * d..(t + 1) * d];
                let zg = &dz[t * d..(t + 1) * d];
                for k in 0..s {
                    let (ltr, lti) = (lrow[k * 2], lrow[k * 2 + 1]);
                    let ub = &urow[k * d * 2..(k + 1) * d * 2];
                    let up = &uprev[k * d * 2..(k + 1) * d * 2];
                    let gub = &mut gu[k * d * 2..(k + 1) * d * 2];
                    let (mut glr, mut gli) = (gl[k * 2], gl[k * 2 + 1]);
                    let mut dg_loc = 0.0f64;
                    for e in 0..d {
                        let g_te = zg[e] * inv_s;
                        // z_t = Σ_k Re(L_t · U_t)/S
                        let gur = gub[e * 2] + g_te * ltr;
                        let gui = gub[e * 2 + 1] - g_te * lti;
                        glr += g_te * ub[e * 2];
                        gli -= g_te * ub[e * 2 + 1];
                        // U_t = gamma U_{t-1} + conj(L_t) v_t
                        dg_loc += (gur * up[e * 2]) as f64 + (gui * up[e * 2 + 1]) as f64;
                        let ve = vr[e];
                        dvr[e] += gur * ltr - gui * lti;
                        glr += gur * ve;
                        gli -= gui * ve;
                        gub[e * 2] = np.gamma * gur;
                        gub[e * 2 + 1] = np.gamma * gui;
                    }
                    dgamma += dg_loc;
                    // L_t = lam L_{t-1} + f_t
                    dfp[t * s + k] += glr;
                    let (lpr, lpi) = (lprev[k * 2], lprev[k * 2 + 1]);
                    da[k] += glr * lpr + gli * lpi;
                    db[k] += -glr * lpi + gli * lpr;
                    let (a, b) = (np.lam_re[k], np.lam_im[k]);
                    gl[k * 2] = a * glr + b * gli;
                    gl[k * 2 + 1] = -b * glr + a * gli;
                }
            }
        }
        // f = fraw ⊙ m: split the gated-feature adjoint
        for t in 0..n {
            for k in 0..s {
                let dfp_tk = dfp[t * s + k];
                dfraw[t * s + k] = dfp_tk * m[t * m_stride + k];
                dm[t * s + k] = dfp_tk * fraw[t * s + k];
            }
        }
        dgamma
    }
}

/// Naive O(N²·S·d) relevance-matrix oracle: materialises L via explicit
/// lam powers and recomputes every discounted U prefix sum. Identical
/// model to [`Recurrence`] (delegates token_step/backward to it); only
/// the chunked forward is the quadratic evaluation, and only from a
/// zero carry ([`Mixer::streaming`] = false, enforced by the engine).
pub struct ReferenceN2;

impl Mixer for ReferenceN2 {
    fn name(&self) -> &'static str {
        "reference_n2"
    }

    fn state_lens(&self, cfg: &ModelConfig) -> (usize, usize) {
        Recurrence.state_lens(cfg)
    }

    fn streaming(&self) -> bool {
        false
    }

    fn token_step(
        &self,
        np: &NodeParams,
        s: usize,
        d: usize,
        fraw_row: &[f32],
        m_row: &[f32],
        l: &mut [f32],
        u: &mut [f32],
        v_row: &[f32],
        z_row: Option<&mut [f32]>,
    ) {
        // the training tape streams even for the quadratic ablation
        // mode — same model, O(N) tape instead of O(N²) evaluation
        Recurrence.token_step(np, s, d, fraw_row, m_row, l, u, v_row, z_row);
    }

    fn mix_chunk(
        &self,
        np: &NodeParams,
        s: usize,
        d: usize,
        n: usize,
        fraw: &[f32],
        m: &[f32],
        m_stride: usize,
        v: &[f32],
        l: &mut [f32],
        u: &mut [f32],
    ) -> Vec<f32> {
        let inv_s = 1.0 / s as f32;
        // gate first, exactly like the streaming path's f_t = fraw_t ⊙ m_t
        let mut fproj = vec![0.0f32; n * s];
        for t in 0..n {
            for k in 0..s {
                fproj[t * s + k] = fraw[t * s + k] * m[t * m_stride + k];
            }
        }
        // lam^p for p in [0, n): [n][s]
        let mut pow_re = vec![0.0f32; n.max(1) * s];
        let mut pow_im = vec![0.0f32; n.max(1) * s];
        for k in 0..s {
            pow_re[k] = 1.0;
            pow_im[k] = 0.0;
        }
        for p in 1..n {
            for k in 0..s {
                let (ar, ai) = (pow_re[(p - 1) * s + k], pow_im[(p - 1) * s + k]);
                pow_re[p * s + k] = ar * np.lam_re[k] - ai * np.lam_im[k];
                pow_im[p * s + k] = ar * np.lam_im[k] + ai * np.lam_re[k];
            }
        }
        // L[t,k] = sum_{m<=t} f[m,k] lam^{t-m}
        let mut l_re = vec![0.0f32; n * s];
        let mut l_im = vec![0.0f32; n * s];
        for t in 0..n {
            for mm in 0..=t {
                let p = t - mm;
                for k in 0..s {
                    let f = fproj[mm * s + k];
                    l_re[t * s + k] += f * pow_re[p * s + k];
                    l_im[t * s + k] += f * pow_im[p * s + k];
                }
            }
        }
        // z_t = Re<L_t, U_t>/S with U_t = sum_{m<=t} gamma^{t-m} conj(L_m) (x) v_m
        let mut z = vec![0.0f32; n * d];
        for t in 0..n {
            for k in 0..s {
                let (ltr, lti) = (l_re[t * s + k], l_im[t * s + k]);
                let mut g = 1.0f32;
                for mm in (0..=t).rev() {
                    let (lmr, lmi) = (l_re[mm * s + k], l_im[mm * s + k]);
                    for e in 0..d {
                        let ve = v[mm * d + e];
                        // ur += g*lmr*ve ; ui += -g*lmi*ve ; z += ltr*ur - lti*ui
                        z[t * d + e] += (ltr * lmr + lti * lmi) * g * ve;
                    }
                    g *= np.gamma;
                }
            }
            for e in 0..d {
                z[t * d + e] *= inv_s;
            }
        }
        // advance the carry to the end-of-chunk state for parity checks
        if n > 0 {
            for k in 0..s {
                l[k * 2] = l_re[(n - 1) * s + k];
                l[k * 2 + 1] = l_im[(n - 1) * s + k];
                let ub = &mut u[k * d * 2..(k + 1) * d * 2];
                for e in 0..d {
                    let (mut ur, mut ui) = (0.0f32, 0.0f32);
                    let mut g = 1.0f32;
                    for mm in (0..n).rev() {
                        ur += g * l_re[mm * s + k] * v[mm * d + e];
                        ui -= g * l_im[mm * s + k] * v[mm * d + e];
                        g *= np.gamma;
                    }
                    ub[e * 2] = ur;
                    ub[e * 2 + 1] = ui;
                }
            }
        }
        z
    }

    fn backward_chunk(
        &self,
        np: &NodeParams,
        s: usize,
        d: usize,
        n: usize,
        ckpt: usize,
        fraw: &[f32],
        m: &[f32],
        m_stride: usize,
        v: &[f32],
        zmix: &[f32],
        dz: &[f32],
        l_snap: &[f32],
        u_snap: &[f32],
        l_seg: &mut [f32],
        u_seg: &mut [f32],
        dfraw: &mut [f32],
        dm: &mut [f32],
        dv: &mut [f32],
        da: &mut [f32],
        db: &mut [f32],
    ) -> f64 {
        Recurrence.backward_chunk(
            np, s, d, n, ckpt, fraw, m, m_stride, v, zmix, dz, l_snap, u_snap, l_seg, u_seg,
            dfraw, dm, dv, da, db,
        )
    }
}

/// φ(x) = elu(x) + 1 and its derivative — the positive feature map of
/// "Transformers are RNNs" (both branches agree at x = 0: φ = φ' = 1).
#[inline(always)]
fn phi(x: f32) -> (f32, f32) {
    if x > 0.0 {
        (x + 1.0, 1.0)
    } else {
        let ex = x.exp();
        (ex, ex)
    }
}

/// Shared-QK linear attention: u_t = φ(fraw_t) ⊙ m_t, streaming state
/// zv_t = Σ u, S_t = Σ u⊗v, readout z_t = (u_tᵀ S_t)/(u_tᵀ zv_t + ε)
/// with inclusive (post-update) reads — the causal-attention form.
/// Gating post-φ keeps the feature map positive and makes m_k → 0
/// remove node k from numerator and denominator alike.
pub struct LinearAttention;

impl LinearAttention {
    /// Readout denominator, accumulated in one fixed order so the
    /// forward and the backward's recomputation agree bitwise.
    #[inline(always)]
    fn den(u: &[f32], zv: &[f32]) -> f32 {
        let mut den = LINATTN_EPS;
        for (uk, zk) in u.iter().zip(zv) {
            den += uk * zk;
        }
        den
    }
}

impl Mixer for LinearAttention {
    fn name(&self) -> &'static str {
        "linear_attention"
    }

    fn state_lens(&self, cfg: &ModelConfig) -> (usize, usize) {
        (cfg.s_max, cfg.s_max * cfg.d_model)
    }

    fn uses_node_params(&self) -> bool {
        false
    }

    fn token_step(
        &self,
        _np: &NodeParams,
        s: usize,
        d: usize,
        fraw_row: &[f32],
        m_row: &[f32],
        zv: &mut [f32],
        s_mat: &mut [f32],
        v_row: &[f32],
        z_row: Option<&mut [f32]>,
    ) {
        let mut u = vec![0.0f32; s];
        for k in 0..s {
            u[k] = phi(fraw_row[k]).0 * m_row[k];
            zv[k] += u[k];
            let sk = &mut s_mat[k * d..(k + 1) * d];
            for (se, &ve) in sk.iter_mut().zip(v_row) {
                *se += u[k] * ve;
            }
        }
        if let Some(zr) = z_row {
            for k in 0..s {
                let sk = &s_mat[k * d..(k + 1) * d];
                for (ze, &se) in zr.iter_mut().zip(sk) {
                    *ze += u[k] * se;
                }
            }
            let inv_den = 1.0 / Self::den(&u, zv);
            for ze in zr.iter_mut() {
                *ze *= inv_den;
            }
        }
    }

    fn backward_chunk(
        &self,
        np: &NodeParams,
        s: usize,
        d: usize,
        n: usize,
        ckpt: usize,
        fraw: &[f32],
        m: &[f32],
        m_stride: usize,
        v: &[f32],
        zmix: &[f32],
        dz: &[f32],
        l_snap: &[f32],
        u_snap: &[f32],
        l_seg: &mut [f32],
        u_seg: &mut [f32],
        dfraw: &mut [f32],
        dm: &mut [f32],
        dv: &mut [f32],
        _da: &mut [f32],
        _db: &mut [f32],
    ) -> f64 {
        // GS = ∂loss/∂S_t, Gzv = ∂loss/∂zv_t, threaded backwards across
        // segment boundaries; the state decompositions S_t = S_{t-1} +
        // u_t ⊗ v_t and zv_t = zv_{t-1} + u_t pass both through
        // unchanged, so no decay factors appear.
        let mut gs = vec![0.0f32; s * d];
        let mut gzv = vec![0.0f32; s];
        let mut u = vec![0.0f32; s];
        let mut dnum = vec![0.0f32; d];
        let nseg = n.div_ceil(ckpt);
        for seg in (0..nseg).rev() {
            let _span = crate::obs::span("train", "segment_replay");
            SEGMENTS_REPLAYED.inc();
            let t0 = seg * ckpt;
            let len = ckpt.min(n - t0);
            l_seg[..s].copy_from_slice(&l_snap[seg * s..(seg + 1) * s]);
            u_seg[..s * d].copy_from_slice(&u_snap[seg * s * d..(seg + 1) * s * d]);
            for j in 0..len {
                let t = t0 + j;
                let (ldone, lrest) = l_seg.split_at_mut((j + 1) * s);
                let lcur = &mut lrest[..s];
                lcur.copy_from_slice(&ldone[j * s..]);
                let (udone, urest) = u_seg.split_at_mut((j + 1) * s * d);
                let ucur = &mut urest[..s * d];
                ucur.copy_from_slice(&udone[j * s * d..]);
                self.token_step(
                    np,
                    s,
                    d,
                    &fraw[t * s..(t + 1) * s],
                    &m[t * m_stride..t * m_stride + s],
                    lcur,
                    ucur,
                    &v[t * d..(t + 1) * d],
                    None,
                );
            }
            for j in (0..len).rev() {
                let t = t0 + j;
                // slot j+1: (zv, S) after token t — num/den read the
                // post-update state, so the adjoints do too
                let zvrow = &l_seg[(j + 1) * s..(j + 2) * s];
                let srow = &u_seg[(j + 1) * s * d..(j + 2) * s * d];
                let frow = &fraw[t * s..(t + 1) * s];
                let mrow = &m[t * m_stride..t * m_stride + s];
                for k in 0..s {
                    u[k] = phi(frow[k]).0 * mrow[k];
                }
                let zrow = &zmix[t * d..(t + 1) * d];
                let dzr = &dz[t * d..(t + 1) * d];
                let inv_den = 1.0 / Self::den(&u, zvrow);
                // z = num/den: dnum = dz/den, dden = -Σ_e dnum_e z_e
                let mut dden = 0.0f32;
                for e in 0..d {
                    dnum[e] = dzr[e] * inv_den;
                    dden -= dnum[e] * zrow[e];
                }
                let vr = &v[t * d..(t + 1) * d];
                let dvr = &mut dv[t * d..(t + 1) * d];
                for k in 0..s {
                    let sk = &srow[k * d..(k + 1) * d];
                    let gsk = &mut gs[k * d..(k + 1) * d];
                    // num_e = Σ_k u_k S[k,e] ; den = Σ_k u_k zv_k + ε
                    let mut du_k = dden * zvrow[k];
                    for e in 0..d {
                        du_k += dnum[e] * sk[e];
                        gsk[e] += dnum[e] * u[k];
                    }
                    gzv[k] += dden * u[k];
                    // S_t = S_{t-1} + u_t ⊗ v_t ; zv_t = zv_{t-1} + u_t
                    for e in 0..d {
                        du_k += gsk[e] * vr[e];
                        dvr[e] += gsk[e] * u[k];
                    }
                    du_k += gzv[k];
                    // u = φ(fraw) ⊙ m
                    let (ph, dph) = phi(frow[k]);
                    dfraw[t * s + k] = du_k * dph * mrow[k];
                    dm[t * s + k] = du_k * ph;
                }
            }
        }
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn cfg(s: usize, d: usize) -> ModelConfig {
        ModelConfig {
            arch: "stlt".into(),
            vocab: 11,
            d_model: d,
            n_layers: 1,
            n_ctx: 16,
            s_max: s,
            batch: 1,
            mode: "linear".into(),
            ..ModelConfig::default()
        }
    }

    fn dummy_np(s: usize) -> NodeParams {
        NodeParams {
            lam_re: vec![0.5; s],
            lam_im: vec![0.1; s],
            gamma: 0.9,
        }
    }

    #[test]
    fn state_lens_agree_with_config() {
        // the trait's carry contract and the feature-independent
        // ModelConfig mirror (used by manifest entry builders) must
        // never drift
        for name in ["recurrence", "reference_n2", "linear_attention"] {
            let mut c = cfg(4, 8);
            c.mixer = name.into();
            let mx = mixer_from_config(&c).unwrap();
            assert_eq!(mx.state_lens(&c), c.state_lens(), "{name}");
            let (sl, su) = c.state_lens();
            assert_eq!(c.carry_lens(), (sl, su), "no gate state when not adaptive");
            c.adaptive = true;
            assert_eq!(c.carry_lens(), (sl + c.d_model + 1, su), "{name} gate state");
        }
        assert!(mixer_from_config(&{
            let mut c = cfg(4, 8);
            c.mixer = "softmax".into();
            c
        })
        .is_err());
    }

    #[test]
    fn linear_attention_matches_quadratic_oracle() {
        // streaming state form == the O(n²) causal-attention form:
        //   z_t[e] = Σ_{t'<=t} (u_t · u_{t'}) v_{t'}[e]
        //           / (Σ_{t'<=t} (u_t · u_{t'}) + ε)
        let (s, d, n) = (4usize, 6usize, 9usize);
        let c = cfg(s, d);
        let np = dummy_np(s);
        let mut rng = Rng::new(7);
        let fraw: Vec<f32> = (0..n * s).map(|_| rng.f32() * 2.0 - 1.0).collect();
        let v: Vec<f32> = (0..n * d).map(|_| rng.f32() - 0.5).collect();
        let m: Vec<f32> = (0..n * s).map(|_| 0.25 + 0.75 * rng.f32()).collect();
        let mx = LinearAttention;
        let (sl, su) = mx.state_lens(&c);
        let (mut l, mut u_st) = (vec![0.0f32; sl], vec![0.0f32; su]);
        let z = mx.mix_chunk(&np, s, d, n, &fraw, &m, s, &v, &mut l, &mut u_st);
        // oracle in f64
        let uu: Vec<f64> = (0..n * s)
            .map(|i| {
                let x = fraw[i] as f64;
                let p = if x > 0.0 { x + 1.0 } else { x.exp() };
                p * m[i] as f64
            })
            .collect();
        for t in 0..n {
            for e in 0..d {
                let (mut num, mut den) = (0.0f64, LINATTN_EPS as f64);
                for tp in 0..=t {
                    let mut dot = 0.0f64;
                    for k in 0..s {
                        dot += uu[t * s + k] * uu[tp * s + k];
                    }
                    num += dot * v[tp * d + e] as f64;
                    if e == 0 {
                        den += dot;
                    }
                }
                let mut den_all = LINATTN_EPS as f64;
                for tp in 0..=t {
                    let mut dot = 0.0f64;
                    for k in 0..s {
                        dot += uu[t * s + k] * uu[tp * s + k];
                    }
                    den_all += dot;
                }
                let want = num / den_all;
                let got = z[t * d + e] as f64;
                assert!(
                    (got - want).abs() < 1e-4 * (1.0 + want.abs()),
                    "z[{t},{e}] {got} vs {want}"
                );
            }
        }
        // and the carried state equals the plain sums
        for k in 0..s {
            let want: f64 = (0..n).map(|t| uu[t * s + k]).sum();
            assert!((l[k] as f64 - want).abs() < 1e-4 * (1.0 + want.abs()));
        }
    }

    #[test]
    fn linear_attention_chunked_state_is_bitwise_invariant() {
        // the carry makes chunk boundaries invisible: any split of the
        // token stream produces bitwise the same outputs and state
        let (s, d, n) = (3usize, 5usize, 12usize);
        let c = cfg(s, d);
        let np = dummy_np(s);
        let mut rng = Rng::new(3);
        let fraw: Vec<f32> = (0..n * s).map(|_| rng.f32() * 2.0 - 1.0).collect();
        let v: Vec<f32> = (0..n * d).map(|_| rng.f32() - 0.5).collect();
        let m = vec![1.0f32; s];
        let mx = LinearAttention;
        let (sl, su) = mx.state_lens(&c);
        let (mut l, mut u_st) = (vec![0.0f32; sl], vec![0.0f32; su]);
        let whole = mx.mix_chunk(&np, s, d, n, &fraw, &m, 0, &v, &mut l, &mut u_st);
        let (mut l2, mut u2) = (vec![0.0f32; sl], vec![0.0f32; su]);
        let mut pieces = Vec::new();
        for (t0, len) in [(0usize, 5usize), (5, 1), (6, 6)] {
            pieces.extend(mx.mix_chunk(
                &np,
                s,
                d,
                len,
                &fraw[t0 * s..(t0 + len) * s],
                &m,
                0,
                &v[t0 * d..(t0 + len) * d],
                &mut l2,
                &mut u2,
            ));
        }
        assert_eq!(whole, pieces);
        assert_eq!(l, l2);
        assert_eq!(u_st, u2);
    }
}
