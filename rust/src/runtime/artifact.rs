//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the Rust runtime. Parses artifacts/manifest.json (via util::json) into
//! typed entries with input/output specs and the originating ModelConfig.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::runtime::tensor::DType;
use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// Subset of the python ModelConfig the Rust side needs. The native
/// backend additionally consumes the STLT numeric hyperparameters
/// (ffn_mult, sigma_min, t_init, omega_zero) and — since the native
/// `train_step` landed — the optimiser/regulariser hyperparameters
/// (lr, warmup, betas, weight_decay, grad_clip, lambda_*, learn_*);
/// all default to the python `ModelConfig` defaults when absent from
/// older manifests.
#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub arch: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_ctx: usize,
    pub s_max: usize,
    pub batch: usize,
    pub adaptive: bool,
    pub mode: String,
    /// Token-mixing family: "" / "recurrence" (the default Laplace
    /// recurrence), "reference_n2" (the quadratic ablation oracle), or
    /// "linear_attention" (the Katharopoulos et al. baseline). Resolved
    /// by `runtime::mixer::mixer_from_config`; validated at parse time.
    pub mixer: String,
    pub total_steps: u64,
    pub ffn_mult: usize,
    pub sigma_min: f32,
    pub t_init: f32,
    pub omega_zero: bool,
    // --- ablation stop-gradients (python: learn_sigma/learn_omega/learn_t)
    pub learn_sigma: bool,
    pub learn_omega: bool,
    pub learn_t: bool,
    // --- Eq. Reg penalty weights
    pub lambda_omega: f32,
    pub lambda_sigma: f32,
    pub lambda_mask: f32,
    // --- optimiser (python/compile/optim.py semantics)
    pub lr: f32,
    pub warmup: u64,
    pub weight_decay: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub grad_clip: f32,
    /// Native-backward gradient-checkpoint segment length (tokens).
    /// 0 = whole sequence (one segment): the backward's activation tape
    /// holds the full O(N·S·d) per-layer U history, exactly the pre-
    /// checkpointing behaviour. A positive value C stores only the
    /// (L, U) carry at every C-token boundary and replays each
    /// segment's tape on the fly during the backward, cutting the peak
    /// tape to O(C·S·d + (N/C)·S·d) per layer. Gradients are bitwise
    /// identical for every value (tests/native_train.rs). Native-only;
    /// the XLA backward ignores it.
    pub grad_ckpt_segment: usize,
    // --- adaptive-gate Gumbel-sigmoid temperature schedule (SS3.6):
    // temp anneals linearly from `gumbel_temp_hi` to `gumbel_temp_lo`
    // over the first `gumbel_anneal_frac * total_steps` train steps,
    // then stays at `gumbel_temp_lo`. Native training only; eval and
    // serving always use the deterministic (noise-free) gate.
    pub gumbel_temp_hi: f32,
    pub gumbel_temp_lo: f32,
    pub gumbel_anneal_frac: f32,
}

impl ModelConfig {
    /// Per-layer streaming-state slot lengths `(l, u)` of the mixer
    /// itself — the feature-independent mirror of
    /// `runtime::mixer::Mixer::state_lens` (pinned equal by a test
    /// there), so entry builders and the wire layer can size carries
    /// without the native feature.
    pub fn state_lens(&self) -> (usize, usize) {
        let (s, d) = (self.s_max, self.d_model);
        if self.mixer == "linear_attention" {
            (s, s * d)
        } else {
            (s * 2, s * d * 2)
        }
    }

    /// Per-layer carry slot lengths `(l, u)` as serialized/streamed:
    /// the mixer state plus, when adaptive, the causal gate's
    /// (pool_sum [d], count [1]) appended to the l slot.
    pub fn carry_lens(&self) -> (usize, usize) {
        let (sl, su) = self.state_lens();
        (sl + if self.adaptive { self.d_model + 1 } else { 0 }, su)
    }
}

impl Default for ModelConfig {
    fn default() -> Self {
        ModelConfig {
            arch: String::new(),
            vocab: 0,
            d_model: 0,
            n_layers: 0,
            n_ctx: 0,
            s_max: 0,
            batch: 0,
            adaptive: false,
            mode: String::new(),
            mixer: String::new(),
            // python config.py defaults
            total_steps: 2000,
            ffn_mult: 4,
            sigma_min: 1e-3,
            t_init: 32.0,
            omega_zero: false,
            learn_sigma: true,
            learn_omega: true,
            learn_t: true,
            lambda_omega: 1e-4,
            lambda_sigma: 1e-4,
            lambda_mask: 1e-3,
            lr: 3e-4,
            warmup: 100,
            weight_decay: 0.01,
            beta1: 0.9,
            beta2: 0.98,
            grad_clip: 1.0,
            grad_ckpt_segment: 0,
            gumbel_temp_hi: 1.0,
            gumbel_temp_lo: 0.1,
            gumbel_anneal_frac: 0.4,
        }
    }
}

#[derive(Clone, Debug)]
pub struct Entry {
    pub name: String,
    pub file: PathBuf,
    pub kind: String,
    pub param_count: usize,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub config: ModelConfig,
    /// extra ints (chunk, n_src, m_tgt ...)
    pub extra: BTreeMap<String, i64>,
    /// python-exact packed init vector (raw LE f32), if the entry has one
    pub init_file: Option<PathBuf>,
    /// indices of inputs that survived jax's unused-argument pruning;
    /// the runtime filters its argument list to exactly these.
    pub kept_inputs: Vec<usize>,
}

#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: BTreeMap<String, Entry>,
}

fn parse_spec(j: &Json) -> Result<TensorSpec> {
    let dtype = DType::from_name(
        j.get("dtype").and_then(|d| d.as_str()).ok_or_else(|| anyhow!("spec missing dtype"))?,
    )
    .map_err(|e| anyhow!("{e}"))?;
    let shape = j
        .get("shape")
        .and_then(|s| s.as_arr())
        .ok_or_else(|| anyhow!("spec missing shape"))?
        .iter()
        .map(|v| v.as_i64().map(|x| x as usize).ok_or_else(|| anyhow!("bad dim")))
        .collect::<Result<Vec<_>>>()?;
    Ok(TensorSpec { dtype, shape })
}

/// Accepted `mixer` config values ("" = the default recurrence).
pub const MIXER_NAMES: [&str; 3] = ["recurrence", "reference_n2", "linear_attention"];

/// Parse a manifest `config` object. Legacy keys stay tolerant (absent
/// or malformed values fall back to the python defaults — older
/// manifests must keep loading); the PR-8 keys (`mixer`, the Gumbel
/// temperature schedule) are validated strictly with actionable errors,
/// because a typo'd mixer name or a negative temperature would
/// otherwise train a silently different model.
fn parse_config(j: Option<&Json>) -> Result<ModelConfig> {
    let mut c = ModelConfig::default();
    if let Some(j) = j {
        let s = |k: &str| j.get(k).and_then(|v| v.as_str()).unwrap_or("").to_string();
        let i = |k: &str| j.get(k).and_then(|v| v.as_i64()).unwrap_or(0);
        let b = |k: &str| j.get(k).and_then(|v| v.as_bool()).unwrap_or(false);
        c.arch = s("arch");
        c.vocab = i("vocab") as usize;
        c.d_model = i("d_model") as usize;
        c.n_layers = i("n_layers") as usize;
        c.n_ctx = i("n_ctx") as usize;
        c.s_max = i("s_max") as usize;
        c.batch = i("batch") as usize;
        c.adaptive = b("adaptive");
        c.mode = s("mode");
        if let Some(ts) = j.get("total_steps").and_then(|v| v.as_i64()) {
            c.total_steps = ts as u64;
        }
        if let Some(fm) = j.get("ffn_mult").and_then(|v| v.as_i64()) {
            if fm > 0 {
                c.ffn_mult = fm as usize;
            }
        }
        if let Some(sm) = j.get("sigma_min").and_then(|v| v.as_f64()) {
            c.sigma_min = sm as f32;
        }
        if let Some(ti) = j.get("t_init").and_then(|v| v.as_f64()) {
            c.t_init = ti as f32;
        }
        if let Some(oz) = j.get("omega_zero").and_then(|v| v.as_bool()) {
            c.omega_zero = oz;
        }
        // optional keys default to the python values, so absent keys must
        // not clobber them (notably learn_* default to true)
        let bopt = |k: &str, dst: &mut bool| {
            if let Some(v) = j.get(k).and_then(|v| v.as_bool()) {
                *dst = v;
            }
        };
        bopt("learn_sigma", &mut c.learn_sigma);
        bopt("learn_omega", &mut c.learn_omega);
        bopt("learn_t", &mut c.learn_t);
        let fopt = |k: &str, dst: &mut f32| {
            if let Some(v) = j.get(k).and_then(|v| v.as_f64()) {
                *dst = v as f32;
            }
        };
        fopt("lambda_omega", &mut c.lambda_omega);
        fopt("lambda_sigma", &mut c.lambda_sigma);
        fopt("lambda_mask", &mut c.lambda_mask);
        fopt("lr", &mut c.lr);
        fopt("weight_decay", &mut c.weight_decay);
        fopt("beta1", &mut c.beta1);
        fopt("beta2", &mut c.beta2);
        fopt("grad_clip", &mut c.grad_clip);
        if let Some(w) = j.get("warmup").and_then(|v| v.as_i64()) {
            c.warmup = w as u64;
        }
        if let Some(g) = j.get("grad_ckpt_segment").and_then(|v| v.as_i64()) {
            if g > 0 {
                c.grad_ckpt_segment = g as usize;
            }
        }
        if let Some(v) = j.get("mixer") {
            let name = v.as_str().ok_or_else(|| {
                anyhow!("config key 'mixer' must be a string, one of {MIXER_NAMES:?}")
            })?;
            if !name.is_empty() && !MIXER_NAMES.contains(&name) {
                bail!("unknown mixer '{name}' (expected one of {MIXER_NAMES:?})");
            }
            c.mixer = name.to_string();
        }
        let gum = |k: &str, dst: &mut f32| -> Result<()> {
            if let Some(v) = j.get(k) {
                let x = v
                    .as_f64()
                    .ok_or_else(|| anyhow!("config key '{k}' must be a number, got {v:?}"))?;
                if !x.is_finite() || x <= 0.0 {
                    bail!("config key '{k}' must be a finite positive number, got {x}");
                }
                *dst = x as f32;
            }
            Ok(())
        };
        gum("gumbel_temp_hi", &mut c.gumbel_temp_hi)?;
        gum("gumbel_temp_lo", &mut c.gumbel_temp_lo)?;
        gum("gumbel_anneal_frac", &mut c.gumbel_anneal_frac)?;
        if c.gumbel_temp_lo > c.gumbel_temp_hi {
            bail!(
                "gumbel_temp_lo ({}) must not exceed gumbel_temp_hi ({}) — the \
                 schedule anneals hi -> lo",
                c.gumbel_temp_lo,
                c.gumbel_temp_hi
            );
        }
        if c.gumbel_anneal_frac > 1.0 {
            bail!(
                "gumbel_anneal_frac ({}) must be in (0, 1] — it is the fraction of \
                 total_steps spent annealing",
                c.gumbel_anneal_frac
            );
        }
    }
    Ok(c)
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
        let entries_j = j
            .get("entries")
            .and_then(|e| e.as_obj())
            .ok_or_else(|| anyhow!("manifest missing entries"))?;
        let mut entries = BTreeMap::new();
        for (name, e) in entries_j {
            let inputs = e
                .get("inputs")
                .and_then(|x| x.as_arr())
                .ok_or_else(|| anyhow!("{name}: missing inputs"))?
                .iter()
                .map(parse_spec)
                .collect::<Result<Vec<_>>>()?;
            let outputs = e
                .get("outputs")
                .and_then(|x| x.as_arr())
                .ok_or_else(|| anyhow!("{name}: missing outputs"))?
                .iter()
                .map(parse_spec)
                .collect::<Result<Vec<_>>>()?;
            let mut extra = BTreeMap::new();
            for k in ["chunk", "n_src", "m_tgt", "batch_srv"] {
                if let Some(v) = e.get(k).and_then(|v| v.as_i64()) {
                    extra.insert(k.to_string(), v);
                }
            }
            let n_inputs = inputs.len();
            entries.insert(
                name.clone(),
                Entry {
                    name: name.clone(),
                    file: dir.join(
                        e.get("file")
                            .and_then(|f| f.as_str())
                            .ok_or_else(|| anyhow!("{name}: missing file"))?,
                    ),
                    kind: e.get("kind").and_then(|k| k.as_str()).unwrap_or("").to_string(),
                    param_count: e.get("param_count").and_then(|p| p.as_i64()).unwrap_or(0)
                        as usize,
                    inputs,
                    outputs,
                    config: parse_config(e.get("config"))
                        .with_context(|| format!("{name}: bad config"))?,
                    extra,
                    init_file: e
                        .get("init")
                        .and_then(|v| v.as_str())
                        .map(|f| dir.join(f)),
                    kept_inputs: e
                        .get("kept_inputs")
                        .and_then(|v| v.as_arr())
                        .map(|a| a.iter().filter_map(|x| x.as_i64()).map(|x| x as usize).collect())
                        .unwrap_or_else(|| (0..n_inputs).collect()),
                },
            );
        }
        Ok(Manifest { dir, entries })
    }

    pub fn get(&self, name: &str) -> Result<&Entry> {
        self.entries.get(name).ok_or_else(|| {
            anyhow!(
                "artifact '{name}' not in manifest ({} entries; run `make artifacts`)",
                self.entries.len()
            )
        })
    }

    /// Entries with a given kind, sorted by name.
    pub fn by_kind(&self, kind: &str) -> Vec<&Entry> {
        self.entries.values().filter(|e| e.kind == kind).collect()
    }
}

/// Locate the artifacts dir: $STLT_ARTIFACTS or ./artifacts upward.
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("STLT_ARTIFACTS") {
        return PathBuf::from(p);
    }
    let mut cur = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        let cand = cur.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !cur.pop() {
            return PathBuf::from("artifacts");
        }
    }
}

impl Entry {
    /// Synthesize a metadata-only entry. The native backend executes
    /// from metadata alone (the HLO `file` is never read), so tests and
    /// benches build in-memory manifests with this instead of running
    /// `make artifacts` — one constructor keeps their entry schemas in
    /// sync with the real parser above.
    pub fn synthetic(
        name: &str,
        kind: &str,
        config: ModelConfig,
        param_count: usize,
        inputs: Vec<TensorSpec>,
        outputs: Vec<TensorSpec>,
        extra: &[(&str, i64)],
    ) -> Entry {
        let n_inputs = inputs.len();
        Entry {
            name: name.to_string(),
            file: PathBuf::from(format!("{name}.native-synthetic")),
            kind: kind.to_string(),
            param_count,
            inputs,
            outputs,
            config,
            extra: extra.iter().map(|(k, v)| ((*k).to_string(), *v)).collect(),
            init_file: None,
            kept_inputs: (0..n_inputs).collect(),
        }
    }

    /// Streaming-carry specs `(l, u)` for a config. Configs whose
    /// per-layer slots are the historical recurrence layout keep the
    /// legacy structured shapes `[layers, S, 2]` / `[layers, S, d, 2]`
    /// (so committed manifests and v2 checkpoints match spec-for-spec);
    /// anything else — adaptive gate state, linear attention — gets the
    /// flat `[layers, ll]` / `[layers, ul]` shapes from
    /// [`ModelConfig::carry_lens`]. The runtime only ever consumes the
    /// carries flattened, so both spell the same buffers.
    fn carry_specs(cfg: &ModelConfig) -> (TensorSpec, TensorSpec) {
        let (ly, s, d) = (cfg.n_layers, cfg.s_max, cfg.d_model);
        let (ll, ul) = cfg.carry_lens();
        let f = |sh: &[usize]| TensorSpec { dtype: DType::F32, shape: sh.to_vec() };
        if (ll, ul) == (s * 2, s * d * 2) {
            (f(&[ly, s, 2]), f(&[ly, s, d, 2]))
        } else {
            (f(&[ly, ll]), f(&[ly, ul]))
        }
    }

    /// [`Entry::synthetic`] for the `stream_step` kind, shapes derived
    /// from the config — the single source of truth for the serving
    /// entry schemas that tests and benches build in memory.
    pub fn synthetic_stream(cfg: &ModelConfig, p: usize, name: &str, chunk: usize) -> Entry {
        let f = |sh: &[usize]| TensorSpec { dtype: DType::F32, shape: sh.to_vec() };
        let i = |sh: &[usize]| TensorSpec { dtype: DType::I32, shape: sh.to_vec() };
        let (l, u) = Entry::carry_specs(cfg);
        Entry::synthetic(
            name,
            "stream_step",
            cfg.clone(),
            p,
            vec![f(&[p]), l.clone(), u.clone(), i(&[chunk]), i(&[chunk]), f(&[chunk])],
            vec![l, u, f(&[]), f(&[])],
            &[("chunk", chunk as i64)],
        )
    }

    /// [`Entry::synthetic`] for the `decode_step` kind.
    pub fn synthetic_decode(cfg: &ModelConfig, p: usize, name: &str) -> Entry {
        let f = |sh: &[usize]| TensorSpec { dtype: DType::F32, shape: sh.to_vec() };
        let i = |sh: &[usize]| TensorSpec { dtype: DType::I32, shape: sh.to_vec() };
        let (l, u) = Entry::carry_specs(cfg);
        Entry::synthetic(
            name,
            "decode_step",
            cfg.clone(),
            p,
            vec![f(&[p]), l.clone(), u.clone(), i(&[1])],
            vec![l, u, f(&[cfg.vocab])],
            &[],
        )
    }

    /// [`Entry::synthetic`] for the `stream_batch_step` kind (the
    /// server's feed-wave artifact, batch width `bsrv`).
    pub fn synthetic_stream_batch(
        cfg: &ModelConfig,
        p: usize,
        name: &str,
        chunk: usize,
        bsrv: usize,
    ) -> Entry {
        let f = |sh: &[usize]| TensorSpec { dtype: DType::F32, shape: sh.to_vec() };
        let i = |sh: &[usize]| TensorSpec { dtype: DType::I32, shape: sh.to_vec() };
        let (l, u) = Entry::carry_specs(cfg);
        let b = |spec: &TensorSpec| TensorSpec {
            dtype: spec.dtype,
            shape: std::iter::once(bsrv).chain(spec.shape.iter().copied()).collect(),
        };
        Entry::synthetic(
            name,
            "stream_batch_step",
            cfg.clone(),
            p,
            vec![
                f(&[p]),
                b(&l),
                b(&u),
                i(&[bsrv, chunk]),
                i(&[bsrv, chunk]),
                f(&[bsrv, chunk]),
                f(&[bsrv]),
            ],
            vec![b(&l), b(&u), f(&[bsrv]), f(&[bsrv])],
            &[("chunk", chunk as i64), ("batch_srv", bsrv as i64)],
        )
    }

    /// Derive the batched single-token decode entry from this
    /// `decode_step` entry: the `decode_batch` kind the continuous-
    /// batching server executes. A batch dimension `b` is prepended to
    /// the carries/token/logits and an `active` row mask [b] is added,
    /// mirroring how `stream_batch_step` extends `stream_step`:
    ///
    ///   (flat, l [b,…], u [b,…], tokens [b], active [b])
    ///     -> (l' [b,…], u' [b,…], logits [b, V])
    ///
    /// Rows with `active <= 0.5` are padding: their carries pass
    /// through untouched and their logits are zero. Derived here (not
    /// read from the manifest) so every existing manifest with a
    /// `decode_step` entry serves batched decode without regeneration;
    /// backends that cannot execute the kind (no AOT program exists for
    /// it) report so via `Backend::supports_kind` and the server falls
    /// back to per-row decode.
    pub fn to_decode_batch(&self, b: usize) -> Result<Entry> {
        if self.kind != "decode_step" {
            bail!("{}: kind '{}' cannot derive decode_batch", self.name, self.kind);
        }
        if b == 0 {
            bail!("{}: decode_batch batch size must be >= 1", self.name);
        }
        if self.inputs.len() < 4 || self.outputs.len() < 3 {
            bail!("{}: malformed decode_step specs", self.name);
        }
        let batched = |spec: &TensorSpec| TensorSpec {
            dtype: spec.dtype,
            shape: std::iter::once(b).chain(spec.shape.iter().copied()).collect(),
        };
        let mut e = self.clone();
        e.name = format!("{}.batch{b}", self.name);
        e.kind = "decode_batch".to_string();
        e.inputs = vec![
            self.inputs[0].clone(),        // flat [p]
            batched(&self.inputs[1]),      // l [b, layers, S, 2]
            batched(&self.inputs[2]),      // u [b, layers, S, d, 2]
            TensorSpec { dtype: DType::I32, shape: vec![b] },
            TensorSpec { dtype: DType::F32, shape: vec![b] },
        ];
        e.outputs = vec![
            batched(&self.outputs[0]),
            batched(&self.outputs[1]),
            batched(&self.outputs[2]), // logits [b, V]
        ];
        e.extra.insert("batch_srv".to_string(), b as i64);
        e.kept_inputs = (0..e.inputs.len()).collect();
        Ok(e)
    }

    /// Validate a set of host tensors against this entry's input specs.
    pub fn check_inputs(&self, tensors: &[crate::runtime::tensor::Tensor]) -> Result<()> {
        if tensors.len() != self.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.name,
                self.inputs.len(),
                tensors.len()
            );
        }
        for (i, (t, spec)) in tensors.iter().zip(&self.inputs).enumerate() {
            if t.dtype() != spec.dtype {
                bail!("{}: input {i} dtype mismatch", self.name);
            }
            if t.shape() != spec.shape.as_slice() {
                bail!(
                    "{}: input {i} shape {:?} != manifest {:?}",
                    self.name,
                    t.shape(),
                    spec.shape
                );
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    const SAMPLE: &str = r#"{"version":1,"entries":{
      "lm.train":{"file":"lm.train.hlo.txt","kind":"train_step","param_count":10,
        "inputs":[{"dtype":"float32","shape":[10]},{"dtype":"int32","shape":[2,3]}],
        "outputs":[{"dtype":"float32","shape":[10]},{"dtype":"float32","shape":[]}],
        "config":{"arch":"stlt","vocab":256,"d_model":64,"n_layers":2,"n_ctx":128,
                  "s_max":32,"batch":8,"adaptive":true,"mode":"linear","total_steps":2000,
                  "grad_ckpt_segment":512},
        "chunk":64}}}"#;

    #[test]
    fn parses_entries() {
        let dir = std::env::temp_dir().join("stlt_manifest_test1");
        write_manifest(&dir, SAMPLE);
        let m = Manifest::load(&dir).unwrap();
        let e = m.get("lm.train").unwrap();
        assert_eq!(e.kind, "train_step");
        assert_eq!(e.param_count, 10);
        assert_eq!(e.inputs[1].shape, vec![2, 3]);
        assert_eq!(e.outputs[1].shape, Vec::<usize>::new());
        assert_eq!(e.config.arch, "stlt");
        assert!(e.config.adaptive);
        assert_eq!(e.config.grad_ckpt_segment, 512);
        assert_eq!(e.extra["chunk"], 64);
        // absent from a manifest (every committed one) -> whole-sequence
        assert_eq!(ModelConfig::default().grad_ckpt_segment, 0);
    }

    #[test]
    fn missing_entry_helpful_error() {
        let dir = std::env::temp_dir().join("stlt_manifest_test2");
        write_manifest(&dir, SAMPLE);
        let m = Manifest::load(&dir).unwrap();
        let err = format!("{:#}", m.get("nope").unwrap_err());
        assert!(err.contains("make artifacts"));
    }

    #[test]
    fn input_validation() {
        use crate::runtime::tensor::Tensor;
        let dir = std::env::temp_dir().join("stlt_manifest_test3");
        write_manifest(&dir, SAMPLE);
        let m = Manifest::load(&dir).unwrap();
        let e = m.get("lm.train").unwrap();
        let good = vec![Tensor::f32(vec![0.0; 10], &[10]), Tensor::i32(vec![0; 6], &[2, 3])];
        assert!(e.check_inputs(&good).is_ok());
        let bad = vec![Tensor::f32(vec![0.0; 10], &[10]), Tensor::f32(vec![0.0; 6], &[2, 3])];
        assert!(e.check_inputs(&bad).is_err());
        assert!(e.check_inputs(&good[..1].to_vec()).is_err());
    }

    #[test]
    fn decode_batch_derivation() {
        let mut e = Entry {
            name: "m.decode".into(),
            file: PathBuf::from("m.decode.hlo.txt"),
            kind: "decode_step".into(),
            param_count: 10,
            inputs: vec![
                TensorSpec { dtype: DType::F32, shape: vec![10] },
                TensorSpec { dtype: DType::F32, shape: vec![2, 4, 2] },
                TensorSpec { dtype: DType::F32, shape: vec![2, 4, 8, 2] },
                TensorSpec { dtype: DType::I32, shape: vec![1] },
            ],
            outputs: vec![
                TensorSpec { dtype: DType::F32, shape: vec![2, 4, 2] },
                TensorSpec { dtype: DType::F32, shape: vec![2, 4, 8, 2] },
                TensorSpec { dtype: DType::F32, shape: vec![19] },
            ],
            config: ModelConfig::default(),
            extra: BTreeMap::new(),
            init_file: None,
            kept_inputs: vec![0, 1, 2, 3],
        };
        let b = e.to_decode_batch(4).unwrap();
        assert_eq!(b.kind, "decode_batch");
        assert_eq!(b.name, "m.decode.batch4");
        assert_eq!(b.inputs[1].shape, vec![4, 2, 4, 2]);
        assert_eq!(b.inputs[2].shape, vec![4, 2, 4, 8, 2]);
        assert_eq!(b.inputs[3].shape, vec![4]);
        assert_eq!(b.inputs[4].shape, vec![4]); // active mask
        assert_eq!(b.inputs[4].dtype, DType::F32);
        assert_eq!(b.outputs[2].shape, vec![4, 19]);
        assert_eq!(b.extra["batch_srv"], 4);
        assert!(b.to_decode_batch(2).is_err(), "only decode_step derives");
        assert!(e.to_decode_batch(0).is_err());
        e.kind = "stream_step".into();
        assert!(e.to_decode_batch(4).is_err());
    }

    fn sample_with_config(extra_cfg: &str) -> String {
        SAMPLE.replace("\"grad_ckpt_segment\":512", &format!("\"grad_ckpt_segment\":512,{extra_cfg}"))
    }

    #[test]
    fn adaptive_config_keys_parse_and_validate() {
        let dir = std::env::temp_dir().join("stlt_manifest_test5");
        // well-formed: every new key lands where it should
        write_manifest(
            &dir,
            &sample_with_config(
                "\"mixer\":\"linear_attention\",\"gumbel_temp_hi\":2.0,\
                 \"gumbel_temp_lo\":0.25,\"gumbel_anneal_frac\":0.5",
            ),
        );
        let m = Manifest::load(&dir).unwrap();
        let c = &m.get("lm.train").unwrap().config;
        assert_eq!(c.mixer, "linear_attention");
        assert_eq!(c.gumbel_temp_hi, 2.0);
        assert_eq!(c.gumbel_temp_lo, 0.25);
        assert_eq!(c.gumbel_anneal_frac, 0.5);
        // absent keys -> python-default schedule
        let d = ModelConfig::default();
        assert_eq!((d.gumbel_temp_hi, d.gumbel_temp_lo, d.gumbel_anneal_frac), (1.0, 0.1, 0.4));
        // malformed values must fail the whole load with a pointed error
        for (bad, needle) in [
            ("\"mixer\":\"softmax\"", "unknown mixer"),
            ("\"mixer\":7", "must be a string"),
            ("\"gumbel_temp_hi\":\"hot\"", "must be a number"),
            ("\"gumbel_temp_lo\":-0.5", "finite positive"),
            ("\"gumbel_temp_lo\":0.0", "finite positive"),
            ("\"gumbel_temp_hi\":0.05", "must not exceed"),
            ("\"gumbel_anneal_frac\":1.5", "must be in (0, 1]"),
        ] {
            write_manifest(&dir, &sample_with_config(bad));
            let err = format!("{:#}", Manifest::load(&dir).unwrap_err());
            assert!(err.contains(needle), "{bad}: expected '{needle}' in: {err}");
            assert!(err.contains("lm.train"), "{bad}: error should name the entry: {err}");
        }
    }

    #[test]
    fn carry_lens_track_mixer_and_gate() {
        let mut c = ModelConfig { s_max: 4, d_model: 8, n_layers: 2, ..ModelConfig::default() };
        assert_eq!(c.carry_lens(), (8, 64), "recurrence: (S*2, S*d*2)");
        c.adaptive = true;
        assert_eq!(c.carry_lens(), (8 + 9, 64), "gate appends (pool_sum d, count)");
        c.mixer = "linear_attention".into();
        assert_eq!(c.state_lens(), (4, 32), "linattn: (S, S*d)");
        assert_eq!(c.carry_lens(), (4 + 9, 32));
        // entry builders follow: legacy structured shapes only for the
        // historical recurrence layout, flat [ly, len] otherwise
        c.adaptive = false;
        c.mixer = String::new();
        let e = Entry::synthetic_decode(&c, 10, "m.decode");
        assert_eq!(e.inputs[1].shape, vec![2, 4, 2]);
        assert_eq!(e.inputs[2].shape, vec![2, 4, 8, 2]);
        c.adaptive = true;
        let e = Entry::synthetic_decode(&c, 10, "m.decode");
        assert_eq!(e.inputs[1].shape, vec![2, 17]);
        assert_eq!(e.inputs[2].shape, vec![2, 64]);
        let e = Entry::synthetic_stream_batch(&c, 10, "m.srv", 8, 3);
        assert_eq!(e.inputs[1].shape, vec![3, 2, 17]);
        assert_eq!(e.outputs[1].shape, vec![3, 2, 64]);
    }

    #[test]
    fn by_kind_filters() {
        let dir = std::env::temp_dir().join("stlt_manifest_test4");
        write_manifest(&dir, SAMPLE);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.by_kind("train_step").len(), 1);
        assert_eq!(m.by_kind("forward").len(), 0);
    }
}
