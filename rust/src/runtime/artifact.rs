//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the Rust runtime. Parses artifacts/manifest.json (via util::json) into
//! typed entries with input/output specs and the originating ModelConfig.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::runtime::tensor::DType;
use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// Subset of the python ModelConfig the Rust side needs. The native
/// backend additionally consumes the STLT numeric hyperparameters
/// (ffn_mult, sigma_min, t_init, omega_zero) and — since the native
/// `train_step` landed — the optimiser/regulariser hyperparameters
/// (lr, warmup, betas, weight_decay, grad_clip, lambda_*, learn_*);
/// all default to the python `ModelConfig` defaults when absent from
/// older manifests.
#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub arch: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_ctx: usize,
    pub s_max: usize,
    pub batch: usize,
    pub adaptive: bool,
    pub mode: String,
    pub total_steps: u64,
    pub ffn_mult: usize,
    pub sigma_min: f32,
    pub t_init: f32,
    pub omega_zero: bool,
    // --- ablation stop-gradients (python: learn_sigma/learn_omega/learn_t)
    pub learn_sigma: bool,
    pub learn_omega: bool,
    pub learn_t: bool,
    // --- Eq. Reg penalty weights
    pub lambda_omega: f32,
    pub lambda_sigma: f32,
    pub lambda_mask: f32,
    // --- optimiser (python/compile/optim.py semantics)
    pub lr: f32,
    pub warmup: u64,
    pub weight_decay: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub grad_clip: f32,
    /// Native-backward gradient-checkpoint segment length (tokens).
    /// 0 = whole sequence (one segment): the backward's activation tape
    /// holds the full O(N·S·d) per-layer U history, exactly the pre-
    /// checkpointing behaviour. A positive value C stores only the
    /// (L, U) carry at every C-token boundary and replays each
    /// segment's tape on the fly during the backward, cutting the peak
    /// tape to O(C·S·d + (N/C)·S·d) per layer. Gradients are bitwise
    /// identical for every value (tests/native_train.rs). Native-only;
    /// the XLA backward ignores it.
    pub grad_ckpt_segment: usize,
}

impl Default for ModelConfig {
    fn default() -> Self {
        ModelConfig {
            arch: String::new(),
            vocab: 0,
            d_model: 0,
            n_layers: 0,
            n_ctx: 0,
            s_max: 0,
            batch: 0,
            adaptive: false,
            mode: String::new(),
            // python config.py defaults
            total_steps: 2000,
            ffn_mult: 4,
            sigma_min: 1e-3,
            t_init: 32.0,
            omega_zero: false,
            learn_sigma: true,
            learn_omega: true,
            learn_t: true,
            lambda_omega: 1e-4,
            lambda_sigma: 1e-4,
            lambda_mask: 1e-3,
            lr: 3e-4,
            warmup: 100,
            weight_decay: 0.01,
            beta1: 0.9,
            beta2: 0.98,
            grad_clip: 1.0,
            grad_ckpt_segment: 0,
        }
    }
}

#[derive(Clone, Debug)]
pub struct Entry {
    pub name: String,
    pub file: PathBuf,
    pub kind: String,
    pub param_count: usize,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub config: ModelConfig,
    /// extra ints (chunk, n_src, m_tgt ...)
    pub extra: BTreeMap<String, i64>,
    /// python-exact packed init vector (raw LE f32), if the entry has one
    pub init_file: Option<PathBuf>,
    /// indices of inputs that survived jax's unused-argument pruning;
    /// the runtime filters its argument list to exactly these.
    pub kept_inputs: Vec<usize>,
}

#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: BTreeMap<String, Entry>,
}

fn parse_spec(j: &Json) -> Result<TensorSpec> {
    let dtype = DType::from_name(
        j.get("dtype").and_then(|d| d.as_str()).ok_or_else(|| anyhow!("spec missing dtype"))?,
    )
    .map_err(|e| anyhow!("{e}"))?;
    let shape = j
        .get("shape")
        .and_then(|s| s.as_arr())
        .ok_or_else(|| anyhow!("spec missing shape"))?
        .iter()
        .map(|v| v.as_i64().map(|x| x as usize).ok_or_else(|| anyhow!("bad dim")))
        .collect::<Result<Vec<_>>>()?;
    Ok(TensorSpec { dtype, shape })
}

fn parse_config(j: Option<&Json>) -> ModelConfig {
    let mut c = ModelConfig::default();
    if let Some(j) = j {
        let s = |k: &str| j.get(k).and_then(|v| v.as_str()).unwrap_or("").to_string();
        let i = |k: &str| j.get(k).and_then(|v| v.as_i64()).unwrap_or(0);
        let b = |k: &str| j.get(k).and_then(|v| v.as_bool()).unwrap_or(false);
        c.arch = s("arch");
        c.vocab = i("vocab") as usize;
        c.d_model = i("d_model") as usize;
        c.n_layers = i("n_layers") as usize;
        c.n_ctx = i("n_ctx") as usize;
        c.s_max = i("s_max") as usize;
        c.batch = i("batch") as usize;
        c.adaptive = b("adaptive");
        c.mode = s("mode");
        if let Some(ts) = j.get("total_steps").and_then(|v| v.as_i64()) {
            c.total_steps = ts as u64;
        }
        if let Some(fm) = j.get("ffn_mult").and_then(|v| v.as_i64()) {
            if fm > 0 {
                c.ffn_mult = fm as usize;
            }
        }
        if let Some(sm) = j.get("sigma_min").and_then(|v| v.as_f64()) {
            c.sigma_min = sm as f32;
        }
        if let Some(ti) = j.get("t_init").and_then(|v| v.as_f64()) {
            c.t_init = ti as f32;
        }
        if let Some(oz) = j.get("omega_zero").and_then(|v| v.as_bool()) {
            c.omega_zero = oz;
        }
        // optional keys default to the python values, so absent keys must
        // not clobber them (notably learn_* default to true)
        let bopt = |k: &str, dst: &mut bool| {
            if let Some(v) = j.get(k).and_then(|v| v.as_bool()) {
                *dst = v;
            }
        };
        bopt("learn_sigma", &mut c.learn_sigma);
        bopt("learn_omega", &mut c.learn_omega);
        bopt("learn_t", &mut c.learn_t);
        let fopt = |k: &str, dst: &mut f32| {
            if let Some(v) = j.get(k).and_then(|v| v.as_f64()) {
                *dst = v as f32;
            }
        };
        fopt("lambda_omega", &mut c.lambda_omega);
        fopt("lambda_sigma", &mut c.lambda_sigma);
        fopt("lambda_mask", &mut c.lambda_mask);
        fopt("lr", &mut c.lr);
        fopt("weight_decay", &mut c.weight_decay);
        fopt("beta1", &mut c.beta1);
        fopt("beta2", &mut c.beta2);
        fopt("grad_clip", &mut c.grad_clip);
        if let Some(w) = j.get("warmup").and_then(|v| v.as_i64()) {
            c.warmup = w as u64;
        }
        if let Some(g) = j.get("grad_ckpt_segment").and_then(|v| v.as_i64()) {
            if g > 0 {
                c.grad_ckpt_segment = g as usize;
            }
        }
    }
    c
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
        let entries_j = j
            .get("entries")
            .and_then(|e| e.as_obj())
            .ok_or_else(|| anyhow!("manifest missing entries"))?;
        let mut entries = BTreeMap::new();
        for (name, e) in entries_j {
            let inputs = e
                .get("inputs")
                .and_then(|x| x.as_arr())
                .ok_or_else(|| anyhow!("{name}: missing inputs"))?
                .iter()
                .map(parse_spec)
                .collect::<Result<Vec<_>>>()?;
            let outputs = e
                .get("outputs")
                .and_then(|x| x.as_arr())
                .ok_or_else(|| anyhow!("{name}: missing outputs"))?
                .iter()
                .map(parse_spec)
                .collect::<Result<Vec<_>>>()?;
            let mut extra = BTreeMap::new();
            for k in ["chunk", "n_src", "m_tgt", "batch_srv"] {
                if let Some(v) = e.get(k).and_then(|v| v.as_i64()) {
                    extra.insert(k.to_string(), v);
                }
            }
            let n_inputs = inputs.len();
            entries.insert(
                name.clone(),
                Entry {
                    name: name.clone(),
                    file: dir.join(
                        e.get("file")
                            .and_then(|f| f.as_str())
                            .ok_or_else(|| anyhow!("{name}: missing file"))?,
                    ),
                    kind: e.get("kind").and_then(|k| k.as_str()).unwrap_or("").to_string(),
                    param_count: e.get("param_count").and_then(|p| p.as_i64()).unwrap_or(0)
                        as usize,
                    inputs,
                    outputs,
                    config: parse_config(e.get("config")),
                    extra,
                    init_file: e
                        .get("init")
                        .and_then(|v| v.as_str())
                        .map(|f| dir.join(f)),
                    kept_inputs: e
                        .get("kept_inputs")
                        .and_then(|v| v.as_arr())
                        .map(|a| a.iter().filter_map(|x| x.as_i64()).map(|x| x as usize).collect())
                        .unwrap_or_else(|| (0..n_inputs).collect()),
                },
            );
        }
        Ok(Manifest { dir, entries })
    }

    pub fn get(&self, name: &str) -> Result<&Entry> {
        self.entries.get(name).ok_or_else(|| {
            anyhow!(
                "artifact '{name}' not in manifest ({} entries; run `make artifacts`)",
                self.entries.len()
            )
        })
    }

    /// Entries with a given kind, sorted by name.
    pub fn by_kind(&self, kind: &str) -> Vec<&Entry> {
        self.entries.values().filter(|e| e.kind == kind).collect()
    }
}

/// Locate the artifacts dir: $STLT_ARTIFACTS or ./artifacts upward.
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("STLT_ARTIFACTS") {
        return PathBuf::from(p);
    }
    let mut cur = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        let cand = cur.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !cur.pop() {
            return PathBuf::from("artifacts");
        }
    }
}

impl Entry {
    /// Synthesize a metadata-only entry. The native backend executes
    /// from metadata alone (the HLO `file` is never read), so tests and
    /// benches build in-memory manifests with this instead of running
    /// `make artifacts` — one constructor keeps their entry schemas in
    /// sync with the real parser above.
    pub fn synthetic(
        name: &str,
        kind: &str,
        config: ModelConfig,
        param_count: usize,
        inputs: Vec<TensorSpec>,
        outputs: Vec<TensorSpec>,
        extra: &[(&str, i64)],
    ) -> Entry {
        let n_inputs = inputs.len();
        Entry {
            name: name.to_string(),
            file: PathBuf::from(format!("{name}.native-synthetic")),
            kind: kind.to_string(),
            param_count,
            inputs,
            outputs,
            config,
            extra: extra.iter().map(|(k, v)| ((*k).to_string(), *v)).collect(),
            init_file: None,
            kept_inputs: (0..n_inputs).collect(),
        }
    }

    /// [`Entry::synthetic`] for the `stream_step` kind, shapes derived
    /// from the config — the single source of truth for the serving
    /// entry schemas that tests and benches build in memory.
    pub fn synthetic_stream(cfg: &ModelConfig, p: usize, name: &str, chunk: usize) -> Entry {
        let (ly, s, d) = (cfg.n_layers, cfg.s_max, cfg.d_model);
        let f = |sh: &[usize]| TensorSpec { dtype: DType::F32, shape: sh.to_vec() };
        let i = |sh: &[usize]| TensorSpec { dtype: DType::I32, shape: sh.to_vec() };
        Entry::synthetic(
            name,
            "stream_step",
            cfg.clone(),
            p,
            vec![
                f(&[p]),
                f(&[ly, s, 2]),
                f(&[ly, s, d, 2]),
                i(&[chunk]),
                i(&[chunk]),
                f(&[chunk]),
            ],
            vec![f(&[ly, s, 2]), f(&[ly, s, d, 2]), f(&[]), f(&[])],
            &[("chunk", chunk as i64)],
        )
    }

    /// [`Entry::synthetic`] for the `decode_step` kind.
    pub fn synthetic_decode(cfg: &ModelConfig, p: usize, name: &str) -> Entry {
        let (ly, s, d) = (cfg.n_layers, cfg.s_max, cfg.d_model);
        let f = |sh: &[usize]| TensorSpec { dtype: DType::F32, shape: sh.to_vec() };
        let i = |sh: &[usize]| TensorSpec { dtype: DType::I32, shape: sh.to_vec() };
        Entry::synthetic(
            name,
            "decode_step",
            cfg.clone(),
            p,
            vec![f(&[p]), f(&[ly, s, 2]), f(&[ly, s, d, 2]), i(&[1])],
            vec![f(&[ly, s, 2]), f(&[ly, s, d, 2]), f(&[cfg.vocab])],
            &[],
        )
    }

    /// [`Entry::synthetic`] for the `stream_batch_step` kind (the
    /// server's feed-wave artifact, batch width `bsrv`).
    pub fn synthetic_stream_batch(
        cfg: &ModelConfig,
        p: usize,
        name: &str,
        chunk: usize,
        bsrv: usize,
    ) -> Entry {
        let (ly, s, d) = (cfg.n_layers, cfg.s_max, cfg.d_model);
        let f = |sh: &[usize]| TensorSpec { dtype: DType::F32, shape: sh.to_vec() };
        let i = |sh: &[usize]| TensorSpec { dtype: DType::I32, shape: sh.to_vec() };
        Entry::synthetic(
            name,
            "stream_batch_step",
            cfg.clone(),
            p,
            vec![
                f(&[p]),
                f(&[bsrv, ly, s, 2]),
                f(&[bsrv, ly, s, d, 2]),
                i(&[bsrv, chunk]),
                i(&[bsrv, chunk]),
                f(&[bsrv, chunk]),
                f(&[bsrv]),
            ],
            vec![
                f(&[bsrv, ly, s, 2]),
                f(&[bsrv, ly, s, d, 2]),
                f(&[bsrv]),
                f(&[bsrv]),
            ],
            &[("chunk", chunk as i64), ("batch_srv", bsrv as i64)],
        )
    }

    /// Derive the batched single-token decode entry from this
    /// `decode_step` entry: the `decode_batch` kind the continuous-
    /// batching server executes. A batch dimension `b` is prepended to
    /// the carries/token/logits and an `active` row mask [b] is added,
    /// mirroring how `stream_batch_step` extends `stream_step`:
    ///
    ///   (flat, l [b,…], u [b,…], tokens [b], active [b])
    ///     -> (l' [b,…], u' [b,…], logits [b, V])
    ///
    /// Rows with `active <= 0.5` are padding: their carries pass
    /// through untouched and their logits are zero. Derived here (not
    /// read from the manifest) so every existing manifest with a
    /// `decode_step` entry serves batched decode without regeneration;
    /// backends that cannot execute the kind (no AOT program exists for
    /// it) report so via `Backend::supports_kind` and the server falls
    /// back to per-row decode.
    pub fn to_decode_batch(&self, b: usize) -> Result<Entry> {
        if self.kind != "decode_step" {
            bail!("{}: kind '{}' cannot derive decode_batch", self.name, self.kind);
        }
        if b == 0 {
            bail!("{}: decode_batch batch size must be >= 1", self.name);
        }
        if self.inputs.len() < 4 || self.outputs.len() < 3 {
            bail!("{}: malformed decode_step specs", self.name);
        }
        let batched = |spec: &TensorSpec| TensorSpec {
            dtype: spec.dtype,
            shape: std::iter::once(b).chain(spec.shape.iter().copied()).collect(),
        };
        let mut e = self.clone();
        e.name = format!("{}.batch{b}", self.name);
        e.kind = "decode_batch".to_string();
        e.inputs = vec![
            self.inputs[0].clone(),        // flat [p]
            batched(&self.inputs[1]),      // l [b, layers, S, 2]
            batched(&self.inputs[2]),      // u [b, layers, S, d, 2]
            TensorSpec { dtype: DType::I32, shape: vec![b] },
            TensorSpec { dtype: DType::F32, shape: vec![b] },
        ];
        e.outputs = vec![
            batched(&self.outputs[0]),
            batched(&self.outputs[1]),
            batched(&self.outputs[2]), // logits [b, V]
        ];
        e.extra.insert("batch_srv".to_string(), b as i64);
        e.kept_inputs = (0..e.inputs.len()).collect();
        Ok(e)
    }

    /// Validate a set of host tensors against this entry's input specs.
    pub fn check_inputs(&self, tensors: &[crate::runtime::tensor::Tensor]) -> Result<()> {
        if tensors.len() != self.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.name,
                self.inputs.len(),
                tensors.len()
            );
        }
        for (i, (t, spec)) in tensors.iter().zip(&self.inputs).enumerate() {
            if t.dtype() != spec.dtype {
                bail!("{}: input {i} dtype mismatch", self.name);
            }
            if t.shape() != spec.shape.as_slice() {
                bail!(
                    "{}: input {i} shape {:?} != manifest {:?}",
                    self.name,
                    t.shape(),
                    spec.shape
                );
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    const SAMPLE: &str = r#"{"version":1,"entries":{
      "lm.train":{"file":"lm.train.hlo.txt","kind":"train_step","param_count":10,
        "inputs":[{"dtype":"float32","shape":[10]},{"dtype":"int32","shape":[2,3]}],
        "outputs":[{"dtype":"float32","shape":[10]},{"dtype":"float32","shape":[]}],
        "config":{"arch":"stlt","vocab":256,"d_model":64,"n_layers":2,"n_ctx":128,
                  "s_max":32,"batch":8,"adaptive":true,"mode":"linear","total_steps":2000,
                  "grad_ckpt_segment":512},
        "chunk":64}}}"#;

    #[test]
    fn parses_entries() {
        let dir = std::env::temp_dir().join("stlt_manifest_test1");
        write_manifest(&dir, SAMPLE);
        let m = Manifest::load(&dir).unwrap();
        let e = m.get("lm.train").unwrap();
        assert_eq!(e.kind, "train_step");
        assert_eq!(e.param_count, 10);
        assert_eq!(e.inputs[1].shape, vec![2, 3]);
        assert_eq!(e.outputs[1].shape, Vec::<usize>::new());
        assert_eq!(e.config.arch, "stlt");
        assert!(e.config.adaptive);
        assert_eq!(e.config.grad_ckpt_segment, 512);
        assert_eq!(e.extra["chunk"], 64);
        // absent from a manifest (every committed one) -> whole-sequence
        assert_eq!(ModelConfig::default().grad_ckpt_segment, 0);
    }

    #[test]
    fn missing_entry_helpful_error() {
        let dir = std::env::temp_dir().join("stlt_manifest_test2");
        write_manifest(&dir, SAMPLE);
        let m = Manifest::load(&dir).unwrap();
        let err = format!("{:#}", m.get("nope").unwrap_err());
        assert!(err.contains("make artifacts"));
    }

    #[test]
    fn input_validation() {
        use crate::runtime::tensor::Tensor;
        let dir = std::env::temp_dir().join("stlt_manifest_test3");
        write_manifest(&dir, SAMPLE);
        let m = Manifest::load(&dir).unwrap();
        let e = m.get("lm.train").unwrap();
        let good = vec![Tensor::f32(vec![0.0; 10], &[10]), Tensor::i32(vec![0; 6], &[2, 3])];
        assert!(e.check_inputs(&good).is_ok());
        let bad = vec![Tensor::f32(vec![0.0; 10], &[10]), Tensor::f32(vec![0.0; 6], &[2, 3])];
        assert!(e.check_inputs(&bad).is_err());
        assert!(e.check_inputs(&good[..1].to_vec()).is_err());
    }

    #[test]
    fn decode_batch_derivation() {
        let mut e = Entry {
            name: "m.decode".into(),
            file: PathBuf::from("m.decode.hlo.txt"),
            kind: "decode_step".into(),
            param_count: 10,
            inputs: vec![
                TensorSpec { dtype: DType::F32, shape: vec![10] },
                TensorSpec { dtype: DType::F32, shape: vec![2, 4, 2] },
                TensorSpec { dtype: DType::F32, shape: vec![2, 4, 8, 2] },
                TensorSpec { dtype: DType::I32, shape: vec![1] },
            ],
            outputs: vec![
                TensorSpec { dtype: DType::F32, shape: vec![2, 4, 2] },
                TensorSpec { dtype: DType::F32, shape: vec![2, 4, 8, 2] },
                TensorSpec { dtype: DType::F32, shape: vec![19] },
            ],
            config: ModelConfig::default(),
            extra: BTreeMap::new(),
            init_file: None,
            kept_inputs: vec![0, 1, 2, 3],
        };
        let b = e.to_decode_batch(4).unwrap();
        assert_eq!(b.kind, "decode_batch");
        assert_eq!(b.name, "m.decode.batch4");
        assert_eq!(b.inputs[1].shape, vec![4, 2, 4, 2]);
        assert_eq!(b.inputs[2].shape, vec![4, 2, 4, 8, 2]);
        assert_eq!(b.inputs[3].shape, vec![4]);
        assert_eq!(b.inputs[4].shape, vec![4]); // active mask
        assert_eq!(b.inputs[4].dtype, DType::F32);
        assert_eq!(b.outputs[2].shape, vec![4, 19]);
        assert_eq!(b.extra["batch_srv"], 4);
        assert!(b.to_decode_batch(2).is_err(), "only decode_step derives");
        assert!(e.to_decode_batch(0).is_err());
        e.kind = "stream_step".into();
        assert!(e.to_decode_batch(4).is_err());
    }

    #[test]
    fn by_kind_filters() {
        let dir = std::env::temp_dir().join("stlt_manifest_test4");
        write_manifest(&dir, SAMPLE);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.by_kind("train_step").len(), 1);
        assert_eq!(m.by_kind("forward").len(), 0);
    }
}
