//! PJRT runtime: loads `artifacts/*.hlo.txt` (AOT-lowered by
//! python/compile/aot.py) and executes them on the XLA CPU client.
//! Python never runs on this path.

pub mod artifact;
pub mod client;
pub mod exec;
pub mod tensor;

pub use artifact::{default_artifacts_dir, Manifest};
pub use client::Runtime;
pub use exec::{
    DecodeStep, EvalStep, Forward, S2sDecode, S2sTrainStep, StepMetrics, StreamCarry,
    StreamStep, TrainState, TrainStep,
};
pub use tensor::{DType, Tensor};
