//! Backend-agnostic runtime: loads `artifacts/manifest.json` entries and
//! executes them on a pluggable [`backend::Backend`].
//!
//! * [`backend::NativeBackend`] (default feature `native`): the STLT
//!   forward / streaming / decode / CE-eval paths run directly in Rust
//!   ([`native_stlt`]) from the flat parameter vector — no XLA, no
//!   Python at run time.
//! * `backend::XlaBackend` (feature `xla`): executes the AOT-lowered
//!   HLO text (`python/compile/aot.py`) on the PJRT CPU client; the
//!   only module touching `xla::` types.

pub mod artifact;
pub mod backend;
pub mod client;
pub mod exec;
#[cfg(feature = "native")]
pub mod mixer;
#[cfg(feature = "native")]
pub mod native_stlt;
pub mod tensor;

pub use artifact::{default_artifacts_dir, Manifest};
pub use backend::{Backend, BackendKind, DeviceBuffer, Executable};
pub use client::Runtime;
pub use exec::{
    BatchedDecodeStep, DecodeStep, EvalStep, Forward, S2sDecode, S2sTrainStep, StepMetrics,
    StreamCarry, StreamStep, TrainState, TrainStep,
};
#[cfg(feature = "native")]
pub use mixer::{mixer_from_config, Mixer};
#[cfg(feature = "native")]
pub use native_stlt::StltModel;
pub use tensor::{DType, Tensor};
