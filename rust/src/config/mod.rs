//! Experiment configuration: a from-scratch TOML-subset parser plus the
//! typed configs the coordinator and experiment harnesses consume.
//!
//! Supported syntax: `[section.sub]` headers, `key = value` with string
//! ("..."), integer, float, bool, and flat arrays of those. Comments (#)
//! and blank lines are ignored. This covers every config in configs/.

use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parsed config: flat map from "section.key" to Value.
#[derive(Clone, Debug, Default)]
pub struct Config {
    pub values: BTreeMap<String, Value>,
}

impl Config {
    pub fn parse(text: &str) -> Result<Config, String> {
        let mut section = String::new();
        let mut values = BTreeMap::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') {
                    return Err(format!("line {}: bad section header", lineno + 1));
                }
                section = line[1..line.len() - 1].trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{}.{}", section, k.trim())
            };
            let val = parse_value(v.trim())
                .map_err(|e| format!("line {}: {}", lineno + 1, e))?;
            values.insert(key, val);
        }
        Ok(Config { values })
    }

    pub fn load(path: &str) -> Result<Config, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        Config::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.values.get(key)
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).and_then(|v| v.as_str()).unwrap_or(default).to_string()
    }

    pub fn i64_or(&self, key: &str, default: i64) -> i64 {
        self.get(key).and_then(|v| v.as_i64()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }

    /// Override from CLI-style "section.key=value" strings.
    pub fn apply_overrides(&mut self, overrides: &[String]) -> Result<(), String> {
        for o in overrides {
            let (k, v) = o.split_once('=').ok_or_else(|| format!("bad override '{o}'"))?;
            self.values.insert(k.trim().to_string(), parse_value(v.trim())?);
        }
        Ok(())
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if s.starts_with('"') && s.ends_with('"') && s.len() >= 2 {
        return Ok(Value::Str(s[1..s.len() - 1].to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if s.starts_with('[') && s.ends_with(']') {
        let inner = &s[1..s.len() - 1];
        let mut items = Vec::new();
        if !inner.trim().is_empty() {
            for part in inner.split(',') {
                items.push(parse_value(part.trim())?);
            }
        }
        return Ok(Value::Arr(items));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value '{s}'"))
}

/// Typed training-run config consumed by coordinator::trainer.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub artifact: String,
    pub steps: u64,
    pub eval_every: u64,
    pub eval_batches: u64,
    pub seed: u64,
    pub log_every: u64,
    pub checkpoint: Option<String>,
    pub corpus_seed: u64,
    pub corpus_domain: String,
}

impl TrainConfig {
    pub fn from_config(c: &Config) -> TrainConfig {
        TrainConfig {
            artifact: c.str_or("train.artifact", "lm_stlt_tiny"),
            steps: c.i64_or("train.steps", 300) as u64,
            eval_every: c.i64_or("train.eval_every", 100) as u64,
            eval_batches: c.i64_or("train.eval_batches", 8) as u64,
            seed: c.i64_or("train.seed", 0) as u64,
            log_every: c.i64_or("train.log_every", 20) as u64,
            checkpoint: c.get("train.checkpoint").and_then(|v| v.as_str()).map(String::from),
            corpus_seed: c.i64_or("data.seed", 1234) as u64,
            corpus_domain: c.str_or("data.domain", "default"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_sections_and_types() {
        let c = Config::parse(
            r#"
# experiment
[train]
steps = 500
lr = 0.0003          # comment after value
artifact = "lm_stlt_tiny"
resume = false
[data]
sizes = [1, 2, 3]
"#,
        )
        .unwrap();
        assert_eq!(c.i64_or("train.steps", 0), 500);
        assert!((c.f64_or("train.lr", 0.0) - 3e-4).abs() < 1e-12);
        assert_eq!(c.str_or("train.artifact", ""), "lm_stlt_tiny");
        assert!(!c.bool_or("train.resume", true));
        match c.get("data.sizes").unwrap() {
            Value::Arr(v) => assert_eq!(v.len(), 3),
            _ => panic!("not array"),
        }
    }

    #[test]
    fn overrides() {
        let mut c = Config::parse("[a]\nx = 1\n").unwrap();
        c.apply_overrides(&["a.x=9".to_string(), "a.y=\"z\"".to_string()]).unwrap();
        assert_eq!(c.i64_or("a.x", 0), 9);
        assert_eq!(c.str_or("a.y", ""), "z");
    }

    #[test]
    fn errors_are_located() {
        let e = Config::parse("[a\n").unwrap_err();
        assert!(e.contains("line 1"));
        let e = Config::parse("[a]\nnovalue\n").unwrap_err();
        assert!(e.contains("line 2"));
    }

    #[test]
    fn hash_inside_string_kept() {
        let c = Config::parse("k = \"a#b\"\n").unwrap();
        assert_eq!(c.str_or("k", ""), "a#b");
    }

    #[test]
    fn ints_promote_to_float() {
        let c = Config::parse("k = 3\n").unwrap();
        assert_eq!(c.f64_or("k", 0.0), 3.0);
    }

    #[test]
    fn typed_train_config_defaults() {
        let c = Config::parse("").unwrap();
        let t = TrainConfig::from_config(&c);
        assert_eq!(t.artifact, "lm_stlt_tiny");
        assert_eq!(t.steps, 300);
        assert!(t.checkpoint.is_none());
    }
}
