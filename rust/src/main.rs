//! `stlt` CLI — leader entrypoint for the laplace-stlt coordinator.
//!
//! Subcommands:
//!   info                      list artifacts + runtime info
//!   train   --artifact NAME --steps N [--ckpt PATH] [--resume PATH]
//!           [--grad-ckpt C] [--set k=v ...]
//!           [--adaptive true|false] [--mixer NAME]
//!           (--adaptive/--mixer override the model family for this
//!           invocation on every subcommand: adaptive node allocation
//!           with Gumbel-sigmoid training, and the token mixer —
//!           recurrence | reference_n2 | linear_attention)
//!   eval    --artifact NAME [--ckpt PATH] [--noise X]
//!   stream  --artifact NAME [--ckpt PATH] --doc-len N   streaming PPL demo
//!   generate --artifact NAME [--ckpt PATH] --len N
//!   serve   --artifact NAME [--sessions N] [--prompt-len N] [--gen-len N]
//!           [--connect ADDR]
//!           continuous-batching demo: N concurrent sessions feed +
//!           stream generations through the session API, reporting
//!           aggregate tokens/s and first-token latency. With
//!           --connect the same workload drives a remote worker or
//!           router over the wire protocol instead of an in-process
//!           server (see `stlt::net`).
//!   worker  --artifact NAME --listen ADDR [--max-sessions N] [--queue-cap N]
//!           host one continuous-batching Server behind the binary
//!           wire protocol (ADDR: host:port or unix:/path)
//!   router  --listen ADDR --workers ADDR[,ADDR...]
//!           front-end: hash-routes sessions across workers, speaks
//!           the same wire protocol to clients, migrates carries
//!   stats   --connect ADDR
//!           fetch a live metrics snapshot (exposition text) from a
//!           worker or router over the wire protocol
//!   inspect --artifact NAME [--ckpt PATH]               learned-parameter dump
//!   lint    [--root DIR] [--deep [--lock-graph FILE]]
//!           concurrency-hygiene lint over DIR/src (default: `rust`
//!           when run from the repo root): SAFETY/ORDERING comment
//!           discipline, unwrap/static-mut bans, std::sync facade
//!           enforcement — see `stlt::lint`. `--deep` adds the
//!           call-graph tier: alloc-free / non-blocking / panic-free
//!           hot paths from the declared roots, bitwise-determinism
//!           rules, and the static lock-order graph (cycles fail;
//!           `--lock-graph FILE` writes the graph JSON). Ledgers:
//!           DIR/lint.allow and DIR/lint_deep.allow. Exit 1 on
//!           violations.
//!
//! Observability: metrics are on by default (`STLT_METRICS=0` to
//! disable); `--metrics-every N` logs a one-line digest every N seconds
//! (serve) or steps (train); `--trace FILE` (serve) writes Chrome
//! trace-event JSON for Perfetto.
//!
//! `--backend native|xla` selects the execution substrate (default:
//! native — pure Rust, no XLA/PJRT needed). Every subcommand including
//! `train` runs on either backend: the native path differentiates the
//! STLT stack by hand and runs a pure-Rust AdamW (`stlt::train`), the
//! xla path executes the AOT optimiser graph inside the lowered HLO.
//!
//! Checkpoints record the artifact they were trained for; loading one
//! against a different artifact or parameter count fails with a clear
//! error. When `--ckpt` is omitted, inference subcommands fall back to
//! the artifact's init vector (untrained weights).

use anyhow::{anyhow, Result};
use stlt::config::Config;
use stlt::coordinator::{self, ServerOpts, TrainOpts};
use stlt::runtime::{default_artifacts_dir, BackendKind, Manifest, Runtime};
use stlt::util::cli::Args;
use stlt::util::sync::Arc;

fn main() {
    stlt::util::logging::init();
    stlt::obs::init_from_env();
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() -> String {
    "usage: stlt <info|train|eval|stream|generate|serve|worker|router|stats|inspect|lint> \
     [--backend native|xla] \
     [--artifact NAME] [--steps N] [--ckpt PATH] [--resume PATH] [--config FILE] \
     [--set key=value ...] [--grad-ckpt C] \
     [--adaptive true|false] [--mixer recurrence|reference_n2|linear_attention] \
     [--noise X] [--len N] [--doc-len N] \
     [--sessions N] [--prompt-len N] [--gen-len N] \
     [--sampling greedy|temp:T|topk:K:T|topp:P:T] \
     [--connect ADDR] [--listen ADDR] [--workers ADDR,...] \
     [--max-sessions N] [--queue-cap N] \
     [--metrics-every N] [--trace FILE] \
     [--root DIR] [--deep] [--lock-graph FILE]"
        .to_string()
}

/// Apply the `--adaptive true|false` / `--mixer NAME` model overrides
/// to every stlt entry in the loaded manifest (this invocation only —
/// nothing is written back to disk). Every subcommand honours them, so
/// the same flags select the model family for training, eval, serving
/// and the worker. Because flipping `adaptive` changes the parameter
/// layout (the gate's w_alpha/b_alpha), the entry's `param_count` and
/// `[p]` tensor specs are recomputed and any python-exact init vector
/// is dropped in favour of the deterministic host init; mixer changes
/// regenerate the per-layer carry specs ([`ModelConfig::carry_lens`]).
fn apply_model_overrides(manifest: &mut Manifest, args: &Args) -> Result<()> {
    use stlt::runtime::artifact::{Entry, MIXER_NAMES};
    let adaptive = match args.get("adaptive") {
        None => None,
        Some(v) => match v {
            "true" | "1" => Some(true),
            "false" | "0" => Some(false),
            other => return Err(anyhow!("--adaptive expects true|false, got '{other}'")),
        },
    };
    let mixer = match args.get("mixer") {
        None => None,
        Some(m) if MIXER_NAMES.contains(&m) => Some(m.to_string()),
        Some(m) => {
            return Err(anyhow!(
                "--mixer '{m}': unknown mixer (expected one of {})",
                MIXER_NAMES.join(" | ")
            ))
        }
    };
    if adaptive.is_none() && mixer.is_none() {
        return Ok(());
    }
    let mut touched = 0usize;
    for e in manifest.entries.values_mut() {
        if e.config.arch != "stlt" {
            continue;
        }
        let p_old = e.param_count;
        if let Some(a) = adaptive {
            e.config.adaptive = a;
        }
        if let Some(mx) = &mixer {
            e.config.mixer = mx.clone();
        }
        let p_new = stlt::interpret::total_params(&stlt::interpret::trunk_layout(&e.config));
        if p_new != p_old {
            e.param_count = p_new;
            // any python-exact init vector packs the old layout
            e.init_file = None;
        }
        for spec in e.inputs.iter_mut().chain(e.outputs.iter_mut()) {
            if spec.shape == [p_old] {
                spec.shape = vec![p_new];
            }
        }
        // serving kinds carry per-layer state whose shape follows the
        // mixer/gate; rebuild their specs from the one source of truth
        // (decode_batch is derived from decode_step at serve time, so
        // it follows automatically)
        let rebuilt = match e.kind.as_str() {
            "stream_step" => {
                let chunk = e.extra.get("chunk").copied().unwrap_or(1).max(1) as usize;
                Some(Entry::synthetic_stream(&e.config, p_new, &e.name, chunk))
            }
            "decode_step" => Some(Entry::synthetic_decode(&e.config, p_new, &e.name)),
            "stream_batch_step" => {
                let chunk = e.extra.get("chunk").copied().unwrap_or(1).max(1) as usize;
                let bsrv = e.extra.get("batch_srv").copied().unwrap_or(1).max(1) as usize;
                Some(Entry::synthetic_stream_batch(&e.config, p_new, &e.name, chunk, bsrv))
            }
            _ => None,
        };
        if let Some(r) = rebuilt {
            e.inputs = r.inputs;
            e.outputs = r.outputs;
            e.kept_inputs = r.kept_inputs;
        }
        touched += 1;
    }
    if touched == 0 {
        return Err(anyhow!("--adaptive/--mixer: no stlt entries in the manifest to override"));
    }
    stlt::info!(
        "cli",
        "model overrides: adaptive={:?} mixer={:?} applied to {touched} entries",
        adaptive,
        mixer
    );
    Ok(())
}

/// Trained weights from --ckpt (validated against the artifact's name
/// and parameter count), else any `{artifact}.*` entry's init vector.
fn load_flat(manifest: &Manifest, artifact: &str, args: &Args) -> Result<Vec<f32>> {
    let prefix = format!("{artifact}.");
    if let Some(ckpt) = args.get("ckpt") {
        let entry = manifest
            .entries
            .values()
            .find(|e| e.name.starts_with(&prefix))
            .ok_or_else(|| anyhow!("no '{artifact}.*' entries in manifest"))?;
        let state = coordinator::load_checkpoint_for(
            std::path::Path::new(ckpt),
            artifact,
            entry.param_count,
        )?;
        return Ok(state.flat);
    }
    // no --ckpt: fall back to an init vector. aot.py attaches a
    // python-exact .init.bin to the train entry; native-only manifests
    // carry none, so artifact_flat synthesizes the deterministic host
    // init — every worker loading the same manifest gets bitwise-equal
    // weights, which is what makes cross-process migration exact.
    stlt::info!("cli", "{artifact}: no --ckpt, using untrained init");
    stlt::runtime::exec::artifact_flat(manifest, artifact)
}

/// `stlt lint [--root DIR] [--deep [--lock-graph FILE]]`: scan
/// DIR/src against the allowlist at DIR/lint.allow. With `--deep`,
/// additionally run the call-graph tier (`stlt::lint::deep`) against
/// DIR/lint_deep.allow, writing the lock-order graph JSON to
/// `--lock-graph FILE` when given. Dispatched before the manifest
/// loads — linting must work in a bare checkout with no artifacts.
fn run_lint(args: &Args) -> Result<()> {
    let default_root = if std::path::Path::new("rust/src").is_dir() { "rust" } else { "." };
    let root = std::path::PathBuf::from(args.get_or("root", default_root));
    let mut violations =
        stlt::lint::run(&root.join("src"), &root.join("lint.allow")).map_err(|e| anyhow!(e))?;
    if args.has_flag("deep") {
        let lock_graph = args.get("lock-graph").map(std::path::PathBuf::from);
        violations.extend(
            stlt::lint::run_deep(
                &root.join("src"),
                &root.join("lint_deep.allow"),
                lock_graph.as_deref(),
            )
            .map_err(|e| anyhow!(e))?,
        );
        if let Some(p) = &lock_graph {
            println!("lint: lock-order graph written to {}", p.display());
        }
    }
    for v in &violations {
        eprintln!("{v}");
    }
    if !violations.is_empty() {
        return Err(anyhow!("lint: {} violation(s) in {}", violations.len(), root.display()));
    }
    let tier = if args.has_flag("deep") { "shallow+deep" } else { "shallow" };
    println!("lint: clean ({}, {tier})", root.join("src").display());
    Ok(())
}

fn run() -> Result<()> {
    let args = Args::from_env(&["verbose", "deep"]).map_err(|e| anyhow!(e))?;
    if args.has_flag("verbose") {
        stlt::util::logging::set_level(stlt::util::logging::Level::Debug);
    }
    if args.subcommand.as_deref() == Some("lint") {
        return run_lint(&args);
    }
    let backend = BackendKind::parse(&args.get_or("backend", "native"))?;
    let mut manifest = Manifest::load(default_artifacts_dir())?;
    apply_model_overrides(&mut manifest, &args)?;
    match args.subcommand.as_deref() {
        Some("info") => {
            let rt = Runtime::new(backend)?;
            println!("backend: {} (platform: {})", backend.name(), rt.platform());
            println!("artifacts dir: {}", manifest.dir.display());
            for (name, e) in &manifest.entries {
                println!(
                    "  {name:42} kind={:16} params={:>9} arch={}",
                    e.kind, e.param_count, e.config.arch
                );
            }
            Ok(())
        }
        Some("train") => {
            let mut cfg = match args.get("config") {
                Some(p) => Config::load(p).map_err(|e| anyhow!(e))?,
                None => Config::default(),
            };
            // repeated --set section.key=value overrides, applied in order
            let overrides = args.get_all("set");
            cfg.apply_overrides(&overrides).map_err(|e| anyhow!(e))?;
            let artifact = args.get_or("artifact", &cfg.str_or("train.artifact", "lm_stlt_tiny"));
            // --grad-ckpt C segment-checkpoints the native backward tape
            // (0 = whole sequence). Gradients are bitwise identical for
            // every C, so this is free to set per-run and never
            // invalidates checkpoints or resume. An *explicit* flag or
            // train.grad_ckpt config key always overrides the manifest —
            // including 0, so whole-sequence can be forced on a manifest
            // that ships a positive grad_ckpt_segment.
            let grad_ckpt = match args.get("grad-ckpt") {
                Some(_) => Some(args.get_usize("grad-ckpt", 0).map_err(|e| anyhow!(e))?),
                None => cfg
                    .get("train.grad_ckpt")
                    .and_then(|v| v.as_i64())
                    .map(|v| v.max(0) as usize),
            };
            if let Some(c) = grad_ckpt {
                let prefix = format!("{artifact}.");
                for e in manifest.entries.values_mut() {
                    if e.name.starts_with(&prefix) {
                        e.config.grad_ckpt_segment = c;
                    }
                }
            }
            let opts = TrainOpts {
                steps: args.get_u64("steps", cfg.i64_or("train.steps", 200) as u64)
                    .map_err(|e| anyhow!(e))?,
                log_every: args.get_u64("log-every", cfg.i64_or("train.log_every", 20) as u64)
                    .map_err(|e| anyhow!(e))?,
                eval_every: args.get_u64("eval-every", cfg.i64_or("train.eval_every", 100) as u64)
                    .map_err(|e| anyhow!(e))?,
                eval_batches: args
                    .get_u64("eval-batches", cfg.i64_or("train.eval_batches", 4) as u64)
                    .map_err(|e| anyhow!(e))?,
                seed: args.get_u64("seed", cfg.i64_or("train.seed", 0) as u64)
                    .map_err(|e| anyhow!(e))?,
                checkpoint: args
                    .get("ckpt")
                    .map(String::from)
                    .or_else(|| cfg.get("train.checkpoint").and_then(|v| v.as_str()).map(String::from)),
                resume: args.get("resume").map(String::from),
                domain: args.get_u64("domain", cfg.i64_or("data.domain", 0) as u64)
                    .map_err(|e| anyhow!(e))?,
                metrics_every: args.get_u64("metrics-every", 0).map_err(|e| anyhow!(e))?,
            };
            let rt = Runtime::new(backend)?;
            let report = coordinator::train_lm(&rt, &manifest, &artifact, &opts)?;
            println!("final ppl: {:.3}", report.final_ppl);
            println!("throughput: {:.0} tokens/s", report.tokens_per_s);
            Ok(())
        }
        Some("eval") => {
            let artifact = args.get_or("artifact", "lm_stlt_tiny");
            let noise = args.get_f64("noise", 0.0).map_err(|e| anyhow!(e))? as f32;
            let flat = load_flat(&manifest, &artifact, &args)?;
            let rt = Runtime::new(backend)?;
            let eval = stlt::runtime::EvalStep::new(&rt, &manifest, &format!("{artifact}.eval"))?;
            let entry = manifest.get(&format!("{artifact}.eval"))?;
            let cfg = stlt::data::corpus::CorpusConfig::default_for_vocab(entry.config.vocab);
            let opts = TrainOpts {
                eval_batches: args.get_u64("batches", 8).map_err(|e| anyhow!(e))?,
                ..Default::default()
            };
            let ppl = coordinator::eval_lm(&eval, &flat, &cfg, &opts, noise)?;
            println!("ppl: {ppl:.3} (noise={noise}, backend={})", backend.name());
            Ok(())
        }
        Some("stream") => {
            let artifact = args.get_or("artifact", "lm_stlt_tiny");
            let doc_len = args.get_usize("doc-len", 4096).map_err(|e| anyhow!(e))?;
            let flat = load_flat(&manifest, &artifact, &args)?;
            let server = coordinator::Server::start(
                &manifest,
                &artifact,
                flat,
                ServerOpts { backend, ..Default::default() },
            )?;
            let entry = manifest.get(&format!("{artifact}.stream_batch"))?;
            let mut corpus = stlt::data::corpus::Corpus::new(
                stlt::data::corpus::CorpusConfig::default_for_vocab(entry.config.vocab), 99,
            );
            let doc = corpus.take(doc_len);
            let t0 = std::time::Instant::now();
            let r = server.feed(1, doc, true)?;
            let dt = t0.elapsed().as_secs_f64();
            println!(
                "streamed {} tokens in {:.2}s ({:.0} tok/s, backend {}), ppl {:.3}",
                doc_len, dt, doc_len as f64 / dt, backend.name(),
                stlt::metrics::perplexity(r.nll_sum, r.count)
            );
            println!("feed latency: {}", server.stats.feed_latency.summary());
            server.shutdown();
            Ok(())
        }
        Some("generate") => {
            let artifact = args.get_or("artifact", "lm_stlt_tiny");
            let len = args.get_usize("len", 64).map_err(|e| anyhow!(e))?;
            let flat = load_flat(&manifest, &artifact, &args)?;
            let server = coordinator::Server::start(
                &manifest,
                &artifact,
                flat,
                ServerOpts { backend, ..Default::default() },
            )?;
            let entry = manifest.get(&format!("{artifact}.stream_batch"))?;
            let mut corpus = stlt::data::corpus::Corpus::new(
                stlt::data::corpus::CorpusConfig::default_for_vocab(entry.config.vocab), 7,
            );
            let prompt = corpus.take(65);
            let seed_token =
                prompt.last().copied().ok_or_else(|| anyhow!("corpus produced empty prompt"))?;
            server.feed(1, prompt.clone(), false)?;
            let sampling = stlt::coordinator::Sampling::parse(
                &args.get_or("sampling", "greedy"),
            )
            .map_err(|e| anyhow!(e))?;
            let g = server.generate_with(
                1, seed_token, len, None, sampling,
                args.get_u64("sample-seed", 0).map_err(|e| anyhow!(e))?,
            )?;
            println!("prompt tail: {:?}", &prompt[prompt.len().saturating_sub(8)..]);
            println!("generated : {:?}", g.tokens);
            server.shutdown();
            Ok(())
        }
        Some("serve") => {
            let artifact = args.get_or("artifact", "lm_stlt_tiny");
            let sessions = args.get_usize("sessions", 4).map_err(|e| anyhow!(e))?.max(1);
            let prompt_len = args.get_usize("prompt-len", 129).map_err(|e| anyhow!(e))?.max(2);
            let gen_len = args.get_usize("gen-len", 32).map_err(|e| anyhow!(e))?.max(1);
            let sampling = stlt::coordinator::Sampling::parse(
                &args.get_or("sampling", "greedy"),
            )
            .map_err(|e| anyhow!(e))?;
            let vocab = manifest.get(&format!("{artifact}.stream_batch"))?.config.vocab;
            let metrics_every = args.get_u64("metrics-every", 0).map_err(|e| anyhow!(e))?;
            let trace_file = args.get("trace").map(String::from);
            if trace_file.is_some() {
                stlt::obs::set_tracing(true);
            }
            if metrics_every > 0 {
                // detached heartbeat: dies with the process
                std::thread::spawn(move || loop {
                    std::thread::sleep(std::time::Duration::from_secs(metrics_every));
                    stlt::info!("obs", "{}", stlt::obs::summary_line());
                });
            }
            // local in-process server, or a wire connection to a
            // worker/router — the per-session workload below drives
            // both through the same `Session` trait
            #[derive(Clone)]
            enum Target {
                Local(Arc<coordinator::Server>),
                Remote(stlt::net::Client),
            }
            let target = match args.get("connect") {
                Some(addr) => {
                    println!("driving remote server at {addr}");
                    Target::Remote(stlt::net::Client::connect(addr)?)
                }
                None => {
                    let flat = load_flat(&manifest, &artifact, &args)?;
                    Target::Local(Arc::new(coordinator::Server::start(
                        &manifest,
                        &artifact,
                        flat,
                        ServerOpts {
                            backend,
                            max_sessions: sessions.max(16),
                            ..Default::default()
                        },
                    )?))
                }
            };
            let t0 = std::time::Instant::now();
            // client-observed first-token latency, shared across client
            // threads via the metrics registry (the same histogram
            // implementation every other latency in the process uses)
            let ttft_hist = stlt::obs::hist("serve_cli/ttft_seconds");
            let mut clients = Vec::new();
            for s in 0..sessions {
                let target = target.clone();
                let ttft_hist = Arc::clone(&ttft_hist);
                clients.push(std::thread::spawn(move || -> Result<(usize, f64, f64)> {
                    use stlt::coordinator::Session;
                    let mut sess: Box<dyn Session> = match &target {
                        Target::Local(server) => Box::new(server.open_session()),
                        Target::Remote(client) => Box::new(client.open(0)?),
                    };
                    let mut corpus = stlt::data::corpus::Corpus::new(
                        stlt::data::corpus::CorpusConfig::default_for_vocab(vocab),
                        1000 + s as u64,
                    );
                    let prompt = corpus.take(prompt_len);
                    let seed_token = prompt
                        .last()
                        .copied()
                        .ok_or_else(|| anyhow!("corpus produced empty prompt"))?;
                    let fr = sess.feed(prompt.clone(), true)?;
                    let tg0 = std::time::Instant::now();
                    let mut stream = sess.generate(stlt::coordinator::GenOpts {
                        seed_token,
                        max_tokens: gen_len,
                        sampling,
                        rng_seed: s as u64,
                        ..Default::default()
                    })?;
                    let (mut n, mut ttft) = (0usize, 0.0f64);
                    while let Some(tok) = stream.recv() {
                        tok?;
                        n += 1;
                        if n == 1 {
                            ttft = tg0.elapsed().as_secs_f64();
                            ttft_hist.record(ttft);
                        }
                    }
                    sess.close()?;
                    let ppl = stlt::metrics::perplexity(fr.nll_sum, fr.count);
                    Ok((n, ttft, ppl))
                }));
            }
            let mut total_tokens = 0usize;
            for (s, c) in clients.into_iter().enumerate() {
                let (n, ttft, ppl) = c.join().map_err(|_| anyhow!("client thread panicked"))??;
                total_tokens += n;
                println!(
                    "session {s}: {n} tokens, first token {:.1}ms, prompt ppl {ppl:.2}",
                    ttft * 1e3
                );
            }
            let dt = t0.elapsed().as_secs_f64();
            println!(
                "served {sessions} concurrent sessions (prompt {prompt_len}, gen {gen_len}) \
                 in {dt:.2}s on {}: {:.0} generated tok/s aggregate",
                backend.name(),
                total_tokens as f64 / dt
            );
            println!("client ttft: {}", ttft_hist.summary());
            if let Target::Local(server) = target {
                println!("ttft: {}", server.stats.ttft_latency.summary());
                println!("feed latency: {}", server.stats.feed_latency.summary());
                println!(
                    "waves: {} (mean fill {:.2}, max {}), evictions {}, cancelled {}",
                    server.stats.waves.get(),
                    server.stats.wave_mean_fill(),
                    server.stats.wave_max_fill.get() as u64,
                    server.stats.evictions.get(),
                    server.stats.cancelled.get(),
                );
                Arc::try_unwrap(server)
                    .map_err(|_| anyhow!("server still shared"))?
                    .shutdown();
            }
            if let Some(path) = trace_file {
                std::fs::write(&path, stlt::obs::drain_json())?;
                println!("trace written to {path}");
            }
            Ok(())
        }
        Some("worker") => {
            let artifact = args.get_or("artifact", "lm_stlt_tiny");
            let listen = args.get_or("listen", "127.0.0.1:7741");
            let max_sessions = args.get_usize("max-sessions", 64).map_err(|e| anyhow!(e))?;
            let queue_cap = args.get_usize("queue-cap", 256).map_err(|e| anyhow!(e))?;
            let flat = load_flat(&manifest, &artifact, &args)?;
            let server = Arc::new(coordinator::Server::start(
                &manifest,
                &artifact,
                flat,
                ServerOpts { backend, max_sessions, queue_cap, ..Default::default() },
            )?);
            let wire = stlt::net::spawn_worker(server, &listen)?;
            // the stdout line is the readiness signal scripts and tests
            // wait for; logging goes to stderr
            println!("worker listening on {}", wire.addr());
            use std::io::Write;
            std::io::stdout().flush()?;
            loop {
                std::thread::park();
            }
        }
        Some("router") => {
            let listen = args.get_or("listen", "127.0.0.1:7740");
            let workers: Vec<String> = args
                .get("workers")
                .ok_or_else(|| anyhow!("router requires --workers ADDR[,ADDR...]"))?
                .split(',')
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
                .collect();
            if workers.is_empty() {
                return Err(anyhow!("router requires at least one worker address"));
            }
            let router = stlt::net::Router::connect(&workers)?;
            let wire = router.listen(&listen)?;
            println!(
                "router listening on {} ({} workers)",
                wire.addr(),
                router.worker_count()
            );
            use std::io::Write;
            std::io::stdout().flush()?;
            loop {
                std::thread::park();
            }
        }
        Some("stats") => {
            let addr = args
                .get("connect")
                .ok_or_else(|| anyhow!("stats requires --connect ADDR (worker or router)"))?;
            let client = stlt::net::Client::connect(addr)?;
            let text = client.stats()?;
            // validate before printing so scripts piping this output
            // never scrape a half-broken document
            stlt::obs::parse(&text).map_err(|e| anyhow!("{addr}: bad stats payload: {e}"))?;
            print!("{text}");
            Ok(())
        }
        Some("inspect") => {
            let artifact = args.get_or("artifact", "lm_stlt_tiny");
            let flat = load_flat(&manifest, &artifact, &args)?;
            // any entry of the artifact carries the ModelConfig; don't
            // require a '.train' entry (inference-only manifests are legal)
            let prefix = format!("{artifact}.");
            let entry = manifest
                .entries
                .values()
                .find(|e| e.name.starts_with(&prefix))
                .ok_or_else(|| anyhow!("no '{artifact}.*' entries in manifest"))?;
            let report = stlt::interpret::inspect_stlt_params(&flat, &entry.config);
            println!("{report}");
            Ok(())
        }
        _ => Err(anyhow!(usage())),
    }
}
