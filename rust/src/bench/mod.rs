//! From-scratch micro/macro benchmark harness (no criterion offline):
//! warmup + timed iterations, reporting mean/p50/p95 and throughput.
//! Used by rust/benches/*.rs (harness = false) and the experiment
//! harnesses in examples/.

use std::time::Instant;

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn row(&self) -> String {
        format!(
            "{:38} iters={:4}  mean={:>10}  p50={:>10}  p95={:>10}  min={:>10}",
            self.name,
            self.iters,
            fmt_time(self.mean_s),
            fmt_time(self.p50_s),
            fmt_time(self.p95_s),
            fmt_time(self.min_s),
        )
    }
}

pub fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.3}s", s)
    }
}

/// Time `f` for `iters` iterations after `warmup` runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    summarize(name, &mut samples)
}

/// Adaptive: run for at least `min_total_s` seconds (at least 3 iters).
pub fn bench_for<F: FnMut()>(name: &str, min_total_s: f64, mut f: F) -> BenchResult {
    f(); // warmup
    let mut samples = Vec::new();
    let start = Instant::now();
    while start.elapsed().as_secs_f64() < min_total_s || samples.len() < 3 {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
        if samples.len() > 10_000 {
            break;
        }
    }
    summarize(name, &mut samples)
}

fn summarize(name: &str, samples: &mut [f64]) -> BenchResult {
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    BenchResult {
        name: name.to_string(),
        iters: n,
        mean_s: mean,
        p50_s: samples[n / 2],
        p95_s: samples[(n as f64 * 0.95) as usize % n],
        min_s: samples[0],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_exact_iters() {
        let mut count = 0;
        let r = bench("noop", 2, 10, || count += 1);
        assert_eq!(count, 12);
        assert_eq!(r.iters, 10);
        assert!(r.min_s <= r.p50_s && r.p50_s <= r.p95_s);
    }

    #[test]
    fn bench_for_respects_budget() {
        let r = bench_for("sleepy", 0.02, || std::thread::sleep(std::time::Duration::from_millis(2)));
        assert!(r.iters >= 3);
        assert!(r.mean_s >= 0.001);
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(2e-9).ends_with("ns"));
        assert!(fmt_time(2e-5).ends_with("us"));
        assert!(fmt_time(2e-2).ends_with("ms"));
        assert!(fmt_time(2.0).ends_with("s"));
    }
}
