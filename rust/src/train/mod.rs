//! Native training subsystem: hand-derived reverse-mode gradients for
//! the pure-Rust STLT stack ([`backward`]), a pure-Rust AdamW with the
//! `python/compile/optim.py` warmup+cosine schedule and global-norm
//! clipping ([`optim`]), and multi-threaded data-parallel gradient
//! accumulation ([`batch_loss_and_grad`]).
//!
//! Every matmul on both sides of the tape runs on the shared blocked
//! kernels in [`crate::util::linalg`] — the tape forward additionally
//! reuses the engine's packed weight panels and its causal-gate /
//! `ffn_parts` / `head_logits` helpers, and token mixing goes through
//! the same [`crate::runtime::Mixer`] trait the engine serves — so
//! training can never optimise a subtly different network than
//! eval/serving executes.
//!
//! Together these make `stlt train --backend native` a first-class
//! path: the same `train_step` contract the AOT-lowered HLO exposes —
//! `(flat, m, v, step, tokens[B,N+1], seed) -> (flat', m', v', loss,
//! ce, s_eff)` — is implemented by [`native_train_step`] and plugged
//! into the [`crate::runtime::Backend`] seam by
//! `runtime/backend/native.rs`, so `coordinator::train_lm` and the CLI
//! drive either backend unchanged. For adaptive configs the step's
//! `seed` drives the Gumbel-sigmoid gate relaxation ([`TrainNoise`]):
//! each row derives an independent noise stream from it, and the
//! relaxation temperature anneals from `gumbel_temp_hi` to
//! `gumbel_temp_lo` over the first `gumbel_anneal_frac · total_steps`
//! steps ([`gumbel_temp_at`]). Eval and serving always use the
//! deterministic `sigmoid(logit)` gate.
//!
//! ## Data-parallel accumulation
//!
//! Unlike PJRT, the native backend has no device parallelism of its
//! own, so the batch is sharded across worker threads: each row's
//! gradient is computed independently (rows only couple through the
//! final mean), and the per-row gradients are summed **in row order on
//! the calling thread**. The reduction order is therefore independent
//! of the worker count — gradients from a 1-thread pool and an
//! N-thread pool are bitwise identical (`tests/native_train.rs`).
//!
//! Memory: the backward tape is segment-checkpointed
//! (`backward::tape_bytes` is the exact accounting) — per in-flight row
//! it stores O(N·d + N·hd) projection/LN activations plus O((N/C)·S·d)
//! Laplace carry snapshots for `grad_ckpt_segment = C`, replaying each
//! segment's O(C·S·d) U history on the fly during the backward instead
//! of materialising the classic O(N·S·d) exact-reverse-mode U tape
//! (`grad_ckpt_segment = 0` keeps one whole-sequence segment). Rows not
//! yet picked up by a worker hold no tape.
//!
//! Metric sums (NLL, reg, s_eff) accumulate in f64 on the reduction
//! thread: an f32 running sum stalls once the total outgrows the 2^-24
//! relative step (a 100k-token batch NLL sits well past it), making the
//! reported loss depend on summation order. The f64 path is pinned by
//! the long-sequence sum-order test in `tests/native_parity.rs`.

pub mod backward;
pub mod optim;

use anyhow::{bail, Result};

pub use backward::{row_loss_and_grad, seg_len, tape_bytes, RowOut, TrainNoise};
pub use optim::{adamw_step, AdamHp};

use crate::runtime::artifact::ModelConfig;
use crate::runtime::native_stlt::StltModel;
use crate::util::sync::Arc;
use crate::util::threadpool::{parallel_map, ThreadPool};

/// Gumbel-sigmoid relaxation temperature at a given training step:
/// linear anneal from `gumbel_temp_hi` to `gumbel_temp_lo` over the
/// first `gumbel_anneal_frac · total_steps` steps, flat afterwards.
pub fn gumbel_temp_at(cfg: &ModelConfig, step: i32) -> f32 {
    let horizon = (cfg.gumbel_anneal_frac * cfg.total_steps as f32).max(1.0);
    let frac = (step.max(0) as f32 / horizon).clamp(0.0, 1.0);
    cfg.gumbel_temp_hi + (cfg.gumbel_temp_lo - cfg.gumbel_temp_hi) * frac
}

/// Scalar outputs of one batch gradient / training step.
#[derive(Clone, Copy, Debug)]
pub struct BatchMetrics {
    /// ce + mean-over-rows Eq. Reg penalty (the quantity differentiated)
    pub loss: f32,
    /// next-token cross-entropy, mean over B·N positions
    pub ce: f32,
    /// mean active node count (token-mean gate mass Σ_k m̄_k averaged
    /// over layers and rows; exactly S for non-adaptive configs)
    pub s_eff: f32,
    /// pre-clip global gradient norm (0 until the optimiser runs)
    pub grad_norm: f32,
    /// peak per-row activation-tape bytes (max over the batch rows;
    /// see [`backward::tape_bytes`])
    pub tape_bytes: usize,
}

/// Gradient of the batch loss `mean_B·N nll + mean_B reg` for a flat
/// `[batch, n_plus_1]` token array, data-parallel over rows.
///
/// Row gradients are computed on `pool` workers and reduced in row
/// order on the calling thread, so the result is bitwise independent
/// of the pool size.
///
/// `noise` is the step-level Gumbel relaxation (adaptive training);
/// each row gets an independent stream by hashing its index into the
/// seed, so the result is also independent of row scheduling.
///
/// F64-REDUCE: scalar reductions (nll/reg/s_eff) accumulate in f64.
pub fn batch_loss_and_grad(
    model: &StltModel,
    tokens: &[i32],
    batch: usize,
    n_plus_1: usize,
    noise: Option<TrainNoise>,
    pool: &ThreadPool,
) -> Result<(Vec<f32>, BatchMetrics)> {
    if batch == 0 || n_plus_1 < 2 || tokens.len() != batch * n_plus_1 {
        bail!(
            "bad batch shape: {} tokens for [{batch}, {n_plus_1}]",
            tokens.len()
        );
    }
    let n = n_plus_1 - 1;
    let ce_scale = 1.0 / (batch * n) as f32;
    let reg_scale = 1.0 / batch as f32;
    let model_c = model.clone();
    let tokens_c: Arc<Vec<i32>> = Arc::new(tokens.to_vec());
    let rows = parallel_map(pool, batch, move |i| {
        // per-row noise stream: splitmix-style index hash into the seed
        let row_noise = noise.map(|ns| TrainNoise {
            temp: ns.temp,
            seed: ns.seed ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
        });
        row_loss_and_grad(
            &model_c,
            &tokens_c[i * n_plus_1..(i + 1) * n_plus_1],
            ce_scale,
            reg_scale,
            row_noise,
        )
    });
    let mut grad: Option<Vec<f32>> = None;
    // all scalar reductions in f64 (satellite fix): f32 running sums
    // drift measurably once rows are 100k tokens long
    let (mut nll, mut reg, mut s_eff_sum) = (0.0f64, 0.0f64, 0.0f64);
    let mut tape_peak = 0usize;
    for r in rows {
        let r = r?;
        nll += r.nll_sum;
        reg += r.reg as f64;
        s_eff_sum += f64::from(r.s_eff);
        tape_peak = tape_peak.max(r.tape_bytes);
        match &mut grad {
            None => grad = Some(r.grad),
            Some(g) => {
                for (a, b) in g.iter_mut().zip(&r.grad) {
                    *a += b;
                }
            }
        }
    }
    let ce = nll * ce_scale as f64;
    let metrics = BatchMetrics {
        loss: (ce + reg * reg_scale as f64) as f32,
        ce: ce as f32,
        s_eff: (s_eff_sum * f64::from(reg_scale)) as f32,
        grad_norm: 0.0,
        tape_bytes: tape_peak,
    };
    Ok((grad.unwrap(), metrics))
}

/// One full native training step matching the XLA `train_step` artifact
/// contract: gradients (data-parallel), LR schedule, global-norm clip,
/// AdamW — all from `python/compile/{train,optim}.py` semantics.
///
/// `flat`/`m`/`v` are updated in place; `step` is the pre-update
/// counter (the scalar the driver feeds the artifact). Returns the step
/// metrics; the caller increments its own step counter, exactly like
/// the XLA path.
///
/// `seed` is the step's RNG seed from the artifact contract. It only
/// matters for adaptive configs, where it (with the step-annealed
/// temperature) drives the Gumbel-sigmoid gate relaxation; elsewhere
/// the step is fully deterministic in (flat, m, v, step, tokens).
#[allow(clippy::too_many_arguments)]
pub fn native_train_step(
    model: &StltModel,
    flat: &mut [f32],
    m: &mut [f32],
    v: &mut [f32],
    step: i32,
    tokens: &[i32],
    batch: usize,
    n_plus_1: usize,
    seed: u64,
    pool: &ThreadPool,
) -> Result<BatchMetrics> {
    let noise = if model.cfg.adaptive {
        Some(TrainNoise {
            temp: gumbel_temp_at(&model.cfg, step),
            seed,
        })
    } else {
        None
    };
    let (mut grad, mut metrics) =
        batch_loss_and_grad(model, tokens, batch, n_plus_1, noise, pool)?;
    let hp = AdamHp::from_config(&model.cfg);
    metrics.grad_norm = adamw_step(&hp, step, flat, m, v, &mut grad);
    Ok(metrics)
}
