//! Hand-derived reverse-mode differentiation of the native STLT trunk.
//!
//! The forward ([`row_loss_and_grad`]) replays the exact semantics of
//! [`StltModel::trunk_chunk`] on one row (full sequence, zero carry,
//! deterministic gate) while recording a tape of activations; the
//! backward sweep then produces the gradient of
//!
//!   loss_row = ce_scale · Σ_t nll_t + reg_scale · reg_row
//!
//! with respect to the *entire* flat parameter vector — embeddings,
//! LayerNorms, FFN and mixer projections, the adaptive gate, and the
//! Laplace-node parameters (sigma_raw, omega, t_raw).
//!
//! Every matmul here — the tape forward's projections (run on the same
//! pre-transposed weight panels as the engine, via the shared
//! [`StltModel::ffn_parts`]/[`StltModel::head_logits`] helpers) and the
//! backward sweep's `dy @ Wᵀ` / `xᵀ dy` adjoint products — goes through
//! the blocked kernels in [`crate::util::linalg`]. One kernel family on
//! both sides of the tape means the gradient can never be taken of a
//! subtly different network than the engine serves
//! (`tests/native_train.rs` pins tape-vs-engine NLL parity).
//!
//! Token mixing routes through the [`Mixer`] trait on both sides: the
//! tape forward advances the mixer state through
//! [`Mixer::token_step`] (snapshotting it at segment boundaries), and
//! the reverse sweep calls [`Mixer::backward_chunk`], which owns the
//! mixer-specific adjoint recurrences (the GL/GU time-transposed sweep
//! for the Laplace recurrence, the GS/Gzv accumulators for linear
//! attention) and the fraw/gate chain-rule split. Mixers with
//! [`Mixer::uses_node_params`] = false (linear attention) skip the
//! node-parameter gradient conversion and the omega/sigma Eq. Reg
//! terms, leaving those groups exactly zero. No autograd framework is
//! involved; correctness is pinned by finite-difference checks against
//! an independent f64 oracle in `tests/native_train.rs`.
//!
//! ## Adaptive node gate (SS3.6)
//!
//! The gate is *causal* (token t sees the running mean of the LN1
//! output over tokens ≤ t — the same pooling the engine streams) and,
//! during training, relaxed with the Gumbel-sigmoid trick: per
//! (row, layer, node) a logistic noise sample g = ln u − ln(1 − u) is
//! drawn once (shared across the row's tokens) and the gate becomes
//! `m = sigmoid((logit + g) / temp)`, with the temperature annealed by
//! the trainer (`gumbel_temp_*` config keys). `noise: None` — eval,
//! serving, FD probes — is the deterministic `sigmoid(logit)` path,
//! bitwise the engine's. The node-budget regularizer `lambda_mask`
//! penalises the token-mean gate m̄ per node, so inactive nodes are
//! driven toward zero mass (cf. Adaptive Attention Span's span budget).
//!
//! ## Segment-checkpointed tape
//!
//! The naive tape stores U_t for every t — O(N·S·d) floats per layer,
//! the term that makes long-context training OOM long before the
//! forward does. Instead, the tape forward records only the (L, U)
//! carry at every `grad_ckpt_segment`-token boundary (the same carry
//! `trunk_chunk` threads through chunked streaming), and the backward
//! replays each segment's state history on the fly, in reverse segment
//! order, from its snapshot — through the *same* [`Mixer::token_step`]
//! the forward and the streaming engine use, so the replayed values
//! are bitwise identical to what a full tape would have stored and the
//! gradient is bitwise independent of the segment length
//! (`tests/native_train.rs`). Peak tape memory drops from O(N·S·d) to
//! O(C·S·d + (N/C)·S·d) per layer for segment length C, at the cost of
//! one extra forward recurrence replay (~the cheap part of the
//! backward; the GEMMs are never replayed). `grad_ckpt_segment = 0`
//! (default) means one whole-sequence segment: the replay buffer is
//! then O(N·S·d), but only ONE layer's buffer is alive at a time —
//! already an n_layers× improvement over the old always-resident
//! per-layer U tape — and the replay sweep applies there too.
//! [`tape_bytes`] is the exact accounting, asserted against the real
//! allocations in tests.
//!
//! Ablation flags mirror `stlt_layer.node_params`/`regulariser`:
//! `learn_sigma=false` (resp. omega, t) zeroes that group's gradient
//! from both the model path and the Eq. Reg penalty.
//!
//! Training-vs-python deviations (documented in rust/README.md): the
//! gate pools *causally* (python mean-pools the whole row acausally,
//! which no streaming decoder can reproduce) and the Eq. Reg mask
//! coupling is per-row through the token-mean gate m̄ (python couples
//! through the batch-mean gate); for non-adaptive configs both
//! reductions are identical.

use anyhow::{bail, Result};

use crate::runtime::artifact::ModelConfig;
use crate::runtime::mixer::Mixer;
use crate::runtime::native_stlt::{sigmoid, softplus, StltModel};
use crate::util::linalg::{self, gelu_grad};
use crate::util::rng::Rng;

/// Gumbel-sigmoid relaxation parameters for one training row. `None`
/// anywhere a `Option<TrainNoise>` is taken means the deterministic
/// `sigmoid(logit)` gate — bitwise the engine's eval/serving path.
#[derive(Clone, Copy, Debug)]
pub struct TrainNoise {
    /// annealed relaxation temperature (> 0); see `gumbel_temp_at`
    pub temp: f32,
    /// seed for this row's logistic noise draws (one [`Rng`] per row;
    /// each layer draws its S samples sequentially in layer order)
    pub seed: u64,
}

/// Gradient + loss terms of one row. `grad` has the full flat length.
pub struct RowOut {
    pub nll_sum: f64,
    /// unscaled Eq. Reg penalty of this row (sum over layers)
    pub reg: f32,
    /// mean over layers of the gate mass Σ_k m̄_k (token-mean per node;
    /// exactly S for non-adaptive configs)
    pub s_eff: f32,
    pub grad: Vec<f32>,
    /// peak activation-tape bytes this row allocated (stored layer
    /// tapes + the backward's segment replay buffers); equals
    /// [`tape_bytes`] for the model's config and this row's length
    pub tape_bytes: usize,
}

/// Activations of one layer recorded during the tape forward. The
/// Laplace recurrence contributes only O((N/C)·S·d) carry snapshots —
/// the per-timestep U history is replayed per segment during the
/// backward, never stored whole.
struct LayerTape {
    x_in: Vec<f32>, // [n,d] residual stream entering the layer
    mu1: Vec<f32>,  // [n] LN1 means
    inv1: Vec<f32>, // [n] LN1 inverse stddevs
    h1: Vec<f32>,   // [n,d] LN1 output (mixer input)
    /// node gate tape: `[n,S]` per-token rows when adaptive
    /// (`m_stride = S`), a single shared all-ones `[S]` row otherwise
    /// (`m_stride = 0`) — row t is `m[t*m_stride .. t*m_stride+S]`
    m: Vec<f32>,
    m_stride: usize,
    fraw: Vec<f32>,   // [n,S] pre-gate feature projection h1 @ w_f
    v: Vec<f32>,      // [n,d] value projection h1 @ w_v
    l_snap: Vec<f32>, // [nseg,sl] first mixer state entering each segment
    u_snap: Vec<f32>, // [nseg,su] second mixer state entering each segment
    zmix: Vec<f32>,   // [n,d] mixed output pre-w_o
    x_mid: Vec<f32>,  // [n,d] residual stream after the mixer
    mu2: Vec<f32>,
    inv2: Vec<f32>,
    h2: Vec<f32>,    // [n,d] LN2 output (FFN input)
    hpre: Vec<f32>,  // [n,hd] FFN pre-GELU activations
    hgelu: Vec<f32>, // [n,hd] gelu(hpre), reused for the w2 gradient
}

impl LayerTape {
    fn bytes(&self) -> usize {
        4 * (self.x_in.len()
            + self.mu1.len()
            + self.inv1.len()
            + self.h1.len()
            + self.m.len()
            + self.fraw.len()
            + self.v.len()
            + self.l_snap.len()
            + self.u_snap.len()
            + self.zmix.len()
            + self.x_mid.len()
            + self.mu2.len()
            + self.inv2.len()
            + self.h2.len()
            + self.hpre.len()
            + self.hgelu.len())
    }
}

/// Resolved checkpoint segment length for a row of `n` tokens:
/// `grad_ckpt_segment` clamped to [1, n], with 0 meaning "one segment
/// covering the whole sequence".
pub fn seg_len(cfg: &ModelConfig, n: usize) -> usize {
    match cfg.grad_ckpt_segment {
        0 => n.max(1),
        c => c.min(n.max(1)),
    }
}

/// Exact activation-tape bytes [`row_loss_and_grad`] allocates for one
/// row of `n` tokens: the stored per-layer tapes (everything in
/// `LayerTape`, dominated by the O((N/C)·S·d) carry snapshots once the
/// O(N·S·d) U history is checkpointed away) plus the backward's
/// segment replay buffers (O(C·S·d), one pair shared across all
/// layers). Asserted equal to the real tape allocation in
/// `tests/native_train.rs`. Scope: this counts the *tape* — the
/// backward additionally holds transient gradient scratch on top: two
/// n·vocab buffers (logits + dlogits) during the CE/head phase, both
/// freed before the layer sweep, then per-layer `dhid` [n·hd] and
/// `dfp`/`dv`/`dzmix` [n·S / n·d] buffers. Treat row-fits-in-RAM
/// budgets as tape_bytes + max(2·n·vocab, a few n·hd/n·d) f32s.
pub fn tape_bytes(cfg: &ModelConfig, n: usize) -> usize {
    let (s, d) = (cfg.s_max, cfg.d_model);
    let hd = d * cfg.ffn_mult.max(1);
    let c = seg_len(cfg, n);
    let nseg = n.max(1).div_ceil(c);
    // mixer state slot sizes (recurrence: S·2 / S·d·2, linear
    // attention: S / S·d) — cfg's mirror of Mixer::state_lens
    let (sl, su) = cfg.state_lens();
    // gate tape: per-token [n,S] when adaptive, one shared [S] row else
    let m_len = if cfg.adaptive { n.max(1) * s } else { s };
    // x_in/h1/v/zmix/x_mid/h2 are [n,d]; hpre/hgelu [n,hd]; fraw [n,S];
    // mu/inv ×4 [n]; snapshots [nseg,sl+su]
    let per_layer = n * (6 * d + 2 * hd + s + 4) + nseg * (sl + su) + m_len;
    // backward replay: (C+1) mixer state slots, shared across layers
    let replay = (c + 1) * (sl + su);
    4 * (cfg.n_layers * per_layer + replay)
}

/// LayerNorm forward recording (mu, inv) per row for the backward.
fn ln_fwd(
    flat: &[f32],
    x: &[f32],
    g_off: usize,
    b_off: usize,
    d: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let n = x.len() / d;
    let mut y = vec![0.0f32; n * d];
    let mut mus = vec![0.0f32; n];
    let mut invs = vec![0.0f32; n];
    for t in 0..n {
        let row = &x[t * d..(t + 1) * d];
        let mu = row.iter().sum::<f32>() / d as f32;
        let var = row.iter().map(|&x| (x - mu) * (x - mu)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + 1e-5).sqrt();
        mus[t] = mu;
        invs[t] = inv;
        let orow = &mut y[t * d..(t + 1) * d];
        for i in 0..d {
            orow[i] = (row[i] - mu) * inv * flat[g_off + i] + flat[b_off + i];
        }
    }
    (y, mus, invs)
}

/// LayerNorm backward: returns dx; accumulates dgain/dbias into `grad`.
fn ln_bwd(
    flat: &[f32],
    grad: &mut [f32],
    dy: &[f32],
    x: &[f32],
    mus: &[f32],
    invs: &[f32],
    g_off: usize,
    b_off: usize,
    d: usize,
) -> Vec<f32> {
    let n = x.len() / d;
    let mut dx = vec![0.0f32; n * d];
    for t in 0..n {
        let (mu, inv) = (mus[t], invs[t]);
        let xr = &x[t * d..(t + 1) * d];
        let dyr = &dy[t * d..(t + 1) * d];
        let mut mq = 0.0f32; // mean of q = dy * gain
        let mut mqx = 0.0f32; // mean of q * xhat
        for i in 0..d {
            let xhat = (xr[i] - mu) * inv;
            let q = dyr[i] * flat[g_off + i];
            grad[g_off + i] += dyr[i] * xhat;
            grad[b_off + i] += dyr[i];
            mq += q;
            mqx += q * xhat;
        }
        mq /= d as f32;
        mqx /= d as f32;
        let dxr = &mut dx[t * d..(t + 1) * d];
        for i in 0..d {
            let xhat = (xr[i] - mu) * inv;
            let q = dyr[i] * flat[g_off + i];
            dxr[i] = (q - mq - xhat * mqx) * inv;
        }
    }
    dx
}

/// Per-row loss + full-flat-vector gradient (see module docs).
///
/// `tokens` is one `[n+1]` next-token row; the loss is
/// `ce_scale · Σ nll + reg_scale · reg_row`, so a caller accumulating a
/// `[B, N+1]` batch passes `ce_scale = 1/(B·N)` and `reg_scale = 1/B`
/// to reproduce `trunk.lm_loss` exactly (for non-adaptive configs).
///
/// `noise` switches the adaptive gate to the Gumbel-sigmoid relaxation
/// (training); `None` keeps the deterministic engine gate (eval, FD
/// probes, non-adaptive configs — where it is ignored entirely).
pub fn row_loss_and_grad(
    model: &StltModel,
    tokens: &[i32],
    ce_scale: f32,
    reg_scale: f32,
    noise: Option<TrainNoise>,
) -> Result<RowOut> {
    if tokens.len() < 2 {
        bail!("training row needs at least 2 tokens");
    }
    let cfg = &model.cfg;
    let (s, d, vcb) = (cfg.s_max, cfg.d_model, cfg.vocab);
    let hd = d * cfg.ffn_mult.max(1);
    let n = tokens.len() - 1;
    let ckpt = seg_len(cfg, n);
    let flat = model.flat_params();
    let panels = model.panels();
    let (embed_off, lnf_g, lnf_b) = model.head_offsets();
    let scale = (d as f32).sqrt();

    // ---------------- forward with tape ----------------
    let mut x = vec![0.0f32; n * d];
    for (t, &tok) in tokens[..n].iter().enumerate() {
        let tok = tok as usize;
        if tok >= vcb {
            bail!("token {tok} out of vocab {vcb}");
        }
        let er = &flat[embed_off + tok * d..embed_off + (tok + 1) * d];
        for (i, &e) in er.iter().enumerate() {
            x[t * d + i] = e * scale;
        }
    }

    // one logistic-noise stream per row, shared by every layer (each
    // layer draws its S samples sequentially, in layer order)
    let mut gum_rng = noise.map(|ns| Rng::new(ns.seed));

    let mut tapes: Vec<LayerTape> = Vec::with_capacity(cfg.n_layers);
    for (lo, lp) in model.layer_offsets().iter().zip(&panels.layers) {
        let (h1, mu1, inv1) = ln_fwd(flat, &x, lo.ln1_g, lo.ln1_b, d);

        // gate tape: causal running-mean pooling — the engine's own
        // kernel when deterministic (so tape and serving gates agree
        // bitwise), the Gumbel-sigmoid relaxation during training
        let (m, m_stride) = if !cfg.adaptive {
            (vec![1.0f32; s], 0)
        } else if let (Some(ns), Some(rng)) = (noise, gum_rng.as_mut()) {
            let ba = lo.b_alpha.expect("adaptive layout exposes b_alpha");
            let wat = lp.w_alpha_t.as_ref().expect("adaptive panel has w_alpha_t");
            let inv_temp = 1.0 / ns.temp;
            // one logistic sample per (layer, node), shared across the
            // row's tokens — python's gate() draws the same shape
            let g: Vec<f32> = (0..s)
                .map(|_| {
                    let u = rng.f64().clamp(1e-6, 1.0 - 1e-6);
                    (u.ln() - (1.0 - u).ln()) as f32
                })
                .collect();
            let mut m = vec![0.0f32; n * s];
            let mut pool = vec![0.0f32; d];
            let mut pooled = vec![0.0f32; d];
            for t in 0..n {
                for (p, &h) in pool.iter_mut().zip(&h1[t * d..(t + 1) * d]) {
                    *p += h;
                }
                let invc = 1.0 / (t + 1) as f32;
                for (pe, &p) in pooled.iter_mut().zip(&pool) {
                    *pe = p * invc;
                }
                for k in 0..s {
                    let logit =
                        flat[ba + k] + linalg::dot(&pooled, &wat[k * d..(k + 1) * d]);
                    m[t * s + k] = sigmoid((logit + g[k]) * inv_temp);
                }
            }
            (m, s)
        } else {
            let mut gate_state = vec![0.0f32; d + 1];
            let m = model
                .causal_gate_rows(lo, lp, &h1, n, &mut gate_state)
                .expect("adaptive layout exposes the gate offsets");
            (m, s)
        };

        let mut fraw = vec![0.0f32; n * s];
        linalg::gemm_at(&h1, &lp.w_f_t, &mut fraw, n, d, s);
        let mut v = vec![0.0f32; n * d];
        linalg::gemm_at(&h1, &lp.w_v_t, &mut v, n, d, d);

        // mixer state walk, storing only per-segment state snapshots —
        // the shared token_step kernel guarantees the backward's
        // segment replay reproduces every dropped value bitwise
        let np = model.node_params(lo);
        let (sl, su) = model.mixer().state_lens(cfg);
        let nseg = n.div_ceil(ckpt);
        let mut l_snap = Vec::with_capacity(nseg * sl);
        let mut u_snap = Vec::with_capacity(nseg * su);
        let mut zmix = vec![0.0f32; n * d];
        {
            let mut l = vec![0.0f32; sl];
            let mut u = vec![0.0f32; su];
            for t in 0..n {
                if t % ckpt == 0 {
                    l_snap.extend_from_slice(&l);
                    u_snap.extend_from_slice(&u);
                }
                model.mixer().token_step(
                    &np,
                    s,
                    d,
                    &fraw[t * s..(t + 1) * s],
                    &m[t * m_stride..t * m_stride + s],
                    &mut l,
                    &mut u,
                    &v[t * d..(t + 1) * d],
                    Some(&mut zmix[t * d..(t + 1) * d]),
                );
            }
        }

        let mut x_mid = x.clone();
        linalg::gemm_at(&zmix, &lp.w_o_t, &mut x_mid, n, d, d);

        let (h2, mu2, inv2) = ln_fwd(flat, &x_mid, lo.ln2_g, lo.ln2_b, d);
        let (hpre, hgelu, f_out) = model.ffn_parts(lo, lp, &h2, n, true);
        let mut x_out = x_mid.clone();
        for (xe, fe) in x_out.iter_mut().zip(&f_out) {
            *xe += fe;
        }

        tapes.push(LayerTape {
            x_in: std::mem::replace(&mut x, x_out),
            mu1,
            inv1,
            h1,
            m,
            m_stride,
            fraw,
            v,
            l_snap,
            u_snap,
            zmix,
            x_mid,
            mu2,
            inv2,
            h2,
            hpre: hpre.expect("ffn_parts(want_pre) returns the pre-GELU tape"),
            hgelu,
        });
    }

    let x_last = x;
    let (xf, muf, invf) = ln_fwd(flat, &x_last, lnf_g, lnf_b, d);

    // tied head (the engine's shared kernel) + softmax CE; dlogits
    // computed from the same logits in the same pass
    let logits = model.head_logits(&xf, n);
    let mut nll_sum = 0.0f64;
    let mut dlogits = vec![0.0f32; n * vcb];
    for t in 0..n {
        let lr = &logits[t * vcb..(t + 1) * vcb];
        let tgt = tokens[t + 1] as usize;
        if tgt >= vcb {
            bail!("target {tgt} out of vocab {vcb}");
        }
        let mx = lr.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut denom = 0.0f64;
        for &l in lr {
            denom += ((l - mx) as f64).exp();
        }
        nll_sum += denom.ln() - (lr[tgt] - mx) as f64;
        let dl = &mut dlogits[t * vcb..(t + 1) * vcb];
        let inv_denom = (1.0 / denom) as f32;
        for (v0, l) in dl.iter_mut().zip(lr) {
            *v0 = ce_scale * ((l - mx) as f64).exp() as f32 * inv_denom;
        }
        dl[tgt] -= ce_scale;
    }
    // logits (n·vocab) are dead once dlogits exist — at long contexts
    // keeping them through the layer sweep would dwarf the checkpointed
    // recurrence tape
    drop(logits);

    // ---------------- backward sweep ----------------
    // peak tape: every layer's stored tape plus the segment replay
    // buffers (allocated once below, shared across layers)
    let (sl_r, su_r) = model.mixer().state_lens(cfg);
    let tape_total =
        tapes.iter().map(LayerTape::bytes).sum::<usize>() + 4 * ((ckpt + 1) * (sl_r + su_r));
    let mut grad = vec![0.0f32; flat.len()];

    // tied head: logits = xf @ embedᵀ, so
    //   dxf += dlogits @ embed ; dembed += dlogitsᵀ @ xf
    let embed = &flat[embed_off..embed_off + vcb * d];
    let mut dxf = vec![0.0f32; n * d];
    linalg::gemm(&dlogits, embed, &mut dxf, n, vcb, d);
    linalg::gemm_ta(&dlogits, &xf, &mut grad[embed_off..embed_off + vcb * d], n, vcb, d);
    drop(dlogits); // n·vocab scratch, dead after the head gradients
    let mut dx = ln_bwd(flat, &mut grad, &dxf, &x_last, &muf, &invf, lnf_g, lnf_b, d);

    let mut reg_total = 0.0f32;
    let mut s_eff_sum = 0.0f32;
    // segment replay buffers, shared across layers (every read slot is
    // freshly written per segment — slot 0 from the snapshot, slots
    // 1..len by the replay — so no per-layer zeroing is needed): slot j
    // holds the mixer state after token t0 + j - 1, slot 0 being the
    // checkpointed carry entering the segment (zero for segment 0)
    let mut l_seg = vec![0.0f32; (ckpt + 1) * sl_r];
    let mut u_seg = vec![0.0f32; (ckpt + 1) * su_r];
    // the sweep needs no panels: the `dy @ Wᵀ` products read the
    // original (input-major) weights, which are already in the gemm_at
    // layout for the transposed direction
    let unp = model.mixer().uses_node_params();
    for (lo, tape) in model.layer_offsets().iter().zip(&tapes).rev() {
        let np = model.node_params(lo);
        s_eff_sum += if tape.m_stride == 0 {
            s as f32
        } else {
            tape.m.iter().sum::<f32>() / n as f32
        };

        // --- FFN block: x_out = x_mid + (b2 + gelu(h2 @ w1 + b1) @ w2)
        //   dhid = dx @ w2ᵀ ; dW2 += hgeluᵀ dx ; db2 += Σ_t dx
        let mut dhid = vec![0.0f32; n * hd];
        linalg::gemm_at(&dx, &flat[lo.ffn_w2..lo.ffn_w2 + hd * d], &mut dhid, n, d, hd);
        linalg::gemm_ta(&tape.hgelu, &dx, &mut grad[lo.ffn_w2..lo.ffn_w2 + hd * d], n, hd, d);
        for dxr in dx.chunks_exact(d) {
            for (e, &dxe) in dxr.iter().enumerate() {
                grad[lo.ffn_b2 + e] += dxe;
            }
        }
        // dhpre = dhid ⊙ gelu'(hpre) (in place); db1 += Σ_t dhpre
        for (dh, &hp) in dhid.iter_mut().zip(&tape.hpre) {
            *dh *= gelu_grad(hp);
        }
        for dhr in dhid.chunks_exact(hd) {
            for (j, &dh) in dhr.iter().enumerate() {
                grad[lo.ffn_b1 + j] += dh;
            }
        }
        let mut dh2 = vec![0.0f32; n * d];
        linalg::gemm_at(&dhid, &flat[lo.ffn_w1..lo.ffn_w1 + d * hd], &mut dh2, n, hd, d);
        linalg::gemm_ta(&tape.h2, &dhid, &mut grad[lo.ffn_w1..lo.ffn_w1 + d * hd], n, d, hd);
        let mut dx_mid = ln_bwd(
            flat, &mut grad, &dh2, &tape.x_mid, &tape.mu2, &tape.inv2, lo.ln2_g, lo.ln2_b, d,
        );
        for (a, b) in dx_mid.iter_mut().zip(&dx) {
            *a += b; // residual branch
        }

        // --- mixer block: x_mid = x_in + (zmix @ w_o)
        let mut dzmix = vec![0.0f32; n * d];
        linalg::gemm_at(&dx_mid, &flat[lo.w_o..lo.w_o + d * d], &mut dzmix, n, d, d);
        linalg::gemm_ta(&tape.zmix, &dx_mid, &mut grad[lo.w_o..lo.w_o + d * d], n, d, d);

        // mixer adjoints, segment-checkpointed: the trait impl walks
        // the segments in reverse, replaying each one's state history
        // from its carry snapshot through the same token_step the
        // forward used (bitwise what a full tape would hold, so the
        // gradient is bitwise independent of the segment length), then
        // runs its adjoint recurrence backwards in t. dfraw/dm come
        // back per-token with the fraw ⊙ gate chain rule already split.
        let mut da = vec![0.0f32; s];
        let mut db = vec![0.0f32; s];
        let mut dv = vec![0.0f32; n * d];
        let mut dfraw = vec![0.0f32; n * s];
        let mut dm = vec![0.0f32; n * s];
        let dgamma = model.mixer().backward_chunk(
            &np,
            s,
            d,
            n,
            ckpt,
            &tape.fraw,
            &tape.m,
            tape.m_stride,
            &tape.v,
            &tape.zmix,
            &dzmix,
            &tape.l_snap,
            &tape.u_snap,
            &mut l_seg,
            &mut u_seg,
            &mut dfraw,
            &mut dm,
            &mut dv,
            &mut da,
            &mut db,
        );

        // Eq. Reg penalty on the token-mean gate m̄ (per-row; python
        // couples through the batch mean — identical for m = 1). The
        // omega/sigma terms exist only for node-parameterised mixers.
        let f = flat;
        let inv_n = 1.0 / n as f32;
        let mbar: Vec<f32> = if tape.m_stride == 0 {
            tape.m.clone()
        } else {
            (0..s)
                .map(|k| (0..n).map(|t| tape.m[t * s + k]).sum::<f32>() * inv_n)
                .collect()
        };
        let mut dmbar = vec![0.0f32; s];
        let t_val = softplus(f[lo.t_raw]) + 1.0;
        let sigma: Vec<f32> = (0..s)
            .map(|k| softplus(f[lo.sigma_raw + k]) + cfg.sigma_min)
            .collect();
        let omega: Vec<f32> = (0..s).map(|k| f[lo.omega + k]).collect();
        let mut reg = 0.0f32;
        for k in 0..s {
            if unp {
                reg += cfg.lambda_omega * omega[k].abs() * mbar[k];
                dmbar[k] += reg_scale * cfg.lambda_omega * omega[k].abs();
                if cfg.learn_omega {
                    grad[lo.omega + k] +=
                        reg_scale * cfg.lambda_omega * abs_grad(omega[k]) * mbar[k];
                }
            }
            reg += cfg.lambda_mask * mbar[k];
            dmbar[k] += reg_scale * cfg.lambda_mask;
        }
        let mut dsigma = vec![0.0f32; s];
        if unp {
            for k in 1..s {
                let dsig = sigma[k] - sigma[k - 1];
                reg += cfg.lambda_sigma * dsig * dsig * mbar[k] * mbar[k - 1];
                dmbar[k] += reg_scale * cfg.lambda_sigma * dsig * dsig * mbar[k - 1];
                dmbar[k - 1] += reg_scale * cfg.lambda_sigma * dsig * dsig * mbar[k];
                if cfg.learn_sigma {
                    let c = reg_scale * cfg.lambda_sigma * 2.0 * dsig * mbar[k] * mbar[k - 1];
                    dsigma[k] += c;
                    dsigma[k - 1] -= c;
                }
            }
        }
        reg_total += reg;

        // projections back to h1:
        //   dh1 += dfraw @ w_fᵀ + dv @ w_vᵀ ; dW += h1ᵀ dy
        let mut dh1 = vec![0.0f32; n * d];
        linalg::gemm_at(&dfraw, &flat[lo.w_f..lo.w_f + d * s], &mut dh1, n, s, d);
        linalg::gemm_ta(&tape.h1, &dfraw, &mut grad[lo.w_f..lo.w_f + d * s], n, d, s);
        linalg::gemm_at(&dv, &flat[lo.w_v..lo.w_v + d * d], &mut dh1, n, d, d);
        linalg::gemm_ta(&tape.h1, &dv, &mut grad[lo.w_v..lo.w_v + d * d], n, d, d);

        // adaptive gate backward. Forward (per token t, node k):
        //   pooled_t = (Σ_{t'≤t} h1_{t'}) / (t+1)        (causal pool)
        //   logit_tk = pooled_t @ w_a[:,k] + b_a[k]
        //   m_tk     = sigmoid((logit_tk + g_k) / temp)   (g = 0, temp = 1
        //                                                  when noise is None)
        if cfg.adaptive && tape.m_stride != 0 {
            if let (Some(wa), Some(ba)) = (lo.w_alpha, lo.b_alpha) {
                // the Eq. Reg m̄ adjoint spreads uniformly over tokens
                for t in 0..n {
                    for k in 0..s {
                        dm[t * s + k] += dmbar[k] * inv_n;
                    }
                }
                let inv_temp = noise.map_or(1.0, |ns| 1.0 / ns.temp);
                // pass 1 (forward in t): rebuild the running pool, push
                // dlogit into w_a/b_a, collect the pooled adjoint per t
                let mut dpooled = vec![0.0f32; n * d];
                let mut pool = vec![0.0f32; d];
                let mut pooled = vec![0.0f32; d];
                for t in 0..n {
                    for (p, &h) in pool.iter_mut().zip(&tape.h1[t * d..(t + 1) * d]) {
                        *p += h;
                    }
                    let invc = 1.0 / (t + 1) as f32;
                    for (pe, &p) in pooled.iter_mut().zip(&pool) {
                        *pe = p * invc;
                    }
                    let dpr = &mut dpooled[t * d..(t + 1) * d];
                    for k in 0..s {
                        let m_tk = tape.m[t * s + k];
                        let dlogit = dm[t * s + k] * m_tk * (1.0 - m_tk) * inv_temp;
                        grad[ba + k] += dlogit;
                        for i in 0..d {
                            grad[wa + i * s + k] += pooled[i] * dlogit;
                            dpr[i] += flat[wa + i * s + k] * dlogit;
                        }
                    }
                }
                // pass 2 (reverse in t): pooled_t sums every h1_{t'≤t},
                // so dh1_t = Σ_{t'≥t} dpooled_{t'}/(t'+1) — a suffix scan
                let mut acc = vec![0.0f32; d];
                for t in (0..n).rev() {
                    let invc = 1.0 / (t + 1) as f32;
                    let dpr = &dpooled[t * d..(t + 1) * d];
                    let dhr = &mut dh1[t * d..(t + 1) * d];
                    for i in 0..d {
                        acc[i] += dpr[i] * invc;
                        dhr[i] += acc[i];
                    }
                }
            }
        }

        // node parameters: lam = e^{-(sigma+1/T)} e^{-j omega}, gamma = e^{-1/(8T)}.
        // With lam_re = decay·cosθ, lam_im = -decay·sinθ:
        //   ∂loss/∂decay · decay = da·lam_re + db·lam_im
        //   ∂decay/∂sigma = -decay,   ∂decay/∂T = decay/T²
        //   ∂lam_re/∂θ = lam_im,      ∂lam_im/∂θ = -lam_re
        // Skipped entirely for mixers that never read them (linear
        // attention): their sigma/omega/T gradients stay exactly zero.
        if unp {
            let mut dt = dgamma as f32 * np.gamma / (8.0 * t_val * t_val);
            for k in 0..s {
                let dot = da[k] * np.lam_re[k] + db[k] * np.lam_im[k];
                if cfg.learn_sigma {
                    dsigma[k] += -dot;
                }
                dt += dot / (t_val * t_val);
                if cfg.learn_omega && !cfg.omega_zero {
                    grad[lo.omega + k] += da[k] * np.lam_im[k] - db[k] * np.lam_re[k];
                }
            }
            if cfg.learn_sigma {
                for k in 0..s {
                    grad[lo.sigma_raw + k] += dsigma[k] * sigmoid(f[lo.sigma_raw + k]);
                }
            }
            if cfg.learn_t {
                grad[lo.t_raw] += dt * sigmoid(f[lo.t_raw]);
            }
        }

        // LN1 + residual into the layer input
        let mut dx_in = ln_bwd(
            flat, &mut grad, &dh1, &tape.x_in, &tape.mu1, &tape.inv1, lo.ln1_g, lo.ln1_b, d,
        );
        for (a, b) in dx_in.iter_mut().zip(&dx_mid) {
            *a += b;
        }
        dx = dx_in;
    }

    // embedding input: x0 = embed[tok] * sqrt(d)
    for (t, &tok) in tokens[..n].iter().enumerate() {
        let tok = tok as usize;
        let ger = &mut grad[embed_off + tok * d..embed_off + (tok + 1) * d];
        let dxr = &dx[t * d..(t + 1) * d];
        for (g, &dxe) in ger.iter_mut().zip(dxr) {
            *g += dxe * scale;
        }
    }

    Ok(RowOut {
        nll_sum,
        reg: reg_total,
        s_eff: s_eff_sum / cfg.n_layers as f32,
        grad,
        tape_bytes: tape_total,
    })
}

/// d|x|/dx with the subgradient 1 at x = 0 — jax's `abs` convention, so
/// the omega Eq. Reg gradient matches the reference (and the lowered
/// HLO the xla backend executes) even at exactly-zero omega, the
/// omega_zero init. Verified against jax.value_and_grad in-session.
fn abs_grad(x: f32) -> f32 {
    if x >= 0.0 {
        1.0
    } else {
        -1.0
    }
}
