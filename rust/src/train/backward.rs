//! Hand-derived reverse-mode differentiation of the native STLT trunk.
//!
//! The forward ([`row_loss_and_grad`]) replays the exact semantics of
//! [`StltModel::trunk_chunk`] on one row (full sequence, zero carry,
//! deterministic gate) while recording a tape of activations; the
//! backward sweep then produces the gradient of
//!
//!   loss_row = ce_scale · Σ_t nll_t + reg_scale · reg_row
//!
//! with respect to the *entire* flat parameter vector — embeddings,
//! LayerNorms, FFN and mixer projections, the adaptive gate, and the
//! Laplace-node parameters (sigma_raw, omega, t_raw).
//!
//! Every matmul here — the tape forward's projections (run on the same
//! pre-transposed weight panels as the engine, via the shared
//! [`StltModel::gate_full`]/[`StltModel::ffn_parts`]/
//! [`StltModel::head_logits`] helpers) and the backward sweep's
//! `dy @ Wᵀ` / `xᵀ dy` adjoint products — goes through the blocked
//! kernels in [`crate::util::linalg`]. One kernel family on both sides
//! of the tape means the gradient can never be taken of a subtly
//! different network than the engine serves (`tests/native_train.rs`
//! pins tape-vs-engine NLL parity).
//!
//! The interesting part is the recurrence. Per node k (lam = lam_re +
//! j·lam_im, discount gamma, all derived from sigma/omega/T):
//!
//!   L_t = lam · L_{t-1} + f_t
//!   U_t = gamma · U_{t-1} + conj(L_t) ⊗ v_t
//!   z_t = Re⟨L_t, U_t⟩ / S
//!
//! Running the adjoints GL_t = ∂loss/∂L_t and GU_t = ∂loss/∂U_t
//! *backwards* in t gives an exact O(N·S·d) gradient — the same
//! linear-attention trick (Katharopoulos et al.) the forward exploits,
//! transposed in time. No autograd framework is involved; correctness
//! is pinned by finite-difference checks against an independent f64
//! oracle in `tests/native_train.rs`.
//!
//! ## Segment-checkpointed tape
//!
//! The naive tape stores U_t for every t — O(N·S·d) floats per layer,
//! the term that makes long-context training OOM long before the
//! forward does. Instead, the tape forward records only the (L, U)
//! carry at every `grad_ckpt_segment`-token boundary (the same carry
//! `trunk_chunk` threads through chunked streaming), and the backward
//! replays each segment's L/U history on the fly, in reverse segment
//! order, from its snapshot — through the *same*
//! [`crate::runtime::native_stlt`] `lu_node_step` kernel the forward
//! and the streaming engine use, so the replayed values are bitwise
//! identical to what a full tape would have stored and the gradient is
//! bitwise independent of the segment length
//! (`tests/native_train.rs`). Peak tape memory drops from O(N·S·d) to
//! O(C·S·d + (N/C)·S·d) per layer for segment length C, at the cost of
//! one extra forward recurrence replay (~the cheap part of the
//! backward; the GEMMs are never replayed). `grad_ckpt_segment = 0`
//! (default) means one whole-sequence segment: the replay buffer is
//! then O(N·S·d), but only ONE layer's buffer is alive at a time —
//! already an n_layers× improvement over the old always-resident
//! per-layer U tape — and the replay sweep applies there too.
//! [`tape_bytes`] is the exact accounting, asserted against the real
//! allocations in tests.
//!
//! Ablation flags mirror `stlt_layer.node_params`/`regulariser`:
//! `learn_sigma=false` (resp. omega, t) zeroes that group's gradient
//! from both the model path and the Eq. Reg penalty.
//!
//! Training-vs-python deviations (documented in rust/README.md):
//! adaptive gating uses the deterministic sigmoid alpha (no
//! Gumbel-sigmoid noise), and the Eq. Reg mask coupling is per-row
//! (python couples through the batch-mean gate); for non-adaptive
//! configs both reductions are identical.

use anyhow::{bail, Result};

use crate::runtime::artifact::ModelConfig;
use crate::runtime::native_stlt::{lu_node_step, sigmoid, softplus, StltModel};
use crate::util::linalg::{self, gelu_grad};

static SEGMENTS_REPLAYED: crate::obs::LazyCounter =
    crate::obs::LazyCounter::new("train/segments_replayed");

/// Gradient + loss terms of one row. `grad` has the full flat length.
pub struct RowOut {
    pub nll_sum: f64,
    /// unscaled Eq. Reg penalty of this row (sum over layers)
    pub reg: f32,
    /// mean over layers of the active node count Σ_k m_k
    pub s_eff: f32,
    pub grad: Vec<f32>,
    /// peak activation-tape bytes this row allocated (stored layer
    /// tapes + the backward's segment replay buffers); equals
    /// [`tape_bytes`] for the model's config and this row's length
    pub tape_bytes: usize,
}

/// Activations of one layer recorded during the tape forward. The
/// Laplace recurrence contributes only O((N/C)·S·d) carry snapshots —
/// the per-timestep U history is replayed per segment during the
/// backward, never stored whole.
struct LayerTape {
    x_in: Vec<f32>,   // [n,d] residual stream entering the layer
    mu1: Vec<f32>,    // [n] LN1 means
    inv1: Vec<f32>,   // [n] LN1 inverse stddevs
    h1: Vec<f32>,     // [n,d] LN1 output (mixer input)
    pooled: Vec<f32>, // [d] mean-pooled h1 (adaptive only, else empty)
    m: Vec<f32>,      // [S] node gate
    fraw: Vec<f32>,   // [n,S] pre-gate feature projection h1 @ w_f
    v: Vec<f32>,      // [n,d] value projection h1 @ w_v
    l_snap: Vec<f32>, // [nseg,S,2] L carry entering each segment
    u_snap: Vec<f32>, // [nseg,S,d,2] U carry entering each segment
    zmix: Vec<f32>,   // [n,d] mixed output pre-w_o
    x_mid: Vec<f32>,  // [n,d] residual stream after the mixer
    mu2: Vec<f32>,
    inv2: Vec<f32>,
    h2: Vec<f32>,    // [n,d] LN2 output (FFN input)
    hpre: Vec<f32>,  // [n,hd] FFN pre-GELU activations
    hgelu: Vec<f32>, // [n,hd] gelu(hpre), reused for the w2 gradient
}

impl LayerTape {
    fn bytes(&self) -> usize {
        4 * (self.x_in.len()
            + self.mu1.len()
            + self.inv1.len()
            + self.h1.len()
            + self.pooled.len()
            + self.m.len()
            + self.fraw.len()
            + self.v.len()
            + self.l_snap.len()
            + self.u_snap.len()
            + self.zmix.len()
            + self.x_mid.len()
            + self.mu2.len()
            + self.inv2.len()
            + self.h2.len()
            + self.hpre.len()
            + self.hgelu.len())
    }
}

/// Resolved checkpoint segment length for a row of `n` tokens:
/// `grad_ckpt_segment` clamped to [1, n], with 0 meaning "one segment
/// covering the whole sequence".
pub fn seg_len(cfg: &ModelConfig, n: usize) -> usize {
    match cfg.grad_ckpt_segment {
        0 => n.max(1),
        c => c.min(n.max(1)),
    }
}

/// Exact activation-tape bytes [`row_loss_and_grad`] allocates for one
/// row of `n` tokens: the stored per-layer tapes (everything in
/// `LayerTape`, dominated by the O((N/C)·S·d) carry snapshots once the
/// O(N·S·d) U history is checkpointed away) plus the backward's
/// segment replay buffers (O(C·S·d), one pair shared across all
/// layers). Asserted equal to the real tape allocation in
/// `tests/native_train.rs`. Scope: this counts the *tape* — the
/// backward additionally holds transient gradient scratch on top: two
/// n·vocab buffers (logits + dlogits) during the CE/head phase, both
/// freed before the layer sweep, then per-layer `dhid` [n·hd] and
/// `dfp`/`dv`/`dzmix` [n·S / n·d] buffers. Treat row-fits-in-RAM
/// budgets as tape_bytes + max(2·n·vocab, a few n·hd/n·d) f32s.
pub fn tape_bytes(cfg: &ModelConfig, n: usize) -> usize {
    let (s, d) = (cfg.s_max, cfg.d_model);
    let hd = d * cfg.ffn_mult.max(1);
    let c = seg_len(cfg, n);
    let nseg = n.max(1).div_ceil(c);
    let pooled = if cfg.adaptive { d } else { 0 };
    // x_in/h1/v/zmix/x_mid/h2 are [n,d]; hpre/hgelu [n,hd]; fraw [n,S];
    // mu/inv ×4 [n]; m [S]; snapshots [nseg,S,(2+2d)]
    let per_layer =
        n * (6 * d + 2 * hd + s + 4) + nseg * s * (2 + 2 * d) + s + pooled;
    // backward replay: (C+1) slots of (L [S,2], U [S,d,2])
    let replay = (c + 1) * s * (2 + 2 * d);
    4 * (cfg.n_layers * per_layer + replay)
}

/// LayerNorm forward recording (mu, inv) per row for the backward.
fn ln_fwd(
    flat: &[f32],
    x: &[f32],
    g_off: usize,
    b_off: usize,
    d: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let n = x.len() / d;
    let mut y = vec![0.0f32; n * d];
    let mut mus = vec![0.0f32; n];
    let mut invs = vec![0.0f32; n];
    for t in 0..n {
        let row = &x[t * d..(t + 1) * d];
        let mu = row.iter().sum::<f32>() / d as f32;
        let var = row.iter().map(|&x| (x - mu) * (x - mu)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + 1e-5).sqrt();
        mus[t] = mu;
        invs[t] = inv;
        let orow = &mut y[t * d..(t + 1) * d];
        for i in 0..d {
            orow[i] = (row[i] - mu) * inv * flat[g_off + i] + flat[b_off + i];
        }
    }
    (y, mus, invs)
}

/// LayerNorm backward: returns dx; accumulates dgain/dbias into `grad`.
fn ln_bwd(
    flat: &[f32],
    grad: &mut [f32],
    dy: &[f32],
    x: &[f32],
    mus: &[f32],
    invs: &[f32],
    g_off: usize,
    b_off: usize,
    d: usize,
) -> Vec<f32> {
    let n = x.len() / d;
    let mut dx = vec![0.0f32; n * d];
    for t in 0..n {
        let (mu, inv) = (mus[t], invs[t]);
        let xr = &x[t * d..(t + 1) * d];
        let dyr = &dy[t * d..(t + 1) * d];
        let mut mq = 0.0f32; // mean of q = dy * gain
        let mut mqx = 0.0f32; // mean of q * xhat
        for i in 0..d {
            let xhat = (xr[i] - mu) * inv;
            let q = dyr[i] * flat[g_off + i];
            grad[g_off + i] += dyr[i] * xhat;
            grad[b_off + i] += dyr[i];
            mq += q;
            mqx += q * xhat;
        }
        mq /= d as f32;
        mqx /= d as f32;
        let dxr = &mut dx[t * d..(t + 1) * d];
        for i in 0..d {
            let xhat = (xr[i] - mu) * inv;
            let q = dyr[i] * flat[g_off + i];
            dxr[i] = (q - mq - xhat * mqx) * inv;
        }
    }
    dx
}

/// Per-row loss + full-flat-vector gradient (see module docs).
///
/// `tokens` is one `[n+1]` next-token row; the loss is
/// `ce_scale · Σ nll + reg_scale · reg_row`, so a caller accumulating a
/// `[B, N+1]` batch passes `ce_scale = 1/(B·N)` and `reg_scale = 1/B`
/// to reproduce `trunk.lm_loss` exactly (for non-adaptive configs).
pub fn row_loss_and_grad(
    model: &StltModel,
    tokens: &[i32],
    ce_scale: f32,
    reg_scale: f32,
) -> Result<RowOut> {
    if tokens.len() < 2 {
        bail!("training row needs at least 2 tokens");
    }
    let cfg = &model.cfg;
    let (s, d, vcb) = (cfg.s_max, cfg.d_model, cfg.vocab);
    let hd = d * cfg.ffn_mult.max(1);
    let n = tokens.len() - 1;
    let ckpt = seg_len(cfg, n);
    let flat = model.flat_params();
    let panels = model.panels();
    let (embed_off, lnf_g, lnf_b) = model.head_offsets();
    let scale = (d as f32).sqrt();

    // ---------------- forward with tape ----------------
    let mut x = vec![0.0f32; n * d];
    for (t, &tok) in tokens[..n].iter().enumerate() {
        let tok = tok as usize;
        if tok >= vcb {
            bail!("token {tok} out of vocab {vcb}");
        }
        let er = &flat[embed_off + tok * d..embed_off + (tok + 1) * d];
        for (i, &e) in er.iter().enumerate() {
            x[t * d + i] = e * scale;
        }
    }

    let mut tapes: Vec<LayerTape> = Vec::with_capacity(cfg.n_layers);
    for (lo, lp) in model.layer_offsets().iter().zip(&panels.layers) {
        let (h1, mu1, inv1) = ln_fwd(flat, &x, lo.ln1_g, lo.ln1_b, d);

        // gate (deterministic alpha; all-ones when not adaptive) —
        // the engine's own kernel, so tape and serving gates agree
        let (m, pooled) = model.gate_full(lo, lp, &h1, n);

        let mut fraw = vec![0.0f32; n * s];
        linalg::gemm_at(&h1, &lp.w_f_t, &mut fraw, n, d, s);
        let mut v = vec![0.0f32; n * d];
        linalg::gemm_at(&h1, &lp.w_v_t, &mut v, n, d, d);

        // recurrence, storing only per-segment (L, U) carry snapshots —
        // the shared lu_node_step kernel guarantees the backward's
        // segment replay reproduces every dropped value bitwise
        let np = model.node_params(lo);
        let inv_s = 1.0 / s as f32;
        let nseg = n.div_ceil(ckpt);
        let mut l_snap = Vec::with_capacity(nseg * s * 2);
        let mut u_snap = Vec::with_capacity(nseg * s * d * 2);
        let mut zmix = vec![0.0f32; n * d];
        {
            let mut l = vec![0.0f32; s * 2];
            let mut u = vec![0.0f32; s * d * 2];
            for t in 0..n {
                if t % ckpt == 0 {
                    l_snap.extend_from_slice(&l);
                    u_snap.extend_from_slice(&u);
                }
                let vr = &v[t * d..(t + 1) * d];
                let zr = &mut zmix[t * d..(t + 1) * d];
                for k in 0..s {
                    lu_node_step(
                        np.lam_re[k],
                        np.lam_im[k],
                        np.gamma,
                        fraw[t * s + k] * m[k],
                        &mut l[k * 2..(k + 1) * 2],
                        &mut u[k * d * 2..(k + 1) * d * 2],
                        vr,
                        Some(&mut zr[..]),
                    );
                }
                for ze in zr.iter_mut() {
                    *ze *= inv_s;
                }
            }
        }

        let mut x_mid = x.clone();
        linalg::gemm_at(&zmix, &lp.w_o_t, &mut x_mid, n, d, d);

        let (h2, mu2, inv2) = ln_fwd(flat, &x_mid, lo.ln2_g, lo.ln2_b, d);
        let (hpre, hgelu, f_out) = model.ffn_parts(lo, lp, &h2, n, true);
        let mut x_out = x_mid.clone();
        for (xe, fe) in x_out.iter_mut().zip(&f_out) {
            *xe += fe;
        }

        tapes.push(LayerTape {
            x_in: std::mem::replace(&mut x, x_out),
            mu1,
            inv1,
            h1,
            pooled,
            m,
            fraw,
            v,
            l_snap,
            u_snap,
            zmix,
            x_mid,
            mu2,
            inv2,
            h2,
            hpre: hpre.expect("ffn_parts(want_pre) returns the pre-GELU tape"),
            hgelu,
        });
    }

    let x_last = x;
    let (xf, muf, invf) = ln_fwd(flat, &x_last, lnf_g, lnf_b, d);

    // tied head (the engine's shared kernel) + softmax CE; dlogits
    // computed from the same logits in the same pass
    let logits = model.head_logits(&xf, n);
    let mut nll_sum = 0.0f64;
    let mut dlogits = vec![0.0f32; n * vcb];
    for t in 0..n {
        let lr = &logits[t * vcb..(t + 1) * vcb];
        let tgt = tokens[t + 1] as usize;
        if tgt >= vcb {
            bail!("target {tgt} out of vocab {vcb}");
        }
        let mx = lr.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut denom = 0.0f64;
        for &l in lr {
            denom += ((l - mx) as f64).exp();
        }
        nll_sum += denom.ln() - (lr[tgt] - mx) as f64;
        let dl = &mut dlogits[t * vcb..(t + 1) * vcb];
        let inv_denom = (1.0 / denom) as f32;
        for (v0, l) in dl.iter_mut().zip(lr) {
            *v0 = ce_scale * ((l - mx) as f64).exp() as f32 * inv_denom;
        }
        dl[tgt] -= ce_scale;
    }
    // logits (n·vocab) are dead once dlogits exist — at long contexts
    // keeping them through the layer sweep would dwarf the checkpointed
    // recurrence tape
    drop(logits);

    // ---------------- backward sweep ----------------
    // peak tape: every layer's stored tape plus the segment replay
    // buffers (allocated once below, shared across layers)
    let tape_total = tapes.iter().map(LayerTape::bytes).sum::<usize>()
        + 4 * ((ckpt + 1) * s * (2 + 2 * d));
    let mut grad = vec![0.0f32; flat.len()];

    // tied head: logits = xf @ embedᵀ, so
    //   dxf += dlogits @ embed ; dembed += dlogitsᵀ @ xf
    let embed = &flat[embed_off..embed_off + vcb * d];
    let mut dxf = vec![0.0f32; n * d];
    linalg::gemm(&dlogits, embed, &mut dxf, n, vcb, d);
    linalg::gemm_ta(&dlogits, &xf, &mut grad[embed_off..embed_off + vcb * d], n, vcb, d);
    drop(dlogits); // n·vocab scratch, dead after the head gradients
    let mut dx = ln_bwd(flat, &mut grad, &dxf, &x_last, &muf, &invf, lnf_g, lnf_b, d);

    let mut reg_total = 0.0f32;
    let mut s_eff_sum = 0.0f32;
    // segment replay buffers, shared across layers (every read slot is
    // freshly written per segment — slot 0 from the snapshot, slots
    // 1..len by the replay — so no per-layer zeroing is needed): slot j
    // holds the (L, U) state after token t0 + j - 1, slot 0 being the
    // checkpointed carry entering the segment (zero for segment 0)
    let mut l_seg = vec![0.0f32; (ckpt + 1) * s * 2];
    let mut u_seg = vec![0.0f32; (ckpt + 1) * s * d * 2];
    // the sweep needs no panels: the `dy @ Wᵀ` products read the
    // original (input-major) weights, which are already in the gemm_at
    // layout for the transposed direction
    for (lo, tape) in model.layer_offsets().iter().zip(&tapes).rev() {
        let np = model.node_params(lo);
        s_eff_sum += tape.m.iter().sum::<f32>();

        // --- FFN block: x_out = x_mid + (b2 + gelu(h2 @ w1 + b1) @ w2)
        //   dhid = dx @ w2ᵀ ; dW2 += hgeluᵀ dx ; db2 += Σ_t dx
        let mut dhid = vec![0.0f32; n * hd];
        linalg::gemm_at(&dx, &flat[lo.ffn_w2..lo.ffn_w2 + hd * d], &mut dhid, n, d, hd);
        linalg::gemm_ta(&tape.hgelu, &dx, &mut grad[lo.ffn_w2..lo.ffn_w2 + hd * d], n, hd, d);
        for dxr in dx.chunks_exact(d) {
            for (e, &dxe) in dxr.iter().enumerate() {
                grad[lo.ffn_b2 + e] += dxe;
            }
        }
        // dhpre = dhid ⊙ gelu'(hpre) (in place); db1 += Σ_t dhpre
        for (dh, &hp) in dhid.iter_mut().zip(&tape.hpre) {
            *dh *= gelu_grad(hp);
        }
        for dhr in dhid.chunks_exact(hd) {
            for (j, &dh) in dhr.iter().enumerate() {
                grad[lo.ffn_b1 + j] += dh;
            }
        }
        let mut dh2 = vec![0.0f32; n * d];
        linalg::gemm_at(&dhid, &flat[lo.ffn_w1..lo.ffn_w1 + d * hd], &mut dh2, n, hd, d);
        linalg::gemm_ta(&tape.h2, &dhid, &mut grad[lo.ffn_w1..lo.ffn_w1 + d * hd], n, d, hd);
        let mut dx_mid = ln_bwd(
            flat, &mut grad, &dh2, &tape.x_mid, &tape.mu2, &tape.inv2, lo.ln2_g, lo.ln2_b, d,
        );
        for (a, b) in dx_mid.iter_mut().zip(&dx) {
            *a += b; // residual branch
        }

        // --- mixer block: x_mid = x_in + (zmix @ w_o)
        let mut dzmix = vec![0.0f32; n * d];
        linalg::gemm_at(&dx_mid, &flat[lo.w_o..lo.w_o + d * d], &mut dzmix, n, d, d);
        linalg::gemm_ta(&tape.zmix, &dx_mid, &mut grad[lo.w_o..lo.w_o + d * d], n, d, d);

        // recurrence adjoints, segment-checkpointed: walk the segments
        // in reverse, replaying each one's (L, U) history from its
        // carry snapshot via the engine's own lu_node_step — the
        // replayed values are bitwise what a full tape would hold, so
        // the gradient is bitwise independent of the segment length.
        // The GL/GU adjoint carries thread across segment boundaries
        // exactly like the forward carries did, just reversed in time.
        let inv_s = 1.0 / s as f32;
        let mut gl = vec![0.0f32; s * 2];
        let mut gu = vec![0.0f32; s * d * 2];
        let mut da = vec![0.0f32; s];
        let mut db = vec![0.0f32; s];
        let mut dgamma = 0.0f64;
        let mut dfp = vec![0.0f32; n * s];
        let mut dv = vec![0.0f32; n * d];
        let nseg = n.div_ceil(ckpt);
        for seg in (0..nseg).rev() {
            let _span = crate::obs::span("train", "segment_replay");
            SEGMENTS_REPLAYED.inc();
            let t0 = seg * ckpt;
            let len = ckpt.min(n - t0);
            l_seg[..s * 2].copy_from_slice(&tape.l_snap[seg * s * 2..(seg + 1) * s * 2]);
            u_seg[..s * d * 2]
                .copy_from_slice(&tape.u_snap[seg * s * d * 2..(seg + 1) * s * d * 2]);
            for j in 0..len {
                let t = t0 + j;
                let (ldone, lrest) = l_seg.split_at_mut((j + 1) * s * 2);
                let lcur = &mut lrest[..s * 2];
                lcur.copy_from_slice(&ldone[j * s * 2..]);
                let (udone, urest) = u_seg.split_at_mut((j + 1) * s * d * 2);
                let ucur = &mut urest[..s * d * 2];
                ucur.copy_from_slice(&udone[j * s * d * 2..]);
                let vr = &tape.v[t * d..(t + 1) * d];
                for k in 0..s {
                    lu_node_step(
                        np.lam_re[k],
                        np.lam_im[k],
                        np.gamma,
                        tape.fraw[t * s + k] * tape.m[k],
                        &mut lcur[k * 2..(k + 1) * 2],
                        &mut ucur[k * d * 2..(k + 1) * d * 2],
                        vr,
                        None, // replay advances L/U only; z is never re-needed
                    );
                }
            }
            for j in (0..len).rev() {
                let t = t0 + j;
                let lrow = &l_seg[(j + 1) * s * 2..(j + 2) * s * 2];
                let urow = &u_seg[(j + 1) * s * d * 2..(j + 2) * s * d * 2];
                // slot j: the state before t — for the global t = 0 this
                // is the zero carry, so its adjoint terms add exact
                // zeros, matching the old tape's explicit t = 0 skip
                let lprev = &l_seg[j * s * 2..(j + 1) * s * 2];
                let uprev = &u_seg[j * s * d * 2..(j + 1) * s * d * 2];
                let vr = &tape.v[t * d..(t + 1) * d];
                let dvr = &mut dv[t * d..(t + 1) * d];
                let zg = &dzmix[t * d..(t + 1) * d];
                for k in 0..s {
                    let (ltr, lti) = (lrow[k * 2], lrow[k * 2 + 1]);
                    let ub = &urow[k * d * 2..(k + 1) * d * 2];
                    let up = &uprev[k * d * 2..(k + 1) * d * 2];
                    let gub = &mut gu[k * d * 2..(k + 1) * d * 2];
                    let (mut glr, mut gli) = (gl[k * 2], gl[k * 2 + 1]);
                    let mut dg_loc = 0.0f64;
                    for e in 0..d {
                        let g_te = zg[e] * inv_s;
                        // z_t = Σ_k Re(L_t · U_t)/S
                        let gur = gub[e * 2] + g_te * ltr;
                        let gui = gub[e * 2 + 1] - g_te * lti;
                        glr += g_te * ub[e * 2];
                        gli -= g_te * ub[e * 2 + 1];
                        // U_t = gamma U_{t-1} + conj(L_t) v_t
                        dg_loc += (gur * up[e * 2]) as f64 + (gui * up[e * 2 + 1]) as f64;
                        let ve = vr[e];
                        dvr[e] += gur * ltr - gui * lti;
                        glr += gur * ve;
                        gli -= gui * ve;
                        gub[e * 2] = np.gamma * gur;
                        gub[e * 2 + 1] = np.gamma * gui;
                    }
                    dgamma += dg_loc;
                    // L_t = lam L_{t-1} + f_t
                    dfp[t * s + k] += glr;
                    let (lpr, lpi) = (lprev[k * 2], lprev[k * 2 + 1]);
                    da[k] += glr * lpr + gli * lpi;
                    db[k] += -glr * lpi + gli * lpr;
                    let (a, b) = (np.lam_re[k], np.lam_im[k]);
                    gl[k * 2] = a * glr + b * gli;
                    gl[k * 2 + 1] = -b * glr + a * gli;
                }
            }
        }

        // f = fraw ⊙ m
        let mut dm = vec![0.0f32; s];
        let mut dfraw = vec![0.0f32; n * s];
        for t in 0..n {
            for k in 0..s {
                let dfp_tk = dfp[t * s + k];
                dfraw[t * s + k] = dfp_tk * tape.m[k];
                dm[k] += dfp_tk * tape.fraw[t * s + k];
            }
        }

        // Eq. Reg penalty (per-row gate; identical to python for m = 1)
        let f = flat;
        let t_val = softplus(f[lo.t_raw]) + 1.0;
        let sigma: Vec<f32> = (0..s)
            .map(|k| softplus(f[lo.sigma_raw + k]) + cfg.sigma_min)
            .collect();
        let omega: Vec<f32> = (0..s).map(|k| f[lo.omega + k]).collect();
        let mut reg = 0.0f32;
        for k in 0..s {
            reg += cfg.lambda_omega * omega[k].abs() * tape.m[k];
            reg += cfg.lambda_mask * tape.m[k];
            dm[k] += reg_scale * (cfg.lambda_omega * omega[k].abs() + cfg.lambda_mask);
            if cfg.learn_omega {
                grad[lo.omega + k] +=
                    reg_scale * cfg.lambda_omega * abs_grad(omega[k]) * tape.m[k];
            }
        }
        let mut dsigma = vec![0.0f32; s];
        for k in 1..s {
            let dsig = sigma[k] - sigma[k - 1];
            reg += cfg.lambda_sigma * dsig * dsig * tape.m[k] * tape.m[k - 1];
            dm[k] += reg_scale * cfg.lambda_sigma * dsig * dsig * tape.m[k - 1];
            dm[k - 1] += reg_scale * cfg.lambda_sigma * dsig * dsig * tape.m[k];
            if cfg.learn_sigma {
                let c = reg_scale * cfg.lambda_sigma * 2.0 * dsig * tape.m[k] * tape.m[k - 1];
                dsigma[k] += c;
                dsigma[k - 1] -= c;
            }
        }
        reg_total += reg;

        // projections back to h1:
        //   dh1 += dfraw @ w_fᵀ + dv @ w_vᵀ ; dW += h1ᵀ dy
        let mut dh1 = vec![0.0f32; n * d];
        linalg::gemm_at(&dfraw, &flat[lo.w_f..lo.w_f + d * s], &mut dh1, n, s, d);
        linalg::gemm_ta(&tape.h1, &dfraw, &mut grad[lo.w_f..lo.w_f + d * s], n, d, s);
        linalg::gemm_at(&dv, &flat[lo.w_v..lo.w_v + d * d], &mut dh1, n, d, d);
        linalg::gemm_ta(&tape.h1, &dv, &mut grad[lo.w_v..lo.w_v + d * d], n, d, d);

        // adaptive gate backward: m = sigmoid(pooled @ w_a + b_a)
        if cfg.adaptive {
            if let (Some(wa), Some(ba)) = (lo.w_alpha, lo.b_alpha) {
                let mut dpooled = vec![0.0f32; d];
                for k in 0..s {
                    let dlogit = dm[k] * tape.m[k] * (1.0 - tape.m[k]);
                    grad[ba + k] += dlogit;
                    for i in 0..d {
                        grad[wa + i * s + k] += tape.pooled[i] * dlogit;
                        dpooled[i] += flat[wa + i * s + k] * dlogit;
                    }
                }
                let inv_n = 1.0 / n as f32;
                for t in 0..n {
                    let dhr = &mut dh1[t * d..(t + 1) * d];
                    for (i, &dp) in dpooled.iter().enumerate() {
                        dhr[i] += dp * inv_n;
                    }
                }
            }
        }

        // node parameters: lam = e^{-(sigma+1/T)} e^{-j omega}, gamma = e^{-1/(8T)}.
        // With lam_re = decay·cosθ, lam_im = -decay·sinθ:
        //   ∂loss/∂decay · decay = da·lam_re + db·lam_im
        //   ∂decay/∂sigma = -decay,   ∂decay/∂T = decay/T²
        //   ∂lam_re/∂θ = lam_im,      ∂lam_im/∂θ = -lam_re
        let mut dt = dgamma as f32 * np.gamma / (8.0 * t_val * t_val);
        for k in 0..s {
            let dot = da[k] * np.lam_re[k] + db[k] * np.lam_im[k];
            if cfg.learn_sigma {
                dsigma[k] += -dot;
            }
            dt += dot / (t_val * t_val);
            if cfg.learn_omega && !cfg.omega_zero {
                grad[lo.omega + k] += da[k] * np.lam_im[k] - db[k] * np.lam_re[k];
            }
        }
        if cfg.learn_sigma {
            for k in 0..s {
                grad[lo.sigma_raw + k] += dsigma[k] * sigmoid(f[lo.sigma_raw + k]);
            }
        }
        if cfg.learn_t {
            grad[lo.t_raw] += dt * sigmoid(f[lo.t_raw]);
        }

        // LN1 + residual into the layer input
        let mut dx_in = ln_bwd(
            flat, &mut grad, &dh1, &tape.x_in, &tape.mu1, &tape.inv1, lo.ln1_g, lo.ln1_b, d,
        );
        for (a, b) in dx_in.iter_mut().zip(&dx_mid) {
            *a += b;
        }
        dx = dx_in;
    }

    // embedding input: x0 = embed[tok] * sqrt(d)
    for (t, &tok) in tokens[..n].iter().enumerate() {
        let tok = tok as usize;
        let ger = &mut grad[embed_off + tok * d..embed_off + (tok + 1) * d];
        let dxr = &dx[t * d..(t + 1) * d];
        for (g, &dxe) in ger.iter_mut().zip(dxr) {
            *g += dxe * scale;
        }
    }

    Ok(RowOut {
        nll_sum,
        reg: reg_total,
        s_eff: s_eff_sum / cfg.n_layers as f32,
        grad,
        tape_bytes: tape_total,
    })
}

/// d|x|/dx with the subgradient 1 at x = 0 — jax's `abs` convention, so
/// the omega Eq. Reg gradient matches the reference (and the lowered
/// HLO the xla backend executes) even at exactly-zero omega, the
/// omega_zero init. Verified against jax.value_and_grad in-session.
fn abs_grad(x: f32) -> f32 {
    if x >= 0.0 {
        1.0
    } else {
        -1.0
    }
}
