//! Pure-Rust AdamW + LR schedule + global-norm clipping, mirroring
//! `python/compile/optim.py` (the graph the XLA backend carries inside
//! its lowered HLO) so a training run can move between backends without
//! changing optimiser semantics:
//!
//!   lr(step)  = linear warmup to `lr`, then cosine decay to 0.1·lr
//!   clip      = g · min(1, grad_clip / max(‖g‖₂, 1e-12))
//!   m         = β₁ m + (1−β₁) g
//!   v         = β₂ v + (1−β₂) g²
//!   update    = m̂/(√v̂ + eps) + weight_decay · θ     (decoupled decay)
//!   θ        -= lr(step) · update
//!
//! with bias correction m̂ = m/(1−β₁ᵗ), v̂ = v/(1−β₂ᵗ) at the 1-based
//! update index t = step+1 — exactly the indices `train.make_train_step`
//! passes. All elementwise state is f32 like the XLA path; the one
//! documented deviation is the global norm, accumulated in f64 for
//! stability on multi-million-parameter vectors.

use crate::runtime::artifact::ModelConfig;

/// Optimiser hyperparameters, lifted from the manifest [`ModelConfig`]
/// (python `config.py` defaults apply when a manifest omits them).
#[derive(Clone, Copy, Debug)]
pub struct AdamHp {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    pub grad_clip: f32,
    pub warmup: u64,
    pub total_steps: u64,
}

impl AdamHp {
    pub fn from_config(cfg: &ModelConfig) -> AdamHp {
        AdamHp {
            lr: cfg.lr,
            beta1: cfg.beta1,
            beta2: cfg.beta2,
            eps: 1e-8,
            weight_decay: cfg.weight_decay,
            grad_clip: cfg.grad_clip,
            warmup: cfg.warmup,
            total_steps: cfg.total_steps,
        }
    }

    /// `optim.lr_schedule(step, ...)`: `step` is the pre-update counter
    /// (0 on the first call), like the scalar the Rust driver feeds the
    /// XLA `train_step`.
    pub fn lr_at(&self, step: i32) -> f32 {
        let s = step as f32;
        let warm = self.lr * s / (self.warmup as f32).max(1.0);
        let denom = (self.total_steps as f64 - self.warmup as f64).max(1.0) as f32;
        let prog = ((s - self.warmup as f32) / denom).clamp(0.0, 1.0);
        let cos = self.lr * (0.1 + 0.9 * 0.5 * (1.0 + (std::f32::consts::PI * prog).cos()));
        if s < self.warmup as f32 {
            warm
        } else {
            cos
        }
    }
}

/// One AdamW step in place. `step` is the pre-update counter (the value
/// the schedule sees); bias correction uses t = step+1. Returns the
/// pre-clip global gradient norm.
pub fn adamw_step(
    hp: &AdamHp,
    step: i32,
    flat: &mut [f32],
    m: &mut [f32],
    v: &mut [f32],
    grad: &mut [f32],
) -> f32 {
    debug_assert_eq!(flat.len(), grad.len());
    debug_assert_eq!(flat.len(), m.len());
    debug_assert_eq!(flat.len(), v.len());
    let norm = (grad.iter().map(|&g| g as f64 * g as f64).sum::<f64>()).sqrt() as f32;
    if hp.grad_clip > 0.0 {
        let scale = (hp.grad_clip / norm.max(1e-12)).min(1.0);
        for g in grad.iter_mut() {
            *g *= scale;
        }
    }
    let lr = hp.lr_at(step);
    let t = step + 1;
    let bc1 = 1.0 - hp.beta1.powi(t);
    let bc2 = 1.0 - hp.beta2.powi(t);
    for i in 0..flat.len() {
        let g = grad[i];
        m[i] = hp.beta1 * m[i] + (1.0 - hp.beta1) * g;
        v[i] = hp.beta2 * v[i] + (1.0 - hp.beta2) * g * g;
        let mhat = m[i] / bc1;
        let vhat = v[i] / bc2;
        let upd = mhat / (vhat.sqrt() + hp.eps) + hp.weight_decay * flat[i];
        flat[i] -= lr * upd;
    }
    norm
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hp() -> AdamHp {
        AdamHp {
            lr: 3e-4,
            beta1: 0.9,
            beta2: 0.98,
            eps: 1e-8,
            weight_decay: 0.01,
            grad_clip: 1.0,
            warmup: 100,
            total_steps: 2000,
        }
    }

    #[test]
    fn schedule_warmup_then_cosine() {
        let h = hp();
        assert_eq!(h.lr_at(0), 0.0);
        assert!((h.lr_at(50) - h.lr * 0.5).abs() < 1e-9);
        // at the warmup boundary the cosine branch starts at full lr
        assert!((h.lr_at(100) - h.lr).abs() < 1e-9);
        // decays to 10% of base at the end
        assert!((h.lr_at(2000) - 0.1 * h.lr).abs() < 1e-8);
        // monotonically non-increasing after warmup
        assert!(h.lr_at(500) > h.lr_at(1500));
    }

    #[test]
    fn clip_rescales_large_gradients() {
        let h = hp();
        let mut flat = vec![0.0f32; 3];
        let (mut m, mut v) = (vec![0.0f32; 3], vec![0.0f32; 3]);
        let mut g = vec![3.0f32, 4.0, 0.0]; // norm 5 > clip 1
        let norm = adamw_step(&h, 200, &mut flat, &mut m, &mut v, &mut g);
        assert!((norm - 5.0).abs() < 1e-6);
        // post-clip gradient has norm 1, so m = 0.1 * g_clipped
        assert!((m[0] - 0.1 * 0.6).abs() < 1e-7);
        assert!((m[1] - 0.1 * 0.8).abs() < 1e-7);
    }

    #[test]
    fn adamw_matches_hand_computed_step() {
        // single param, step 0 (lr = 0 in warmup): params must not move
        let h = hp();
        let mut flat = vec![1.0f32];
        let (mut m, mut v) = (vec![0.0f32], vec![0.0f32]);
        let mut g = vec![0.5f32];
        adamw_step(&h, 0, &mut flat, &mut m, &mut v, &mut g);
        assert_eq!(flat[0], 1.0);
        // step past warmup: hand-compute one update from zero moments
        let mut h2 = hp();
        h2.warmup = 0;
        h2.total_steps = 0; // python: max(1.0, total-warmup) == 1 -> prog clamps to 1
        let lr = h2.lr_at(10);
        assert!((lr - 0.1 * h2.lr).abs() < 1e-9);
        let mut flat = vec![1.0f32];
        let (mut m, mut v) = (vec![0.0f32], vec![0.0f32]);
        let mut g = vec![0.5f32];
        adamw_step(&h2, 10, &mut flat, &mut m, &mut v, &mut g);
        let mm = 0.1f32 * 0.5;
        let vv = 0.02f32 * 0.25;
        let mhat = mm / (1.0 - 0.9f32.powi(11));
        let vhat = vv / (1.0 - 0.98f32.powi(11));
        let want = 1.0 - lr * (mhat / (vhat.sqrt() + 1e-8) + 0.01 * 1.0);
        assert!((flat[0] - want).abs() < 1e-7, "{} vs {want}", flat[0]);
    }
}
