//! `laplace-stlt` — reproduction of "Adaptive Two-Sided Laplace
//! Transforms: A Learnable, Interpretable, and Scalable Replacement for
//! Self-Attention" (Kiruluta, 2025) as a three-layer Rust + JAX + Pallas
//! stack (see DESIGN.md).
//!
//! * Layer 1/2 (python/, build-time only): Pallas STLT kernels + JAX
//!   models, AOT-lowered to HLO text.
//! * Layer 3 (this crate): PJRT runtime, training driver, streaming
//!   long-document coordinator, and every substrate (tokenizer, data
//!   generators, metrics, config, CLI, RNG, thread pool) built from
//!   scratch.

pub mod bench;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod harness;
pub mod interpret;
pub mod metrics;
pub mod runtime;
pub mod tokenizer;
pub mod util;
