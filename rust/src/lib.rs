//! `stlt` — reproduction of "Adaptive Two-Sided Laplace Transforms: A
//! Learnable, Interpretable, and Scalable Replacement for Self-Attention"
//! (Kiruluta, 2025) as a backend-agnostic Rust serving system.
//!
//! The runtime executes manifest entries (`artifacts/manifest.json`)
//! through a pluggable [`runtime::Backend`]:
//!
//! * **native** (default): STLT token mixing is an O(N·S·d) recursive
//!   convolution with O(S·d) streaming carries, so neither inference
//!   nor training needs an XLA compiler — [`runtime::native_stlt`]
//!   runs forward, streaming, decode and CE-eval directly in Rust from
//!   the flat parameter vector, and [`train`] adds a hand-derived
//!   exact backward pass, a pure-Rust AdamW (optim.py semantics) and
//!   multi-threaded data-parallel gradient accumulation. The full
//!   `stlt train|eval|stream|generate|serve|inspect --backend native`
//!   surface works with zero external dependencies.
//! * **xla** (feature `xla`): AOT-lowered HLO artifacts (Pallas STLT
//!   kernels + JAX models, lowered by python/compile/aot.py at build
//!   time) executed on the PJRT CPU client, including the baseline
//!   architectures, quadratic mode and seq2seq training.
//!
//! Layered on top: the training driver, the continuous-batching
//! serving coordinator (session handles / token streams / batched
//! decode waves / carry state-pool / backpressure), and every
//! substrate (tokenizer, data generators, metrics, config, CLI, RNG,
//! FFT, thread pool) built from scratch.
//!
//! See rust/README.md for the Backend trait contract, the manifest /
//! flat-parameter layout the native backend consumes, and the
//! per-backend CLI support matrix.

// The crate predates clippy enforcement; these lints are stylistic and
// pervasive in the numeric kernels (index loops mirror the math) and
// the coordinator (wide tuples on the wire protocol).
#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]
#![allow(clippy::type_complexity)]
#![allow(clippy::manual_memcpy)]
// Every dereference inside an `unsafe fn` must sit in its own
// `unsafe {}` block with its own SAFETY argument — the crate's one
// unsafe region (threadpool::scatter_rows) is kept minimal this way.
#![deny(unsafe_op_in_unsafe_fn)]

pub mod bench;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod harness;
pub mod interpret;
pub mod lint;
pub mod metrics;
pub mod net;
pub mod obs;
pub mod runtime;
pub mod tokenizer;
#[cfg(feature = "native")]
pub mod train;
pub mod util;
