//! Synthetic workload generators standing in for the paper's datasets
//! (WikiText-103/Gutenberg, WMT'14 En-De, NarrativeQA) — see DESIGN.md
//! §3 for the substitution rationale per dataset.

pub mod batch;
pub mod corpus;
pub mod longqa;
pub mod translate;
