//! Synthetic translation task (WMT'14 En-De stand-in, DESIGN.md §3).
//!
//! A source sentence is Zipfian tokens; the "target language" applies
//!   1. a fixed bijective vocabulary mapping (lexical translation),
//!   2. local reordering: each window of 3 is rotated (word-order
//!      divergence, the part attention/cross-STLT must learn),
//!   3. BOS/EOS framing and PAD to fixed length.
//!
//! BLEU separates models by how well they learn the mapping + reordering
//! across the whole source — the same axis Table 2 measures.

use crate::tokenizer::{BOS, EOS, PAD};
use crate::util::rng::{Rng, Zipf};

#[derive(Clone, Debug)]
pub struct TranslateConfig {
    pub vocab: usize,
    pub first_id: usize,
    pub n_src: usize,
    pub m_tgt: usize,
    pub min_len: usize,
}

impl TranslateConfig {
    pub fn tiny(vocab: usize, n_src: usize, m_tgt: usize) -> TranslateConfig {
        TranslateConfig { vocab, first_id: 4, n_src, m_tgt, min_len: 8 }
    }
}

pub struct TranslateGen {
    cfg: TranslateConfig,
    rng: Rng,
    zipf: Zipf,
    mapping: Vec<i32>,
}

#[derive(Clone, Debug)]
pub struct Pair {
    /// fixed length n_src, PAD-padded
    pub src: Vec<i32>,
    /// BOS + translation + EOS, PAD-padded to m_tgt + 1 (teacher forcing)
    pub tgt: Vec<i32>,
    /// unpadded gold target (no BOS/EOS) for BLEU
    pub gold: Vec<i32>,
}

impl TranslateGen {
    pub fn new(cfg: TranslateConfig, seed: u64) -> TranslateGen {
        let rng = Rng::new(seed);
        let usable = cfg.vocab - cfg.first_id;
        // fixed bijective "dictionary": shuffled identity over usable ids
        let mut mapping: Vec<i32> = (0..usable as i32).collect();
        let mut map_rng = Rng::new(0xD1C7 ^ seed);
        map_rng.shuffle(&mut mapping);
        let zipf = Zipf::new(usable, 1.05);
        TranslateGen { cfg, rng, zipf, mapping }
    }

    /// The reference translation function (the task's ground truth).
    pub fn translate(&self, src: &[i32]) -> Vec<i32> {
        let f = self.cfg.first_id as i32;
        let mut out: Vec<i32> =
            src.iter().map(|&t| f + self.mapping[(t - f) as usize]).collect();
        // rotate every window of 3: abc -> bca (local reordering)
        let mut i = 0;
        while i + 3 <= out.len() {
            out[i..i + 3].rotate_left(1);
            i += 3;
        }
        out
    }

    pub fn sample(&mut self) -> Pair {
        let max_len = self.cfg.n_src.min(self.cfg.m_tgt - 1);
        let len = self.rng.range(self.cfg.min_len as i64, (max_len + 1) as i64) as usize;
        let f = self.cfg.first_id as i32;
        let src_raw: Vec<i32> =
            (0..len).map(|_| f + self.zipf.sample(&mut self.rng) as i32).collect();
        let gold = self.translate(&src_raw);
        let mut src = src_raw;
        src.resize(self.cfg.n_src, PAD);
        let mut tgt = Vec::with_capacity(self.cfg.m_tgt + 1);
        tgt.push(BOS);
        tgt.extend_from_slice(&gold);
        tgt.push(EOS);
        tgt.resize(self.cfg.m_tgt + 1, PAD);
        Pair { src, tgt, gold }
    }

    /// Batch of pairs as flat row-major [B, n_src] and [B, m_tgt+1].
    pub fn batch(&mut self, b: usize) -> (Vec<i32>, Vec<i32>, Vec<Pair>) {
        let mut src = Vec::with_capacity(b * self.cfg.n_src);
        let mut tgt = Vec::with_capacity(b * (self.cfg.m_tgt + 1));
        let mut pairs = Vec::with_capacity(b);
        for _ in 0..b {
            let p = self.sample();
            src.extend_from_slice(&p.src);
            tgt.extend_from_slice(&p.tgt);
            pairs.push(p);
        }
        (src, tgt, pairs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gen() -> TranslateGen {
        TranslateGen::new(TranslateConfig::tiny(256, 48, 48), 9)
    }

    #[test]
    fn shapes_fixed() {
        let mut g = gen();
        for _ in 0..20 {
            let p = g.sample();
            assert_eq!(p.src.len(), 48);
            assert_eq!(p.tgt.len(), 49);
            assert_eq!(p.tgt[0], BOS);
            assert!(p.tgt.contains(&EOS));
        }
    }

    #[test]
    fn translation_is_deterministic_function() {
        let g = gen();
        let src = vec![10, 11, 12, 13, 14, 15];
        assert_eq!(g.translate(&src), g.translate(&src));
    }

    #[test]
    fn mapping_is_bijective() {
        let g = gen();
        let mut seen = std::collections::HashSet::new();
        for t in 4..256 {
            let out = g.translate(&[t, t, t]); // window rotation is a no-op on equal tokens
            assert!((4..256).contains(&out[0]));
            seen.insert(out[0]);
        }
        assert_eq!(seen.len(), 252);
    }

    #[test]
    fn reordering_rotates_triples() {
        let g = gen();
        let src = vec![4, 5, 6];
        let one: Vec<i32> = src.iter().map(|&t| g.translate(&[t, t, t])[0]).collect();
        let out = g.translate(&src);
        assert_eq!(out, vec![one[1], one[2], one[0]]);
    }

    #[test]
    fn gold_matches_tgt_payload() {
        let mut g = gen();
        let p = g.sample();
        let payload: Vec<i32> =
            p.tgt[1..].iter().cloned().take_while(|&t| t != EOS).collect();
        assert_eq!(payload, p.gold);
    }

    #[test]
    fn batch_flat_layout() {
        let mut g = gen();
        let (src, tgt, pairs) = g.batch(4);
        assert_eq!(src.len(), 4 * 48);
        assert_eq!(tgt.len(), 4 * 49);
        assert_eq!(&src[48..96], pairs[1].src.as_slice());
    }
}
