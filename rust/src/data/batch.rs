//! Batch iterators: turn streaming generators into the flat row-major
//! i32 arrays the train/eval artifacts expect ([B, N+1] next-token
//! format), with disjoint train/valid streams.

use crate::data::corpus::{Corpus, CorpusConfig};

/// LM batches of shape [b, n_plus_1] (flat row-major) from `b`
/// independent corpus streams (so rows are decorrelated).
pub struct LmBatcher {
    streams: Vec<Corpus>,
    pub b: usize,
    pub n_plus_1: usize,
    /// one-token overlap: each row continues its stream, repeating the
    /// previous last token as the new first (next-token alignment)
    last: Vec<Option<i32>>,
}

impl LmBatcher {
    pub fn new(cfg: CorpusConfig, seed: u64, b: usize, n_plus_1: usize) -> LmBatcher {
        let streams = (0..b)
            .map(|i| Corpus::new(cfg.clone(), seed.wrapping_add(1 + i as u64)))
            .collect();
        LmBatcher { streams, b, n_plus_1, last: vec![None; b] }
    }

    pub fn next_batch(&mut self) -> Vec<i32> {
        let mut out = Vec::with_capacity(self.b * self.n_plus_1);
        for (i, s) in self.streams.iter_mut().enumerate() {
            match self.last[i] {
                Some(t) => {
                    out.push(t);
                    out.extend(s.take(self.n_plus_1 - 1));
                }
                None => out.extend(s.take(self.n_plus_1)),
            }
            self.last[i] = Some(out[out.len() - 1]);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk() -> LmBatcher {
        LmBatcher::new(CorpusConfig::default_for_vocab(256), 42, 4, 17)
    }

    #[test]
    fn shape_and_range() {
        let mut b = mk();
        let batch = b.next_batch();
        assert_eq!(batch.len(), 4 * 17);
        assert!(batch.iter().all(|&t| (4..256).contains(&t)));
    }

    #[test]
    fn rows_are_contiguous_streams() {
        let mut b = mk();
        let b1 = b.next_batch();
        let b2 = b.next_batch();
        // first token of each row in batch2 == last token of same row in batch1
        for r in 0..4 {
            assert_eq!(b2[r * 17], b1[r * 17 + 16]);
        }
    }

    #[test]
    fn rows_decorrelated() {
        let mut b = mk();
        let batch = b.next_batch();
        assert_ne!(&batch[0..17], &batch[17..34]);
    }

    #[test]
    fn deterministic() {
        let mut a = mk();
        let mut b = mk();
        assert_eq!(a.next_batch(), b.next_batch());
        assert_eq!(a.next_batch(), b.next_batch());
    }
}
