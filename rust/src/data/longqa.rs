//! Long-document needle QA (NarrativeQA stand-in, DESIGN.md §3).
//!
//! A document is corpus filler with planted facts:
//!     ... SEP k1 k2 v1 v2 v3 SEP ...
//! The question (SEP k1 k2 SEP) comes after the document; the model must
//! produce v1 v2 v3. F1 over answer tokens reproduces Table 3's metric.
//! The fact-to-question distance is the experimental knob: streaming
//! STLT carries it across 100k+ tokens with O(S d) state, while a
//! chunked baseline physically loses facts beyond its window.

use crate::data::corpus::{Corpus, CorpusConfig};
use crate::tokenizer::SEP;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct QaConfig {
    pub vocab: usize,
    pub first_id: usize,
    pub key_len: usize,
    pub answer_len: usize,
    /// tokens between the fact and the question
    pub distance: usize,
    /// filler after the fact (fact sits `distance` before the question)
    pub doc_len: usize,
}

impl QaConfig {
    pub fn with_distance(vocab: usize, distance: usize) -> QaConfig {
        QaConfig {
            vocab,
            first_id: 4,
            key_len: 2,
            answer_len: 3,
            distance,
            doc_len: distance + 64,
        }
    }
}

#[derive(Clone, Debug)]
pub struct QaSample {
    /// document ++ question, ready to stream; answer must follow
    pub prompt: Vec<i32>,
    pub answer: Vec<i32>,
    /// index in `prompt` where the question starts (for chunked baselines)
    pub question_start: usize,
}

pub struct QaGen {
    cfg: QaConfig,
    rng: Rng,
    corpus_seed: u64,
    counter: u64,
}

impl QaGen {
    pub fn new(cfg: QaConfig, seed: u64) -> QaGen {
        QaGen { cfg, rng: Rng::new(seed), corpus_seed: seed ^ 0x9A5EED, counter: 0 }
    }

    pub fn sample(&mut self) -> QaSample {
        let f = self.cfg.first_id as i32;
        let usable = (self.cfg.vocab - self.cfg.first_id) as i64;
        let key: Vec<i32> =
            (0..self.cfg.key_len).map(|_| f + self.rng.range(0, usable) as i32).collect();
        let answer: Vec<i32> =
            (0..self.cfg.answer_len).map(|_| f + self.rng.range(0, usable) as i32).collect();

        self.counter += 1;
        let mut filler =
            Corpus::new(CorpusConfig::default_for_vocab(self.cfg.vocab),
                        self.corpus_seed.wrapping_add(self.counter));

        let fact_len = self.cfg.key_len + self.cfg.answer_len + 2;
        let pre = self.cfg.doc_len.saturating_sub(self.cfg.distance + fact_len);
        let mut prompt = Vec::with_capacity(self.cfg.doc_len + self.cfg.key_len + 2);
        prompt.extend(filler.take(pre));
        prompt.push(SEP);
        prompt.extend_from_slice(&key);
        prompt.extend_from_slice(&answer);
        prompt.push(SEP);
        prompt.extend(filler.take(self.cfg.distance));
        let question_start = prompt.len();
        prompt.push(SEP);
        prompt.extend_from_slice(&key);
        prompt.push(SEP);
        QaSample { prompt, answer, question_start }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn structure() {
        let mut g = QaGen::new(QaConfig::with_distance(256, 100), 3);
        let s = g.sample();
        // question = SEP key SEP at the end
        assert_eq!(s.prompt[s.question_start], SEP);
        assert_eq!(*s.prompt.last().unwrap(), SEP);
        let key_in_q = &s.prompt[s.question_start + 1..s.prompt.len() - 1];
        assert_eq!(key_in_q.len(), 2);
        // the same key must appear earlier (in the fact), followed by the answer
        let mut found = false;
        for i in 0..s.question_start.saturating_sub(5) {
            if s.prompt[i..i + 2] == *key_in_q {
                assert_eq!(&s.prompt[i + 2..i + 5], s.answer.as_slice());
                found = true;
                break;
            }
        }
        assert!(found, "fact not planted");
    }

    #[test]
    fn distance_respected() {
        for dist in [50usize, 500, 5000] {
            let mut g = QaGen::new(QaConfig::with_distance(256, dist), 7);
            let s = g.sample();
            // fact SEP ... question SEP distance apart (allow fact framing)
            let gap = s.question_start
                - s.prompt[..s.question_start]
                    .iter()
                    .rposition(|&t| t == SEP)
                    .unwrap();
            assert!(gap >= dist, "gap {gap} < {dist}");
        }
    }

    #[test]
    fn samples_differ() {
        let mut g = QaGen::new(QaConfig::with_distance(256, 64), 5);
        let a = g.sample();
        let b = g.sample();
        assert_ne!(a.prompt, b.prompt);
        assert_ne!(a.answer, b.answer);
    }

    #[test]
    fn deterministic_across_generators() {
        let a = QaGen::new(QaConfig::with_distance(256, 64), 11).sample();
        let b = QaGen::new(QaConfig::with_distance(256, 64), 11).sample();
        assert_eq!(a.prompt, b.prompt);
        assert_eq!(a.answer, b.answer);
    }
}
