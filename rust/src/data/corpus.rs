//! Synthetic language-modeling corpus (WikiText-103 / Gutenberg stand-in,
//! DESIGN.md §3). Three planted structures map one-to-one onto the
//! capacities the paper claims STLT learns:
//!
//!   * order-2 Markov transitions over a Zipfian vocabulary — local
//!     syntax (any architecture can learn this),
//!   * periodic motifs with period P — oscillatory structure (the
//!     omega_k frequencies),
//!   * long-range copy spans from `lag` tokens back — slowly-decaying
//!     relevance (the sigma_k half-lives).
//!
//! A model that captures all three gets materially lower perplexity than
//! one that only models locals, which is exactly the separation Table 1
//! measures. `domain` perturbs the Markov tables for the §4.7 OOD split.

use crate::util::rng::{Rng, Zipf};

#[derive(Clone, Debug)]
pub struct CorpusConfig {
    pub vocab: usize,
    /// first usable token id (below are PAD/BOS/EOS/SEP)
    pub first_id: usize,
    pub zipf_alpha: f64,
    /// probability of entering a copy span at each step
    pub p_copy: f64,
    pub copy_len: (usize, usize),
    pub copy_lag: (usize, usize),
    /// motif period and length (0 disables)
    pub motif_period: usize,
    pub motif_len: usize,
    /// Markov interpolation weight (vs unigram)
    pub p_markov: f64,
    /// domain tag — changes Markov tables + motif content (OOD split)
    pub domain: u64,
}

impl CorpusConfig {
    pub fn default_for_vocab(vocab: usize) -> CorpusConfig {
        CorpusConfig {
            vocab,
            first_id: 4,
            zipf_alpha: 1.05,
            p_copy: 0.02,
            copy_len: (8, 24),
            copy_lag: (16, 96),
            motif_period: 32,
            motif_len: 4,
            p_markov: 0.55,
            domain: 0,
        }
    }
}

/// Streaming token generator with O(max_lag) memory.
pub struct Corpus {
    cfg: CorpusConfig,
    rng: Rng,
    zipf: Zipf,
    history: Vec<i32>,
    copy_remaining: usize,
    copy_lag: usize,
    motif: Vec<i32>,
    t: usize,
}

impl Corpus {
    pub fn new(cfg: CorpusConfig, seed: u64) -> Corpus {
        let mut rng = Rng::new(seed ^ cfg.domain.wrapping_mul(0x9E3779B97F4A7C15));
        let usable = cfg.vocab - cfg.first_id;
        let zipf = Zipf::new(usable, cfg.zipf_alpha);
        let motif: Vec<i32> = (0..cfg.motif_len)
            .map(|i| {
                let h = (cfg.domain.wrapping_mul(31).wrapping_add(i as u64))
                    .wrapping_mul(0x2545F4914F6CDD1D);
                (cfg.first_id + (h % usable as u64) as usize) as i32
            })
            .collect();
        let first = (cfg.first_id + zipf.sample(&mut rng)) as i32;
        Corpus {
            cfg,
            rng,
            zipf,
            history: vec![first],
            copy_remaining: 0,
            copy_lag: 0,
            motif,
            t: 1,
        }
    }

    /// Deterministic "Markov table": hash (prev2, prev1, domain) to a
    /// preferred next token. Dense tables would need V^2 memory; the hash
    /// gives the same learnable-bigram effect at O(1).
    fn markov_next(&self, p2: i32, p1: i32) -> i32 {
        let usable = (self.cfg.vocab - self.cfg.first_id) as u64;
        let h = (p2 as u64)
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(p1 as u64)
            .wrapping_mul(0xBF58476D1CE4E5B9)
            .wrapping_add(self.cfg.domain.wrapping_mul(0x94D049BB133111EB));
        (self.cfg.first_id as u64 + (h >> 17) % usable) as i32
    }

    pub fn next_token(&mut self) -> i32 {
        let tok = if self.copy_remaining > 0 && self.history.len() > self.copy_lag {
            self.copy_remaining -= 1;
            self.history[self.history.len() - self.copy_lag]
        } else if self.cfg.motif_period > 0 && self.t % self.cfg.motif_period < self.cfg.motif_len
        {
            self.motif[self.t % self.cfg.motif_period]
        } else if self.rng.bool(self.cfg.p_copy) && self.history.len() > self.cfg.copy_lag.1 {
            self.copy_lag =
                self.rng.range(self.cfg.copy_lag.0 as i64, self.cfg.copy_lag.1 as i64) as usize;
            self.copy_remaining =
                self.rng.range(self.cfg.copy_len.0 as i64, self.cfg.copy_len.1 as i64) as usize;
            self.history[self.history.len() - self.copy_lag]
        } else {
            let n = self.history.len();
            let p1 = self.history[n - 1];
            let p2 = if n >= 2 { self.history[n - 2] } else { p1 };
            if self.rng.bool(self.cfg.p_markov) {
                self.markov_next(p2, p1)
            } else {
                (self.cfg.first_id + self.zipf.sample(&mut self.rng)) as i32
            }
        };
        self.history.push(tok);
        // keep history bounded: we only need max copy lag
        let keep = self.cfg.copy_lag.1 + 2;
        if self.history.len() > 4 * keep {
            let cut = self.history.len() - keep;
            self.history.drain(..cut);
        }
        self.t += 1;
        tok
    }

    pub fn take(&mut self, n: usize) -> Vec<i32> {
        (0..n).map(|_| self.next_token()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(seed: u64, domain: u64) -> Corpus {
        let mut cfg = CorpusConfig::default_for_vocab(256);
        cfg.domain = domain;
        Corpus::new(cfg, seed)
    }

    #[test]
    fn tokens_in_range() {
        let mut c = mk(1, 0);
        for _ in 0..5000 {
            let t = c.next_token();
            assert!((4..256).contains(&t), "token {t} out of range");
        }
    }

    #[test]
    fn deterministic() {
        let a = mk(7, 0).take(2000);
        let b = mk(7, 0).take(2000);
        assert_eq!(a, b);
    }

    #[test]
    fn domains_differ() {
        let a = mk(7, 0).take(2000);
        let b = mk(7, 1).take(2000);
        assert_ne!(a, b);
    }

    #[test]
    fn motif_is_periodic() {
        // copy spans take precedence over motifs, so disable them here
        let mut cfg = CorpusConfig::default_for_vocab(256);
        cfg.p_copy = 0.0;
        let mut c = Corpus::new(cfg, 3);
        let toks = c.take(512);
        // positions p with p % 32 == 0 should repeat the same motif token
        // (t starts at 1, motif occupies t%32 in 0..4)
        let mut motif_vals = std::collections::HashSet::new();
        for (i, t) in toks.iter().enumerate() {
            let tt = i + 1;
            if tt % 32 == 0 {
                motif_vals.insert(*t);
            }
        }
        assert_eq!(motif_vals.len(), 1, "motif position should be constant");
    }

    #[test]
    fn zipf_skew_present() {
        let mut c = mk(11, 0);
        let toks = c.take(20_000);
        let mut counts = vec![0usize; 256];
        for t in toks {
            counts[t as usize] += 1;
        }
        let max = *counts.iter().max().unwrap();
        let nonzero = counts.iter().filter(|&&c| c > 0).count();
        assert!(max > 20_000 / nonzero * 3, "expected skewed unigram distribution");
    }

    #[test]
    fn history_bounded() {
        let mut c = mk(5, 0);
        c.take(50_000);
        assert!(c.history.len() < 1000);
    }
}
