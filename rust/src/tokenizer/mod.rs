//! Tokenizer substrate: byte-level base vocabulary + BPE trainer/encoder.
//!
//! Used by the e2e pipeline (vocab 4096 BPE over the synthetic corpus)
//! and by the text-facing examples. The artifact embedding size fixes
//! the vocabulary size, so `train` takes an exact target size.
//!
//! Reserved ids: 0 = PAD, 1 = BOS, 2 = EOS, 3 = SEP; bytes occupy
//! ids 4..260; merges occupy 260..vocab_size.

use std::collections::HashMap;

pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
pub const SEP: i32 = 3;
pub const N_SPECIAL: usize = 4;

#[derive(Clone, Debug)]
pub struct Bpe {
    /// merge list in training order: (left_id, right_id) -> new_id
    pub merges: Vec<(i32, i32)>,
    /// rank lookup for encoding
    ranks: HashMap<(i32, i32), usize>,
    pub vocab_size: usize,
}

impl Bpe {
    /// Byte-level tokenizer with no merges (vocab = 260).
    pub fn byte_level() -> Bpe {
        Bpe { merges: Vec::new(), ranks: HashMap::new(), vocab_size: N_SPECIAL + 256 }
    }

    /// Train BPE on `text` until exactly `vocab_size` ids exist (or no
    /// pair repeats). Standard greedy highest-frequency pair merging.
    pub fn train(text: &str, vocab_size: usize) -> Bpe {
        assert!(vocab_size >= N_SPECIAL + 256, "vocab must cover bytes + specials");
        let mut seq: Vec<i32> = text.bytes().map(|b| b as i32 + N_SPECIAL as i32).collect();
        let mut merges = Vec::new();
        let mut next_id = (N_SPECIAL + 256) as i32;
        while (next_id as usize) < vocab_size {
            let mut counts: HashMap<(i32, i32), usize> = HashMap::new();
            for w in seq.windows(2) {
                *counts.entry((w[0], w[1])).or_insert(0) += 1;
            }
            let Some((&pair, &cnt)) = counts.iter().max_by_key(|(p, c)| (**c, std::cmp::Reverse(**p)))
            else {
                break;
            };
            if cnt < 2 {
                break;
            }
            merges.push(pair);
            // apply merge in-place
            let mut out = Vec::with_capacity(seq.len());
            let mut i = 0;
            while i < seq.len() {
                if i + 1 < seq.len() && (seq[i], seq[i + 1]) == pair {
                    out.push(next_id);
                    i += 2;
                } else {
                    out.push(seq[i]);
                    i += 1;
                }
            }
            seq = out;
            next_id += 1;
        }
        let ranks = merges.iter().enumerate().map(|(i, p)| (*p, i)).collect();
        Bpe { merges, ranks, vocab_size }
    }

    pub fn encode(&self, text: &str) -> Vec<i32> {
        let mut seq: Vec<i32> = text.bytes().map(|b| b as i32 + N_SPECIAL as i32).collect();
        loop {
            // find lowest-rank applicable merge
            let mut best: Option<(usize, usize)> = None; // (rank, pos)
            for i in 0..seq.len().saturating_sub(1) {
                if let Some(&r) = self.ranks.get(&(seq[i], seq[i + 1])) {
                    if best.map(|(br, _)| r < br).unwrap_or(true) {
                        best = Some((r, i));
                    }
                }
            }
            let Some((rank, _)) = best else { break };
            let pair = self.merges[rank];
            let new_id = (N_SPECIAL + 256 + rank) as i32;
            let mut out = Vec::with_capacity(seq.len());
            let mut i = 0;
            while i < seq.len() {
                if i + 1 < seq.len() && (seq[i], seq[i + 1]) == pair {
                    out.push(new_id);
                    i += 2;
                } else {
                    out.push(seq[i]);
                    i += 1;
                }
            }
            seq = out;
        }
        seq
    }

    pub fn decode(&self, ids: &[i32]) -> String {
        let mut bytes = Vec::new();
        for &id in ids {
            self.push_bytes(id, &mut bytes);
        }
        String::from_utf8_lossy(&bytes).into_owned()
    }

    fn push_bytes(&self, id: i32, out: &mut Vec<u8>) {
        if id < N_SPECIAL as i32 {
            return; // specials decode to nothing
        }
        let base = N_SPECIAL as i32;
        if id < base + 256 {
            out.push((id - base) as u8);
        } else {
            let (l, r) = self.merges[(id - base - 256) as usize];
            self.push_bytes(l, out);
            self.push_bytes(r, out);
        }
    }

    // -- persistence (plain text: one "left right" merge per line) --

    pub fn save(&self, path: &str) -> std::io::Result<()> {
        let mut s = format!("bpe v1 vocab={}\n", self.vocab_size);
        for (l, r) in &self.merges {
            s.push_str(&format!("{l} {r}\n"));
        }
        std::fs::write(path, s)
    }

    pub fn load(path: &str) -> Result<Bpe, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        let mut lines = text.lines();
        let header = lines.next().ok_or("empty bpe file")?;
        let vocab_size: usize = header
            .split("vocab=")
            .nth(1)
            .and_then(|v| v.trim().parse().ok())
            .ok_or("bad bpe header")?;
        let mut merges = Vec::new();
        for l in lines {
            if l.trim().is_empty() {
                continue;
            }
            let mut it = l.split_whitespace();
            let a: i32 = it.next().and_then(|x| x.parse().ok()).ok_or("bad merge line")?;
            let b: i32 = it.next().and_then(|x| x.parse().ok()).ok_or("bad merge line")?;
            merges.push((a, b));
        }
        let ranks = merges.iter().enumerate().map(|(i, p)| (*p, i)).collect();
        Ok(Bpe { merges, ranks, vocab_size })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byte_level_roundtrip() {
        let t = Bpe::byte_level();
        let s = "hello, Laplace! σω";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn bpe_roundtrip_and_compression() {
        let corpus = "the cat sat on the mat. the cat sat on the hat. ".repeat(50);
        let t = Bpe::train(&corpus, N_SPECIAL + 256 + 64);
        let enc = t.encode(&corpus);
        assert_eq!(t.decode(&enc), corpus);
        assert!(enc.len() < corpus.len() / 2, "BPE should compress repetitive text");
    }

    #[test]
    fn merges_respect_vocab_bound() {
        let corpus = "abababab abab".repeat(20);
        let t = Bpe::train(&corpus, N_SPECIAL + 256 + 8);
        assert!(t.merges.len() <= 8);
        for &id in &t.encode(&corpus) {
            assert!((id as usize) < t.vocab_size);
        }
    }

    #[test]
    fn save_load_identical() {
        let corpus = "zxzxzx yzyzyz ".repeat(30);
        let t = Bpe::train(&corpus, N_SPECIAL + 256 + 16);
        let path = std::env::temp_dir().join("stlt_bpe_test.txt");
        t.save(path.to_str().unwrap()).unwrap();
        let t2 = Bpe::load(path.to_str().unwrap()).unwrap();
        assert_eq!(t.merges, t2.merges);
        assert_eq!(t.encode(&corpus), t2.encode(&corpus));
    }

    #[test]
    fn specials_silent_in_decode() {
        let t = Bpe::byte_level();
        let mut ids = vec![BOS];
        ids.extend(t.encode("ok"));
        ids.push(EOS);
        assert_eq!(t.decode(&ids), "ok");
    }

    #[test]
    fn deterministic_training() {
        let corpus = "deterministic deterministic determinism".repeat(10);
        let a = Bpe::train(&corpus, N_SPECIAL + 256 + 32);
        let b = Bpe::train(&corpus, N_SPECIAL + 256 + 32);
        assert_eq!(a.merges, b.merges);
    }
}
