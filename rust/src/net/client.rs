//! Wire client: one multiplexed connection ([`Client`]) and the
//! remote implementation of [`crate::coordinator::Session`]
//! ([`RemoteSession`]).
//!
//! One reader thread demultiplexes reply/stream frames by `req` id
//! into per-operation channels: plain requests get a one-shot reply,
//! generations get an [`mpsc`] channel that the reader feeds
//! `Start`/`Token`/`End` items — the *same* [`TokenStream`] type a
//! local [`crate::coordinator::SessionHandle`] returns, so streaming
//! consumers cannot tell local from remote. Dropping a remote
//! `TokenStream` mid-generation sends a `Cancel` frame (mirroring the
//! local drop-cancels contract). If the connection dies, every
//! pending operation fails with a clear error instead of hanging.

use std::collections::HashMap;
use std::thread;

use anyhow::{anyhow, bail, Result};

use crate::coordinator::session::StreamItem;
use crate::coordinator::{CarrySnapshot, FeedResult, GenOpts, Session, TokenStream};
use crate::util::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use crate::util::sync::{mpsc, Arc, Mutex};

use super::wire::{self, EndOutcome, Frame};
use super::Stream;

enum Pending {
    /// One-shot reply (Open/Feed/Cancel/Close/Export/Import).
    Resp(mpsc::Sender<Result<Frame>>),
    /// A generation stream; `session` is kept for the implicit Cancel
    /// when the local receiver is dropped.
    Stream { tx: mpsc::Sender<StreamItem>, session: u64 },
}

struct ClientInner {
    peer: String,
    writer: Mutex<std::io::BufWriter<Stream>>,
    pending: Mutex<HashMap<u64, Pending>>,
    next_req: AtomicU64,
    alive: AtomicBool,
}

/// A connection to a worker or router. Cheap to clone (all clones
/// share the socket and the reader thread); thread-safe — sessions
/// opened from one client can be driven from many threads.
#[derive(Clone)]
pub struct Client {
    inner: Arc<ClientInner>,
}

impl Client {
    /// Connect and handshake. `addr` is `host:port` or `unix:/path`.
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = Stream::connect(addr)?;
        let mut wstream = stream.try_clone()?;
        {
            use std::io::Write;
            wire::write_frame(
                &mut wstream,
                &Frame::Hello { magic: wire::MAGIC, version: wire::PROTOCOL_VERSION },
            )?;
            wstream.flush()?;
        }
        let mut reader = std::io::BufReader::new(stream.try_clone()?);
        match wire::read_frame(&mut reader)? {
            Some(Frame::HelloAck { version }) if version == wire::PROTOCOL_VERSION => {}
            Some(Frame::HelloAck { version }) => {
                bail!("{addr}: server speaks protocol version {version}, this client speaks {}",
                    wire::PROTOCOL_VERSION)
            }
            Some(Frame::Error { msg, .. }) => bail!("{addr}: handshake refused: {msg}"),
            Some(f) => bail!("{addr}: unexpected handshake reply {}", f.name()),
            None => bail!("{addr}: connection closed during handshake"),
        }
        let inner = Arc::new(ClientInner {
            peer: addr.to_string(),
            writer: Mutex::new(std::io::BufWriter::new(wstream)),
            pending: Mutex::new(HashMap::new()),
            next_req: AtomicU64::new(1),
            alive: AtomicBool::new(true),
        });
        let inner_r = Arc::clone(&inner);
        thread::Builder::new()
            .name("stlt-client-reader".into())
            .spawn(move || read_loop(inner_r, reader))?;
        Ok(Client { inner })
    }

    /// False once the connection has failed (all operations error).
    pub fn is_alive(&self) -> bool {
        // ORDERING: Acquire — pairs with the Release stores that mark
        // the connection dead, so a caller that observes false also
        // observes everything the failing thread did first (in
        // particular the reader's drain of `pending`). request() and
        // start_generate() rely on this for their insert-after-drain
        // race check.
        self.inner.alive.load(Ordering::Acquire)
    }

    /// The address this client connected to.
    pub fn peer(&self) -> &str {
        &self.inner.peer
    }

    /// Open a session. `desired == 0` lets the server allocate an id;
    /// nonzero opens that exact id (the router's migration contract).
    pub fn open(&self, desired: u64) -> Result<RemoteSession> {
        let req = self.fresh_req();
        match self.request(req, Frame::Open { req, session: desired })? {
            Frame::OpenOk { session, .. } => {
                Ok(RemoteSession { client: self.clone(), session, closed: false })
            }
            f => bail!("unexpected reply to Open: {}", f.name()),
        }
    }

    /// Fetch the peer's metrics snapshot in exposition format (see
    /// [`crate::obs::expo`]). Works against both workers and routers.
    pub fn stats(&self) -> Result<String> {
        let req = self.fresh_req();
        match self.request(req, Frame::Stats { req })? {
            Frame::StatsOk { version, text, .. } => {
                if version != crate::obs::EXPO_VERSION {
                    bail!(
                        "{}: stats exposition version {version}, this client reads {}",
                        self.inner.peer,
                        crate::obs::EXPO_VERSION
                    );
                }
                Ok(text)
            }
            f => bail!("unexpected reply to Stats: {}", f.name()),
        }
    }

    fn fresh_req(&self) -> u64 {
        // ORDERING: Relaxed — req ids only need uniqueness; matching
        // request/reply state is published via the `pending` Mutex.
        self.inner.next_req.fetch_add(1, Ordering::Relaxed)
    }

    /// Send `frame` and block for its one-shot reply. `Error` frames
    /// come back as `Err`.
    fn request(&self, req: u64, frame: Frame) -> Result<Frame> {
        let (tx, rx) = mpsc::channel();
        self.inner.pending.lock().unwrap_or_else(|e| e.into_inner()).insert(req, Pending::Resp(tx));
        if let Err(e) = self.inner.send_frame(&frame) {
            self.inner.pending.lock().unwrap_or_else(|e| e.into_inner()).remove(&req);
            return Err(e);
        }
        // The reader thread fails all pending ops when the connection
        // dies — but only ones registered before its drain. If we
        // registered after (send raced the death), clean up ourselves.
        if !self.is_alive() && self.inner.pending.lock().unwrap_or_else(|e| e.into_inner()).remove(&req).is_some() {
            bail!("connection to {} lost", self.inner.peer);
        }
        match rx.recv() {
            Ok(reply) => reply,
            Err(_) => bail!("connection to {} lost", self.inner.peer),
        }
    }

    /// Start a remote generation: registers the stream, sends the
    /// frame, and returns a [`TokenStream`] fed by the reader thread.
    fn start_generate(&self, session: u64, opts: GenOpts) -> Result<TokenStream> {
        let req = self.fresh_req();
        let (tx, rx) = mpsc::channel();
        self.inner
            .pending
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .insert(req, Pending::Stream { tx, session });
        if let Err(e) = self.inner.send_frame(&Frame::Generate { req, session, opts }) {
            self.inner.pending.lock().unwrap_or_else(|e| e.into_inner()).remove(&req);
            return Err(e);
        }
        if !self.is_alive() {
            // as in request(): cover the insert-after-drain race; if
            // the reader already failed this entry the stream below
            // yields that error
            if self.inner.pending.lock().unwrap_or_else(|e| e.into_inner()).remove(&req).is_some() {
                bail!("connection to {} lost", self.inner.peer);
            }
        }
        Ok(TokenStream::new(rx))
    }
}

impl ClientInner {
    fn send_frame(&self, frame: &Frame) -> Result<()> {
        use std::io::Write;
        // ORDERING: Acquire — see Client::is_alive().
        if !self.alive.load(Ordering::Acquire) {
            bail!("connection to {} lost", self.peer);
        }
        let mut w = self.writer.lock().unwrap_or_else(|e| e.into_inner());
        let r = wire::write_frame(&mut *w, frame).and_then(|()| w.flush().map_err(Into::into));
        if r.is_err() {
            // ORDERING: Release — pairs with the Acquire loads above;
            // whoever observes the death also observes the failed write.
            self.alive.store(false, Ordering::Release);
        }
        r
    }

    /// Route one server frame to its pending operation.
    fn dispatch(&self, frame: Frame) {
        match frame {
            Frame::Start { req, evicted, fresh_carry } => {
                self.stream_item(req, StreamItem::Start { evicted, fresh_carry }, false);
            }
            Frame::Token { req, token } => {
                self.stream_item(req, StreamItem::Token(token), false);
            }
            Frame::End { req, outcome } => {
                let item = match outcome {
                    EndOutcome::Finished(r) => StreamItem::End(Ok(r)),
                    EndOutcome::Failed(msg) => StreamItem::End(Err(anyhow!(msg))),
                };
                self.stream_item(req, item, true);
            }
            Frame::Error { req, msg } => {
                match self.pending.lock().unwrap_or_else(|e| e.into_inner()).remove(&req) {
                    Some(Pending::Resp(tx)) => {
                        let _ = tx.send(Err(anyhow!(msg)));
                    }
                    Some(Pending::Stream { tx, .. }) => {
                        let _ = tx.send(StreamItem::End(Err(anyhow!(msg))));
                    }
                    // connection-level (req 0) or stale: log and move on
                    None => crate::warnlog!("net", "server error ({}): {msg}", self.peer),
                }
            }
            Frame::OpenOk { req, .. }
            | Frame::FeedOk { req, .. }
            | Frame::Carry { req, .. }
            | Frame::ImportOk { req, .. }
            | Frame::StatsOk { req, .. }
            | Frame::Ack { req } => {
                if let Some(Pending::Resp(tx)) = self.pending.lock().unwrap_or_else(|e| e.into_inner()).remove(&req) {
                    let _ = tx.send(Ok(frame));
                }
            }
            f => crate::warnlog!(
                "net",
                "unexpected frame {} from server {} (ignored)",
                f.name(),
                self.peer
            ),
        }
    }

    /// Deliver one stream item; `last` removes the pending entry. A
    /// dead local receiver (dropped TokenStream) triggers the
    /// implicit remote Cancel.
    fn stream_item(&self, req: u64, item: StreamItem, last: bool) {
        let mut cancel_session = None;
        {
            let mut pending = self.pending.lock().unwrap_or_else(|e| e.into_inner());
            let dead = match pending.get(&req) {
                Some(Pending::Stream { tx, .. }) => tx.send(item).is_err(),
                // Resp entry or unknown req: stray frame, drop it
                _ => return,
            };
            if dead || last {
                if let Some(Pending::Stream { session, .. }) = pending.remove(&req) {
                    if dead {
                        cancel_session = Some(session);
                    }
                }
            }
        }
        if let Some(session) = cancel_session {
            // receiver gone mid-stream: mirror the local drop-cancels
            // contract. Fresh req id; the Ack comes back unmatched and
            // is dropped by dispatch.
            // ORDERING: Relaxed — uniqueness only (see fresh_req).
            let req = self.next_req.fetch_add(1, Ordering::Relaxed);
            let _ = self.send_frame(&Frame::Cancel { req, session });
        }
    }
}

/// Reader thread: demultiplex until EOF/error, then fail everything.
fn read_loop(inner: Arc<ClientInner>, mut reader: std::io::BufReader<Stream>) {
    loop {
        match wire::read_frame(&mut reader) {
            Ok(Some(frame)) => inner.dispatch(frame),
            Ok(None) => break,
            Err(e) => {
                // ORDERING: Relaxed — only gates a log line (don't
                // double-report a death send_frame already announced).
                if inner.alive.load(Ordering::Relaxed) {
                    crate::debuglog!("net", "connection to {} failed: {e:#}", inner.peer);
                }
                break;
            }
        }
    }
    // ORDERING: Release — published before the drain below; a requester
    // that reads false here (Acquire) and finds its entry already gone
    // knows the drain failed it, so nothing can leak undelivered.
    inner.alive.store(false, Ordering::Release);
    let mut pending = inner.pending.lock().unwrap_or_else(|e| e.into_inner());
    for (_, p) in pending.drain() {
        match p {
            Pending::Resp(tx) => {
                let _ = tx.send(Err(anyhow!("connection to {} lost", inner.peer)));
            }
            Pending::Stream { tx, .. } => {
                let _ = tx.send(StreamItem::End(Err(anyhow!(
                    "connection to {} lost mid-generation",
                    inner.peer
                ))));
            }
        }
    }
}

/// A session living on a remote worker (or behind a router), driven
/// through the [`Session`] trait exactly like a local
/// [`crate::coordinator::SessionHandle`].
pub struct RemoteSession {
    client: Client,
    session: u64,
    closed: bool,
}

impl RemoteSession {
    /// The session id (globally meaningful: it survives migration).
    pub fn id(&self) -> u64 {
        self.session
    }
}

impl Session for RemoteSession {
    fn session_id(&self) -> u64 {
        self.session
    }

    fn feed(&self, tokens: Vec<i32>, count_loss: bool) -> Result<FeedResult> {
        let req = self.client.fresh_req();
        let frame = Frame::Feed { req, session: self.session, count_loss, tokens };
        match self.client.request(req, frame)? {
            Frame::FeedOk { nll_sum, count, evicted, .. } => {
                Ok(FeedResult { nll_sum, count, evicted })
            }
            f => bail!("unexpected reply to Feed: {}", f.name()),
        }
    }

    fn generate(&self, opts: GenOpts) -> Result<TokenStream> {
        self.client.start_generate(self.session, opts)
    }

    fn cancel(&self) -> Result<()> {
        let req = self.client.fresh_req();
        match self.client.request(req, Frame::Cancel { req, session: self.session })? {
            Frame::Ack { .. } => Ok(()),
            f => bail!("unexpected reply to Cancel: {}", f.name()),
        }
    }

    fn export_carry(&self) -> Result<CarrySnapshot> {
        let req = self.client.fresh_req();
        match self.client.request(req, Frame::ExportCarry { req, session: self.session })? {
            Frame::Carry { snap, .. } => Ok(snap),
            f => bail!("unexpected reply to ExportCarry: {}", f.name()),
        }
    }

    fn import_carry(&self, snap: CarrySnapshot) -> Result<Option<u64>> {
        let req = self.client.fresh_req();
        let frame = Frame::ImportCarry { req, session: self.session, snap };
        match self.client.request(req, frame)? {
            Frame::ImportOk { evicted, .. } => Ok(evicted),
            f => bail!("unexpected reply to ImportCarry: {}", f.name()),
        }
    }

    fn close(&mut self) -> Result<()> {
        if self.closed {
            return Ok(());
        }
        self.closed = true;
        let req = self.client.fresh_req();
        match self.client.request(req, Frame::Close { req, session: self.session })? {
            Frame::Ack { .. } => Ok(()),
            f => bail!("unexpected reply to Close: {}", f.name()),
        }
    }
}

impl Drop for RemoteSession {
    fn drop(&mut self) {
        if !self.closed && self.client.is_alive() {
            // fire-and-forget: the Ack comes back unmatched and is
            // dropped; the worker releases the session either way
            self.closed = true;
            let req = self.client.fresh_req();
            let _ = self
                .client
                .inner
                .send_frame(&Frame::Close { req, session: self.session });
        }
    }
}
