//! Layer-4 sharded serving: the multi-process story on top of the
//! continuous-batching [`crate::coordinator::Server`].
//!
//! The STLT carry is O(S·d) per session — a few hundred KiB at e2e
//! scale, perfectly serializable — so sessions are cheap to route
//! between processes and *migrate live*, unlike an O(N·d) KV cache.
//! This module turns that property into a deployment topology:
//!
//!   clients ──wire──> router (`stlt router`) ──wire──> N workers
//!                       │ hash-routes session ids        (`stlt worker`)
//!                       │ multiplexes connections         one Server +
//!                       │ migrates carries on             StatePool each
//!                       │ drain/rebalance
//!
//! * [`wire`]: the dependency-free length-prefixed binary frame
//!   protocol (versioned handshake, request/stream/error frames,
//!   carry snapshots as raw bits).
//! * [`worker`]: serves a [`crate::coordinator::Server`] over the
//!   protocol — per-connection reader + bounded writer, per-request
//!   threads, and teardown that releases (and thereby cancels) every
//!   session a dropped connection owned.
//! * [`client`]: [`Client`] multiplexes one connection;
//!   [`RemoteSession`] implements [`crate::coordinator::Session`], so
//!   local and remote sessions are interchangeable.
//! * [`router`]: [`Router`] fans sessions out across workers by id
//!   hash and moves them between workers with
//!   `ExportCarry`/`ImportCarry` (bitwise-identical continuations,
//!   pinned by `tests/native_wire.rs`).
//!
//! Addresses are `host:port` (TCP, `TCP_NODELAY` — token streams are
//! latency-bound) or `unix:/path/to.sock` on Unix.

pub mod client;
pub mod router;
pub mod wire;
pub mod worker;

pub use client::{Client, RemoteSession};
pub use router::{Router, RouterSession};
pub use wire::{
    read_frame, write_frame, write_frame_buf, EndOutcome, Frame, MAGIC, MAX_FRAME,
    PROTOCOL_VERSION,
};
pub use worker::{spawn_worker, WireServer};

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};

use anyhow::{Context, Result};

/// One bidirectional byte stream: TCP or (on Unix) a Unix-domain
/// socket. `try_clone` splits it into independently-owned read/write
/// halves (reader thread + writer thread).
pub enum Stream {
    Tcp(TcpStream),
    #[cfg(unix)]
    Unix(UnixStream),
}

impl Stream {
    /// Connect to `addr`: `host:port` or `unix:/path`.
    pub fn connect(addr: &str) -> Result<Stream> {
        if let Some(path) = addr.strip_prefix("unix:") {
            #[cfg(unix)]
            {
                let s = UnixStream::connect(path)
                    .with_context(|| format!("connect to unix socket {path}"))?;
                return Ok(Stream::Unix(s));
            }
            #[cfg(not(unix))]
            anyhow::bail!("unix sockets unsupported on this platform: {path}");
        }
        let s = TcpStream::connect(addr).with_context(|| format!("connect to {addr}"))?;
        // token streams are one small frame at a time; Nagle would add
        // up to 40ms per token
        let _ = s.set_nodelay(true);
        Ok(Stream::Tcp(s))
    }

    pub fn try_clone(&self) -> Result<Stream> {
        Ok(match self {
            Stream::Tcp(s) => Stream::Tcp(s.try_clone()?),
            #[cfg(unix)]
            Stream::Unix(s) => Stream::Unix(s.try_clone()?),
        })
    }

    /// Hard-close both halves (unblocks a reader in another thread).
    pub fn shutdown(&self) {
        match self {
            Stream::Tcp(s) => {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
            #[cfg(unix)]
            Stream::Unix(s) => {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
        }
    }
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            Stream::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            Stream::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.flush(),
            #[cfg(unix)]
            Stream::Unix(s) => s.flush(),
        }
    }
}

/// Listening socket for either address family.
pub enum Listener {
    Tcp(TcpListener),
    #[cfg(unix)]
    Unix(UnixListener),
}

impl Listener {
    /// Bind `addr`: `host:port` (`:0` for an ephemeral port) or
    /// `unix:/path` (a stale socket file is removed first).
    pub fn bind(addr: &str) -> Result<Listener> {
        if let Some(path) = addr.strip_prefix("unix:") {
            #[cfg(unix)]
            {
                let _ = std::fs::remove_file(path);
                let l = UnixListener::bind(path)
                    .with_context(|| format!("bind unix socket {path}"))?;
                return Ok(Listener::Unix(l));
            }
            #[cfg(not(unix))]
            anyhow::bail!("unix sockets unsupported on this platform: {path}");
        }
        let l = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        Ok(Listener::Tcp(l))
    }

    /// The bound address in connectable form (resolves `:0`).
    pub fn local_addr(&self) -> Result<String> {
        Ok(match self {
            Listener::Tcp(l) => l.local_addr()?.to_string(),
            #[cfg(unix)]
            Listener::Unix(l) => {
                let a = l.local_addr()?;
                match a.as_pathname() {
                    Some(p) => format!("unix:{}", p.display()),
                    None => "unix:?".to_string(),
                }
            }
        })
    }

    pub fn set_nonblocking(&self, v: bool) -> Result<()> {
        match self {
            Listener::Tcp(l) => l.set_nonblocking(v)?,
            #[cfg(unix)]
            Listener::Unix(l) => l.set_nonblocking(v)?,
        }
        Ok(())
    }

    /// Accept one connection (respects `set_nonblocking`).
    pub fn accept(&self) -> std::io::Result<Stream> {
        match self {
            Listener::Tcp(l) => {
                let (s, _) = l.accept()?;
                let _ = s.set_nodelay(true);
                Ok(Stream::Tcp(s))
            }
            #[cfg(unix)]
            Listener::Unix(l) => {
                let (s, _) = l.accept()?;
                Ok(Stream::Unix(s))
            }
        }
    }
}
