//! Session router: the sharding front-end over N wire workers.
//!
//! Sessions are placed on workers by a splitmix hash of the session
//! id (stable across router restarts for explicitly-chosen ids) with
//! linear probing past dead workers. Each session's placement is a
//! mutex-guarded `(worker, RemoteSession)` pair: ops lock the
//! placement for their duration, so a migration never races an
//! in-flight feed/generate — it waits, then atomically swaps where
//! the session lives.
//!
//! Live migration is the STLT-specific payoff: a session is its
//! O(S·d) carry, so `migrate` = `ExportCarry` from worker A → `Open`
//! the *same session id* on worker B → `ImportCarry` → swap
//! placement. Preserving the id preserves the generation RNG seed
//! (`rng_seed ^ session`), and carries cross the wire as raw f32
//! bits, so a migrated session's continuation is bitwise identical to
//! never having moved (pinned by `tests/native_wire.rs`).
//!
//! The router is usable two ways:
//! * in-process: [`Router::open_session`] hands out
//!   [`RouterSession`]s (the [`Session`] trait again);
//! * as a process: [`Router::listen`] serves the same wire protocol
//!   clients speak to workers — `stlt serve --connect` cannot tell a
//!   router from a worker.

use std::collections::HashMap;

use anyhow::{anyhow, bail, Result};

use crate::coordinator::{CarrySnapshot, FeedResult, GenOpts, Session, TokenStream};
use crate::util::sync::atomic::{AtomicU64, Ordering};
use crate::util::sync::{Arc, Mutex};

use super::client::{Client, RemoteSession};
use super::worker::{spawn_node, Node, WireServer};

static MIGRATIONS: crate::obs::LazyCounter = crate::obs::LazyCounter::new("router/migrations");
static MIGRATE_SECONDS: crate::obs::LazyHist = crate::obs::LazyHist::new("router/migrate_seconds");
static SESSIONS_OPEN: crate::obs::LazyGauge = crate::obs::LazyGauge::new("router/sessions_open");

/// Router-allocated session ids start here: disjoint from both
/// `Server::open_session` ids (1<<32) and small hand-picked ids.
const ROUTER_SESSION_BASE: u64 = 1 << 40;

struct WorkerLink {
    addr: String,
    client: Client,
}

/// Where one session currently lives.
struct Placement {
    worker: usize,
    remote: RemoteSession,
}

struct Routed {
    /// Locked for the duration of every op on the session; migration
    /// takes the same lock, so ops never straddle a move.
    place: Mutex<Placement>,
}

pub(crate) struct RouterCore {
    workers: Vec<WorkerLink>,
    sessions: Mutex<HashMap<u64, Arc<Routed>>>,
    next_session: AtomicU64,
}

/// The sharding front-end. Cheap to clone; all clones share worker
/// connections and the placement table.
#[derive(Clone)]
pub struct Router {
    core: Arc<RouterCore>,
}

impl Router {
    /// Connect to every worker address (`host:port` or `unix:/path`).
    /// All workers must be reachable at startup; losing one later
    /// fails only the sessions placed on it.
    pub fn connect(worker_addrs: &[String]) -> Result<Router> {
        if worker_addrs.is_empty() {
            bail!("router needs at least one worker address");
        }
        let mut workers = Vec::with_capacity(worker_addrs.len());
        for addr in worker_addrs {
            let client = Client::connect(addr)?;
            workers.push(WorkerLink { addr: addr.clone(), client });
        }
        // register the router's metric families up front so a stats
        // scrape sees them (zeroed) even before the first migration
        crate::obs::counter("router/migrations");
        crate::obs::hist("router/migrate_seconds");
        crate::obs::gauge("router/sessions_open");
        Ok(Router {
            core: Arc::new(RouterCore {
                workers,
                sessions: Mutex::new(HashMap::new()),
                next_session: AtomicU64::new(ROUTER_SESSION_BASE),
            }),
        })
    }

    /// Open a session on the worker its id hashes to.
    pub fn open_session(&self) -> Result<RouterSession> {
        let id = self.core.open(0)?;
        Ok(RouterSession { core: Arc::clone(&self.core), id, closed: false })
    }

    /// Open a session with an explicit id (for resume-by-id flows).
    pub fn open_session_with_id(&self, id: u64) -> Result<RouterSession> {
        let id = self.core.open(id)?;
        Ok(RouterSession { core: Arc::clone(&self.core), id, closed: false })
    }

    pub fn worker_count(&self) -> usize {
        self.core.workers.len()
    }

    pub fn worker_addr(&self, worker: usize) -> Option<&str> {
        self.core.workers.get(worker).map(|w| w.addr.as_str())
    }

    pub fn worker_alive(&self, worker: usize) -> bool {
        self.core.workers.get(worker).is_some_and(|w| w.client.is_alive())
    }

    /// Which worker a session currently lives on.
    pub fn worker_of(&self, session: u64) -> Option<usize> {
        let routed = self.core.routed(session).ok()?;
        let place = routed.place.lock().unwrap_or_else(|e| e.into_inner());
        Some(place.worker)
    }

    /// Sessions currently placed on `worker`.
    pub fn sessions_on(&self, worker: usize) -> Vec<u64> {
        let sessions = self.core.sessions.lock().unwrap_or_else(|e| e.into_inner());
        let mut out = Vec::new();
        for (id, routed) in sessions.iter() {
            if routed.place.lock().unwrap_or_else(|e| e.into_inner()).worker == worker {
                out.push(*id);
            }
        }
        out.sort_unstable();
        out
    }

    /// Total sessions the router is tracking.
    pub fn session_count(&self) -> usize {
        self.core.sessions.lock().unwrap_or_else(|e| e.into_inner()).len()
    }

    /// Live-migrate one session to `to`. Blocks until in-flight ops on
    /// the session finish (placement lock), then ships the carry.
    /// No-op `Ok` if the session is already there.
    pub fn migrate(&self, session: u64, to: usize) -> Result<()> {
        self.core.migrate(session, to)
    }

    /// Drain `worker`: migrate every session off it, round-robin onto
    /// the other alive workers. Returns (moved, failed).
    pub fn drain(&self, worker: usize) -> (usize, usize) {
        let ids = self.sessions_on(worker);
        let targets: Vec<usize> = (0..self.core.workers.len())
            .filter(|&w| w != worker && self.worker_alive(w))
            .collect();
        if targets.is_empty() {
            return (0, ids.len());
        }
        let (mut moved, mut failed) = (0, 0);
        for (id, &target) in ids.iter().zip(targets.iter().cycle()) {
            match self.core.migrate(*id, target) {
                Ok(()) => moved += 1,
                Err(e) => {
                    crate::warnlog!("router", "drain: session {id} failed to move: {e:#}");
                    failed += 1;
                }
            }
        }
        (moved, failed)
    }

    /// One rebalance pass: move sessions from the most-loaded worker
    /// to the least-loaded until they differ by at most one. Returns
    /// sessions moved.
    pub fn rebalance_once(&self) -> usize {
        let n = self.core.workers.len();
        if n < 2 {
            return 0;
        }
        let mut moved = 0;
        loop {
            let loads: Vec<usize> = (0..n).map(|w| self.sessions_on(w).len()).collect();
            let alive: Vec<usize> = (0..n).filter(|&w| self.worker_alive(w)).collect();
            if alive.len() < 2 {
                return moved;
            }
            // alive.len() >= 2 here, but prove it to the compiler
            // rather than unwrapping
            let load_of = |w: usize| loads.get(w).copied().unwrap_or(0);
            let (Some(&max_w), Some(&min_w)) = (
                alive.iter().max_by_key(|&&w| load_of(w)),
                alive.iter().min_by_key(|&&w| load_of(w)),
            ) else {
                return moved;
            };
            if load_of(max_w) <= load_of(min_w) + 1 {
                return moved;
            }
            let candidates = self.sessions_on(max_w);
            let Some(&id) = candidates.first() else { return moved };
            match self.core.migrate(id, min_w) {
                Ok(()) => moved += 1,
                Err(_) => return moved, // likely in-flight; try next pass
            }
        }
    }

    /// Serve the wire protocol (the same one workers speak) at
    /// `listen`; clients drive routed sessions transparently.
    pub fn listen(&self, listen: &str) -> Result<WireServer> {
        let node: Arc<dyn Node> = Arc::clone(&self.core) as Arc<dyn Node>;
        spawn_node(node, listen, "router")
    }
}

impl RouterCore {
    /// splitmix64 finalizer: uncorrelated worker choice from
    /// sequential session ids.
    fn hash_worker(&self, session: u64) -> usize {
        let mut z = session.wrapping_add(0x9E37_79B9_7F4A_7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^= z >> 31;
        (z % self.workers.len() as u64) as usize
    }

    /// Preferred worker for `session`, probing past dead links.
    fn pick(&self, session: u64) -> Result<usize> {
        let n = self.workers.len();
        let start = self.hash_worker(session);
        for i in 0..n {
            let w = (start + i) % n;
            if self.workers.get(w).is_some_and(|wk| wk.client.is_alive()) {
                return Ok(w);
            }
        }
        bail!("no alive workers")
    }

    fn routed(&self, session: u64) -> Result<Arc<Routed>> {
        self.sessions
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .get(&session)
            .cloned()
            .ok_or_else(|| anyhow!("session {session} is not open on this router"))
    }

    fn open(&self, desired: u64) -> Result<u64> {
        let id = if desired == 0 {
            // ORDERING: Relaxed — ids only need uniqueness; the routed
            // entry itself is published via the `sessions` Mutex.
            self.next_session.fetch_add(1, Ordering::Relaxed)
        } else {
            desired
        };
        let worker = self.pick(id)?;
        // Reserve the id before the worker round-trip so two clients
        // opening the same id race on the map, not on the worker.
        {
            let mut sessions = self.sessions.lock().unwrap_or_else(|e| e.into_inner());
            if sessions.contains_key(&id) {
                bail!("session {id} is already open on this router");
            }
            // placeholder-free reservation: insert after the remote
            // open would be racy, so hold the map lock across it only
            // for explicit ids (allocated ids cannot collide)
        }
        let remote = self
            .workers
            .get(worker)
            .ok_or_else(|| anyhow!("placement probe returned unknown worker {worker}"))?
            .client
            .open(id)?;
        let routed = Arc::new(Routed { place: Mutex::new(Placement { worker, remote }) });
        let mut sessions = self.sessions.lock().unwrap_or_else(|e| e.into_inner());
        if sessions.contains_key(&id) {
            // two explicit opens raced; the remote session drops (and
            // closes worker-side) harmlessly
            bail!("session {id} is already open on this router");
        }
        sessions.insert(id, routed);
        SESSIONS_OPEN.set(sessions.len() as f64);
        Ok(id)
    }

    fn close(&self, session: u64) -> Result<()> {
        let routed = {
            let mut sessions = self.sessions.lock().unwrap_or_else(|e| e.into_inner());
            let r = sessions.remove(&session);
            SESSIONS_OPEN.set(sessions.len() as f64);
            match r {
                Some(r) => r,
                None => return Ok(()),
            }
        };
        let mut place = routed.place.lock().unwrap_or_else(|e| e.into_inner());
        place.remote.close()
    }

    fn migrate(&self, session: u64, to: usize) -> Result<()> {
        let dst = self.workers.get(to).ok_or_else(|| anyhow!("no such worker {to}"))?;
        if !dst.client.is_alive() {
            bail!("worker {to} ({}) is down", dst.addr);
        }
        let routed = self.routed(session)?;
        let mut place = routed.place.lock().unwrap_or_else(|e| e.into_inner());
        if place.worker == to {
            return Ok(());
        }
        let _span = crate::obs::span("router", "migrate");
        let t0 = std::time::Instant::now();
        // Export waits for nothing: the placement lock means no op of
        // ours is in flight, and the worker refuses if some *other*
        // path holds the carry.
        let snap = {
            let _s = crate::obs::span("router", "migrate_export");
            place.remote.export_carry()?
        };
        // Same session id on the destination — the RNG-seed coupling
        // (rng_seed ^ session) is what keeps continuations bitwise.
        let mut fresh = {
            let _s = crate::obs::span("router", "migrate_open");
            dst.client.open(session)?
        };
        {
            let _s = crate::obs::span("router", "migrate_import");
            if let Err(e) = fresh.import_carry(snap) {
                let _ = fresh.close();
                return Err(e.context(format!("importing carry on worker {to}")));
            }
        }
        let _s = crate::obs::span("router", "migrate_swap");
        let old_worker = place.worker;
        let mut old = std::mem::replace(&mut *place, Placement { worker: to, remote: fresh });
        // Best-effort: the source may be mid-death during a drain.
        if let Err(e) = old.remote.close() {
            crate::debuglog!(
                "router",
                "migrate: closing session {session} on worker {old_worker} failed: {e:#}"
            );
        }
        MIGRATIONS.inc();
        MIGRATE_SECONDS.record(t0.elapsed().as_secs_f64());
        Ok(())
    }
}

// The router's wire face: the same serve_conn loop workers use, over
// routed sessions. Open allocates router ids; everything else locks
// the placement and forwards.
impl Node for RouterCore {
    fn node_open(&self, desired: u64) -> Result<u64> {
        self.open(desired)
    }

    fn node_feed(&self, id: u64, tokens: Vec<i32>, count_loss: bool) -> Result<FeedResult> {
        let routed = self.routed(id)?;
        let place = routed.place.lock().unwrap_or_else(|e| e.into_inner());
        place.remote.feed(tokens, count_loss)
    }

    fn node_generate(&self, id: u64, opts: GenOpts) -> Result<TokenStream> {
        let routed = self.routed(id)?;
        let place = routed.place.lock().unwrap_or_else(|e| e.into_inner());
        place.remote.generate(opts)
    }

    fn node_cancel(&self, id: u64) -> Result<()> {
        let routed = self.routed(id)?;
        let place = routed.place.lock().unwrap_or_else(|e| e.into_inner());
        place.remote.cancel()
    }

    fn node_close(&self, id: u64) -> Result<()> {
        self.close(id)
    }

    fn node_export(&self, id: u64) -> Result<CarrySnapshot> {
        let routed = self.routed(id)?;
        let place = routed.place.lock().unwrap_or_else(|e| e.into_inner());
        place.remote.export_carry()
    }

    fn node_import(&self, id: u64, snap: CarrySnapshot) -> Result<Option<u64>> {
        let routed = self.routed(id)?;
        let place = routed.place.lock().unwrap_or_else(|e| e.into_inner());
        place.remote.import_carry(snap)
    }
}

/// A routed session handle: the [`Session`] trait over whichever
/// worker the router currently places the session on. Migration is
/// transparent — ops serialize against it via the placement lock.
pub struct RouterSession {
    core: Arc<RouterCore>,
    id: u64,
    closed: bool,
}

impl RouterSession {
    pub fn id(&self) -> u64 {
        self.id
    }
}

impl Session for RouterSession {
    fn session_id(&self) -> u64 {
        self.id
    }

    fn feed(&self, tokens: Vec<i32>, count_loss: bool) -> Result<FeedResult> {
        self.core.node_feed(self.id, tokens, count_loss)
    }

    fn generate(&self, opts: GenOpts) -> Result<TokenStream> {
        self.core.node_generate(self.id, opts)
    }

    fn cancel(&self) -> Result<()> {
        self.core.node_cancel(self.id)
    }

    fn export_carry(&self) -> Result<CarrySnapshot> {
        self.core.node_export(self.id)
    }

    fn import_carry(&self, snap: CarrySnapshot) -> Result<Option<u64>> {
        self.core.node_import(self.id, snap)
    }

    fn close(&mut self) -> Result<()> {
        if self.closed {
            return Ok(());
        }
        self.closed = true;
        self.core.close(self.id)
    }
}

impl Drop for RouterSession {
    fn drop(&mut self) {
        if !self.closed {
            let _ = self.core.close(self.id);
        }
    }
}

/// Model-check the migration placement protocol (build with
/// `RUSTFLAGS="--cfg model_check"`): [`RouterCore::migrate`]'s
/// correctness rests on holding the placement lock across the whole
/// export → open/import → swap sequence, so a concurrent op can never
/// observe a placement whose worker no longer holds the carry. The
/// model reduces a worker to "does it hold the carry" and an op to
/// "read the placement, expect the carry there"; the mutant re-locks
/// between export and swap — exactly the window the real lock closes —
/// and the checker must catch the feed that falls into it.
#[cfg(all(test, model_check))]
mod model_check {
    use crate::util::chk::{self, Config};
    use crate::util::sync::atomic::{AtomicBool, Ordering};
    use crate::util::sync::{Arc, Mutex};

    /// Feed-path model: under the placement lock, the placed worker
    /// must hold the carry.
    fn feeder(place: &Mutex<usize>, carry: &[AtomicBool; 2]) {
        let g = place.lock().unwrap_or_else(|e| e.into_inner());
        assert!(
            carry[*g].load(Ordering::SeqCst),
            "placement points at worker {} but the carry is not there",
            *g
        );
    }

    #[test]
    fn migration_placement_protocol_holds() {
        let report = chk::check(Config::default(), || {
            let place = Arc::new(Mutex::new(0usize));
            let carry = Arc::new([AtomicBool::new(true), AtomicBool::new(false)]);
            let (p2, c2) = (Arc::clone(&place), Arc::clone(&carry));
            let migrator = chk::spawn(move || {
                // migrate(): one lock held across export/import/swap
                let mut g = p2.lock().unwrap_or_else(|e| e.into_inner());
                let from = *g;
                let to = 1 - from;
                assert!(c2[from].swap(false, Ordering::SeqCst), "export needs the carry");
                c2[to].store(true, Ordering::SeqCst);
                *g = to;
            });
            let (p3, c3) = (Arc::clone(&place), Arc::clone(&carry));
            let ops = chk::spawn(move || {
                feeder(&p3, &c3);
                feeder(&p3, &c3);
            });
            migrator.join();
            ops.join();
            feeder(&place, &carry);
        });
        report.assert_ok();
        assert!(report.dfs_complete, "migration protocol should be exhaustible");
    }

    /// Mutant: export under one lock acquisition, swap under another —
    /// a feed scheduled into the gap sees the stale placement with the
    /// carry already exported.
    #[test]
    fn checker_catches_migration_lock_gap() {
        let report = chk::check(Config::default(), || {
            let place = Arc::new(Mutex::new(0usize));
            let carry = Arc::new([AtomicBool::new(true), AtomicBool::new(false)]);
            let (p2, c2) = (Arc::clone(&place), Arc::clone(&carry));
            let migrator = chk::spawn(move || {
                let from = *p2.lock().unwrap_or_else(|e| e.into_inner());
                // BUG: the placement lock is released here.
                let to = 1 - from;
                assert!(c2[from].swap(false, Ordering::SeqCst), "export needs the carry");
                c2[to].store(true, Ordering::SeqCst);
                *p2.lock().unwrap_or_else(|e| e.into_inner()) = to;
            });
            let (p3, c3) = (Arc::clone(&place), Arc::clone(&carry));
            let ops = chk::spawn(move || feeder(&p3, &c3));
            migrator.join();
            ops.join();
        });
        let f = report.assert_fails();
        assert!(f.message.contains("panicked"), "{}", f.message);
    }
}
