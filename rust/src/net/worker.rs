//! Wire-serving loop: host a [`Server`] (worker) or a router core
//! behind the frame protocol.
//!
//! Connection model (one per client):
//!
//! * the accept loop spawns a *reader* thread per connection, which
//!   dispatches frames; blocking operations (feed, export/import,
//!   generation relays) run on short-lived per-request threads so one
//!   slow feed never stalls the connection;
//! * all replies funnel through one *writer* thread behind a bounded
//!   channel ([`FRAME_WINDOW`]) — per-connection backpressure: a slow
//!   client throttles its own producers instead of ballooning memory;
//! * at most [`MAX_INFLIGHT`] operations may be in flight per
//!   connection; excess requests get an `Error` frame immediately
//!   (admission parking *inside* the server is the capacity story —
//!   this bound is purely against a misbehaving client);
//! * on disconnect — clean or abrupt — the reader releases every
//!   session the connection opened. Release runs the PR-5 cancel
//!   path, so a client that vanishes mid-`generate` cancels its
//!   in-flight generation at the next wave boundary instead of
//!   leaking a pinned session (pinned by `tests/native_wire.rs`).

use std::collections::HashMap;
use std::thread;
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use crate::coordinator::session::StreamItem;
use crate::coordinator::{CarrySnapshot, FeedResult, GenOpts, Server, SessionHandle, TokenStream};
use crate::util::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use crate::util::sync::{mpsc, Arc, Mutex};

use super::wire::{self, EndOutcome, Frame};
use super::{Listener, Stream};

/// Writer-channel depth (frames). A full window blocks the producing
/// request thread — the per-connection backpressure seam.
pub const FRAME_WINDOW: usize = 256;
/// Per-connection cap on concurrently running operations.
pub const MAX_INFLIGHT: usize = 1024;

/// What a wire endpoint serves: the session-by-id operations behind
/// the frame protocol. Implemented by the worker (over one [`Server`])
/// and by the router core (over routed remote sessions), so both ends
/// share one [`serve_conn`] loop.
pub(crate) trait Node: Send + Sync {
    /// Open a session; `desired == 0` means allocate. Returns the id.
    fn node_open(&self, desired: u64) -> Result<u64>;
    fn node_feed(&self, id: u64, tokens: Vec<i32>, count_loss: bool) -> Result<FeedResult>;
    fn node_generate(&self, id: u64, opts: GenOpts) -> Result<TokenStream>;
    fn node_cancel(&self, id: u64) -> Result<()>;
    fn node_close(&self, id: u64) -> Result<()>;
    fn node_export(&self, id: u64) -> Result<CarrySnapshot>;
    fn node_import(&self, id: u64, snap: CarrySnapshot) -> Result<Option<u64>>;
    /// Render this process's metrics registry (exposition text). The
    /// default covers every node kind: a worker's registry carries its
    /// server/scheduler/panel families, a router's its migration
    /// families — both live in the same process-wide registry.
    fn node_stats(&self) -> Result<String> {
        Ok(crate::obs::render())
    }
}

/// The worker-side [`Node`]: one continuous-batching [`Server`] plus
/// the registry of sessions currently owned by live connections (two
/// connections can never claim the same session id).
pub(crate) struct WorkerNode {
    server: Arc<Server>,
    active: Mutex<HashMap<u64, SessionHandle>>,
}

impl WorkerNode {
    pub(crate) fn new(server: Arc<Server>) -> WorkerNode {
        WorkerNode { server, active: Mutex::new(HashMap::new()) }
    }
}

impl Node for WorkerNode {
    fn node_open(&self, desired: u64) -> Result<u64> {
        let mut active = self.active.lock().unwrap_or_else(|e| e.into_inner());
        let handle = if desired == 0 {
            self.server.open_session()
        } else {
            if active.contains_key(&desired) {
                bail!("session {desired} is already open on this worker");
            }
            self.server.session_handle(desired)
        };
        let id = handle.id();
        if desired == 0 && active.contains_key(&id) {
            // cannot happen (open_session ids are unique), but never
            // clobber an owned session on a logic regression
            bail!("session allocator returned an id already in use: {id}");
        }
        active.insert(id, handle);
        Ok(id)
    }

    fn node_feed(&self, id: u64, tokens: Vec<i32>, count_loss: bool) -> Result<FeedResult> {
        self.server.feed(id, tokens, count_loss)
    }

    fn node_generate(&self, id: u64, opts: GenOpts) -> Result<TokenStream> {
        self.server.start_generate(id, opts)
    }

    fn node_cancel(&self, id: u64) -> Result<()> {
        self.server.cancel(id)
    }

    fn node_close(&self, id: u64) -> Result<()> {
        match self.active.lock().unwrap_or_else(|e| e.into_inner()).remove(&id) {
            // close() releases the carry; a released session's
            // in-flight generation ends Cancelled (the PR-5 path)
            Some(handle) => handle.close(),
            None => Ok(()),
        }
    }

    fn node_export(&self, id: u64) -> Result<CarrySnapshot> {
        self.server.export_carry(id)
    }

    fn node_import(&self, id: u64, snap: CarrySnapshot) -> Result<Option<u64>> {
        self.server.import_carry(id, snap)
    }
}

/// A running wire endpoint (accept loop + per-connection threads).
/// Dropping it stops accepting; live connections run to their natural
/// end (process exit tears them down in the CLI).
pub struct WireServer {
    addr: String,
    stop: Arc<AtomicBool>,
    accept_thread: Option<thread::JoinHandle<()>>,
}

impl WireServer {
    /// The bound address (with `:0` resolved to the real port).
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Stop accepting new connections and join the accept loop.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        // ORDERING: Relaxed — pure stop flag; the accept loop re-polls
        // it every iteration and publishes nothing through it. join()
        // below is the real synchronization edge.
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for WireServer {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

/// Serve `server` over the wire protocol at `listen`
/// (`host:port`/`:0` or `unix:/path`). Returns once bound; accepting
/// runs on a background thread.
pub fn spawn_worker(server: Arc<Server>, listen: &str) -> Result<WireServer> {
    spawn_node(Arc::new(WorkerNode::new(server)), listen, "worker")
}

pub(crate) fn spawn_node(
    node: Arc<dyn Node>,
    listen: &str,
    label: &'static str,
) -> Result<WireServer> {
    let listener = Listener::bind(listen)?;
    let addr = listener.local_addr()?;
    listener.set_nonblocking(true)?;
    let stop = Arc::new(AtomicBool::new(false));
    let stop_t = Arc::clone(&stop);
    let accept_thread = thread::Builder::new()
        .name(format!("stlt-{label}-accept"))
        .spawn(move || {
            // ORDERING: Relaxed — see stop_and_join(): a late read only
            // delays shutdown by one accept-poll interval.
            while !stop_t.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok(stream) => {
                        let node = Arc::clone(&node);
                        let _ = thread::Builder::new()
                            .name(format!("stlt-{label}-conn"))
                            .spawn(move || serve_conn(node, stream, label));
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        thread::sleep(Duration::from_millis(5));
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                    Err(e) => {
                        crate::warnlog!("net", "{label} accept error: {e}");
                        thread::sleep(Duration::from_millis(50));
                    }
                }
            }
        })?;
    Ok(WireServer { addr, stop, accept_thread: Some(accept_thread) })
}

/// Decrements the in-flight counter when a request thread finishes
/// (on every exit path, including panics unwinding).
struct InflightGuard(Arc<AtomicUsize>);

impl Drop for InflightGuard {
    fn drop(&mut self) {
        // ORDERING: Relaxed — pairs with the CAS in admit_inflight;
        // the counter is a pure admission cap and publishes no data.
        self.0.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Serve one connection to completion. Cleanup (session release) runs
/// on every exit path — clean EOF, protocol error, or socket failure.
fn serve_conn(node: Arc<dyn Node>, stream: Stream, label: &'static str) {
    match conn_loop(&node, stream) {
        Ok(()) => {}
        Err(e) => crate::debuglog!("net", "{label} connection ended: {e:#}"),
    }
}

fn conn_loop(node: &Arc<dyn Node>, stream: Stream) -> Result<()> {
    let mut reader = std::io::BufReader::new(stream.try_clone()?);

    // Handshake happens before the writer thread exists; replies go
    // straight to the socket.
    let mut direct = stream.try_clone()?;
    match wire::read_frame(&mut reader)? {
        Some(Frame::Hello { magic, version })
            if magic == wire::MAGIC && version == wire::PROTOCOL_VERSION =>
        {
            wire::write_frame(&mut direct, &Frame::HelloAck { version: wire::PROTOCOL_VERSION })?;
            use std::io::Write;
            direct.flush()?;
        }
        Some(Frame::Hello { magic, version }) => {
            let msg = if magic != wire::MAGIC {
                format!("handshake: bad magic 0x{magic:08x} (not an STLT peer?)")
            } else {
                format!(
                    "handshake: protocol version {version} != {} (upgrade both ends)",
                    wire::PROTOCOL_VERSION
                )
            };
            let _ = wire::write_frame(&mut direct, &Frame::Error { req: 0, msg: msg.clone() });
            use std::io::Write;
            let _ = direct.flush();
            bail!("{msg}");
        }
        Some(f) => bail!("handshake: expected Hello, got {}", f.name()),
        None => return Ok(()), // connected and left without a word
    }

    // Writer thread: the single socket writer. Bounded channel =
    // per-connection backpressure. On a write error it keeps draining
    // (discarding) so producers never block on a dead socket.
    let (out_tx, out_rx) = mpsc::sync_channel::<Frame>(FRAME_WINDOW);
    let wstream = stream.try_clone()?;
    let writer = thread::Builder::new()
        .name("stlt-conn-writer".into())
        .spawn(move || write_loop(wstream, out_rx))?;

    // Sessions this connection opened; released on any exit.
    let mut owned: std::collections::HashSet<u64> = std::collections::HashSet::new();
    let inflight = Arc::new(AtomicUsize::new(0));

    let send_err = |req: u64, msg: String| {
        let _ = out_tx.send(Frame::Error { req, msg });
    };

    let result = loop {
        let frame = match wire::read_frame(&mut reader) {
            Ok(Some(f)) => f,
            Ok(None) => break Ok(()), // clean EOF
            Err(e) => break Err(e),
        };
        match frame {
            Frame::Open { req, session } => match node.node_open(session) {
                Ok(id) => {
                    owned.insert(id);
                    let _ = out_tx.send(Frame::OpenOk { req, session: id });
                }
                Err(e) => send_err(req, format!("{e:#}")),
            },
            Frame::Feed { req, session, count_loss, tokens } => {
                if !owned.contains(&session) {
                    send_err(req, format!("session {session} is not open on this connection"));
                    continue;
                }
                if !admit_inflight(&inflight) {
                    send_err(req, format!("connection in-flight limit ({MAX_INFLIGHT}) reached"));
                    continue;
                }
                let node = Arc::clone(node);
                let out = out_tx.clone();
                let guard = InflightGuard(Arc::clone(&inflight));
                spawn_request(move || {
                    let _guard = guard;
                    match node.node_feed(session, tokens, count_loss) {
                        Ok(fr) => {
                            let _ = out.send(Frame::FeedOk {
                                req,
                                nll_sum: fr.nll_sum,
                                count: fr.count,
                                evicted: fr.evicted,
                            });
                        }
                        Err(e) => {
                            let _ = out.send(Frame::Error { req, msg: format!("{e:#}") });
                        }
                    }
                });
            }
            Frame::Generate { req, session, opts } => {
                if !owned.contains(&session) {
                    send_err(req, format!("session {session} is not open on this connection"));
                    continue;
                }
                if !admit_inflight(&inflight) {
                    send_err(req, format!("connection in-flight limit ({MAX_INFLIGHT}) reached"));
                    continue;
                }
                let node = Arc::clone(node);
                let out = out_tx.clone();
                let guard = InflightGuard(Arc::clone(&inflight));
                spawn_request(move || {
                    let _guard = guard;
                    relay_generation(&*node, session, opts, req, &out);
                });
            }
            Frame::Cancel { req, session } => {
                if !owned.contains(&session) {
                    send_err(req, format!("session {session} is not open on this connection"));
                    continue;
                }
                match node.node_cancel(session) {
                    Ok(()) => {
                        let _ = out_tx.send(Frame::Ack { req });
                    }
                    Err(e) => send_err(req, format!("{e:#}")),
                }
            }
            Frame::Close { req, session } => {
                if !owned.remove(&session) {
                    send_err(req, format!("session {session} is not open on this connection"));
                    continue;
                }
                match node.node_close(session) {
                    Ok(()) => {
                        let _ = out_tx.send(Frame::Ack { req });
                    }
                    Err(e) => send_err(req, format!("{e:#}")),
                }
            }
            Frame::ExportCarry { req, session } => {
                if !owned.contains(&session) {
                    send_err(req, format!("session {session} is not open on this connection"));
                    continue;
                }
                if !admit_inflight(&inflight) {
                    send_err(req, format!("connection in-flight limit ({MAX_INFLIGHT}) reached"));
                    continue;
                }
                let node = Arc::clone(node);
                let out = out_tx.clone();
                let guard = InflightGuard(Arc::clone(&inflight));
                spawn_request(move || {
                    let _guard = guard;
                    match node.node_export(session) {
                        Ok(snap) => {
                            let _ = out.send(Frame::Carry { req, snap });
                        }
                        Err(e) => {
                            let _ = out.send(Frame::Error { req, msg: format!("{e:#}") });
                        }
                    }
                });
            }
            Frame::ImportCarry { req, session, snap } => {
                if !owned.contains(&session) {
                    send_err(req, format!("session {session} is not open on this connection"));
                    continue;
                }
                if !admit_inflight(&inflight) {
                    send_err(req, format!("connection in-flight limit ({MAX_INFLIGHT}) reached"));
                    continue;
                }
                let node = Arc::clone(node);
                let out = out_tx.clone();
                let guard = InflightGuard(Arc::clone(&inflight));
                spawn_request(move || {
                    let _guard = guard;
                    match node.node_import(session, snap) {
                        Ok(evicted) => {
                            let _ = out.send(Frame::ImportOk { req, evicted });
                        }
                        Err(e) => {
                            let _ = out.send(Frame::Error { req, msg: format!("{e:#}") });
                        }
                    }
                });
            }
            // Stats needs no session and never blocks on the model
            // thread: render inline on the reader (like Cancel).
            Frame::Stats { req } => match node.node_stats() {
                Ok(text) => {
                    let _ = out_tx.send(Frame::StatsOk {
                        req,
                        version: crate::obs::EXPO_VERSION,
                        text,
                    });
                }
                Err(e) => send_err(req, format!("{e:#}")),
            },
            Frame::Hello { .. } => break Err(anyhow!("unexpected second Hello")),
            f => break Err(anyhow!("unexpected server-side frame {} from client", f.name())),
        }
    };

    // Teardown: release every session this connection owned. For a
    // connection that vanished mid-generate this runs the server's
    // release path, which cancels the in-flight generation — the
    // relay thread sees End(Cancelled) and exits.
    for id in owned {
        let _ = node.node_close(id);
    }
    // The writer exits when every sender is gone: ours now, the relay
    // threads' as their generations end Cancelled.
    drop(out_tx);
    let _ = writer.join();
    result
}

fn admit_inflight(inflight: &Arc<AtomicUsize>) -> bool {
    // ORDERING: Relaxed — the counter is a pure admission cap and
    // publishes no other memory. The single CAS (rather than the old
    // load-then-fetch_add, which could overshoot under concurrent
    // admits) is what makes MAX_INFLIGHT exact.
    inflight
        .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |n| {
            if n < MAX_INFLIGHT {
                Some(n + 1)
            } else {
                None
            }
        })
        .is_ok()
}

fn spawn_request<F: FnOnce() + Send + 'static>(f: F) {
    let _ = thread::Builder::new().name("stlt-conn-req".into()).spawn(f);
}

/// Pump one generation's stream items into wire frames. A failed send
/// means the connection is gone — dropping the [`TokenStream`] then
/// cancels the generation server-side.
fn relay_generation(
    node: &dyn Node,
    session: u64,
    opts: GenOpts,
    req: u64,
    out: &mpsc::SyncSender<Frame>,
) {
    let mut stream = match node.node_generate(session, opts) {
        Ok(s) => s,
        Err(e) => {
            let _ = out.send(Frame::Error { req, msg: format!("{e:#}") });
            return;
        }
    };
    loop {
        match stream.recv_raw() {
            Some(StreamItem::Start { evicted, fresh_carry }) => {
                if out.send(Frame::Start { req, evicted, fresh_carry }).is_err() {
                    return;
                }
            }
            Some(StreamItem::Token(t)) => {
                if out.send(Frame::Token { req, token: t }).is_err() {
                    return;
                }
            }
            Some(StreamItem::End(Ok(reason))) => {
                let _ = out.send(Frame::End { req, outcome: EndOutcome::Finished(reason) });
                return;
            }
            Some(StreamItem::End(Err(e))) => {
                let _ = out.send(Frame::End { req, outcome: EndOutcome::Failed(format!("{e:#}")) });
                return;
            }
            None => {
                let _ = out.send(Frame::End {
                    req,
                    outcome: EndOutcome::Failed("server shut down mid-generation".into()),
                });
                return;
            }
        }
    }
}

/// The writer thread: serialize frames in arrival order, flush when
/// the burst drains. After a socket error it drains-and-discards so
/// producers blocked on the bounded channel always make progress.
fn write_loop(stream: Stream, rx: mpsc::Receiver<Frame>) {
    use std::io::Write;
    let mut w = std::io::BufWriter::new(stream);
    let mut dead = false;
    // one encode buffer for the connection's lifetime: token streams
    // push a frame per generated token, so per-frame buffers would put
    // the decode hot path back on the allocator
    let mut payload: Vec<u8> = Vec::with_capacity(64);
    loop {
        let mut frame = match rx.recv() {
            Ok(f) => f,
            Err(_) => break, // all senders gone
        };
        loop {
            if !dead && wire::write_frame_buf(&mut w, &frame, &mut payload).is_err() {
                dead = true;
            }
            match rx.try_recv() {
                Ok(next) => frame = next,
                Err(_) => break,
            }
        }
        if !dead && w.flush().is_err() {
            dead = true;
        }
    }
    let _ = w.flush();
}

/// Model-check the writer-thread protocol (build with
/// `RUSTFLAGS="--cfg model_check"`): producers push frames through the
/// bounded channel while the writer drains in [`write_loop`]'s
/// recv-then-burst shape, and teardown follows [`conn_loop`]'s
/// drop-senders-then-join order. The checker proves backpressure never
/// wedges — including when the socket dies mid-stream — and the mutant
/// pins that joining the writer *before* dropping the reader's sender
/// is the deadlock the real teardown comment warns about.
#[cfg(all(test, model_check))]
mod model_check {
    use crate::util::chk::{self, Config};
    use crate::util::sync::atomic::{AtomicUsize, Ordering};
    use crate::util::sync::{mpsc, Arc};

    /// The writer side of [`super::write_loop`], reduced to its visible
    /// operations: block for one frame, burst-drain the rest, discard
    /// (but keep draining) once the socket is dead. `u32` frames stand
    /// in for [`super::Frame`]; a frame >= `dead_after` kills the
    /// "socket".
    fn writer_model(rx: mpsc::Receiver<u32>, dead_after: u32, drained: Arc<AtomicUsize>) {
        let mut dead = false;
        loop {
            let mut frame = match rx.recv() {
                Ok(f) => f,
                Err(_) => break, // all senders gone
            };
            loop {
                drained.fetch_add(1, Ordering::SeqCst);
                if frame >= dead_after {
                    dead = true; // write failed; keep draining
                }
                match rx.try_recv() {
                    Ok(next) => frame = next,
                    Err(_) => break,
                }
            }
            let _ = dead; // flush-or-discard; no visible op either way
        }
    }

    /// Correct protocol: a producer saturates the bounded window (4
    /// sends through capacity 2, so backpressure blocking is explored),
    /// the socket dies halfway, and teardown drops every sender before
    /// joining the writer. Every frame must still be drained — a dead
    /// socket discards output but never blocks producers.
    #[test]
    fn writer_queue_protocol_holds() {
        let report = chk::check(Config::default(), || {
            let (tx, rx) = mpsc::sync_channel::<u32>(2);
            let drained = Arc::new(AtomicUsize::new(0));
            let d2 = Arc::clone(&drained);
            let writer = chk::spawn(move || writer_model(rx, 2, d2));
            let producer = chk::spawn(move || {
                for i in 0..4u32 {
                    tx.send(i).expect("writer holds the receiver until senders drop");
                }
                // tx drops here = the last producer going away
            });
            producer.join();
            // conn_loop teardown order: every sender gone, then join.
            writer.join();
            assert_eq!(drained.load(Ordering::SeqCst), 4, "dead socket must still drain");
        });
        report.assert_ok();
        assert!(report.dfs_complete, "writer protocol should be exhaustible");
    }

    /// Mutant: join the writer while the reader's own sender is still
    /// alive (the order conn_loop must NOT use). The writer never sees
    /// senders-gone, recv blocks forever, and the joining thread blocks
    /// behind it — a deadlock in every schedule, which the checker must
    /// report on the first one.
    #[test]
    fn checker_catches_join_before_sender_drop() {
        let report = chk::check(Config::default(), || {
            let (tx, rx) = mpsc::sync_channel::<u32>(2);
            let drained = Arc::new(AtomicUsize::new(0));
            let writer = chk::spawn(move || writer_model(rx, u32::MAX, drained));
            let producer_tx = tx.clone();
            let producer = chk::spawn(move || {
                for i in 0..2u32 {
                    let _ = producer_tx.send(i);
                }
            });
            producer.join();
            // BUG: the reader-side sender `tx` is still live here.
            writer.join();
            drop(tx);
        });
        let f = report.assert_fails();
        assert!(f.message.contains("deadlock"), "{}", f.message);
    }
}
