//! The STLT wire protocol: dependency-free length-prefixed binary
//! frames mirroring the [`crate::coordinator::Session`] seam.
//!
//! Framing (all integers little-endian):
//!
//!   u32 payload_len | u8 tag | payload...
//!
//! Requests carry a client-chosen `req` id (u64) so one connection
//! multiplexes many sessions/operations; every reply (including each
//! frame of a generation stream) echoes it. `req` ids only need to be
//! unique among a connection's *in-flight* operations.
//!
//! Connection handshake: the client sends `Hello { magic, version }`
//! first; the server answers `HelloAck { version }` on a match or a
//! connection-level `Error { req: 0 }` (then closes) on a mismatch —
//! version negotiation is exact-match at protocol version 1.
//!
//! Stream frames (`Start`/`Token`/`End`) relay the model thread's
//! stream items 1:1, so a remote [`crate::net::RemoteSession`] sees
//! the same eviction/fresh-carry/finish metadata as a local
//! [`crate::coordinator::SessionHandle`]. `Feed` replies carry the
//! NLL sum/count as raw f64 bits — perplexity accounting survives the
//! wire bitwise.
//!
//! `ExportCarry`/`ImportCarry` ship a session's O(S·d)
//! [`CarrySnapshot`] for live migration; f32 carry values are encoded
//! as raw bits (bitwise round-trip, pinned by test).

use std::io::{Read, Write};

use anyhow::{anyhow, bail, Result};

use crate::coordinator::{CarrySnapshot, FinishReason, GenOpts, Sampling};

/// "STLT" as a little-endian u32 (bytes `53 54 4C 54` on the wire).
pub const MAGIC: u32 = 0x544C_5453;
/// Exact-match protocol version (bump on any frame-layout change).
pub const PROTOCOL_VERSION: u16 = 1;
/// Hard ceiling on one frame's payload (64 MiB — comfortably above
/// any e2e-scale carry snapshot, far below an allocation bomb).
pub const MAX_FRAME: usize = 64 << 20;

/// One protocol frame. `C->S` frames are client requests; `S->C`
/// frames are replies or server-pushed stream items.
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    // -- handshake ----------------------------------------------------
    /// C->S, first frame on every connection.
    Hello { magic: u32, version: u16 },
    /// S->C, handshake accepted.
    HelloAck { version: u16 },

    // -- requests (C->S) ----------------------------------------------
    /// Open a session. `session == 0` asks the server to allocate an
    /// id; a nonzero id opens that exact session (router-chosen ids
    /// survive migration this way). Reply: `OpenOk` | `Error`.
    Open { req: u64, session: u64 },
    /// Stream document tokens in. Reply: `FeedOk` | `Error`.
    Feed { req: u64, session: u64, count_loss: bool, tokens: Vec<i32> },
    /// Start a generation. Reply: `Start`, `Token`*, `End` (or a bare
    /// `Error` if the generation could not start).
    Generate { req: u64, session: u64, opts: GenOpts },
    /// Cancel the session's in-flight generation. Reply: `Ack`.
    Cancel { req: u64, session: u64 },
    /// Release the session's state. Reply: `Ack` | `Error`.
    Close { req: u64, session: u64 },
    /// Export the session's carry. Reply: `Carry` | `Error`.
    ExportCarry { req: u64, session: u64 },
    /// Install an exported carry. Reply: `ImportOk` | `Error`.
    ImportCarry { req: u64, session: u64, snap: CarrySnapshot },
    /// Dump the peer's metrics registry (no session required — works
    /// against any worker or router). Reply: `StatsOk` | `Error`.
    /// Protocol-version-1 peers predating this frame refuse it with
    /// "unknown frame tag" and close, which is the intended failure
    /// mode for `stlt stats` against an old binary.
    Stats { req: u64 },

    // -- replies / stream (S->C) --------------------------------------
    /// Session opened (echoes the allocated or requested id).
    OpenOk { req: u64, session: u64 },
    /// Feed consumed; f64 NLL accounting crosses bitwise.
    FeedOk { req: u64, nll_sum: f64, count: f64, evicted: Option<u64> },
    /// Generation bound to its session state (before the first token).
    Start { req: u64, evicted: Option<u64>, fresh_carry: bool },
    /// One generated token.
    Token { req: u64, token: i32 },
    /// Generation over: how it finished, or why it failed.
    End { req: u64, outcome: EndOutcome },
    /// Exported carry snapshot.
    Carry { req: u64, snap: CarrySnapshot },
    /// Carry imported; `evicted` names any LRU victim.
    ImportOk { req: u64, evicted: Option<u64> },
    /// Generic success reply (Cancel/Close).
    Ack { req: u64 },
    /// Registry snapshot: `version` is the exposition-format version
    /// ([`crate::obs::EXPO_VERSION`]), `text` the rendered registry.
    StatsOk { req: u64, version: u16, text: String },
    /// Operation failed (`req` echoes the request) or, with `req == 0`,
    /// a connection-level failure (e.g. handshake refusal).
    Error { req: u64, msg: String },
}

/// How a remote generation ended: a [`FinishReason`] on success, or
/// the server-side error message.
#[derive(Clone, Debug, PartialEq)]
pub enum EndOutcome {
    Finished(FinishReason),
    Failed(String),
}

// Tag bytes: requests in 0x0_, replies/stream frames in 0x8_.
const TAG_HELLO: u8 = 0x01;
const TAG_OPEN: u8 = 0x02;
const TAG_FEED: u8 = 0x03;
const TAG_GENERATE: u8 = 0x04;
const TAG_CANCEL: u8 = 0x05;
const TAG_CLOSE: u8 = 0x06;
const TAG_EXPORT: u8 = 0x07;
const TAG_IMPORT: u8 = 0x08;
const TAG_STATS: u8 = 0x09;
const TAG_HELLO_ACK: u8 = 0x81;
const TAG_OPEN_OK: u8 = 0x82;
const TAG_FEED_OK: u8 = 0x83;
const TAG_START: u8 = 0x84;
const TAG_TOKEN: u8 = 0x85;
const TAG_END: u8 = 0x86;
const TAG_CARRY: u8 = 0x87;
const TAG_IMPORT_OK: u8 = 0x88;
const TAG_ACK: u8 = 0x89;
const TAG_STATS_OK: u8 = 0x8A;
const TAG_ERROR: u8 = 0xFF;

// wire-layer telemetry: every framed byte in/out of this process
static FRAMES_TX: crate::obs::LazyCounter = crate::obs::LazyCounter::new("wire/frames_tx");
static FRAMES_RX: crate::obs::LazyCounter = crate::obs::LazyCounter::new("wire/frames_rx");
static BYTES_TX: crate::obs::LazyCounter = crate::obs::LazyCounter::new("wire/bytes_tx");
static BYTES_RX: crate::obs::LazyCounter = crate::obs::LazyCounter::new("wire/bytes_rx");

impl Frame {
    /// Human-readable frame name for error messages.
    pub fn name(&self) -> &'static str {
        match self {
            Frame::Hello { .. } => "Hello",
            Frame::HelloAck { .. } => "HelloAck",
            Frame::Open { .. } => "Open",
            Frame::Feed { .. } => "Feed",
            Frame::Generate { .. } => "Generate",
            Frame::Cancel { .. } => "Cancel",
            Frame::Close { .. } => "Close",
            Frame::ExportCarry { .. } => "ExportCarry",
            Frame::ImportCarry { .. } => "ImportCarry",
            Frame::Stats { .. } => "Stats",
            Frame::OpenOk { .. } => "OpenOk",
            Frame::FeedOk { .. } => "FeedOk",
            Frame::Start { .. } => "Start",
            Frame::Token { .. } => "Token",
            Frame::End { .. } => "End",
            Frame::Carry { .. } => "Carry",
            Frame::ImportOk { .. } => "ImportOk",
            Frame::Ack { .. } => "Ack",
            Frame::StatsOk { .. } => "StatsOk",
            Frame::Error { .. } => "Error",
        }
    }

    /// Serialize the payload (tag byte + fields, no length prefix).
    pub fn encode(&self, out: &mut Vec<u8>) {
        match self {
            Frame::Hello { magic, version } => {
                out.push(TAG_HELLO);
                put_u32(out, *magic);
                put_u16(out, *version);
            }
            Frame::HelloAck { version } => {
                out.push(TAG_HELLO_ACK);
                put_u16(out, *version);
            }
            Frame::Open { req, session } => {
                out.push(TAG_OPEN);
                put_u64(out, *req);
                put_u64(out, *session);
            }
            Frame::Feed { req, session, count_loss, tokens } => {
                out.push(TAG_FEED);
                put_u64(out, *req);
                put_u64(out, *session);
                out.push(u8::from(*count_loss));
                put_vec_i32(out, tokens);
            }
            Frame::Generate { req, session, opts } => {
                out.push(TAG_GENERATE);
                put_u64(out, *req);
                put_u64(out, *session);
                put_gen_opts(out, opts);
            }
            Frame::Cancel { req, session } => {
                out.push(TAG_CANCEL);
                put_u64(out, *req);
                put_u64(out, *session);
            }
            Frame::Close { req, session } => {
                out.push(TAG_CLOSE);
                put_u64(out, *req);
                put_u64(out, *session);
            }
            Frame::ExportCarry { req, session } => {
                out.push(TAG_EXPORT);
                put_u64(out, *req);
                put_u64(out, *session);
            }
            Frame::ImportCarry { req, session, snap } => {
                out.push(TAG_IMPORT);
                put_u64(out, *req);
                put_u64(out, *session);
                put_snapshot(out, snap);
            }
            Frame::Stats { req } => {
                out.push(TAG_STATS);
                put_u64(out, *req);
            }
            Frame::OpenOk { req, session } => {
                out.push(TAG_OPEN_OK);
                put_u64(out, *req);
                put_u64(out, *session);
            }
            Frame::FeedOk { req, nll_sum, count, evicted } => {
                out.push(TAG_FEED_OK);
                put_u64(out, *req);
                // raw bits: f64 NLL accounting crosses the wire bitwise
                put_u64(out, nll_sum.to_bits());
                put_u64(out, count.to_bits());
                put_opt_u64(out, *evicted);
            }
            Frame::Start { req, evicted, fresh_carry } => {
                out.push(TAG_START);
                put_u64(out, *req);
                put_opt_u64(out, *evicted);
                out.push(u8::from(*fresh_carry));
            }
            Frame::Token { req, token } => {
                out.push(TAG_TOKEN);
                put_u64(out, *req);
                put_u32(out, *token as u32);
            }
            Frame::End { req, outcome } => {
                out.push(TAG_END);
                put_u64(out, *req);
                match outcome {
                    EndOutcome::Finished(r) => out.push(match r {
                        FinishReason::MaxTokens => 0,
                        FinishReason::Stop => 1,
                        FinishReason::Cancelled => 2,
                    }),
                    EndOutcome::Failed(msg) => {
                        out.push(3);
                        put_str(out, msg);
                    }
                }
            }
            Frame::Carry { req, snap } => {
                out.push(TAG_CARRY);
                put_u64(out, *req);
                put_snapshot(out, snap);
            }
            Frame::ImportOk { req, evicted } => {
                out.push(TAG_IMPORT_OK);
                put_u64(out, *req);
                put_opt_u64(out, *evicted);
            }
            Frame::Ack { req } => {
                out.push(TAG_ACK);
                put_u64(out, *req);
            }
            Frame::StatsOk { req, version, text } => {
                out.push(TAG_STATS_OK);
                put_u64(out, *req);
                put_u16(out, *version);
                put_str(out, text);
            }
            Frame::Error { req, msg } => {
                out.push(TAG_ERROR);
                put_u64(out, *req);
                put_str(out, msg);
            }
        }
    }

    /// Decode one payload (as framed by [`write_frame`]). Strict:
    /// trailing bytes, truncated fields, bad tags and non-UTF-8
    /// strings are all errors, never panics.
    pub fn decode(payload: &[u8]) -> Result<Frame> {
        let mut c = Cursor { buf: payload, off: 0 };
        let tag = c.u8()?;
        let frame = match tag {
            TAG_HELLO => Frame::Hello { magic: c.u32()?, version: c.u16()? },
            TAG_HELLO_ACK => Frame::HelloAck { version: c.u16()? },
            TAG_OPEN => Frame::Open { req: c.u64()?, session: c.u64()? },
            TAG_FEED => Frame::Feed {
                req: c.u64()?,
                session: c.u64()?,
                count_loss: c.bool()?,
                tokens: c.vec_i32()?,
            },
            TAG_GENERATE => Frame::Generate {
                req: c.u64()?,
                session: c.u64()?,
                opts: c.gen_opts()?,
            },
            TAG_CANCEL => Frame::Cancel { req: c.u64()?, session: c.u64()? },
            TAG_CLOSE => Frame::Close { req: c.u64()?, session: c.u64()? },
            TAG_EXPORT => Frame::ExportCarry { req: c.u64()?, session: c.u64()? },
            TAG_IMPORT => Frame::ImportCarry {
                req: c.u64()?,
                session: c.u64()?,
                snap: c.snapshot()?,
            },
            TAG_STATS => Frame::Stats { req: c.u64()? },
            TAG_OPEN_OK => Frame::OpenOk { req: c.u64()?, session: c.u64()? },
            TAG_FEED_OK => Frame::FeedOk {
                req: c.u64()?,
                nll_sum: f64::from_bits(c.u64()?),
                count: f64::from_bits(c.u64()?),
                evicted: c.opt_u64()?,
            },
            TAG_START => Frame::Start {
                req: c.u64()?,
                evicted: c.opt_u64()?,
                fresh_carry: c.bool()?,
            },
            TAG_TOKEN => Frame::Token { req: c.u64()?, token: c.u32()? as i32 },
            TAG_END => {
                let req = c.u64()?;
                let outcome = match c.u8()? {
                    0 => EndOutcome::Finished(FinishReason::MaxTokens),
                    1 => EndOutcome::Finished(FinishReason::Stop),
                    2 => EndOutcome::Finished(FinishReason::Cancelled),
                    3 => EndOutcome::Failed(c.string()?),
                    x => bail!("bad End status byte {x}"),
                };
                Frame::End { req, outcome }
            }
            TAG_CARRY => Frame::Carry { req: c.u64()?, snap: c.snapshot()? },
            TAG_IMPORT_OK => Frame::ImportOk { req: c.u64()?, evicted: c.opt_u64()? },
            TAG_ACK => Frame::Ack { req: c.u64()? },
            TAG_STATS_OK => Frame::StatsOk {
                req: c.u64()?,
                version: c.u16()?,
                text: c.string()?,
            },
            TAG_ERROR => Frame::Error { req: c.u64()?, msg: c.string()? },
            x => bail!("unknown frame tag 0x{x:02x}"),
        };
        c.finish()?;
        Ok(frame)
    }
}

/// Write one length-prefixed frame. The caller flushes (the worker's
/// writer thread coalesces bursts into one flush).
pub fn write_frame<W: Write>(w: &mut W, frame: &Frame) -> Result<()> {
    let mut payload = Vec::with_capacity(64);
    write_frame_buf(w, frame, &mut payload)
}

/// [`write_frame`] with a caller-owned encode buffer: `payload` is
/// cleared and refilled, so a long-lived writer (the worker's writer
/// thread) pays for one buffer over the whole connection instead of
/// one per frame.
pub fn write_frame_buf<W: Write>(w: &mut W, frame: &Frame, payload: &mut Vec<u8>) -> Result<()> {
    let _span = crate::obs::span("wire", "frame_encode");
    payload.clear();
    frame.encode(payload);
    if payload.len() > MAX_FRAME {
        bail!("frame {} exceeds MAX_FRAME ({} > {MAX_FRAME})", frame.name(), payload.len());
    }
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    FRAMES_TX.inc();
    BYTES_TX.add(4 + payload.len() as u64);
    Ok(())
}

/// Read one length-prefixed frame. `Ok(None)` on clean EOF (peer
/// closed between frames); EOF mid-frame is an error.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Option<Frame>> {
    let mut len4 = [0u8; 4];
    if !read_full_or_eof(r, &mut len4)? {
        return Ok(None);
    }
    let len = u32::from_le_bytes(len4) as usize;
    if len == 0 || len > MAX_FRAME {
        bail!("bad frame length {len} (max {MAX_FRAME})");
    }
    let mut payload = vec![0u8; len];
    if !read_full_or_eof(r, &mut payload)? {
        bail!("connection closed mid-frame (wanted {len} payload bytes)");
    }
    let _span = crate::obs::span("wire", "frame_decode");
    FRAMES_RX.inc();
    BYTES_RX.add(4 + len as u64);
    Frame::decode(&payload).map(Some)
}

/// Fill `buf` completely. `Ok(false)` iff EOF arrived before the
/// first byte; EOF after a partial read is an error.
fn read_full_or_eof<R: Read>(r: &mut R, buf: &mut [u8]) -> Result<bool> {
    let mut off = 0;
    while off < buf.len() {
        match r.read(&mut buf[off..]) {
            Ok(0) => {
                if off == 0 {
                    return Ok(false);
                }
                bail!("connection closed mid-frame ({off}/{} bytes)", buf.len());
            }
            Ok(n) => off += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
    Ok(true)
}

// -- field encoders ---------------------------------------------------

fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_opt_u64(out: &mut Vec<u8>, v: Option<u64>) {
    match v {
        None => out.push(0),
        Some(x) => {
            out.push(1);
            put_u64(out, x);
        }
    }
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

fn put_vec_i32(out: &mut Vec<u8>, v: &[i32]) {
    put_u32(out, v.len() as u32);
    for &x in v {
        put_u32(out, x as u32);
    }
}

fn put_vec_f32(out: &mut Vec<u8>, v: &[f32]) {
    put_u32(out, v.len() as u32);
    for &x in v {
        // raw bits: carries must round-trip bitwise
        put_u32(out, x.to_bits());
    }
}

fn put_shape(out: &mut Vec<u8>, shape: &[usize]) {
    out.push(shape.len() as u8);
    for &d in shape {
        put_u32(out, d as u32);
    }
}

fn put_gen_opts(out: &mut Vec<u8>, o: &GenOpts) {
    put_u32(out, o.seed_token as u32);
    put_u64(out, o.max_tokens as u64);
    match o.stop {
        None => out.push(0),
        Some(s) => {
            out.push(1);
            put_u32(out, s as u32);
        }
    }
    match o.sampling {
        Sampling::Greedy => out.push(0),
        Sampling::Temperature(t) => {
            out.push(1);
            put_u32(out, t.to_bits());
        }
        Sampling::TopK(k, t) => {
            out.push(2);
            put_u32(out, k as u32);
            put_u32(out, t.to_bits());
        }
        Sampling::TopP(p, t) => {
            out.push(3);
            put_u32(out, p.to_bits());
            put_u32(out, t.to_bits());
        }
    }
    put_u64(out, o.rng_seed);
}

fn put_snapshot(out: &mut Vec<u8>, s: &CarrySnapshot) {
    put_shape(out, &s.l_shape);
    put_shape(out, &s.u_shape);
    put_vec_f32(out, &s.l);
    put_vec_f32(out, &s.u);
    put_u64(out, s.tokens_seen);
}

// -- strict decoder ---------------------------------------------------

struct Cursor<'a> {
    buf: &'a [u8],
    off: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.buf.len() - self.off < n {
            bail!(
                "truncated frame: wanted {n} bytes at offset {}, payload is {}",
                self.off,
                self.buf.len()
            );
        }
        // PANIC-OK: the length check above guarantees
        // off + n <= buf.len(), and off never exceeds buf.len()
        let s = &self.buf[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    /// Fixed-size form of [`Cursor::take`]: the length check lives in
    /// `take`, so the slice-to-array conversion is infallible by
    /// construction (no `.try_into().unwrap()` in the decode path).
    fn take_n<const N: usize>(&mut self) -> Result<[u8; N]> {
        let s = self.take(N)?;
        let mut a = [0u8; N];
        a.copy_from_slice(s);
        Ok(a)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn bool(&mut self) -> Result<bool> {
        Ok(self.u8()? != 0)
    }

    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take_n()?))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take_n()?))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take_n()?))
    }

    fn opt_u64(&mut self) -> Result<Option<u64>> {
        match self.u8()? {
            0 => Ok(None),
            _ => Ok(Some(self.u64()?)),
        }
    }

    fn string(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| anyhow!("non-UTF-8 string in frame"))
    }

    /// Element count, bounds-checked against the remaining payload
    /// *before* allocating (a forged count cannot force a huge alloc).
    fn count(&mut self, elem_bytes: usize) -> Result<usize> {
        let n = self.u32()? as usize;
        if (self.buf.len() - self.off) / elem_bytes < n {
            bail!("frame claims {n} elements but only {} bytes remain", self.buf.len() - self.off);
        }
        Ok(n)
    }

    fn vec_i32(&mut self) -> Result<Vec<i32>> {
        let n = self.count(4)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.u32()? as i32);
        }
        Ok(v)
    }

    fn vec_f32(&mut self) -> Result<Vec<f32>> {
        let n = self.count(4)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(f32::from_bits(self.u32()?));
        }
        Ok(v)
    }

    fn shape(&mut self) -> Result<Vec<usize>> {
        let n = self.u8()? as usize;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.u32()? as usize);
        }
        Ok(v)
    }

    fn gen_opts(&mut self) -> Result<GenOpts> {
        let seed_token = self.u32()? as i32;
        let max_tokens = self.u64()? as usize;
        let stop = match self.u8()? {
            0 => None,
            _ => Some(self.u32()? as i32),
        };
        let sampling = match self.u8()? {
            0 => Sampling::Greedy,
            1 => Sampling::Temperature(f32::from_bits(self.u32()?)),
            2 => Sampling::TopK(self.u32()? as usize, f32::from_bits(self.u32()?)),
            3 => Sampling::TopP(f32::from_bits(self.u32()?), f32::from_bits(self.u32()?)),
            x => bail!("bad sampling tag {x}"),
        };
        let rng_seed = self.u64()?;
        Ok(GenOpts { seed_token, max_tokens, stop, sampling, rng_seed })
    }

    fn snapshot(&mut self) -> Result<CarrySnapshot> {
        let l_shape = self.shape()?;
        let u_shape = self.shape()?;
        let l = self.vec_f32()?;
        let u = self.vec_f32()?;
        let tokens_seen = self.u64()?;
        Ok(CarrySnapshot { l, u, l_shape, u_shape, tokens_seen })
    }

    fn finish(&self) -> Result<()> {
        if self.off != self.buf.len() {
            bail!("{} trailing bytes after frame payload", self.buf.len() - self.off);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(f: Frame) {
        let mut buf = Vec::new();
        write_frame(&mut buf, &f).unwrap();
        let got = read_frame(&mut buf.as_slice()).unwrap().unwrap();
        assert_eq!(got, f, "frame {} did not round-trip", got.name());
    }

    fn snap() -> CarrySnapshot {
        CarrySnapshot {
            l: vec![1.5, -0.0, f32::MIN_POSITIVE, 3.25e-7],
            u: vec![0.1; 8],
            l_shape: vec![2, 2],
            u_shape: vec![2, 2, 2],
            tokens_seen: 9001,
        }
    }

    #[test]
    fn all_frames_round_trip() {
        roundtrip(Frame::Hello { magic: MAGIC, version: PROTOCOL_VERSION });
        roundtrip(Frame::HelloAck { version: PROTOCOL_VERSION });
        roundtrip(Frame::Open { req: 1, session: 0 });
        roundtrip(Frame::Open { req: 2, session: 77 });
        roundtrip(Frame::Feed { req: 3, session: 77, count_loss: true, tokens: vec![1, -2, 3] });
        roundtrip(Frame::Feed { req: 4, session: 77, count_loss: false, tokens: vec![] });
        roundtrip(Frame::Generate {
            req: 5,
            session: 77,
            opts: GenOpts {
                seed_token: 42,
                max_tokens: 128,
                stop: Some(3),
                sampling: Sampling::TopK(40, 0.8),
                rng_seed: 0xDEAD_BEEF,
            },
        });
        roundtrip(Frame::Generate {
            req: 6,
            session: 77,
            opts: GenOpts {
                sampling: Sampling::TopP(0.9, 1.0),
                ..GenOpts::default()
            },
        });
        roundtrip(Frame::Cancel { req: 7, session: 77 });
        roundtrip(Frame::Close { req: 8, session: 77 });
        roundtrip(Frame::ExportCarry { req: 9, session: 77 });
        roundtrip(Frame::ImportCarry { req: 10, session: 77, snap: snap() });
        roundtrip(Frame::OpenOk { req: 11, session: 1 << 40 });
        roundtrip(Frame::FeedOk { req: 12, nll_sum: 1234.5678, count: 64.0, evicted: Some(5) });
        roundtrip(Frame::FeedOk { req: 13, nll_sum: 0.0, count: 0.0, evicted: None });
        roundtrip(Frame::Start { req: 14, evicted: Some(9), fresh_carry: true });
        roundtrip(Frame::Token { req: 15, token: -1 });
        roundtrip(Frame::End { req: 16, outcome: EndOutcome::Finished(FinishReason::MaxTokens) });
        roundtrip(Frame::End { req: 17, outcome: EndOutcome::Finished(FinishReason::Stop) });
        roundtrip(Frame::End { req: 18, outcome: EndOutcome::Finished(FinishReason::Cancelled) });
        roundtrip(Frame::End { req: 19, outcome: EndOutcome::Failed("boom: §µ".into()) });
        roundtrip(Frame::Carry { req: 20, snap: snap() });
        roundtrip(Frame::ImportOk { req: 21, evicted: None });
        roundtrip(Frame::Ack { req: 22 });
        roundtrip(Frame::Stats { req: 23 });
        roundtrip(Frame::StatsOk {
            req: 24,
            version: 1,
            text: "# stlt-metrics v1\ncounter server/feeds 12\n".into(),
        });
        roundtrip(Frame::StatsOk { req: 25, version: 7, text: String::new() });
        roundtrip(Frame::Error { req: 0, msg: "handshake: version 2 != 1".into() });
    }

    /// A peer built before the Stats frames existed refuses the tags
    /// with "unknown frame tag" (this is the compatibility story: no
    /// silent misparse, the connection errors out). Emulated here by
    /// checking the *next* unassigned tags still hard-error, and that
    /// truncated Stats frames never panic.
    #[test]
    fn stats_frames_strict_and_future_tags_refused() {
        // next free tags after Stats/StatsOk behave like 0x09/0x8A did
        // for a v1 peer: decode refuses outright
        for tag in [0x0Au8, 0x8Bu8] {
            let mut p = vec![tag];
            p.extend_from_slice(&1u64.to_le_bytes());
            let err = Frame::decode(&p).unwrap_err().to_string();
            assert!(err.contains("unknown frame tag"), "{err}");
        }
        // truncated Stats / StatsOk payloads error, never panic
        let mut p = Vec::new();
        Frame::Stats { req: 9 }.encode(&mut p);
        assert!(Frame::decode(&p[..p.len() - 1]).is_err());
        let mut p2 = Vec::new();
        Frame::StatsOk { req: 9, version: 1, text: "abc".into() }.encode(&mut p2);
        assert!(Frame::decode(&p2[..p2.len() - 1]).is_err());
        // trailing bytes after a well-formed Stats frame are refused
        let mut p3 = Vec::new();
        Frame::Stats { req: 9 }.encode(&mut p3);
        p3.push(0);
        assert!(Frame::decode(&p3).is_err());
    }

    #[test]
    fn f64_nll_bits_survive_the_wire() {
        // a value with no short decimal representation
        let nll = 123.456789f64.ln() * 7.0 / 3.0;
        let f = Frame::FeedOk { req: 1, nll_sum: nll, count: 65.0, evicted: None };
        let mut buf = Vec::new();
        write_frame(&mut buf, &f).unwrap();
        match read_frame(&mut buf.as_slice()).unwrap().unwrap() {
            Frame::FeedOk { nll_sum, count, .. } => {
                assert_eq!(nll_sum.to_bits(), nll.to_bits());
                assert_eq!(count.to_bits(), 65.0f64.to_bits());
            }
            f => panic!("wrong frame {}", f.name()),
        }
    }

    #[test]
    fn f32_carry_bits_survive_the_wire() {
        let mut s = snap();
        // exercise non-finite and denormal payloads
        s.l = vec![f32::NAN, f32::INFINITY, -0.0, 1e-40];
        let f = Frame::Carry { req: 1, snap: s.clone() };
        let mut buf = Vec::new();
        write_frame(&mut buf, &f).unwrap();
        match read_frame(&mut buf.as_slice()).unwrap().unwrap() {
            Frame::Carry { snap: got, .. } => {
                let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
                assert_eq!(bits(&got.l), bits(&s.l));
                assert_eq!(bits(&got.u), bits(&s.u));
            }
            f => panic!("wrong frame {}", f.name()),
        }
    }

    #[test]
    fn clean_eof_is_none_partial_is_error() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::Ack { req: 1 }).unwrap();
        // clean EOF before any byte
        assert!(read_frame(&mut (&buf[..0])).unwrap().is_none());
        // EOF inside the length prefix / payload
        assert!(read_frame(&mut (&buf[..2])).is_err());
        assert!(read_frame(&mut (&buf[..buf.len() - 1])).is_err());
    }

    #[test]
    fn malformed_frames_error_not_panic() {
        // zero / oversized length prefix
        assert!(read_frame(&mut (&[0u8, 0, 0, 0][..])).is_err());
        let huge = (MAX_FRAME as u32 + 1).to_le_bytes();
        assert!(read_frame(&mut (&huge[..])).is_err());
        // unknown tag
        assert!(Frame::decode(&[0x42]).is_err());
        // trailing garbage
        let mut p = Vec::new();
        Frame::Ack { req: 1 }.encode(&mut p);
        p.push(0);
        assert!(Frame::decode(&p).is_err());
        // truncated field
        let mut p2 = Vec::new();
        Frame::Ack { req: 1 }.encode(&mut p2);
        assert!(Frame::decode(&p2[..p2.len() - 1]).is_err());
        // forged element count larger than the payload
        let mut p3 = vec![TAG_FEED];
        p3.extend_from_slice(&1u64.to_le_bytes());
        p3.extend_from_slice(&2u64.to_le_bytes());
        p3.push(0);
        p3.extend_from_slice(&u32::MAX.to_le_bytes()); // claims 4B tokens
        assert!(Frame::decode(&p3).is_err());
        // bad End status byte
        let mut p4 = vec![TAG_END];
        p4.extend_from_slice(&1u64.to_le_bytes());
        p4.push(9);
        assert!(Frame::decode(&p4).is_err());
    }

    #[test]
    fn oversized_frame_refused_on_write() {
        let f = Frame::Feed {
            req: 1,
            session: 1,
            count_loss: false,
            tokens: vec![0; MAX_FRAME / 4 + 8],
        };
        let mut buf = Vec::new();
        assert!(write_frame(&mut buf, &f).is_err());
        assert!(buf.is_empty(), "nothing written for an oversized frame");
    }

    /// Well-formed frames the fuzzer mutates: every tag, every
    /// variable-length field shape.
    fn fuzz_corpus() -> Vec<Vec<u8>> {
        let frames = vec![
            Frame::Hello { magic: MAGIC, version: PROTOCOL_VERSION },
            Frame::HelloAck { version: PROTOCOL_VERSION },
            Frame::Open { req: 1, session: 77 },
            Frame::Feed { req: 2, session: 77, count_loss: true, tokens: vec![1, -2, 3, 4] },
            Frame::Generate {
                req: 3,
                session: 77,
                opts: GenOpts {
                    seed_token: 42,
                    max_tokens: 128,
                    stop: Some(3),
                    sampling: Sampling::TopP(0.9, 0.7),
                    rng_seed: 7,
                },
            },
            Frame::Cancel { req: 4, session: 77 },
            Frame::Close { req: 5, session: 77 },
            Frame::ExportCarry { req: 6, session: 77 },
            Frame::ImportCarry { req: 7, session: 77, snap: snap() },
            Frame::Stats { req: 8 },
            Frame::OpenOk { req: 9, session: 1 << 40 },
            Frame::FeedOk { req: 10, nll_sum: 12.5, count: 3.0, evicted: Some(5) },
            Frame::Start { req: 11, evicted: None, fresh_carry: true },
            Frame::Token { req: 12, token: -9 },
            Frame::End { req: 13, outcome: EndOutcome::Failed("boom".into()) },
            Frame::Carry { req: 14, snap: snap() },
            Frame::ImportOk { req: 15, evicted: Some(2) },
            Frame::Ack { req: 16 },
            Frame::StatsOk { req: 17, version: 1, text: "# stlt-metrics v1\n".into() },
            Frame::Error { req: 18, msg: "nope".into() },
        ];
        frames
            .iter()
            .map(|f| {
                let mut buf = Vec::new();
                write_frame(&mut buf, f).unwrap();
                buf
            })
            .collect()
    }

    /// Deterministic decoder fuzz: splitmix64-driven bit flips, length
    /// corruption, truncation, and tag swaps over framed bytes. The
    /// contract under test is total: [`read_frame`]/[`Frame::decode`]
    /// return `Ok`/`Err` on arbitrary input, never panic and never
    /// trust a forged length for an allocation. Iterations come from
    /// `STLT_FUZZ_ITERS` (CI nightly runs a long sweep; the tier-1
    /// default keeps the test fast) and the seed is fixed, so every
    /// failure is reproducible by iteration count alone.
    #[test]
    fn decoder_survives_deterministic_fuzz() {
        let iters: u64 = std::env::var("STLT_FUZZ_ITERS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(2_000);
        let corpus = fuzz_corpus();
        let mut rng = 0x57A7_F00D_u64;
        let mut step = || crate::util::chk::splitmix64(&mut rng);
        for _ in 0..iters {
            let mut buf = corpus[(step() % corpus.len() as u64) as usize].clone();
            for _ in 0..=(step() % 3) {
                // every arm guards on the current length: an earlier
                // truncation may have left fewer than 4 (or 0) bytes
                match step() % 4 {
                    // bit flip anywhere, length prefix included
                    0 if !buf.is_empty() => {
                        let i = (step() % buf.len() as u64) as usize;
                        buf[i] ^= 1 << (step() % 8);
                    }
                    // length-prefix corruption: zero, nearby, huge
                    1 if buf.len() >= 4 => {
                        let claim: u32 = match step() % 4 {
                            0 => 0,
                            1 => (buf.len() as u32)
                                .wrapping_sub(8)
                                .wrapping_add((step() % 9) as u32),
                            2 => MAX_FRAME as u32 + 1,
                            _ => step() as u32,
                        };
                        buf[..4].copy_from_slice(&claim.to_le_bytes());
                    }
                    // truncation at an arbitrary point
                    2 => {
                        buf.truncate((step() % (buf.len() as u64 + 1)) as usize);
                    }
                    // tag swap: another valid tag over this payload
                    3 if buf.len() > 4 => {
                        let tags = [
                            TAG_HELLO, TAG_OPEN, TAG_FEED, TAG_GENERATE, TAG_IMPORT,
                            TAG_STATS, TAG_FEED_OK, TAG_START, TAG_END, TAG_CARRY,
                            TAG_STATS_OK, TAG_ERROR, 0x42,
                        ];
                        buf[4] = tags[(step() % tags.len() as u64) as usize];
                    }
                    _ => {}
                }
            }
            // Total: any outcome but a panic (or a forged-length alloc
            // bomb, which count() and MAX_FRAME preclude) is correct.
            let _ = read_frame(&mut buf.as_slice());
            if buf.len() > 4 {
                let _ = Frame::decode(&buf[4..]);
            }
        }
    }
}
