//! STLT carry-state manager: the serving-side "KV-cache" pool.
//!
//! Each streaming session owns one O(S d) StreamCarry (a few hundred KB
//! at e2e scale vs O(N d) for attention KV). The pool enforces a
//! capacity: admitting a new session beyond capacity evicts the
//! least-recently-used idle session (its document would need re-feeding
//! — the same trade vLLM makes when preempting).

use std::collections::HashMap;

use crate::runtime::StreamCarry;

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Admit {
    Ok,
    Evicted(u64),
    Rejected,
}

/// Outcome of [`StatePool::export`].
pub enum Export {
    /// The session has no resident state (never admitted, or evicted).
    Missing,
    /// The carry is checked out by an in-flight feed/generate wave;
    /// exporting now would ship the empty placeholder.
    InFlight,
    /// A copy of the resident carry plus its served-token counter.
    Carry { carry: StreamCarry, tokens_seen: u64 },
}

/// Outcome of [`StatePool::import`]. The rejected variants hand the
/// carry back so the caller can park and retry without a reclone.
pub enum Import {
    Ok,
    /// Imported; admission LRU-evicted this victim.
    Evicted(u64),
    /// Every resident session is pinned — transient, retry later.
    NoCapacity(StreamCarry),
    /// The session's own carry is checked out by in-flight work;
    /// overwriting it would corrupt the wave's checkin.
    InFlight(StreamCarry),
}

pub struct StatePool {
    capacity: usize,
    states: HashMap<u64, SessionState>,
    clock: u64,
}

struct SessionState {
    carry: StreamCarry,
    last_used: u64,
    pinned: bool,
    pub tokens_seen: u64,
}

impl StatePool {
    pub fn new(capacity: usize) -> StatePool {
        StatePool { capacity: capacity.max(1), states: HashMap::new(), clock: 0 }
    }

    pub fn len(&self) -> usize {
        self.states.len()
    }

    pub fn is_empty(&self) -> bool {
        self.states.is_empty()
    }

    pub fn contains(&self, id: u64) -> bool {
        self.states.contains_key(&id)
    }

    pub fn state_bytes(&self) -> usize {
        self.states.values().map(|s| s.carry.state_bytes()).sum()
    }

    /// Admit a session with a zero carry. Evicts LRU unpinned if full.
    pub fn admit(&mut self, id: u64, carry: StreamCarry) -> Admit {
        if self.states.contains_key(&id) {
            return Admit::Ok;
        }
        let mut evicted = None;
        if self.states.len() >= self.capacity {
            let victim = self
                .states
                .iter()
                .filter(|(_, s)| !s.pinned)
                .min_by_key(|(_, s)| s.last_used)
                .map(|(k, _)| *k);
            match victim {
                Some(v) => {
                    self.states.remove(&v);
                    evicted = Some(v);
                }
                None => return Admit::Rejected,
            }
        }
        self.clock += 1;
        self.states.insert(
            id,
            SessionState { carry, last_used: self.clock, pinned: false, tokens_seen: 0 },
        );
        match evicted {
            Some(v) => Admit::Evicted(v),
            None => Admit::Ok,
        }
    }

    /// Temporarily take the carry out (pins the session so eviction
    /// cannot drop in-flight state). The serving scheduler holds a
    /// checkout for the whole lifetime of a feed/generate task, so a
    /// session being decoded can never lose its carry mid-flight.
    ///
    /// Returns None while the carry is already checked out: the old
    /// behaviour silently handed the *empty placeholder* to a second
    /// caller, which would have executed from a zero-length carry.
    pub fn checkout(&mut self, id: u64) -> Option<StreamCarry> {
        self.clock += 1;
        let clock = self.clock;
        let s = self.states.get_mut(&id)?;
        if s.pinned {
            return None;
        }
        s.last_used = clock;
        s.pinned = true;
        // move out, leave empty placeholder
        let carry = std::mem::replace(
            &mut s.carry,
            StreamCarry { l: Vec::new(), u: Vec::new(), l_shape: vec![], u_shape: vec![] },
        );
        Some(carry)
    }

    pub fn checkin(&mut self, id: u64, carry: StreamCarry, tokens: u64) {
        if let Some(s) = self.states.get_mut(&id) {
            s.carry = carry;
            s.pinned = false;
            s.tokens_seen += tokens;
        }
    }

    pub fn tokens_seen(&self, id: u64) -> u64 {
        self.states.get(&id).map(|s| s.tokens_seen).unwrap_or(0)
    }

    pub fn release(&mut self, id: u64) -> bool {
        self.states.remove(&id).is_some()
    }

    /// Copy a session's carry out for migration/resume. Checkout-safe:
    /// refuses while a wave holds the carry (the resident value is the
    /// empty placeholder then — exporting it would ship zero-length
    /// state that "imports" cleanly and corrupts the session).
    pub fn export(&self, id: u64) -> Export {
        match self.states.get(&id) {
            None => Export::Missing,
            Some(s) if s.pinned => Export::InFlight,
            Some(s) => Export::Carry { carry: s.carry.clone(), tokens_seen: s.tokens_seen },
        }
    }

    /// Install an exported carry under `id`: replaces the resident
    /// state if the session exists (and is not pinned), otherwise
    /// admits it like [`StatePool::admit`] — including LRU eviction
    /// and the all-pinned `NoCapacity` rejection.
    pub fn import(&mut self, id: u64, carry: StreamCarry, tokens_seen: u64) -> Import {
        self.clock += 1;
        let clock = self.clock;
        if let Some(s) = self.states.get_mut(&id) {
            if s.pinned {
                return Import::InFlight(carry);
            }
            s.carry = carry;
            s.tokens_seen = tokens_seen;
            s.last_used = clock;
            return Import::Ok;
        }
        let mut evicted = None;
        if self.states.len() >= self.capacity {
            let victim = self
                .states
                .iter()
                .filter(|(_, s)| !s.pinned)
                .min_by_key(|(_, s)| s.last_used)
                .map(|(k, _)| *k);
            match victim {
                Some(v) => {
                    self.states.remove(&v);
                    evicted = Some(v);
                }
                None => return Import::NoCapacity(carry),
            }
        }
        self.states
            .insert(id, SessionState { carry, last_used: clock, pinned: false, tokens_seen });
        match evicted {
            Some(v) => Import::Evicted(v),
            None => Import::Ok,
        }
    }
}

/// Model-check the pool's concurrency contract (build with
/// `RUSTFLAGS="--cfg model_check"`): the serving scheduler drives a
/// `Mutex<StatePool>` from wave threads (checkout → compute outside
/// the lock → checkin) while admissions apply eviction pressure. The
/// checker proves the two invariants the pin flag exists for — a
/// checkout never observes the empty placeholder, and a pinned session
/// survives any interleaving of admissions — and the mutant reverts
/// checkout to its pre-pin behaviour to prove the checker would have
/// caught the original double-checkout bug.
#[cfg(all(test, model_check))]
mod model_check {
    use super::*;
    use crate::util::chk::{self, Config};
    use crate::util::sync::{Arc, Mutex};

    fn carry() -> StreamCarry {
        StreamCarry { l: vec![0.0; 8], u: vec![0.0; 32], l_shape: vec![2, 2, 2], u_shape: vec![2, 2, 4, 2] }
    }

    fn lock(p: &Mutex<StatePool>) -> crate::util::sync::MutexGuard<'_, StatePool> {
        p.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// One serving wave on session 1: checkout (pin), "compute" with
    /// the lock dropped, then checkin — asserting the carry is real and
    /// that the pinned session survived any concurrent admissions.
    /// `None` checkouts (in-flight elsewhere, or legitimately LRU-
    /// evicted while idle) are the refusal path and simply give up.
    fn wave(pool: &Mutex<StatePool>) {
        let c = lock(pool).checkout(1);
        if let Some(c) = c {
            assert_eq!(c.l.len(), 8, "checkout must hand out the real carry, not the placeholder");
            let mut p = lock(pool);
            p.checkin(1, c, 1);
            assert!(p.contains(1), "a pinned session must survive admission pressure");
        }
    }

    #[test]
    fn statepool_checkout_protocol_holds() {
        let report = chk::check(Config::default(), || {
            let pool = Arc::new(Mutex::new(StatePool::new(2)));
            {
                let mut p = lock(&pool);
                p.admit(1, carry());
                p.admit(2, carry());
            }
            let (pa, pb, pe) = (Arc::clone(&pool), Arc::clone(&pool), Arc::clone(&pool));
            let a = chk::spawn(move || wave(&pa));
            let b = chk::spawn(move || wave(&pb));
            let e = chk::spawn(move || {
                for id in [3u64, 4] {
                    let adm = lock(&pe).admit(id, carry());
                    // sessions 2/3 are never pinned, so eviction always
                    // finds an unpinned victim here
                    assert_ne!(adm, Admit::Rejected, "admission found no unpinned victim");
                }
            });
            a.join();
            b.join();
            e.join();
            assert!(lock(&pool).len() <= 2, "capacity respected in every interleaving");
        });
        report.assert_ok();
        assert!(report.dfs_complete, "pool protocol should be exhaustible");
    }

    /// The pre-pin checkout: no in-flight check, so a second caller
    /// silently receives the zero-length placeholder.
    fn checkout_unpinned(p: &mut StatePool, id: u64) -> Option<StreamCarry> {
        p.clock += 1;
        let clock = p.clock;
        let s = p.states.get_mut(&id)?;
        s.last_used = clock;
        s.pinned = true;
        // BUG: s.pinned was not consulted before replacing the carry.
        Some(std::mem::replace(
            &mut s.carry,
            StreamCarry { l: Vec::new(), u: Vec::new(), l_shape: vec![], u_shape: vec![] },
        ))
    }

    #[test]
    fn checker_catches_unpinned_double_checkout() {
        let report = chk::check(Config::default(), || {
            let pool = Arc::new(Mutex::new(StatePool::new(2)));
            lock(&pool).admit(1, carry());
            let mut hs = Vec::new();
            for _ in 0..2 {
                let p2 = Arc::clone(&pool);
                hs.push(chk::spawn(move || {
                    let c = checkout_unpinned(&mut lock(&p2), 1);
                    if let Some(c) = c {
                        assert_eq!(c.l.len(), 8, "second checkout got the placeholder");
                        lock(&p2).checkin(1, c, 1);
                    }
                }));
            }
            for h in hs {
                h.join();
            }
        });
        let f = report.assert_fails();
        assert!(f.message.contains("panicked"), "{}", f.message);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn carry() -> StreamCarry {
        StreamCarry { l: vec![0.0; 8], u: vec![0.0; 32], l_shape: vec![2, 2, 2], u_shape: vec![2, 2, 4, 2] }
    }

    #[test]
    fn admit_and_checkout_roundtrip() {
        let mut p = StatePool::new(4);
        assert_eq!(p.admit(1, carry()), Admit::Ok);
        let mut c = p.checkout(1).unwrap();
        c.l[0] = 42.0;
        p.checkin(1, c, 64);
        assert_eq!(p.checkout(1).unwrap().l[0], 42.0);
        assert_eq!(p.tokens_seen(1), 64);
    }

    #[test]
    fn lru_eviction() {
        let mut p = StatePool::new(2);
        p.admit(1, carry());
        p.admit(2, carry());
        // touch 1 so 2 becomes LRU
        let c = p.checkout(1).unwrap();
        p.checkin(1, c, 1);
        assert_eq!(p.admit(3, carry()), Admit::Evicted(2));
        assert!(p.contains(1) && p.contains(3) && !p.contains(2));
    }

    #[test]
    fn pinned_sessions_not_evicted() {
        let mut p = StatePool::new(2);
        p.admit(1, carry());
        p.admit(2, carry());
        let _c1 = p.checkout(1).unwrap(); // pins 1
        let _c2 = p.checkout(2).unwrap(); // pins 2
        assert_eq!(p.admit(3, carry()), Admit::Rejected);
    }

    #[test]
    fn readmit_is_noop() {
        let mut p = StatePool::new(2);
        p.admit(1, carry());
        let mut c = p.checkout(1).unwrap();
        c.l[1] = 7.0;
        p.checkin(1, c, 10);
        assert_eq!(p.admit(1, carry()), Admit::Ok); // does not reset
        assert_eq!(p.tokens_seen(1), 10);
    }

    #[test]
    fn double_checkout_is_refused_not_empty() {
        let mut p = StatePool::new(2);
        p.admit(1, carry());
        let c = p.checkout(1).unwrap();
        assert_eq!(c.l.len(), 8, "first checkout gets the real carry");
        assert!(p.checkout(1).is_none(), "carry is in flight");
        p.checkin(1, c, 4);
        assert_eq!(p.checkout(1).unwrap().l.len(), 8);
    }

    #[test]
    fn release_frees_capacity() {
        let mut p = StatePool::new(1);
        p.admit(1, carry());
        assert!(p.release(1));
        assert!(!p.release(1));
        assert_eq!(p.admit(2, carry()), Admit::Ok);
    }

    #[test]
    fn state_bytes_accounting() {
        let mut p = StatePool::new(4);
        p.admit(1, carry());
        p.admit(2, carry());
        assert_eq!(p.state_bytes(), 2 * 40 * 4);
    }

    #[test]
    fn export_copies_resident_state() {
        let mut p = StatePool::new(2);
        p.admit(1, carry());
        let mut c = p.checkout(1).unwrap();
        c.l[0] = 3.5;
        p.checkin(1, c, 16);
        match p.export(1) {
            Export::Carry { carry, tokens_seen } => {
                assert_eq!(carry.l[0], 3.5);
                assert_eq!(tokens_seen, 16);
            }
            _ => panic!("expected a carry"),
        }
        // export is a copy: the session stays resident and usable
        assert!(p.contains(1));
        assert_eq!(p.checkout(1).unwrap().l[0], 3.5);
    }

    #[test]
    fn export_refuses_checked_out_and_missing() {
        let mut p = StatePool::new(2);
        p.admit(1, carry());
        let c = p.checkout(1).unwrap();
        assert!(matches!(p.export(1), Export::InFlight));
        p.checkin(1, c, 0);
        assert!(matches!(p.export(1), Export::Carry { .. }));
        assert!(matches!(p.export(9), Export::Missing));
    }

    #[test]
    fn import_replaces_or_admits() {
        let mut p = StatePool::new(2);
        // import into an empty pool admits
        let mut c = carry();
        c.u[0] = 7.0;
        assert!(matches!(p.import(5, c, 12), Import::Ok));
        assert_eq!(p.tokens_seen(5), 12);
        assert_eq!(p.checkout(5).unwrap().u[0], 7.0);
        // import over a resident (unpinned) session replaces its state
        let mut p2 = StatePool::new(2);
        p2.admit(5, carry());
        let mut c2 = carry();
        c2.u[0] = 9.0;
        assert!(matches!(p2.import(5, c2, 3), Import::Ok));
        assert_eq!(p2.tokens_seen(5), 3);
        assert_eq!(p2.checkout(5).unwrap().u[0], 9.0);
    }

    #[test]
    fn import_evicts_lru_and_respects_pins() {
        let mut p = StatePool::new(2);
        p.admit(1, carry());
        p.admit(2, carry());
        let c = p.checkout(1).unwrap();
        p.checkin(1, c, 1); // 2 is now LRU
        assert!(matches!(p.import(3, carry(), 0), Import::Evicted(2)));
        // pinned resident session refuses an overwrite
        let _held = p.checkout(1).unwrap();
        assert!(matches!(p.import(1, carry(), 0), Import::InFlight(_)));
        // all pinned -> no capacity for a new id (carry handed back)
        let _held3 = p.checkout(3).unwrap();
        match p.import(4, carry(), 0) {
            Import::NoCapacity(c) => assert_eq!(c.l.len(), 8),
            _ => panic!("expected NoCapacity"),
        }
    }
}
