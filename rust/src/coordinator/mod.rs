//! Layer-3 coordinator: training driver + continuous-batching
//! inference server (session handles / token streams / scheduler /
//! state-pool / backpressure). This is where the paper's
//! "streaming-friendly, O(S d) state" claim becomes a serving system.

pub mod batcher;
pub mod beam;
pub mod queue;
pub mod sampling;
pub mod server;
pub mod session;
pub mod state;
pub mod trainer;

pub use batcher::{BatchPolicy, Batcher};
pub use beam::{beam_search, StepScorer};
pub use queue::BoundedQueue;
pub use sampling::Sampling;
pub use server::{FeedResult, Server, ServerOpts, ServerStats};
pub use session::{
    CarrySnapshot, FinishReason, GenOpts, GenResult, Session, SessionHandle, TokenStream,
};
pub use state::{Admit, Export, Import, StatePool};
pub use trainer::{
    eval_lm, load_checkpoint, load_checkpoint_for, load_checkpoint_meta, save_checkpoint,
    save_checkpoint_for_run, train_lm, CkptMeta, TrainOpts, TrainReport,
};
