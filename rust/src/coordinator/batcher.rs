//! Dynamic batcher: groups queue items into fixed-size batches under a
//! latency deadline.
//!
//! NOTE: the serving `Server` no longer uses this — its model thread
//! runs a continuous-batching scheduler that forms waves from whatever
//! is in flight each iteration (see `coordinator/server.rs`), which
//! strictly dominates deadline batching for that workload.
//! `Batcher`/`BatchPolicy` remain as a standalone queue primitive
//! (benches, property tests, and any future fixed-batch pipeline);
//! `ServerOpts::policy` is kept only for construction compatibility.
//!
//! Policy: block for the first item, then drain whatever else is queued
//! up to `max_batch` or until `max_wait` elapses.

use std::time::{Duration, Instant};

use crate::util::sync::Arc;

use super::queue::BoundedQueue;

#[derive(Clone, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(2) }
    }
}

pub struct Batcher<T> {
    queue: Arc<BoundedQueue<T>>,
    pub policy: BatchPolicy,
}

impl<T> Batcher<T> {
    pub fn new(queue: Arc<BoundedQueue<T>>, policy: BatchPolicy) -> Self {
        Batcher { queue, policy }
    }

    /// Next batch: blocks for the first element (None = queue closed),
    /// then fills greedily until max_batch or max_wait.
    pub fn next_batch(&self) -> Option<Vec<T>> {
        let first = self.queue.pop()?;
        let mut batch = vec![first];
        let deadline = Instant::now() + self.policy.max_wait;
        while batch.len() < self.policy.max_batch {
            let remaining = deadline.saturating_duration_since(Instant::now());
            if remaining.is_zero() {
                // final non-blocking sweep
                batch.extend(self.queue.drain_up_to(self.policy.max_batch - batch.len()));
                break;
            }
            match self.queue.pop_timeout(remaining) {
                Some(x) => batch.push(x),
                None => break,
            }
        }
        Some(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    fn setup(cap: usize, policy: BatchPolicy) -> (Arc<BoundedQueue<u32>>, Batcher<u32>) {
        let q = Arc::new(BoundedQueue::new(cap));
        let b = Batcher::new(Arc::clone(&q), policy);
        (q, b)
    }

    #[test]
    fn batches_up_to_max() {
        let (q, b) = setup(16, BatchPolicy { max_batch: 3, max_wait: Duration::from_millis(5) });
        for i in 0..7 {
            q.try_push(i).unwrap();
        }
        assert_eq!(b.next_batch().unwrap(), vec![0, 1, 2]);
        assert_eq!(b.next_batch().unwrap(), vec![3, 4, 5]);
        assert_eq!(b.next_batch().unwrap(), vec![6]);
    }

    #[test]
    fn deadline_returns_partial() {
        let (q, b) = setup(16, BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(10) });
        q.try_push(1).unwrap();
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch, vec![1]);
        assert!(t0.elapsed() < Duration::from_millis(200));
    }

    #[test]
    fn waits_for_first_item() {
        let (q, b) = setup(16, BatchPolicy::default());
        let qp = Arc::clone(&q);
        thread::spawn(move || {
            thread::sleep(Duration::from_millis(20));
            qp.try_push(9).unwrap();
        });
        assert_eq!(b.next_batch().unwrap(), vec![9]);
    }

    #[test]
    fn closed_queue_ends_batching() {
        let (q, b) = setup(16, BatchPolicy::default());
        q.try_push(1).unwrap();
        q.close();
        assert_eq!(b.next_batch().unwrap(), vec![1]);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn late_arrivals_join_within_window() {
        let (q, b) =
            setup(16, BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(50) });
        q.try_push(1).unwrap();
        let qp = Arc::clone(&q);
        thread::spawn(move || {
            thread::sleep(Duration::from_millis(5));
            qp.try_push(2).unwrap();
            qp.try_push(3).unwrap();
        });
        let batch = b.next_batch().unwrap();
        assert!(batch.len() >= 2, "late arrivals should join: {batch:?}");
    }
}
