//! Client-side surface of the continuous-batching server: session
//! handles and streamed tokens.
//!
//! [`crate::coordinator::Server::open_session`] returns a
//! [`SessionHandle`] — a cheap, thread-safe handle over one streaming
//! session's O(S·d) carry state. `feed` streams document tokens in,
//! `generate` returns a [`TokenStream`] that yields tokens *as the
//! model thread produces them* (an mpsc-backed iterator), `cancel`
//! stops an in-flight generation at the next wave boundary, and
//! `close` (or dropping the handle) releases the carry.
//!
//! Lifecycle:
//!
//!   open_session() ─ feed()* ─ generate() ─┬─ next()* ─ finish
//!                                          └─ cancel()
//!
//! A session's carry stays resident (and pinned against LRU eviction)
//! while a feed or generation is in flight; between calls it is idle
//! and evictable. If an idle session's state was evicted and a later
//! `generate` re-admits it, the stream reports `fresh_carry() == true`
//! — the generation started from a zero carry, not the fed context —
//! and `evicted()` names any victim this admission displaced, exactly
//! like `FeedResult::evicted` does on the feed path.

use crate::util::sync::mpsc;
use crate::util::sync::Arc;

use anyhow::{anyhow, Result};

use super::sampling::Sampling;
use super::server::{FeedResult, ServerCore};

/// A session's serializable state: the O(S·d) STLT carry plus the
/// served-token counter, as exported by
/// [`SessionHandle::export_carry`]. This is the unit of live migration
/// — a few hundred KiB at e2e scale (vs an O(N·d) KV cache), cheap to
/// ship over the wire and re-import bitwise on another worker.
#[derive(Clone, Debug, PartialEq)]
pub struct CarrySnapshot {
    pub l: Vec<f32>,
    pub u: Vec<f32>,
    pub l_shape: Vec<usize>,
    pub u_shape: Vec<usize>,
    /// Tokens served so far (feed + decode), carried for stats
    /// continuity on the importing worker.
    pub tokens_seen: u64,
}

impl CarrySnapshot {
    /// Bytes of carry state this snapshot ships (excluding shapes).
    pub fn state_bytes(&self) -> usize {
        (self.l.len() + self.u.len()) * 4
    }
}

/// Options for one generation through a [`SessionHandle`] (or the
/// blocking `Server::generate_with` wrapper).
#[derive(Clone, Debug, PartialEq)]
pub struct GenOpts {
    /// First input token. `feed` consumes tokens pairwise (input ->
    /// target) and leaves the final prompt token unconsumed; pass it
    /// here to continue the fed context.
    pub seed_token: i32,
    /// Maximum number of tokens to produce.
    pub max_tokens: usize,
    /// Stop after producing this token (it is included in the output).
    pub stop: Option<i32>,
    /// Sampling policy (greedy / temperature / top-k / nucleus).
    pub sampling: Sampling,
    /// RNG seed for reproducible stochastic decoding (xor'd with the
    /// session id, so concurrent sessions draw independent streams).
    pub rng_seed: u64,
}

impl Default for GenOpts {
    fn default() -> Self {
        GenOpts {
            seed_token: 0,
            max_tokens: 64,
            stop: None,
            sampling: Sampling::Greedy,
            rng_seed: 0,
        }
    }
}

/// Why a generation ended.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// Produced `max_tokens` tokens.
    MaxTokens,
    /// Produced the stop token (included in the output).
    Stop,
    /// Cancelled — explicitly, by dropping the [`TokenStream`], by
    /// releasing the session, or by server shutdown.
    Cancelled,
}

/// Completed generation: every streamed token plus the end-of-stream
/// metadata. The blocking `generate`/`generate_with` wrappers return
/// this directly; streaming callers get the same fields from
/// [`TokenStream`] accessors after the stream ends.
#[derive(Clone, Debug)]
pub struct GenResult {
    pub tokens: Vec<i32>,
    pub reason: FinishReason,
    /// Session id LRU-evicted when this generation (re)admitted its
    /// session — the generate-path analog of `FeedResult::evicted`.
    pub evicted: Option<u64>,
    /// True when the generation started from a freshly-admitted zero
    /// carry rather than resuming fed context — the signal that this
    /// session's own state had been evicted (or never fed). Before
    /// this surfaced, an evicted client silently got logits from a
    /// zero carry.
    pub fresh_carry: bool,
}

/// One item on the model-thread -> client stream channel.
pub(crate) enum StreamItem {
    /// Sent once, when the scheduler binds the session state to the
    /// generation (before the first token).
    Start { evicted: Option<u64>, fresh_carry: bool },
    Token(i32),
    End(Result<FinishReason>),
}

/// Streamed generation output: an iterator over tokens, delivered as
/// the continuous-batching scheduler produces them — the first token
/// arrives while the rest of the completion is still being decoded.
///
/// Iteration yields `Result<i32>`; an `Err` item reports a model-thread
/// failure (or server shutdown) and ends the stream. Dropping the
/// stream cancels the in-flight generation at the next wave boundary.
/// After the stream ends, [`TokenStream::finish_reason`],
/// [`TokenStream::evicted`] and [`TokenStream::fresh_carry`] expose the
/// end-of-stream metadata; [`TokenStream::wait`] collects everything
/// into a [`GenResult`].
pub struct TokenStream {
    rx: mpsc::Receiver<StreamItem>,
    evicted: Option<u64>,
    fresh_carry: bool,
    finished: Option<FinishReason>,
    failed: bool,
}

impl TokenStream {
    pub(crate) fn new(rx: mpsc::Receiver<StreamItem>) -> TokenStream {
        TokenStream { rx, evicted: None, fresh_carry: false, finished: None, failed: false }
    }

    /// Receive the next raw protocol item (Start/Token/End) without
    /// collapsing it into the iterator view. The wire layer relays
    /// these 1:1 into stream frames so remote clients see the same
    /// metadata (eviction, fresh-carry, finish reason) as local ones.
    /// `None` when the model thread dropped the channel mid-stream.
    pub(crate) fn recv_raw(&mut self) -> Option<StreamItem> {
        self.rx.recv().ok()
    }

    /// Block for the next token. `None` once the generation has
    /// finished (see [`TokenStream::finish_reason`]) or after an error
    /// has been yielded.
    pub fn recv(&mut self) -> Option<Result<i32>> {
        if self.finished.is_some() || self.failed {
            return None;
        }
        loop {
            match self.rx.recv() {
                Ok(StreamItem::Start { evicted, fresh_carry }) => {
                    self.evicted = evicted;
                    self.fresh_carry = fresh_carry;
                }
                Ok(StreamItem::Token(t)) => return Some(Ok(t)),
                Ok(StreamItem::End(Ok(reason))) => {
                    self.finished = Some(reason);
                    return None;
                }
                Ok(StreamItem::End(Err(e))) => {
                    self.failed = true;
                    return Some(Err(e));
                }
                Err(_) => {
                    self.failed = true;
                    return Some(Err(anyhow!("server shut down mid-generation")));
                }
            }
        }
    }

    /// Why the stream ended; `None` while it is still live (or if it
    /// ended in an error).
    pub fn finish_reason(&self) -> Option<FinishReason> {
        self.finished
    }

    /// Victim session LRU-evicted by this generation's admission.
    /// Populated once the scheduler has bound the session (always by
    /// the first received token).
    pub fn evicted(&self) -> Option<u64> {
        self.evicted
    }

    /// True when the generation started from a freshly-admitted zero
    /// carry (this session's own state was evicted, or it was never
    /// fed). Populated like [`TokenStream::evicted`].
    pub fn fresh_carry(&self) -> bool {
        self.fresh_carry
    }

    /// Drain the stream to completion and collect a [`GenResult`].
    pub fn wait(mut self) -> Result<GenResult> {
        let mut tokens = Vec::new();
        while let Some(item) = self.recv() {
            tokens.push(item?);
        }
        let reason = self
            .finished
            .ok_or_else(|| anyhow!("generation stream ended without a finish reason"))?;
        Ok(GenResult { tokens, reason, evicted: self.evicted, fresh_carry: self.fresh_carry })
    }
}

impl Iterator for TokenStream {
    type Item = Result<i32>;

    fn next(&mut self) -> Option<Result<i32>> {
        self.recv()
    }
}

/// Handle over one serving session. Cheap to clone-by-open (each
/// `open_session` allocates a fresh id); all methods are non-blocking
/// submissions except `feed`, which blocks until the server has
/// consumed the chunk (use multiple handles from multiple threads for
/// concurrency — the scheduler batches them into shared waves).
/// Dropping the handle releases the session's carry.
pub struct SessionHandle {
    id: u64,
    core: Arc<ServerCore>,
    released: bool,
}

impl SessionHandle {
    pub(crate) fn new(id: u64, core: Arc<ServerCore>) -> SessionHandle {
        SessionHandle { id, core, released: false }
    }

    pub fn id(&self) -> u64 {
        self.id
    }

    /// Stream a chunk of document tokens into the session. Blocking;
    /// concurrent feeds from other sessions share batched waves.
    pub fn feed(&self, tokens: Vec<i32>, count_loss: bool) -> Result<FeedResult> {
        self.core.feed(self.id, tokens, count_loss)
    }

    /// Start a generation; returns immediately with a [`TokenStream`]
    /// yielding tokens as the scheduler produces them.
    pub fn generate(&self, opts: GenOpts) -> Result<TokenStream> {
        self.core.start_generate(self.id, opts)
    }

    /// Convenience: run a generation to completion.
    pub fn generate_blocking(&self, opts: GenOpts) -> Result<GenResult> {
        self.generate(opts)?.wait()
    }

    /// Cancel the in-flight generation (if any) at the next wave
    /// boundary; its stream ends with [`FinishReason::Cancelled`].
    pub fn cancel(&self) -> Result<()> {
        self.core.cancel(self.id)
    }

    /// Release the session's carry state explicitly.
    pub fn close(mut self) -> Result<()> {
        self.released = true;
        self.core.release(self.id)
    }

    /// Export a copy of the session's carry for migration or
    /// client-side resume. Checkout-safe: fails while a feed or
    /// generation holds the carry (wait for the stream to finish or
    /// cancel first) and when the state was evicted.
    pub fn export_carry(&self) -> Result<CarrySnapshot> {
        self.core.export_carry(self.id)
    }

    /// Install an exported carry into this session, replacing whatever
    /// state it had (including none — an evicted or fresh session).
    /// Returns the victim id if the admission LRU-evicted a session.
    pub fn import_carry(&self, snap: CarrySnapshot) -> Result<Option<u64>> {
        self.core.import_carry(self.id, snap)
    }
}

impl Drop for SessionHandle {
    fn drop(&mut self) {
        if !self.released {
            let _ = self.core.release(self.id);
        }
    }
}

/// The one seam local and remote serving share: [`SessionHandle`]
/// (in-process) and `net::RemoteSession`/`net::RouterSession` (over
/// the wire) all implement it, so `stlt serve`, the benches, and the
/// soak tests drive either through the same code. Object-safe — the
/// CLI holds `Box<dyn Session>`.
pub trait Session: Send {
    fn session_id(&self) -> u64;
    /// Stream document tokens in (blocking until consumed).
    fn feed(&self, tokens: Vec<i32>, count_loss: bool) -> Result<FeedResult>;
    /// Start a generation; tokens stream back as they are produced.
    fn generate(&self, opts: GenOpts) -> Result<TokenStream>;
    /// Cancel the in-flight generation at the next wave boundary.
    fn cancel(&self) -> Result<()>;
    /// Export the session's carry (refused while a wave holds it).
    fn export_carry(&self) -> Result<CarrySnapshot>;
    /// Install an exported carry; returns any LRU-evicted victim.
    fn import_carry(&self, snap: CarrySnapshot) -> Result<Option<u64>>;
    /// Release the session's state. `&mut self` (not `self`) keeps the
    /// trait object-safe; implementations make a later drop a no-op.
    fn close(&mut self) -> Result<()>;

    /// Convenience: run a generation to completion.
    fn generate_blocking(&self, opts: GenOpts) -> Result<GenResult> {
        self.generate(opts)?.wait()
    }
}

impl Session for SessionHandle {
    fn session_id(&self) -> u64 {
        self.id
    }

    fn feed(&self, tokens: Vec<i32>, count_loss: bool) -> Result<FeedResult> {
        SessionHandle::feed(self, tokens, count_loss)
    }

    fn generate(&self, opts: GenOpts) -> Result<TokenStream> {
        SessionHandle::generate(self, opts)
    }

    fn cancel(&self) -> Result<()> {
        SessionHandle::cancel(self)
    }

    fn export_carry(&self) -> Result<CarrySnapshot> {
        SessionHandle::export_carry(self)
    }

    fn import_carry(&self, snap: CarrySnapshot) -> Result<Option<u64>> {
        SessionHandle::import_carry(self, snap)
    }

    fn close(&mut self) -> Result<()> {
        self.released = true;
        self.core.release(self.id)
    }
}
