//! Training driver: owns TrainState, feeds batches from the synthetic
//! corpus through a `train_step` executable, logs metrics, runs
//! periodic held-out evaluation, and checkpoints (own binary format).
//!
//! The driver is backend-agnostic: on the xla backend the LR schedule,
//! AdamW and gradient clipping live *inside* the lowered HLO
//! (python/compile/optim.py); on the native backend the same contract
//! is implemented by [`crate::train`] (hand-derived backward pass +
//! pure-Rust AdamW + data-parallel gradient accumulation), so
//! `train_lm` runs unchanged on either.
//!
//! Checkpoints record the artifact name and parameter count (format
//! v2); `load_checkpoint_for` fails fast instead of silently binding a
//! wrong-shaped flat vector. Resuming is exact: the batch stream is
//! fast-forwarded to the checkpoint step, so a resumed run is bitwise
//! identical to an uninterrupted one.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::data::batch::LmBatcher;
use crate::data::corpus::CorpusConfig;
use crate::metrics::{perplexity, OnlineStats};
use crate::runtime::{EvalStep, Manifest, Runtime, TrainState, TrainStep};

pub struct TrainOpts {
    /// target total step count (a resumed run continues up to this)
    pub steps: u64,
    pub log_every: u64,
    pub eval_every: u64,
    pub eval_batches: u64,
    pub seed: u64,
    pub checkpoint: Option<String>,
    /// checkpoint to resume from (validated against the artifact)
    pub resume: Option<String>,
    pub domain: u64,
    /// every N steps: refresh the per-node `node/` gauges from the live
    /// weights and log a one-line metrics digest (0 = off)
    pub metrics_every: u64,
}

impl Default for TrainOpts {
    fn default() -> Self {
        TrainOpts {
            steps: 200,
            log_every: 20,
            eval_every: 100,
            eval_batches: 4,
            seed: 0,
            checkpoint: None,
            resume: None,
            domain: 0,
            metrics_every: 0,
        }
    }
}

#[derive(Clone, Debug)]
pub struct TrainReport {
    /// (step, train loss) every log_every
    pub loss_curve: Vec<(u64, f32)>,
    /// (step, valid ppl)
    pub eval_curve: Vec<(u64, f64)>,
    pub final_ppl: f64,
    pub final_s_eff: f32,
    pub tokens_per_s: f64,
    pub steps_done: u64,
}

/// Train `artifact` (e.g. "lm_stlt_tiny") for opts.steps; returns the
/// report. `eval_artifact` defaults to the matching ".eval" entry.
pub fn train_lm(
    rt: &Runtime,
    manifest: &Manifest,
    artifact_base: &str,
    opts: &TrainOpts,
) -> Result<TrainReport> {
    let step_exec = TrainStep::new(rt, manifest, &format!("{artifact_base}.train"))?;
    let eval_exec = EvalStep::new(rt, manifest, &format!("{artifact_base}.eval"))?;
    let entry = step_exec.entry();
    let vocab = entry.config.vocab.max(8);

    let mut state = match &opts.resume {
        Some(path) => {
            let p = Path::new(path);
            let (st, meta) = load_checkpoint_meta(p)?;
            validate_ckpt(p, &st, &meta, artifact_base, entry.param_count)?;
            // a resumed run replays the original batch stream; a different
            // seed/domain would silently train on different data
            if let Some((seed, domain)) = meta.as_ref().and_then(|m| m.train_stream) {
                if (seed, domain) != (opts.seed, opts.domain) {
                    bail!(
                        "{path}: checkpoint was trained with --seed {seed} --domain \
                         {domain}; resume with those (got --seed {} --domain {})",
                        opts.seed,
                        opts.domain
                    );
                }
            }
            crate::info!("train", "{artifact_base}: resumed {path} at step {}", st.step);
            st
        }
        None => TrainState::init_for(entry, opts.seed)?,
    };
    let start = state.step.max(0) as u64;
    if start > opts.steps {
        bail!(
            "{artifact_base}: checkpoint is at step {start}, beyond --steps {}",
            opts.steps
        );
    }
    let mut cfg = CorpusConfig::default_for_vocab(vocab);
    cfg.domain = opts.domain;
    let mut train_data =
        LmBatcher::new(cfg.clone(), opts.seed ^ 0x7261, step_exec.batch, step_exec.n_plus_1);
    // fast-forward the deterministic batch stream so a resumed run sees
    // exactly the batches an uninterrupted run would
    for _ in 0..start {
        train_data.next_batch();
    }

    // surface the backward-tape budget up front: at long contexts the
    // tape (plus the O(n·vocab) softmax scratch on top of it) is what
    // decides whether the run fits in RAM
    #[cfg(feature = "native")]
    {
        let mcfg = &entry.config;
        // only the native backward has this tape (the XLA backward is
        // whatever the lowered HLO does and ignores grad_ckpt_segment)
        if mcfg.arch == "stlt" && rt.platform() == "native" {
            let n = step_exec.n_plus_1.saturating_sub(1);
            let bytes = crate::train::tape_bytes(mcfg, n);
            crate::obs::gauge("train/tape_bytes").set(bytes as f64);
            crate::info!(
                "train",
                "{artifact_base}: activation tape {:.1} MiB/row + transient grad scratch \
                 (grad_ckpt_segment {} of {n} tok)",
                bytes as f64 / (1024.0 * 1024.0),
                crate::train::seg_len(mcfg, n),
            );
        }
    }

    let mut report = TrainReport {
        loss_curve: Vec::new(),
        eval_curve: Vec::new(),
        final_ppl: f64::NAN,
        final_s_eff: 0.0,
        tokens_per_s: 0.0,
        steps_done: 0,
    };
    let mut loss_window = OnlineStats::new();
    let mut s_eff_last = 0.0f32;
    let t0 = std::time::Instant::now();
    let tokens_per_step = (step_exec.batch * (step_exec.n_plus_1 - 1)) as f64;

    for step in start..opts.steps {
        let tokens = train_data.next_batch();
        let m = step_exec.run(&mut state, &tokens, (opts.seed as i32) ^ (step as i32))?;
        if !m.loss.is_finite() {
            bail!("{artifact_base}: non-finite loss at step {step}");
        }
        loss_window.push(m.loss as f64);
        s_eff_last = m.s_eff;
        // adaptive runs: surface the annealed Gumbel-sigmoid temperature
        // (the native backend derives the same value from the step)
        #[cfg(feature = "native")]
        let gum_temp = if entry.config.adaptive {
            let t = crate::train::gumbel_temp_at(&entry.config, step as i32);
            crate::obs::gauge("train/gumbel_temp").set(t as f64);
            Some(t)
        } else {
            None
        };
        #[cfg(not(feature = "native"))]
        let gum_temp: Option<f32> = None;
        if (opts.log_every > 0 && (step + 1) % opts.log_every == 0) || step + 1 == opts.steps {
            let temp_part =
                gum_temp.map_or(String::new(), |t| format!(" gumbel_temp {t:.3}"));
            crate::info!(
                "train",
                "{artifact_base} step {:4}/{} loss {:.4} ce {:.4} s_eff {:.1}{}",
                step + 1,
                opts.steps,
                loss_window.mean(),
                m.ce,
                m.s_eff,
                temp_part
            );
            report.loss_curve.push((step + 1, loss_window.mean() as f32));
            loss_window = OnlineStats::new();
        }
        if opts.eval_every > 0 && (step + 1) % opts.eval_every == 0 {
            let ppl = eval_lm(&eval_exec, &state.flat, &cfg, opts, 0.0)?;
            crate::info!("train", "{artifact_base} step {:4} valid ppl {:.3}", step + 1, ppl);
            report.eval_curve.push((step + 1, ppl));
        }
        if opts.metrics_every > 0 && (step + 1) % opts.metrics_every == 0 {
            // the interpretability heartbeat: per-node sigma/omega/T and
            // half-life gauges track the weights as they train
            #[cfg(feature = "native")]
            crate::runtime::native_stlt::publish_node_gauges(&entry.config, &state.flat);
            crate::info!("train", "metrics: {}", crate::obs::summary_line());
        }
        report.steps_done = step + 1;
    }
    report.tokens_per_s =
        tokens_per_step * (opts.steps - start) as f64 / t0.elapsed().as_secs_f64();
    report.final_ppl = eval_lm(&eval_exec, &state.flat, &cfg, opts, 0.0)?;
    report.final_s_eff = s_eff_last;
    if let Some(path) = &opts.checkpoint {
        save_checkpoint_for_run(Path::new(path), &state, artifact_base, opts.seed, opts.domain)?;
        crate::info!("train", "checkpoint -> {path}");
    }
    Ok(report)
}

/// Held-out perplexity on a disjoint stream (seed offset), with optional
/// embedding noise (the §4.7 robustness knob — executed inside the HLO).
pub fn eval_lm(
    eval_exec: &EvalStep,
    flat: &[f32],
    corpus_cfg: &CorpusConfig,
    opts: &TrainOpts,
    noise_std: f32,
) -> Result<f64> {
    let mut data = LmBatcher::new(
        corpus_cfg.clone(),
        opts.seed ^ 0xE7A1, // disjoint from training streams
        eval_exec.batch,
        eval_exec.n_plus_1,
    );
    // upload the frozen weights once (§Perf L3-1) instead of copying the
    // full parameter vector on every batch
    let params = eval_exec.upload(flat)?;
    let mut nll = 0.0;
    let mut count = 0.0;
    for i in 0..opts.eval_batches {
        let tokens = data.next_batch();
        let (n, c, _seff) = eval_exec.run_h(&params, &tokens, noise_std, i as i32)?;
        nll += n;
        count += c;
    }
    Ok(perplexity(nll, count))
}

// ---------------------------------------------------------------------------
// Checkpoints: magic + version + step + param_count + artifact name +
// optional training-stream (seed, domain) (v2), then flat/m/v raw LE
// f32. v1 files (no metadata) still load; validation then only covers
// the parameter count.
// ---------------------------------------------------------------------------

const CKPT_MAGIC: &[u8; 8] = b"STLTCKPT";

/// Metadata recorded alongside a checkpoint (format v2).
#[derive(Clone, Debug)]
pub struct CkptMeta {
    /// artifact base name the state was trained for
    pub artifact: String,
    /// (seed, domain) of the training data stream when the writer was
    /// `train_lm`; resume validates these so the "bitwise identical to
    /// an uninterrupted run" guarantee cannot be silently broken
    pub train_stream: Option<(u64, u64)>,
}

fn write_checkpoint(path: &Path, state: &TrainState, meta: &CkptMeta) -> Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    let mut f = std::fs::File::create(path).with_context(|| format!("{}", path.display()))?;
    f.write_all(CKPT_MAGIC)?;
    f.write_all(&2u32.to_le_bytes())?;
    f.write_all(&state.step.to_le_bytes())?;
    f.write_all(&(state.flat.len() as u64).to_le_bytes())?;
    let name = meta.artifact.as_bytes();
    f.write_all(&(name.len() as u32).to_le_bytes())?;
    f.write_all(name)?;
    let (has_stream, seed, domain) = match meta.train_stream {
        Some((s, d)) => (1u8, s, d),
        None => (0u8, 0, 0),
    };
    f.write_all(&[has_stream])?;
    f.write_all(&seed.to_le_bytes())?;
    f.write_all(&domain.to_le_bytes())?;
    for vec in [&state.flat, &state.m, &state.v] {
        let bytes: Vec<u8> = vec.iter().flat_map(|x| x.to_le_bytes()).collect();
        f.write_all(&bytes)?;
    }
    Ok(())
}

/// Save a checkpoint with no training-stream metadata (generic writers:
/// experiment harnesses, seq2seq loops). `train_lm` uses
/// [`save_checkpoint_for_run`] so resume can be validated.
pub fn save_checkpoint(path: &Path, state: &TrainState, artifact: &str) -> Result<()> {
    write_checkpoint(
        path,
        state,
        &CkptMeta { artifact: artifact.to_string(), train_stream: None },
    )
}

/// Save a checkpoint recording the training-stream (seed, domain).
pub fn save_checkpoint_for_run(
    path: &Path,
    state: &TrainState,
    artifact: &str,
    seed: u64,
    domain: u64,
) -> Result<()> {
    write_checkpoint(
        path,
        state,
        &CkptMeta { artifact: artifact.to_string(), train_stream: Some((seed, domain)) },
    )
}

/// Load a checkpoint plus its recorded metadata (None for v1 files).
pub fn load_checkpoint_meta(path: &Path) -> Result<(TrainState, Option<CkptMeta>)> {
    let mut f = std::fs::File::open(path).with_context(|| format!("{}", path.display()))?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != CKPT_MAGIC {
        bail!("{}: not an STLT checkpoint", path.display());
    }
    let mut u32b = [0u8; 4];
    f.read_exact(&mut u32b)?;
    let version = u32::from_le_bytes(u32b);
    if version != 1 && version != 2 {
        bail!("unsupported checkpoint version {version}");
    }
    f.read_exact(&mut u32b)?;
    let step = i32::from_le_bytes(u32b);
    let mut u64b = [0u8; 8];
    f.read_exact(&mut u64b)?;
    let n = u64::from_le_bytes(u64b) as usize;
    let meta = if version >= 2 {
        f.read_exact(&mut u32b)?;
        let len = u32::from_le_bytes(u32b) as usize;
        if len > 4096 {
            bail!("{}: corrupt checkpoint (artifact name {len} bytes)", path.display());
        }
        let mut name = vec![0u8; len];
        f.read_exact(&mut name)?;
        let artifact =
            String::from_utf8(name).context("checkpoint artifact name not UTF-8")?;
        let mut flag = [0u8; 1];
        f.read_exact(&mut flag)?;
        f.read_exact(&mut u64b)?;
        let seed = u64::from_le_bytes(u64b);
        f.read_exact(&mut u64b)?;
        let domain = u64::from_le_bytes(u64b);
        let train_stream = if flag[0] == 1 { Some((seed, domain)) } else { None };
        Some(CkptMeta { artifact, train_stream })
    } else {
        None
    };
    let mut read_vec = |n: usize| -> Result<Vec<f32>> {
        let mut buf = vec![0u8; n * 4];
        f.read_exact(&mut buf)?;
        Ok(buf.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
    };
    let flat = read_vec(n)?;
    let m = read_vec(n)?;
    let v = read_vec(n)?;
    Ok((TrainState { flat, m, v, step }, meta))
}

pub fn load_checkpoint(path: &Path) -> Result<TrainState> {
    Ok(load_checkpoint_meta(path)?.0)
}

fn validate_ckpt(
    path: &Path,
    state: &TrainState,
    meta: &Option<CkptMeta>,
    artifact: &str,
    param_count: usize,
) -> Result<()> {
    if let Some(meta) = meta {
        if meta.artifact != artifact {
            bail!(
                "{}: checkpoint was trained for artifact '{}', not '{artifact}' \
                 (pass the matching --artifact, or retrain)",
                path.display(),
                meta.artifact
            );
        }
    }
    if state.flat.len() != param_count {
        bail!(
            "{}: checkpoint has {} params but artifact '{artifact}' needs {param_count} \
             (model shape changed since this checkpoint was written?)",
            path.display(),
            state.flat.len()
        );
    }
    Ok(())
}

/// Load a checkpoint for a specific artifact, failing with a clear
/// error when the recorded artifact name or the parameter count does
/// not match — instead of silently binding a wrong-shaped flat vector.
pub fn load_checkpoint_for(
    path: &Path,
    artifact: &str,
    param_count: usize,
) -> Result<TrainState> {
    let (state, meta) = load_checkpoint_meta(path)?;
    validate_ckpt(path, &state, &meta, artifact, param_count)?;
    Ok(state)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checkpoint_roundtrip() {
        let state = TrainState {
            flat: vec![1.0, -2.5, 3.25],
            m: vec![0.1, 0.2, 0.3],
            v: vec![4.0, 5.0, 6.0],
            step: 42,
        };
        let path = std::env::temp_dir().join("stlt_ckpt_test.bin");
        save_checkpoint(&path, &state, "lm_demo").unwrap();
        let loaded = load_checkpoint(&path).unwrap();
        assert_eq!(loaded.step, 42);
        assert_eq!(loaded.flat, state.flat);
        assert_eq!(loaded.m, state.m);
        assert_eq!(loaded.v, state.v);
        let (_, meta) = load_checkpoint_meta(&path).unwrap();
        let meta = meta.unwrap();
        assert_eq!(meta.artifact, "lm_demo");
        assert_eq!(meta.train_stream, None);

        save_checkpoint_for_run(&path, &state, "lm_demo", 7, 3).unwrap();
        let (_, meta) = load_checkpoint_meta(&path).unwrap();
        assert_eq!(meta.unwrap().train_stream, Some((7, 3)));
    }

    #[test]
    fn checkpoint_for_rejects_mismatches() {
        let state = TrainState {
            flat: vec![1.0, 2.0],
            m: vec![0.0; 2],
            v: vec![0.0; 2],
            step: 1,
        };
        let path = std::env::temp_dir().join("stlt_ckpt_mismatch.bin");
        save_checkpoint(&path, &state, "lm_a").unwrap();
        assert!(load_checkpoint_for(&path, "lm_a", 2).is_ok());
        let err = format!("{:#}", load_checkpoint_for(&path, "lm_b", 2).unwrap_err());
        assert!(err.contains("lm_a") && err.contains("lm_b"), "unhelpful: {err}");
        let err = format!("{:#}", load_checkpoint_for(&path, "lm_a", 3).unwrap_err());
        assert!(err.contains('3') && err.contains('2'), "unhelpful: {err}");
    }

    #[test]
    fn loads_v1_checkpoints_without_metadata() {
        // PR-1-era format: magic, version=1, step, n, flat/m/v — no
        // artifact name or stream block. Pin backward compatibility.
        let path = std::env::temp_dir().join("stlt_ckpt_v1.bin");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"STLTCKPT");
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&7i32.to_le_bytes());
        bytes.extend_from_slice(&2u64.to_le_bytes());
        for v in [1.5f32, -2.0, 0.1, 0.2, 3.0, 4.0] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        std::fs::write(&path, bytes).unwrap();
        let (st, meta) = load_checkpoint_meta(&path).unwrap();
        assert!(meta.is_none(), "v1 files carry no metadata");
        assert_eq!(st.step, 7);
        assert_eq!(st.flat, vec![1.5, -2.0]);
        assert_eq!(st.m, vec![0.1, 0.2]);
        assert_eq!(st.v, vec![3.0, 4.0]);
        // *_for validation on a v1 file only checks the param count
        assert!(load_checkpoint_for(&path, "anything", 2).is_ok());
        assert!(load_checkpoint_for(&path, "anything", 3).is_err());
    }

    #[test]
    fn rejects_bad_magic() {
        let path = std::env::temp_dir().join("stlt_ckpt_bad.bin");
        std::fs::write(&path, b"NOTACKPTxxxxxxxxxxxx").unwrap();
        assert!(load_checkpoint(&path).is_err());
    }
}
