//! Training driver: owns TrainState, feeds batches from the synthetic
//! corpus through the AOT `train_step` artifact, logs metrics, runs
//! periodic held-out evaluation, and checkpoints (own binary format).
//!
//! The LR schedule, AdamW and gradient clipping live *inside* the HLO
//! (python/compile/optim.py), so training requires an xla-backed
//! [`Runtime`] (`--features xla`); the driver itself is backend-agnostic
//! and fails fast with a clear error on backends without `train_step`
//! support.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::data::batch::LmBatcher;
use crate::data::corpus::CorpusConfig;
use crate::metrics::{perplexity, OnlineStats};
use crate::runtime::{EvalStep, Manifest, Runtime, TrainState, TrainStep};

pub struct TrainOpts {
    pub steps: u64,
    pub log_every: u64,
    pub eval_every: u64,
    pub eval_batches: u64,
    pub seed: u64,
    pub checkpoint: Option<String>,
    pub domain: u64,
}

impl Default for TrainOpts {
    fn default() -> Self {
        TrainOpts {
            steps: 200,
            log_every: 20,
            eval_every: 100,
            eval_batches: 4,
            seed: 0,
            checkpoint: None,
            domain: 0,
        }
    }
}

#[derive(Clone, Debug)]
pub struct TrainReport {
    /// (step, train loss) every log_every
    pub loss_curve: Vec<(u64, f32)>,
    /// (step, valid ppl)
    pub eval_curve: Vec<(u64, f64)>,
    pub final_ppl: f64,
    pub final_s_eff: f32,
    pub tokens_per_s: f64,
    pub steps_done: u64,
}

/// Train `artifact` (e.g. "lm_stlt_tiny") for opts.steps; returns the
/// report. `eval_artifact` defaults to the matching ".eval" entry.
pub fn train_lm(
    rt: &Runtime,
    manifest: &Manifest,
    artifact_base: &str,
    opts: &TrainOpts,
) -> Result<TrainReport> {
    if rt.backend_kind() == crate::runtime::BackendKind::Native {
        bail!(
            "training executes the AOT optimiser graph and requires the \
             xla backend (run with --backend xla on a build with \
             --features xla)"
        );
    }
    let step_exec = TrainStep::new(rt, manifest, &format!("{artifact_base}.train"))?;
    let eval_exec = EvalStep::new(rt, manifest, &format!("{artifact_base}.eval"))?;
    let entry = step_exec.entry();
    let vocab = entry.config.vocab.max(8);

    let mut state = TrainState::from_entry(entry)?;
    let mut cfg = CorpusConfig::default_for_vocab(vocab);
    cfg.domain = opts.domain;
    let mut train_data =
        LmBatcher::new(cfg.clone(), opts.seed ^ 0x7261, step_exec.batch, step_exec.n_plus_1);

    let mut report = TrainReport {
        loss_curve: Vec::new(),
        eval_curve: Vec::new(),
        final_ppl: f64::NAN,
        final_s_eff: 0.0,
        tokens_per_s: 0.0,
        steps_done: 0,
    };
    let mut loss_window = OnlineStats::new();
    let mut s_eff_last = 0.0f32;
    let t0 = std::time::Instant::now();
    let tokens_per_step = (step_exec.batch * (step_exec.n_plus_1 - 1)) as f64;

    for step in 0..opts.steps {
        let tokens = train_data.next_batch();
        let m = step_exec.run(&mut state, &tokens, (opts.seed as i32) ^ (step as i32))?;
        if !m.loss.is_finite() {
            bail!("{artifact_base}: non-finite loss at step {step}");
        }
        loss_window.push(m.loss as f64);
        s_eff_last = m.s_eff;
        if (step + 1) % opts.log_every == 0 || step + 1 == opts.steps {
            crate::info!(
                "train",
                "{artifact_base} step {:4}/{} loss {:.4} ce {:.4} s_eff {:.1}",
                step + 1,
                opts.steps,
                loss_window.mean(),
                m.ce,
                m.s_eff
            );
            report.loss_curve.push((step + 1, loss_window.mean() as f32));
            loss_window = OnlineStats::new();
        }
        if opts.eval_every > 0 && (step + 1) % opts.eval_every == 0 {
            let ppl = eval_lm(&eval_exec, &state.flat, &cfg, opts, 0.0)?;
            crate::info!("train", "{artifact_base} step {:4} valid ppl {:.3}", step + 1, ppl);
            report.eval_curve.push((step + 1, ppl));
        }
        report.steps_done = step + 1;
    }
    report.tokens_per_s = tokens_per_step * opts.steps as f64 / t0.elapsed().as_secs_f64();
    report.final_ppl = eval_lm(&eval_exec, &state.flat, &cfg, opts, 0.0)?;
    report.final_s_eff = s_eff_last;
    if let Some(path) = &opts.checkpoint {
        save_checkpoint(Path::new(path), &state)?;
        crate::info!("train", "checkpoint -> {path}");
    }
    Ok(report)
}

/// Held-out perplexity on a disjoint stream (seed offset), with optional
/// embedding noise (the §4.7 robustness knob — executed inside the HLO).
pub fn eval_lm(
    eval_exec: &EvalStep,
    flat: &[f32],
    corpus_cfg: &CorpusConfig,
    opts: &TrainOpts,
    noise_std: f32,
) -> Result<f64> {
    let mut data = LmBatcher::new(
        corpus_cfg.clone(),
        opts.seed ^ 0xE7A1, // disjoint from training streams
        eval_exec.batch,
        eval_exec.n_plus_1,
    );
    // upload the frozen weights once (§Perf L3-1) instead of copying the
    // full parameter vector on every batch
    let params = eval_exec.upload(flat)?;
    let mut nll = 0.0;
    let mut count = 0.0;
    for i in 0..opts.eval_batches {
        let tokens = data.next_batch();
        let (n, c, _seff) = eval_exec.run_h(&params, &tokens, noise_std, i as i32)?;
        nll += n;
        count += c;
    }
    Ok(perplexity(nll, count))
}

// ---------------------------------------------------------------------------
// Checkpoints: magic + version + step + param_count + flat/m/v raw LE f32
// ---------------------------------------------------------------------------

const CKPT_MAGIC: &[u8; 8] = b"STLTCKPT";

pub fn save_checkpoint(path: &Path, state: &TrainState) -> Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    let mut f = std::fs::File::create(path).with_context(|| format!("{}", path.display()))?;
    f.write_all(CKPT_MAGIC)?;
    f.write_all(&1u32.to_le_bytes())?;
    f.write_all(&state.step.to_le_bytes())?;
    f.write_all(&(state.flat.len() as u64).to_le_bytes())?;
    for vec in [&state.flat, &state.m, &state.v] {
        let bytes: Vec<u8> = vec.iter().flat_map(|x| x.to_le_bytes()).collect();
        f.write_all(&bytes)?;
    }
    Ok(())
}

pub fn load_checkpoint(path: &Path) -> Result<TrainState> {
    let mut f = std::fs::File::open(path).with_context(|| format!("{}", path.display()))?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != CKPT_MAGIC {
        bail!("{}: not an STLT checkpoint", path.display());
    }
    let mut u32b = [0u8; 4];
    f.read_exact(&mut u32b)?;
    let version = u32::from_le_bytes(u32b);
    if version != 1 {
        bail!("unsupported checkpoint version {version}");
    }
    f.read_exact(&mut u32b)?;
    let step = i32::from_le_bytes(u32b);
    let mut u64b = [0u8; 8];
    f.read_exact(&mut u64b)?;
    let n = u64::from_le_bytes(u64b) as usize;
    let mut read_vec = |n: usize| -> Result<Vec<f32>> {
        let mut buf = vec![0u8; n * 4];
        f.read_exact(&mut buf)?;
        Ok(buf.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
    };
    let flat = read_vec(n)?;
    let m = read_vec(n)?;
    let v = read_vec(n)?;
    Ok(TrainState { flat, m, v, step })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checkpoint_roundtrip() {
        let state = TrainState {
            flat: vec![1.0, -2.5, 3.25],
            m: vec![0.1, 0.2, 0.3],
            v: vec![4.0, 5.0, 6.0],
            step: 42,
        };
        let path = std::env::temp_dir().join("stlt_ckpt_test.bin");
        save_checkpoint(&path, &state).unwrap();
        let loaded = load_checkpoint(&path).unwrap();
        assert_eq!(loaded.step, 42);
        assert_eq!(loaded.flat, state.flat);
        assert_eq!(loaded.m, state.m);
        assert_eq!(loaded.v, state.v);
    }

    #[test]
    fn rejects_bad_magic() {
        let path = std::env::temp_dir().join("stlt_ckpt_bad.bin");
        std::fs::write(&path, b"NOTACKPTxxxxxxxxxxxx").unwrap();
        assert!(load_checkpoint(&path).is_err());
    }
}
